"""AOT path: HLO-text lowering + manifest emission."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp

from compile import aot, model

jax.config.update("jax_platform_name", "cpu")


def test_to_hlo_text_produces_parseable_module():
    fn = lambda x, y: (x @ y + 2.0,)
    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    hlo = aot.to_hlo_text(fn, [spec, spec])
    assert "HloModule" in hlo
    assert "f32[2,2]" in hlo
    # return_tuple=True → the entry root is a tuple.
    assert "tuple(" in hlo or ") tuple" in hlo


def test_dtype_and_shape_formatting():
    import numpy as np

    assert aot.dtype_name(np.dtype(np.float32)) == "f32"
    assert aot.dtype_name(np.dtype(np.int32)) == "i32"
    assert aot.shape_str(()) == "scalar"
    assert aot.shape_str((256, 32)) == "256x32"


def test_full_aot_run_writes_manifest(tmp_path):
    # Lower only the two small models to keep the test fast.
    out = tmp_path / "artifacts"
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out-dir", str(out),
         "--only", "walker_act"],
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(aot.__file__))),
        capture_output=True, text=True, env=env, timeout=300,
    )
    assert r.returncode == 0, r.stderr
    manifest = (out / "manifest.txt").read_text()
    assert "model walker_act walker_act.hlo.txt" in manifest
    assert f"input walker_act 0 f32 {model.WALKER_DIM}" in manifest
    assert f"input walker_act 1 f32 {model.ACT_BATCH}x24" in manifest
    assert f"output walker_act 0 f32 {model.ACT_BATCH}x4" in manifest
    hlo = (out / "walker_act.hlo.txt").read_text()
    assert "HloModule" in hlo


def test_manifest_matches_eval_shapes():
    # Every declared signature must lower without error through eval_shape
    # (cheap structural check; the full lowering is covered above and by
    # `make artifacts`).
    for name, (fn, inputs) in model.signatures().items():
        outs = jax.tree_util.tree_leaves(jax.eval_shape(fn, *inputs))
        for o in outs:
            assert o.dtype in (jnp.float32, jnp.int32), name
