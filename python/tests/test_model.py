"""L2 correctness: graph semantics + the Rust interop contract."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---- layout contract (locked against rust/src/algo/nn.rs) ------------------


def test_param_counts_match_rust_constants():
    assert model.WALKER_DIM == 2804
    assert model.PPO_DIM == 6597


def test_unpack_roundtrip_walker():
    flat = jnp.arange(model.WALKER_DIM, dtype=jnp.float32)
    layers, off = model.unpack_mlp(flat, model.WALKER_SIZES)
    assert off == model.WALKER_DIM
    # First weight element is flat[0]; layout is W then b per layer.
    w1, b1 = layers[0]
    assert w1.shape == (24, 40)
    assert float(w1[0, 0]) == 0.0
    assert float(w1[0, 1]) == 1.0          # row-major (in, out)
    assert float(b1[0]) == 24 * 40         # bias follows its W


def test_unpack_ppo_offsets():
    flat = jnp.arange(model.PPO_DIM, dtype=jnp.float32)
    trunk, wp, bp, wv, bv = model.unpack_ppo(flat)
    assert trunk[0][0].shape == (32, 64)
    assert wp.shape == (64, 4)
    assert wv.shape == (64,)
    assert float(bv) == model.PPO_DIM - 1  # value bias is the final scalar


# ---- walker_act -------------------------------------------------------------


def test_walker_act_shape_and_range():
    params = rand(0, (model.WALKER_DIM,), 0.2)
    obs = rand(1, (model.ACT_BATCH, 24))
    (act,) = model.walker_act(params, obs)
    assert act.shape == (model.ACT_BATCH, 4)
    assert float(jnp.max(jnp.abs(act))) <= 1.0  # tanh output


# ---- es_update --------------------------------------------------------------


def es_inputs(seed, pop=model.ES_POP, dim=model.WALKER_DIM):
    return dict(
        theta=rand(seed, (dim,), 0.1),
        noise=rand(seed + 1, (pop, dim)),
        rewards=rand(seed + 2, (pop,), 5.0),
        m=jnp.zeros(dim),
        v=jnp.zeros(dim),
        t=jnp.array(1.0),
        lr=jnp.array(0.02),
        sigma=jnp.array(0.05),
    )


def test_es_update_matches_composed_reference():
    kw = es_inputs(10)
    theta2, m2, v2, gnorm = model.es_update(**kw)
    ranks = ref.centered_ranks(kw["rewards"])
    grad = ref.es_combine(ranks, kw["noise"], float(kw["sigma"]))
    t_ref, m_ref, v_ref = ref.adam(
        kw["theta"], kw["m"], kw["v"], grad, 1.0, float(kw["lr"])
    )
    np.testing.assert_allclose(theta2, t_ref, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(m2, m_ref, rtol=1e-4, atol=1e-6)
    np.testing.assert_allclose(v2, v_ref, rtol=1e-4, atol=1e-7)
    np.testing.assert_allclose(gnorm, jnp.linalg.norm(grad), rtol=1e-4)


def test_es_update_moves_toward_better_candidates():
    # Make reward = +noise·direction: the update must move θ along direction.
    dim = model.WALKER_DIM
    pop = model.ES_POP
    direction = jnp.zeros(dim).at[7].set(1.0)
    noise = rand(3, (pop, dim))
    rewards = noise @ direction
    kw = es_inputs(4)
    kw["noise"], kw["rewards"] = noise, rewards
    theta2, *_ = model.es_update(**kw)
    delta = theta2 - kw["theta"]
    assert float(delta[7]) > 0.0, "θ must move along the rewarded direction"
    # ... and dominate the movement of unrelated coordinates on average.
    assert abs(float(delta[7])) >= float(jnp.abs(delta).mean())


# ---- ppo graphs -------------------------------------------------------------


def test_ppo_act_matches_jnp_forward():
    params = rand(5, (model.PPO_DIM,), 0.2)
    obs = rand(6, (model.PPO_BATCH, 32))
    logits, values = model.ppo_act(params, obs)
    rl, rv = model.ppo_forward_jnp(params, obs)
    np.testing.assert_allclose(logits, rl, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(values, rv, rtol=1e-5, atol=1e-6)


def ppo_inputs(seed):
    b = model.PPO_BATCH
    key = jax.random.PRNGKey(seed + 100)
    return dict(
        params=rand(seed, (model.PPO_DIM,), 0.2),
        m=jnp.zeros(model.PPO_DIM),
        v=jnp.zeros(model.PPO_DIM),
        t=jnp.array(1.0),
        obs=rand(seed + 1, (b, 32)),
        actions=jax.random.randint(key, (b,), 0, 4, jnp.int32),
        old_logp=jnp.log(jnp.full((b,), 0.25, jnp.float32)),
        adv=rand(seed + 2, (b,)),
        ret=rand(seed + 3, (b,)),
        lr=jnp.array(1e-2),
        clip=jnp.array(0.2),
        ent_coef=jnp.array(0.01),
        vf_coef=jnp.array(0.5),
    )


def test_ppo_update_repeated_reduces_value_loss():
    kw = ppo_inputs(7)
    v_first = None
    for step in range(1, 31):
        kw["t"] = jnp.array(float(step))
        params2, m2, v2, pi_l, v_l, ent = model.ppo_update(**kw)
        kw["params"], kw["m"], kw["v"] = params2, m2, v2
        if v_first is None:
            v_first = float(v_l)
    assert float(v_l) < v_first, f"value loss should fall: {v_first} -> {float(v_l)}"
    assert float(ent) > 0.0


def test_ppo_update_zero_lr_is_identity_on_params():
    kw = ppo_inputs(8)
    kw["lr"] = jnp.array(0.0)
    params2, *_ = model.ppo_update(**kw)
    np.testing.assert_allclose(params2, kw["params"], atol=1e-7)


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 2**31))
def test_ppo_losses_finite(seed):
    kw = ppo_inputs(seed % 1000)
    total, (pi_l, v_l, ent) = model.ppo_losses(
        kw["params"], kw["obs"], kw["actions"], kw["old_logp"], kw["adv"],
        kw["ret"], kw["clip"], kw["ent_coef"], kw["vf_coef"],
    )
    for x in (total, pi_l, v_l, ent):
        assert bool(jnp.isfinite(x))
    # Uniform policy entropy is ln(4) at init-ish scale; just check bounds.
    assert 0.0 < float(ent) <= float(jnp.log(4.0)) + 1e-4


# ---- signatures -------------------------------------------------------------


def test_signatures_cover_all_models_and_eval():
    sigs = model.signatures()
    assert set(sigs) == {"walker_act", "es_update", "ppo_act", "ppo_update"}
    for name, (fn, inputs) in sigs.items():
        outs = jax.eval_shape(fn, *inputs)
        assert len(jax.tree_util.tree_leaves(outs)) >= 1, name
