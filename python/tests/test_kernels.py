"""L1 correctness: every Pallas kernel vs. its pure-jnp oracle.

Hypothesis sweeps shapes and value ranges; `interpret=True` makes the
kernels runnable on CPU while exercising the same program the TPU build
would lower.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import adam as adam_k
from compile.kernels import es_combine as esc_k
from compile.kernels import mlp_fwd as mlp_k
from compile.kernels import ppo_loss as pl_k
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32) * scale


# ---- mlp3_tanh -------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    batch_blocks=st.integers(1, 4),
    block=st.sampled_from([16, 32, 64]),
    d_in=st.integers(3, 24),
    d_h=st.integers(4, 40),
    d_out=st.integers(1, 8),
    seed=st.integers(0, 2**31),
)
def test_mlp3_matches_ref(batch_blocks, block, d_in, d_h, d_out, seed):
    bsz = batch_blocks * block
    x = rand(seed, (bsz, d_in))
    w1, b1 = rand(seed + 1, (d_in, d_h), 0.3), rand(seed + 2, (d_h,), 0.1)
    w2, b2 = rand(seed + 3, (d_h, d_h), 0.3), rand(seed + 4, (d_h,), 0.1)
    w3, b3 = rand(seed + 5, (d_h, d_out), 0.3), rand(seed + 6, (d_out,), 0.1)
    got = mlp_k.mlp3_tanh(x, w1, b1, w2, b2, w3, b3, block_b=block)
    want = ref.mlp3_tanh(x, w1, b1, w2, b2, w3, b3)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


def test_mlp3_rejects_unaligned_batch():
    x = rand(0, (50, 8))
    w, b = rand(1, (8, 8)), rand(2, (8,))
    with pytest.raises(AssertionError):
        mlp_k.mlp3_tanh(x, w, b, w, b, w, b, block_b=64)


# ---- ppo_heads -------------------------------------------------------------


@settings(max_examples=10, deadline=None)
@given(
    blocks=st.integers(1, 3),
    seed=st.integers(0, 2**31),
)
def test_ppo_heads_matches_ref(blocks, seed):
    bsz = blocks * 128
    x = rand(seed, (bsz, 32))
    w1, b1 = rand(seed + 1, (32, 64), 0.25), rand(seed + 2, (64,), 0.1)
    w2, b2 = rand(seed + 3, (64, 64), 0.25), rand(seed + 4, (64,), 0.1)
    wp, bp = rand(seed + 5, (64, 4), 0.1), rand(seed + 6, (4,), 0.01)
    wv, bv = rand(seed + 7, (64,), 0.1), rand(seed + 8, (1,), 0.01)
    logits, values = mlp_k.ppo_heads(x, w1, b1, w2, b2, wp, bp, wv, bv)
    rl, rv = ref.ppo_heads(x, w1, b1, w2, b2, wp, bp, wv, bv[0])
    np.testing.assert_allclose(logits, rl, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(values, rv, rtol=1e-5, atol=1e-6)


# ---- es_combine ------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    pop=st.sampled_from([8, 64, 256]),
    dim=st.sampled_from([16, 701, 2804]),
    sigma=st.floats(0.01, 1.0),
    seed=st.integers(0, 2**31),
)
def test_es_combine_matches_ref(pop, dim, sigma, seed):
    w = rand(seed, (pop,))
    e = rand(seed + 1, (pop, dim))
    got = esc_k.es_combine(w, e, jnp.array([sigma], jnp.float32))
    want = ref.es_combine(w, e, sigma)
    np.testing.assert_allclose(got, want, rtol=5e-4, atol=1e-5)


def test_es_combine_zero_weights_zero_grad():
    e = rand(3, (16, 32))
    got = esc_k.es_combine(jnp.zeros(16), e, jnp.array([0.1]))
    np.testing.assert_array_equal(got, jnp.zeros(32))


# ---- adam ------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    dim=st.sampled_from([32, 701, 2804, 6597]),
    t=st.integers(1, 500),
    lr=st.floats(1e-5, 0.5),
    seed=st.integers(0, 2**31),
)
def test_adam_matches_ref(dim, t, lr, seed):
    theta = rand(seed, (dim,))
    m = rand(seed + 1, (dim,), 0.1)
    v = jnp.abs(rand(seed + 2, (dim,), 0.1))
    g = rand(seed + 3, (dim,))
    got = adam_k.adam(theta, m, v, g, jnp.array([float(t)]), jnp.array([lr], jnp.float32))
    want = ref.adam(theta, m, v, g, float(t), lr)
    # The kernel computes β^t in f32 (jnp.power) while the oracle uses
    # python float64 — allow the resulting few-ulp drift on θ near zero.
    for a, b in zip(got, want):
        np.testing.assert_allclose(a, b, rtol=2e-3, atol=1e-5)


def test_adam_zero_grad_converges_to_no_update():
    theta = rand(1, (64,))
    # With g = 0 and zero moments the step must be ~0.
    out, m2, v2 = adam_k.adam(
        theta, jnp.zeros(64), jnp.zeros(64), jnp.zeros(64),
        jnp.array([1.0]), jnp.array([0.1]),
    )
    np.testing.assert_allclose(out, theta, atol=1e-6)
    np.testing.assert_array_equal(m2, jnp.zeros(64))


# ---- ppo_surrogate ---------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(
    bsz=st.sampled_from([32, 128, 256]),
    clip=st.floats(0.05, 0.4),
    seed=st.integers(0, 2**31),
)
def test_surrogate_matches_ref(bsz, clip, seed):
    lp = -jnp.abs(rand(seed, (bsz,))) - 0.05
    olp = -jnp.abs(rand(seed + 1, (bsz,))) - 0.05
    adv = rand(seed + 2, (bsz,))
    got = pl_k.ppo_surrogate(lp, olp, adv, jnp.array([clip], jnp.float32))
    want = ref.ppo_surrogate(lp, olp, adv, clip)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-6)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31), clip=st.floats(0.05, 0.4))
def test_surrogate_vjp_matches_analytic(seed, clip):
    bsz = 128
    lp = -jnp.abs(rand(seed, (bsz,))) - 0.05
    olp = -jnp.abs(rand(seed + 1, (bsz,))) - 0.05
    adv = rand(seed + 2, (bsz,))
    c = jnp.array([clip], jnp.float32)
    grad = jax.grad(lambda l: pl_k.ppo_surrogate(l, olp, adv, c).sum())(lp)
    want = ref.ppo_surrogate_grad(lp, olp, adv, clip)
    np.testing.assert_allclose(grad, want, rtol=1e-5, atol=1e-6)


def test_surrogate_clip_actually_clips():
    # Large positive ratio with positive advantage must be clipped.
    lp = jnp.array([0.0], jnp.float32)
    olp = jnp.array([-2.0], jnp.float32)  # ratio = e^2 ≈ 7.4
    adv = jnp.array([1.0], jnp.float32)
    out = pl_k.ppo_surrogate(lp, olp, adv, jnp.array([0.2], jnp.float32))
    np.testing.assert_allclose(out, [-1.2], rtol=1e-5)
    # And the gradient through the clipped branch is zero.
    g = jax.grad(
        lambda l: pl_k.ppo_surrogate(l, olp, adv, jnp.array([0.2], jnp.float32)).sum()
    )(lp)
    np.testing.assert_allclose(g, [0.0], atol=1e-7)


# ---- centered ranks --------------------------------------------------------


@settings(max_examples=15, deadline=None)
@given(n=st.integers(2, 300), seed=st.integers(0, 2**31))
def test_centered_ranks_bounds_and_sum(n, seed):
    r = rand(seed, (n,), 5.0)
    cr = np.asarray(ref.centered_ranks(r))
    assert cr.min() == pytest.approx(-0.5)
    assert cr.max() == pytest.approx(0.5)
    assert cr.sum() == pytest.approx(0.0, abs=1e-4)
    # Order-preserving: argmax of rewards gets the top rank.
    assert cr[np.argmax(np.asarray(r))] == pytest.approx(0.5)
