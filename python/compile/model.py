"""L2: the JAX compute graphs Fiber's workloads execute through PJRT.

Four graphs, each AOT-lowered to one HLO artifact by `aot.py`:

* ``walker_act``  — batched walker-policy forward (Pallas ``mlp3_tanh``).
* ``es_update``   — centered ranks → Pallas ``es_combine`` → Pallas
  ``adam``; the ES master's whole model step in one fused artifact.
* ``ppo_act``     — batched PPO logits+values (Pallas ``ppo_heads``).
* ``ppo_update``  — clipped-surrogate loss (Pallas ``ppo_surrogate`` with
  custom VJP) + value + entropy terms, ``jax.grad``, then Pallas ``adam``.

The flat parameter layout is the Rust contract (`rust/src/algo/nn.rs`):
per layer `W (in,out)` row-major then `b (out,)`; PPO appends the policy
head then the value head. Shapes here must stay in sync with the constants
in `nn.rs` — `test_model.py` locks them.
"""

import jax
import jax.numpy as jnp

from .kernels import adam as adam_k
from .kernels import es_combine as esc_k
from .kernels import mlp_fwd as mlp_k
from .kernels import ppo_loss as pl_k
from .kernels import ref

# ---- architecture constants (mirror rust/src/algo/nn.rs) -----------------

WALKER_SIZES = (24, 40, 40, 4)
PPO_TRUNK = (32, 64, 64)
PPO_ACTIONS = 4

ES_POP = 256          # es_update artifact population
ACT_BATCH = 64        # walker_act batch rows
PPO_BATCH = 256       # ppo_act / ppo_update batch rows


def param_count(sizes):
    return sum(i * o + o for i, o in zip(sizes[:-1], sizes[1:]))


WALKER_DIM = param_count(WALKER_SIZES)                       # 2804
PPO_DIM = (
    param_count(PPO_TRUNK)
    + PPO_TRUNK[-1] * PPO_ACTIONS + PPO_ACTIONS
    + PPO_TRUNK[-1] + 1
)                                                            # 6597


def unpack_mlp(flat, sizes):
    """Split a flat vector into [(W, b), …] following the shared layout."""
    out, off = [], 0
    for i, o in zip(sizes[:-1], sizes[1:]):
        w = flat[off:off + i * o].reshape(i, o)
        off += i * o
        b = flat[off:off + o]
        off += o
        out.append((w, b))
    return out, off


def unpack_ppo(flat):
    trunk, off = unpack_mlp(flat, PPO_TRUNK)
    h = PPO_TRUNK[-1]
    wp = flat[off:off + h * PPO_ACTIONS].reshape(h, PPO_ACTIONS)
    off += h * PPO_ACTIONS
    bp = flat[off:off + PPO_ACTIONS]
    off += PPO_ACTIONS
    wv = flat[off:off + h]
    off += h
    bv = flat[off]
    return trunk, wp, bp, wv, bv


# ---- graphs ---------------------------------------------------------------


def walker_act(params, obs):
    """(params (2804,), obs (B,24)) → (actions (B,4),)."""
    (w1, b1), (w2, b2), (w3, b3) = unpack_mlp(params, WALKER_SIZES)[0]
    return (mlp_k.mlp3_tanh(obs, w1, b1, w2, b2, w3, b3),)


def es_update(theta, noise, rewards, m, v, t, lr, sigma):
    """One ES model step; returns (theta', m', v', grad_norm)."""
    ranks = ref.centered_ranks(rewards)
    grad = esc_k.es_combine(ranks, noise, sigma.reshape(1))
    theta2, m2, v2 = adam_k.adam(theta, m, v, grad, t.reshape(1), lr.reshape(1))
    return theta2, m2, v2, jnp.linalg.norm(grad)


def ppo_forward_jnp(params, obs):
    """Differentiable pure-jnp forward (used inside ppo_update's grad)."""
    (trunk, wp, bp, wv, bv) = unpack_ppo(params)
    (w1, b1), (w2, b2) = trunk
    return ref.ppo_heads(obs, w1, b1, w2, b2, wp, bp, wv, bv)


def ppo_act(params, obs):
    """(params (6597,), obs (B,32)) → (logits (B,4), values (B,))."""
    (trunk, wp, bp, wv, bv) = unpack_ppo(params)
    (w1, b1), (w2, b2) = trunk
    logits, values = mlp_k.ppo_heads(
        obs, w1, b1, w2, b2, wp, bp, wv, bv.reshape(1)
    )
    return logits, values


def ppo_losses(params, obs, actions, old_logp, adv, ret, clip, ent_coef, vf_coef):
    """Scalar (total, pi_loss, v_loss, entropy) for one minibatch.

    Matches the Rust reference in `algo/ppo.rs` term for term:
    total = mean(pg) + vf·mean(½(v−R)²) − ent·mean(H).
    """
    logits, values = ppo_forward_jnp(params, obs)
    lp = jax.nn.log_softmax(logits, axis=-1)
    logp_a = jnp.take_along_axis(lp, actions[:, None], axis=-1)[:, 0]
    pg = pl_k.ppo_surrogate(logp_a, old_logp, adv, clip.reshape(1))
    pi_loss = jnp.mean(pg)
    entropy = jnp.mean(-jnp.sum(jnp.exp(lp) * lp, axis=-1))
    v_loss = jnp.mean(0.5 * (values - ret) ** 2)
    total = pi_loss + vf_coef * v_loss - ent_coef * entropy
    return total, (pi_loss, v_loss, entropy)


def ppo_update(params, m, v, t, obs, actions, old_logp, adv, ret,
               lr, clip, ent_coef, vf_coef):
    """One PPO minibatch Adam step.

    Returns (params', m', v', pi_loss, v_loss, entropy).
    """
    grad_fn = jax.grad(ppo_losses, has_aux=True)
    grads, (pi_loss, v_loss, entropy) = grad_fn(
        params, obs, actions, old_logp, adv, ret, clip, ent_coef, vf_coef
    )
    params2, m2, v2 = adam_k.adam(
        params, m, v, grads, t.reshape(1), lr.reshape(1)
    )
    return params2, m2, v2, pi_loss, v_loss, entropy


# ---- example input signatures (shared by aot.py and the tests) ------------

F32 = jnp.float32
I32 = jnp.int32


def signatures():
    """name → (fn, [ShapeDtypeStruct inputs])."""
    s = jax.ShapeDtypeStruct
    return {
        "walker_act": (
            walker_act,
            [s((WALKER_DIM,), F32), s((ACT_BATCH, WALKER_SIZES[0]), F32)],
        ),
        "es_update": (
            es_update,
            [
                s((WALKER_DIM,), F32),
                s((ES_POP, WALKER_DIM), F32),
                s((ES_POP,), F32),
                s((WALKER_DIM,), F32),
                s((WALKER_DIM,), F32),
                s((), F32),
                s((), F32),
                s((), F32),
            ],
        ),
        "ppo_act": (
            ppo_act,
            [s((PPO_DIM,), F32), s((PPO_BATCH, PPO_TRUNK[0]), F32)],
        ),
        "ppo_update": (
            ppo_update,
            [
                s((PPO_DIM,), F32),
                s((PPO_DIM,), F32),
                s((PPO_DIM,), F32),
                s((), F32),
                s((PPO_BATCH, PPO_TRUNK[0]), F32),
                s((PPO_BATCH,), I32),
                s((PPO_BATCH,), F32),
                s((PPO_BATCH,), F32),
                s((PPO_BATCH,), F32),
                s((), F32),
                s((), F32),
                s((), F32),
                s((), F32),
            ],
        ),
    }
