"""L1 Pallas kernel: ES noise combination `g = -(wᵀE)/(pop·σ)`.

Tiles the parameter dimension: each grid step keeps the full rank-weight
vector (pop floats) resident in VMEM while one (pop × block_d) slab of the
noise matrix streams through — the access pattern a TPU would use to avoid
re-reading the weights per slab. At paper scale (pop 2048, dim 2804,
block 701) a slab is 2048×701×4 ≈ 5.6 MB: within VMEM with double-buffering
headroom.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(pop, w_ref, e_ref, sigma_ref, o_ref):
    w = w_ref[...]
    o_ref[...] = -(w @ e_ref[...]) / (pop * sigma_ref[0])


def es_combine(weights, noise, sigma, *, block_d=None):
    """`weights` (pop,), `noise` (pop, dim), `sigma` (1,) → grad (dim,)."""
    pop, dim = noise.shape
    if block_d is None:
        # Largest divisor of dim ≤ 1024 keeps slabs VMEM-sized.
        block_d = next(b for b in range(min(dim, 1024), 0, -1) if dim % b == 0)
    assert dim % block_d == 0
    grid = (dim // block_d,)
    import functools

    kernel = functools.partial(_combine_kernel, float(pop))
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((pop,), lambda j: (0,)),
            pl.BlockSpec((pop, block_d), lambda j: (0, j)),
            pl.BlockSpec((1,), lambda j: (0,)),
        ],
        out_specs=pl.BlockSpec((block_d,), lambda j: (j,)),
        out_shape=jax.ShapeDtypeStruct((dim,), noise.dtype),
        interpret=True,
    )(weights, noise, sigma)
