"""Pure-jnp oracles for every Pallas kernel.

Each function here defines the *semantics*; the Pallas kernels in this
package must match these (float32 tolerance) under pytest + hypothesis
sweeps, and the Rust reference implementations
(`rust/src/algo/{nn,es,ppo}.rs`) implement the same math on the other side
of the artifact boundary.

Parameter layout contract (shared with Rust): a dense layer is `W` stored
row-major as `(in, out)` followed by `b (out,)`; forward is `y = x @ W + b`.
"""

import jax.numpy as jnp


def mlp3_tanh(x, w1, b1, w2, b2, w3, b3):
    """3-layer MLP, tanh after every layer (walker policy)."""
    h = jnp.tanh(x @ w1 + b1)
    h = jnp.tanh(h @ w2 + b2)
    return jnp.tanh(h @ w3 + b3)


def ppo_heads(x, w1, b1, w2, b2, wp, bp, wv, bv):
    """Shared tanh trunk with linear policy + value heads.

    `wv` has shape (hidden,), `bv` is a scalar; returns (logits, values).
    """
    h = jnp.tanh(x @ w1 + b1)
    h = jnp.tanh(h @ w2 + b2)
    logits = h @ wp + bp
    values = h @ wv + bv
    return logits, values


def es_combine(weights, noise, sigma):
    """ES gradient estimate: g = -(wᵀE) / (pop·σ) (descent on -reward)."""
    pop = weights.shape[0]
    return -(weights @ noise) / (pop * sigma)


def adam(theta, m, v, grad, t, lr, beta1=0.9, beta2=0.999, eps=1e-8):
    """One Adam step; returns (theta', m', v'). `t` is the post-increment
    step count (Rust increments before calling the artifact)."""
    m2 = beta1 * m + (1.0 - beta1) * grad
    v2 = beta2 * v + (1.0 - beta2) * grad * grad
    mhat = m2 / (1.0 - beta1**t)
    vhat = v2 / (1.0 - beta2**t)
    return theta - lr * mhat / (jnp.sqrt(vhat) + eps), m2, v2


def ppo_surrogate(logp_a, old_logp, adv, clip):
    """Per-sample clipped surrogate loss: -min(r·A, clip(r)·A)."""
    ratio = jnp.exp(logp_a - old_logp)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv
    return -jnp.minimum(unclipped, clipped)


def ppo_surrogate_grad(logp_a, old_logp, adv, clip):
    """d(surrogate)/d(logp_a): -A·r where the unclipped branch is active
    (matches the Rust backprop in `algo/ppo.rs`)."""
    ratio = jnp.exp(logp_a - old_logp)
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv
    return jnp.where(unclipped <= clipped, -adv * ratio, 0.0)


def centered_ranks(rewards):
    """Centered-rank fitness shaping in [-0.5, 0.5] (Salimans et al.)."""
    n = rewards.shape[0]
    order = jnp.argsort(rewards, stable=True)
    ranks = jnp.zeros_like(rewards).at[order].set(
        jnp.arange(n, dtype=rewards.dtype)
    )
    return ranks / max(n - 1, 1) - 0.5
