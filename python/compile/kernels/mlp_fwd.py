"""L1 Pallas kernels: fused MLP forward passes.

Both kernels tile the **batch** dimension: each grid step holds one
(block_b × in) observation tile plus the full weight set in VMEM and runs
the whole fused forward (matmul → tanh → matmul → tanh → heads) without
touching HBM in between. VMEM budget at the default shapes (DESIGN.md
§Hardware-Adaptation):

* walker  (block 64):  64×24 x-tile + 2 804 params + 64×4 out ≈ 24 KB
* ppo     (block 128): 128×32 x-tile + 6 597 params + outs    ≈ 47 KB

both far under the ~16 MB/core budget, leaving room to grow block_b; the
matmuls feed the MXU with (block_b × in) · (in × out) f32 contractions.

`interpret=True` everywhere: the CPU PJRT plugin cannot run Mosaic
custom-calls (see /opt/xla-example/README.md); lowered this way the kernels
become plain HLO and run on any backend.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _mlp3_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, w3_ref, b3_ref, o_ref):
    h = jnp.tanh(x_ref[...] @ w1_ref[...] + b1_ref[...])
    h = jnp.tanh(h @ w2_ref[...] + b2_ref[...])
    o_ref[...] = jnp.tanh(h @ w3_ref[...] + b3_ref[...])


def mlp3_tanh(x, w1, b1, w2, b2, w3, b3, *, block_b=64):
    """Batched 3-layer tanh MLP via Pallas. `x` is (B, in); B % block_b == 0."""
    bsz, d_in = x.shape
    assert bsz % block_b == 0, f"batch {bsz} must be a multiple of {block_b}"
    d_out = w3.shape[1]
    grid = (bsz // block_b,)
    full = lambda a: pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)
    return pl.pallas_call(
        _mlp3_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d_in), lambda i: (i, 0)),
            full(w1),
            full(b1),
            full(w2),
            full(b2),
            full(w3),
            full(b3),
        ],
        out_specs=pl.BlockSpec((block_b, d_out), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((bsz, d_out), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2, w3, b3)


def _ppo_heads_kernel(
    x_ref, w1_ref, b1_ref, w2_ref, b2_ref, wp_ref, bp_ref, wv_ref, bv_ref,
    logits_ref, values_ref,
):
    h = jnp.tanh(x_ref[...] @ w1_ref[...] + b1_ref[...])
    h = jnp.tanh(h @ w2_ref[...] + b2_ref[...])
    logits_ref[...] = h @ wp_ref[...] + bp_ref[...]
    values_ref[...] = h @ wv_ref[...] + bv_ref[0]


def ppo_heads(x, w1, b1, w2, b2, wp, bp, wv, bv, *, block_b=128):
    """Fused PPO trunk + heads. `wv` is (hidden,), `bv` is (1,).

    Returns (logits (B, actions), values (B,)).
    """
    bsz, d_in = x.shape
    assert bsz % block_b == 0, f"batch {bsz} must be a multiple of {block_b}"
    n_act = wp.shape[1]
    grid = (bsz // block_b,)
    full = lambda a: pl.BlockSpec(a.shape, lambda i: (0,) * a.ndim)
    return pl.pallas_call(
        _ppo_heads_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, d_in), lambda i: (i, 0)),
            full(w1),
            full(b1),
            full(w2),
            full(b2),
            full(wp),
            full(bp),
            full(wv),
            full(bv),
        ],
        out_specs=(
            pl.BlockSpec((block_b, n_act), lambda i: (i, 0)),
            pl.BlockSpec((block_b,), lambda i: (i,)),
        ),
        out_shape=(
            jax.ShapeDtypeStruct((bsz, n_act), x.dtype),
            jax.ShapeDtypeStruct((bsz,), x.dtype),
        ),
        interpret=True,
    )(x, w1, b1, w2, b2, wp, bp, wv, bv)
