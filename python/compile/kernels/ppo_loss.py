"""L1 Pallas kernel: PPO clipped surrogate, forward + analytic backward.

The surrogate is elementwise in `(logp_a, old_logp, adv)`, so it makes a
clean `custom_vjp` pair of Pallas kernels: the forward computes
`-min(r·A, clip(r)·A)` and the backward the branch-masked `-A·r` gradient —
the same expression the Rust reference backprop uses (`algo/ppo.rs`), so
the artifact and the fallback agree. Autodiff flows through the jnp
log-softmax/gather around it; this kernel is where the branchy part lives.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _fwd_kernel(lp_ref, olp_ref, adv_ref, clip_ref, o_ref):
    ratio = jnp.exp(lp_ref[...] - olp_ref[...])
    adv = adv_ref[...]
    clip = clip_ref[0]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv
    o_ref[...] = -jnp.minimum(unclipped, clipped)


def _bwd_kernel(lp_ref, olp_ref, adv_ref, clip_ref, o_ref):
    ratio = jnp.exp(lp_ref[...] - olp_ref[...])
    adv = adv_ref[...]
    clip = clip_ref[0]
    unclipped = ratio * adv
    clipped = jnp.clip(ratio, 1.0 - clip, 1.0 + clip) * adv
    o_ref[...] = jnp.where(unclipped <= clipped, -adv * ratio, 0.0)


def _call(kernel, logp_a, old_logp, adv, clip, *, block_b=None):
    (bsz,) = logp_a.shape
    if block_b is None:
        block_b = next(b for b in range(min(bsz, 256), 0, -1) if bsz % b == 0)
    assert bsz % block_b == 0
    vec = pl.BlockSpec((block_b,), lambda i: (i,))
    scalar = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        kernel,
        grid=(bsz // block_b,),
        in_specs=[vec, vec, vec, scalar],
        out_specs=vec,
        out_shape=jax.ShapeDtypeStruct((bsz,), logp_a.dtype),
        interpret=True,
    )(logp_a, old_logp, adv, clip)


@jax.custom_vjp
def ppo_surrogate(logp_a, old_logp, adv, clip):
    """Per-sample clipped surrogate loss (B,). `clip` is a (1,) array."""
    return _call(_fwd_kernel, logp_a, old_logp, adv, clip)


def _vjp_fwd(logp_a, old_logp, adv, clip):
    out = _call(_fwd_kernel, logp_a, old_logp, adv, clip)
    return out, (logp_a, old_logp, adv, clip)


def _vjp_bwd(residuals, g):
    logp_a, old_logp, adv, clip = residuals
    d_lp = _call(_bwd_kernel, logp_a, old_logp, adv, clip)
    return (g * d_lp, jnp.zeros_like(old_logp), jnp.zeros_like(adv),
            jnp.zeros_like(clip))


ppo_surrogate.defvjp(_vjp_fwd, _vjp_bwd)
