"""L1 Pallas kernel: fused Adam step.

Elementwise over the flat parameter vector, one pass: both moment updates,
bias corrections and the parameter step fused so θ/m/v/g stream through
VMEM exactly once (vs. ~7 separate elementwise HLO ops unfused). The
step count `t` and learning rate arrive as (1,) refs because they are
runtime inputs of the artifact, not compile-time constants.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BETA1 = 0.9
BETA2 = 0.999
EPS = 1e-8


def _adam_kernel(theta_ref, m_ref, v_ref, g_ref, t_ref, lr_ref,
                 ot_ref, om_ref, ov_ref):
    g = g_ref[...]
    t = t_ref[0]
    lr = lr_ref[0]
    m2 = BETA1 * m_ref[...] + (1.0 - BETA1) * g
    v2 = BETA2 * v_ref[...] + (1.0 - BETA2) * g * g
    mhat = m2 / (1.0 - jnp.power(BETA1, t))
    vhat = v2 / (1.0 - jnp.power(BETA2, t))
    ot_ref[...] = theta_ref[...] - lr * mhat / (jnp.sqrt(vhat) + EPS)
    om_ref[...] = m2
    ov_ref[...] = v2


def adam(theta, m, v, grad, t, lr, *, block_d=None):
    """One fused Adam step. `t`, `lr` are (1,) arrays.

    Returns (theta', m', v').
    """
    (dim,) = theta.shape
    if block_d is None:
        block_d = next(b for b in range(min(dim, 2048), 0, -1) if dim % b == 0)
    assert dim % block_d == 0
    grid = (dim // block_d,)
    vec = pl.BlockSpec((block_d,), lambda j: (j,))
    scalar = pl.BlockSpec((1,), lambda j: (0,))
    out = jax.ShapeDtypeStruct((dim,), theta.dtype)
    return pl.pallas_call(
        _adam_kernel,
        grid=grid,
        in_specs=[vec, vec, vec, vec, scalar, scalar],
        out_specs=(vec, vec, vec),
        out_shape=(out, out, out),
        interpret=True,
    )(theta, m, v, grad, t, lr)
