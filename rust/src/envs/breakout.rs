//! Breakout — the ALE substitute for the PPO experiment (DESIGN.md §2).
//!
//! Paddle, ball and a 6×10 brick wall in a unit court. The observation is a
//! compact 32-d feature vector (paddle x, ball kinematics, per-column brick
//! counts, …) instead of 84×84 pixels: the PPO experiment probes the
//! *framework's* distributed env stepping, and a feature observation keeps
//! the model MLP-sized so the step budget is spent where the experiment
//! looks. Actions follow ALE Breakout: NOOP / FIRE / RIGHT / LEFT.

use crate::util::Rng;

use super::{Action, ActionSpec, Env, StepResult};

pub const BRICK_COLS: usize = 10;
pub const BRICK_ROWS: usize = 6;
const PADDLE_W: f32 = 0.14;
const PADDLE_SPEED: f32 = 0.035;
const BALL_SPEED: f32 = 0.022;
const BRICK_TOP: f32 = 0.55;
const BRICK_H: f32 = 0.04;
const LIVES: u32 = 5;

/// The Breakout environment.
#[derive(Clone, Debug)]
pub struct Breakout {
    paddle_x: f32,
    ball: (f32, f32),
    vel: (f32, f32),
    bricks: [[bool; BRICK_COLS]; BRICK_ROWS],
    lives: u32,
    launched: bool,
    rng: Rng,
    done: bool,
    score: u32,
}

impl Default for Breakout {
    fn default() -> Self {
        Self::new()
    }
}

impl Breakout {
    pub fn new() -> Self {
        Self {
            paddle_x: 0.5,
            ball: (0.5, 0.2),
            vel: (0.0, 0.0),
            bricks: [[true; BRICK_COLS]; BRICK_ROWS],
            lives: LIVES,
            launched: false,
            rng: Rng::new(0),
            done: false,
            score: 0,
        }
    }

    pub fn score(&self) -> u32 {
        self.score
    }

    pub fn bricks_left(&self) -> usize {
        self.bricks
            .iter()
            .flat_map(|r| r.iter())
            .filter(|&&b| b)
            .count()
    }

    fn obs(&self) -> Vec<f32> {
        let mut o = Vec::with_capacity(32);
        o.push(self.paddle_x * 2.0 - 1.0);
        o.push(self.ball.0 * 2.0 - 1.0);
        o.push(self.ball.1 * 2.0 - 1.0);
        o.push(self.vel.0 / BALL_SPEED);
        o.push(self.vel.1 / BALL_SPEED);
        o.push(self.lives as f32 / LIVES as f32);
        // Per-column brick counts (10) + per-row brick counts (6).
        for c in 0..BRICK_COLS {
            let n = (0..BRICK_ROWS).filter(|&r| self.bricks[r][c]).count();
            o.push(n as f32 / BRICK_ROWS as f32);
        }
        for r in 0..BRICK_ROWS {
            let n = (0..BRICK_COLS).filter(|&c| self.bricks[r][c]).count();
            o.push(n as f32 / BRICK_COLS as f32);
        }
        // Relative paddle→ball, launch flag, and padding to 32.
        o.push(self.ball.0 - self.paddle_x);
        o.push(if self.launched { 1.0 } else { 0.0 });
        while o.len() < 32 {
            o.push(0.0);
        }
        o
    }

    fn launch(&mut self) {
        if !self.launched {
            self.launched = true;
            let dir = if self.rng.chance(0.5) { 1.0 } else { -1.0 };
            let angle = 0.35 + self.rng.f32() * 0.4;
            self.vel = (dir * BALL_SPEED * angle.sin(), BALL_SPEED * angle.cos());
        }
    }
}

impl Env for Breakout {
    fn obs_dim(&self) -> usize {
        32
    }

    fn action_spec(&self) -> ActionSpec {
        ActionSpec::Discrete(4) // NOOP, FIRE, RIGHT, LEFT
    }

    fn reset(&mut self, seed: u64) -> Vec<f32> {
        *self = Breakout::new();
        self.rng = Rng::new(seed ^ 0xB4EA);
        self.paddle_x = 0.3 + self.rng.f32() * 0.4;
        self.obs()
    }

    fn step(&mut self, action: &Action) -> StepResult {
        debug_assert!(!self.done, "step() after done");
        let a = match action {
            Action::Discrete(a) => *a,
            Action::Continuous(v) => {
                // Allow continuous drivers: sign → direction.
                let x = v.first().copied().unwrap_or(0.0);
                if x > 0.33 {
                    2
                } else if x < -0.33 {
                    3
                } else {
                    0
                }
            }
        };
        match a {
            1 => self.launch(),
            2 => self.paddle_x = (self.paddle_x + PADDLE_SPEED).min(1.0 - PADDLE_W / 2.0),
            3 => self.paddle_x = (self.paddle_x - PADDLE_SPEED).max(PADDLE_W / 2.0),
            _ => {}
        }
        let mut reward = 0.0f32;
        if self.launched {
            let (mut bx, mut by) = self.ball;
            bx += self.vel.0;
            by += self.vel.1;
            // Walls.
            if bx <= 0.0 {
                bx = -bx;
                self.vel.0 = self.vel.0.abs();
            }
            if bx >= 1.0 {
                bx = 2.0 - bx;
                self.vel.0 = -self.vel.0.abs();
            }
            if by >= 1.0 {
                by = 2.0 - by;
                self.vel.1 = -self.vel.1.abs();
            }
            // Bricks.
            if by >= BRICK_TOP && by < BRICK_TOP + BRICK_ROWS as f32 * BRICK_H {
                let r = ((by - BRICK_TOP) / BRICK_H) as usize;
                let c = ((bx * BRICK_COLS as f32) as usize).min(BRICK_COLS - 1);
                if r < BRICK_ROWS && self.bricks[r][c] {
                    self.bricks[r][c] = false;
                    self.vel.1 = -self.vel.1;
                    // Higher rows score more, like ALE.
                    reward += (BRICK_ROWS - r) as f32;
                    self.score += (BRICK_ROWS - r) as u32;
                }
            }
            // Paddle.
            let paddle_y = 0.08;
            if by <= paddle_y && self.vel.1 < 0.0 {
                if (bx - self.paddle_x).abs() <= PADDLE_W / 2.0 {
                    by = paddle_y + (paddle_y - by);
                    // English: hit offset bends the rebound.
                    let off = (bx - self.paddle_x) / (PADDLE_W / 2.0);
                    self.vel.0 = BALL_SPEED * off * 0.9;
                    self.vel.1 = (BALL_SPEED * BALL_SPEED - self.vel.0 * self.vel.0)
                        .max(1e-6)
                        .sqrt();
                } else if by <= 0.0 {
                    // Missed: lose a life.
                    self.lives -= 1;
                    self.launched = false;
                    self.ball = (self.paddle_x, 0.2);
                    self.vel = (0.0, 0.0);
                    if self.lives == 0 {
                        self.done = true;
                    }
                    return StepResult {
                        obs: self.obs(),
                        reward: 0.0,
                        done: self.done,
                    };
                }
            }
            self.ball = (bx, by);
        } else {
            self.ball = (self.paddle_x, 0.2);
        }
        if self.bricks_left() == 0 {
            self.done = true; // cleared the wall
        }
        StepResult {
            obs: self.obs(),
            reward,
            done: self.done,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn obs_is_32d_and_bounded() {
        let mut env = Breakout::new();
        let obs = env.reset(1);
        assert_eq!(obs.len(), 32);
        env.step(&Action::Discrete(1));
        for _ in 0..200 {
            let r = env.step(&Action::Discrete(0));
            for (i, v) in r.obs.iter().enumerate() {
                assert!(v.abs() <= 2.0, "obs[{i}]={v} out of range");
            }
            if r.done {
                break;
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut env = Breakout::new();
            env.reset(seed);
            let mut total = 0.0;
            env.step(&Action::Discrete(1));
            for i in 0..400 {
                let a = if i % 3 == 0 { 2 } else { 3 };
                let r = env.step(&Action::Discrete(a));
                total += r.reward;
                if r.done {
                    break;
                }
            }
            total
        };
        assert_eq!(run(9), run(9));
    }

    #[test]
    fn ball_eventually_hits_bricks_with_tracking_policy() {
        let mut env = Breakout::new();
        let mut obs = env.reset(3);
        env.step(&Action::Discrete(1)); // FIRE
        let mut total = 0.0;
        for _ in 0..3000 {
            // Track the ball with the paddle.
            let ball_rel = obs[16 + BRICK_COLS]; // actually recompute:
            let _ = ball_rel;
            let paddle = obs[0];
            let ball = obs[1];
            let a = if ball > paddle + 0.02 {
                2
            } else if ball < paddle - 0.02 {
                3
            } else if obs[31] == 0.0 {
                1
            } else {
                0
            };
            // Relaunch if needed.
            let r = env.step(&Action::Discrete(a));
            total += r.reward;
            obs = r.obs;
            if r.done {
                break;
            }
            if obs[29] == 0.0 {
                env_relaunch(&mut env);
            }
        }
        assert!(total > 0.0, "tracking policy should break bricks, got {total}");
        assert!(env.score() > 0);
    }

    fn env_relaunch(env: &mut Breakout) {
        env.step(&Action::Discrete(1));
    }

    #[test]
    fn losing_all_lives_ends_episode() {
        let mut env = Breakout::new();
        env.reset(5);
        // Never move the paddle; fire and wait for 5 misses.
        let mut done = false;
        for _ in 0..20_000 {
            let r = env.step(&Action::Discrete(1)); // FIRE relaunches when idle
            if r.done {
                done = true;
                break;
            }
        }
        // Either died (lost lives without moving) or cleared; dying is the
        // overwhelmingly likely case with a static paddle.
        assert!(done, "episode must terminate");
    }

    #[test]
    fn brick_counts_decrease_monotonically() {
        let mut env = Breakout::new();
        env.reset(2);
        env.step(&Action::Discrete(1));
        let mut last = env.bricks_left();
        for _ in 0..2000 {
            let r = env.step(&Action::Discrete(0));
            let now = env.bricks_left();
            assert!(now <= last);
            last = now;
            if r.done {
                break;
            }
        }
    }
}
