//! Simulation substrates — the CPU-bound workloads Fiber schedules.
//!
//! The paper's experiments run OpenAI Gym / ALE simulators; those are
//! Python/C++ and unavailable here, so we build the equivalent environments
//! in Rust (DESIGN.md §2):
//!
//! * [`cartpole`] — classic control, used by quickstart examples/tests.
//! * [`walker2d`] — a planar biped with torque-controlled legs on
//!   procedurally-generated *hardcore* terrain (stumps, gaps, stairs,
//!   roughness): the BipedalWalkerHardcore substitute for the ES
//!   experiments, with variable-length rollouts (the heterogeneity Fiber
//!   targets).
//! * [`breakout`] — a Breakout clone with a compact feature observation:
//!   the ALE substitute for the PPO experiments.
//!
//! All environments implement [`Env`]: deterministic given a seed, pure
//! Rust, `Send`, and cheap enough that the *framework* under test (not the
//! simulator) dominates when the experiment wants it to.

pub mod breakout;
pub mod cartpole;
pub mod walker2d;

pub use breakout::Breakout;
pub use cartpole::CartPole;
pub use walker2d::{TerrainConfig, Walker2d};

/// An action: discrete index or continuous torque vector.
#[derive(Clone, Debug, PartialEq)]
pub enum Action {
    Discrete(usize),
    Continuous(Vec<f32>),
}

/// Result of one environment step.
#[derive(Clone, Debug)]
pub struct StepResult {
    pub obs: Vec<f32>,
    pub reward: f32,
    pub done: bool,
}

/// The environment contract (Gym-like).
pub trait Env: Send {
    /// Observation dimensionality.
    fn obs_dim(&self) -> usize;
    /// Discrete action count, or continuous action dimensionality.
    fn action_spec(&self) -> ActionSpec;
    /// Reset with a seed; returns the initial observation.
    fn reset(&mut self, seed: u64) -> Vec<f32>;
    /// Advance one step.
    fn step(&mut self, action: &Action) -> StepResult;
}

/// Action-space description.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ActionSpec {
    Discrete(usize),
    Continuous(usize),
}

impl ActionSpec {
    pub fn dim(&self) -> usize {
        match self {
            ActionSpec::Discrete(n) => *n,
            ActionSpec::Continuous(d) => *d,
        }
    }
}

/// Roll out `policy` for at most `max_steps`, returning (total reward, steps).
/// `?Sized`: callers may hold the environment as a `Box<dyn Env>`.
pub fn rollout<E: Env + ?Sized>(
    env: &mut E,
    seed: u64,
    max_steps: usize,
    mut policy: impl FnMut(&[f32]) -> Action,
) -> (f32, usize) {
    let mut obs = env.reset(seed);
    let mut total = 0.0f32;
    for t in 0..max_steps {
        let a = policy(&obs);
        let r = env.step(&a);
        total += r.reward;
        obs = r.obs;
        if r.done {
            return (total, t + 1);
        }
    }
    (total, max_steps)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rollout_runs_all_envs() {
        let mut cp = CartPole::new();
        let (r, steps) = rollout(&mut cp, 3, 500, |_| Action::Discrete(0));
        assert!(steps > 0 && steps <= 500);
        assert!(r > 0.0, "cartpole rewards survival");

        let mut bo = Breakout::new();
        let (_, steps) = rollout(&mut bo, 3, 500, |_| Action::Discrete(1));
        assert!(steps > 0);

        let mut w = Walker2d::hardcore(7);
        let (_, steps) = rollout(&mut w, 3, 300, |obs| {
            Action::Continuous(vec![obs[0].sin(), 0.3, -0.2, 0.1])
        });
        assert!(steps > 0);
    }
}
