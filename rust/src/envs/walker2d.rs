//! A planar biped on procedurally-generated hardcore terrain — the
//! BipedalWalkerHardcore substitute (DESIGN.md §2).
//!
//! The paper's ES experiment uses a modified BipedalWalkerHardcore (Wang
//! 2019, the POET terrain family). Box2D is unavailable here, so this is a
//! purpose-built simplified dynamics model preserving what the *systems*
//! experiment needs: a CPU-bound stepper in the µs range, 24-d observations
//! and 4-d torque actions like BipedalWalker, POET-style terrain parameters
//! (roughness / stumps / gaps / stairs), and **variable-length rollouts**
//! (early falls vs. full walks — the heterogeneity Fiber schedules around).
//!
//! Simplifications vs. Box2D (documented, deliberate): the hull is a single
//! rigid body; legs are massless 2-segment chains with first-order joint
//! dynamics; ground contact is a spring-damper on each foot acting on the
//! hull. The result walks (badly) under random torques and rewards forward
//! progress, which is all ES needs to optimize.

use crate::util::Rng;

use super::{Action, ActionSpec, Env, StepResult};

const DT: f32 = 0.02;
const GRAVITY: f32 = -9.8;
const HULL_MASS: f32 = 5.0;
const HULL_INERTIA: f32 = 1.2;
const L1: f32 = 0.34; // thigh
const L2: f32 = 0.42; // shin
const MOTOR_TORQUE: f32 = 14.0;
const JOINT_DAMPING: f32 = 1.4;
const JOINT_INERTIA: f32 = 0.08;
const CONTACT_K: f32 = 900.0; // ground spring
const CONTACT_C: f32 = 28.0; // ground damper
const FRICTION: f32 = 2.2;
const HIP_LIMIT: f32 = 1.1;
const KNEE_LO: f32 = -1.9;
const KNEE_HI: f32 = -0.1;
const STAND_HEIGHT: f32 = 0.65;
const FINISH_X: f32 = 60.0;
const N_LIDAR: usize = 10;

/// POET-style terrain parameters.
#[derive(Clone, Copy, Debug)]
pub struct TerrainConfig {
    /// Amplitude of the random-walk ground roughness (m).
    pub roughness: f32,
    /// Probability of a stump at each terrain segment.
    pub stump_prob: f64,
    /// Max stump height (m).
    pub stump_height: f32,
    /// Probability of a gap (pit).
    pub gap_prob: f64,
    /// Max gap width (m).
    pub gap_width: f32,
    /// Probability of a stair run.
    pub stair_prob: f64,
}

impl TerrainConfig {
    /// Flat ground (the easy environment).
    pub fn flat() -> Self {
        Self {
            roughness: 0.0,
            stump_prob: 0.0,
            stump_height: 0.0,
            gap_prob: 0.0,
            gap_width: 0.0,
            stair_prob: 0.0,
        }
    }

    /// The hardcore mix used in the ES experiment.
    pub fn hardcore() -> Self {
        Self {
            roughness: 0.12,
            stump_prob: 0.06,
            stump_height: 0.3,
            gap_prob: 0.05,
            gap_width: 0.9,
            stair_prob: 0.04,
        }
    }
}

/// Piecewise-linear heightfield, 0.25 m resolution out to the finish line.
#[derive(Clone, Debug)]
struct Terrain {
    heights: Vec<f32>,
    res: f32,
}

impl Terrain {
    fn generate(cfg: &TerrainConfig, seed: u64) -> Self {
        let res = 0.25f32;
        let n = ((FINISH_X + 20.0) / res) as usize;
        let mut rng = Rng::new(seed ^ 0x7E44A1);
        let mut h = vec![0.0f32; n];
        let mut y = 0.0f32;
        let mut i = 8; // flat spawn pad
        while i < n {
            if rng.chance(cfg.gap_prob) {
                let w = ((rng.f32() * cfg.gap_width / res) as usize).max(1);
                for k in 0..w.min(n - i) {
                    h[i + k] = y - 3.0; // pit
                }
                i += w;
            } else if rng.chance(cfg.stump_prob) {
                let sh = rng.f32() * cfg.stump_height;
                let w = 2usize;
                for k in 0..w.min(n - i) {
                    h[i + k] = y + sh;
                }
                i += w;
            } else if rng.chance(cfg.stair_prob) {
                let steps = 3 + rng.below(3);
                let rise = if rng.chance(0.5) { 0.12 } else { -0.12 };
                for _ in 0..steps {
                    y += rise;
                    for k in 0..2.min(n - i) {
                        h[i + k] = y;
                    }
                    i += 2;
                    if i >= n {
                        break;
                    }
                }
            } else {
                y += (rng.f32() - 0.5) * 2.0 * cfg.roughness;
                y = y.clamp(-1.5, 1.5);
                h[i] = y;
                i += 1;
            }
        }
        Self { heights: h, res }
    }

    /// Ground height at world x (linear interpolation).
    fn height(&self, x: f32) -> f32 {
        if x <= 0.0 {
            return self.heights[0];
        }
        let fi = x / self.res;
        let i = fi as usize;
        if i + 1 >= self.heights.len() {
            return *self.heights.last().unwrap();
        }
        let t = fi - i as f32;
        self.heights[i] * (1.0 - t) + self.heights[i + 1] * t
    }
}

/// The planar biped environment.
pub struct Walker2d {
    cfg: TerrainConfig,
    terrain: Terrain,
    // hull state
    x: f32,
    y: f32,
    vx: f32,
    vy: f32,
    angle: f32,
    omega: f32,
    // joints: [hip0, knee0, hip1, knee1]
    q: [f32; 4],
    qd: [f32; 4],
    contact: [bool; 2],
    steps: usize,
    done: bool,
}

impl Walker2d {
    pub fn new(cfg: TerrainConfig, seed: u64) -> Self {
        let terrain = Terrain::generate(&cfg, seed);
        let mut w = Self {
            cfg,
            terrain,
            x: 2.0,
            y: 0.0,
            vx: 0.0,
            vy: 0.0,
            angle: 0.0,
            omega: 0.0,
            q: [0.2, -0.6, -0.2, -0.8],
            qd: [0.0; 4],
            contact: [false; 2],
            steps: 0,
            done: false,
        };
        w.y = w.terrain.height(w.x) + STAND_HEIGHT;
        w
    }

    /// Hardcore terrain with the given seed.
    pub fn hardcore(seed: u64) -> Self {
        Self::new(TerrainConfig::hardcore(), seed)
    }

    /// Flat terrain (easy mode).
    pub fn flat(seed: u64) -> Self {
        Self::new(TerrainConfig::flat(), seed)
    }

    /// Foot world position for leg `l` (0/1).
    fn foot_pos(&self, l: usize) -> (f32, f32) {
        let hip = self.q[2 * l] + self.angle;
        let knee = self.q[2 * l + 1];
        // Thigh hangs from the hull; knee bends the shin.
        let kx = self.x + L1 * hip.sin();
        let ky = self.y - L1 * hip.cos();
        let shin = hip + knee;
        (kx + L2 * shin.sin(), ky - L2 * shin.cos())
    }

    fn obs(&self) -> Vec<f32> {
        let mut o = Vec::with_capacity(14 + N_LIDAR);
        o.push(self.angle);
        o.push(self.omega);
        o.push(self.vx * 0.3);
        o.push(self.vy * 0.3);
        for l in 0..2 {
            o.push(self.q[2 * l]);
            o.push(self.qd[2 * l] * 0.1);
            o.push(self.q[2 * l + 1]);
            o.push(self.qd[2 * l + 1] * 0.1);
            o.push(if self.contact[l] { 1.0 } else { 0.0 });
        }
        // Lidar: terrain clearance at 10 points ahead.
        for k in 0..N_LIDAR {
            let dx = 0.4 + 0.4 * k as f32;
            let clearance = self.y - self.terrain.height(self.x + dx);
            o.push((clearance / 2.0).clamp(-1.0, 1.5));
        }
        o
    }
}

impl Env for Walker2d {
    fn obs_dim(&self) -> usize {
        14 + N_LIDAR // 24, like BipedalWalker
    }

    fn action_spec(&self) -> ActionSpec {
        ActionSpec::Continuous(4)
    }

    fn reset(&mut self, seed: u64) -> Vec<f32> {
        *self = Walker2d::new(self.cfg, seed);
        self.obs()
    }

    fn step(&mut self, action: &Action) -> StepResult {
        debug_assert!(!self.done, "step() after done");
        let torques: [f32; 4] = match action {
            Action::Continuous(v) => {
                let mut t = [0.0f32; 4];
                for (i, s) in t.iter_mut().enumerate() {
                    *s = v.get(i).copied().unwrap_or(0.0).clamp(-1.0, 1.0);
                }
                t
            }
            Action::Discrete(_) => [0.0; 4],
        };

        // Joint dynamics (first order + damping, hard limits).
        for j in 0..4 {
            let acc = (torques[j] * MOTOR_TORQUE - JOINT_DAMPING * self.qd[j]) / JOINT_INERTIA;
            self.qd[j] += acc * DT;
            self.q[j] += self.qd[j] * DT;
            let (lo, hi) = if j % 2 == 0 {
                (-HIP_LIMIT, HIP_LIMIT)
            } else {
                (KNEE_LO, KNEE_HI)
            };
            if self.q[j] < lo {
                self.q[j] = lo;
                self.qd[j] = 0.0;
            }
            if self.q[j] > hi {
                self.q[j] = hi;
                self.qd[j] = 0.0;
            }
        }

        // Foot contacts → forces on the hull.
        let mut fx = 0.0f32;
        let mut fy = HULL_MASS * GRAVITY;
        let mut tau = -2.0 * self.angle - 0.4 * self.omega; // posture stabiliser
        for l in 0..2 {
            let (px, py) = self.foot_pos(l);
            let ground = self.terrain.height(px);
            let pen = ground - py;
            self.contact[l] = pen > 0.0;
            if pen > 0.0 {
                let foot_vy = self.vy; // massless legs: foot shares hull velocity
                let n = (CONTACT_K * pen - CONTACT_C * foot_vy).max(0.0);
                fy += n;
                // Friction opposes horizontal motion, capped by µN. Leg
                // torque pushes the body forward through the stance leg.
                let drive = torques[2 * l] * MOTOR_TORQUE * 0.5;
                let fric = (-FRICTION * self.vx * 10.0 + drive).clamp(-FRICTION * n, FRICTION * n);
                fx += fric;
                // Contact offset applies torque to the hull.
                tau += (px - self.x) * n * 0.12;
            }
        }

        // Integrate hull.
        self.vx += fx / HULL_MASS * DT;
        self.vy += fy / HULL_MASS * DT;
        self.x += self.vx * DT;
        self.y += self.vy * DT;
        self.omega += tau / HULL_INERTIA * DT;
        self.omega = self.omega.clamp(-4.0, 4.0);
        self.angle += self.omega * DT;
        self.steps += 1;

        // Reward: forward progress minus control cost (BipedalWalker-shaped).
        let mut reward = self.vx * DT * 13.0;
        reward -= 0.001 * torques.iter().map(|t| t.abs()).sum::<f32>();

        // Termination.
        let ground_here = self.terrain.height(self.x);
        let fell = self.y < ground_here + 0.25 || self.angle.abs() > 1.1;
        let finished = self.x > FINISH_X;
        if fell {
            reward = -100.0;
            self.done = true;
        } else if finished {
            reward += 50.0;
            self.done = true;
        }
        StepResult {
            obs: self.obs(),
            reward,
            done: self.done,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::envs::rollout;

    #[test]
    fn obs_dim_matches_bipedalwalker() {
        let w = Walker2d::flat(1);
        assert_eq!(w.obs_dim(), 24);
        let mut w = Walker2d::flat(1);
        assert_eq!(w.reset(1).len(), 24);
    }

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut w = Walker2d::hardcore(seed);
            w.reset(seed);
            let mut trace = vec![];
            for i in 0..100 {
                let a = Action::Continuous(vec![
                    (i as f32 * 0.1).sin(),
                    0.3,
                    -(i as f32 * 0.1).sin(),
                    0.3,
                ]);
                let r = w.step(&a);
                trace.push((r.obs[0].to_bits(), r.reward.to_bits()));
                if r.done {
                    break;
                }
            }
            trace
        };
        assert_eq!(run(4), run(4));
        assert_ne!(run(4), run(5));
    }

    #[test]
    fn zero_torque_stands_then_or_falls_eventually() {
        let mut w = Walker2d::flat(2);
        let (_, steps) = rollout(&mut w, 2, 2000, |_| Action::Continuous(vec![0.0; 4]));
        assert!(steps > 10, "should not die immediately, died at {steps}");
    }

    #[test]
    fn falling_is_penalized() {
        // Max forward hip torque tips the walker over on hardcore terrain.
        let mut w = Walker2d::hardcore(3);
        let (total, steps) = rollout(&mut w, 3, 2000, |_| {
            Action::Continuous(vec![1.0, 1.0, 1.0, 1.0])
        });
        if steps < 2000 {
            assert!(total < 0.0, "early termination should reflect the fall penalty: {total}");
        }
    }

    #[test]
    fn rollout_lengths_vary_across_seeds() {
        // The heterogeneity claim: different rollouts take different times.
        let lens: Vec<usize> = (0..12)
            .map(|seed| {
                let mut w = Walker2d::hardcore(seed);
                let mut rng = Rng::new(seed);
                rollout(&mut w, seed, 600, |_| {
                    Action::Continuous(vec![
                        rng.f32() * 2.0 - 1.0,
                        rng.f32() * 2.0 - 1.0,
                        rng.f32() * 2.0 - 1.0,
                        rng.f32() * 2.0 - 1.0,
                    ])
                })
                .1
            })
            .collect();
        let min = lens.iter().min().unwrap();
        let max = lens.iter().max().unwrap();
        assert!(max > min, "rollout lengths must vary: {lens:?}");
    }

    #[test]
    fn hardcore_terrain_has_features() {
        let t = Terrain::generate(&TerrainConfig::hardcore(), 11);
        let flat = Terrain::generate(&TerrainConfig::flat(), 11);
        let var_h: f32 = t.heights.iter().map(|h| h.abs()).sum();
        let var_f: f32 = flat.heights.iter().map(|h| h.abs()).sum();
        assert!(var_h > var_f, "hardcore must be rougher than flat");
        assert!(flat.heights.iter().all(|&h| h == 0.0));
    }

    #[test]
    fn terrain_height_interpolates() {
        let t = Terrain {
            heights: vec![0.0, 1.0, 1.0],
            res: 1.0,
        };
        assert_eq!(t.height(0.0), 0.0);
        assert_eq!(t.height(0.5), 0.5);
        assert_eq!(t.height(1.0), 1.0);
        assert_eq!(t.height(99.0), 1.0);
        assert_eq!(t.height(-5.0), 0.0);
    }
}
