//! CartPole-v1 physics (Barto, Sutton & Anderson 1983, Gym parameters).

use crate::util::Rng;

use super::{Action, ActionSpec, Env, StepResult};

const GRAVITY: f32 = 9.8;
const CART_MASS: f32 = 1.0;
const POLE_MASS: f32 = 0.1;
const TOTAL_MASS: f32 = CART_MASS + POLE_MASS;
const POLE_HALF_LEN: f32 = 0.5;
const POLE_MASS_LEN: f32 = POLE_MASS * POLE_HALF_LEN;
const FORCE_MAG: f32 = 10.0;
const TAU: f32 = 0.02;
const THETA_LIMIT: f32 = 12.0 * std::f32::consts::PI / 180.0;
const X_LIMIT: f32 = 2.4;

/// The classic cart-pole balancing task. Observation: `[x, ẋ, θ, θ̇]`;
/// actions: 0 = push left, 1 = push right; reward 1 per step alive.
#[derive(Clone, Debug, Default)]
pub struct CartPole {
    x: f32,
    x_dot: f32,
    theta: f32,
    theta_dot: f32,
    done: bool,
}

impl CartPole {
    pub fn new() -> Self {
        Self::default()
    }

    fn obs(&self) -> Vec<f32> {
        vec![self.x, self.x_dot, self.theta, self.theta_dot]
    }
}

impl Env for CartPole {
    fn obs_dim(&self) -> usize {
        4
    }

    fn action_spec(&self) -> ActionSpec {
        ActionSpec::Discrete(2)
    }

    fn reset(&mut self, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed ^ 0xCA47);
        self.x = rng.range_f64(-0.05, 0.05) as f32;
        self.x_dot = rng.range_f64(-0.05, 0.05) as f32;
        self.theta = rng.range_f64(-0.05, 0.05) as f32;
        self.theta_dot = rng.range_f64(-0.05, 0.05) as f32;
        self.done = false;
        self.obs()
    }

    fn step(&mut self, action: &Action) -> StepResult {
        debug_assert!(!self.done, "step() after done");
        let force = match action {
            Action::Discrete(1) => FORCE_MAG,
            Action::Discrete(_) => -FORCE_MAG,
            Action::Continuous(v) => v.first().copied().unwrap_or(0.0).clamp(-1.0, 1.0) * FORCE_MAG,
        };
        let cos = self.theta.cos();
        let sin = self.theta.sin();
        let temp = (force + POLE_MASS_LEN * self.theta_dot * self.theta_dot * sin) / TOTAL_MASS;
        let theta_acc = (GRAVITY * sin - cos * temp)
            / (POLE_HALF_LEN * (4.0 / 3.0 - POLE_MASS * cos * cos / TOTAL_MASS));
        let x_acc = temp - POLE_MASS_LEN * theta_acc * cos / TOTAL_MASS;
        self.x += TAU * self.x_dot;
        self.x_dot += TAU * x_acc;
        self.theta += TAU * self.theta_dot;
        self.theta_dot += TAU * theta_acc;
        self.done = self.x.abs() > X_LIMIT || self.theta.abs() > THETA_LIMIT;
        StepResult {
            obs: self.obs(),
            reward: 1.0,
            done: self.done,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let run = |seed| {
            let mut env = CartPole::new();
            env.reset(seed);
            let mut rs = vec![];
            for i in 0..50 {
                let r = env.step(&Action::Discrete(i % 2));
                rs.push(r.obs);
                if r.done {
                    break;
                }
            }
            rs
        };
        assert_eq!(run(5), run(5));
        assert_ne!(run(5), run(6));
    }

    #[test]
    fn constant_push_falls_over() {
        let mut env = CartPole::new();
        env.reset(1);
        let mut steps = 0;
        loop {
            let r = env.step(&Action::Discrete(1));
            steps += 1;
            if r.done {
                break;
            }
            assert!(steps < 500, "constant push must terminate");
        }
        assert!(steps >= 5, "shouldn't die instantly, died at {steps}");
    }

    #[test]
    fn alternating_policy_survives_longer_than_constant() {
        let run = |f: &dyn Fn(usize) -> usize| {
            let mut env = CartPole::new();
            env.reset(2);
            let mut steps = 0;
            for i in 0..500 {
                if env.step(&Action::Discrete(f(i))).done {
                    break;
                }
                steps = i;
            }
            steps
        };
        let alternating = run(&|i| i % 2);
        let constant = run(&|_| 1);
        assert!(alternating > constant);
    }
}
