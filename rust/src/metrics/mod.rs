//! Lightweight runtime metrics: counters + latency recorders, registered in
//! a process-wide registry, snapshot-able for experiment logs. Fiber's
//! leader exposes these per pool (dispatch latency, queue depth, restarts)
//! — the observability a production coordinator needs.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use once_cell::sync::Lazy;

use crate::util::Histogram;

/// A monotonically-increasing counter.
#[derive(Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A latency recorder (log-bucketed histogram under a mutex).
#[derive(Default)]
pub struct Latency {
    hist: Mutex<Histogram>,
}

impl Latency {
    pub fn record_ns(&self, ns: u64) {
        self.hist.lock().unwrap().record_ns(ns);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn snapshot(&self) -> (u64, f64, u64, u64) {
        let h = self.hist.lock().unwrap();
        (h.count(), h.mean_ns(), h.quantile_ns(0.5), h.quantile_ns(0.99))
    }
}

#[derive(Default)]
struct Registry {
    counters: BTreeMap<String, Arc<Counter>>,
    latencies: BTreeMap<String, Arc<Latency>>,
}

static REGISTRY: Lazy<Mutex<Registry>> = Lazy::new(|| Mutex::new(Registry::default()));

/// Get-or-create a named counter.
pub fn counter(name: &str) -> Arc<Counter> {
    REGISTRY
        .lock()
        .unwrap()
        .counters
        .entry(name.to_string())
        .or_default()
        .clone()
}

/// Get-or-create a named latency recorder.
pub fn latency(name: &str) -> Arc<Latency> {
    REGISTRY
        .lock()
        .unwrap()
        .latencies
        .entry(name.to_string())
        .or_default()
        .clone()
}

/// Render all metrics as `name value` lines (Prometheus-flavoured).
pub fn dump() -> String {
    let reg = REGISTRY.lock().unwrap();
    let mut out = String::new();
    for (name, c) in &reg.counters {
        out += &format!("{name} {}\n", c.get());
    }
    for (name, l) in &reg.latencies {
        let (n, mean, p50, p99) = l.snapshot();
        out += &format!("{name}_count {n}\n");
        out += &format!("{name}_mean_ns {mean:.0}\n");
        out += &format!("{name}_p50_ns {p50}\n");
        out += &format!("{name}_p99_ns {p99}\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_share() {
        let a = counter("test.m.a");
        let b = counter("test.m.a");
        a.inc();
        b.add(4);
        assert_eq!(a.get(), 5);
    }

    #[test]
    fn latency_snapshot() {
        let l = latency("test.m.lat");
        l.record_ns(1_000);
        l.record_ns(2_000);
        let (n, mean, _p50, _p99) = l.snapshot();
        assert_eq!(n, 2);
        assert!(mean >= 1_000.0 && mean <= 2_000.0);
    }

    #[test]
    fn dump_contains_entries() {
        counter("test.m.dumpme").inc();
        let d = dump();
        assert!(d.contains("test.m.dumpme 1"));
    }
}
