//! Lightweight runtime metrics: counters + latency recorders, registered in
//! a process-wide registry, snapshot-able for experiment logs. Fiber's
//! leader exposes these per pool (dispatch latency, queue depth, restarts)
//! — the observability a production coordinator needs.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicI64, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};

use once_cell::sync::Lazy;

use crate::util::Histogram;

/// Recover a guard from a poisoned mutex. Metrics are observability, not
/// invariants: a thread that panicked while holding a metrics lock left a
/// histogram mid-update at worst, and that must not cascade a panic into
/// every later `dump()` on an unrelated thread.
fn unpoison<T>(
    r: Result<MutexGuard<'_, T>, std::sync::PoisonError<MutexGuard<'_, T>>>,
) -> MutexGuard<'_, T> {
    r.unwrap_or_else(|e| e.into_inner())
}

/// A monotonically-increasing counter.
#[derive(Default)]
pub struct Counter {
    value: AtomicU64,
}

impl Counter {
    pub fn inc(&self) {
        self.add(1);
    }

    pub fn add(&self, n: u64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A settable instantaneous level (queue depth, in-flight slices): unlike
/// a [`Counter`] it can go down.
#[derive(Default)]
pub struct Gauge {
    value: AtomicI64,
}

impl Gauge {
    pub fn set(&self, v: i64) {
        self.value.store(v, Ordering::Relaxed);
    }

    pub fn add(&self, n: i64) {
        self.value.fetch_add(n, Ordering::Relaxed);
    }

    pub fn sub(&self, n: i64) {
        self.value.fetch_sub(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> i64 {
        self.value.load(Ordering::Relaxed)
    }
}

/// A latency recorder (log-bucketed histogram under a mutex).
#[derive(Default)]
pub struct Latency {
    hist: Mutex<Histogram>,
}

impl Latency {
    pub fn record_ns(&self, ns: u64) {
        unpoison(self.hist.lock()).record_ns(ns);
    }

    pub fn record(&self, d: std::time::Duration) {
        self.record_ns(d.as_nanos() as u64);
    }

    pub fn snapshot(&self) -> (u64, f64, u64, u64) {
        let h = unpoison(self.hist.lock());
        (h.count(), h.mean_ns(), h.quantile_ns(0.5), h.quantile_ns(0.99))
    }
}

#[derive(Default)]
struct Registry {
    counters: BTreeMap<String, Arc<Counter>>,
    gauges: BTreeMap<String, Arc<Gauge>>,
    latencies: BTreeMap<String, Arc<Latency>>,
}

static REGISTRY: Lazy<Mutex<Registry>> = Lazy::new(|| Mutex::new(Registry::default()));

/// Get-or-create a named counter.
pub fn counter(name: &str) -> Arc<Counter> {
    unpoison(REGISTRY.lock())
        .counters
        .entry(name.to_string())
        .or_default()
        .clone()
}

/// Get-or-create a named gauge.
pub fn gauge(name: &str) -> Arc<Gauge> {
    unpoison(REGISTRY.lock())
        .gauges
        .entry(name.to_string())
        .or_default()
        .clone()
}

/// Get-or-create a named latency recorder.
pub fn latency(name: &str) -> Arc<Latency> {
    unpoison(REGISTRY.lock())
        .latencies
        .entry(name.to_string())
        .or_default()
        .clone()
}

/// Render all metrics as `name value` lines (Prometheus-flavoured).
pub fn dump() -> String {
    let reg = unpoison(REGISTRY.lock());
    let mut out = String::new();
    for (name, c) in &reg.counters {
        out += &format!("{name} {}\n", c.get());
    }
    for (name, g) in &reg.gauges {
        out += &format!("{name} {}\n", g.get());
    }
    for (name, l) in &reg.latencies {
        let (n, mean, p50, p99) = l.snapshot();
        out += &format!("{name}_count {n}\n");
        out += &format!("{name}_mean_ns {mean:.0}\n");
        out += &format!("{name}_p50_ns {p50}\n");
        out += &format!("{name}_p99_ns {p99}\n");
    }
    out
}

/// Sanitize a metric name into the Prometheus exposition charset
/// (`[a-zA-Z_:][a-zA-Z0-9_:]*`): every other character becomes `_`.
/// Fiber's dotted names (`pool.restarts`) come out underscored.
fn prom_name(name: &str) -> String {
    let mut out = String::with_capacity(name.len());
    for (i, c) in name.chars().enumerate() {
        let ok = c.is_ascii_alphabetic()
            || c == '_'
            || c == ':'
            || (i > 0 && c.is_ascii_digit());
        out.push(if ok { c } else { '_' });
    }
    if out.is_empty() {
        out.push('_');
    }
    out
}

/// Render every registered metric in the Prometheus text exposition
/// format: `# TYPE` headers, counters and gauges as plain samples, and
/// each latency recorder as a summary — `quantile="0.5"` / `"0.99"`
/// sample lines plus `_sum` (mean × count, the histogram does not keep an
/// exact sum) and `_count`. `fiber-cli --metrics-file FILE` writes this
/// on exit so any run can drop a scrape-ready snapshot next to its trace.
pub fn export_prometheus() -> String {
    let reg = unpoison(REGISTRY.lock());
    let mut out = String::new();
    for (name, c) in &reg.counters {
        let n = prom_name(name);
        out += &format!("# TYPE {n} counter\n{n} {}\n", c.get());
    }
    for (name, g) in &reg.gauges {
        let n = prom_name(name);
        out += &format!("# TYPE {n} gauge\n{n} {}\n", g.get());
    }
    for (name, l) in &reg.latencies {
        let n = format!("{}_ns", prom_name(name));
        let (count, mean, p50, p99) = l.snapshot();
        out += &format!("# TYPE {n} summary\n");
        out += &format!("{n}{{quantile=\"0.5\"}} {p50}\n");
        out += &format!("{n}{{quantile=\"0.99\"}} {p99}\n");
        out += &format!("{n}_sum {:.0}\n", mean * count as f64);
        out += &format!("{n}_count {count}\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate_and_share() {
        let a = counter("test.m.a");
        let b = counter("test.m.a");
        a.inc();
        b.add(4);
        assert_eq!(a.get(), 5);
    }

    #[test]
    fn latency_snapshot() {
        let l = latency("test.m.lat");
        l.record_ns(1_000);
        l.record_ns(2_000);
        let (n, mean, _p50, _p99) = l.snapshot();
        assert_eq!(n, 2);
        assert!(mean >= 1_000.0 && mean <= 2_000.0);
    }

    #[test]
    fn dump_contains_entries() {
        counter("test.m.dumpme").inc();
        let d = dump();
        assert!(d.contains("test.m.dumpme 1"));
    }

    #[test]
    fn gauges_move_both_ways_and_share() {
        let g = gauge("test.m.gauge");
        g.set(10);
        gauge("test.m.gauge").add(5);
        g.sub(12);
        assert_eq!(g.get(), 3);
        assert!(dump().contains("test.m.gauge 3"));
        g.set(-4);
        assert_eq!(g.get(), -4, "gauges may go negative");
    }

    #[test]
    fn prometheus_export_types_and_sanitizes() {
        counter("test.prom.hits").add(3);
        gauge("test.prom.depth").set(-2);
        let l = latency("test.prom.lat");
        l.record_ns(1_000);
        l.record_ns(3_000);
        let text = export_prometheus();
        assert!(text.contains("# TYPE test_prom_hits counter"), "{text}");
        assert!(text.contains("test_prom_hits 3"), "{text}");
        assert!(text.contains("# TYPE test_prom_depth gauge"), "{text}");
        assert!(text.contains("test_prom_depth -2"), "{text}");
        assert!(text.contains("# TYPE test_prom_lat_ns summary"), "{text}");
        assert!(text.contains("test_prom_lat_ns{quantile=\"0.5\"}"), "{text}");
        assert!(text.contains("test_prom_lat_ns{quantile=\"0.99\"}"), "{text}");
        assert!(text.contains("test_prom_lat_ns_count 2"), "{text}");
        assert!(text.contains("test_prom_lat_ns_sum"), "{text}");
    }

    #[test]
    fn prom_name_keeps_legal_chars() {
        assert_eq!(prom_name("pool.restarts"), "pool_restarts");
        assert_eq!(prom_name("ring:gen2"), "ring:gen2");
        assert_eq!(prom_name("9lives"), "_lives");
        assert_eq!(prom_name(""), "_");
    }

    #[test]
    fn poisoned_latency_lock_recovers() {
        let l = latency("test.m.poison");
        l.record_ns(1_000);
        // Poison the histogram mutex by panicking while holding it.
        let l2 = l.clone();
        let _ = std::thread::spawn(move || {
            let _guard = l2.hist.lock().unwrap();
            panic!("poison the metrics lock");
        })
        .join();
        // Recording and snapshotting must keep working afterwards.
        l.record_ns(2_000);
        let (n, _, _, _) = l.snapshot();
        assert_eq!(n, 2);
        assert!(dump().contains("test.m.poison_count 2"));
    }
}
