//! `fiber::pop` — the population-based-training orchestrator (the
//! population layer the paper's title promises).
//!
//! PBT (Jaderberg et al. 2017) trains a *population* of trials
//! concurrently, periodically replacing the worst performers with
//! perturbed clones of the best. This module is the first subsystem that
//! stresses all four building blocks at once:
//!
//! * **Pool** runs the train slices: each [`Trial`]'s fixed-budget slice
//!   is an ordinary task, so worker failures heal through the pending
//!   table — a killed worker's slice is requeued with the same
//!   checkpoint reference and the trial is never lost.
//! * **Store** holds the checkpoints: a trial's model is a
//!   reference-held [`crate::store::ObjRef`] (held puts on the producer,
//!   leader-side refcounts — never evictable while a trial names it), so
//!   the exploit step (bottom-q% cloning a top-q% model) copies a
//!   24-byte handle instead of θ, and the shared ES noise table
//!   circulates as one pinned blob per node.
//! * **Envs** ([`crate::envs::cartpole`], [`crate::envs::walker2d`])
//!   provide the simulators; **algo** provides the two trial backends —
//!   ES slices wrapping [`crate::algo::es::EsMaster`] (mutable `lr`,
//!   `sigma`) and PPO slices wrapping [`crate::algo::ppo::PpoTrainer`]
//!   (mutable `lr`, `clip`, `ent_coef`).
//!
//! Dispatch is **asynchronous** by default: there is no generation
//! barrier — a trial re-enters the queue the moment its slice returns,
//! with exploit/explore decided against the population's current scores,
//! so heterogeneous slice durations never serialize the population
//! (compare [`DispatchMode::Generational`], the lock-step baseline the
//! `pbt_figure` panel and `benches/pbt.rs` measure against). The
//! [`Leaderboard`] records every slice, clone and mutation for post-hoc
//! lineage analysis.
//!
//! Surface: `fiber-cli pbt --algo {es,ppo} --pop N --workers W [--proc
//! true] [--kill-rank R]`, `examples/pbt.rs`, and
//! `experiments::pbt_figure`. The [`Leaderboard`] exports the full
//! lineage log — per-trial hyper-parameter schedules included — as
//! `pbt_lineage.json`.
//!
//! # Examples
//!
//! ```
//! use fiber::api::pool::Pool;
//! use fiber::pop::{DispatchMode, PbtConfig, PopulationRunner};
//!
//! // A tiny async population: 2 ES-on-cartpole trials, 1 slice each.
//! let store = fiber::store::node_or_host(64 << 20);
//! let pool = Pool::builder()
//!     .processes(2)
//!     .store(store.clone())
//!     .build()
//!     .unwrap();
//! let cfg = PbtConfig {
//!     pop: 2,
//!     slices: 1,
//!     iters_per_slice: 1,
//!     max_steps: 40,
//!     pop_inner: 4,
//!     ..Default::default()
//! };
//! let mut runner = PopulationRunner::new(cfg, store).unwrap();
//! let report = runner.run(&pool, DispatchMode::Async).unwrap();
//! assert_eq!(report.slices_completed, 2);
//! assert!(runner.leaderboard().events().len() >= 4); // 2 inits + 2 slices
//! ```

pub mod backend;
pub mod leaderboard;
pub mod runner;
pub mod trial;

pub use backend::{
    default_hparams, init_checkpoint, put_noise_table, register_pbt_tasks, run_slice, EnvKind,
    PbtAlgo, SliceInput, SliceOutput, SLICE_TASK,
};
pub use leaderboard::{Leaderboard, LineageEvent, LineageEventKind};
pub use runner::{DispatchMode, PbtConfig, PbtReport, PopulationRunner};
pub use trial::{truncation_split, Hparam, Hparams, Trial, TrialId};
