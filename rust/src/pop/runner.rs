//! The population orchestrator: asynchronous PBT over Pool workers.
//!
//! The runner owns the trials and a store node. Each trial repeatedly
//! runs a fixed-budget **train slice** as a pool task; in the default
//! [`DispatchMode::Async`] there is **no generation barrier** — the
//! moment a trial's slice returns, the runner applies truncation-selection
//! exploit/explore against the population's *current* scores and
//! re-dispatches the trial, so fast trials never idle behind slow ones.
//! [`DispatchMode::Generational`] is the lock-step baseline the
//! `pbt_figure` panel and `benches/pbt.rs` compare against.
//!
//! Exploit is a store operation: the bottom-q trial adopts a top-q
//! trial's checkpoint by copying its 24-byte [`ObjRef`] and bumping a
//! refcount — θ itself never moves. The leader also faults every
//! completed checkpoint into its own node, so a worker crash can never
//! take the only copy of a trial's lineage with it.

use std::collections::HashMap;
use std::sync::Arc;
use std::time::Instant;

use anyhow::{Context, Result};

use crate::api::pool::{MapHandle, MapSelect, Pool};
use crate::store::{ObjId, ObjRef, StoreNode};
use crate::util::Rng;

use super::backend::{
    self, default_hparams, register_pbt_tasks, EnvKind, PbtAlgo, SliceInput, SliceOutput,
    SLICE_TASK,
};
use super::leaderboard::{Leaderboard, LineageEvent, LineageEventKind};
use super::trial::{truncation_split, Trial, TrialId};

/// Population-level configuration.
#[derive(Clone, Debug)]
pub struct PbtConfig {
    pub algo: PbtAlgo,
    pub env: EnvKind,
    /// Population size (>= 2).
    pub pop: usize,
    /// Train slices each trial must complete.
    pub slices: usize,
    /// Train iterations inside one slice (the fixed budget).
    pub iters_per_slice: usize,
    /// Episode step cap per rollout.
    pub max_steps: usize,
    /// ES inner mirrored population per update (even).
    pub pop_inner: usize,
    /// PPO rollout horizon per iteration.
    pub horizon: usize,
    /// Truncation quantile: the bottom q clone a top-q checkpoint.
    pub quantile: f32,
    pub seed: u64,
    /// Chaos: pool worker id to kill mid-slice (0 = disarmed). Stays
    /// armed on every dispatch until the pool reports a restart.
    pub kill_worker: u64,
    /// ES: circulate the shared noise table as one store blob.
    pub store_noise_table: bool,
    /// Task name to dispatch (`pbt.slice`; benches substitute a
    /// synthetic slice to time pure dispatch).
    pub slice_task: String,
    /// Print a progress line per slice completion.
    pub verbose: bool,
}

impl Default for PbtConfig {
    fn default() -> Self {
        Self {
            algo: PbtAlgo::Es,
            env: EnvKind::CartPole,
            pop: 8,
            slices: 4,
            iters_per_slice: 2,
            max_steps: 200,
            pop_inner: 16,
            horizon: 64,
            quantile: 0.25,
            seed: 7,
            kill_worker: 0,
            store_noise_table: false,
            slice_task: SLICE_TASK.to_string(),
            verbose: false,
        }
    }
}

/// How slices are scheduled.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DispatchMode {
    /// Barrier-free: re-dispatch each trial the moment its slice returns.
    Async,
    /// Lock-step generations (the baseline PBT loop).
    Generational,
}

/// Result of a population run.
#[derive(Clone, Debug)]
pub struct PbtReport {
    pub best: TrialId,
    pub best_score: f32,
    pub mean_score: f32,
    pub slices_completed: usize,
    pub exploits: usize,
    pub wall_s: f64,
}

/// The PBT orchestrator.
pub struct PopulationRunner {
    cfg: PbtConfig,
    store: Arc<StoreNode>,
    trials: Vec<Trial>,
    rng: Rng,
    board: Leaderboard,
    table_ref: Option<ObjRef<Vec<f32>>>,
    exploits: usize,
    t0: Instant,
    /// Detached per-slice trace spans: begun at dispatch, ended (recorded
    /// with their full dispatch→fold duration) when the result folds in.
    slice_spans: HashMap<TrialId, crate::trace::Span>,
    /// Cached gauge of dispatched-but-unfolded slices (cached so the hot
    /// dispatch path skips the metrics-registry lock).
    inflight_gauge: Arc<crate::metrics::Gauge>,
}

impl PopulationRunner {
    /// Build the initial population: per-trial random hyper-parameters
    /// (log-uniform over each range) and per-trial initial checkpoints,
    /// `put` into `store` and referenced for their lifetime as a trial's
    /// current checkpoint.
    pub fn new(cfg: PbtConfig, store: Arc<StoreNode>) -> Result<PopulationRunner> {
        anyhow::ensure!(cfg.pop >= 2, "a population needs at least 2 trials");
        anyhow::ensure!(cfg.slices >= 1, "each trial needs at least 1 slice");
        register_pbt_tasks();
        let mut rng = Rng::new(cfg.seed ^ 0x0b57);
        let mut trials = Vec::with_capacity(cfg.pop);
        let mut board = Leaderboard::new();
        for i in 0..cfg.pop {
            let mut hparams = default_hparams(cfg.algo);
            hparams.resample(&mut rng);
            let ck = backend::init_checkpoint(
                cfg.algo,
                cfg.env,
                cfg.seed.wrapping_add(i as u64 * 7919),
            );
            // Held put: stored and referenced atomically — this very
            // reference is the leader's hold on the trial's current
            // checkpoint (released when the trial moves off it).
            let checkpoint = store.put_held(&ck)?;
            let id = TrialId(i as u64);
            let hp_snapshot = hparams.to_wire();
            trials.push(Trial {
                id,
                hparams,
                checkpoint,
                score: f32::NEG_INFINITY,
                best_score: f32::NEG_INFINITY,
                slices_done: 0,
                parent: None,
                clones: 0,
            });
            board.record(LineageEvent {
                trial: id,
                slice: 0,
                t_s: 0.0,
                kind: LineageEventKind::Init,
                best_so_far: f32::NEG_INFINITY,
                hparams: hp_snapshot,
            });
        }
        let table_ref = if cfg.store_noise_table && cfg.algo == PbtAlgo::Es {
            Some(backend::put_noise_table(&store)?)
        } else {
            None
        };
        Ok(PopulationRunner {
            cfg,
            store,
            trials,
            rng,
            board,
            table_ref,
            exploits: 0,
            t0: Instant::now(),
            slice_spans: HashMap::new(),
            inflight_gauge: crate::metrics::gauge("pop.inflight"),
        })
    }

    pub fn trials(&self) -> &[Trial] {
        &self.trials
    }

    pub fn leaderboard(&self) -> &Leaderboard {
        &self.board
    }

    pub fn exploits(&self) -> usize {
        self.exploits
    }

    /// Drive the population until every trial completed its slices.
    pub fn run(&mut self, pool: &Pool, mode: DispatchMode) -> Result<PbtReport> {
        self.t0 = Instant::now();
        match mode {
            DispatchMode::Async => self.run_async(pool)?,
            DispatchMode::Generational => self.run_generational(pool)?,
        }
        Ok(self.report())
    }

    fn run_async(&mut self, pool: &Pool) -> Result<()> {
        // Event-driven wait-any: every in-flight slice subscribes its
        // trial id to one completion channel, and the collector's delivery
        // of a result wakes `select()` exactly once for it. There is no
        // poll interval and no ready-scan — the re-dispatch latency is the
        // wakeup itself.
        let select: MapSelect<SliceOutput> = MapSelect::new();
        for idx in 0..self.trials.len() {
            let id = self.trials[idx].id;
            select.add(id.0, self.dispatch(pool, idx)?);
        }
        while let Some((key, out)) = select.select() {
            let id = TrialId(key);
            let out = out
                .with_context(|| format!("pbt slice of {id}"))?
                .pop()
                .context("empty slice result")?;
            let idx = self.trial_index(id);
            self.complete(idx, out)?;
            // No barrier: exploit against the scores of *right now*, then
            // put the trial straight back to work.
            if self.trials[idx].slices_done < self.cfg.slices {
                self.exploit_explore(idx)?;
                select.add(key, self.dispatch(pool, idx)?);
            }
        }
        Ok(())
    }

    fn run_generational(&mut self, pool: &Pool) -> Result<()> {
        for gen in 0..self.cfg.slices {
            let mut handles: Vec<(usize, MapHandle<SliceOutput>)> =
                Vec::with_capacity(self.trials.len());
            for idx in 0..self.trials.len() {
                handles.push((idx, self.dispatch(pool, idx)?));
            }
            for (idx, handle) in handles {
                let out = handle
                    .wait()
                    .with_context(|| format!("pbt slice of {}", self.trials[idx].id))?
                    .pop()
                    .context("empty slice result")?;
                self.complete(idx, out)?;
            }
            if gen + 1 == self.cfg.slices {
                break;
            }
            // Exploit/explore at the generation barrier, on one snapshot
            // of the scores.
            let scores: Vec<(TrialId, f32)> =
                self.trials.iter().map(|t| (t.id, t.score)).collect();
            let (bottom, top) = truncation_split(&scores, self.cfg.quantile);
            for id in bottom {
                let idx = self.trial_index(id);
                self.exploit_from(idx, &top)?;
            }
        }
        Ok(())
    }

    fn dispatch(&mut self, pool: &Pool, idx: usize) -> Result<MapHandle<SliceOutput>> {
        let t = &self.trials[idx];
        // Chaos stays armed on every dispatch until the pool has actually
        // replaced a worker. Only the worker whose id matches the target
        // dies, so the caller must keep at least `workers` slices in
        // flight (pop >= workers — the CLI enforces this) for the victim
        // to be guaranteed to fetch an armed one.
        let kill_worker = if self.cfg.kill_worker != 0 && pool.restarts() == 0 {
            self.cfg.kill_worker
        } else {
            0
        };
        let input = SliceInput {
            trial: t.id.0,
            slice: t.slices_done as u64,
            algo: self.cfg.algo.tag(),
            env: self.cfg.env.tag(),
            seed: self.cfg.seed,
            iters: self.cfg.iters_per_slice as u64,
            max_steps: self.cfg.max_steps as u64,
            pop_inner: self.cfg.pop_inner as u64,
            horizon: self.cfg.horizon as u64,
            hparams: t.hparams.to_wire(),
            checkpoint: t.checkpoint,
            table: self.table_ref,
            kill_worker,
        };
        let trial_id = t.id;
        // The slice span is detached — it begins here and ends on another
        // turn of the loop, when complete() folds the result in. Wrapping
        // the submission makes it the ambient parent, so the Pool's
        // dispatch span (and through the task envelope, the worker-side
        // run span and any store fetches the slice performs) all chain
        // under this trial's slice.
        // The ckpt arg is the audit hook for `trace::check`'s
        // `pop.slice-ckpt` invariant: a chaos-requeued slice must carry
        // the same checkpoint ref as its first dispatch.
        let span = crate::trace::Span::begin_detached("pop.slice", crate::trace::current_span())
            .arg("trial", trial_id.0 as i64)
            .arg("slice", self.trials[idx].slices_done as i64)
            .arg("ckpt", crate::store::trace_obj(self.trials[idx].checkpoint.id()));
        let t_dispatch = Instant::now();
        let handle = crate::trace::with_span(span.id(), || {
            pool.map_async_chunked(&self.cfg.slice_task, std::iter::once(input), 1)
        })?;
        crate::metrics::latency("pop.dispatch.latency")
            .record_ns(t_dispatch.elapsed().as_nanos() as u64);
        self.slice_spans.insert(trial_id, span);
        self.inflight_gauge.add(1);
        Ok(handle)
    }

    /// Fold a finished slice into the trial: adopt the new checkpoint
    /// (replicated onto the leader's node so no worker crash can strand
    /// the lineage), update scores, and log the event.
    fn complete(&mut self, idx: usize, out: SliceOutput) -> Result<()> {
        // Close this trial's detached slice span: its recorded duration is
        // the full dispatch→fold latency, fed to `metrics::latency` too.
        self.slice_spans.remove(&TrialId(out.trial));
        self.inflight_gauge.sub(1);
        // Replicate onto the leader's node and take the leader's own
        // reference. The producer's handoff reference stays until a later
        // slice resumes from this checkpoint (the worker-side ledger —
        // see `pop::backend`'s HANDOFFS), so no copy is ever observable
        // at refcount 0 while a trial names it. Echo slices (synthetic
        // benches return their input checkpoint unchanged) are naturally
        // balanced: one incref here, one decref in release(old) below.
        self.store
            .get_bytes(out.checkpoint.id())
            .with_context(|| format!("replicate checkpoint of trial {}", out.trial))?;
        self.store.incref(out.checkpoint.id());
        let old = self.trials[idx].checkpoint.id();
        self.release(old);
        let t = &mut self.trials[idx];
        t.checkpoint = out.checkpoint;
        t.score = out.reward;
        t.best_score = t.best_score.max(out.reward);
        t.slices_done += 1;
        let (id, slice, best) = (t.id, t.slices_done, t.best_score);
        let hp_snapshot = t.hparams.to_wire();
        let t_s = self.t0.elapsed().as_secs_f64();
        self.board.record(LineageEvent {
            trial: id,
            slice,
            t_s,
            kind: LineageEventKind::Slice { reward: out.reward },
            best_so_far: best,
            hparams: hp_snapshot,
        });
        // Scores feed the live leaderboard (`fiber-cli top`'s POP
        // section); milli-units keep the integer-only trace arg schema.
        crate::trace::instant(
            "pop.score",
            &[
                ("trial", out.trial as i64),
                ("slice", slice as i64),
                ("reward_milli", (out.reward as f64 * 1000.0) as i64),
                ("best_milli", (best as f64 * 1000.0) as i64),
            ],
        );
        let scored: Vec<f32> = self
            .trials
            .iter()
            .filter(|t| t.slices_done > 0)
            .map(|t| t.score)
            .collect();
        let pop_best = scored.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let pop_mean = scored.iter().sum::<f32>() / scored.len() as f32;
        self.board.record_population(t_s, pop_best, pop_mean);
        if self.cfg.verbose {
            println!(
                "[{t_s:7.2}s] {id} slice {slice}/{}  reward {:>9.2}  best {best:>9.2}  \
                 (worker {})",
                self.cfg.slices, out.reward, out.worker
            );
        }
        Ok(())
    }

    /// Truncation selection for the trial that just finished a slice: if
    /// it ranks in the bottom quantile of the currently-scored
    /// population, exploit a top-quantile trial.
    fn exploit_explore(&mut self, idx: usize) -> Result<()> {
        let scores: Vec<(TrialId, f32)> = self
            .trials
            .iter()
            .filter(|t| t.slices_done > 0)
            .map(|t| (t.id, t.score))
            .collect();
        if scores.len() < 2 {
            return Ok(());
        }
        let (bottom, top) = truncation_split(&scores, self.cfg.quantile);
        if !bottom.contains(&self.trials[idx].id) {
            return Ok(());
        }
        self.exploit_from(idx, &top)
    }

    /// Exploit: adopt a uniformly-chosen source's checkpoint (24-byte
    /// `ObjRef` clone + incref — θ never moves) and hyper-parameters,
    /// then explore by perturbing the copied hyper-parameters.
    pub(crate) fn exploit_from(&mut self, idx: usize, top: &[TrialId]) -> Result<()> {
        if top.is_empty() {
            return Ok(());
        }
        let src_id = top[self.rng.below(top.len())];
        if src_id == self.trials[idx].id {
            return Ok(());
        }
        let src = &self.trials[self.trial_index(src_id)];
        let (src_ck, src_hp, src_score) = (src.checkpoint, src.hparams.clone(), src.score);
        self.store.incref(src_ck.id());
        let old = self.trials[idx].checkpoint.id();
        self.release(old);
        let t = &mut self.trials[idx];
        t.checkpoint = src_ck;
        t.hparams = src_hp;
        t.parent = Some(src_id);
        t.clones += 1;
        t.score = src_score;
        let (id, slice, best) = (t.id, t.slices_done, t.best_score);
        let adopted = t.hparams.to_wire();
        let t_s = self.t0.elapsed().as_secs_f64();
        self.board.record(LineageEvent {
            trial: id,
            slice,
            t_s,
            kind: LineageEventKind::Clone { parent: src_id },
            best_so_far: best,
            hparams: adopted,
        });
        // Trace events carry the same lineage ids the Leaderboard logs,
        // so a trace join on `trial`/`parent` lines up with the lineage.
        crate::trace::instant(
            "pop.exploit",
            &[
                ("trial", id.0 as i64),
                ("parent", src_id.0 as i64),
                ("slice", slice as i64),
            ],
        );
        self.trials[idx].hparams.perturb(&mut self.rng);
        crate::trace::instant(
            "pop.mutate",
            &[("trial", id.0 as i64), ("slice", slice as i64)],
        );
        self.board.record(LineageEvent {
            trial: id,
            slice,
            t_s,
            kind: LineageEventKind::Explore,
            best_so_far: best,
            hparams: self.trials[idx].hparams.to_wire(),
        });
        self.exploits += 1;
        if self.cfg.verbose {
            println!("[{t_s:7.2}s] {id} exploits {src_id} (clone by ref) and explores");
        }
        Ok(())
    }

    /// Drop the runner's reference to a checkpoint blob (it may then be
    /// LRU-evicted once nothing else references it).
    fn release(&self, id: ObjId) {
        self.store.decref(id);
    }

    fn trial_index(&self, id: TrialId) -> usize {
        // Ids equal positions by construction (new() assigns TrialId(i)
        // and the population is never reordered or resized).
        debug_assert_eq!(self.trials[id.0 as usize].id, id);
        id.0 as usize
    }

    fn report(&self) -> PbtReport {
        let best = self
            .trials
            .iter()
            .max_by(|a, b| {
                a.best_score
                    .partial_cmp(&b.best_score)
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .expect("non-empty population");
        let mean = self.trials.iter().map(|t| t.score).sum::<f32>() / self.trials.len() as f32;
        PbtReport {
            best: best.id,
            best_score: best.best_score,
            mean_score: mean,
            slices_completed: self.trials.iter().map(|t| t.slices_done).sum(),
            exploits: self.exploits,
            wall_s: self.t0.elapsed().as_secs_f64(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> PbtConfig {
        PbtConfig {
            pop: 4,
            slices: 2,
            iters_per_slice: 1,
            max_steps: 60,
            pop_inner: 4,
            ..Default::default()
        }
    }

    #[test]
    fn exploit_clones_checkpoint_by_reference() {
        let store = crate::store::node_or_host(256 << 20);
        let mut runner = PopulationRunner::new(tiny_cfg(), store).unwrap();
        // Fabricate scores: trial 0 is the straggler, 3 the front-runner.
        for (i, t) in runner.trials.iter_mut().enumerate() {
            t.score = i as f32;
            t.slices_done = 1;
        }
        let src_ck = runner.trials[3].checkpoint;
        let src_hp = runner.trials[3].hparams.clone();
        runner.exploit_from(0, &[TrialId(3)]).unwrap();
        let t = &runner.trials[0];
        assert_eq!(
            t.checkpoint.id(),
            src_ck.id(),
            "exploit must adopt the source handle, not copy θ"
        );
        assert_eq!(t.parent, Some(TrialId(3)));
        assert_eq!(t.clones, 1);
        assert_eq!(t.score, 3.0, "the trial now *is* the source model");
        // Explore perturbed the copied hyper-parameters within range.
        for (h, s) in t.hparams.0.iter().zip(&src_hp.0) {
            assert!(h.value >= h.min && h.value <= h.max);
            let _ = s;
        }
        assert_eq!(runner.exploits(), 1);
        let parents = runner.leaderboard().parents(TrialId(0));
        assert_eq!(parents, vec![TrialId(3)]);
    }

    #[test]
    fn exploit_decisions_are_deterministic_for_a_seed() {
        let decide = |seed| {
            let store = crate::store::node_or_host(256 << 20);
            let cfg = PbtConfig { seed, ..tiny_cfg() };
            let mut runner = PopulationRunner::new(cfg, store).unwrap();
            for (i, t) in runner.trials.iter_mut().enumerate() {
                t.score = (i % 3) as f32;
                t.slices_done = 1;
            }
            runner
                .exploit_from(0, &[TrialId(1), TrialId(2), TrialId(3)])
                .unwrap();
            (
                runner.trials[0].parent,
                runner.trials[0].hparams.to_wire(),
            )
        };
        assert_eq!(decide(11), decide(11), "same seed, same clone + mutation");
    }

    #[test]
    fn self_exploit_is_a_no_op() {
        let store = crate::store::node_or_host(256 << 20);
        let mut runner = PopulationRunner::new(tiny_cfg(), store).unwrap();
        let before = runner.trials[2].checkpoint.id();
        runner.exploit_from(2, &[TrialId(2)]).unwrap();
        assert_eq!(runner.trials[2].checkpoint.id(), before);
        assert_eq!(runner.exploits(), 0);
    }
}
