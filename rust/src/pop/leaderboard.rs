//! Post-hoc analysis: the population leaderboard and per-trial lineage
//! log.
//!
//! Every slice completion, exploit (checkpoint clone) and explore
//! (hyper-parameter mutation) appends a [`LineageEvent`]; the population
//! best/mean series is sampled on the same cadence. Together they answer
//! the questions PBT papers plot: who descended from whom, when each
//! trial's hyper-parameters jumped, and how the population front moved
//! over wall-clock time.

use super::trial::TrialId;

/// What happened at one lineage step.
#[derive(Clone, Debug, PartialEq)]
pub enum LineageEventKind {
    /// The trial entered the population.
    Init,
    /// A train slice completed with this evaluation reward.
    Slice { reward: f32 },
    /// Exploit: the trial adopted `parent`'s checkpoint — a 24-byte
    /// `ObjRef` clone, not a θ copy.
    Clone { parent: TrialId },
    /// Explore: the trial's hyper-parameters were perturbed/resampled.
    Explore,
}

/// One entry in the lineage log.
#[derive(Clone, Debug)]
pub struct LineageEvent {
    pub trial: TrialId,
    /// Slices the trial had completed when the event fired.
    pub slice: usize,
    /// Wall-clock seconds since the run started.
    pub t_s: f64,
    pub kind: LineageEventKind,
    /// The trial's best slice reward so far (monotone per lineage).
    pub best_so_far: f32,
}

/// The run-wide event log plus the sampled population series.
#[derive(Clone, Debug, Default)]
pub struct Leaderboard {
    events: Vec<LineageEvent>,
    /// `(t_s, best, mean)` over trials with at least one score, sampled
    /// at every slice completion.
    series: Vec<(f64, f32, f32)>,
}

impl Leaderboard {
    pub fn new() -> Leaderboard {
        Leaderboard::default()
    }

    pub fn record(&mut self, event: LineageEvent) {
        self.events.push(event);
    }

    pub fn record_population(&mut self, t_s: f64, best: f32, mean: f32) {
        self.series.push((t_s, best, mean));
    }

    pub fn events(&self) -> &[LineageEvent] {
        &self.events
    }

    /// The best-vs-mean population reward series over wall clock.
    pub fn series(&self) -> &[(f64, f32, f32)] {
        &self.series
    }

    /// All events of one trial, in order.
    pub fn lineage(&self, trial: TrialId) -> Vec<&LineageEvent> {
        self.events.iter().filter(|e| e.trial == trial).collect()
    }

    /// Exploits recorded for `trial` (clone events, with their sources).
    pub fn parents(&self, trial: TrialId) -> Vec<TrialId> {
        self.lineage(trial)
            .into_iter()
            .filter_map(|e| match e.kind {
                LineageEventKind::Clone { parent } => Some(parent),
                _ => None,
            })
            .collect()
    }

    /// The lineage invariant: a trial's recorded best-so-far never
    /// decreases (exploits adopt weights, not history — the trial's own
    /// achieved rewards only accumulate).
    pub fn best_is_monotone(&self, trial: TrialId) -> bool {
        let mut last = f32::NEG_INFINITY;
        for e in self.lineage(trial) {
            if e.best_so_far < last {
                return false;
            }
            last = e.best_so_far;
        }
        true
    }

    /// Slice completions recorded for `trial`.
    pub fn slices(&self, trial: TrialId) -> usize {
        self.lineage(trial)
            .iter()
            .filter(|e| matches!(e.kind, LineageEventKind::Slice { .. }))
            .count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(trial: u64, slice: usize, kind: LineageEventKind, best: f32) -> LineageEvent {
        LineageEvent {
            trial: TrialId(trial),
            slice,
            t_s: slice as f64,
            kind,
            best_so_far: best,
        }
    }

    #[test]
    fn lineage_filters_and_counts_per_trial() {
        let mut b = Leaderboard::new();
        b.record(ev(0, 0, LineageEventKind::Init, f32::NEG_INFINITY));
        b.record(ev(1, 0, LineageEventKind::Init, f32::NEG_INFINITY));
        b.record(ev(0, 1, LineageEventKind::Slice { reward: 2.0 }, 2.0));
        b.record(ev(1, 1, LineageEventKind::Slice { reward: 5.0 }, 5.0));
        b.record(ev(0, 1, LineageEventKind::Clone { parent: TrialId(1) }, 2.0));
        b.record(ev(0, 1, LineageEventKind::Explore, 2.0));
        b.record(ev(0, 2, LineageEventKind::Slice { reward: 6.0 }, 6.0));
        assert_eq!(b.lineage(TrialId(0)).len(), 5);
        assert_eq!(b.slices(TrialId(0)), 2);
        assert_eq!(b.slices(TrialId(1)), 1);
        assert_eq!(b.parents(TrialId(0)), vec![TrialId(1)]);
        assert!(b.parents(TrialId(1)).is_empty());
        assert!(b.best_is_monotone(TrialId(0)));
        assert!(b.best_is_monotone(TrialId(1)));
    }

    #[test]
    fn monotone_check_catches_regressions() {
        let mut b = Leaderboard::new();
        b.record(ev(3, 1, LineageEventKind::Slice { reward: 4.0 }, 4.0));
        b.record(ev(3, 2, LineageEventKind::Slice { reward: 1.0 }, 3.0));
        assert!(!b.best_is_monotone(TrialId(3)), "best-so-far fell: 4 → 3");
    }
}
