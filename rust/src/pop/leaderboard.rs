//! Post-hoc analysis: the population leaderboard and per-trial lineage
//! log.
//!
//! Every slice completion, exploit (checkpoint clone) and explore
//! (hyper-parameter mutation) appends a [`LineageEvent`] — including a
//! snapshot of the trial's hyper-parameters, so the full per-trial
//! **hyper-parameter schedule** is reconstructible; the population
//! best/mean series is sampled on the same cadence. Together they answer
//! the questions PBT papers plot: who descended from whom, when each
//! trial's hyper-parameters jumped, and how the population front moved
//! over wall-clock time. [`Leaderboard::export`] dumps the whole log as
//! `pbt_lineage.json` next to the BENCH files (see `fiber-cli pbt` and
//! `benches/pbt.rs`), round-trippable through
//! [`crate::benchkit::Json::parse`].

use crate::benchkit::Json;

use super::trial::TrialId;

/// What happened at one lineage step.
#[derive(Clone, Debug, PartialEq)]
pub enum LineageEventKind {
    /// The trial entered the population.
    Init,
    /// A train slice completed with this evaluation reward.
    Slice { reward: f32 },
    /// Exploit: the trial adopted `parent`'s checkpoint — a 24-byte
    /// `ObjRef` clone, not a θ copy.
    Clone { parent: TrialId },
    /// Explore: the trial's hyper-parameters were perturbed/resampled.
    Explore,
}

/// One entry in the lineage log.
#[derive(Clone, Debug)]
pub struct LineageEvent {
    pub trial: TrialId,
    /// Slices the trial had completed when the event fired.
    pub slice: usize,
    /// Wall-clock seconds since the run started.
    pub t_s: f64,
    pub kind: LineageEventKind,
    /// The trial's best slice reward so far (monotone per lineage).
    pub best_so_far: f32,
    /// Snapshot of the trial's hyper-parameters at this event (post-clone
    /// for exploits, post-perturbation for explores) — consecutive
    /// snapshots of one trial are its hyper-parameter schedule.
    pub hparams: Vec<(String, f32)>,
}

/// The run-wide event log plus the sampled population series.
#[derive(Clone, Debug, Default)]
pub struct Leaderboard {
    events: Vec<LineageEvent>,
    /// `(t_s, best, mean)` over trials with at least one score, sampled
    /// at every slice completion.
    series: Vec<(f64, f32, f32)>,
}

impl Leaderboard {
    pub fn new() -> Leaderboard {
        Leaderboard::default()
    }

    pub fn record(&mut self, event: LineageEvent) {
        self.events.push(event);
    }

    pub fn record_population(&mut self, t_s: f64, best: f32, mean: f32) {
        self.series.push((t_s, best, mean));
    }

    pub fn events(&self) -> &[LineageEvent] {
        &self.events
    }

    /// The best-vs-mean population reward series over wall clock.
    pub fn series(&self) -> &[(f64, f32, f32)] {
        &self.series
    }

    /// All events of one trial, in order.
    pub fn lineage(&self, trial: TrialId) -> Vec<&LineageEvent> {
        self.events.iter().filter(|e| e.trial == trial).collect()
    }

    /// Exploits recorded for `trial` (clone events, with their sources).
    pub fn parents(&self, trial: TrialId) -> Vec<TrialId> {
        self.lineage(trial)
            .into_iter()
            .filter_map(|e| match e.kind {
                LineageEventKind::Clone { parent } => Some(parent),
                _ => None,
            })
            .collect()
    }

    /// The lineage invariant: a trial's recorded best-so-far never
    /// decreases (exploits adopt weights, not history — the trial's own
    /// achieved rewards only accumulate).
    pub fn best_is_monotone(&self, trial: TrialId) -> bool {
        let mut last = f32::NEG_INFINITY;
        for e in self.lineage(trial) {
            if e.best_so_far < last {
                return false;
            }
            last = e.best_so_far;
        }
        true
    }

    /// Slice completions recorded for `trial`.
    pub fn slices(&self, trial: TrialId) -> usize {
        self.lineage(trial)
            .iter()
            .filter(|e| matches!(e.kind, LineageEventKind::Slice { .. }))
            .count()
    }

    /// The hyper-parameter schedule of `trial`: `(t_s, hparams)` per
    /// recorded event, in order — the thing PBT papers plot per lineage.
    pub fn hparam_schedule(&self, trial: TrialId) -> Vec<(f64, Vec<(String, f32)>)> {
        self.lineage(trial)
            .into_iter()
            .map(|e| (e.t_s, e.hparams.clone()))
            .collect()
    }

    /// The whole log — events (with per-event hyper-parameter snapshots)
    /// and the sampled population series — as a [`Json`] document.
    /// Non-finite rewards (a trial before its first score) render as
    /// `null`, matching the renderer's convention.
    pub fn to_json(&self) -> Json {
        let events = self
            .events
            .iter()
            .map(|e| {
                let mut fields = vec![
                    ("trial".to_string(), Json::num(e.trial.0 as f64)),
                    ("slice".to_string(), Json::num(e.slice as f64)),
                    ("t_s".to_string(), Json::num(e.t_s)),
                ];
                match &e.kind {
                    LineageEventKind::Init => {
                        fields.push(("kind".into(), Json::str("init")));
                    }
                    LineageEventKind::Slice { reward } => {
                        fields.push(("kind".into(), Json::str("slice")));
                        fields.push(("reward".into(), Json::num(*reward as f64)));
                    }
                    LineageEventKind::Clone { parent } => {
                        fields.push(("kind".into(), Json::str("clone")));
                        fields.push(("parent".into(), Json::num(parent.0 as f64)));
                    }
                    LineageEventKind::Explore => {
                        fields.push(("kind".into(), Json::str("explore")));
                    }
                }
                fields.push(("best".into(), Json::num(e.best_so_far as f64)));
                fields.push((
                    "hparams".into(),
                    Json::Obj(
                        e.hparams
                            .iter()
                            .map(|(k, v)| (k.clone(), Json::num(*v as f64)))
                            .collect(),
                    ),
                ));
                Json::Obj(fields)
            })
            .collect();
        let series = self
            .series
            .iter()
            .map(|(t, best, mean)| {
                Json::Obj(vec![
                    ("t_s".into(), Json::num(*t)),
                    ("best".into(), Json::num(*best as f64)),
                    ("mean".into(), Json::num(*mean as f64)),
                ])
            })
            .collect();
        Json::Obj(vec![
            ("events".into(), Json::Arr(events)),
            ("series".into(), Json::Arr(series)),
        ])
    }

    /// Write the lineage log as JSON (the `pbt_lineage.json` artifact).
    pub fn export(&self, path: &str) -> std::io::Result<()> {
        self.to_json().write(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(trial: u64, slice: usize, kind: LineageEventKind, best: f32) -> LineageEvent {
        LineageEvent {
            trial: TrialId(trial),
            slice,
            t_s: slice as f64,
            kind,
            best_so_far: best,
            hparams: vec![("lr".into(), 0.01 * (slice + 1) as f32)],
        }
    }

    #[test]
    fn lineage_filters_and_counts_per_trial() {
        let mut b = Leaderboard::new();
        b.record(ev(0, 0, LineageEventKind::Init, f32::NEG_INFINITY));
        b.record(ev(1, 0, LineageEventKind::Init, f32::NEG_INFINITY));
        b.record(ev(0, 1, LineageEventKind::Slice { reward: 2.0 }, 2.0));
        b.record(ev(1, 1, LineageEventKind::Slice { reward: 5.0 }, 5.0));
        b.record(ev(0, 1, LineageEventKind::Clone { parent: TrialId(1) }, 2.0));
        b.record(ev(0, 1, LineageEventKind::Explore, 2.0));
        b.record(ev(0, 2, LineageEventKind::Slice { reward: 6.0 }, 6.0));
        assert_eq!(b.lineage(TrialId(0)).len(), 5);
        assert_eq!(b.slices(TrialId(0)), 2);
        assert_eq!(b.slices(TrialId(1)), 1);
        assert_eq!(b.parents(TrialId(0)), vec![TrialId(1)]);
        assert!(b.parents(TrialId(1)).is_empty());
        assert!(b.best_is_monotone(TrialId(0)));
        assert!(b.best_is_monotone(TrialId(1)));
    }

    #[test]
    fn monotone_check_catches_regressions() {
        let mut b = Leaderboard::new();
        b.record(ev(3, 1, LineageEventKind::Slice { reward: 4.0 }, 4.0));
        b.record(ev(3, 2, LineageEventKind::Slice { reward: 1.0 }, 3.0));
        assert!(!b.best_is_monotone(TrialId(3)), "best-so-far fell: 4 → 3");
    }

    #[test]
    fn lineage_export_roundtrips_through_json() {
        use crate::benchkit::Json;
        let mut b = Leaderboard::new();
        b.record(ev(0, 0, LineageEventKind::Init, f32::NEG_INFINITY));
        b.record(ev(0, 1, LineageEventKind::Slice { reward: 2.5 }, 2.5));
        b.record(ev(0, 1, LineageEventKind::Clone { parent: TrialId(1) }, 2.5));
        b.record(ev(0, 1, LineageEventKind::Explore, 2.5));
        b.record(ev(1, 1, LineageEventKind::Slice { reward: 7.0 }, 7.0));
        b.record_population(1.0, 7.0, 4.75);
        let doc = b.to_json();
        let rendered = doc.render();
        let back = Json::parse(&rendered).expect("export must be valid JSON");
        assert_eq!(back.render(), rendered, "parse ∘ render must be identity");
        // The per-trial hyper-parameter schedule survives the round trip.
        let events = back.get("events").expect("events array");
        let schedule: Vec<f64> = (0..4)
            .map(|i| {
                let e = events.at(i).unwrap();
                assert!(matches!(e.get("trial"), Some(Json::Num(t)) if *t == 0.0));
                match e.get("hparams").and_then(|h| h.get("lr")) {
                    Some(Json::Num(v)) => *v,
                    other => panic!("missing lr in event {i}: {other:?}"),
                }
            })
            .collect();
        let want: Vec<f64> = b
            .hparam_schedule(TrialId(0))
            .iter()
            .map(|(_, hp)| hp[0].1 as f64)
            .collect();
        for (got, want) in schedule.iter().zip(&want) {
            assert!((got - want).abs() < 1e-9, "{got} vs {want}");
        }
        // Kinds and parents decode structurally.
        assert!(matches!(events.at(2).unwrap().get("kind"), Some(Json::Str(s)) if s == "clone"));
        assert!(matches!(events.at(2).unwrap().get("parent"), Some(Json::Num(p)) if *p == 1.0));
        // The pre-score -inf best rendered as null and parsed as non-finite.
        assert!(matches!(events.at(0).unwrap().get("best"), Some(Json::Num(x)) if !x.is_finite()));
        // Series survives too.
        assert!(matches!(
            back.get("series").and_then(|s| s.at(0)).and_then(|r| r.get("mean")),
            Some(Json::Num(m)) if (*m - 4.75).abs() < 1e-9
        ));
    }
}
