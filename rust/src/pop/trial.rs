//! Trials: one member of a PBT population — a mutable hyper-parameter
//! set plus a model checkpoint held **by reference** in the object store.
//!
//! A trial's checkpoint is an [`ObjRef`], so the exploit step — the
//! bottom of the population adopting a top performer's weights — copies a
//! 24-byte handle and bumps a refcount, never θ itself. Lineage fields
//! (`parent`, `clones`) plus the [`super::Leaderboard`] event log make
//! every trial's ancestry reconstructible post-hoc.

use crate::store::ObjRef;
use crate::util::Rng;

/// Population-unique trial identity. Stable across exploit/explore: a
/// trial keeps its id when it clones another trial's checkpoint — the
/// lineage log records the adoption instead.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TrialId(pub u64);

impl std::fmt::Display for TrialId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "T{}", self.0)
    }
}

/// One mutable hyper-parameter with its search range (`min > 0`: ranges
/// are sampled log-uniformly).
#[derive(Clone, Debug)]
pub struct Hparam {
    pub name: &'static str,
    pub value: f32,
    pub min: f32,
    pub max: f32,
}

/// A trial's hyper-parameter set.
#[derive(Clone, Debug, Default)]
pub struct Hparams(pub Vec<Hparam>);

impl Hparams {
    pub fn get(&self, name: &str) -> Option<f32> {
        self.0.iter().find(|h| h.name == name).map(|h| h.value)
    }

    /// Log-uniform resample of every parameter (initial diversity).
    pub fn resample(&mut self, rng: &mut Rng) {
        for h in &mut self.0 {
            h.value = log_uniform(rng, h.min, h.max);
        }
    }

    /// PBT explore with an explicit resample probability: each parameter
    /// is multiplied by 0.8 or 1.25 (coin flip), except with probability
    /// `resample_p` it is freshly log-uniform resampled; always clamped
    /// to its range. [`Hparams::perturb`] fixes `resample_p` at the
    /// standard 25%.
    pub fn perturb_with(&mut self, rng: &mut Rng, resample_p: f64) {
        for h in &mut self.0 {
            if rng.chance(resample_p) {
                h.value = log_uniform(rng, h.min, h.max);
            } else {
                h.value *= if rng.chance(0.5) { 1.25 } else { 0.8 };
            }
            h.value = h.value.clamp(h.min, h.max);
        }
    }

    /// The standard PBT explore step (Jaderberg et al. 2017).
    pub fn perturb(&mut self, rng: &mut Rng) {
        self.perturb_with(rng, 0.25);
    }

    /// The wire shape carried in slice payloads.
    pub fn to_wire(&self) -> Vec<(String, f32)> {
        self.0.iter().map(|h| (h.name.to_string(), h.value)).collect()
    }
}

fn log_uniform(rng: &mut Rng, min: f32, max: f32) -> f32 {
    debug_assert!(min > 0.0 && max >= min, "log-uniform needs 0 < min <= max");
    let (lo, hi) = (min.ln() as f64, max.ln() as f64);
    rng.range_f64(lo, hi).exp() as f32
}

/// One population member, leader-side.
#[derive(Clone, Debug)]
pub struct Trial {
    pub id: TrialId,
    pub hparams: Hparams,
    /// The latest checkpoint, by reference: exploiting it onto another
    /// trial copies 24 bytes, not θ.
    pub checkpoint: ObjRef<Vec<u8>>,
    /// Evaluation reward of the latest completed slice.
    pub score: f32,
    /// Best slice reward this trial ever evaluated to (monotone — the
    /// lineage invariant the chaos tests assert).
    pub best_score: f32,
    /// Train slices completed.
    pub slices_done: usize,
    /// Trial whose checkpoint this one last cloned (exploit lineage).
    pub parent: Option<TrialId>,
    /// Exploits survived (clone depth in the lineage forest).
    pub clones: u64,
}

/// Truncation selection: rank the population by score and return
/// `(bottom, top)` — the bottom ⌈q·n⌉ trial ids (exploit targets, they
/// clone) and the top ⌈q·n⌉ (exploit sources). Deterministic: score ties
/// break by trial id, and `k` is clamped so bottom and top never overlap.
pub fn truncation_split(scores: &[(TrialId, f32)], q: f32) -> (Vec<TrialId>, Vec<TrialId>) {
    let n = scores.len();
    if n < 2 {
        return (Vec::new(), Vec::new());
    }
    let k = ((n as f32 * q).ceil() as usize).clamp(1, n / 2);
    let mut order: Vec<(TrialId, f32)> = scores.to_vec();
    order.sort_by(|a, b| {
        a.1.partial_cmp(&b.1)
            .unwrap_or(std::cmp::Ordering::Equal)
            .then(a.0.cmp(&b.0))
    });
    let bottom = order[..k].iter().map(|x| x.0).collect();
    let top = order[n - k..].iter().map(|x| x.0).collect();
    (bottom, top)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(xs: &[u64]) -> Vec<TrialId> {
        xs.iter().map(|&i| TrialId(i)).collect()
    }

    #[test]
    fn truncation_split_picks_extremes_deterministically() {
        let scores: Vec<(TrialId, f32)> = vec![
            (TrialId(0), 5.0),
            (TrialId(1), 1.0),
            (TrialId(2), 9.0),
            (TrialId(3), 3.0),
        ];
        let (bottom, top) = truncation_split(&scores, 0.25);
        assert_eq!(bottom, ids(&[1]));
        assert_eq!(top, ids(&[2]));
        let (bottom, top) = truncation_split(&scores, 0.5);
        assert_eq!(bottom, ids(&[1, 3]));
        assert_eq!(top, ids(&[0, 2]));
    }

    #[test]
    fn truncation_split_breaks_ties_by_id_and_never_overlaps() {
        let scores: Vec<(TrialId, f32)> =
            (0..5).map(|i| (TrialId(i), 1.0)).collect();
        let (bottom, top) = truncation_split(&scores, 0.9); // clamped to n/2
        assert_eq!(bottom, ids(&[0, 1]));
        assert_eq!(top, ids(&[3, 4]));
        for b in &bottom {
            assert!(!top.contains(b), "bottom and top must be disjoint");
        }
        // Degenerate populations select nothing.
        assert_eq!(truncation_split(&scores[..1], 0.5), (vec![], vec![]));
    }

    fn lr_sigma() -> Hparams {
        Hparams(vec![
            Hparam { name: "lr", value: 0.02, min: 1e-3, max: 0.2 },
            Hparam { name: "sigma", value: 0.05, min: 0.01, max: 0.5 },
        ])
    }

    #[test]
    fn perturb_without_resample_multiplies_by_known_factors() {
        let mut hp = lr_sigma();
        let before: Vec<f32> = hp.0.iter().map(|h| h.value).collect();
        let mut rng = Rng::new(42);
        hp.perturb_with(&mut rng, 0.0);
        for (h, b) in hp.0.iter().zip(&before) {
            let factor = h.value / b;
            assert!(
                (factor - 1.25).abs() < 1e-5 || (factor - 0.8).abs() < 1e-5,
                "{}: factor {factor}",
                h.name
            );
        }
    }

    #[test]
    fn perturb_is_deterministic_and_stays_in_range() {
        let run = |seed| {
            let mut hp = lr_sigma();
            let mut rng = Rng::new(seed);
            for _ in 0..50 {
                hp.perturb(&mut rng);
            }
            hp.0.iter().map(|h| h.value).collect::<Vec<f32>>()
        };
        assert_eq!(run(7), run(7), "same seed, same mutation trajectory");
        assert_ne!(run(7), run(8));
        let mut hp = lr_sigma();
        let mut rng = Rng::new(3);
        for _ in 0..200 {
            hp.perturb(&mut rng);
            for h in &hp.0 {
                assert!(h.value >= h.min && h.value <= h.max, "{h:?}");
            }
        }
    }

    #[test]
    fn resample_covers_the_range_log_uniformly() {
        let mut hp = lr_sigma();
        let mut rng = Rng::new(5);
        let mut lrs = Vec::new();
        for _ in 0..200 {
            hp.resample(&mut rng);
            lrs.push(hp.get("lr").unwrap());
        }
        assert!(lrs.iter().all(|&v| (1e-3..=0.2).contains(&v)));
        // Log-uniform: a decent fraction lands below the geometric mean.
        let below = lrs.iter().filter(|&&v| v < 0.0141).count();
        assert!(below > 60 && below < 140, "{below} of 200 below geo-mean");
    }
}
