//! Trial backends: the worker-side train slices.
//!
//! A PBT population is algorithm-generic by construction: the runner only
//! ever dispatches `pbt.slice` tasks carrying a checkpoint [`ObjRef`], a
//! hyper-parameter list and a fixed iteration budget, and collects a new
//! checkpoint reference plus an evaluation reward. Two backends prove the
//! genericity from day one:
//!
//! * **ES trials** wrap [`EsMaster`]: the slice evaluates one inner
//!   mirrored-sampling population locally (rollouts over
//!   [`crate::envs::cartpole`] or [`crate::envs::walker2d`]) and applies
//!   the master's Adam update; `lr` and `sigma` are the mutable
//!   hyper-parameters. The shared noise table is reused across the whole
//!   population — per process via [`shared_table`], and across *nodes* as
//!   one pinned store blob ([`put_noise_table`]) so a worker node faults
//!   it in once instead of regenerating it per process.
//! * **PPO trials** wrap [`PpoTrainer`]: the slice collects an on-policy
//!   rollout from a handful of in-process environments, runs the
//!   clipped-surrogate epochs, and scores the result with greedy
//!   episodes; `lr`, `clip` and `ent_coef` are the mutable
//!   hyper-parameters. Both simulators drive the fixed 32-obs/4-action
//!   PPO network through a thin pad/adapter.
//!
//! Checkpoints are opaque wire blobs (θ + Adam moments + iteration),
//! `put` into the store by the worker that produced them and named by a
//! 24-byte handle from then on.

use std::collections::HashSet;
use std::sync::Mutex;

use anyhow::{anyhow, Result};
use once_cell::sync::Lazy;

use crate::algo::es::{Adam, EsConfig, EsMaster};
use crate::algo::nn::{
    param_count, ppo_param_count, Mlp, PpoNet, PPO_ACTIONS, PPO_TRUNK, WALKER_SIZES,
};
use crate::algo::noise::{install_shared_table, shared_table, try_shared_table, NoiseTable};
use crate::algo::ppo::{gae, MiniBatch, PpoConfig, PpoTrainer};
use crate::coordinator::register_task;
use crate::coordinator::task::current_worker;
use crate::envs::{rollout, Action, CartPole, Env, Walker2d};
use crate::store::{self, ObjId, ObjRef, StoreNode};
use crate::util::Rng;
use crate::wire::{Decode, Encode, Reader, WireError};

use super::trial::{Hparam, Hparams};

/// Name the runner dispatches train slices under.
pub const SLICE_TASK: &str = "pbt.slice";

/// Seed and size of the population-shared ES noise table (64 Ki floats —
/// one 256 KB blob per node when store-warmed).
pub const PBT_NOISE_SEED: u64 = 2026;
pub const PBT_TABLE: usize = 1 << 16;

/// Which algorithm a trial trains.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PbtAlgo {
    Es,
    Ppo,
}

impl PbtAlgo {
    pub fn parse(s: &str) -> Result<PbtAlgo> {
        match s {
            "es" => Ok(PbtAlgo::Es),
            "ppo" => Ok(PbtAlgo::Ppo),
            other => Err(anyhow!("unknown algo {other:?} (es|ppo)")),
        }
    }

    pub fn tag(self) -> u8 {
        match self {
            PbtAlgo::Es => 0,
            PbtAlgo::Ppo => 1,
        }
    }

    pub fn from_tag(t: u8) -> Result<PbtAlgo> {
        match t {
            0 => Ok(PbtAlgo::Es),
            1 => Ok(PbtAlgo::Ppo),
            other => Err(anyhow!("bad algo tag {other}")),
        }
    }
}

/// Which simulator a trial trains on.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EnvKind {
    CartPole,
    Walker2d,
}

impl EnvKind {
    pub fn parse(s: &str) -> Result<EnvKind> {
        match s {
            "cartpole" => Ok(EnvKind::CartPole),
            "walker2d" | "walker" => Ok(EnvKind::Walker2d),
            other => Err(anyhow!("unknown env {other:?} (cartpole|walker2d)")),
        }
    }

    pub fn tag(self) -> u8 {
        match self {
            EnvKind::CartPole => 0,
            EnvKind::Walker2d => 1,
        }
    }

    pub fn from_tag(t: u8) -> Result<EnvKind> {
        match t {
            0 => Ok(EnvKind::CartPole),
            1 => Ok(EnvKind::Walker2d),
            other => Err(anyhow!("bad env tag {other}")),
        }
    }

    fn make(self, seed: u64) -> Box<dyn Env> {
        match self {
            EnvKind::CartPole => Box::new(CartPole::new()),
            EnvKind::Walker2d => Box::new(Walker2d::flat(seed)),
        }
    }
}

/// The default hyper-parameters of each backend, with PBT search ranges.
pub fn default_hparams(algo: PbtAlgo) -> Hparams {
    match algo {
        PbtAlgo::Es => Hparams(vec![
            Hparam { name: "lr", value: 0.02, min: 1e-3, max: 0.2 },
            Hparam { name: "sigma", value: 0.05, min: 0.01, max: 0.5 },
        ]),
        PbtAlgo::Ppo => Hparams(vec![
            Hparam { name: "lr", value: 2.5e-4, min: 1e-5, max: 1e-2 },
            Hparam { name: "clip", value: 0.1, min: 0.02, max: 0.5 },
            Hparam { name: "ent_coef", value: 0.01, min: 1e-4, max: 0.1 },
        ]),
    }
}

fn hp(hparams: &[(String, f32)], name: &str, default: f32) -> f32 {
    hparams
        .iter()
        .find(|(n, _)| n == name)
        .map(|(_, v)| *v)
        .unwrap_or(default)
}

/// Payload of one `pbt.slice` task.
#[derive(Clone, Debug)]
pub struct SliceInput {
    pub trial: u64,
    /// The trial's slice index (deterministic seeding).
    pub slice: u64,
    pub algo: u8,
    pub env: u8,
    pub seed: u64,
    /// Train iterations inside the slice (the fixed budget).
    pub iters: u64,
    /// Episode step cap per rollout.
    pub max_steps: u64,
    /// ES: inner mirrored population per update (even). PPO: unused.
    pub pop_inner: u64,
    /// PPO: rollout horizon per iteration. ES: unused.
    pub horizon: u64,
    pub hparams: Vec<(String, f32)>,
    pub checkpoint: ObjRef<Vec<u8>>,
    /// ES: the shared noise table as a store blob (cold nodes fault it in
    /// once; everyone else cache-hits the process table registry).
    pub table: Option<ObjRef<Vec<f32>>>,
    /// Chaos switch: the pool worker with this id dies (panics) the
    /// moment it picks the slice up — the pending table must requeue the
    /// slice and the trial's checkpoint ref must survive. 0 disarms
    /// (worker ids start at 1).
    pub kill_worker: u64,
}

impl Encode for SliceInput {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.trial.encode(buf);
        self.slice.encode(buf);
        self.algo.encode(buf);
        self.env.encode(buf);
        self.seed.encode(buf);
        self.iters.encode(buf);
        self.max_steps.encode(buf);
        self.pop_inner.encode(buf);
        self.horizon.encode(buf);
        self.hparams.encode(buf);
        self.checkpoint.encode(buf);
        self.table.encode(buf);
        self.kill_worker.encode(buf);
    }
}

impl Decode for SliceInput {
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(SliceInput {
            trial: u64::decode(r)?,
            slice: u64::decode(r)?,
            algo: u8::decode(r)?,
            env: u8::decode(r)?,
            seed: u64::decode(r)?,
            iters: u64::decode(r)?,
            max_steps: u64::decode(r)?,
            pop_inner: u64::decode(r)?,
            horizon: u64::decode(r)?,
            hparams: Vec::<(String, f32)>::decode(r)?,
            checkpoint: ObjRef::<Vec<u8>>::decode(r)?,
            table: Option::<ObjRef<Vec<f32>>>::decode(r)?,
            kill_worker: u64::decode(r)?,
        })
    }
}

/// Result of one train slice.
#[derive(Clone, Debug)]
pub struct SliceOutput {
    pub trial: u64,
    pub slice: u64,
    /// The post-slice checkpoint, stored by the worker that produced it.
    pub checkpoint: ObjRef<Vec<u8>>,
    /// Greedy-evaluation reward of the updated parameters.
    pub reward: f32,
    pub env_steps: u64,
    /// Worker that ran the slice (observability).
    pub worker: u64,
}

impl Encode for SliceOutput {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.trial.encode(buf);
        self.slice.encode(buf);
        self.checkpoint.encode(buf);
        self.reward.encode(buf);
        self.env_steps.encode(buf);
        self.worker.encode(buf);
    }
}

impl Decode for SliceOutput {
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(SliceOutput {
            trial: u64::decode(r)?,
            slice: u64::decode(r)?,
            checkpoint: ObjRef::<Vec<u8>>::decode(r)?,
            reward: f32::decode(r)?,
            env_steps: u64::decode(r)?,
            worker: u64::decode(r)?,
        })
    }
}

/// Register the worker-side PBT slice task (idempotent; part of
/// `fiber-cli`'s task bootstrap so OS-process workers serve it too).
pub fn register_pbt_tasks() {
    register_task(SLICE_TASK, |input: SliceInput| {
        run_slice(&input).map_err(|e| format!("{e:#}"))
    });
}

/// Checkpoint handoff ledger: blob ids this process stored with a held
/// put ([`StoreNode::put_held`]) whose reference it has not yet
/// released. The held reference guarantees a fresh checkpoint survives
/// until the leader replicates it; once a *later* slice arrives whose
/// input names that very checkpoint, the dispatch itself proves the
/// leader replicated it (the runner replicates before re-dispatching),
/// so the handoff reference is no longer load-bearing and is released.
/// Checkpoints whose successor slice ran on a different node keep their
/// handoff ref until this node exits — bounded by the run, and a
/// ROADMAP follow-up (distributed checkpoint GC) for long-lived workers.
static HANDOFFS: Lazy<Mutex<HashSet<ObjId>>> = Lazy::new(|| Mutex::new(HashSet::new()));

fn record_handoff(id: ObjId) {
    HANDOFFS.lock().unwrap().insert(id);
}

fn release_delivered_handoff(input: &SliceInput) -> Result<()> {
    let id = input.checkpoint.id();
    if HANDOFFS.lock().unwrap().remove(&id) {
        store::node()?.decref(id);
    }
    Ok(())
}

/// Execute one slice in-process (thread workers, tests, and the proc
/// worker loop all come through here).
pub fn run_slice(input: &SliceInput) -> Result<SliceOutput> {
    if input.kill_worker != 0 && current_worker() == input.kill_worker {
        // Simulated mid-slice crash: the panic unwinds out of the worker
        // loop (threads) or the worker process (proc backend), the
        // supervisor heals the pool, and the pending table re-dispatches
        // this very task — checkpoint ref included, so the trial is
        // never lost.
        panic!("pbt chaos: worker {} killed mid-slice", input.kill_worker);
    }
    release_delivered_handoff(input)?;
    match PbtAlgo::from_tag(input.algo)? {
        PbtAlgo::Es => es_slice(input),
        PbtAlgo::Ppo => ppo_slice(input),
    }
}

// ---- checkpoints ---------------------------------------------------------

fn encode_checkpoint(params: &[f32], adam: &Adam, iteration: u64) -> Vec<u8> {
    crate::wire::to_bytes(&(
        params.to_vec(),
        adam.m.clone(),
        adam.v.clone(),
        adam.t as u64,
        iteration,
    ))
}

fn decode_checkpoint(bytes: &[u8]) -> Result<(Vec<f32>, Adam, u64)> {
    let (params, m, v, t, iteration): (Vec<f32>, Vec<f32>, Vec<f32>, u64, u64) =
        crate::wire::from_bytes(bytes).map_err(|e| anyhow!("checkpoint decode: {e}"))?;
    anyhow::ensure!(
        m.len() == params.len() && v.len() == params.len(),
        "checkpoint moment shapes disagree with θ"
    );
    let mut adam = Adam::new(params.len());
    adam.m = m;
    adam.v = v;
    adam.t = t as u32;
    Ok((params, adam, iteration))
}

/// Build a fresh trial checkpoint (leader-side, at population init).
pub fn init_checkpoint(algo: PbtAlgo, env: EnvKind, seed: u64) -> Vec<u8> {
    match algo {
        PbtAlgo::Es => {
            let mut rng = Rng::new(seed);
            let net = Mlp::init(&es_sizes(env), &mut rng);
            let adam = Adam::new(net.n_params());
            encode_checkpoint(&net.params, &adam, 0)
        }
        PbtAlgo::Ppo => {
            let tr = PpoTrainer::new(PpoConfig { seed, ..Default::default() });
            let adam = Adam::new(tr.net.n_params());
            encode_checkpoint(&tr.net.params, &adam, 0)
        }
    }
}

/// Publish the population's shared noise table as one pinned store blob:
/// remote worker nodes fault it in once per node and install it into the
/// process table registry instead of regenerating it per process.
pub fn put_noise_table(node: &StoreNode) -> Result<ObjRef<Vec<f32>>> {
    let table = shared_table(PBT_NOISE_SEED, PBT_TABLE);
    // Held put, then pin, then drop the temporary reference: the blob is
    // never observable unprotected between insert and pin.
    let r = node.put_held(&table.data().to_vec())?;
    node.pin(r.id());
    node.decref(r.id());
    Ok(r)
}

fn resolve_table(table_ref: Option<ObjRef<Vec<f32>>>) -> Result<std::sync::Arc<NoiseTable>> {
    match table_ref {
        None => Ok(shared_table(PBT_NOISE_SEED, PBT_TABLE)),
        Some(tref) => match try_shared_table(PBT_NOISE_SEED, PBT_TABLE) {
            Some(t) => Ok(t),
            None => {
                let data: Vec<f32> = tref.get()?;
                anyhow::ensure!(data.len() == PBT_TABLE, "noise table blob size");
                Ok(install_shared_table(PBT_NOISE_SEED, PBT_TABLE, data))
            }
        },
    }
}

// ---- ES backend ----------------------------------------------------------

fn es_sizes(env: EnvKind) -> Vec<usize> {
    match env {
        // 4 → 16 → 1, tanh: one continuous push in [-1, 1].
        EnvKind::CartPole => vec![4, 16, 1],
        EnvKind::Walker2d => WALKER_SIZES.to_vec(),
    }
}

fn es_action(env: EnvKind, out: &[f32]) -> Action {
    match env {
        EnvKind::CartPole => Action::Continuous(vec![out[0]]),
        EnvKind::Walker2d => Action::Continuous(out.to_vec()),
    }
}

fn es_eval(env: EnvKind, policy: &Mlp, seed: u64, max_steps: usize) -> (f32, usize) {
    let mut e = env.make(seed);
    rollout(&mut *e, seed, max_steps, |obs| es_action(env, &policy.forward(obs)))
}

/// One ES train slice: `iters` mirrored-sampling updates of an
/// [`EsMaster`] restored from the checkpoint, followed by a deterministic
/// greedy evaluation of the updated θ.
fn es_slice(input: &SliceInput) -> Result<SliceOutput> {
    let env = EnvKind::from_tag(input.env)?;
    let bytes = input.checkpoint.get()?;
    let (theta, adam, iteration) = decode_checkpoint(&bytes)?;
    let sizes = es_sizes(env);
    anyhow::ensure!(
        theta.len() == param_count(&sizes),
        "es checkpoint is {} params, {env:?} policy needs {}",
        theta.len(),
        param_count(&sizes)
    );
    anyhow::ensure!(
        input.pop_inner >= 2 && input.pop_inner % 2 == 0,
        "pop_inner must be even and >= 2"
    );
    let cfg = EsConfig {
        pop: input.pop_inner as usize,
        sigma: hp(&input.hparams, "sigma", 0.05),
        lr: hp(&input.hparams, "lr", 0.02),
        noise_seed: PBT_NOISE_SEED,
        table_size: PBT_TABLE,
        max_steps: input.max_steps as usize,
        hardcore: false,
        seed: input.seed,
        eval_task: String::new(),
    };
    let mut master = EsMaster::from_state(cfg, theta, adam);
    let table = resolve_table(input.table)?;
    let dim = master.theta.len();
    // Deterministic per (trial, resume point): a requeued slice replays
    // the exact same offsets and env seeds.
    let mut rng = Rng::new(
        input
            .seed
            .wrapping_add(input.trial.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            ^ (iteration << 1),
    );
    let mut env_steps = 0u64;
    for _ in 0..input.iters {
        let half = master.cfg.pop / 2;
        let offsets: Vec<u64> = (0..half)
            .map(|_| table.sample_offset(&mut rng, dim) as u64)
            .collect();
        let mut rewards = Vec::with_capacity(half * 2);
        for &off in &offsets {
            for sign in [1.0f32, -1.0] {
                let mut noise = table.slice(off as usize, dim);
                for n in noise.iter_mut() {
                    *n *= sign;
                }
                let policy = Mlp { sizes: sizes.clone(), params: master.theta.clone() }
                    .perturbed(&noise, master.cfg.sigma);
                let env_seed = rng.next_u64() % 1_000_000;
                let (r, steps) = es_eval(env, &policy, env_seed, master.cfg.max_steps);
                rewards.push(r);
                env_steps += steps as u64;
            }
        }
        master.update(&offsets, &rewards, None)?;
    }
    // The PBT score: the unperturbed updated policy on fixed seeds.
    let policy = Mlp { sizes, params: master.theta.clone() };
    let mut total = 0.0f32;
    for k in 0..3u64 {
        let (r, steps) = es_eval(env, &policy, 10_000 + k, master.cfg.max_steps);
        total += r;
        env_steps += steps as u64;
    }
    let ck = encode_checkpoint(&master.theta, master.adam(), iteration + input.iters);
    let node = store::node()?;
    // Held put: the handoff reference keeps LRU pressure from evicting
    // the only copy before the leader replicates it; released by a later
    // slice resuming from this checkpoint (see HANDOFFS).
    let checkpoint = node.put_held(&ck)?;
    record_handoff(checkpoint.id());
    Ok(SliceOutput {
        trial: input.trial,
        slice: input.slice,
        checkpoint,
        reward: total / 3.0,
        env_steps,
        worker: current_worker(),
    })
}

// ---- PPO backend ---------------------------------------------------------

/// Bang-bang torque patterns mapping the 4 discrete PPO actions onto the
/// walker's 4 continuous joints.
const TORQUE_PATTERNS: [[f32; 4]; 4] = [
    [0.8, -0.4, -0.4, 0.8],
    [-0.4, 0.8, 0.8, -0.4],
    [0.5, 0.5, -0.5, -0.5],
    [-0.6, -0.6, 0.6, 0.6],
];

/// Pad an environment observation to the PPO network's fixed 32 inputs.
fn ppo_obs(obs: &[f32]) -> Vec<f32> {
    let mut out = vec![0.0f32; PPO_TRUNK[0]];
    let n = obs.len().min(PPO_TRUNK[0]);
    out[..n].copy_from_slice(&obs[..n]);
    out
}

fn ppo_action(env: EnvKind, a: usize) -> Action {
    match env {
        EnvKind::CartPole => Action::Discrete(a & 1),
        EnvKind::Walker2d => Action::Continuous(TORQUE_PATTERNS[a % PPO_ACTIONS].to_vec()),
    }
}

fn argmax(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, v) in xs.iter().enumerate() {
        if *v > xs[best] {
            best = i;
        }
    }
    best
}

/// Greedy episodes with the current policy head — the PBT score.
fn ppo_greedy_eval(env: EnvKind, net: &PpoNet, max_steps: usize) -> (f32, u64) {
    let mut total = 0.0f32;
    let mut env_steps = 0u64;
    for k in 0..2u64 {
        let seed = 90_000 + k;
        let mut e = env.make(seed);
        let mut obs = ppo_obs(&e.reset(seed));
        for _ in 0..max_steps {
            let (logits, _) = net.forward(&obs);
            let sr = e.step(&ppo_action(env, argmax(&logits)));
            total += sr.reward;
            env_steps += 1;
            if sr.done {
                break;
            }
            obs = ppo_obs(&sr.obs);
        }
    }
    (total / 2.0, env_steps)
}

/// One PPO train slice: `iters` × (on-policy rollout of `horizon` steps
/// over a few in-process environments → GAE → clipped-surrogate epochs),
/// with a [`PpoTrainer`] restored from the checkpoint.
fn ppo_slice(input: &SliceInput) -> Result<SliceOutput> {
    let env_kind = EnvKind::from_tag(input.env)?;
    let bytes = input.checkpoint.get()?;
    let (params, adam, iteration) = decode_checkpoint(&bytes)?;
    let n_envs = 4usize;
    let horizon = (input.horizon as usize).max(8);
    let max_steps = input.max_steps as usize;
    let cfg = PpoConfig {
        n_envs,
        horizon,
        epochs: 2,
        minibatch: 32,
        lr: hp(&input.hparams, "lr", 2.5e-4),
        clip: hp(&input.hparams, "clip", 0.1),
        ent_coef: hp(&input.hparams, "ent_coef", 0.01),
        seed: input
            .seed
            .wrapping_add(input.trial.wrapping_mul(0x9E37_79B9_7F4A_7C15))
            ^ iteration,
        ..Default::default()
    };
    anyhow::ensure!(
        params.len() == ppo_param_count(),
        "ppo checkpoint is {} params, the network needs {}",
        params.len(),
        ppo_param_count()
    );
    let mut tr = PpoTrainer::from_state(cfg.clone(), params, adam);
    let mut rng = Rng::new(cfg.seed ^ 0xFACE);
    let mut envs: Vec<Box<dyn Env>> = (0..n_envs)
        .map(|e| env_kind.make(cfg.seed.wrapping_add(e as u64)))
        .collect();
    let mut obs: Vec<Vec<f32>> = envs
        .iter_mut()
        .enumerate()
        .map(|(e, env)| ppo_obs(&env.reset(cfg.seed.wrapping_add(e as u64))))
        .collect();
    let mut ep_len = vec![0usize; n_envs];
    let mut env_steps = 0u64;
    for _ in 0..input.iters {
        let mut b_obs: Vec<Vec<f32>> = Vec::with_capacity(horizon * n_envs);
        let mut b_actions = Vec::with_capacity(horizon * n_envs);
        let mut b_logps = Vec::with_capacity(horizon * n_envs);
        let mut b_values = Vec::with_capacity(horizon * n_envs);
        let mut b_rewards = Vec::with_capacity(horizon * n_envs);
        let mut b_dones = Vec::with_capacity(horizon * n_envs);
        for _t in 0..horizon {
            let (actions, logps, values) = tr.act(&obs, None)?;
            for e in 0..n_envs {
                let sr = envs[e].step(&ppo_action(env_kind, actions[e]));
                ep_len[e] += 1;
                env_steps += 1;
                let done = sr.done || ep_len[e] >= max_steps;
                b_obs.push(obs[e].clone());
                b_actions.push(actions[e] as i32);
                b_logps.push(logps[e]);
                b_values.push(values[e]);
                b_rewards.push(sr.reward);
                b_dones.push(u8::from(done));
                if done {
                    ep_len[e] = 0;
                    obs[e] = ppo_obs(&envs[e].reset(rng.next_u64() % 1_000_000));
                } else {
                    obs[e] = ppo_obs(&sr.obs);
                }
            }
        }
        let (_, _, last_values) = tr.act(&obs, None)?;
        let (adv, ret) = gae(
            &b_rewards, &b_values, &b_dones, &last_values, n_envs, horizon, cfg.gamma, cfg.lam,
        );
        let mean = adv.iter().sum::<f32>() / adv.len() as f32;
        let var = adv.iter().map(|a| (a - mean) * (a - mean)).sum::<f32>() / adv.len() as f32;
        let std = var.sqrt().max(1e-8);
        let adv: Vec<f32> = adv.iter().map(|a| (a - mean) / std).collect();
        let total = b_obs.len();
        let mut idx: Vec<usize> = (0..total).collect();
        for _ in 0..cfg.epochs {
            rng.shuffle(&mut idx);
            for chunk in idx.chunks(cfg.minibatch) {
                let b = cfg.minibatch;
                let mut mb = MiniBatch {
                    obs: Vec::with_capacity(b * PPO_TRUNK[0]),
                    actions: Vec::with_capacity(b),
                    old_logp: Vec::with_capacity(b),
                    adv: Vec::with_capacity(b),
                    ret: Vec::with_capacity(b),
                };
                for k in 0..b {
                    // Pad short tails by cycling the chunk.
                    let i = chunk[k % chunk.len()];
                    mb.obs.extend(&b_obs[i]);
                    mb.actions.push(b_actions[i]);
                    mb.old_logp.push(b_logps[i]);
                    mb.adv.push(adv[i]);
                    mb.ret.push(ret[i]);
                }
                tr.update_minibatch(&mb, None)?;
            }
        }
    }
    let (reward, eval_steps) = ppo_greedy_eval(env_kind, &tr.net, max_steps);
    let ck = encode_checkpoint(&tr.net.params, tr.adam(), iteration + input.iters);
    let node = store::node()?;
    // Held put — see es_slice / HANDOFFS for the reference lifecycle.
    let checkpoint = node.put_held(&ck)?;
    record_handoff(checkpoint.id());
    Ok(SliceOutput {
        trial: input.trial,
        slice: input.slice,
        checkpoint,
        reward,
        env_steps: env_steps + eval_steps,
        worker: current_worker(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slice_payloads_roundtrip_wire() {
        let input = SliceInput {
            trial: 3,
            slice: 2,
            algo: PbtAlgo::Ppo.tag(),
            env: EnvKind::Walker2d.tag(),
            seed: 99,
            iters: 4,
            max_steps: 200,
            pop_inner: 8,
            horizon: 64,
            hparams: vec![("lr".into(), 0.01), ("clip".into(), 0.2)],
            checkpoint: ObjRef::from_parts(crate::store::ObjId::of(b"ck"), 123),
            table: Some(ObjRef::from_parts(crate::store::ObjId::of(b"tbl"), 77)),
            kill_worker: 0,
        };
        let bytes = crate::wire::to_bytes(&input);
        let back: SliceInput = crate::wire::from_bytes(&bytes).unwrap();
        assert_eq!(back.trial, 3);
        assert_eq!(back.hparams, input.hparams);
        assert_eq!(back.checkpoint.id(), input.checkpoint.id());
        assert_eq!(back.table.unwrap().len(), 77);

        let out = SliceOutput {
            trial: 3,
            slice: 2,
            checkpoint: ObjRef::from_parts(crate::store::ObjId::of(b"ck2"), 55),
            reward: 12.5,
            env_steps: 4096,
            worker: 2,
        };
        let bytes = crate::wire::to_bytes(&out);
        let back: SliceOutput = crate::wire::from_bytes(&bytes).unwrap();
        assert_eq!(back.reward, 12.5);
        assert_eq!(back.env_steps, 4096);
    }

    #[test]
    fn checkpoint_roundtrips_with_optimizer_state() {
        let mut adam = Adam::new(4);
        adam.m = vec![0.1, 0.2, 0.3, 0.4];
        adam.v = vec![1.0, 2.0, 3.0, 4.0];
        adam.t = 17;
        let ck = encode_checkpoint(&[9.0, 8.0, 7.0, 6.0], &adam, 5);
        let (params, adam2, iter) = decode_checkpoint(&ck).unwrap();
        assert_eq!(params, vec![9.0, 8.0, 7.0, 6.0]);
        assert_eq!(adam2.m, adam.m);
        assert_eq!(adam2.v, adam.v);
        assert_eq!(adam2.t, 17);
        assert_eq!(iter, 5);
        // Shape mismatches are rejected, not mis-stepped.
        let bad = crate::wire::to_bytes(&(
            vec![1.0f32; 4],
            vec![0.0f32; 3],
            vec![0.0f32; 4],
            0u64,
            0u64,
        ));
        assert!(decode_checkpoint(&bad).is_err());
    }

    #[test]
    fn es_slice_runs_and_scores_on_cartpole() {
        let node = crate::store::node_or_host(256 << 20);
        register_pbt_tasks();
        let ck = init_checkpoint(PbtAlgo::Es, EnvKind::CartPole, 11);
        let r = node.put(&ck).unwrap();
        let input = SliceInput {
            trial: 0,
            slice: 0,
            algo: PbtAlgo::Es.tag(),
            env: EnvKind::CartPole.tag(),
            seed: 11,
            iters: 1,
            max_steps: 100,
            pop_inner: 8,
            horizon: 0,
            hparams: default_hparams(PbtAlgo::Es).to_wire(),
            checkpoint: r,
            table: None,
            kill_worker: 0,
        };
        let out = run_slice(&input).unwrap();
        assert!(out.reward.is_finite() && out.reward > 0.0);
        assert!(out.env_steps > 0);
        assert_ne!(out.checkpoint.id(), r.id(), "training must move θ");
        // Deterministic: the same input replays to the same checkpoint
        // (what makes a requeued chaos slice harmless).
        let out2 = run_slice(&input).unwrap();
        assert_eq!(out2.checkpoint.id(), out.checkpoint.id());
        assert_eq!(out2.reward, out.reward);
    }

    #[test]
    fn ppo_slice_runs_and_scores_on_cartpole() {
        let node = crate::store::node_or_host(256 << 20);
        register_pbt_tasks();
        let ck = init_checkpoint(PbtAlgo::Ppo, EnvKind::CartPole, 21);
        let r = node.put(&ck).unwrap();
        let input = SliceInput {
            trial: 1,
            slice: 0,
            algo: PbtAlgo::Ppo.tag(),
            env: EnvKind::CartPole.tag(),
            seed: 21,
            iters: 1,
            max_steps: 120,
            pop_inner: 0,
            horizon: 32,
            hparams: default_hparams(PbtAlgo::Ppo).to_wire(),
            checkpoint: r,
            table: None,
            kill_worker: 0,
        };
        let out = run_slice(&input).unwrap();
        assert!(out.reward.is_finite() && out.reward > 0.0);
        assert_ne!(out.checkpoint.id(), r.id(), "training must move θ");
        let (params, _, iter) = decode_checkpoint(&out.checkpoint.get().unwrap()).unwrap();
        assert_eq!(iter, 1);
        assert!(params.iter().all(|p| p.is_finite()));
    }

    #[test]
    fn walker_backends_accept_both_algos() {
        let node = crate::store::node_or_host(256 << 20);
        register_pbt_tasks();
        for algo in [PbtAlgo::Es, PbtAlgo::Ppo] {
            let ck = init_checkpoint(algo, EnvKind::Walker2d, 31);
            let r = node.put(&ck).unwrap();
            let input = SliceInput {
                trial: 2,
                slice: 0,
                algo: algo.tag(),
                env: EnvKind::Walker2d.tag(),
                seed: 31,
                iters: 1,
                max_steps: 60,
                pop_inner: 4,
                horizon: 16,
                hparams: default_hparams(algo).to_wire(),
                checkpoint: r,
                table: None,
                kill_worker: 0,
            };
            let out = run_slice(&input).unwrap();
            assert!(out.reward.is_finite(), "{algo:?} on walker2d");
        }
    }

    #[test]
    fn ppo_env_adapters_pad_and_map() {
        assert_eq!(argmax(&[0.1, 0.9, 0.3, 0.2]), 1);
        assert_eq!(ppo_action(EnvKind::CartPole, 3), Action::Discrete(1));
        assert_eq!(ppo_action(EnvKind::CartPole, 2), Action::Discrete(0));
        match ppo_action(EnvKind::Walker2d, 1) {
            Action::Continuous(t) => assert_eq!(t.len(), 4),
            other => panic!("walker actions are torque vectors, got {other:?}"),
        }
        let padded = ppo_obs(&[1.0, 2.0]);
        assert_eq!(padded.len(), PPO_TRUNK[0]);
        assert_eq!(&padded[..2], &[1.0, 2.0]);
        assert!(padded[2..].iter().all(|&x| x == 0.0));
    }
}
