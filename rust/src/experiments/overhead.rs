//! E1 — Fig 3a: framework overhead on a fixed-total-work batch.
//!
//! "The testing procedure is to create a batch of workload that takes a
//! fixed amount of time in total to finish. The duration of each single
//! task ranges from 1 second to 1 millisecond. We run five workers for
//! each framework locally and adjust the batch size to make sure the total
//! finish time for each framework is roughly 1 second."
//!
//! Tasks are precise sleeps, so five workers co-exist on one core without
//! contending for CPU; what the experiment measures is exactly the
//! framework's dispatch/collect machinery.

use anyhow::Result;

use crate::baselines::exec::{register_bench_tasks, Executor, FiberExec, MpLike};
use crate::baselines::{IppLike, SparkLike};
use crate::benchkit::{measure, Table};
use crate::wire;

/// Experiment parameters.
#[derive(Clone, Debug)]
pub struct OverheadConfig {
    pub workers: usize,
    /// Task durations to sweep, µs.
    pub durations_us: Vec<u64>,
    /// Total work per batch, µs (the paper's "roughly 1 second").
    pub total_us: u64,
    pub samples: usize,
}

impl Default for OverheadConfig {
    fn default() -> Self {
        Self {
            workers: 5,
            durations_us: vec![1_000_000, 100_000, 10_000, 1_000],
            // Batch sized so the *completion* time is ~1 s on 5 workers:
            // "for 1 millisecond duration, we run 5,000 tasks" (paper).
            total_us: 5_000_000,
            samples: 3,
        }
    }
}

fn run_one(ex: &dyn Executor, duration_us: u64, total_us: u64, samples: usize) -> Option<f64> {
    let n_tasks = (total_us / duration_us).max(1) as usize;
    let items: Vec<Vec<u8>> = (0..n_tasks).map(|_| wire::to_bytes(&duration_us)).collect();
    // One un-measured run to warm worker threads and surface failures.
    if ex.run_batch("bench.sleep_us", items.clone()).is_err() {
        return None;
    }
    let stats = measure(0, samples, || {
        ex.run_batch("bench.sleep_us", items.clone()).expect("batch");
    });
    Some(stats.mean())
}

/// Run Fig 3a; returns the rendered table (rows = frameworks, cols =
/// task durations, cells = mean batch completion seconds).
pub fn overhead_experiment(cfg: &OverheadConfig) -> Result<Table> {
    register_bench_tasks();
    let col_labels: Vec<String> = cfg
        .durations_us
        .iter()
        .map(|&d| {
            if d >= 1_000_000 {
                format!("{}s", d / 1_000_000)
            } else {
                format!("{}ms", d / 1_000)
            }
        })
        .collect();
    let ideal = cfg.total_us as f64 / 1e6 / cfg.workers as f64;
    let mut table = Table::new(
        format!(
            "E1 / Fig 3a — framework overhead ({} workers, {:.1}s total work, ideal {ideal:.2}s)",
            cfg.workers,
            cfg.total_us as f64 / 1e6
        ),
        "framework",
        col_labels,
    );
    let fiber = FiberExec::new(cfg.workers)?;
    let mp = MpLike::new(cfg.workers);
    let ipp = IppLike::new(cfg.workers);
    let spark = SparkLike::new(cfg.workers);
    let execs: [&dyn Executor; 4] = [&mp, &fiber, &ipp, &spark];
    for ex in execs {
        let cells: Vec<Option<f64>> = cfg
            .durations_us
            .iter()
            .map(|&d| run_one(ex, d, cfg.total_us, cfg.samples))
            .collect();
        table.add_row(ex.name(), cells);
    }
    Ok(table)
}

/// Calibration for the virtual-time models: measured per-task dispatch +
/// collect cost of a real fiber pool on zero-work tasks, ns.
pub fn calibrate_fiber_dispatch_ns(workers: usize, tasks: usize) -> Result<u64> {
    register_bench_tasks();
    let ex = FiberExec::new(workers)?;
    let items: Vec<Vec<u8>> = (0..tasks).map(|i| wire::to_bytes(&(i as u64))).collect();
    ex.run_batch("bench.echo", items.clone())?; // warm
    let stats = measure(1, 5, || {
        ex.run_batch("bench.echo", items.clone()).unwrap();
    });
    Ok((stats.mean() * 1e9 / tasks as f64) as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn small_overhead_experiment_shape_holds() {
        // Tiny version: 10 ms tasks, 100 ms total → fast but still ranks the
        // frameworks correctly at the short-task end.
        let cfg = OverheadConfig {
            workers: 3,
            durations_us: vec![10_000, 1_000],
            total_us: 60_000,
            samples: 1,
        };
        let table = overhead_experiment(&cfg).unwrap();
        assert_eq!(table.rows.len(), 4);
        let get = |name: &str| {
            table
                .rows
                .iter()
                .find(|(l, _)| l == name)
                .map(|(_, c)| c.clone())
                .unwrap()
        };
        let (mp, fiber, ipp, spark) = (
            get("multiprocessing"),
            get("fiber"),
            get("ipyparallel"),
            get("spark"),
        );
        // At 1 ms tasks the paper's ordering is mp ≲ fiber < ipp < spark.
        // Under full-test-suite contention on this 1-core box the
        // fiber-vs-ipp margin can wobble, so the unit test asserts only the
        // robust ends of the ordering; the strict comparison is made by the
        // real bench (rust/benches/overhead.rs) on a quiet machine.
        let last = 1;
        assert!(
            fiber[last].unwrap() < spark[last].unwrap(),
            "fiber must beat spark"
        );
        assert!(
            ipp[last].unwrap() < spark[last].unwrap() * 1.5,
            "ipp must not be far behind spark"
        );
        assert!(mp[last].is_some() && mp[last].unwrap() > 0.0);
    }

    #[test]
    fn calibration_returns_plausible_cost() {
        let ns = calibrate_fiber_dispatch_ns(2, 200).unwrap();
        assert!(ns > 100, "dispatch can't be free: {ns}");
        assert!(ns < 5_000_000, "dispatch must be ≪ 5ms: {ns}");
    }
}
