//! The PBT dashboard panel: asynchronous vs lock-step population
//! dispatch on a small ES/cartpole population — wall time, slice
//! throughput, and the population best/mean reward the run ended on.
//! The timing harness ([`timed_pbt`]) is shared with `benches/pbt.rs`,
//! which persists the full sweep (pop 8/32, plus by-ref vs by-value
//! exploit cost) to `BENCH_pbt.json` — panel and bench measure the same
//! orchestration paths.

use anyhow::Result;

use crate::api::pool::Pool;
use crate::benchkit::Table;
use crate::pop::{DispatchMode, EnvKind, PbtAlgo, PbtConfig, PopulationRunner};

/// Result of one timed population run.
pub struct PbtTiming {
    pub wall_s: f64,
    pub slices_per_s: f64,
    pub best: f32,
    pub mean: f32,
    pub exploits: usize,
}

/// Run one small ES/cartpole population to completion under `mode` and
/// time it. `slice_task` lets benches substitute a synthetic slice (to
/// time pure dispatch); `None` runs the real ES backend.
pub fn timed_pbt(
    mode: DispatchMode,
    pop: usize,
    workers: usize,
    slices: usize,
    slice_task: Option<&str>,
) -> Result<PbtTiming> {
    let store = crate::store::node_or_host(256 << 20);
    let pool = Pool::builder()
        .processes(workers)
        .store(store.clone())
        .build()?;
    let mut cfg = PbtConfig {
        algo: PbtAlgo::Es,
        env: EnvKind::CartPole,
        pop,
        slices,
        iters_per_slice: 1,
        max_steps: 100,
        pop_inner: 8,
        seed: 40 + pop as u64,
        ..Default::default()
    };
    if let Some(task) = slice_task {
        cfg.slice_task = task.to_string();
    }
    let mut runner = PopulationRunner::new(cfg, store)?;
    let report = runner.run(&pool, mode)?;
    Ok(PbtTiming {
        wall_s: report.wall_s,
        slices_per_s: report.slices_completed as f64 / report.wall_s.max(1e-9),
        best: report.best_score,
        mean: report.mean_score,
        exploits: report.exploits,
    })
}

/// The dashboard table: async vs generational dispatch of the same
/// population budget — wall time, slices/s, and where the population
/// reward landed.
pub fn pbt_figure() -> Result<Table> {
    let mut table = Table::new(
        "PBT (ES/cartpole, pop 6 × 3 slices over 3 workers): async vs lock-step",
        "dispatch",
        vec![
            "wall s".into(),
            "slices/s".into(),
            "best reward".into(),
            "mean reward".into(),
        ],
    );
    // Mixed units per column: suppress the global seconds suffix.
    table.unit = "";
    for (label, mode) in [
        ("async", DispatchMode::Async),
        ("generational", DispatchMode::Generational),
    ] {
        let t = timed_pbt(mode, 6, 3, 3, None)?;
        table.add_row(
            label,
            vec![
                Some(t.wall_s),
                Some(t.slices_per_s),
                Some(t.best as f64),
                Some(t.mean as f64),
            ],
        );
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timed_pbt_completes_in_both_modes() {
        for mode in [DispatchMode::Async, DispatchMode::Generational] {
            let t = timed_pbt(mode, 4, 2, 2, None).unwrap();
            assert!(t.wall_s > 0.0);
            assert!(t.slices_per_s > 0.0);
            assert!(t.best.is_finite() && t.best > 0.0, "{mode:?}: {}", t.best);
            assert!(t.mean.is_finite());
        }
    }
}
