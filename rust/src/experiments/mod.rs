//! The paper's experiments, as reusable library functions.
//!
//! Each function regenerates one table/figure (DESIGN.md §3 experiment
//! index) and returns a [`crate::benchkit::Table`]. `fiber-cli` and the
//! `cargo bench` targets are thin wrappers around these.

pub mod dynamic;
pub mod overhead;
pub mod pbt;
pub mod ring;
pub mod scaling;

pub use dynamic::dynamic_scaling_experiment;
pub use overhead::{calibrate_fiber_dispatch_ns, overhead_experiment, OverheadConfig};
pub use pbt::{pbt_figure, timed_pbt, PbtTiming};
pub use ring::{ring_collectives_figure, timed_allreduce, RingTiming};
pub use scaling::{es_scaling_figure, ppo_scaling_figure, ScalingConfig};
