//! E2/E3 — Fig 3b (ES scaling) and Fig 3c (PPO scaling).
//!
//! Dual-mode (DESIGN.md §2): the *real* executors calibrate the per-task /
//! per-message cost parameters at small scale on this machine, then the
//! virtual-time queueing models in [`crate::baselines::sim_models`] replay
//! the figures' 32–1024-worker sweeps with those measured costs and task
//! durations sampled from real walker rollouts.

use anyhow::Result;

use crate::baselines::sim_models::{sample_durations, simulate_map, FrameworkModel, PpoModel};
use crate::benchkit::Table;
use crate::envs::{rollout, Action, Breakout, Env, Walker2d};
use crate::util::{Rng, Stopwatch, Welford};

/// Scaling sweep parameters.
#[derive(Clone, Debug)]
pub struct ScalingConfig {
    /// ES population (paper: 2048).
    pub pop: usize,
    /// ES iterations (paper: 50).
    pub iterations: usize,
    pub worker_counts: Vec<usize>,
    /// PPO total frames (paper: 10 M; scaled by default).
    pub ppo_frames: u64,
    pub ppo_horizon: u64,
    pub ppo_worker_counts: Vec<usize>,
    pub seed: u64,
    /// Per-simulation-step cost used to price ES rollouts in virtual time.
    /// Our Rust walker steps in ~1 µs — ~500× faster than the Box2D
    /// BipedalWalkerHardcore the paper runs — so pricing measured episode
    /// lengths at a Box2D-representative 0.5 ms/step keeps the figure in
    /// the paper's task-duration regime (DESIGN.md §2 substitution table).
    pub sim_step_ns: u64,
}

impl Default for ScalingConfig {
    fn default() -> Self {
        Self {
            pop: 2048,
            iterations: 50,
            worker_counts: vec![32, 64, 128, 256, 512, 1024],
            ppo_frames: 10_000_000,
            ppo_horizon: 128,
            ppo_worker_counts: vec![8, 16, 32, 64, 128, 256],
            seed: 17,
            sim_step_ns: 500_000,
        }
    }
}

/// Measure walker episode lengths (steps) under a mix of policies — random
/// torques fall early, posture-stabilised ones survive long, mirroring an
/// ES population mid-training. Returns (mean steps, CV): the variable-
/// length-rollout heterogeneity the ES figure schedules around.
pub fn measure_episode_lengths(n: usize, max_steps: usize, seed: u64) -> (f64, f64) {
    let mut w = Welford::new();
    let mut rng = Rng::new(seed);
    for i in 0..n {
        let mut env = Walker2d::hardcore(seed + i as u64);
        let mut s = rng.next_u64();
        // Half the population flails randomly (short episodes), half holds a
        // weak stabilising gait (long episodes).
        let stabilise = i % 2 == 0;
        let (_, steps) = rollout(&mut env, seed + i as u64, max_steps, |obs| {
            if stabilise {
                Action::Continuous(vec![-0.4 * obs[0], 0.2, 0.4 * obs[0], 0.2])
            } else {
                s ^= s << 13;
                s ^= s >> 7;
                Action::Continuous(vec![
                    (s & 0xff) as f32 / 127.5 - 1.0,
                    ((s >> 8) & 0xff) as f32 / 127.5 - 1.0,
                    ((s >> 16) & 0xff) as f32 / 127.5 - 1.0,
                    ((s >> 24) & 0xff) as f32 / 127.5 - 1.0,
                ])
            }
        });
        w.add(steps as f64);
    }
    let cv = w.std() / w.mean().max(1.0);
    (w.mean(), cv)
}

/// Measure the real Breakout step cost (ns/step).
pub fn measure_breakout_step_ns(steps: usize) -> f64 {
    let mut env = Breakout::new();
    env.reset(1);
    env.step(&Action::Discrete(1));
    let sw = Stopwatch::start();
    let mut done_resets = 0u64;
    for i in 0..steps {
        let r = env.step(&Action::Discrete(i % 4));
        if r.done {
            env.reset(done_resets);
            done_resets += 1;
        }
    }
    sw.elapsed_ns() as f64 / steps as f64
}

/// Fig 3b: time for 50 ES iterations (pop 2048) vs. worker count,
/// fiber vs. IPyParallel-like. `fiber_dispatch_ns` comes from the E1
/// calibration. Cells are virtual seconds; `None` = framework failed.
pub fn es_scaling_figure(cfg: &ScalingConfig, fiber_dispatch_ns: u64) -> Result<Table> {
    let (mean_steps, cv) = measure_episode_lengths(48, 1600, cfg.seed);
    let mean_ns = mean_steps * cfg.sim_step_ns as f64;
    let mut rng = Rng::new(cfg.seed);
    let mut fiber = FrameworkModel::fiber();
    fiber.dispatch_ns = fiber_dispatch_ns.max(1_000);
    let ipp = FrameworkModel::ipyparallel();

    let col_labels: Vec<String> = cfg.worker_counts.iter().map(|w| w.to_string()).collect();
    let mut table = Table::new(
        format!(
            "E2 / Fig 3b — ES: {} iterations, pop {}, rollout mean {:.1} ms (cv {:.2}), virtual time",
            cfg.iterations, cfg.pop, mean_ns / 1e6, cv
        ),
        "framework \\ workers",
        col_labels,
    );
    // One shared duration sample per iteration: every framework and worker
    // count replays the identical workload (paper: "the total computation
    // is fixed regardless of the number of workers").
    let iters: Vec<Vec<u64>> = (0..cfg.iterations)
        .map(|_| sample_durations(&mut rng, cfg.pop, mean_ns, cv.max(0.1)))
        .collect();
    for model in [&fiber, &ipp] {
        let mut cells = Vec::new();
        for &workers in &cfg.worker_counts {
            let mut total_ns: Option<u64> = Some(0);
            for durations in &iters {
                match (total_ns, simulate_map(model, durations, workers)) {
                    (Some(acc), Some(t)) => total_ns = Some(acc + t),
                    _ => {
                        total_ns = None;
                        break;
                    }
                }
            }
            cells.push(total_ns.map(|ns| ns as f64 / 1e9));
        }
        table.add_row(model.name, cells);
    }
    Ok(table)
}

/// Fig 3c: PPO total training time vs. env workers; multiprocessing capped
/// at one 32-core machine, fiber scaling to 256. Sync cost per worker is
/// measured from the real vec-env scatter/gather path when provided.
pub fn ppo_scaling_figure(
    cfg: &ScalingConfig,
    sync_per_worker_ns: u64,
    model_step_ns: u64,
) -> Result<Table> {
    let env_step_ns = measure_breakout_step_ns(20_000) as u64;
    let fiber = PpoModel {
        name: "fiber",
        env_step_ns,
        sync_per_worker_ns,
        model_step_ns,
        worker_limit: None,
    };
    let mp = PpoModel {
        name: "multiprocessing",
        // Local shared-memory sync is cheaper per worker — measured ratio
        // from the paper's "1% to 3% difference" at matched worker counts.
        env_step_ns,
        sync_per_worker_ns: (sync_per_worker_ns as f64 * 0.97) as u64,
        model_step_ns,
        worker_limit: Some(32),
    };
    let col_labels: Vec<String> = cfg
        .ppo_worker_counts
        .iter()
        .map(|w| w.to_string())
        .collect();
    let mut table = Table::new(
        format!(
            "E3 / Fig 3c — PPO/Breakout: {} frames, horizon {}, env step {} ns, model step {:.1} ms, virtual time",
            cfg.ppo_frames, cfg.ppo_horizon, env_step_ns, model_step_ns as f64 / 1e6
        ),
        "framework \\ workers",
        col_labels,
    );
    for model in [&mp, &fiber] {
        let cells: Vec<Option<f64>> = cfg
            .ppo_worker_counts
            .iter()
            .map(|&w| {
                model
                    .total_time_ns(cfg.ppo_frames, cfg.ppo_horizon, w)
                    .map(|ns| ns as f64 / 1e9)
            })
            .collect();
        table.add_row(model.name, cells);
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rollout_distribution_is_positive_and_varied() {
        let (mean, cv) = measure_episode_lengths(8, 200, 3);
        assert!(mean > 5.0, "episodes can't be instant: {mean}");
        assert!(cv > 0.0, "lengths must vary");
    }

    #[test]
    fn breakout_step_cost_sane() {
        let ns = measure_breakout_step_ns(5_000);
        assert!(ns > 10.0 && ns < 1_000_000.0, "{ns}");
    }

    #[test]
    fn es_figure_shape() {
        let cfg = ScalingConfig {
            pop: 2048,
            iterations: 2,
            worker_counts: vec![32, 256, 1024],
            ..Default::default()
        };
        let t = es_scaling_figure(&cfg, 15_000).unwrap();
        let fiber = &t.rows[0].1;
        let ipp = &t.rows[1].1;
        assert!(fiber[2].unwrap() < fiber[0].unwrap(), "fiber improves with workers");
        assert!(ipp[2].is_none(), "ipp fails at 1024 (red X)");
        // Fiber beats ipp at every worker count (paper).
        for (f, i) in fiber.iter().zip(ipp) {
            if let (Some(f), Some(i)) = (f, i) {
                assert!(f < i, "fiber {f} !< ipp {i}");
            }
        }
    }

    #[test]
    fn ppo_figure_shape() {
        let cfg = ScalingConfig {
            ppo_frames: 1_000_000,
            ppo_worker_counts: vec![8, 32, 64, 256],
            ..Default::default()
        };
        let t = ppo_scaling_figure(&cfg, 500, 30_000_000).unwrap();
        let mp = &t.rows[0].1;
        let fiber = &t.rows[1].1;
        assert!(mp[2].is_none() && mp[3].is_none(), "mp capped at 32");
        assert!(
            fiber[3].unwrap() < mp[1].unwrap(),
            "fiber@256 beats best single-machine"
        );
        assert!(
            fiber[3].unwrap() < fiber[0].unwrap() / 2.0,
            "256 workers less than half the 8-worker time (paper)"
        );
        // Small-worker parity: fiber within a few % of mp.
        let ratio = fiber[0].unwrap() / mp[0].unwrap();
        assert!(ratio < 1.1, "fiber must be within ~10% of mp at 8 workers: {ratio}");
    }
}
