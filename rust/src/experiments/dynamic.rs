//! E5 — dynamic scaling: the Go-Explore / POET resource pattern.
//!
//! A two-phase workload on the simulated cluster: a CPU-heavy exploration
//! phase (many small CPU pods) followed by a GPU robustification phase
//! (few GPU pods). Static allocation must reserve the *peak* of both
//! resource kinds for the whole run; Fiber's dynamic allocation requests
//! pods per phase and returns them. The metric is the paper's claim:
//! reserved-resource × time (cost) and mean utilization.

use anyhow::Result;

use crate::benchkit::Table;
use crate::cluster::simk8s::{NodeSpec, PodSpec, SimCluster, SimClusterConfig};
use crate::cluster::Resources;

/// Result of one allocation strategy.
#[derive(Clone, Debug)]
pub struct DynamicOutcome {
    pub makespan_s: f64,
    /// CPU-core-seconds reserved (requested × duration).
    pub reserved_cpu_core_s: f64,
    /// CPU-core-seconds actually used by running pods.
    pub used_cpu_core_s: f64,
}

impl DynamicOutcome {
    pub fn utilization(&self) -> f64 {
        if self.reserved_cpu_core_s == 0.0 {
            0.0
        } else {
            self.used_cpu_core_s / self.reserved_cpu_core_s
        }
    }
}

fn cluster() -> SimCluster {
    SimCluster::new(SimClusterConfig {
        nodes: vec![NodeSpec::with_gpu(32, 128_000, 4); 8], // 256 cores, 32 GPUs
        schedule_latency_ns: 30_000_000,
        start_latency_ns: 500_000_000,
        failure_rate_per_s: 0.0,
        seed: 5,
    })
}

const EXPLORE_PODS: usize = 128; // 1 CPU each
const EXPLORE_SECS: u64 = 120;
const ROBUST_PODS: usize = 8; // 1 GPU + 4 CPU each
const ROBUST_SECS: u64 = 240;

fn cpu_pod(secs: u64) -> PodSpec {
    PodSpec {
        name: "explore".into(),
        resources: Resources {
            cpu_milli: 1000,
            mem_mb: 512,
            gpu: 0,
        },
        duration_ns: Some(secs * 1_000_000_000),
    }
}

fn gpu_pod(secs: u64) -> PodSpec {
    PodSpec {
        name: "robustify".into(),
        resources: Resources {
            cpu_milli: 4000,
            mem_mb: 4096,
            gpu: 1,
        },
        duration_ns: Some(secs * 1_000_000_000),
    }
}

/// Dynamic: request exploration pods, wait, release implicitly on
/// completion, then request robustification pods. Reserved = what's
/// actually requested in each phase.
pub fn run_dynamic() -> DynamicOutcome {
    let mut c = cluster();
    let explore: Vec<_> = (0..EXPLORE_PODS).map(|_| c.submit(cpu_pod(EXPLORE_SECS))).collect();
    c.run_to_quiescence();
    let t_explore_end = c.now();
    let robust: Vec<_> = (0..ROBUST_PODS).map(|_| c.submit(gpu_pod(ROBUST_SECS))).collect();
    c.run_to_quiescence();
    let makespan = c.now();
    let _ = (explore, robust);
    let reserved = EXPLORE_PODS as f64 * (t_explore_end as f64 / 1e9)
        + ROBUST_PODS as f64 * 4.0 * ((makespan - t_explore_end) as f64 / 1e9);
    let used = EXPLORE_PODS as f64 * EXPLORE_SECS as f64
        + ROBUST_PODS as f64 * 4.0 * ROBUST_SECS as f64;
    DynamicOutcome {
        makespan_s: makespan as f64 / 1e9,
        reserved_cpu_core_s: reserved,
        used_cpu_core_s: used,
    }
}

/// Static peak allocation: reserve max(explore CPUs, robust CPUs) *and* the
/// GPUs for the entire run (the "allocate for the peak of all stages"
/// baseline from the paper's introduction).
pub fn run_static() -> DynamicOutcome {
    let mut c = cluster();
    // Same pod executions, same timeline…
    let explore: Vec<_> = (0..EXPLORE_PODS).map(|_| c.submit(cpu_pod(EXPLORE_SECS))).collect();
    c.run_to_quiescence();
    let robust: Vec<_> = (0..ROBUST_PODS).map(|_| c.submit(gpu_pod(ROBUST_SECS))).collect();
    c.run_to_quiescence();
    let makespan = c.now() as f64 / 1e9;
    let _ = (explore, robust);
    // …but the reservation is the peak CPU demand for the whole makespan.
    let peak_cpu = (EXPLORE_PODS as f64).max(ROBUST_PODS as f64 * 4.0);
    let reserved = peak_cpu * makespan;
    let used = EXPLORE_PODS as f64 * EXPLORE_SECS as f64
        + ROBUST_PODS as f64 * 4.0 * ROBUST_SECS as f64;
    DynamicOutcome {
        makespan_s: makespan,
        reserved_cpu_core_s: reserved,
        used_cpu_core_s: used,
    }
}

/// E5 table: dynamic vs static.
pub fn dynamic_scaling_experiment() -> Result<Table> {
    let dynamic = run_dynamic();
    let static_ = run_static();
    let mut t = Table::new(
        "E5 — dynamic scaling (Go-Explore-style two-phase workload on simk8s)",
        "strategy",
        vec![
            "makespan s".into(),
            "reserved core·s".into(),
            "used core·s".into(),
            "util %".into(),
        ],
    );
    t.unit = "";
    for (name, o) in [("fiber dynamic", &dynamic), ("static peak", &static_)] {
        t.add_row(
            name,
            vec![
                Some(o.makespan_s),
                Some(o.reserved_cpu_core_s),
                Some(o.used_cpu_core_s),
                Some(o.utilization() * 100.0),
            ],
        );
    }
    Ok(t)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dynamic_beats_static_on_utilization() {
        let d = run_dynamic();
        let s = run_static();
        assert!(
            d.utilization() > s.utilization(),
            "dynamic {:.2} must beat static {:.2}",
            d.utilization(),
            s.utilization()
        );
        assert!(
            d.reserved_cpu_core_s < s.reserved_cpu_core_s,
            "dynamic reserves less"
        );
        // Same actual work in both.
        assert!((d.used_cpu_core_s - s.used_cpu_core_s).abs() < 1e-6);
    }

    #[test]
    fn phases_complete() {
        let d = run_dynamic();
        assert!(d.makespan_s > (EXPLORE_SECS + ROBUST_SECS) as f64 * 0.9);
        assert!(d.makespan_s < (EXPLORE_SECS + ROBUST_SECS) as f64 * 2.0);
    }
}
