//! The ring-collectives dashboard panel: overlap on/off allreduce wall
//! time plus kill-one-member recovery, rendered as a [`Table`] alongside
//! the Fig 3a/3b experiment outputs. The timing harness itself
//! ([`timed_allreduce`]) is the single source of truth shared with
//! `benches/ring_allreduce.rs`, which persists the full machine-readable
//! sweep to `BENCH_ring.json` — panel and bench cannot silently measure
//! different chaos protocols.

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::benchkit::Table;
use crate::ring::{is_chaos_killed, Rendezvous, RingMember};

/// Result of one timed (possibly chaos-injected) allreduce.
pub struct RingTiming {
    /// Worst surviving member's wall time for the collective (detection
    /// timeout and heal included when `kill_one` was set).
    pub wall_s: f64,
    /// World size after the collective (shrinks by one under chaos).
    pub world_after: usize,
    /// Heals survived (0 without chaos, ≥1 with).
    pub heals: u64,
}

/// One timed allreduce over `world` thread members, split into 8 chunks so
/// the overlap pipeline and the chunk-resume machinery are both exercised.
/// With `kill_one`, the highest rank dies after completing chunk 1 and the
/// survivors' heal + resume time is what gets measured. With `spares > 0`
/// (requires `kill_one`), that many standby members wait in the spare
/// pool, the heal drains them back in, and the timed collective resumes
/// over the **re-grown** world — `world_after` comes back equal to
/// `world`, proving kill → heal → auto-grow inside one op's wall time.
pub fn timed_allreduce(
    world: usize,
    elems: usize,
    overlap: bool,
    kill_one: bool,
    spares: usize,
) -> Result<RingTiming> {
    anyhow::ensure!(
        spares == 0 || kill_one,
        "spares are only drained by a heal here: pass kill_one with spares"
    );
    let rv = Rendezvous::new(world);
    rv.set_heartbeat_grace(Duration::from_millis(40));
    let victim_rank = world - 1;
    let spare_handles: Vec<_> = (0..spares)
        .map(|_| {
            let rv = rv.clone();
            std::thread::spawn(move || -> Result<Option<(f64, usize, u64)>> {
                let mut m = RingMember::join_spare_inproc(&rv, Duration::from_secs(10))?;
                m.set_timeout(Duration::from_millis(250));
                m.set_probe_interval(Duration::from_millis(10));
                m.set_overlap(overlap);
                m.set_chunk_elems((elems / 8).max(1));
                let cold = m.cold_op().cloned().expect("spare drained mid-op");
                let mut buf = vec![0.0f32; cold.op.elems as usize];
                m.allreduce_sum(&mut buf)?;
                // The rejoiner's clock starts at admission; the survivors'
                // wall time is the recovery figure. Report the grown world.
                Ok(Some((0.0, m.world(), m.heal_count())))
            })
        })
        .collect();
    let gate = Instant::now() + Duration::from_secs(10);
    while rv.spares().len() < spares {
        anyhow::ensure!(
            Instant::now() < gate,
            "spare registration timed out: {}/{spares} pending after 10s",
            rv.spares().len()
        );
        std::thread::sleep(Duration::from_millis(1));
    }
    let handles: Vec<_> = (0..world)
        .map(|_| {
            let rv = rv.clone();
            std::thread::spawn(move || -> Result<Option<(f64, usize, u64)>> {
                let mut m = RingMember::join_inproc(&rv)?;
                m.set_overlap(overlap);
                m.set_chunk_elems((elems / 8).max(1));
                if kill_one {
                    m.set_timeout(Duration::from_millis(250));
                    m.set_probe_interval(Duration::from_millis(10));
                    if m.rank() == victim_rank {
                        m.set_kill_after_chunk(Some(1));
                    }
                } else {
                    // Warmup only when timing the steady state, not chaos.
                    let mut w = vec![0.5f32; elems];
                    m.allreduce_sum(&mut w)?;
                }
                let mut buf = vec![1.0f32; elems];
                let t = Instant::now();
                match m.allreduce_sum(&mut buf) {
                    Ok(()) => Ok(Some((
                        t.elapsed().as_secs_f64(),
                        m.world(),
                        m.heal_count(),
                    ))),
                    Err(e) if kill_one && is_chaos_killed(&e) => Ok(None),
                    Err(e) => Err(e),
                }
            })
        })
        .collect();
    let mut timing = RingTiming {
        wall_s: 0.0,
        world_after: 0,
        heals: 0,
    };
    for h in handles.into_iter().chain(spare_handles) {
        if let Some((secs, w, heals)) = h.join().expect("ring timing thread")? {
            timing.wall_s = timing.wall_s.max(secs);
            timing.world_after = timing.world_after.max(w);
            timing.heals = timing.heals.max(heals);
        }
    }
    Ok(timing)
}

/// The dashboard table: per world size, overlap-on vs overlap-off wall
/// time for a 256 KB allreduce, the wall time of the same collective when
/// one member is killed mid-flight (heal + resume included), and the same
/// kill with a spare standing by (heal + auto-grow back to the original
/// world + resume).
pub fn ring_collectives_figure() -> Result<Table> {
    let elems = 64 * 1024; // 256 KB of f32
    let mut table = Table::new(
        "Ring allreduce (256KB): overlap vs lockstep, kill-one recovery, kill+regrow",
        "world",
        vec![
            "overlap on".into(),
            "overlap off".into(),
            "kill-one recovery".into(),
            "kill+regrow".into(),
        ],
    );
    for world in [2usize, 4] {
        let on = timed_allreduce(world, elems, true, false, 0)?;
        let off = timed_allreduce(world, elems, false, false, 0)?;
        let recovery = timed_allreduce(world, elems, true, true, 0)?;
        let regrow = timed_allreduce(world, elems, true, true, 1)?;
        table.add_row(
            format!("{world}"),
            vec![
                Some(on.wall_s),
                Some(off.wall_s),
                Some(recovery.wall_s),
                Some(regrow.wall_s),
            ],
        );
    }
    Ok(table)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn chaos_timing_reports_heal_and_shrunk_world() {
        let t = timed_allreduce(3, 1024, true, true, 0).unwrap();
        assert_eq!(t.world_after, 2);
        assert!(t.heals >= 1);
        assert!(t.wall_s > 0.0);
    }

    #[test]
    fn chaos_timing_with_spare_regrows_to_original_world() {
        let t = timed_allreduce(3, 1024, true, true, 1).unwrap();
        assert_eq!(t.world_after, 3, "the drained spare restores the world");
        assert!(t.heals >= 1);
        assert!(t.wall_s > 0.0);
    }

    #[test]
    fn panel_renders_with_all_cells_populated() {
        let t = ring_collectives_figure().unwrap();
        assert_eq!(t.rows.len(), 2);
        for (label, cells) in &t.rows {
            assert_eq!(cells.len(), 4, "row {label}");
            assert!(cells.iter().all(|c| c.is_some()), "row {label} has gaps");
        }
    }
}
