//! `fiber::trace::live` — the streaming observability plane.
//!
//! Everything in [`super::export`] is post-hoc: journals drain once, at
//! exit, so a hung collective or a SIGKILLed leader yields zero telemetry
//! exactly when it matters most. This module makes the same journals
//! *stream*:
//!
//! * [`SegmentWriter`] appends each incremental drain to rotating on-disk
//!   JSONL **segments** (`segment-0000.jsonl`, …). A run killed at
//!   iteration N leaves segments 0..N−1 intact — and
//!   [`super::export::read_trace`] accepts the segment directory wherever
//!   it accepts a file, so `trace-view`/`trace-check` audit partial runs.
//! * [`Health`] folds the event stream into an online model: per-node
//!   liveness, pool throughput and queue depth, ring generation and
//!   in-flight op/chunk progress, store hit-rate and resident bytes, the
//!   pop leaderboard, and **online straggler detection** against rolling
//!   per-span-kind p50/p99 baselines (flagged spans are also emitted back
//!   into the trace as `trace.straggler` instants, parented under the
//!   offending span).
//! * [`Streamer`] runs the drain→segment→health loop on a background
//!   cadence, optionally re-exporting [`crate::metrics::export_prometheus`]
//!   snapshots and serving [`HealthSnapshot`]s over RPC for
//!   `fiber-cli top --connect`.
//! * [`install_crash_hook`] / [`crash_dump_now`] dump the
//!   [`super::FlightRecorder`]'s last window to `fiber-crash-<pid>.jsonl`
//!   on panic or fatal error, with the panicking span marked by a
//!   `trace.crash` instant. Crash dumps carry the `crash` footer marker so
//!   [`super::check`] audits them as the bounded suffixes they are.

use std::collections::{HashMap, VecDeque};
use std::io::Write as _;
use std::net::SocketAddr;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::comms::rpc::{RpcClient, RpcServer};
use crate::wire::{self, Decode, Encode};

use super::collect::{Collector, TraceDump};
use super::TraceEvent;

// ---------------------------------------------------------------------------
// Segment writer
// ---------------------------------------------------------------------------

/// Default events per segment before rotation.
pub const SEGMENT_EVENTS: usize = 4096;

/// Appends incremental drains to rotating JSONL segment files. Each closed
/// segment ends with a metadata footer whose `dropped` field is the
/// *delta* of the journals' cumulative dropped counter since the previous
/// segment — so a reader summing footers across a directory reconstructs
/// the run total without double counting ([`super::export::read_trace_dir`]).
///
/// Appends go straight to the file (no userspace buffering): a SIGKILL
/// costs at most one torn trailing line, which the directory reader
/// tolerates on the final segment.
pub struct SegmentWriter {
    dir: PathBuf,
    max_events: usize,
    seg_index: u32,
    in_current: usize,
    current: Option<std::fs::File>,
    /// Cumulative dropped count already attributed to closed segments.
    dropped_base: u64,
    /// Latest cumulative dropped count observed (for the final footer).
    last_dropped: u64,
}

impl SegmentWriter {
    /// Create (or reuse) `dir` and start writing at `segment-0000.jsonl`.
    pub fn new(dir: &Path, max_events: usize) -> Result<SegmentWriter> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("create live trace dir {}", dir.display()))?;
        Ok(SegmentWriter {
            dir: dir.to_path_buf(),
            max_events: max_events.max(1),
            seg_index: 0,
            in_current: 0,
            current: None,
            dropped_base: 0,
            last_dropped: 0,
        })
    }

    fn segment_path(&self, index: u32) -> PathBuf {
        self.dir.join(format!("segment-{index:04}.jsonl"))
    }

    fn open_current(&mut self) -> Result<&mut std::fs::File> {
        if self.current.is_none() {
            let path = self.segment_path(self.seg_index);
            let f = std::fs::OpenOptions::new()
                .create(true)
                .append(true)
                .open(&path)
                .with_context(|| format!("open trace segment {}", path.display()))?;
            self.current = Some(f);
            self.in_current = 0;
        }
        Ok(self.current.as_mut().unwrap())
    }

    /// Close the current segment: write its dropped-*delta* footer and
    /// advance the rotation index.
    fn close_current(&mut self) -> Result<()> {
        if let Some(mut f) = self.current.take() {
            let delta = self.last_dropped.saturating_sub(self.dropped_base);
            self.dropped_base = self.last_dropped;
            let footer = super::export::meta_footer(delta, false);
            f.write_all(footer.as_bytes())
                .and_then(|()| f.write_all(b"\n"))
                .with_context(|| {
                    format!("write footer to {}", self.segment_path(self.seg_index).display())
                })?;
            self.seg_index += 1;
            self.in_current = 0;
        }
        Ok(())
    }

    /// Append one incremental drain. `dump.dropped` must be the journals'
    /// *cumulative* dropped count (what [`Collector::drain_incremental`]
    /// returns); the writer converts it to per-segment deltas itself.
    pub fn append(&mut self, dump: &TraceDump) -> Result<()> {
        self.last_dropped = self.last_dropped.max(dump.dropped);
        let mut i = 0;
        while i < dump.events.len() {
            let room = self.max_events - self.in_current.min(self.max_events);
            if room == 0 {
                self.close_current()?;
                continue;
            }
            let take = room.min(dump.events.len() - i);
            let mut buf = String::new();
            for (node, ev) in &dump.events[i..i + take] {
                buf.push_str(&super::export::jsonl_line(node, ev));
                buf.push('\n');
            }
            let seg = self.seg_index;
            let f = self.open_current()?;
            f.write_all(buf.as_bytes())
                .with_context(|| format!("append to segment {seg}"))?;
            self.in_current += take;
            i += take;
        }
        Ok(())
    }

    /// Seal the stream: footer the current segment (creating an empty
    /// footer-only segment if nothing was ever written, so the directory
    /// is always readable).
    pub fn finish(&mut self) -> Result<()> {
        self.open_current()?;
        self.close_current()
    }

    /// Segments fully written so far (excluding the open one).
    pub fn segments_closed(&self) -> u32 {
        self.seg_index
    }
}

// ---------------------------------------------------------------------------
// Health model
// ---------------------------------------------------------------------------

/// Rolling per-span-kind duration window for online quantile baselines.
struct Baseline {
    window: VecDeque<u64>,
    cap: usize,
}

impl Baseline {
    fn new(cap: usize) -> Baseline {
        Baseline {
            window: VecDeque::new(),
            cap,
        }
    }

    fn push(&mut self, dur_ns: u64) {
        if self.window.len() >= self.cap {
            self.window.pop_front();
        }
        self.window.push_back(dur_ns);
    }

    fn quantile(&self, q: f64) -> u64 {
        if self.window.is_empty() {
            return 0;
        }
        let mut v: Vec<u64> = self.window.iter().copied().collect();
        v.sort_unstable();
        let rank = ((q * (v.len() - 1) as f64).round() as usize).min(v.len() - 1);
        v[rank]
    }
}

struct NodeState {
    last_ts_ns: u64,
    events: u64,
    stragglers: u64,
}

/// Per-node liveness in a [`HealthSnapshot`].
#[derive(Clone, Debug, Default, PartialEq)]
pub struct NodeHealth {
    pub name: String,
    /// Leader-clock timestamp of the node's most recent event — the
    /// heartbeat; `snapshot.now_ns - last_ts_ns` is the liveness age.
    pub last_ts_ns: u64,
    pub events: u64,
    pub stragglers: u64,
}

impl Encode for NodeHealth {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.name.encode(buf);
        self.last_ts_ns.encode(buf);
        self.events.encode(buf);
        self.stragglers.encode(buf);
    }
}

impl Decode for NodeHealth {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        Ok(NodeHealth {
            name: String::decode(r)?,
            last_ts_ns: u64::decode(r)?,
            events: u64::decode(r)?,
            stragglers: u64::decode(r)?,
        })
    }
}

/// One flagged straggler (kept for the `top` readout; the trace-side
/// record is the `trace.straggler` instant).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct StragglerFlag {
    pub node: String,
    /// Span kind that blew its baseline (`pool.run`, `ring.allreduce`, …).
    pub name: String,
    pub dur_ns: u64,
    pub p99_ns: u64,
}

impl Encode for StragglerFlag {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.node.encode(buf);
        self.name.encode(buf);
        self.dur_ns.encode(buf);
        self.p99_ns.encode(buf);
    }
}

impl Decode for StragglerFlag {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        Ok(StragglerFlag {
            node: String::decode(r)?,
            name: String::decode(r)?,
            dur_ns: u64::decode(r)?,
            p99_ns: u64::decode(r)?,
        })
    }
}

/// A point-in-time readout of the [`Health`] model — what `fiber-cli top`
/// renders and what the telemetry RPC ships (wire-encodable).
#[derive(Clone, Debug, Default)]
pub struct HealthSnapshot {
    /// Leader-clock high-water mark of the observed stream, ns.
    pub now_ns: u64,
    pub nodes: Vec<NodeHealth>,
    pub pool_runs: u64,
    /// Pool throughput over the trailing window, runs/s × 1000.
    pub pool_tp_milli: u64,
    /// `pool.queue.depth` gauge (leader-process metrics; 0 offline).
    pub pool_queue_depth: i64,
    /// Highest ring generation seen (−1: no ring activity).
    pub ring_gen: i64,
    /// Completed collective ops (`ring.allreduce` + `ring.broadcast`).
    pub ring_ops: u64,
    /// Chunk-level progress instants (`ring.chunk.*`) — the in-flight op's
    /// heartbeat between op completions.
    pub ring_chunks: u64,
    pub ring_heals: u64,
    /// Latest `ring.chunk.*` chunk / step args (−1: none yet).
    pub ring_last_chunk: i64,
    pub ring_last_step: i64,
    pub store_hits: u64,
    pub store_fetches: u64,
    /// `store.bytes` gauge (leader-process metrics; 0 offline).
    pub store_bytes: i64,
    /// Pop leaderboard: best `(trial, reward_milli)` pairs, reward-desc.
    pub pop_best: Vec<(i64, i64)>,
    pub straggler_flags: u64,
    pub recent_stragglers: Vec<StragglerFlag>,
}

impl Encode for HealthSnapshot {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.now_ns.encode(buf);
        self.nodes.encode(buf);
        self.pool_runs.encode(buf);
        self.pool_tp_milli.encode(buf);
        self.pool_queue_depth.encode(buf);
        self.ring_gen.encode(buf);
        self.ring_ops.encode(buf);
        self.ring_chunks.encode(buf);
        self.ring_heals.encode(buf);
        self.ring_last_chunk.encode(buf);
        self.ring_last_step.encode(buf);
        self.store_hits.encode(buf);
        self.store_fetches.encode(buf);
        self.store_bytes.encode(buf);
        self.pop_best.encode(buf);
        self.straggler_flags.encode(buf);
        self.recent_stragglers.encode(buf);
    }
}

impl Decode for HealthSnapshot {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        Ok(HealthSnapshot {
            now_ns: u64::decode(r)?,
            nodes: Vec::<NodeHealth>::decode(r)?,
            pool_runs: u64::decode(r)?,
            pool_tp_milli: u64::decode(r)?,
            pool_queue_depth: i64::decode(r)?,
            ring_gen: i64::decode(r)?,
            ring_ops: u64::decode(r)?,
            ring_chunks: u64::decode(r)?,
            ring_heals: u64::decode(r)?,
            ring_last_chunk: i64::decode(r)?,
            ring_last_step: i64::decode(r)?,
            store_hits: u64::decode(r)?,
            store_fetches: u64::decode(r)?,
            store_bytes: i64::decode(r)?,
            pop_best: Vec::<(i64, i64)>::decode(r)?,
            straggler_flags: u64::decode(r)?,
            recent_stragglers: Vec::<StragglerFlag>::decode(r)?,
        })
    }
}

fn fmt_ms(ns: u64) -> String {
    format!("{:.1} ms", ns as f64 / 1e6)
}

fn fmt_bytes(b: i64) -> String {
    let b = b.max(0) as f64;
    if b >= 1024.0 * 1024.0 {
        format!("{:.1} MiB", b / (1024.0 * 1024.0))
    } else if b >= 1024.0 {
        format!("{:.1} KiB", b / 1024.0)
    } else {
        format!("{b:.0} B")
    }
}

impl HealthSnapshot {
    /// Plain-text rendering: one screen, grep-friendly section prefixes
    /// (`NODE`, `POOL`, `RING`, `STORE`, `POP`, `STRAGGLER`) so CI can
    /// assert on lines and humans can watch it refresh.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "fiber top — t={} — {} node(s), {} straggler flag(s)\n",
            fmt_ms(self.now_ns),
            self.nodes.len(),
            self.straggler_flags
        ));
        out.push_str("NODE            LAST-EVENT-AGE      EVENTS  STRAGGLERS\n");
        for n in &self.nodes {
            out.push_str(&format!(
                "NODE {:<14} {:>10}  {:>10}  {:>10}\n",
                n.name,
                fmt_ms(self.now_ns.saturating_sub(n.last_ts_ns)),
                n.events,
                n.stragglers
            ));
        }
        out.push_str(&format!(
            "POOL  runs {}  throughput {:.1}/s  queue-depth {}\n",
            self.pool_runs,
            self.pool_tp_milli as f64 / 1000.0,
            self.pool_queue_depth
        ));
        out.push_str(&format!(
            "RING  gen {}  ops {}  chunks {}  heals {}  last-chunk {}  last-step {}\n",
            self.ring_gen,
            self.ring_ops,
            self.ring_chunks,
            self.ring_heals,
            self.ring_last_chunk,
            self.ring_last_step
        ));
        let lookups = self.store_hits + self.store_fetches;
        let hit_rate = if lookups == 0 {
            0.0
        } else {
            self.store_hits as f64 * 100.0 / lookups as f64
        };
        out.push_str(&format!(
            "STORE hits {}  fetches {}  hit-rate {:.1}%  bytes {}\n",
            self.store_hits,
            self.store_fetches,
            hit_rate,
            fmt_bytes(self.store_bytes)
        ));
        if self.pop_best.is_empty() {
            out.push_str("POP   (no trials observed)\n");
        } else {
            let board: Vec<String> = self
                .pop_best
                .iter()
                .map(|(t, r)| format!("trial {t}: {:.3}", *r as f64 / 1000.0))
                .collect();
            out.push_str(&format!("POP   leaderboard  {}\n", board.join("  |  ")));
        }
        for s in &self.recent_stragglers {
            let factor = if s.p99_ns == 0 {
                0.0
            } else {
                s.dur_ns as f64 / s.p99_ns as f64
            };
            out.push_str(&format!(
                "STRAGGLER {} on {}: {} vs p99 {} ({factor:.1}x)\n",
                s.name,
                s.node,
                fmt_ms(s.dur_ns),
                fmt_ms(s.p99_ns)
            ));
        }
        out
    }
}

/// Online aggregator over the incremental event stream. Feed it batches
/// with [`Health::observe`] (leader-clock order within a batch is fine —
/// [`Collector::drain_incremental`] sorts), read it with
/// [`Health::snapshot`].
pub struct Health {
    /// Straggler threshold multiplier: a span is flagged when its duration
    /// exceeds `k × p99` of its kind's rolling baseline.
    k: u64,
    /// Minimum baseline samples before flagging (warm-up guard).
    min_baseline: usize,
    nodes: Vec<(String, NodeState)>,
    baselines: HashMap<String, Baseline>,
    now_ns: u64,
    pool_runs: u64,
    run_ends: VecDeque<u64>,
    ring_gen: i64,
    ring_ops: u64,
    ring_chunks: u64,
    ring_heals: u64,
    ring_last_chunk: i64,
    ring_last_step: i64,
    store_hits: u64,
    store_fetches: u64,
    pop_best: HashMap<i64, i64>,
    straggler_flags: u64,
    recent_stragglers: VecDeque<StragglerFlag>,
}

/// Trailing window for pool throughput, ns.
const TP_WINDOW_NS: u64 = 2_000_000_000;
/// Rolling baseline window per span kind.
const BASELINE_CAP: usize = 256;
/// Recent straggler flags kept for display.
const RECENT_STRAGGLERS: usize = 8;

impl Health {
    /// `k` is the straggler multiplier (duration > k × rolling p99 flags).
    pub fn new(k: u64) -> Health {
        Health {
            k: k.max(1),
            min_baseline: 20,
            nodes: Vec::new(),
            baselines: HashMap::new(),
            now_ns: 0,
            pool_runs: 0,
            run_ends: VecDeque::new(),
            ring_gen: -1,
            ring_ops: 0,
            ring_chunks: 0,
            ring_heals: 0,
            ring_last_chunk: -1,
            ring_last_step: -1,
            store_hits: 0,
            store_fetches: 0,
            pop_best: HashMap::new(),
            straggler_flags: 0,
            recent_stragglers: VecDeque::new(),
        }
    }

    fn node_mut(&mut self, name: &str) -> &mut NodeState {
        if let Some(pos) = self.nodes.iter().position(|(n, _)| n == name) {
            return &mut self.nodes[pos].1;
        }
        self.nodes.push((
            name.to_string(),
            NodeState {
                last_ts_ns: 0,
                events: 0,
                stragglers: 0,
            },
        ));
        &mut self.nodes.last_mut().unwrap().1
    }

    /// Fold one batch of `(node, event)` pairs into the model. Straggler
    /// flags are checked against the baseline *before* the new sample
    /// joins it, then emitted as `trace.straggler` instants (parented
    /// under the offending span) when tracing is enabled — so the flag
    /// itself lands in the stream the next drain picks up.
    pub fn observe(&mut self, events: &[(String, TraceEvent)]) {
        for (node, ev) in events {
            let end_ns = ev.ts_ns.saturating_add(ev.dur_ns);
            self.now_ns = self.now_ns.max(end_ns);
            {
                let st = self.node_mut(node);
                st.last_ts_ns = st.last_ts_ns.max(end_ns);
                st.events += 1;
            }
            match ev.name.as_str() {
                "pool.run" => {
                    self.pool_runs += 1;
                    self.run_ends.push_back(end_ns);
                    while self
                        .run_ends
                        .front()
                        .is_some_and(|&t| t + TP_WINDOW_NS < self.now_ns)
                    {
                        self.run_ends.pop_front();
                    }
                }
                "ring.allreduce" | "ring.broadcast" => self.ring_ops += 1,
                "ring.heal" => self.ring_heals += 1,
                "store.fetch" => self.store_fetches += 1,
                "store.hit" | "store.wait" => self.store_hits += 1,
                "pop.score" => {
                    if let (Some(trial), Some(reward)) =
                        (ev.arg("trial"), ev.arg("reward_milli"))
                    {
                        let best = self.pop_best.entry(trial).or_insert(i64::MIN);
                        *best = (*best).max(reward);
                    }
                }
                name if name.starts_with("ring.chunk.") => {
                    self.ring_chunks += 1;
                    if let Some(c) = ev.arg("chunk") {
                        self.ring_last_chunk = c;
                    }
                    if let Some(s) = ev.arg("step") {
                        self.ring_last_step = s;
                    }
                }
                _ => {}
            }
            if let Some(g) = ev.arg("gen") {
                if ev.name.starts_with("ring.") {
                    self.ring_gen = self.ring_gen.max(g);
                }
            }
            // Straggler detection on every completed span.
            if ev.dur_ns > 0 {
                let (flagged, p99) = {
                    let base = self
                        .baselines
                        .entry(ev.name.clone())
                        .or_insert_with(|| Baseline::new(BASELINE_CAP));
                    let p99 = base.quantile(0.99);
                    let flagged = base.window.len() >= self.min_baseline
                        && p99 > 0
                        && ev.dur_ns > self.k.saturating_mul(p99);
                    base.push(ev.dur_ns);
                    (flagged, p99)
                };
                if flagged {
                    self.straggler_flags += 1;
                    self.node_mut(node).stragglers += 1;
                    if self.recent_stragglers.len() >= RECENT_STRAGGLERS {
                        self.recent_stragglers.pop_front();
                    }
                    self.recent_stragglers.push_back(StragglerFlag {
                        node: node.clone(),
                        name: ev.name.clone(),
                        dur_ns: ev.dur_ns,
                        p99_ns: p99,
                    });
                    super::instant_under(
                        "trace.straggler",
                        ev.span,
                        &[
                            ("dur_ns", ev.dur_ns as i64),
                            ("p99_ns", p99 as i64),
                            (
                                "factor_milli",
                                (ev.dur_ns.saturating_mul(1000) / p99.max(1)) as i64,
                            ),
                        ],
                    );
                }
            }
        }
    }

    /// Current readout. Gauge-backed fields (`pool.queue.depth`,
    /// `store.bytes`) are read from this process's metrics registry — live
    /// in-process values on a leader, zeros when replaying a trace offline.
    pub fn snapshot(&self) -> HealthSnapshot {
        let mut nodes: Vec<NodeHealth> = self
            .nodes
            .iter()
            .map(|(name, st)| NodeHealth {
                name: name.clone(),
                last_ts_ns: st.last_ts_ns,
                events: st.events,
                stragglers: st.stragglers,
            })
            .collect();
        nodes.sort_by(|a, b| a.name.cmp(&b.name));
        let mut pop_best: Vec<(i64, i64)> =
            self.pop_best.iter().map(|(&t, &r)| (t, r)).collect();
        pop_best.sort_by(|a, b| b.1.cmp(&a.1).then(a.0.cmp(&b.0)));
        pop_best.truncate(5);
        let tp_milli = if self.run_ends.is_empty() {
            0
        } else {
            // runs/s × 1000 over the trailing window.
            self.run_ends.len() as u64 * 1_000_000 / (TP_WINDOW_NS / 1_000_000)
        };
        HealthSnapshot {
            now_ns: self.now_ns,
            nodes,
            pool_runs: self.pool_runs,
            pool_tp_milli: tp_milli,
            pool_queue_depth: crate::metrics::gauge("pool.queue.depth").get(),
            ring_gen: self.ring_gen,
            ring_ops: self.ring_ops,
            ring_chunks: self.ring_chunks,
            ring_heals: self.ring_heals,
            ring_last_chunk: self.ring_last_chunk,
            ring_last_step: self.ring_last_step,
            store_hits: self.store_hits,
            store_fetches: self.store_fetches,
            store_bytes: crate::metrics::gauge("store.bytes").get(),
            pop_best,
            straggler_flags: self.straggler_flags,
            recent_stragglers: self.recent_stragglers.iter().cloned().collect(),
        }
    }
}

/// Replay a whole dump (a file or segment directory read back via
/// [`super::export::read_trace`]) through a fresh [`Health`] — the offline
/// path behind `fiber-cli top --input`.
pub fn health_from_dump(dump: &TraceDump, k: u64) -> Health {
    let mut h = Health::new(k);
    h.observe(&dump.events);
    h
}

// ---------------------------------------------------------------------------
// Telemetry RPC (fiber-cli top --connect)
// ---------------------------------------------------------------------------

/// RPC tags of the live-telemetry protocol.
pub mod top_tags {
    /// Request: empty. Reply: wire-encoded [`super::HealthSnapshot`].
    pub const SNAPSHOT: u32 = 1;
}

/// Serve `health` snapshots for `fiber-cli top --connect ADDR`.
pub fn serve_health(health: Arc<Mutex<Health>>, bind: &str) -> Result<RpcServer> {
    RpcServer::bind(
        bind,
        Arc::new(move |tag, _payload| match tag {
            top_tags::SNAPSHOT => {
                let snap = health
                    .lock()
                    .unwrap_or_else(|e| e.into_inner())
                    .snapshot();
                Ok(wire::to_bytes(&snap))
            }
            other => Err(format!("unknown telemetry rpc tag {other}")),
        }),
    )
}

/// Pull one snapshot from a [`serve_health`] endpoint.
pub fn fetch_snapshot(addr: SocketAddr) -> Result<HealthSnapshot> {
    let cli = RpcClient::connect(addr).context("connect to telemetry endpoint")?;
    let reply = cli
        .call(top_tags::SNAPSHOT, &[])
        .context("telemetry snapshot call")?;
    wire::from_bytes(&reply).map_err(|e| anyhow::anyhow!("snapshot decode: {e}"))
}

// ---------------------------------------------------------------------------
// Streamer
// ---------------------------------------------------------------------------

/// Configuration for [`Streamer::start`].
pub struct StreamerConfig {
    /// Segment directory (created if absent).
    pub dir: PathBuf,
    /// Drain cadence.
    pub interval: Duration,
    /// Events per segment before rotation.
    pub max_segment_events: usize,
    /// Bind address for the [`serve_health`] telemetry endpoint
    /// (`--serve-top`); `None` disables it.
    pub serve: Option<String>,
    /// Rewrite a Prometheus snapshot here on every cadence tick
    /// (`--metrics-file` while live); `None` disables it.
    pub metrics_file: Option<String>,
    /// Straggler threshold multiplier ([`Health::new`]).
    pub straggler_k: u64,
}

impl StreamerConfig {
    pub fn to_dir(dir: &Path) -> StreamerConfig {
        StreamerConfig {
            dir: dir.to_path_buf(),
            interval: Duration::from_millis(200),
            max_segment_events: SEGMENT_EVENTS,
            serve: None,
            metrics_file: None,
            straggler_k: 3,
        }
    }
}

/// The background drain loop: every `interval`, pull
/// [`Collector::drain_incremental`], append to the [`SegmentWriter`], fold
/// into [`Health`], and (optionally) refresh the Prometheus snapshot.
/// [`Streamer::stop`] performs one final drain and seals the segment
/// stream; a process that never reaches `stop` (kill −9) still leaves all
/// previously appended segments on disk.
pub struct Streamer {
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<(Collector, SegmentWriter)>>,
    health: Arc<Mutex<Health>>,
    /// Held for its Drop (listener lifetime) when `serve` was configured.
    _server: Option<RpcServer>,
    metrics_file: Option<String>,
}

impl Streamer {
    pub fn start(mut collector: Collector, cfg: StreamerConfig) -> Result<Streamer> {
        let mut writer = SegmentWriter::new(&cfg.dir, cfg.max_segment_events)?;
        let health = Arc::new(Mutex::new(Health::new(cfg.straggler_k)));
        let server = match &cfg.serve {
            Some(bind) => Some(serve_health(health.clone(), bind)?),
            None => None,
        };
        let stop = Arc::new(AtomicBool::new(false));
        let stop_t = stop.clone();
        let health_t = health.clone();
        let interval = cfg.interval;
        let metrics_file = cfg.metrics_file.clone();
        let handle = std::thread::Builder::new()
            .name("fiber-trace-live".into())
            .spawn(move || {
                while !stop_t.load(Ordering::Relaxed) {
                    std::thread::sleep(interval);
                    let dump = collector.drain_incremental();
                    if !dump.events.is_empty() {
                        if let Err(e) = writer.append(&dump) {
                            eprintln!("warning: live trace append failed: {e:#}");
                        }
                        health_t
                            .lock()
                            .unwrap_or_else(|e| e.into_inner())
                            .observe(&dump.events);
                    }
                    if let Some(path) = &metrics_file {
                        let _ = std::fs::write(path, crate::metrics::export_prometheus());
                    }
                }
                (collector, writer)
            })
            .context("spawn live trace streamer")?;
        Ok(Streamer {
            stop,
            handle: Some(handle),
            health,
            _server: server,
            metrics_file: cfg.metrics_file,
        })
    }

    /// Shared handle to the live model (the telemetry RPC reads the same).
    pub fn health(&self) -> Arc<Mutex<Health>> {
        self.health.clone()
    }

    /// Stop the cadence, run one final drain (nothing recorded before
    /// `stop` is lost), seal the segment stream, and return the final
    /// snapshot.
    pub fn stop(mut self) -> Result<HealthSnapshot> {
        self.stop.store(true, Ordering::Relaxed);
        let Some(handle) = self.handle.take() else {
            anyhow::bail!("streamer already stopped");
        };
        let (mut collector, mut writer) = handle
            .join()
            .map_err(|_| anyhow::anyhow!("live trace streamer panicked"))?;
        let dump = collector.drain_incremental();
        if !dump.events.is_empty() {
            writer.append(&dump)?;
            self.health
                .lock()
                .unwrap_or_else(|e| e.into_inner())
                .observe(&dump.events);
        }
        writer.finish()?;
        if let Some(path) = &self.metrics_file {
            let _ = std::fs::write(path, crate::metrics::export_prometheus());
        }
        Ok(self
            .health
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .snapshot())
    }
}

// ---------------------------------------------------------------------------
// Crash flight-recorder dumps
// ---------------------------------------------------------------------------

static CRASH_DIR: Mutex<Option<PathBuf>> = Mutex::new(None);
static HOOK_INSTALLED: AtomicBool = AtomicBool::new(false);

/// Where crash dumps land (default: the current directory).
pub fn set_crash_dir(dir: &Path) {
    *CRASH_DIR.lock().unwrap_or_else(|e| e.into_inner()) = Some(dir.to_path_buf());
}

/// Dump the flight recorder's window to `fiber-crash-<pid>.jsonl` in the
/// crash dir. The panicking/faulting context is marked by appending a
/// `trace.crash` instant parented under the calling thread's current span
/// — on a panic hook that is the span the panic unwound out of. The file
/// carries the `crash` footer marker (plus a non-zero `dropped`, since the
/// window is a truncated suffix by construction) so `trace-check` audits
/// it with crash-window semantics.
///
/// Returns `None` when the flight recorder is disabled or empty — there is
/// nothing to dump, and an empty file would be noise.
pub fn crash_dump_now(reason: &str) -> Option<PathBuf> {
    let (events, overwritten) = super::flight().snapshot();
    if events.is_empty() {
        return None;
    }
    let journal = super::global();
    let node = journal.node_name();
    let mut pairs: Vec<(String, TraceEvent)> =
        events.into_iter().map(|e| (node.clone(), e)).collect();
    pairs.push((
        node,
        TraceEvent {
            ts_ns: journal.now_ns(),
            dur_ns: 0,
            span: super::fresh_span_id(),
            parent: super::current_span(),
            tid: super::thread_tid(),
            name: "trace.crash".to_string(),
            args: vec![
                ("pid".to_string(), std::process::id() as i64),
                ("overwritten".to_string(), overwritten as i64),
            ],
        },
    ));
    pairs.sort_by_key(|(_, e)| e.ts_ns);
    let dump = TraceDump {
        events: pairs,
        // A flight window is always a truncated view: even when nothing
        // rolled off the ring, history before the window is gone.
        dropped: overwritten.max(1),
        crash: true,
    };
    let dir = CRASH_DIR
        .lock()
        .unwrap_or_else(|e| e.into_inner())
        .clone()
        .unwrap_or_else(|| PathBuf::from("."));
    let path = dir.join(format!("fiber-crash-{}.jsonl", std::process::id()));
    let path_str = path.to_string_lossy().to_string();
    match super::export::write_jsonl(&path_str, &dump) {
        Ok(()) => {
            eprintln!(
                "fiber: {reason} — flight recorder dumped {} event(s) to {path_str}",
                dump.events.len()
            );
            Some(path)
        }
        Err(e) => {
            eprintln!("fiber: {reason} — flight recorder dump failed: {e:#}");
            None
        }
    }
}

/// Install a panic hook that dumps the flight recorder before the default
/// hook runs. Idempotent; chains whatever hook was installed before.
pub fn install_crash_hook() {
    if HOOK_INSTALLED.swap(true, Ordering::SeqCst) {
        return;
    }
    let prev = std::panic::take_hook();
    std::panic::set_hook(Box::new(move |info| {
        let _ = crash_dump_now("panic");
        prev(info);
    }));
}

#[cfg(test)]
mod tests {
    use super::super::tests::TEST_GUARD;
    use super::*;
    use crate::trace::check::check;
    use crate::trace::export::read_trace;
    use crate::trace::{Journal, TraceEvent};

    fn ev(ts: u64, dur: u64, span: u64, name: &str, args: &[(&str, i64)]) -> TraceEvent {
        TraceEvent {
            ts_ns: ts,
            dur_ns: dur,
            span,
            parent: 0,
            tid: 1,
            name: name.into(),
            args: args.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        }
    }

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("fiber_live_{tag}_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn segments_rotate_without_duplication_or_loss() {
        let dir = tmpdir("rotate");
        let mut w = SegmentWriter::new(&dir, 3).unwrap();
        // 8 events across three appends straddle two rotation boundaries.
        let batches = [(0u64..4u64), (4..5), (5..8)];
        let mut cumulative_dropped = 0;
        for batch in batches {
            let events: Vec<(String, TraceEvent)> = batch
                .map(|i| ("n".to_string(), ev(i * 10, 0, i + 1, "x", &[("i", i as i64)])))
                .collect();
            cumulative_dropped += 2;
            let dump = TraceDump {
                events,
                dropped: cumulative_dropped,
                crash: false,
            };
            w.append(&dump).unwrap();
        }
        w.finish().unwrap();
        assert!(w.segments_closed() >= 3, "rotation at 3 events per segment");
        let back = read_trace(dir.to_str().unwrap()).unwrap();
        assert_eq!(back.events.len(), 8, "no duplication, no loss across rotation");
        let spans: Vec<u64> = back.events.iter().map(|(_, e)| e.span).collect();
        assert_eq!(spans, (1..=8).collect::<Vec<_>>());
        assert_eq!(
            back.dropped, 6,
            "per-segment deltas reassemble the cumulative dropped count"
        );
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn cursor_monotonicity_under_concurrent_writers() {
        // Writers hammer a journal while a collector incrementally drains
        // into segments; every recorded event must land exactly once.
        let journal = Journal::with_capacity(1 << 14);
        journal.set_node_name("w");
        let dir = tmpdir("concurrent");
        let mut w = SegmentWriter::new(&dir, 64).unwrap();
        let mut c = Collector::new();
        c.add_local(journal.clone());

        const WRITERS: u64 = 4;
        const PER_WRITER: u64 = 500;
        let mut handles = Vec::new();
        for t in 0..WRITERS {
            let j = journal.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..PER_WRITER {
                    j.record(TraceEvent {
                        ts_ns: t * 1_000_000 + i,
                        dur_ns: 0,
                        span: t * PER_WRITER + i + 1,
                        parent: 0,
                        tid: t as u32 + 1,
                        name: "w.ev".into(),
                        args: vec![],
                    });
                    if i % 64 == 0 {
                        std::thread::yield_now();
                    }
                }
            }));
        }
        // Drain concurrently with the writers.
        loop {
            let dump = c.drain_incremental();
            w.append(&dump).unwrap();
            if handles.iter().all(|h| h.is_finished()) {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
        }
        for h in handles {
            h.join().unwrap();
        }
        let dump = c.drain_incremental();
        w.append(&dump).unwrap();
        w.finish().unwrap();

        let back = read_trace(dir.to_str().unwrap()).unwrap();
        assert_eq!(
            back.events.len() as u64,
            WRITERS * PER_WRITER,
            "every event exactly once despite concurrent writers and rotation"
        );
        let mut spans: Vec<u64> = back.events.iter().map(|(_, e)| e.span).collect();
        spans.sort_unstable();
        spans.dedup();
        assert_eq!(spans.len() as u64, WRITERS * PER_WRITER, "no duplicates");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn segment_dir_audits_identically_to_single_file() {
        // The same healthy stream written as (a) rotated segments and (b)
        // one file must produce byte-identical check verdicts.
        let events: Vec<(String, TraceEvent)> = vec![
            ("leader".into(), ev(10, 600, 1, "pop.slice", &[("trial", 0), ("slice", 0), ("ckpt", 7)])),
            ("leader".into(), {
                let mut e = ev(20, 100, 2, "pool.dispatch", &[("map_id", 0), ("tasks", 1)]);
                e.parent = 1;
                e
            }),
            ("w1".into(), {
                let mut e = ev(40, 200, 3, "pool.run", &[("worker", 1), ("index", 0)]);
                e.parent = 2;
                e
            }),
            ("leader".into(), ev(300, 150, 5, "ring.heal", &[("from_gen", 0), ("op_seq", 7), ("completed", 2)])),
            ("leader".into(), {
                let mut e = ev(440, 0, 6, "ring.resume", &[("op_seq", 7)]);
                e.parent = 5;
                e
            }),
        ];
        let dump = TraceDump {
            events: events.clone(),
            dropped: 0,
            crash: false,
        };
        let dir = tmpdir("parity");
        let mut w = SegmentWriter::new(&dir, 2).unwrap();
        w.append(&dump).unwrap();
        w.finish().unwrap();
        let single = std::env::temp_dir().join(format!(
            "fiber_live_parity_single_{}.jsonl",
            std::process::id()
        ));
        let single = single.to_str().unwrap().to_string();
        crate::trace::export::write_jsonl(&single, &dump).unwrap();

        let from_dir = read_trace(dir.to_str().unwrap()).unwrap();
        let from_file = read_trace(&single).unwrap();
        assert_eq!(from_dir.events, from_file.events);
        assert_eq!(from_dir.dropped, from_file.dropped);
        let rep_dir = check(&from_dir, "src");
        let rep_file = check(&from_file, "src");
        assert!(rep_dir.ok() && rep_file.ok(), "{}\n{}", rep_dir.render(), rep_file.render());
        assert_eq!(rep_dir.warnings.len(), rep_file.warnings.len());
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_file(&single);
    }

    #[test]
    fn health_flags_stragglers_against_rolling_p99() {
        // Flagging emits a trace.straggler instant into the global journal
        // when tracing is on — serialize with the other global-state tests.
        let _g = TEST_GUARD.lock().unwrap_or_else(|e| e.into_inner());
        let mut h = Health::new(3);
        let mut events: Vec<(String, TraceEvent)> = Vec::new();
        // 30 well-behaved ~10ms runs build the baseline…
        for i in 0..30u64 {
            events.push((
                format!("w{}", i % 3),
                ev(i * 1_000_000, 10_000_000 + (i % 5) * 100_000, 100 + i, "pool.run", &[]),
            ));
        }
        // …then one 60ms outlier (6× the baseline) on w2.
        events.push(("w2".into(), ev(40_000_000, 60_000_000, 999, "pool.run", &[])));
        h.observe(&events);
        let snap = h.snapshot();
        assert_eq!(snap.straggler_flags, 1, "exactly the outlier is flagged");
        assert_eq!(snap.recent_stragglers.len(), 1);
        let s = &snap.recent_stragglers[0];
        assert_eq!(s.node, "w2");
        assert_eq!(s.name, "pool.run");
        assert!(s.dur_ns > 3 * s.p99_ns);
        let w2 = snap.nodes.iter().find(|n| n.name == "w2").unwrap();
        assert_eq!(w2.stragglers, 1);
        let text = snap.render();
        assert!(text.contains("STRAGGLER pool.run on w2"), "{text}");
    }

    #[test]
    fn health_aggregates_all_layers_and_snapshot_roundtrips_wire() {
        let mut h = Health::new(3);
        h.observe(&[
            ("leader".into(), ev(10, 100, 1, "pool.run", &[])),
            ("leader".into(), ev(10, 0, 7, "pool.restart", &[])),
            ("w1".into(), ev(20, 500, 2, "ring.allreduce", &[("gen", 2), ("elems", 64)])),
            ("w1".into(), ev(25, 0, 3, "ring.chunk.send", &[("chunk", 3), ("step", 5)])),
            ("w1".into(), ev(30, 200, 4, "ring.heal", &[("from_gen", 2)])),
            ("w2".into(), ev(40, 0, 5, "store.hit", &[("obj", 9)])),
            ("w2".into(), ev(41, 90, 6, "store.fetch", &[("obj", 8)])),
            ("leader".into(), ev(50, 0, 8, "pop.score", &[("trial", 1), ("reward_milli", 812)])),
            ("leader".into(), ev(51, 0, 9, "pop.score", &[("trial", 2), ("reward_milli", 790)])),
            ("leader".into(), ev(52, 0, 10, "pop.score", &[("trial", 1), ("reward_milli", 700)])),
        ]);
        let snap = h.snapshot();
        assert_eq!(snap.nodes.len(), 3);
        assert_eq!(snap.pool_runs, 1);
        assert_eq!(snap.ring_ops, 1);
        assert_eq!(snap.ring_gen, 2);
        assert_eq!(snap.ring_chunks, 1);
        assert_eq!(snap.ring_last_chunk, 3);
        assert_eq!(snap.ring_last_step, 5);
        assert_eq!(snap.ring_heals, 1);
        assert_eq!(snap.store_hits, 1);
        assert_eq!(snap.store_fetches, 1);
        assert_eq!(snap.pop_best, vec![(1, 812), (2, 790)], "best per trial, desc");
        let bytes = wire::to_bytes(&snap);
        let back: HealthSnapshot = wire::from_bytes(&bytes).unwrap();
        assert_eq!(back.nodes, snap.nodes);
        assert_eq!(back.pop_best, snap.pop_best);
        assert_eq!(back.now_ns, snap.now_ns);
        let text = back.render();
        assert!(text.contains("POOL"), "{text}");
        assert!(text.contains("RING"), "{text}");
        assert!(text.contains("STORE"), "{text}");
        assert!(text.contains("trial 1: 0.812"), "{text}");
    }

    #[test]
    fn streamer_streams_journal_to_segments_and_serves_top() {
        let journal = Journal::with_capacity(1 << 12);
        journal.set_node_name("leader");
        let dir = tmpdir("streamer");
        let mut c = Collector::new();
        c.add_local(journal.clone());
        let mut cfg = StreamerConfig::to_dir(&dir);
        cfg.interval = Duration::from_millis(10);
        cfg.serve = Some("127.0.0.1:0".into());
        let metrics_path = dir.join("metrics.prom");
        cfg.metrics_file = Some(metrics_path.to_string_lossy().into_owned());
        let s = Streamer::start(c, cfg).unwrap();
        let addr = s._server.as_ref().unwrap().local_addr();
        for i in 0..50u64 {
            journal.record(ev(i * 1000, 100, i + 1, "pool.run", &[]));
        }
        std::thread::sleep(Duration::from_millis(60));
        // Mid-run: segments exist on disk and the RPC serves a snapshot.
        let live = fetch_snapshot(addr).unwrap();
        assert!(live.pool_runs > 0, "telemetry visible while running");
        journal.record(ev(100_000, 100, 777, "pool.run", &[]));
        let snap = s.stop().unwrap();
        assert_eq!(snap.pool_runs, 51, "final drain catches the tail");
        let back = read_trace(dir.to_str().unwrap()).unwrap();
        assert_eq!(back.events.len(), 51);
        assert!(metrics_path.exists(), "prometheus snapshot refreshed");
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn crash_dump_writes_marked_window_that_passes_check() {
        let _g = TEST_GUARD.lock().unwrap_or_else(|e| e.into_inner());
        let dir = tmpdir("crash");
        std::fs::create_dir_all(&dir).unwrap();
        set_crash_dir(&dir);
        crate::trace::set_flight_enabled(true);
        let span_id;
        {
            let span = crate::trace::Span::begin_detached("pool.run", 0);
            span_id = span.id();
            // The "panicking" context: current span set via with_span.
            crate::trace::with_span(span_id, || {
                crate::trace::instant("test.live.mark", &[("v", 1)]);
                let p = crash_dump_now("test fatal").expect("dump written");
                assert!(p.exists());
            });
            drop(span);
        }
        crate::trace::set_flight_enabled(false);
        let path = dir.join(format!("fiber-crash-{}.jsonl", std::process::id()));
        let dump = read_trace(path.to_str().unwrap()).unwrap();
        assert!(dump.crash, "crash marker in the footer");
        assert!(dump.dropped >= 1, "crash windows are lossy by construction");
        let crash_ev = dump
            .events
            .iter()
            .find(|(_, e)| e.name == "trace.crash")
            .expect("panicking span marked");
        assert_eq!(crash_ev.1.parent, span_id, "crash instant names the open span");
        let rep = check(&dump, "crash.jsonl");
        assert!(rep.ok(), "{}", rep.render());
        // set_crash_dir is global state: point it back at a harmless temp
        // default for any later test in this process.
        set_crash_dir(&std::env::temp_dir());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
