//! `fiber::trace` — causally-linked event tracing across the four building
//! blocks (Pool, ring, store, pop).
//!
//! The [`metrics`](crate::metrics) registry answers *how much* (counts,
//! latency quantiles); this module answers *what happened, in what order,
//! and because of what*. Every instrumented site records a [`TraceEvent`]
//! into a per-node bounded [`Journal`]: a **span** (an interval with a
//! duration) or an **instant** (a point event), each carrying a span id
//! and a *parent* span id. Parent links are how causality crosses layers
//! and machines: a PBT slice's span parents the worker-side run span
//! (the id rides the Pool task envelope), the run span parents the store
//! checkpoint fetch it triggers, and a ring heal span parents the resume
//! event of the collective it interrupted.
//!
//! Design points, in the order the issue demands them:
//!
//! * **Near-zero cost when disabled.** Every site starts with a single
//!   relaxed atomic load ([`enabled`]); when it is false no allocation,
//!   no lock, and no timestamp is taken. Tracing is off by default and
//!   switched on by `--trace` (or [`set_enabled`]).
//! * **Lossy under pressure.** A [`Journal`] holds a bounded deque; when
//!   full, new events are counted in an explicit `dropped` counter rather
//!   than blocking the hot path or growing without bound.
//! * **Aggregation.** A leader-side [`collect::Collector`] drains journals
//!   — in-process via `Arc` sharing, remote over [`crate::comms::rpc`]
//!   with RPC-midpoint clock-offset alignment — into one leader-clock
//!   timeline.
//! * **Export.** [`export`] renders Chrome trace-event JSON (loadable in
//!   Perfetto / `chrome://tracing`) and a replayable JSONL stream
//!   (documented in `docs/trace_schema.md`) — the record side of the
//!   ROADMAP's trace-driven cluster-simulation item.
//! * **Live streaming & crash forensics.** Journals support cursor-based
//!   incremental drains ([`Journal::drain_since`], at-least-once); the
//!   [`live`] module streams those deltas to rotating on-disk JSONL
//!   segments during the run, aggregates them into an online [`live::Health`]
//!   model (behind `fiber-cli top`), and keeps a bounded [`FlightRecorder`]
//!   ring whose last window is dumped to `fiber-crash-<pid>.jsonl` on
//!   panic or fatal error.
//! * **Audit, analytics, replay.** [`check`] is the causal invariant
//!   engine behind `fiber-cli trace-check`; [`analyze`] extracts the
//!   critical path, per-node busy/idle series and folded flamegraph
//!   stacks; [`replay`] re-drives scenario-composed chaos schedules
//!   against [`crate::cluster::simk8s`] pods on the virtual clock and
//!   emits a fresh trace that must itself pass [`check`].
//!
//! Span durations are also fed into [`crate::metrics::latency`] under the
//! span name, so `metrics::dump()` stays the cheap aggregate view of the
//! same instrumentation.

pub mod analyze;
pub mod check;
pub mod collect;
pub mod export;
pub mod live;
pub mod replay;

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, AtomicU8, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use once_cell::sync::Lazy;

use crate::wire::{self, Decode, Encode};

/// Journal bit of [`MODE`]: events go to the process-global [`Journal`]
/// (what `--trace` and the live streamer drain).
const MODE_JOURNAL: u8 = 1;
/// Flight bit of [`MODE`]: events also land in the bounded in-memory
/// [`FlightRecorder`] ring, dumped on panic/fatal error.
const MODE_FLIGHT: u8 = 2;

/// Master switch, as a bitset so the journal pipeline and the flight
/// recorder toggle independently. All bits off by default; every
/// instrumented site checks this with one relaxed atomic load before
/// doing any other work.
static MODE: AtomicU8 = AtomicU8::new(0);

/// Is any tracing sink enabled? This is the per-site fast-path check.
#[inline(always)]
pub fn enabled() -> bool {
    MODE.load(Ordering::Relaxed) != 0
}

/// Turn journal tracing on or off process-wide (the `--trace` pipeline).
pub fn set_enabled(on: bool) {
    if on {
        MODE.fetch_or(MODE_JOURNAL, Ordering::Relaxed);
    } else {
        MODE.fetch_and(!MODE_JOURNAL, Ordering::Relaxed);
    }
}

/// Is the journal sink enabled (as opposed to flight-recorder-only)?
pub fn journal_enabled() -> bool {
    MODE.load(Ordering::Relaxed) & MODE_JOURNAL != 0
}

/// Turn the always-on crash flight recorder on or off. Independent of
/// [`set_enabled`]: a run with no `--trace` can still keep the last few
/// thousand events in memory for a crash dump.
pub fn set_flight_enabled(on: bool) {
    if on {
        MODE.fetch_or(MODE_FLIGHT, Ordering::Relaxed);
    } else {
        MODE.fetch_and(!MODE_FLIGHT, Ordering::Relaxed);
    }
}

/// Is the flight recorder capturing events?
pub fn flight_enabled() -> bool {
    MODE.load(Ordering::Relaxed) & MODE_FLIGHT != 0
}

/// Route one finished event to whichever sinks are enabled. Clones only
/// when both sinks want it.
fn record_event(ev: TraceEvent) {
    let mode = MODE.load(Ordering::Relaxed);
    match (mode & MODE_JOURNAL != 0, mode & MODE_FLIGHT != 0) {
        (true, true) => {
            flight().record(ev.clone());
            global().record(ev);
        }
        (true, false) => global().record(ev),
        (false, true) => flight().record(ev),
        (false, false) => {}
    }
}

/// Span-id allocator. Seeded with (the low 20 bits of) the OS pid in bits
/// 32..52 so ids from different worker processes cannot collide when a
/// [`collect::Collector`] merges their journals, while every id stays
/// below 2^53 — exactly representable as a JSON number, so span/parent
/// links survive the Chrome/JSONL exporters bit-for-bit.
static NEXT_SPAN: Lazy<AtomicU64> =
    Lazy::new(|| AtomicU64::new((((std::process::id() as u64) & 0xF_FFFF) << 32) | 1));

/// Allocate a fresh process-unique (and, via the pid bits, cluster-unique)
/// span id. 0 is reserved for "no span".
pub fn fresh_span_id() -> u64 {
    NEXT_SPAN.fetch_add(1, Ordering::Relaxed)
}

/// Compact per-thread lane ids for the exporters (Chrome `tid`). Assigned
/// lazily on a thread's first recorded event.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static THREAD_TID: Cell<u32> = const { Cell::new(0) };
    /// Stack of span ids active on this thread; the top is the causal
    /// parent for any event recorded here ([`current_span`]).
    static SPAN_STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

pub(crate) fn thread_tid() -> u32 {
    THREAD_TID.with(|t| {
        let mut id = t.get();
        if id == 0 {
            id = NEXT_TID.fetch_add(1, Ordering::Relaxed) as u32;
            t.set(id);
        }
        id
    })
}

/// The span id events on this thread parent under (0 = no active span).
pub fn current_span() -> u64 {
    SPAN_STACK.with(|s| s.borrow().last().copied().unwrap_or(0))
}

fn stack_push(id: u64) {
    SPAN_STACK.with(|s| s.borrow_mut().push(id));
}

/// Remove `id` from this thread's stack wherever it is (defensive: guards
/// dropped out of order must not corrupt an unrelated span's parentage).
fn stack_remove(id: u64) {
    SPAN_STACK.with(|s| {
        let mut v = s.borrow_mut();
        if let Some(pos) = v.iter().rposition(|&x| x == id) {
            v.remove(pos);
        }
    });
}

/// Run `f` with `span` as this thread's current span, so every event `f`
/// records parents under it. This is how a causal id crosses an API
/// boundary without threading it through every signature — e.g. the pop
/// runner wraps its Pool submission so the task envelope captures the
/// slice span.
pub fn with_span<R>(span: u64, f: impl FnOnce() -> R) -> R {
    if span == 0 {
        return f();
    }
    stack_push(span);
    let r = f();
    stack_remove(span);
    r
}

/// One recorded event. `dur_ns == 0` marks an instant (point event);
/// otherwise the event is a completed span starting at `ts_ns`.
///
/// Timestamps are nanoseconds on the recording journal's monotonic clock
/// (its creation `Instant`); the [`collect::Collector`] re-bases remote
/// timestamps onto the leader's clock before export.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub ts_ns: u64,
    pub dur_ns: u64,
    /// This event's own span id (instants get a fresh id too, so they are
    /// addressable as causes).
    pub span: u64,
    /// Causal parent span id (0 = root).
    pub parent: u64,
    /// Recording thread's compact lane id (exporter `tid`).
    pub tid: u32,
    /// Span kind, dot-namespaced by layer: `pool.run`, `ring.heal`,
    /// `store.fetch`, `pop.slice`, …
    pub name: String,
    /// Small typed payload: named integer arguments (ranks, generations,
    /// op sequence numbers, byte counts, trial ids).
    pub args: Vec<(String, i64)>,
}

impl TraceEvent {
    /// Look up an argument by name.
    pub fn arg(&self, name: &str) -> Option<i64> {
        self.args.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }
}

impl Encode for TraceEvent {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.ts_ns.encode(buf);
        self.dur_ns.encode(buf);
        self.span.encode(buf);
        self.parent.encode(buf);
        self.tid.encode(buf);
        self.name.encode(buf);
        self.args.encode(buf);
    }
}

impl Decode for TraceEvent {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        Ok(TraceEvent {
            ts_ns: u64::decode(r)?,
            dur_ns: u64::decode(r)?,
            span: u64::decode(r)?,
            parent: u64::decode(r)?,
            tid: u32::decode(r)?,
            name: String::decode(r)?,
            args: Vec::<(String, i64)>::decode(r)?,
        })
    }
}

struct JournalInner {
    events: VecDeque<TraceEvent>,
    dropped: u64,
    /// Sequence number of `events[0]`; buffered events are contiguous in
    /// sequence, so `events[i]` has sequence `first_seq + i`.
    first_seq: u64,
    /// Sequence the *next* recorded event will get (== `first_seq +
    /// events.len()`; dropped events consume no sequence number).
    next_seq: u64,
}

/// A bounded per-node event buffer. Recording is one mutex push; when the
/// buffer is full the event is dropped and counted — the tracing layer
/// must never stall a collective or a task to preserve its own data.
pub struct Journal {
    node: Mutex<String>,
    epoch: Instant,
    cap: usize,
    inner: Mutex<JournalInner>,
}

fn unpoison<T>(r: Result<MutexGuard<'_, T>, std::sync::PoisonError<MutexGuard<'_, T>>>) -> MutexGuard<'_, T> {
    r.unwrap_or_else(|e| e.into_inner())
}

impl Journal {
    /// A journal holding at most `cap` events.
    pub fn with_capacity(cap: usize) -> Arc<Journal> {
        Arc::new(Journal {
            node: Mutex::new(format!("pid-{}", std::process::id())),
            epoch: Instant::now(),
            cap: cap.max(1),
            inner: Mutex::new(JournalInner {
                events: VecDeque::new(),
                dropped: 0,
                first_seq: 0,
                next_seq: 0,
            }),
        })
    }

    /// Nanoseconds since this journal's epoch (monotonic).
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// The node label stamped on drained events (defaults to `pid-<pid>`).
    pub fn node_name(&self) -> String {
        unpoison(self.node.lock()).clone()
    }

    pub fn set_node_name(&self, name: &str) {
        *unpoison(self.node.lock()) = name.to_string();
    }

    /// Append an event; lossy when full.
    pub fn record(&self, ev: TraceEvent) {
        let mut inner = unpoison(self.inner.lock());
        if inner.events.len() >= self.cap {
            inner.dropped += 1;
        } else {
            inner.events.push_back(ev);
            inner.next_seq += 1;
        }
    }

    /// Events currently buffered.
    pub fn len(&self) -> usize {
        unpoison(self.inner.lock()).events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events dropped because the buffer was full.
    pub fn dropped(&self) -> u64 {
        unpoison(self.inner.lock()).dropped
    }

    /// Take every buffered event (and the running dropped count). The
    /// journal keeps recording; drain is incremental by construction.
    pub fn drain(&self) -> (Vec<TraceEvent>, u64) {
        let mut inner = unpoison(self.inner.lock());
        inner.first_seq = inner.next_seq;
        (inner.events.drain(..).collect(), inner.dropped)
    }

    /// Cursor-based incremental drain with *at-least-once* delivery.
    ///
    /// `cursor` acknowledges everything the caller has durably consumed:
    /// events with sequence `< cursor` are freed, then every still-buffered
    /// event is **cloned** (not removed) and returned together with the
    /// next cursor (pass it back on the next call) and the running dropped
    /// count. Because events are only freed once a *later* call's cursor
    /// acknowledges them, a lost reply (crashed collector, dropped RPC)
    /// re-delivers the same window instead of losing it; the collector's
    /// unchanged cursor also means it never double-processes. A cursor
    /// older than `first_seq` (e.g. after a destructive [`Journal::drain`])
    /// is clamped, never an error.
    pub fn drain_since(&self, cursor: u64) -> (Vec<TraceEvent>, u64, u64) {
        let mut inner = unpoison(self.inner.lock());
        let first = inner.first_seq;
        if cursor > first {
            let ack = (cursor - first).min(inner.events.len() as u64);
            inner.events.drain(..ack as usize);
            inner.first_seq = first + ack;
        }
        let out: Vec<TraceEvent> = inner.events.iter().cloned().collect();
        (out, inner.next_seq, inner.dropped)
    }

    /// Sequence number the next recorded event will receive (test and
    /// diagnostics hook; the cursor returned by an up-to-date
    /// [`Journal::drain_since`] equals this).
    pub fn next_seq(&self) -> u64 {
        unpoison(self.inner.lock()).next_seq
    }
}

/// A fixed-size drop-*oldest* ring of the most recent events — the crash
/// flight recorder. Unlike the [`Journal`] (which drops *new* events when
/// full so the stream stays contiguous for the collector), the flight ring
/// always holds the latest window: exactly what you want seconds before a
/// panic. Dumped by [`live::crash_dump_now`] / the panic hook installed by
/// [`live::install_crash_hook`].
pub struct FlightRecorder {
    cap: usize,
    inner: Mutex<FlightInner>,
}

struct FlightInner {
    events: VecDeque<TraceEvent>,
    overwritten: u64,
}

impl FlightRecorder {
    pub fn with_capacity(cap: usize) -> FlightRecorder {
        FlightRecorder {
            cap: cap.max(1),
            inner: Mutex::new(FlightInner {
                events: VecDeque::new(),
                overwritten: 0,
            }),
        }
    }

    /// Append, evicting the oldest event when full.
    pub fn record(&self, ev: TraceEvent) {
        let mut inner = unpoison(self.inner.lock());
        if inner.events.len() >= self.cap {
            inner.events.pop_front();
            inner.overwritten += 1;
        }
        inner.events.push_back(ev);
    }

    /// Non-destructive copy of the current window plus the count of events
    /// that have already rolled off it.
    pub fn snapshot(&self) -> (Vec<TraceEvent>, u64) {
        let inner = unpoison(self.inner.lock());
        (inner.events.iter().cloned().collect(), inner.overwritten)
    }

    pub fn len(&self) -> usize {
        unpoison(self.inner.lock()).events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Default flight-recorder window: recent-history, not whole-run, sized.
pub const FLIGHT_CAP: usize = 4096;

static FLIGHT: Lazy<FlightRecorder> = Lazy::new(|| FlightRecorder::with_capacity(FLIGHT_CAP));

/// The process-global flight recorder (shares the global journal's clock:
/// flight events carry [`Journal::now_ns`] timestamps from [`global`]).
pub fn flight() -> &'static FlightRecorder {
    &FLIGHT
}

/// The process-global journal every instrumented site records into.
/// Default capacity: 64Ki events (a chaos demo run is a few thousand).
static GLOBAL: Lazy<Arc<Journal>> = Lazy::new(|| Journal::with_capacity(1 << 16));

/// The process-global journal (what `--trace` drains and exports).
pub fn global() -> Arc<Journal> {
    GLOBAL.clone()
}

/// Record an instant (point) event under this thread's current span.
pub fn instant(name: &'static str, args: &[(&str, i64)]) {
    if !enabled() {
        return;
    }
    instant_under(name, current_span(), args);
}

/// Record an instant event under an explicit parent span — how lifecycle
/// events are pinned to a span that lives across scopes (a ring resume
/// event under the heal span that made it necessary).
pub fn instant_under(name: &'static str, parent: u64, args: &[(&str, i64)]) {
    if !enabled() {
        return;
    }
    record_event(TraceEvent {
        ts_ns: global().now_ns(),
        dur_ns: 0,
        span: fresh_span_id(),
        parent,
        tid: thread_tid(),
        name: name.to_string(),
        args: args.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
    });
}

/// A RAII span: created at a site, recorded (with duration) on drop. A
/// disabled-trace span is inert — construction is the single relaxed
/// atomic check, and drop is one branch on a plain field.
pub struct Span {
    id: u64, // 0 = tracing was disabled at begin
    parent: u64,
    start_ns: u64,
    name: &'static str,
    args: Vec<(String, i64)>,
    on_stack: bool,
}

impl Span {
    /// Begin a span parented under this thread's current span, and make it
    /// the current span until dropped (on this thread).
    pub fn begin(name: &'static str) -> Span {
        if !enabled() {
            return Span::inert(name);
        }
        Span::begin_child(name, current_span())
    }

    /// Begin a span under an explicit parent (a span id that arrived over
    /// the wire, e.g. from a Pool task envelope). Current-span scoped like
    /// [`Span::begin`].
    pub fn begin_child(name: &'static str, parent: u64) -> Span {
        if !enabled() {
            return Span::inert(name);
        }
        let id = fresh_span_id();
        stack_push(id);
        Span {
            id,
            parent,
            start_ns: global().now_ns(),
            name,
            args: Vec::new(),
            on_stack: true,
        }
    }

    /// Begin a span **not** tied to this thread's span stack, so it can be
    /// stored in a table and ended on a different thread (a pop slice span
    /// begun at dispatch and ended at completion).
    pub fn begin_detached(name: &'static str, parent: u64) -> Span {
        if !enabled() {
            return Span::inert(name);
        }
        Span {
            id: fresh_span_id(),
            parent,
            start_ns: global().now_ns(),
            name,
            args: Vec::new(),
            on_stack: false,
        }
    }

    fn inert(name: &'static str) -> Span {
        Span {
            id: 0,
            parent: 0,
            start_ns: 0,
            name,
            args: Vec::new(),
            on_stack: false,
        }
    }

    /// This span's id (0 when tracing was disabled at begin) — what gets
    /// piggybacked on envelopes so remote work can parent under it.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Attach a named integer argument (builder style).
    pub fn arg(mut self, key: &str, value: i64) -> Span {
        self.add_arg(key, value);
        self
    }

    /// Attach a named integer argument.
    pub fn add_arg(&mut self, key: &str, value: i64) {
        if self.id != 0 {
            self.args.push((key.to_string(), value));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.id == 0 {
            return;
        }
        if self.on_stack {
            stack_remove(self.id);
        }
        let dur_ns = global().now_ns().saturating_sub(self.start_ns);
        record_event(TraceEvent {
            ts_ns: self.start_ns,
            dur_ns: dur_ns.max(1), // a span is never an instant
            span: self.id,
            parent: self.parent,
            tid: thread_tid(),
            name: self.name.to_string(),
            args: std::mem::take(&mut self.args),
        });
        // The aggregate view rides the same instrumentation.
        crate::metrics::latency(self.name).record_ns(dur_ns.max(1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trace unit tests mutate the process-global enabled flag and
    /// journal; serialize them so parallel test threads cannot interleave.
    pub(crate) static TEST_GUARD: Mutex<()> = Mutex::new(());

    #[test]
    fn journal_is_bounded_and_counts_drops() {
        let j = Journal::with_capacity(2);
        for i in 0..5 {
            j.record(TraceEvent {
                ts_ns: i,
                dur_ns: 0,
                span: i,
                parent: 0,
                tid: 1,
                name: "x".into(),
                args: vec![],
            });
        }
        assert_eq!(j.len(), 2);
        assert_eq!(j.dropped(), 3);
        let (evs, dropped) = j.drain();
        assert_eq!(evs.len(), 2);
        assert_eq!(dropped, 3);
        assert!(j.is_empty());
    }

    fn ev(i: u64) -> TraceEvent {
        TraceEvent {
            ts_ns: i,
            dur_ns: 0,
            span: i + 1,
            parent: 0,
            tid: 1,
            name: "x".into(),
            args: vec![],
        }
    }

    #[test]
    fn drain_since_redelivers_until_acked() {
        let j = Journal::with_capacity(16);
        for i in 0..3 {
            j.record(ev(i));
        }
        // First pull: everything, cursor advances to 3, nothing freed yet.
        let (evs, cur, dropped) = j.drain_since(0);
        assert_eq!(evs.len(), 3);
        assert_eq!(cur, 3);
        assert_eq!(dropped, 0);
        assert_eq!(j.len(), 3, "at-least-once: events freed only on ack");
        // A retry with the *old* cursor (lost reply) re-delivers the same
        // window — no loss.
        let (again, cur2, _) = j.drain_since(0);
        assert_eq!(again.len(), 3);
        assert_eq!(cur2, 3);
        // Acking with the advanced cursor frees the prefix and returns
        // only what arrived since.
        j.record(ev(3));
        let (fresh, cur3, _) = j.drain_since(cur);
        assert_eq!(fresh.len(), 1);
        assert_eq!(fresh[0].ts_ns, 3);
        assert_eq!(cur3, 4);
        assert_eq!(j.len(), 1);
        // Empty steady state.
        let (none, cur4, _) = j.drain_since(cur3);
        assert!(none.is_empty());
        assert_eq!(cur4, 4);
        assert!(j.is_empty());
    }

    #[test]
    fn drain_since_cursor_clamps_after_destructive_drain() {
        let j = Journal::with_capacity(16);
        for i in 0..4 {
            j.record(ev(i));
        }
        let (_, cur, _) = j.drain_since(0);
        assert_eq!(cur, 4);
        j.drain(); // destructive full drain advances first_seq to next_seq
        j.record(ev(4));
        // Stale and future-less cursors both resolve to the live window.
        let (evs, cur2, _) = j.drain_since(0);
        assert_eq!(evs.len(), 1);
        assert_eq!(cur2, 5);
        let (evs2, cur3, _) = j.drain_since(cur);
        assert_eq!(evs2.len(), 1);
        assert_eq!(cur3, 5);
    }

    #[test]
    fn flight_ring_keeps_latest_window() {
        let f = FlightRecorder::with_capacity(3);
        for i in 0..7 {
            f.record(ev(i));
        }
        let (evs, overwritten) = f.snapshot();
        assert_eq!(evs.len(), 3);
        assert_eq!(overwritten, 4);
        // Drop-oldest: the window is the *last* three events.
        assert_eq!(evs.iter().map(|e| e.ts_ns).collect::<Vec<_>>(), vec![4, 5, 6]);
        // Snapshot is non-destructive.
        assert_eq!(f.len(), 3);
    }

    #[test]
    fn flight_mode_records_without_journal() {
        let _g = TEST_GUARD.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        set_flight_enabled(true);
        let journal_before = global().len();
        let flight_before = flight().len();
        {
            let _s = Span::begin("test.trace.flightonly").arg("k", 1);
            instant("test.trace.flightonly.i", &[]);
        }
        set_flight_enabled(false);
        assert_eq!(global().len(), journal_before, "journal off: nothing lands there");
        assert_eq!(flight().len(), flight_before + 2, "flight ring got span + instant");
    }

    #[test]
    fn event_roundtrips_wire() {
        let ev = TraceEvent {
            ts_ns: 123,
            dur_ns: 456,
            span: 7,
            parent: 3,
            tid: 2,
            name: "ring.heal".into(),
            args: vec![("gen".into(), 4), ("rank".into(), -1)],
        };
        let bytes = wire::to_bytes(&ev);
        let back: TraceEvent = wire::from_bytes(&bytes).unwrap();
        assert_eq!(ev, back);
        assert_eq!(back.arg("gen"), Some(4));
        assert_eq!(back.arg("nope"), None);
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = TEST_GUARD.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        let before = global().len();
        {
            let _s = Span::begin("test.trace.off").arg("k", 1);
            instant("test.trace.off.i", &[("a", 2)]);
        }
        assert_eq!(global().len(), before, "disabled tracing must not record");
    }

    #[test]
    fn spans_nest_and_parent_causally() {
        let _g = TEST_GUARD.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        global().drain();
        let outer_id;
        {
            let outer = Span::begin("test.trace.outer");
            outer_id = outer.id();
            assert_ne!(outer_id, 0);
            assert_eq!(current_span(), outer_id);
            {
                let inner = Span::begin("test.trace.inner");
                assert_eq!(current_span(), inner.id());
                instant("test.trace.mark", &[("v", 9)]);
            }
            assert_eq!(current_span(), outer_id);
        }
        set_enabled(false);
        let (evs, _) = global().drain();
        let outer = evs.iter().find(|e| e.name == "test.trace.outer").unwrap();
        let inner = evs.iter().find(|e| e.name == "test.trace.inner").unwrap();
        let mark = evs.iter().find(|e| e.name == "test.trace.mark").unwrap();
        assert_eq!(inner.parent, outer.span);
        assert_eq!(mark.parent, inner.span);
        assert_eq!(outer.span, outer_id);
        assert!(inner.dur_ns >= 1);
        assert_eq!(mark.dur_ns, 0);
    }

    #[test]
    fn with_span_sets_ambient_parent_and_detached_ends_anywhere() {
        let _g = TEST_GUARD.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        global().drain();
        let detached = Span::begin_detached("test.trace.detached", 0);
        let id = detached.id();
        with_span(id, || {
            instant("test.trace.under", &[]);
            assert_eq!(current_span(), id);
        });
        assert_eq!(current_span(), 0);
        // End the detached span on another thread.
        std::thread::spawn(move || drop(detached)).join().unwrap();
        set_enabled(false);
        let (evs, _) = global().drain();
        let under = evs.iter().find(|e| e.name == "test.trace.under").unwrap();
        assert_eq!(under.parent, id);
        assert!(evs.iter().any(|e| e.name == "test.trace.detached" && e.span == id));
    }
}
