//! `fiber::trace` — causally-linked event tracing across the four building
//! blocks (Pool, ring, store, pop).
//!
//! The [`metrics`](crate::metrics) registry answers *how much* (counts,
//! latency quantiles); this module answers *what happened, in what order,
//! and because of what*. Every instrumented site records a [`TraceEvent`]
//! into a per-node bounded [`Journal`]: a **span** (an interval with a
//! duration) or an **instant** (a point event), each carrying a span id
//! and a *parent* span id. Parent links are how causality crosses layers
//! and machines: a PBT slice's span parents the worker-side run span
//! (the id rides the Pool task envelope), the run span parents the store
//! checkpoint fetch it triggers, and a ring heal span parents the resume
//! event of the collective it interrupted.
//!
//! Design points, in the order the issue demands them:
//!
//! * **Near-zero cost when disabled.** Every site starts with a single
//!   relaxed atomic load ([`enabled`]); when it is false no allocation,
//!   no lock, and no timestamp is taken. Tracing is off by default and
//!   switched on by `--trace` (or [`set_enabled`]).
//! * **Lossy under pressure.** A [`Journal`] holds a bounded deque; when
//!   full, new events are counted in an explicit `dropped` counter rather
//!   than blocking the hot path or growing without bound.
//! * **Aggregation.** A leader-side [`collect::Collector`] drains journals
//!   — in-process via `Arc` sharing, remote over [`crate::comms::rpc`]
//!   with RPC-midpoint clock-offset alignment — into one leader-clock
//!   timeline.
//! * **Export.** [`export`] renders Chrome trace-event JSON (loadable in
//!   Perfetto / `chrome://tracing`) and a replayable JSONL stream
//!   (documented in `docs/trace_schema.md`) — the record side of the
//!   ROADMAP's trace-driven cluster-simulation item.
//! * **Audit, analytics, replay.** [`check`] is the causal invariant
//!   engine behind `fiber-cli trace-check`; [`analyze`] extracts the
//!   critical path, per-node busy/idle series and folded flamegraph
//!   stacks; [`replay`] re-drives scenario-composed chaos schedules
//!   against [`crate::cluster::simk8s`] pods on the virtual clock and
//!   emits a fresh trace that must itself pass [`check`].
//!
//! Span durations are also fed into [`crate::metrics::latency`] under the
//! span name, so `metrics::dump()` stays the cheap aggregate view of the
//! same instrumentation.

pub mod analyze;
pub mod check;
pub mod collect;
pub mod export;
pub mod replay;

use std::cell::{Cell, RefCell};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

use once_cell::sync::Lazy;

use crate::wire::{self, Decode, Encode};

/// Master switch. Off by default; every instrumented site checks this with
/// one relaxed atomic load before doing any other work.
static ENABLED: AtomicBool = AtomicBool::new(false);

/// Is tracing globally enabled? This is the per-site fast-path check.
#[inline(always)]
pub fn enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// Turn tracing on or off process-wide.
pub fn set_enabled(on: bool) {
    ENABLED.store(on, Ordering::Relaxed);
}

/// Span-id allocator. Seeded with (the low 20 bits of) the OS pid in bits
/// 32..52 so ids from different worker processes cannot collide when a
/// [`collect::Collector`] merges their journals, while every id stays
/// below 2^53 — exactly representable as a JSON number, so span/parent
/// links survive the Chrome/JSONL exporters bit-for-bit.
static NEXT_SPAN: Lazy<AtomicU64> =
    Lazy::new(|| AtomicU64::new((((std::process::id() as u64) & 0xF_FFFF) << 32) | 1));

/// Allocate a fresh process-unique (and, via the pid bits, cluster-unique)
/// span id. 0 is reserved for "no span".
pub fn fresh_span_id() -> u64 {
    NEXT_SPAN.fetch_add(1, Ordering::Relaxed)
}

/// Compact per-thread lane ids for the exporters (Chrome `tid`). Assigned
/// lazily on a thread's first recorded event.
static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static THREAD_TID: Cell<u32> = const { Cell::new(0) };
    /// Stack of span ids active on this thread; the top is the causal
    /// parent for any event recorded here ([`current_span`]).
    static SPAN_STACK: RefCell<Vec<u64>> = const { RefCell::new(Vec::new()) };
}

fn thread_tid() -> u32 {
    THREAD_TID.with(|t| {
        let mut id = t.get();
        if id == 0 {
            id = NEXT_TID.fetch_add(1, Ordering::Relaxed) as u32;
            t.set(id);
        }
        id
    })
}

/// The span id events on this thread parent under (0 = no active span).
pub fn current_span() -> u64 {
    SPAN_STACK.with(|s| s.borrow().last().copied().unwrap_or(0))
}

fn stack_push(id: u64) {
    SPAN_STACK.with(|s| s.borrow_mut().push(id));
}

/// Remove `id` from this thread's stack wherever it is (defensive: guards
/// dropped out of order must not corrupt an unrelated span's parentage).
fn stack_remove(id: u64) {
    SPAN_STACK.with(|s| {
        let mut v = s.borrow_mut();
        if let Some(pos) = v.iter().rposition(|&x| x == id) {
            v.remove(pos);
        }
    });
}

/// Run `f` with `span` as this thread's current span, so every event `f`
/// records parents under it. This is how a causal id crosses an API
/// boundary without threading it through every signature — e.g. the pop
/// runner wraps its Pool submission so the task envelope captures the
/// slice span.
pub fn with_span<R>(span: u64, f: impl FnOnce() -> R) -> R {
    if span == 0 {
        return f();
    }
    stack_push(span);
    let r = f();
    stack_remove(span);
    r
}

/// One recorded event. `dur_ns == 0` marks an instant (point event);
/// otherwise the event is a completed span starting at `ts_ns`.
///
/// Timestamps are nanoseconds on the recording journal's monotonic clock
/// (its creation `Instant`); the [`collect::Collector`] re-bases remote
/// timestamps onto the leader's clock before export.
#[derive(Clone, Debug, PartialEq)]
pub struct TraceEvent {
    pub ts_ns: u64,
    pub dur_ns: u64,
    /// This event's own span id (instants get a fresh id too, so they are
    /// addressable as causes).
    pub span: u64,
    /// Causal parent span id (0 = root).
    pub parent: u64,
    /// Recording thread's compact lane id (exporter `tid`).
    pub tid: u32,
    /// Span kind, dot-namespaced by layer: `pool.run`, `ring.heal`,
    /// `store.fetch`, `pop.slice`, …
    pub name: String,
    /// Small typed payload: named integer arguments (ranks, generations,
    /// op sequence numbers, byte counts, trial ids).
    pub args: Vec<(String, i64)>,
}

impl TraceEvent {
    /// Look up an argument by name.
    pub fn arg(&self, name: &str) -> Option<i64> {
        self.args.iter().find(|(k, _)| k == name).map(|(_, v)| *v)
    }
}

impl Encode for TraceEvent {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.ts_ns.encode(buf);
        self.dur_ns.encode(buf);
        self.span.encode(buf);
        self.parent.encode(buf);
        self.tid.encode(buf);
        self.name.encode(buf);
        self.args.encode(buf);
    }
}

impl Decode for TraceEvent {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        Ok(TraceEvent {
            ts_ns: u64::decode(r)?,
            dur_ns: u64::decode(r)?,
            span: u64::decode(r)?,
            parent: u64::decode(r)?,
            tid: u32::decode(r)?,
            name: String::decode(r)?,
            args: Vec::<(String, i64)>::decode(r)?,
        })
    }
}

struct JournalInner {
    events: VecDeque<TraceEvent>,
    dropped: u64,
}

/// A bounded per-node event buffer. Recording is one mutex push; when the
/// buffer is full the event is dropped and counted — the tracing layer
/// must never stall a collective or a task to preserve its own data.
pub struct Journal {
    node: Mutex<String>,
    epoch: Instant,
    cap: usize,
    inner: Mutex<JournalInner>,
}

fn unpoison<T>(r: Result<MutexGuard<'_, T>, std::sync::PoisonError<MutexGuard<'_, T>>>) -> MutexGuard<'_, T> {
    r.unwrap_or_else(|e| e.into_inner())
}

impl Journal {
    /// A journal holding at most `cap` events.
    pub fn with_capacity(cap: usize) -> Arc<Journal> {
        Arc::new(Journal {
            node: Mutex::new(format!("pid-{}", std::process::id())),
            epoch: Instant::now(),
            cap: cap.max(1),
            inner: Mutex::new(JournalInner {
                events: VecDeque::new(),
                dropped: 0,
            }),
        })
    }

    /// Nanoseconds since this journal's epoch (monotonic).
    pub fn now_ns(&self) -> u64 {
        self.epoch.elapsed().as_nanos() as u64
    }

    /// The node label stamped on drained events (defaults to `pid-<pid>`).
    pub fn node_name(&self) -> String {
        unpoison(self.node.lock()).clone()
    }

    pub fn set_node_name(&self, name: &str) {
        *unpoison(self.node.lock()) = name.to_string();
    }

    /// Append an event; lossy when full.
    pub fn record(&self, ev: TraceEvent) {
        let mut inner = unpoison(self.inner.lock());
        if inner.events.len() >= self.cap {
            inner.dropped += 1;
        } else {
            inner.events.push_back(ev);
        }
    }

    /// Events currently buffered.
    pub fn len(&self) -> usize {
        unpoison(self.inner.lock()).events.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Events dropped because the buffer was full.
    pub fn dropped(&self) -> u64 {
        unpoison(self.inner.lock()).dropped
    }

    /// Take every buffered event (and the running dropped count). The
    /// journal keeps recording; drain is incremental by construction.
    pub fn drain(&self) -> (Vec<TraceEvent>, u64) {
        let mut inner = unpoison(self.inner.lock());
        (inner.events.drain(..).collect(), inner.dropped)
    }
}

/// The process-global journal every instrumented site records into.
/// Default capacity: 64Ki events (a chaos demo run is a few thousand).
static GLOBAL: Lazy<Arc<Journal>> = Lazy::new(|| Journal::with_capacity(1 << 16));

/// The process-global journal (what `--trace` drains and exports).
pub fn global() -> Arc<Journal> {
    GLOBAL.clone()
}

/// Record an instant (point) event under this thread's current span.
pub fn instant(name: &'static str, args: &[(&str, i64)]) {
    if !enabled() {
        return;
    }
    instant_under(name, current_span(), args);
}

/// Record an instant event under an explicit parent span — how lifecycle
/// events are pinned to a span that lives across scopes (a ring resume
/// event under the heal span that made it necessary).
pub fn instant_under(name: &'static str, parent: u64, args: &[(&str, i64)]) {
    if !enabled() {
        return;
    }
    let j = global();
    j.record(TraceEvent {
        ts_ns: j.now_ns(),
        dur_ns: 0,
        span: fresh_span_id(),
        parent,
        tid: thread_tid(),
        name: name.to_string(),
        args: args.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
    });
}

/// A RAII span: created at a site, recorded (with duration) on drop. A
/// disabled-trace span is inert — construction is the single relaxed
/// atomic check, and drop is one branch on a plain field.
pub struct Span {
    id: u64, // 0 = tracing was disabled at begin
    parent: u64,
    start_ns: u64,
    name: &'static str,
    args: Vec<(String, i64)>,
    on_stack: bool,
}

impl Span {
    /// Begin a span parented under this thread's current span, and make it
    /// the current span until dropped (on this thread).
    pub fn begin(name: &'static str) -> Span {
        if !enabled() {
            return Span::inert(name);
        }
        Span::begin_child(name, current_span())
    }

    /// Begin a span under an explicit parent (a span id that arrived over
    /// the wire, e.g. from a Pool task envelope). Current-span scoped like
    /// [`Span::begin`].
    pub fn begin_child(name: &'static str, parent: u64) -> Span {
        if !enabled() {
            return Span::inert(name);
        }
        let id = fresh_span_id();
        stack_push(id);
        Span {
            id,
            parent,
            start_ns: global().now_ns(),
            name,
            args: Vec::new(),
            on_stack: true,
        }
    }

    /// Begin a span **not** tied to this thread's span stack, so it can be
    /// stored in a table and ended on a different thread (a pop slice span
    /// begun at dispatch and ended at completion).
    pub fn begin_detached(name: &'static str, parent: u64) -> Span {
        if !enabled() {
            return Span::inert(name);
        }
        Span {
            id: fresh_span_id(),
            parent,
            start_ns: global().now_ns(),
            name,
            args: Vec::new(),
            on_stack: false,
        }
    }

    fn inert(name: &'static str) -> Span {
        Span {
            id: 0,
            parent: 0,
            start_ns: 0,
            name,
            args: Vec::new(),
            on_stack: false,
        }
    }

    /// This span's id (0 when tracing was disabled at begin) — what gets
    /// piggybacked on envelopes so remote work can parent under it.
    pub fn id(&self) -> u64 {
        self.id
    }

    /// Attach a named integer argument (builder style).
    pub fn arg(mut self, key: &str, value: i64) -> Span {
        self.add_arg(key, value);
        self
    }

    /// Attach a named integer argument.
    pub fn add_arg(&mut self, key: &str, value: i64) {
        if self.id != 0 {
            self.args.push((key.to_string(), value));
        }
    }
}

impl Drop for Span {
    fn drop(&mut self) {
        if self.id == 0 {
            return;
        }
        if self.on_stack {
            stack_remove(self.id);
        }
        let j = global();
        let dur_ns = j.now_ns().saturating_sub(self.start_ns);
        j.record(TraceEvent {
            ts_ns: self.start_ns,
            dur_ns: dur_ns.max(1), // a span is never an instant
            span: self.id,
            parent: self.parent,
            tid: thread_tid(),
            name: self.name.to_string(),
            args: std::mem::take(&mut self.args),
        });
        // The aggregate view rides the same instrumentation.
        crate::metrics::latency(self.name).record_ns(dur_ns.max(1));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Trace unit tests mutate the process-global enabled flag and
    /// journal; serialize them so parallel test threads cannot interleave.
    pub(crate) static TEST_GUARD: Mutex<()> = Mutex::new(());

    #[test]
    fn journal_is_bounded_and_counts_drops() {
        let j = Journal::with_capacity(2);
        for i in 0..5 {
            j.record(TraceEvent {
                ts_ns: i,
                dur_ns: 0,
                span: i,
                parent: 0,
                tid: 1,
                name: "x".into(),
                args: vec![],
            });
        }
        assert_eq!(j.len(), 2);
        assert_eq!(j.dropped(), 3);
        let (evs, dropped) = j.drain();
        assert_eq!(evs.len(), 2);
        assert_eq!(dropped, 3);
        assert!(j.is_empty());
    }

    #[test]
    fn event_roundtrips_wire() {
        let ev = TraceEvent {
            ts_ns: 123,
            dur_ns: 456,
            span: 7,
            parent: 3,
            tid: 2,
            name: "ring.heal".into(),
            args: vec![("gen".into(), 4), ("rank".into(), -1)],
        };
        let bytes = wire::to_bytes(&ev);
        let back: TraceEvent = wire::from_bytes(&bytes).unwrap();
        assert_eq!(ev, back);
        assert_eq!(back.arg("gen"), Some(4));
        assert_eq!(back.arg("nope"), None);
    }

    #[test]
    fn disabled_spans_record_nothing() {
        let _g = TEST_GUARD.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(false);
        let before = global().len();
        {
            let _s = Span::begin("test.trace.off").arg("k", 1);
            instant("test.trace.off.i", &[("a", 2)]);
        }
        assert_eq!(global().len(), before, "disabled tracing must not record");
    }

    #[test]
    fn spans_nest_and_parent_causally() {
        let _g = TEST_GUARD.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        global().drain();
        let outer_id;
        {
            let outer = Span::begin("test.trace.outer");
            outer_id = outer.id();
            assert_ne!(outer_id, 0);
            assert_eq!(current_span(), outer_id);
            {
                let inner = Span::begin("test.trace.inner");
                assert_eq!(current_span(), inner.id());
                instant("test.trace.mark", &[("v", 9)]);
            }
            assert_eq!(current_span(), outer_id);
        }
        set_enabled(false);
        let (evs, _) = global().drain();
        let outer = evs.iter().find(|e| e.name == "test.trace.outer").unwrap();
        let inner = evs.iter().find(|e| e.name == "test.trace.inner").unwrap();
        let mark = evs.iter().find(|e| e.name == "test.trace.mark").unwrap();
        assert_eq!(inner.parent, outer.span);
        assert_eq!(mark.parent, inner.span);
        assert_eq!(outer.span, outer_id);
        assert!(inner.dur_ns >= 1);
        assert_eq!(mark.dur_ns, 0);
    }

    #[test]
    fn with_span_sets_ambient_parent_and_detached_ends_anywhere() {
        let _g = TEST_GUARD.lock().unwrap_or_else(|e| e.into_inner());
        set_enabled(true);
        global().drain();
        let detached = Span::begin_detached("test.trace.detached", 0);
        let id = detached.id();
        with_span(id, || {
            instant("test.trace.under", &[]);
            assert_eq!(current_span(), id);
        });
        assert_eq!(current_span(), 0);
        // End the detached span on another thread.
        std::thread::spawn(move || drop(detached)).join().unwrap();
        set_enabled(false);
        let (evs, _) = global().drain();
        let under = evs.iter().find(|e| e.name == "test.trace.under").unwrap();
        assert_eq!(under.parent, id);
        assert!(evs.iter().any(|e| e.name == "test.trace.detached" && e.span == id));
    }
}
