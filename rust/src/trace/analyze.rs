//! `fiber::trace::analyze` — critical-path extraction and latency
//! analytics over the causal DAG.
//!
//! [`check`](super::check) answers *is this trace consistent*; this module
//! answers *where did the time go*:
//!
//! * [`critical_path`] — walk the causal DAG from the longest root span
//!   down its latest-finishing child at every level: the chain of spans
//!   that bounded the run's wall time, with per-step **self time** (a
//!   step's duration minus its on-chain child's) and per-span-kind
//!   attribution. Shaving any other span cannot shorten the run.
//! * [`busy_idle`] — per-node interval union: how much of each node's
//!   observed window was covered by at least one span, and the longest
//!   idle gap (stragglers and stalls show up here at a glance).
//! * [`folded_stacks`] — the flamegraph interchange format: one
//!   `root;child;leaf <µs>` line per distinct causal stack, weighted by
//!   exclusive time ([`super::export::write_folded`] writes it to disk,
//!   ready for `flamegraph.pl` / speedscope).

use std::collections::HashMap;

use crate::benchkit::Table;

use super::collect::TraceDump;

/// Hard cap on parent-chain walks: a causal stack deeper than this is a
/// recorder bug (and possibly a cycle), not a real program shape.
const MAX_DEPTH: usize = 64;

/// One step on the critical path (root first).
#[derive(Clone, Debug)]
pub struct CriticalStep {
    /// Index into `dump.events`.
    pub index: usize,
    pub node: String,
    pub name: String,
    pub span: u64,
    pub start_ns: u64,
    pub dur_ns: u64,
    /// Time attributed to this step alone: its duration minus its
    /// on-chain child's (the part no deeper span explains).
    pub self_ns: u64,
}

/// The longest causal chain and its per-span-kind attribution.
#[derive(Clone, Debug)]
pub struct CriticalPath {
    /// Root → leaf.
    pub steps: Vec<CriticalStep>,
    /// Wall time of the chain's root span.
    pub total_ns: u64,
    /// Self time summed by span kind, largest first.
    pub by_kind: Vec<(String, u64)>,
}

fn span_index(dump: &TraceDump) -> HashMap<u64, usize> {
    let mut by_span = HashMap::new();
    for (i, (_, ev)) in dump.events.iter().enumerate() {
        by_span.entry(ev.span).or_insert(i);
    }
    by_span
}

fn children_index(dump: &TraceDump) -> HashMap<u64, Vec<usize>> {
    let mut children: HashMap<u64, Vec<usize>> = HashMap::new();
    for (i, (_, ev)) in dump.events.iter().enumerate() {
        if ev.parent != 0 {
            children.entry(ev.parent).or_default().push(i);
        }
    }
    children
}

/// Extract the critical path: start from the root span (no resolvable
/// parent) with the latest end time, then repeatedly descend into the
/// child that finishes last, until a span with no children remains.
/// Returns `None` on an empty dump.
pub fn critical_path(dump: &TraceDump) -> Option<CriticalPath> {
    let by_span = span_index(dump);
    let children = children_index(dump);
    let end = |i: usize| {
        let ev = &dump.events[i].1;
        ev.ts_ns.saturating_add(ev.dur_ns)
    };
    // Roots: events whose parent is absent from the dump (0 or dropped).
    let root = dump
        .events
        .iter()
        .enumerate()
        .filter(|(_, (_, ev))| ev.parent == 0 || !by_span.contains_key(&ev.parent))
        .map(|(i, _)| i)
        .max_by_key(|&i| end(i))?;

    let mut chain = vec![root];
    let mut cur = root;
    for _ in 0..MAX_DEPTH {
        let Some(kids) = children.get(&dump.events[cur].1.span) else {
            break;
        };
        // Latest-finishing child; ties break to the earlier event for
        // determinism (events are time-sorted).
        let Some(&next) = kids.iter().max_by_key(|&&i| (end(i), std::cmp::Reverse(i))) else {
            break;
        };
        chain.push(next);
        cur = next;
    }

    let mut steps: Vec<CriticalStep> = Vec::with_capacity(chain.len());
    for (depth, &i) in chain.iter().enumerate() {
        let (node, ev) = &dump.events[i];
        let child_dur = chain.get(depth + 1).map_or(0, |&c| dump.events[c].1.dur_ns);
        steps.push(CriticalStep {
            index: i,
            node: node.clone(),
            name: ev.name.clone(),
            span: ev.span,
            start_ns: ev.ts_ns,
            dur_ns: ev.dur_ns,
            self_ns: ev.dur_ns.saturating_sub(child_dur),
        });
    }
    let mut by_kind: HashMap<String, u64> = HashMap::new();
    for s in &steps {
        *by_kind.entry(s.name.clone()).or_insert(0) += s.self_ns;
    }
    let mut by_kind: Vec<(String, u64)> = by_kind.into_iter().collect();
    by_kind.sort_by(|a, b| b.1.cmp(&a.1).then_with(|| a.0.cmp(&b.0)));
    Some(CriticalPath {
        total_ns: steps.first().map_or(0, |s| s.dur_ns),
        steps,
        by_kind,
    })
}

/// Render a [`CriticalPath`] as two stacked tables: the chain itself
/// (root → leaf) and the per-kind attribution.
pub fn critical_path_table(cp: &CriticalPath) -> Table {
    let mut t = Table::new(
        format!(
            "critical path — {} step(s), {:.3} ms end to end",
            cp.steps.len(),
            cp.total_ns as f64 / 1e6
        ),
        "step",
        vec![
            "start ms".into(),
            "dur ms".into(),
            "self ms".into(),
        ],
    );
    t.unit = "";
    for (depth, s) in cp.steps.iter().enumerate() {
        t.add_row(
            format!("{}{} @{}", "  ".repeat(depth.min(8)), s.name, s.node),
            vec![
                Some(s.start_ns as f64 / 1e6),
                Some(s.dur_ns as f64 / 1e6),
                Some(s.self_ns as f64 / 1e6),
            ],
        );
    }
    for (kind, self_ns) in &cp.by_kind {
        t.add_row(
            format!("Σ {kind}"),
            vec![None, None, Some(*self_ns as f64 / 1e6)],
        );
    }
    t
}

/// Per-node busy/idle accounting: union the node's span intervals and
/// report coverage of its observed window plus the longest gap.
pub fn busy_idle(dump: &TraceDump) -> Table {
    // node → sorted (start, end) span intervals (instants contribute
    // presence to the window but no busy time).
    let mut nodes: Vec<String> = Vec::new();
    let mut intervals: HashMap<String, Vec<(u64, u64)>> = HashMap::new();
    let mut windows: HashMap<String, (u64, u64)> = HashMap::new();
    for (node, ev) in &dump.events {
        if !nodes.contains(node) {
            nodes.push(node.clone());
        }
        let end = ev.ts_ns.saturating_add(ev.dur_ns);
        let w = windows.entry(node.clone()).or_insert((ev.ts_ns, end));
        w.0 = w.0.min(ev.ts_ns);
        w.1 = w.1.max(end);
        if ev.dur_ns > 0 {
            intervals.entry(node.clone()).or_default().push((ev.ts_ns, end));
        }
    }
    let mut t = Table::new(
        "per-node busy/idle (span-interval union)".to_string(),
        "node",
        vec![
            "events".into(),
            "busy ms".into(),
            "idle ms".into(),
            "max gap ms".into(),
        ],
    );
    t.unit = "";
    for node in &nodes {
        let count = dump.events.iter().filter(|(n, _)| n == node).count();
        let (busy, max_gap, window) = match intervals.get(node) {
            None => (0, windows[node].1 - windows[node].0, windows[node].1 - windows[node].0),
            Some(iv) => {
                let mut iv = iv.clone();
                iv.sort_unstable();
                let (w0, w1) = windows[node];
                let mut busy = 0u64;
                let mut max_gap = iv[0].0 - w0;
                let (mut cs, mut ce) = iv[0];
                for &(s, e) in &iv[1..] {
                    if s <= ce {
                        ce = ce.max(e);
                    } else {
                        busy += ce - cs;
                        max_gap = max_gap.max(s - ce);
                        cs = s;
                        ce = e;
                    }
                }
                busy += ce - cs;
                max_gap = max_gap.max(w1 - ce);
                (busy, max_gap, w1 - w0)
            }
        };
        t.add_row(
            node.clone(),
            vec![
                Some(count as f64),
                Some(busy as f64 / 1e6),
                Some(window.saturating_sub(busy) as f64 / 1e6),
                Some(max_gap as f64 / 1e6),
            ],
        );
    }
    t
}

/// Render the dump as folded flamegraph stacks: for every span, the
/// `;`-joined chain of ancestor names plus its own, weighted by its
/// **exclusive** time (duration minus the sum of its direct children's
/// durations) in µs. Lines are sorted for deterministic output; zero
/// weights are omitted. Instants contribute stack frames but no weight.
pub fn folded_stacks(dump: &TraceDump) -> String {
    let by_span = span_index(dump);
    // Sum of direct children's durations per parent span id.
    let mut child_dur: HashMap<u64, u64> = HashMap::new();
    for (_, ev) in &dump.events {
        if ev.parent != 0 && ev.dur_ns > 0 {
            *child_dur.entry(ev.parent).or_insert(0) += ev.dur_ns;
        }
    }
    let mut stacks: HashMap<String, u64> = HashMap::new();
    for (_, ev) in &dump.events {
        if ev.dur_ns == 0 {
            continue;
        }
        let exclusive = ev.dur_ns.saturating_sub(child_dur.get(&ev.span).copied().unwrap_or(0));
        if exclusive == 0 {
            continue;
        }
        // Build root→self frame list by walking parents.
        let mut frames = vec![ev.name.as_str()];
        let mut cur = ev.parent;
        for _ in 0..MAX_DEPTH {
            if cur == 0 {
                break;
            }
            let Some(&pi) = by_span.get(&cur) else { break };
            let pev = &dump.events[pi].1;
            frames.push(pev.name.as_str());
            cur = pev.parent;
        }
        frames.reverse();
        *stacks.entry(frames.join(";")).or_insert(0) += exclusive / 1000;
    }
    let mut lines: Vec<(String, u64)> = stacks.into_iter().filter(|(_, w)| *w > 0).collect();
    lines.sort();
    let mut out = String::new();
    for (stack, weight) in lines {
        out.push_str(&format!("{stack} {weight}\n"));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::TraceEvent;

    fn ev(ts: u64, dur: u64, span: u64, parent: u64, name: &str) -> TraceEvent {
        TraceEvent {
            ts_ns: ts,
            dur_ns: dur,
            span,
            parent,
            tid: 1,
            name: name.into(),
            args: vec![],
        }
    }

    /// slice(10ms) → dispatch(1ms) & run(8ms) → fetch(3ms); a second,
    /// shorter run on another node that is NOT on the critical path.
    fn dump() -> TraceDump {
        TraceDump {
            events: vec![
                ("leader".into(), ev(0, 10_000_000, 1, 0, "pop.slice")),
                ("leader".into(), ev(100_000, 1_000_000, 2, 1, "pool.dispatch")),
                ("w1".into(), ev(1_200_000, 8_000_000, 3, 1, "pool.run")),
                ("w1".into(), ev(1_500_000, 3_000_000, 4, 3, "store.fetch")),
                ("w2".into(), ev(1_200_000, 2_000_000, 5, 1, "pool.run")),
            ],
            dropped: 0,
            crash: false,
        }
    }

    #[test]
    fn critical_path_follows_latest_finishing_children() {
        let cp = critical_path(&dump()).unwrap();
        let names: Vec<&str> = cp.steps.iter().map(|s| s.name.as_str()).collect();
        assert_eq!(names, ["pop.slice", "pool.run", "store.fetch"]);
        assert_eq!(cp.total_ns, 10_000_000);
        // Self times: slice 10−8, run 8−3, fetch 3.
        assert_eq!(cp.steps[0].self_ns, 2_000_000);
        assert_eq!(cp.steps[1].self_ns, 5_000_000);
        assert_eq!(cp.steps[2].self_ns, 3_000_000);
        // Attribution is sorted largest-first.
        assert_eq!(cp.by_kind[0].0, "pool.run");
        let table = critical_path_table(&cp).render();
        assert!(table.contains("pop.slice"), "{table}");
        assert!(table.contains("Σ pool.run"), "{table}");
    }

    #[test]
    fn critical_path_of_empty_dump_is_none() {
        let d = TraceDump::new(vec![], 0);
        assert!(critical_path(&d).is_none());
    }

    #[test]
    fn busy_idle_unions_overlapping_intervals() {
        let d = TraceDump {
            events: vec![
                // Two overlapping spans (0..10, 5..15) then a gap to 30..35.
                ("n".into(), ev(0, 10, 1, 0, "a")),
                ("n".into(), ev(5, 10, 2, 0, "b")),
                ("n".into(), ev(30, 5, 3, 0, "c")),
            ],
            dropped: 0,
            crash: false,
        };
        let t = busy_idle(&d).render();
        // busy = 20ns union, window 35ns, idle 15ns, max gap 15ns — all
        // rendered in ms, so just assert the row exists and renders.
        assert!(t.contains('n'), "{t}");
        // Check the math directly through a focused recomputation.
        let cp = critical_path(&d).unwrap();
        assert_eq!(cp.steps.len(), 1);
    }

    #[test]
    fn folded_stacks_weight_exclusive_time() {
        let d = TraceDump {
            events: vec![
                ("n".into(), ev(0, 10_000_000, 1, 0, "outer")),
                ("n".into(), ev(1_000_000, 4_000_000, 2, 1, "inner")),
            ],
            dropped: 0,
            crash: false,
        };
        let folded = folded_stacks(&d);
        let lines: Vec<&str> = folded.lines().collect();
        assert_eq!(lines, ["outer 6000", "outer;inner 4000"]);
    }

    #[test]
    fn folded_stacks_survive_orphan_parents() {
        let d = TraceDump {
            events: vec![("n".into(), ev(0, 2_000_000, 7, 999, "lonely"))],
            dropped: 1,
            crash: false,
        };
        assert_eq!(folded_stacks(&d), "lonely 2000\n");
    }
}
