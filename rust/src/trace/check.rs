//! `fiber::trace::check` — the causal invariant engine.
//!
//! A recorded trace is only worth keeping if it can be *audited*: the
//! parent links and argument payloads documented in `docs/trace_schema.md`
//! imply invariants that every healthy run must satisfy, and a chaos run
//! that violates one has found a real bug (or a broken recorder). This
//! module checks a [`TraceDump`] — freshly collected, re-read from a
//! JSONL/Chrome file, or synthesized by [`super::replay`] — and reports
//! every violation with a `file:line`-style coordinate (for JSONL files
//! written by [`super::export::write_jsonl`], the line number *is* the
//! event's line in the file; for other sources it is the event's ordinal
//! in the time-sorted dump).
//!
//! Two severities:
//!
//! * **violation** — the trace contradicts a documented invariant;
//!   `fiber-cli trace-check` exits non-zero.
//! * **warning** — the trace is suspicious but explainable (lossy journal
//!   holes, untraced proc workers, cross-node clock skew).
//!
//! The catalog (also in `docs/trace_schema.md`):
//!
//! | invariant | statement |
//! |---|---|
//! | `parent-exists` | every non-zero parent id resolves to a recorded event |
//! | `span-unique` | span ids are unique across the dump |
//! | `span-ends` | known span kinds carry a non-zero duration (the span ended) |
//! | `monotone-ts` | a child never starts before its parent (same-node hard, cross-node within skew) |
//! | `lossy` | a non-zero `dropped` counter is surfaced, never silently analyzed over |
//! | `ring.resume-heal` | every `ring.resume` is parented by a `ring.heal` span |
//! | `ring.adopt-op` | every `ring.adopt` names an `op_seq` some heal interrupted |
//! | `store.fetch-once` | at most one cold fetch per `(node, obj)` beyond re-fetches justified by evictions |
//! | `store.refcount` | per `(node, obj)`, releases never exceed held puts + increfs, and no referenced blob is evicted |
//! | `pool.run-link` | every `pool.run`'s resolved parent is a `pool.dispatch` (or the submitting `pop.slice`) |
//! | `pool.dispatch-run` | a dispatch with tasks has at least one observed run (warning: workers may be untraced) |
//! | `pool.rerun-restart` | a task that ran twice under one dispatch is explained by a `pool.restart` |
//! | `pop.slice-ckpt` | re-dispatches of one `(trial, slice)` reuse the same checkpoint ref |
//!
//! The two-level scheduler's events (`sched.assign`, `sched.steal`,
//! `sched.local_hit` — see `docs/trace_schema.md`) are instants: they
//! carry no duration obligation and no cross-layer invariant of their
//! own, so they pass the audit untouched — the CI sched smoke greps for
//! their presence after running a traced `sched-demo` through this
//! checker.

use std::collections::HashMap;

use super::collect::TraceDump;
use super::TraceEvent;

/// Span kinds that must end (be recorded with `dur_ns > 0`). Instants are
/// everything else; an unknown name is never flagged.
pub const SPAN_KINDS: &[&str] = &[
    "pool.dispatch",
    "pool.run",
    "ring.allreduce",
    "ring.broadcast",
    "ring.heal",
    "store.put",
    "store.fetch",
    "store.wait",
    "pop.slice",
];

/// One failed (or suspicious) invariant, anchored to an event.
#[derive(Clone, Debug)]
pub struct Finding {
    /// Catalog name of the invariant (`ring.resume-heal`, …).
    pub invariant: &'static str,
    /// `file:line`-style coordinate of the offending event.
    pub at: String,
    /// Node the event was recorded on.
    pub node: String,
    /// The offending event's span id.
    pub span: u64,
    pub message: String,
}

impl Finding {
    fn render(&self) -> String {
        format!(
            "{at}: [{inv}] {msg} (node {node}, span {span})",
            at = self.at,
            inv = self.invariant,
            msg = self.message,
            node = self.node,
            span = self.span,
        )
    }
}

/// Tunables for [`check_with`].
#[derive(Clone, Debug)]
pub struct CheckOptions {
    /// Tolerated cross-node start-time skew (clock-alignment noise), ns.
    pub skew_ns: u64,
}

impl Default for CheckOptions {
    fn default() -> Self {
        CheckOptions {
            skew_ns: 10_000_000, // 10 ms — well above midpoint-probe error
        }
    }
}

/// The audit result: what was checked and everything that failed.
#[derive(Debug, Default)]
pub struct CheckReport {
    pub source: String,
    pub events: usize,
    pub dropped: u64,
    /// The dump was a crash flight-recorder window; whole-run invariants
    /// were skipped (see [`check_with`]).
    pub crash: bool,
    pub violations: Vec<Finding>,
    pub warnings: Vec<Finding>,
}

impl CheckReport {
    /// True when no invariant was violated (warnings don't fail an audit).
    pub fn ok(&self) -> bool {
        self.violations.is_empty()
    }

    fn violation(&mut self, invariant: &'static str, at: String, node: &str, span: u64, message: String) {
        self.violations.push(Finding {
            invariant,
            at,
            node: node.to_string(),
            span,
            message,
        });
    }

    fn warning(&mut self, invariant: &'static str, at: String, node: &str, span: u64, message: String) {
        self.warnings.push(Finding {
            invariant,
            at,
            node: node.to_string(),
            span,
            message,
        });
    }

    /// A lossy dump downgrades link-shaped violations to warnings: the
    /// missing half of the link may simply have been dropped.
    fn linkage(&mut self, lossy: bool, invariant: &'static str, at: String, node: &str, span: u64, message: String) {
        if lossy {
            self.warning(invariant, at, node, span, message);
        } else {
            self.violation(invariant, at, node, span, message);
        }
    }

    /// Human-readable report: verdict line, then findings (violations
    /// first), then the honesty footer.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "trace-check {}: {} — {} events, {} violation(s), {} warning(s)\n",
            self.source,
            if self.ok() { "PASS" } else { "FAIL" },
            self.events,
            self.violations.len(),
            self.warnings.len(),
        ));
        for f in &self.violations {
            out.push_str(&format!("  violation {}\n", f.render()));
        }
        for f in &self.warnings {
            out.push_str(&format!("  warning   {}\n", f.render()));
        }
        if self.crash {
            out.push_str(
                "  CRASH WINDOW: this is a flight-recorder dump (the last moments \
                 before a panic/fatal error); whole-run invariants were not audited\n",
            );
        }
        if self.dropped > 0 {
            out.push_str(&format!(
                "  LOSSY TRACE: {} event(s) were dropped by bounded journals — \
                 the causal record has holes and this audit is best-effort\n",
                self.dropped
            ));
        }
        out
    }
}

/// Check `dump` against the full invariant catalog with default options.
pub fn check(dump: &TraceDump, source: &str) -> CheckReport {
    check_with(dump, source, &CheckOptions::default())
}

/// Check `dump` against the full invariant catalog.
pub fn check_with(dump: &TraceDump, source: &str, opts: &CheckOptions) -> CheckReport {
    let mut rep = CheckReport {
        source: source.to_string(),
        events: dump.events.len(),
        dropped: dump.dropped,
        crash: dump.crash,
        ..CheckReport::default()
    };
    // A crash dump is a bounded *suffix* of the run (the flight-recorder
    // window): everything before it is missing by construction, so it is
    // audited as lossy even when nothing was dropped inside the window.
    let lossy = dump.dropped > 0 || dump.crash;
    let at = |i: usize| format!("{source}:{}", i + 1);

    // ---- structural: span-id index, uniqueness, orphan parents --------
    let mut by_span: HashMap<u64, usize> = HashMap::new();
    for (i, (node, ev)) in dump.events.iter().enumerate() {
        if ev.span == 0 {
            rep.violation(
                "span-unique",
                at(i),
                node,
                0,
                format!("event {:?} has no span id (0 is reserved)", ev.name),
            );
            continue;
        }
        if let Some(prev) = by_span.insert(ev.span, i) {
            rep.violation(
                "span-unique",
                at(i),
                node,
                ev.span,
                format!(
                    "span id {} already used by {:?} at {}",
                    ev.span,
                    dump.events[prev].1.name,
                    at(prev)
                ),
            );
        }
    }
    if lossy {
        rep.warning(
            "lossy",
            format!("{source}:0"),
            "-",
            0,
            format!(
                "{} event(s) dropped by bounded journals; holes are possible",
                dump.dropped
            ),
        );
    }
    for (i, (node, ev)) in dump.events.iter().enumerate() {
        if ev.parent != 0 && !by_span.contains_key(&ev.parent) {
            rep.linkage(
                lossy,
                "parent-exists",
                at(i),
                node,
                ev.span,
                format!("{:?} parents under span {} which is not in the dump", ev.name, ev.parent),
            );
        }
        if ev.dur_ns == 0 && SPAN_KINDS.contains(&ev.name.as_str()) {
            rep.violation(
                "span-ends",
                at(i),
                node,
                ev.span,
                format!("{:?} is a span kind but was recorded with zero duration — it never ended", ev.name),
            );
        }
        // monotone-ts: a child must not start before its parent started.
        if ev.parent != 0 {
            if let Some(&pi) = by_span.get(&ev.parent) {
                let (pnode, pev) = &dump.events[pi];
                if ev.ts_ns < pev.ts_ns {
                    let skew = pev.ts_ns - ev.ts_ns;
                    if pnode == node {
                        rep.violation(
                            "monotone-ts",
                            at(i),
                            node,
                            ev.span,
                            format!(
                                "{:?} starts {} ns before its same-node parent {:?}",
                                ev.name, skew, pev.name
                            ),
                        );
                    } else if skew > opts.skew_ns {
                        rep.warning(
                            "monotone-ts",
                            at(i),
                            node,
                            ev.span,
                            format!(
                                "{:?} starts {} ns before its parent {:?} on node {pnode} \
                                 (beyond the {} ns clock-alignment allowance)",
                                ev.name, skew, pev.name, opts.skew_ns
                            ),
                        );
                    }
                }
            }
        }
    }

    // ---- ring: heal → resume parentage, adopt names a healed op ------
    let healed_ops: Vec<i64> = dump
        .events
        .iter()
        .filter(|(_, e)| e.name == "ring.heal")
        .filter_map(|(_, e)| e.arg("op_seq"))
        .collect();
    for (i, (node, ev)) in dump.events.iter().enumerate() {
        if ev.name == "ring.resume" {
            match by_span.get(&ev.parent).map(|&pi| &dump.events[pi].1) {
                Some(p) if p.name == "ring.heal" => {}
                Some(p) => rep.violation(
                    "ring.resume-heal",
                    at(i),
                    node,
                    ev.span,
                    format!("ring.resume parented by {:?}, not a ring.heal span", p.name),
                ),
                None => rep.linkage(
                    lossy,
                    "ring.resume-heal",
                    at(i),
                    node,
                    ev.span,
                    "ring.resume has no resolvable ring.heal parent".to_string(),
                ),
            }
        }
        if ev.name == "ring.adopt" {
            match ev.arg("op_seq") {
                Some(op) if healed_ops.contains(&op) => {}
                Some(op) => rep.linkage(
                    lossy,
                    "ring.adopt-op",
                    at(i),
                    node,
                    ev.span,
                    format!("ring.adopt names op_seq {op}, but no ring.heal interrupted that op"),
                ),
                None => rep.violation(
                    "ring.adopt-op",
                    at(i),
                    node,
                    ev.span,
                    "ring.adopt carries no op_seq argument".to_string(),
                ),
            }
        }
    }

    // A crash window stops here: the structural and ring-linkage checks
    // above are valid on any suffix (linkage already downgraded via
    // `lossy`), but the remaining families count events across the whole
    // run (puts vs releases, fetches vs evictions, dispatches vs runs,
    // first-dispatch checkpoints) and would report phantom violations when
    // the balancing half predates the flight-recorder window.
    if dump.crash {
        rep.warning(
            "crash",
            format!("{source}:0"),
            "-",
            0,
            "crash flight-recorder window: whole-run invariants (store.fetch-once, \
             store.refcount, pool.rerun-restart, pool.dispatch-run, pop.slice-ckpt) \
             not audited — history before the window is missing by construction"
                .to_string(),
        );
        return rep;
    }

    // ---- store: transfer conservation + refcount balance -------------
    // Walk in time order (the dump is ts-sorted), keyed by (node, obj).
    #[derive(Default)]
    struct ObjState {
        fetches: Vec<usize>,
        evictions: u64,
        refs: i64,
    }
    let mut objs: HashMap<(String, i64), ObjState> = HashMap::new();
    for (i, (node, ev)) in dump.events.iter().enumerate() {
        let Some(obj) = ev.arg("obj") else { continue };
        let st = objs.entry((node.clone(), obj)).or_default();
        match ev.name.as_str() {
            "store.fetch" => st.fetches.push(i),
            "store.put" => {
                if ev.arg("held") == Some(1) {
                    st.refs += 1;
                }
            }
            "store.incref" => st.refs += 1,
            "store.release" => {
                st.refs -= 1;
                if st.refs < 0 {
                    rep.violation(
                        "store.refcount",
                        at(i),
                        node,
                        ev.span,
                        format!(
                            "store.release on obj {obj} drives its refcount negative \
                             (more releases than held puts + increfs)"
                        ),
                    );
                    st.refs = 0; // report once per underflow, keep auditing
                }
            }
            "store.evict" => {
                if st.refs > 0 {
                    rep.violation(
                        "store.refcount",
                        at(i),
                        node,
                        ev.span,
                        format!("store.evict of obj {obj} while {} reference(s) are outstanding", st.refs),
                    );
                }
                st.evictions += 1;
            }
            _ => {}
        }
    }
    for ((node, obj), st) in &objs {
        let allowed = 1 + st.evictions as usize;
        if st.fetches.len() > allowed {
            for &i in &st.fetches[allowed..] {
                rep.violation(
                    "store.fetch-once",
                    at(i),
                    node,
                    dump.events[i].1.span,
                    format!(
                        "duplicate cold fetch of obj {obj}: {} fetch(es) but only {} eviction(s) \
                         could justify a re-fetch",
                        st.fetches.len(),
                        st.evictions
                    ),
                );
            }
        }
    }

    // ---- pool: dispatch ↔ run envelope links, reruns need a restart --
    let restarts = dump.events.iter().filter(|(_, e)| e.name == "pool.restart").count();
    let mut dispatch_runs: HashMap<u64, u64> = HashMap::new(); // dispatch span → observed runs
    let mut reran: HashMap<(u64, i64), usize> = HashMap::new(); // (dispatch, index) → runs
    for (i, (node, ev)) in dump.events.iter().enumerate() {
        if ev.name != "pool.run" {
            continue;
        }
        match by_span.get(&ev.parent).map(|&pi| &dump.events[pi].1) {
            Some(p) if p.name == "pool.dispatch" => {
                *dispatch_runs.entry(p.span).or_insert(0) += 1;
                if let Some(index) = ev.arg("index") {
                    let n = reran.entry((p.span, index)).or_insert(0);
                    *n += 1;
                    if *n > 1 && restarts == 0 {
                        rep.violation(
                            "pool.rerun-restart",
                            at(i),
                            node,
                            ev.span,
                            format!(
                                "task index {index} ran {n} times under one dispatch \
                                 with no pool.restart recorded"
                            ),
                        );
                    }
                }
            }
            // The dispatch span is elided when tracing was enabled after
            // submit — the envelope then carries the submitting scope.
            Some(p) if p.name == "pop.slice" => {}
            Some(p) => rep.violation(
                "pool.run-link",
                at(i),
                node,
                ev.span,
                format!("pool.run parented by {:?}, not a pool.dispatch envelope link", p.name),
            ),
            None if ev.parent == 0 => rep.warning(
                "pool.run-link",
                at(i),
                node,
                ev.span,
                "pool.run with no envelope link (root span)".to_string(),
            ),
            None => {} // orphan already reported by parent-exists
        }
    }
    for (i, (node, ev)) in dump.events.iter().enumerate() {
        if ev.name == "pool.dispatch"
            && ev.arg("tasks").unwrap_or(0) > 0
            && dispatch_runs.get(&ev.span).copied().unwrap_or(0) == 0
        {
            rep.warning(
                "pool.dispatch-run",
                at(i),
                node,
                ev.span,
                format!(
                    "dispatch of {} task(s) has no observed pool.run \
                     (untraced worker processes, or a lossy journal)",
                    ev.arg("tasks").unwrap_or(0)
                ),
            );
        }
    }

    // ---- pop: a re-dispatched (trial, slice) keeps its checkpoint ----
    let mut slice_ckpt: HashMap<(i64, i64), (usize, i64)> = HashMap::new();
    for (i, (node, ev)) in dump.events.iter().enumerate() {
        if ev.name != "pop.slice" {
            continue;
        }
        let (Some(trial), Some(slice)) = (ev.arg("trial"), ev.arg("slice")) else {
            continue;
        };
        let Some(ckpt) = ev.arg("ckpt") else { continue };
        match slice_ckpt.get(&(trial, slice)) {
            None => {
                slice_ckpt.insert((trial, slice), (i, ckpt));
            }
            Some(&(first, first_ckpt)) if first_ckpt != ckpt => rep.violation(
                "pop.slice-ckpt",
                at(i),
                node,
                ev.span,
                format!(
                    "trial {trial} slice {slice} re-dispatched with checkpoint {ckpt}, \
                     but the first dispatch at {} carried {first_ckpt} — a requeued \
                     slice must reuse the same checkpoint ref",
                    at(first)
                ),
            ),
            Some(_) => {}
        }
    }

    rep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(ts: u64, dur: u64, span: u64, parent: u64, name: &str, args: &[(&str, i64)]) -> TraceEvent {
        TraceEvent {
            ts_ns: ts,
            dur_ns: dur,
            span,
            parent,
            tid: 1,
            name: name.into(),
            args: args.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
        }
    }

    fn dump(events: Vec<(&str, TraceEvent)>) -> TraceDump {
        TraceDump::new(
            events.into_iter().map(|(n, e)| (n.to_string(), e)).collect(),
            0,
        )
    }

    /// A small healthy trace: slice → dispatch → run → fetch, heal →
    /// resume, adopt naming the healed op, balanced refcounts.
    fn good() -> TraceDump {
        dump(vec![
            ("leader", ev(5, 0, 9, 0, "store.put", &[("obj", 42), ("held", 1), ("len", 64)])),
            ("leader", ev(10, 600, 1, 0, "pop.slice", &[("trial", 0), ("slice", 0), ("ckpt", 42)])),
            ("leader", ev(20, 100, 2, 1, "pool.dispatch", &[("map_id", 0), ("tasks", 1)])),
            ("w1", ev(40, 200, 3, 2, "pool.run", &[("worker", 1), ("index", 0)])),
            ("w1", ev(50, 80, 4, 3, "store.fetch", &[("obj", 42)])),
            ("leader", ev(300, 150, 5, 0, "ring.heal", &[("from_gen", 0), ("op_seq", 7), ("completed", 2)])),
            ("leader", ev(440, 0, 6, 5, "ring.resume", &[("op_seq", 7), ("chunk", 2), ("gen", 1)])),
            ("w2", ev(460, 0, 7, 0, "ring.adopt", &[("op_seq", 7), ("kind", 1), ("resume_chunk", 2)])),
            ("leader", ev(500, 0, 8, 1, "store.release", &[("obj", 42)])),
        ])
    }

    #[test]
    fn healthy_trace_passes() {
        let rep = check(&good(), "good.jsonl");
        assert!(rep.ok(), "unexpected violations: {}", rep.render());
    }

    #[test]
    fn orphan_parent_is_reported_with_coordinates() {
        let mut d = good();
        d.events.push(("w1".into(), ev(600, 0, 20, 999, "pop.exploit", &[("trial", 1)])));
        let rep = check(&d, "trace.jsonl");
        assert!(!rep.ok());
        let f = rep.violations.iter().find(|f| f.invariant == "parent-exists").unwrap();
        assert_eq!(f.at, "trace.jsonl:10", "coordinate names the event's line");
        // A lossy dump downgrades the same finding to a warning.
        d.dropped = 3;
        let rep = check(&d, "trace.jsonl");
        assert!(rep.ok(), "{}", rep.render());
        assert!(rep.warnings.iter().any(|f| f.invariant == "parent-exists"));
        assert!(rep.warnings.iter().any(|f| f.invariant == "lossy"));
    }

    #[test]
    fn resume_without_heal_parent_fails() {
        let mut d = good();
        // Re-parent the resume under the dispatch span.
        let resume = d.events.iter_mut().find(|(_, e)| e.name == "ring.resume").unwrap();
        resume.1.parent = 2;
        let rep = check(&d, "t.jsonl");
        let f = rep.violations.iter().find(|f| f.invariant == "ring.resume-heal").unwrap();
        assert!(f.message.contains("pool.dispatch"), "{}", f.message);
    }

    #[test]
    fn adopt_must_name_a_healed_op() {
        let mut d = good();
        let adopt = d.events.iter_mut().find(|(_, e)| e.name == "ring.adopt").unwrap();
        adopt.1.args = vec![("op_seq".into(), 99)];
        let rep = check(&d, "t.jsonl");
        assert!(rep.violations.iter().any(|f| f.invariant == "ring.adopt-op"));
    }

    #[test]
    fn unbalanced_refcounts_fail() {
        let mut d = good();
        // One held put, one release already — a second release underflows.
        d.events.push(("leader".into(), ev(700, 0, 21, 0, "store.release", &[("obj", 42)])));
        let rep = check(&d, "t.jsonl");
        let f = rep.violations.iter().find(|f| f.invariant == "store.refcount").unwrap();
        assert_eq!(f.at, "t.jsonl:10");
        // Evicting while a reference is outstanding also fails.
        let mut d2 = good();
        d2.events.push(("leader".into(), ev(450, 0, 22, 0, "store.evict", &[("obj", 42)])));
        d2.events.sort_by_key(|(_, e)| e.ts_ns);
        let rep2 = check(&d2, "t.jsonl");
        assert!(rep2.violations.iter().any(|f| f.invariant == "store.refcount"
            && f.message.contains("outstanding")));
    }

    #[test]
    fn duplicate_cold_fetch_fails_unless_evicted() {
        let mut d = good();
        d.events.push(("w1".into(), ev(800, 50, 23, 3, "store.fetch", &[("obj", 42)])));
        let rep = check(&d, "t.jsonl");
        let f = rep.violations.iter().find(|f| f.invariant == "store.fetch-once").unwrap();
        assert!(f.message.contains("duplicate cold fetch"), "{}", f.message);
        // An eviction between the two fetches justifies the re-fetch —
        // but the evicted obj held a reference in `good()`, so release
        // it first to keep the refcount invariant clean.
        let mut d2 = good();
        d2.events.push(("w1".into(), ev(700, 0, 24, 0, "store.evict", &[("obj", 42)])));
        d2.events.push(("w1".into(), ev(800, 50, 25, 0, "store.fetch", &[("obj", 42)])));
        let rep2 = check(&d2, "t.jsonl");
        assert!(
            !rep2.violations.iter().any(|f| f.invariant == "store.fetch-once"),
            "{}",
            rep2.render()
        );
    }

    #[test]
    fn span_kind_with_zero_duration_never_ended() {
        let mut d = good();
        d.events.push(("leader".into(), ev(900, 0, 26, 0, "ring.allreduce", &[("elems", 8)])));
        let rep = check(&d, "t.jsonl");
        assert!(rep.violations.iter().any(|f| f.invariant == "span-ends"));
    }

    #[test]
    fn duplicate_span_ids_fail() {
        let mut d = good();
        d.events.push(("w2".into(), ev(950, 0, 3, 0, "pop.mutate", &[])));
        let rep = check(&d, "t.jsonl");
        assert!(rep.violations.iter().any(|f| f.invariant == "span-unique"));
    }

    #[test]
    fn rerun_without_restart_fails_and_restart_excuses_it() {
        let mut d = good();
        d.events.push(("w2".into(), ev(960, 100, 27, 2, "pool.run", &[("worker", 2), ("index", 0)])));
        let rep = check(&d, "t.jsonl");
        assert!(rep.violations.iter().any(|f| f.invariant == "pool.rerun-restart"));
        d.events.push(("leader".into(), ev(955, 0, 28, 0, "pool.restart", &[("worker", 1), ("requeued", 1)])));
        d.events.sort_by_key(|(_, e)| e.ts_ns);
        let rep2 = check(&d, "t.jsonl");
        assert!(
            !rep2.violations.iter().any(|f| f.invariant == "pool.rerun-restart"),
            "{}",
            rep2.render()
        );
    }

    #[test]
    fn requeued_slice_must_reuse_checkpoint() {
        let mut d = good();
        d.events.push((
            "leader".into(),
            ev(980, 100, 29, 0, "pop.slice", &[("trial", 0), ("slice", 0), ("ckpt", 43)]),
        ));
        let rep = check(&d, "t.jsonl");
        let f = rep.violations.iter().find(|f| f.invariant == "pop.slice-ckpt").unwrap();
        assert!(f.message.contains("must reuse the same checkpoint"), "{}", f.message);
        // Same ckpt on the re-dispatch is fine.
        let mut d2 = good();
        d2.events.push((
            "leader".into(),
            ev(980, 100, 30, 0, "pop.slice", &[("trial", 0), ("slice", 0), ("ckpt", 42)]),
        ));
        assert!(check(&d2, "t.jsonl").ok());
    }

    #[test]
    fn same_node_time_travel_fails_cross_node_warns() {
        let mut d = good();
        // Child starting before its same-node parent: rewind the fetch
        // (span 4, node w1, parent run span 3 on w1 at ts 40).
        let fetch = d.events.iter_mut().find(|(_, e)| e.name == "store.fetch").unwrap();
        fetch.1.ts_ns = 10;
        d.events.sort_by_key(|(_, e)| e.ts_ns);
        let rep = check(&d, "t.jsonl");
        assert!(rep.violations.iter().any(|f| f.invariant == "monotone-ts"));
        // Cross-node skew beyond the allowance is a warning, not a failure.
        let mut d2 = good();
        let run = d2.events.iter_mut().find(|(_, e)| e.name == "pool.run").unwrap();
        run.1.ts_ns = 0;
        d2.events.sort_by_key(|(_, e)| e.ts_ns);
        let rep2 = check_with(&d2, "t.jsonl", &CheckOptions { skew_ns: 5 });
        assert!(rep2.violations.iter().all(|f| f.invariant != "monotone-ts"), "{}", rep2.render());
        assert!(rep2.warnings.iter().any(|f| f.invariant == "monotone-ts"));
    }

    #[test]
    fn crash_window_relaxes_whole_run_invariants() {
        // A flight-recorder window that caught only the *tail* of the run:
        // a release whose put predates the window, a fetch whose first
        // fetch predates it, and an instant parented under a span that was
        // still open (never recorded) when the process died. As a normal
        // dump this fails three ways; as a crash window it must pass with
        // warnings only.
        let mut d = dump(vec![
            ("w1", ev(100, 0, 40, 777, "trace.crash", &[("reason", 1)])),
            ("w1", ev(10, 0, 41, 0, "store.release", &[("obj", 5)])),
            ("w1", ev(20, 80, 42, 0, "store.fetch", &[("obj", 6)])),
            ("w1", ev(30, 60, 43, 0, "store.fetch", &[("obj", 6)])),
        ]);
        d.events.sort_by_key(|(_, e)| e.ts_ns);
        let rep = check(&d, "normal.jsonl");
        assert!(!rep.ok(), "as a normal dump this trace is broken");
        d.crash = true;
        let rep = check(&d, "fiber-crash-1.jsonl");
        assert!(rep.ok(), "{}", rep.render());
        assert!(rep.warnings.iter().any(|f| f.invariant == "crash"));
        assert!(
            rep.warnings.iter().any(|f| f.invariant == "parent-exists"),
            "linkage findings downgrade, not vanish: {}",
            rep.render()
        );
        let text = rep.render();
        assert!(text.contains("CRASH WINDOW"), "{text}");
        // Structural self-contained invariants still fail a crash dump.
        d.events.push(("w1".into(), ev(40, 0, 41, 0, "pop.mutate", &[])));
        let rep2 = check(&d, "fiber-crash-1.jsonl");
        assert!(rep2.violations.iter().any(|f| f.invariant == "span-unique"));
    }

    #[test]
    fn report_renders_verdict_and_coordinates() {
        let mut d = good();
        d.events.push(("w1".into(), ev(600, 0, 31, 999, "pop.exploit", &[])));
        d.dropped = 2;
        let rep = check(&d, "chaos.jsonl");
        let text = rep.render();
        assert!(text.contains("chaos.jsonl"), "{text}");
        assert!(text.contains("LOSSY TRACE"), "{text}");
        assert!(text.contains("warning"), "{text}");
    }
}
