//! `fiber::trace::replay` — scenario-driven chaos replay on the virtual
//! clock.
//!
//! The record side ([`super::export`]) turns a chaos run into a JSONL
//! artifact; this module is the re-drive side, the simkube idiom from the
//! ROADMAP: a **scenario file** (JSON, [`crate::benchkit::Json`] — no
//! serde) composes a chaos schedule — node churn, stragglers, partitions,
//! spare drain/regrow storms — and a **calibration** (per-span-kind mean
//! durations, either defaults or measured from a recorded trace via
//! [`Calibration::from_dump`]) sets the service times. The
//! [`crate::cluster::simk8s::ReplayDriver`] re-drives the schedule against
//! simulated pods on the [`crate::cluster::des`] virtual clock at 1000+
//! nodes and emits a fresh [`TraceDump`] that must itself pass
//! [`super::check`] — which is the point: every elasticity claim becomes a
//! checkable artifact, reproducible in CI without hardware.
//!
//! Scenario schema (documented in `docs/trace_schema.md`):
//!
//! ```json
//! {"name":"churn_storm","nodes":1000,"spares":8,"iters":8,
//!  "elems":65536,"seed":7,"events":[
//!    {"at_iter":1,"kind":"kill","rank":17},
//!    {"at_iter":2,"kind":"straggle","rank":5,"factor":4.0},
//!    {"at_iter":3,"kind":"partition","rank":9,"iters":2},
//!    {"at_iter":5,"kind":"grow","count":4}]}
//! ```

use anyhow::{bail, Context, Result};

use crate::benchkit::Json;

use super::collect::TraceDump;

/// One scheduled chaos injection.
#[derive(Clone, Debug, PartialEq)]
pub enum ChaosKind {
    /// Kill the member at `rank` mid-compute: its journal (and in-flight
    /// spans) die with it, survivors heal, a spare adopts, the task is
    /// requeued, and a replacement pod regrows the spare pool.
    Kill { rank: usize },
    /// Multiply the member's compute time by `factor` for one iteration.
    Straggle { rank: usize, factor: f64 },
    /// Disconnect the member for `iters` iterations: the ring shrink-heals
    /// around it, and on rejoin it re-enters via the regrow path (its
    /// cached checkpoint must *hit*, not re-fetch — `store.fetch-once`).
    Partition { rank: usize, iters: usize },
    /// `count` fresh nodes join the ring (elastic grow).
    Grow { count: usize },
}

/// A chaos injection pinned to an iteration of the replayed run.
#[derive(Clone, Debug, PartialEq)]
pub struct ChaosEvent {
    pub at_iter: usize,
    pub kind: ChaosKind,
}

/// A replayable chaos schedule. Ranks index the *current* member list at
/// apply time (mod its length); rank 0 — the leader — is never targeted
/// (targets resolving to 0 shift to 1).
#[derive(Clone, Debug, PartialEq)]
pub struct Scenario {
    pub name: String,
    /// Ring members at start (≥ 2; the CLI `--nodes` flag overrides this).
    pub nodes: usize,
    /// Warm spare nodes available for adoption.
    pub spares: usize,
    pub iters: usize,
    /// Gradient elements per collective (scales nothing today but is
    /// recorded in the trace args for cross-run comparison).
    pub elems: usize,
    pub seed: u64,
    pub events: Vec<ChaosEvent>,
}

fn get_u(j: &Json, key: &str) -> Option<u64> {
    match j.get(key) {
        Some(Json::Num(x)) if x.is_finite() && *x >= 0.0 => Some(*x as u64),
        _ => None,
    }
}

fn get_f(j: &Json, key: &str) -> Option<f64> {
    match j.get(key) {
        Some(Json::Num(x)) if x.is_finite() => Some(*x),
        _ => None,
    }
}

fn get_s(j: &Json, key: &str) -> Option<String> {
    match j.get(key) {
        Some(Json::Str(s)) => Some(s.clone()),
        _ => None,
    }
}

impl ChaosEvent {
    fn to_json(&self) -> Json {
        let mut f: Vec<(String, Json)> = vec![("at_iter".into(), Json::num(self.at_iter as f64))];
        match &self.kind {
            ChaosKind::Kill { rank } => {
                f.push(("kind".into(), Json::str("kill")));
                f.push(("rank".into(), Json::num(*rank as f64)));
            }
            ChaosKind::Straggle { rank, factor } => {
                f.push(("kind".into(), Json::str("straggle")));
                f.push(("rank".into(), Json::num(*rank as f64)));
                f.push(("factor".into(), Json::num(*factor)));
            }
            ChaosKind::Partition { rank, iters } => {
                f.push(("kind".into(), Json::str("partition")));
                f.push(("rank".into(), Json::num(*rank as f64)));
                f.push(("iters".into(), Json::num(*iters as f64)));
            }
            ChaosKind::Grow { count } => {
                f.push(("kind".into(), Json::str("grow")));
                f.push(("count".into(), Json::num(*count as f64)));
            }
        }
        Json::Obj(f)
    }

    fn from_json(j: &Json) -> Result<ChaosEvent> {
        let at_iter = get_u(j, "at_iter").context("chaos event: missing at_iter")? as usize;
        let kind = get_s(j, "kind").context("chaos event: missing kind")?;
        let rank = || get_u(j, "rank").map(|r| r as usize).context("chaos event: missing rank");
        let kind = match kind.as_str() {
            "kill" => ChaosKind::Kill { rank: rank()? },
            "straggle" => ChaosKind::Straggle {
                rank: rank()?,
                factor: get_f(j, "factor").unwrap_or(2.0).max(1.0),
            },
            "partition" => ChaosKind::Partition {
                rank: rank()?,
                iters: get_u(j, "iters").unwrap_or(1).max(1) as usize,
            },
            "grow" => ChaosKind::Grow {
                count: get_u(j, "count").unwrap_or(1).max(1) as usize,
            },
            other => bail!("chaos event: unknown kind {other:?} (kill|straggle|partition|grow)"),
        };
        Ok(ChaosEvent { at_iter, kind })
    }
}

impl Scenario {
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("name".into(), Json::str(self.name.clone())),
            ("nodes".into(), Json::num(self.nodes as f64)),
            ("spares".into(), Json::num(self.spares as f64)),
            ("iters".into(), Json::num(self.iters as f64)),
            ("elems".into(), Json::num(self.elems as f64)),
            ("seed".into(), Json::num(self.seed as f64)),
            (
                "events".into(),
                Json::Arr(self.events.iter().map(ChaosEvent::to_json).collect()),
            ),
        ])
    }

    pub fn from_json(j: &Json) -> Result<Scenario> {
        let nodes = get_u(j, "nodes").context("scenario: missing nodes")? as usize;
        let iters = get_u(j, "iters").context("scenario: missing iters")? as usize;
        if nodes < 2 {
            bail!("scenario: nodes must be >= 2 (a ring needs members), got {nodes}");
        }
        if iters < 1 {
            bail!("scenario: iters must be >= 1");
        }
        let mut events = Vec::new();
        if let Some(Json::Arr(items)) = j.get("events") {
            for (i, item) in items.iter().enumerate() {
                let ev =
                    ChaosEvent::from_json(item).with_context(|| format!("scenario events[{i}]"))?;
                if ev.at_iter >= iters {
                    bail!("scenario events[{i}]: at_iter {} >= iters {iters}", ev.at_iter);
                }
                events.push(ev);
            }
        }
        Ok(Scenario {
            name: get_s(j, "name").unwrap_or_else(|| "unnamed".into()),
            nodes,
            spares: get_u(j, "spares").unwrap_or(0) as usize,
            iters,
            elems: get_u(j, "elems").unwrap_or(1024) as usize,
            seed: get_u(j, "seed").unwrap_or(0),
            events,
        })
    }

    /// Parse a scenario file.
    pub fn load(path: &str) -> Result<Scenario> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("read scenario {path}"))?;
        let j = Json::parse(text.trim())
            .map_err(|e| anyhow::anyhow!("scenario {path}: json parse: {e}"))?;
        Scenario::from_json(&j).with_context(|| format!("scenario {path}"))
    }

    pub fn save(&self, path: &str) -> Result<()> {
        self.to_json().write(path).with_context(|| format!("write scenario {path}"))
    }
}

/// Per-span-kind mean service times driving the replay's virtual-time
/// arithmetic. Defaults model the toy ES chaos demo; calibrating from a
/// recorded trace ([`Calibration::from_dump`]) is what couples a *record*
/// to its *re-drive*.
#[derive(Clone, Debug)]
pub struct Calibration {
    pub pool_run_ns: u64,
    pub allreduce_ns: u64,
    pub heal_ns: u64,
    pub fetch_ns: u64,
    pub put_ns: u64,
    pub dispatch_ns: u64,
    /// One-way envelope/RPC latency between leader and members.
    pub rpc_ns: u64,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration {
            pool_run_ns: 20_000_000,
            allreduce_ns: 8_000_000,
            heal_ns: 3_000_000,
            fetch_ns: 2_000_000,
            put_ns: 1_000_000,
            dispatch_ns: 300_000,
            rpc_ns: 200_000,
        }
    }
}

impl Calibration {
    /// Mean span durations from a recorded dump; kinds absent from the
    /// recording keep their defaults.
    pub fn from_dump(dump: &TraceDump) -> Calibration {
        let mut c = Calibration::default();
        let mean = |name: &str| {
            let mut sum = 0u64;
            let mut n = 0u64;
            for (_, ev) in &dump.events {
                if ev.name == name && ev.dur_ns > 0 {
                    sum += ev.dur_ns;
                    n += 1;
                }
            }
            (n > 0).then(|| sum / n)
        };
        if let Some(v) = mean("pool.run") {
            c.pool_run_ns = v;
        }
        if let Some(v) = mean("ring.allreduce") {
            c.allreduce_ns = v;
        }
        if let Some(v) = mean("ring.heal") {
            c.heal_ns = v;
        }
        if let Some(v) = mean("store.fetch") {
            c.fetch_ns = v;
        }
        if let Some(v) = mean("store.put") {
            c.put_ns = v;
        }
        if let Some(v) = mean("pool.dispatch") {
            c.dispatch_ns = v;
        }
        c
    }
}

/// Re-drive `scenario` on the virtual clock and return the synthesized
/// trace (time-sorted, loss-free) plus the driver's run statistics. The
/// emitted dump is expected to pass [`super::check::check`] — the
/// integration tests and the CI replay smoke both enforce that.
pub fn replay(
    scenario: &Scenario,
    cal: &Calibration,
) -> Result<(TraceDump, crate::cluster::simk8s::ReplayStats)> {
    let driver = crate::cluster::simk8s::ReplayDriver::new(scenario.clone(), cal.clone());
    let outcome = driver.run()?;
    let mut events = outcome.events;
    events.sort_by_key(|(_, e)| e.ts_ns);
    Ok((TraceDump::new(events, 0), outcome.stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::check::check;

    fn storm() -> Scenario {
        Scenario {
            name: "test_storm".into(),
            nodes: 8,
            spares: 2,
            iters: 5,
            elems: 1024,
            seed: 3,
            events: vec![
                ChaosEvent { at_iter: 1, kind: ChaosKind::Kill { rank: 2 } },
                ChaosEvent {
                    at_iter: 2,
                    kind: ChaosKind::Straggle { rank: 3, factor: 4.0 },
                },
                ChaosEvent {
                    at_iter: 2,
                    kind: ChaosKind::Partition { rank: 4, iters: 1 },
                },
                ChaosEvent { at_iter: 3, kind: ChaosKind::Grow { count: 2 } },
            ],
        }
    }

    #[test]
    fn scenario_roundtrips_through_json() {
        let sc = storm();
        let text = sc.to_json().render();
        let back = Scenario::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, sc);
    }

    #[test]
    fn scenario_rejects_garbage() {
        let bad = Json::parse(r#"{"name":"x","nodes":1,"iters":3,"events":[]}"#).unwrap();
        assert!(Scenario::from_json(&bad).is_err(), "nodes < 2");
        let bad =
            Json::parse(r#"{"nodes":4,"iters":3,"events":[{"at_iter":9,"kind":"kill","rank":1}]}"#)
                .unwrap();
        assert!(Scenario::from_json(&bad).is_err(), "at_iter out of range");
        let bad =
            Json::parse(r#"{"nodes":4,"iters":3,"events":[{"at_iter":0,"kind":"meteor"}]}"#)
                .unwrap();
        assert!(Scenario::from_json(&bad).is_err(), "unknown kind");
    }

    #[test]
    fn calibration_reads_means_from_a_dump() {
        use crate::trace::TraceEvent;
        let mk = |dur, name: &str| TraceEvent {
            ts_ns: 0,
            dur_ns: dur,
            span: 1,
            parent: 0,
            tid: 1,
            name: name.into(),
            args: vec![],
        };
        let dump = TraceDump {
            events: vec![
                ("a".into(), mk(10, "pool.run")),
                ("a".into(), mk(30, "pool.run")),
                ("a".into(), mk(50, "ring.heal")),
            ],
            dropped: 0,
            crash: false,
        };
        let c = Calibration::from_dump(&dump);
        assert_eq!(c.pool_run_ns, 20);
        assert_eq!(c.heal_ns, 50);
        assert_eq!(c.fetch_ns, Calibration::default().fetch_ns, "absent kinds keep defaults");
    }

    #[test]
    fn replayed_storm_passes_the_invariant_checker() {
        let (dump, stats) = replay(&storm(), &Calibration::default()).unwrap();
        let rep = check(&dump, "replay");
        assert!(rep.ok(), "replayed trace must audit clean:\n{}", rep.render());
        assert_eq!(dump.dropped, 0);
        assert!(stats.kills == 1 && stats.grows >= 1, "{stats:?}");
        let has = |name: &str| dump.events.iter().any(|(_, e)| e.name == name);
        for kind in [
            "pop.slice",
            "pool.dispatch",
            "pool.run",
            "pool.restart",
            "ring.allreduce",
            "ring.heal",
            "ring.resume",
            "ring.adopt",
            "ring.grow",
            "store.put",
            "store.fetch",
            "store.hit",
            "store.release",
        ] {
            assert!(has(kind), "replay must emit {kind}");
        }
        // Virtual time moved, and the straggled iteration is the longest.
        assert!(stats.final_ns > 0);
    }

    #[test]
    fn replay_scales_to_a_thousand_nodes() {
        let sc = Scenario {
            name: "wide".into(),
            nodes: 1000,
            spares: 4,
            iters: 3,
            elems: 65536,
            seed: 11,
            events: vec![
                ChaosEvent { at_iter: 1, kind: ChaosKind::Kill { rank: 500 } },
                ChaosEvent { at_iter: 2, kind: ChaosKind::Grow { count: 8 } },
            ],
        };
        let (dump, stats) = replay(&sc, &Calibration::default()).unwrap();
        assert!(stats.members_final >= 1001, "{stats:?}");
        assert!(dump.events.len() > 6000, "got {}", dump.events.len());
        let rep = check(&dump, "wide");
        assert!(rep.ok(), "{}", rep.render());
    }

    #[test]
    fn replay_is_deterministic_for_a_seed() {
        let run = || {
            let (dump, _) = replay(&storm(), &Calibration::default()).unwrap();
            dump.events
                .iter()
                .map(|(n, e)| (n.clone(), e.ts_ns, e.span, e.name.clone()))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
