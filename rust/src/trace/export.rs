//! Trace exporters: Chrome trace-event JSON (Perfetto /
//! `chrome://tracing`), replayable JSONL (`docs/trace_schema.md`),
//! folded-stack flamegraph lines, and the per-span-kind latency summary
//! behind `fiber-cli trace-view`.
//!
//! The Chrome format is the *viewing* artifact; JSONL is the *replay*
//! artifact — one self-contained event object per line, append-friendly
//! and streamable, intended as the record side of the ROADMAP's
//! trace-driven cluster-simulation item. Both carry the span/parent ids,
//! so causality survives export and re-import.

use anyhow::{Context, Result};

use crate::benchkit::{Json, Table};
use crate::util::Histogram;

use super::collect::TraceDump;
use super::TraceEvent;

/// Stable small integer per node name (Chrome `pid`).
fn node_ids(dump: &TraceDump) -> Vec<String> {
    let mut nodes: Vec<String> = Vec::new();
    for (node, _) in &dump.events {
        if !nodes.contains(node) {
            nodes.push(node.clone());
        }
    }
    nodes
}

fn args_json(ev: &TraceEvent) -> Json {
    let mut fields: Vec<(String, Json)> = vec![
        ("span".into(), Json::num(ev.span as f64)),
        ("parent".into(), Json::num(ev.parent as f64)),
    ];
    for (k, v) in &ev.args {
        fields.push((k.clone(), Json::num(*v as f64)));
    }
    Json::Obj(fields)
}

/// Render a [`TraceDump`] as a Chrome trace-event JSON document:
/// `{"traceEvents":[...]}` with complete (`"X"`) events for spans and
/// instant (`"i"`) events for point events; one `pid` per node, one `tid`
/// per recording thread, span/parent ids carried in `args`.
pub fn chrome_json(dump: &TraceDump) -> Json {
    let nodes = node_ids(dump);
    let mut events: Vec<Json> = Vec::new();
    // Metadata: name the process lanes after the nodes they came from.
    for (pid, node) in nodes.iter().enumerate() {
        events.push(Json::Obj(vec![
            ("name".into(), Json::str("process_name")),
            ("ph".into(), Json::str("M")),
            ("pid".into(), Json::num(pid as f64)),
            ("tid".into(), Json::num(0.0)),
            (
                "args".into(),
                Json::Obj(vec![("name".into(), Json::str(node.clone()))]),
            ),
        ]));
    }
    for (node, ev) in &dump.events {
        let pid = nodes.iter().position(|n| n == node).unwrap_or(0);
        let mut fields: Vec<(String, Json)> = vec![
            ("name".into(), Json::str(ev.name.clone())),
            ("cat".into(), Json::str("fiber")),
            (
                "ph".into(),
                Json::str(if ev.dur_ns == 0 { "i" } else { "X" }),
            ),
            // Chrome timestamps are microseconds (fractional ok).
            ("ts".into(), Json::num(ev.ts_ns as f64 / 1000.0)),
        ];
        if ev.dur_ns == 0 {
            // Instant scope: thread.
            fields.push(("s".into(), Json::str("t")));
        } else {
            fields.push(("dur".into(), Json::num(ev.dur_ns as f64 / 1000.0)));
        }
        fields.push(("pid".into(), Json::num(pid as f64)));
        fields.push(("tid".into(), Json::num(ev.tid as f64)));
        fields.push(("args".into(), args_json(ev)));
        events.push(Json::Obj(fields));
    }
    Json::Obj(vec![
        ("traceEvents".into(), Json::Arr(events)),
        ("displayTimeUnit".into(), Json::str("ms")),
        ("dropped".into(), Json::num(dump.dropped as f64)),
    ])
}

/// Write the Chrome trace-event document to `path`.
pub fn write_chrome(path: &str, dump: &TraceDump) -> Result<()> {
    chrome_json(dump)
        .write(path)
        .with_context(|| format!("write trace {path}"))
}

pub(crate) fn jsonl_line(node: &str, ev: &TraceEvent) -> String {
    let mut args: Vec<(String, Json)> = Vec::new();
    for (k, v) in &ev.args {
        args.push((k.clone(), Json::num(*v as f64)));
    }
    Json::Obj(vec![
        ("node".into(), Json::str(node)),
        ("ts_ns".into(), Json::num(ev.ts_ns as f64)),
        ("dur_ns".into(), Json::num(ev.dur_ns as f64)),
        ("span".into(), Json::num(ev.span as f64)),
        ("parent".into(), Json::num(ev.parent as f64)),
        ("tid".into(), Json::num(ev.tid as f64)),
        ("name".into(), Json::str(ev.name.clone())),
        ("args".into(), Json::Obj(args)),
    ])
    .render()
}

/// Write the replayable JSONL stream (one event object per line, time
/// order; schema in `docs/trace_schema.md`), closed by a metadata footer
/// line carrying the journals' `dropped` counter. The footer goes *last*
/// so an event's 1-based line number equals its ordinal in the time-sorted
/// dump — which is exactly the `file:line` coordinate
/// [`super::check`] findings point at.
pub fn write_jsonl(path: &str, dump: &TraceDump) -> Result<()> {
    let mut out = String::new();
    for (node, ev) in &dump.events {
        out.push_str(&jsonl_line(node, ev));
        out.push('\n');
    }
    out.push_str(&meta_footer(dump.dropped, dump.crash));
    out.push('\n');
    std::fs::write(path, out).with_context(|| format!("write trace {path}"))
}

/// The JSONL metadata footer line. `crash` marks a flight-recorder crash
/// window (a bounded suffix of the run — [`super::check`] relaxes
/// whole-run invariants when it sees this).
pub(crate) fn meta_footer(dropped: u64, crash: bool) -> String {
    let mut fields = vec![
        ("fiber_trace_meta".to_string(), Json::num(1.0)),
        ("dropped".to_string(), Json::num(dropped as f64)),
    ];
    if crash {
        fields.push(("crash".to_string(), Json::num(1.0)));
    }
    Json::Obj(fields).render()
}

fn num_u64(j: Option<&Json>) -> u64 {
    match j {
        Some(Json::Num(x)) if x.is_finite() && *x >= 0.0 => *x as u64,
        _ => 0,
    }
}

fn num_f64(j: Option<&Json>) -> f64 {
    match j {
        Some(Json::Num(x)) if x.is_finite() && *x >= 0.0 => *x,
        _ => 0.0,
    }
}

fn str_of(j: Option<&Json>) -> String {
    match j {
        Some(Json::Str(s)) => s.clone(),
        _ => String::new(),
    }
}

fn event_from_obj(obj: &Json, chrome: bool) -> Option<(String, TraceEvent)> {
    let name = str_of(obj.get("name"));
    if name.is_empty() {
        return None;
    }
    let args: Vec<(String, i64)> = match obj.get("args") {
        Some(Json::Obj(fields)) => fields
            .iter()
            .filter(|(k, _)| !chrome || (k != "span" && k != "parent"))
            .filter_map(|(k, v)| match v {
                Json::Num(x) if x.is_finite() => Some((k.clone(), *x as i64)),
                _ => None,
            })
            .collect(),
        _ => Vec::new(),
    };
    let (ts_ns, dur_ns, span, parent, node) = if chrome {
        if str_of(obj.get("ph")) == "M" {
            return None; // metadata, not an event
        }
        let a = obj.get("args");
        // Chrome timestamps are fractional microseconds; parse as f64 and
        // round, or sub-µs spans would truncate to 0-dur instants and the
        // invariant checker would flag them as never-ending spans.
        (
            (num_f64(obj.get("ts")) * 1000.0).round() as u64,
            (num_f64(obj.get("dur")) * 1000.0).round() as u64,
            num_u64(a.and_then(|a| a.get("span"))),
            num_u64(a.and_then(|a| a.get("parent"))),
            format!("pid-{}", num_u64(obj.get("pid"))),
        )
    } else {
        (
            num_u64(obj.get("ts_ns")),
            num_u64(obj.get("dur_ns")),
            num_u64(obj.get("span")),
            num_u64(obj.get("parent")),
            str_of(obj.get("node")),
        )
    };
    Some((
        node,
        TraceEvent {
            ts_ns,
            dur_ns,
            span,
            parent,
            tid: num_u64(obj.get("tid")) as u32,
            name,
            args,
        },
    ))
}

/// One parsed JSONL text: events plus whatever the footer(s) carried.
struct JsonlParse {
    events: Vec<(String, TraceEvent)>,
    dropped: u64,
    crash: bool,
}

/// Parse JSONL trace text. With `lenient_tail`, an unparseable *final*
/// non-empty line is discarded instead of failing the read — a process
/// killed mid-append (SIGKILL during a live-segment write) leaves exactly
/// one truncated trailing line, and losing that one event must not make
/// the surviving history unreadable.
fn parse_jsonl(text: &str, lenient_tail: bool) -> Result<JsonlParse> {
    let lines: Vec<&str> = text
        .lines()
        .map(|l| l.trim())
        .filter(|l| !l.is_empty())
        .collect();
    let mut out = JsonlParse {
        events: Vec::new(),
        dropped: 0,
        crash: false,
    };
    for (i, line) in lines.iter().enumerate() {
        let obj = match Json::parse(line) {
            Ok(o) => o,
            Err(e) if lenient_tail && i + 1 == lines.len() => {
                let _ = e; // torn tail from a kill mid-write: drop it
                break;
            }
            Err(e) => return Err(anyhow::anyhow!("trace jsonl parse: {e}")),
        };
        if obj.get("fiber_trace_meta").is_some() {
            // Footer line written by `write_jsonl` / the segment writer —
            // carries the dropped counter (and crash marker), not an event.
            out.dropped += num_u64(obj.get("dropped"));
            out.crash |= num_u64(obj.get("crash")) != 0;
            continue;
        }
        if let Some(pair) = event_from_obj(&obj, false) {
            out.events.push(pair);
        }
    }
    Ok(out)
}

/// Load a trace back into a [`TraceDump`]. `path` may be a file written by
/// [`write_chrome`] or [`write_jsonl`] (format sniffed from the content),
/// or a **live-segment directory** produced by a `--live` run — see
/// [`read_trace_dir`]. This is what `fiber-cli trace-view` summarizes and
/// `trace-check` audits.
pub fn read_trace(path: &str) -> Result<TraceDump> {
    if std::fs::metadata(path).map(|m| m.is_dir()).unwrap_or(false) {
        return read_trace_dir(path);
    }
    let text = std::fs::read_to_string(path).with_context(|| format!("read trace {path}"))?;
    let trimmed = text.trim_start();
    let mut events: Vec<(String, TraceEvent)> = Vec::new();
    let mut dropped = 0u64;
    let mut crash = false;
    if trimmed.starts_with('{') && !trimmed.contains('\n') || trimmed.starts_with("{\"traceEvents\"") {
        // Chrome document: one object with a traceEvents array.
        let doc = Json::parse(text.trim())
            .map_err(|e| anyhow::anyhow!("trace json parse: {e}"))?;
        dropped = num_u64(doc.get("dropped"));
        if let Some(Json::Arr(items)) = doc.get("traceEvents") {
            for item in items {
                if let Some(pair) = event_from_obj(item, true) {
                    events.push(pair);
                }
            }
        }
    } else {
        let parsed = parse_jsonl(&text, false)?;
        events = parsed.events;
        dropped = parsed.dropped;
        crash = parsed.crash;
    }
    events.sort_by_key(|(_, e)| e.ts_ns);
    Ok(TraceDump {
        events,
        dropped,
        crash,
    })
}

/// Load a live-segment directory (`segment-0000.jsonl`, `segment-0001.jsonl`,
/// …) written by [`super::live::SegmentWriter`] and merge it into one
/// [`TraceDump`], exactly as if the run had exported a single file:
///
/// * segments are read in name order (zero-padded rotation indices sort
///   lexicographically);
/// * each segment's footer carries its *delta* of the dropped counter, so
///   summing them reconstructs the run total without double counting;
/// * the **last** segment tolerates a torn final line and a missing footer
///   — that is precisely what a SIGKILL mid-run leaves behind, and the
///   surviving segments 0..N−1 must still audit cleanly.
pub fn read_trace_dir(dir: &str) -> Result<TraceDump> {
    let mut paths: Vec<std::path::PathBuf> = std::fs::read_dir(dir)
        .with_context(|| format!("read trace dir {dir}"))?
        .filter_map(|e| e.ok())
        .map(|e| e.path())
        .filter(|p| {
            p.file_name()
                .and_then(|n| n.to_str())
                .map(|n| n.starts_with("segment-") && n.ends_with(".jsonl"))
                .unwrap_or(false)
        })
        .collect();
    paths.sort();
    if paths.is_empty() {
        anyhow::bail!("no segment-*.jsonl files in {dir}");
    }
    let mut events: Vec<(String, TraceEvent)> = Vec::new();
    let mut dropped = 0u64;
    let mut crash = false;
    let last = paths.len() - 1;
    for (i, p) in paths.iter().enumerate() {
        let text = std::fs::read_to_string(p)
            .with_context(|| format!("read trace segment {}", p.display()))?;
        let parsed = parse_jsonl(&text, i == last)
            .with_context(|| format!("segment {}", p.display()))?;
        events.extend(parsed.events);
        dropped += parsed.dropped;
        crash |= parsed.crash;
    }
    events.sort_by_key(|(_, e)| e.ts_ns);
    Ok(TraceDump {
        events,
        dropped,
        crash,
    })
}

/// Write the folded-stack (flamegraph) rendering of `dump` to `path`:
/// one `frame;frame;frame weight` line per causal stack, weights in µs of
/// exclusive time — ready for `flamegraph.pl` / `inferno-flamegraph` or
/// speedscope's "folded" importer. See [`super::analyze::folded_stacks`].
pub fn write_folded(path: &str, dump: &TraceDump) -> Result<()> {
    std::fs::write(path, super::analyze::folded_stacks(dump))
        .with_context(|| format!("write folded stacks {path}"))
}

/// Per-span-kind latency summary: count, p50/p99/mean duration in µs
/// (instants report count only). Rows sorted by name.
pub fn summary(dump: &TraceDump) -> Table {
    let mut kinds: Vec<(String, u64, Histogram)> = Vec::new();
    for (_, ev) in &dump.events {
        let entry = match kinds.iter_mut().find(|(n, _, _)| *n == ev.name) {
            Some(e) => e,
            None => {
                kinds.push((ev.name.clone(), 0, Histogram::new()));
                kinds.last_mut().unwrap()
            }
        };
        entry.1 += 1;
        if ev.dur_ns > 0 {
            entry.2.record_ns(ev.dur_ns);
        }
    }
    kinds.sort_by(|a, b| a.0.cmp(&b.0));
    let mut t = Table::new(
        format!(
            "trace summary — {} events, {} dropped",
            dump.events.len(),
            dump.dropped
        ),
        "span kind",
        vec![
            "count".into(),
            "p50 µs".into(),
            "p99 µs".into(),
            "mean µs".into(),
        ],
    );
    t.unit = "";
    for (name, count, hist) in &kinds {
        let spans = hist.count() > 0;
        t.add_row(
            name.clone(),
            vec![
                Some(*count as f64),
                spans.then(|| hist.quantile_ns(0.5) as f64 / 1000.0),
                spans.then(|| hist.quantile_ns(0.99) as f64 / 1000.0),
                spans.then(|| hist.mean_ns() / 1000.0),
            ],
        );
    }
    t
}

#[cfg(test)]
mod tests {
    use super::*;

    fn dump() -> TraceDump {
        let mk = |ts, dur, span, parent, name: &str, args: Vec<(String, i64)>| TraceEvent {
            ts_ns: ts,
            dur_ns: dur,
            span,
            parent,
            tid: 1,
            name: name.into(),
            args,
        };
        TraceDump {
            events: vec![
                (
                    "leader".into(),
                    mk(1000, 5000, 2, 0, "ring.allreduce", vec![("gen".into(), 1)]),
                ),
                ("leader".into(), mk(2000, 0, 3, 2, "ring.resume", vec![])),
                (
                    "worker".into(),
                    mk(2500, 800, 4, 2, "store.fetch", vec![("bytes".into(), 64)]),
                ),
            ],
            dropped: 7,
            crash: false,
        }
    }

    #[test]
    fn chrome_json_is_valid_and_typed() {
        let d = dump();
        let doc = chrome_json(&d);
        let text = doc.render();
        let back = Json::parse(&text).expect("chrome trace must be valid JSON");
        let evs = back.get("traceEvents").expect("traceEvents array");
        // 2 process_name metadata records + 3 events.
        assert!(matches!(evs, Json::Arr(v) if v.len() == 5));
        // The span event is a complete ("X") event with µs units.
        let x = evs.at(2).unwrap();
        assert!(matches!(x.get("ph"), Some(Json::Str(s)) if s == "X"));
        assert!(matches!(x.get("ts"), Some(Json::Num(v)) if *v == 1.0));
        assert!(matches!(x.get("dur"), Some(Json::Num(v)) if *v == 5.0));
        // The instant keeps its parent link in args.
        let i = evs.at(3).unwrap();
        assert!(matches!(i.get("ph"), Some(Json::Str(s)) if s == "i"));
        assert!(
            matches!(i.get("args").and_then(|a| a.get("parent")), Some(Json::Num(v)) if *v == 2.0)
        );
        assert!(matches!(back.get("dropped"), Some(Json::Num(v)) if *v == 7.0));
    }

    #[test]
    fn jsonl_roundtrips_through_read_trace() {
        let d = dump();
        let path = std::env::temp_dir().join("fiber_trace_test.jsonl");
        let path = path.to_str().unwrap().to_string();
        write_jsonl(&path, &d).unwrap();
        let back = read_trace(&path).unwrap();
        assert_eq!(back.events.len(), 3, "the meta footer is not an event");
        assert_eq!(back.dropped, 7, "the meta footer carries dropped");
        assert_eq!(back.events[0].0, "leader");
        assert_eq!(back.events[2].1.name, "store.fetch");
        assert_eq!(back.events[2].1.parent, 2);
        assert_eq!(back.events[2].1.arg("bytes"), Some(64));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn chrome_read_keeps_submicrosecond_durations() {
        // A 800 ns span exports as dur=0.8 µs; reading it back must not
        // truncate to a 0-dur instant (which the checker would flag as a
        // span that never ends).
        let d = dump();
        let path = std::env::temp_dir().join("fiber_trace_test_subus.json");
        let path = path.to_str().unwrap().to_string();
        write_chrome(&path, &d).unwrap();
        let back = read_trace(&path).unwrap();
        let fetch = back
            .events
            .iter()
            .find(|(_, e)| e.name == "store.fetch")
            .unwrap();
        assert_eq!(fetch.1.dur_ns, 800, "fractional µs survive the round trip");
        assert_eq!(fetch.1.ts_ns, 2500);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn folded_output_writes_stack_lines() {
        let d = dump();
        let path = std::env::temp_dir().join("fiber_trace_test.folded");
        let path = path.to_str().unwrap().to_string();
        write_folded(&path, &d).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        // 5000 ns allreduce minus the 800 ns nested fetch → 4 µs exclusive;
        // the fetch itself is sub-µs so its own line rounds away.
        assert_eq!(text.trim(), "ring.allreduce 4", "{text}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn chrome_roundtrips_through_read_trace() {
        let d = dump();
        let path = std::env::temp_dir().join("fiber_trace_test.json");
        let path = path.to_str().unwrap().to_string();
        write_chrome(&path, &d).unwrap();
        let back = read_trace(&path).unwrap();
        assert_eq!(back.events.len(), 3, "metadata records are not events");
        assert_eq!(back.dropped, 7);
        let heal = back
            .events
            .iter()
            .find(|(_, e)| e.name == "ring.resume")
            .unwrap();
        assert_eq!(heal.1.parent, 2, "causal links survive chrome export");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn crash_footer_roundtrips() {
        let mut d = dump();
        d.crash = true;
        let path = std::env::temp_dir().join("fiber_trace_test_crash.jsonl");
        let path = path.to_str().unwrap().to_string();
        write_jsonl(&path, &d).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("\"crash\""), "{text}");
        let back = read_trace(&path).unwrap();
        assert!(back.crash, "crash marker survives the round trip");
        assert_eq!(back.dropped, 7);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn segment_dir_merges_sums_deltas_and_tolerates_torn_tail() {
        let d = dump();
        let dir = std::env::temp_dir().join("fiber_trace_test_segdir");
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // Segment 0: first two events, dropped delta 3.
        let mut seg0 = String::new();
        for (node, ev) in &d.events[..2] {
            seg0.push_str(&jsonl_line(node, ev));
            seg0.push('\n');
        }
        seg0.push_str(&meta_footer(3, false));
        seg0.push('\n');
        std::fs::write(dir.join("segment-0000.jsonl"), seg0).unwrap();
        // Segment 1: last event, dropped delta 4, then a torn half-line and
        // no footer — what a SIGKILL mid-append leaves behind.
        let mut seg1 = String::new();
        seg1.push_str(&jsonl_line(&d.events[2].0, &d.events[2].1));
        seg1.push('\n');
        seg1.push_str(&meta_footer(4, false));
        seg1.push('\n');
        seg1.push_str("{\"node\":\"worker\",\"ts_ns\":99");
        std::fs::write(dir.join("segment-0001.jsonl"), seg1).unwrap();
        // An unrelated file in the directory is ignored.
        std::fs::write(dir.join("notes.txt"), "not a segment").unwrap();

        let back = read_trace(dir.to_str().unwrap()).unwrap();
        assert_eq!(back.events.len(), 3, "all segments merged, torn tail dropped");
        assert_eq!(back.dropped, 7, "per-segment deltas sum to the run total");
        assert!(!back.crash);
        assert_eq!(back.events[2].1.name, "store.fetch");
        // A torn line anywhere *except* the final segment's tail is still
        // an error — silent mid-run corruption must not pass.
        std::fs::write(
            dir.join("segment-0000.jsonl"),
            "{\"node\":\"worker\",\"ts_ns\":99",
        )
        .unwrap();
        assert!(read_trace(dir.to_str().unwrap()).is_err());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn summary_counts_and_quantiles() {
        let t = summary(&dump());
        let s = t.render();
        assert!(s.contains("ring.allreduce"), "{s}");
        assert!(s.contains("ring.resume"), "{s}");
        assert!(s.contains("dropped"), "{s}");
    }
}
