//! Leader-side aggregation: drain every node's [`Journal`] into one
//! leader-clock timeline.
//!
//! Two source kinds, mirroring the Pool's two backends:
//!
//! * **Local** — an `Arc<Journal>` shared in-process (the thread backend
//!   and the leader's own journal). Draining is a lock-and-take; clocks
//!   trivially agree because there is only one.
//! * **Remote** — a TCP node serving its journal via [`serve_journal`]
//!   over [`crate::comms::rpc`]. Monotonic clocks of different processes
//!   have unrelated epochs, so admission performs an NTP-style midpoint
//!   probe: the leader notes its own clock `t0`, asks the remote for its
//!   clock reading `r`, notes `t1` on reply, and estimates
//!   `offset = (t0 + t1)/2 − r` — the remote's reading is assumed to
//!   happen at the RPC midpoint. The probe repeats a few times and keeps
//!   the minimum-RTT estimate (least queueing noise). Drained remote
//!   timestamps are re-based by that offset.

use std::net::SocketAddr;
use std::sync::Arc;

use anyhow::{Context, Result};

use crate::comms::rpc::{RpcClient, RpcServer};
use crate::wire;

use super::{Journal, TraceEvent};

/// RPC tags of the journal-drain protocol.
pub mod tags {
    /// Request: empty. Reply: `u64` — the node's journal clock, ns.
    pub const CLOCK: u32 = 1;
    /// Request: empty. Reply: `(String, Vec<TraceEvent>, u64)` — node
    /// name, buffered events (journal is emptied), dropped count.
    pub const DRAIN: u32 = 2;
    /// Request: `u64` — the caller's cursor (acknowledges every event with
    /// a smaller sequence). Reply: `(String, Vec<TraceEvent>, u64, u64)` —
    /// node name, unacknowledged events (at-least-once: they stay buffered
    /// until a later cursor acks them), next cursor, dropped count.
    pub const DRAIN_SINCE: u32 = 3;
}

/// Serve `journal` for remote collection. Bind with port 0 for an
/// ephemeral port; hand `local_addr()` to the leader's
/// [`Collector::add_remote`].
pub fn serve_journal(journal: Arc<Journal>, bind: &str) -> Result<RpcServer> {
    RpcServer::bind(
        bind,
        Arc::new(move |tag, payload| match tag {
            tags::CLOCK => Ok(wire::to_bytes(&journal.now_ns())),
            tags::DRAIN => {
                let (events, dropped) = journal.drain();
                Ok(wire::to_bytes(&(journal.node_name(), events, dropped)))
            }
            tags::DRAIN_SINCE => {
                let cursor: u64 =
                    wire::from_bytes(payload).map_err(|e| format!("cursor decode: {e}"))?;
                let (events, next, dropped) = journal.drain_since(cursor);
                Ok(wire::to_bytes(&(journal.node_name(), events, next, dropped)))
            }
            other => Err(format!("unknown trace rpc tag {other}")),
        }),
    )
}

enum Source {
    Local {
        journal: Arc<Journal>,
        /// Incremental-drain cursor ([`Journal::drain_since`] semantics).
        cursor: u64,
    },
    Remote {
        name: String,
        cli: RpcClient,
        /// Added to remote timestamps to express them on the reference
        /// (leader) clock. Signed: the remote may have booted first.
        /// Re-probed (EWMA-smoothed) on every incremental drain so clock
        /// *drift* — not just epoch skew — stays corrected on long runs.
        offset_ns: i64,
        /// Incremental-drain cursor acknowledged to the remote.
        cursor: u64,
    },
}

/// EWMA weight (3/10) applied to fresh offset probes during incremental
/// drains: heavy enough to track real drift within a few cadence ticks,
/// light enough that one queueing-noise outlier cannot yank the timeline.
const OFFSET_EWMA_NUM: i64 = 3;
const OFFSET_EWMA_DEN: i64 = 10;

/// Everything one collection pass produced: per-node events re-based onto
/// the leader clock and merged in timestamp order, plus the total dropped
/// count (the honesty figure every summary must carry).
pub struct TraceDump {
    /// `(node, event)` pairs, sorted by aligned `ts_ns`.
    pub events: Vec<(String, TraceEvent)>,
    pub dropped: u64,
    /// True when this dump is a crash flight-recorder window: a bounded
    /// suffix of the run, so whole-run invariants cannot be audited
    /// ([`super::check`] relaxes them).
    pub crash: bool,
}

impl TraceDump {
    /// A normal (non-crash) dump.
    pub fn new(events: Vec<(String, TraceEvent)>, dropped: u64) -> TraceDump {
        TraceDump {
            events,
            dropped,
            crash: false,
        }
    }

    /// Events with a given name (span kind), in time order.
    pub fn named(&self, name: &str) -> Vec<&TraceEvent> {
        self.events
            .iter()
            .filter(|(_, e)| e.name == name)
            .map(|(_, e)| e)
            .collect()
    }

    /// The event that owns span id `id`, if collected.
    pub fn span(&self, id: u64) -> Option<&TraceEvent> {
        self.events.iter().map(|(_, e)| e).find(|e| e.span == id)
    }
}

/// The leader-side drain: registered sources are polled by [`Collector::drain`].
#[derive(Default)]
pub struct Collector {
    sources: Vec<Source>,
    /// The clock every timestamp is re-based onto (the leader's own
    /// journal, which is also usually one of the sources).
    reference: Option<Arc<Journal>>,
}

impl Collector {
    pub fn new() -> Collector {
        Collector::default()
    }

    /// Add an in-process journal (Arc fast path — no copy until drain).
    /// The first local journal becomes the reference clock.
    pub fn add_local(&mut self, journal: Arc<Journal>) {
        if self.reference.is_none() {
            self.reference = Some(journal.clone());
        }
        self.sources.push(Source::Local { journal, cursor: 0 });
    }

    /// Convenience: add this process's global journal.
    pub fn add_global(&mut self) {
        self.add_local(super::global());
    }

    /// Connect to a remote [`serve_journal`] endpoint and estimate its
    /// clock offset by RPC-midpoint probing.
    pub fn add_remote(&mut self, addr: SocketAddr) -> Result<()> {
        let reference = match &self.reference {
            Some(j) => j.clone(),
            None => {
                let j = super::global();
                self.reference = Some(j.clone());
                j
            }
        };
        let cli = RpcClient::connect(addr).context("trace collector connect")?;
        let offset_ns = probe_offset(&reference, &cli, 5)?;
        let name = format!("{addr}");
        self.sources.push(Source::Remote {
            name,
            cli,
            offset_ns,
            cursor: 0,
        });
        Ok(())
    }

    /// Number of registered sources.
    pub fn sources(&self) -> usize {
        self.sources.len()
    }

    /// Current clock-offset estimate for remote source `idx` (test and
    /// diagnostics hook; `None` for local sources).
    pub fn offset_ns(&self, idx: usize) -> Option<i64> {
        match self.sources.get(idx)? {
            Source::Local { .. } => None,
            Source::Remote { offset_ns, .. } => Some(*offset_ns),
        }
    }

    /// Drain every source, align clocks, and merge into one timeline. A
    /// remote that died since admission contributes nothing (its events
    /// are lost with it — the trace reports what was observable).
    pub fn drain(&mut self) -> TraceDump {
        let mut out: Vec<(String, TraceEvent)> = Vec::new();
        let mut dropped = 0u64;
        for src in &self.sources {
            match src {
                Source::Local { journal, .. } => {
                    let (events, d) = journal.drain();
                    let node = journal.node_name();
                    dropped += d;
                    out.extend(events.into_iter().map(|e| (node.clone(), e)));
                }
                Source::Remote {
                    name,
                    cli,
                    offset_ns,
                    ..
                } => {
                    let Ok(reply) = cli.call(tags::DRAIN, &[]) else {
                        continue;
                    };
                    let Ok((node, events, d)) =
                        wire::from_bytes::<(String, Vec<TraceEvent>, u64)>(&reply)
                    else {
                        continue;
                    };
                    dropped += d;
                    let node = if node.is_empty() { name.clone() } else { node };
                    out.extend(events.into_iter().map(|mut e| {
                        e.ts_ns = (e.ts_ns as i64).saturating_add(*offset_ns).max(0) as u64;
                        (node.clone(), e)
                    }));
                }
            }
        }
        out.sort_by_key(|(_, e)| e.ts_ns);
        TraceDump::new(out, dropped)
    }

    /// Incremental pull: collect only what arrived since the previous
    /// call, acknowledging consumed events via per-source cursors. This is
    /// the live-streaming path — call it on a cadence (the
    /// [`super::live::Streamer`] does) and the run's telemetry lands on
    /// disk *while it runs* instead of at exit.
    ///
    /// Remote clocks are **re-probed on every pull** and blended into the
    /// running offset with an EWMA, so drift between the leader's and a
    /// worker's monotonic clock is corrected continuously instead of being
    /// frozen at admission time. An unreachable remote contributes nothing
    /// this round and — because its cursor is unchanged — re-delivers the
    /// same window once it comes back.
    ///
    /// `dropped` in the returned dump is the *cumulative* per-journal drop
    /// count, same as [`Collector::drain`]; segment writers turn it into
    /// per-segment deltas.
    pub fn drain_incremental(&mut self) -> TraceDump {
        let reference = self.reference.clone();
        let mut out: Vec<(String, TraceEvent)> = Vec::new();
        let mut dropped = 0u64;
        for src in &mut self.sources {
            match src {
                Source::Local { journal, cursor } => {
                    let (events, next, d) = journal.drain_since(*cursor);
                    *cursor = next;
                    dropped += d;
                    let node = journal.node_name();
                    out.extend(events.into_iter().map(|e| (node.clone(), e)));
                }
                Source::Remote {
                    name,
                    cli,
                    offset_ns,
                    cursor,
                } => {
                    // Re-align first: two quick probes, EWMA-blended, so a
                    // drifting remote clock stays pinned to the reference.
                    if let Some(reference) = &reference {
                        if let Ok(fresh) = probe_offset(reference, cli, 2) {
                            *offset_ns += (fresh - *offset_ns) * OFFSET_EWMA_NUM / OFFSET_EWMA_DEN;
                        }
                    }
                    let Ok(reply) = cli.call(tags::DRAIN_SINCE, &wire::to_bytes(cursor)) else {
                        continue; // cursor unchanged: retry next cadence
                    };
                    let Ok((node, events, next, d)) =
                        wire::from_bytes::<(String, Vec<TraceEvent>, u64, u64)>(&reply)
                    else {
                        continue;
                    };
                    *cursor = next;
                    dropped += d;
                    let node = if node.is_empty() { name.clone() } else { node };
                    out.extend(events.into_iter().map(|mut e| {
                        e.ts_ns = (e.ts_ns as i64).saturating_add(*offset_ns).max(0) as u64;
                        (node.clone(), e)
                    }));
                }
            }
        }
        out.sort_by_key(|(_, e)| e.ts_ns);
        TraceDump::new(out, dropped)
    }
}

/// One NTP-style offset estimate: `probes` round trips, keep the
/// minimum-RTT midpoint (least queueing noise). Returns the amount to add
/// to remote timestamps to express them on the reference clock.
fn probe_offset(reference: &Journal, cli: &RpcClient, probes: usize) -> Result<i64> {
    let mut best_rtt = u64::MAX;
    let mut offset_ns = 0i64;
    for _ in 0..probes {
        let t0 = reference.now_ns();
        let reply = cli.call(tags::CLOCK, &[]).context("trace clock probe")?;
        let t1 = reference.now_ns();
        let remote: u64 =
            wire::from_bytes(&reply).map_err(|e| anyhow::anyhow!("clock decode: {e}"))?;
        let rtt = t1.saturating_sub(t0);
        if rtt < best_rtt {
            best_rtt = rtt;
            let midpoint = (t0 / 2) + (t1 / 2);
            offset_ns = midpoint as i64 - remote as i64;
        }
    }
    Ok(offset_ns)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ev(ts: u64, span: u64, name: &str) -> TraceEvent {
        TraceEvent {
            ts_ns: ts,
            dur_ns: 10,
            span,
            parent: 0,
            tid: 1,
            name: name.into(),
            args: vec![],
        }
    }

    #[test]
    fn local_drain_merges_in_time_order() {
        let a = Journal::with_capacity(16);
        let b = Journal::with_capacity(16);
        a.set_node_name("a");
        b.set_node_name("b");
        a.record(ev(50, 1, "x"));
        b.record(ev(20, 2, "y"));
        let mut c = Collector::new();
        c.add_local(a);
        c.add_local(b);
        let dump = c.drain();
        assert_eq!(dump.events.len(), 2);
        assert_eq!(dump.events[0].0, "b");
        assert_eq!(dump.events[1].0, "a");
        assert_eq!(dump.dropped, 0);
        assert_eq!(dump.named("x").len(), 1);
        assert!(dump.span(2).is_some());
    }

    #[test]
    fn remote_drain_aligns_clocks() {
        // The reference journal and the "remote" journal are created at
        // different instants, so their raw clocks disagree by however long
        // the sleep below lasts; midpoint alignment must absorb it.
        let reference = Journal::with_capacity(16);
        std::thread::sleep(std::time::Duration::from_millis(30));
        let remote = Journal::with_capacity(16);
        remote.set_node_name("worker-1");
        let srv = serve_journal(remote.clone(), "127.0.0.1:0").unwrap();

        let mut c = Collector::new();
        c.add_local(reference.clone());
        c.add_remote(srv.local_addr()).unwrap();
        assert_eq!(c.sources(), 2);

        // Two "simultaneous" events, one on each clock.
        reference.record(ev(reference.now_ns(), 1, "ref"));
        remote.record(ev(remote.now_ns(), 2, "rem"));
        let dump = c.drain();
        assert_eq!(dump.events.len(), 2);
        let ref_ts = dump.named("ref")[0].ts_ns as i64;
        let rem_ts = dump.named("rem")[0].ts_ns as i64;
        // Raw clocks differ by >= 30ms; aligned clocks must agree to well
        // under that (loopback RTT noise, give it 10ms of slack).
        assert!(
            (ref_ts - rem_ts).abs() < 10_000_000,
            "aligned skew {} ns",
            ref_ts - rem_ts
        );
        assert_eq!(dump.named("rem")[0].span, 2);
        assert!(dump.events.iter().any(|(n, _)| n == "worker-1"));
    }

    #[test]
    fn incremental_drain_is_exactly_once_across_pulls() {
        let a = Journal::with_capacity(16);
        a.set_node_name("a");
        let remote = Journal::with_capacity(16);
        remote.set_node_name("worker-1");
        let srv = serve_journal(remote.clone(), "127.0.0.1:0").unwrap();

        let mut c = Collector::new();
        c.add_local(a.clone());
        c.add_remote(srv.local_addr()).unwrap();

        a.record(ev(10, 1, "x"));
        remote.record(ev(10, 2, "y"));
        let first = c.drain_incremental();
        assert_eq!(first.events.len(), 2);

        // Nothing new → nothing re-delivered (cursors acknowledged).
        let idle = c.drain_incremental();
        assert_eq!(idle.events.len(), 0, "acked events must not re-appear");

        a.record(ev(20, 3, "x"));
        remote.record(ev(20, 4, "y"));
        let second = c.drain_incremental();
        assert_eq!(second.events.len(), 2);
        assert!(second.events.iter().any(|(_, e)| e.span == 3));
        assert!(second.events.iter().any(|(_, e)| e.span == 4));
    }

    #[test]
    fn incremental_drain_tracks_drifting_remote_clock() {
        // Synthetic remote whose clock runs 25% fast on top of a 50 ms
        // epoch skew: skew(t) = 50ms + t/4 on the reference timeline. A
        // collector that probes the offset once at admission (the old
        // behavior) accumulates t/4 of alignment error; per-drain EWMA
        // re-probing must keep the aligned error a small fraction of that.
        let reference = Journal::with_capacity(64);
        let refc = reference.clone();
        let srv = RpcServer::bind(
            "127.0.0.1:0",
            Arc::new(move |tag, payload| {
                let t = refc.now_ns();
                let remote_now = t + 50_000_000 + t / 4;
                match tag {
                    tags::CLOCK => Ok(wire::to_bytes(&remote_now)),
                    tags::DRAIN_SINCE => {
                        let cursor: u64 =
                            wire::from_bytes(payload).map_err(|e| e.to_string())?;
                        // One fresh instant stamped "now" on the drifting clock.
                        let e = TraceEvent {
                            ts_ns: remote_now,
                            dur_ns: 0,
                            span: cursor + 1,
                            parent: 0,
                            tid: 1,
                            name: "drift.tick".into(),
                            args: vec![],
                        };
                        Ok(wire::to_bytes(&(
                            "drifty".to_string(),
                            vec![e],
                            cursor + 1,
                            0u64,
                        )))
                    }
                    other => Err(format!("unknown tag {other}")),
                }
            }),
        )
        .unwrap();

        let mut c = Collector::new();
        c.add_local(reference.clone());
        c.add_remote(srv.local_addr()).unwrap();

        let mut worst_err = 0i64;
        for _ in 0..8 {
            std::thread::sleep(std::time::Duration::from_millis(20));
            let dump = c.drain_incremental();
            let now = reference.now_ns() as i64;
            for e in dump.named("drift.tick") {
                worst_err = worst_err.max((e.ts_ns as i64 - now).abs());
            }
        }
        let accumulated_drift = (reference.now_ns() / 4) as i64;
        assert!(
            accumulated_drift > 25_000_000,
            "test must run long enough for drift to matter; got {accumulated_drift} ns"
        );
        assert!(
            worst_err < 25_000_000,
            "EWMA re-probe must bound aligned error well below the \
             {accumulated_drift} ns a frozen offset would accumulate; worst {worst_err} ns"
        );
    }

    #[test]
    fn dead_remote_is_skipped() {
        let remote = Journal::with_capacity(16);
        let srv = serve_journal(remote, "127.0.0.1:0").unwrap();
        let mut c = Collector::new();
        c.add_local(Journal::with_capacity(4));
        c.add_remote(srv.local_addr()).unwrap();
        drop(srv);
        std::thread::sleep(std::time::Duration::from_millis(20));
        let dump = c.drain();
        assert_eq!(dump.events.len(), 0, "dead remote contributes nothing");
    }
}
