#![cfg_attr(feature = "simd", feature(portable_simd))]

//! # fiber-rs
//!
//! A Rust reproduction of **Fiber** (Zhi, Wang, Clune, Stanley, 2020): a
//! distributed computing platform for reinforcement learning and
//! population-based methods, built on a multiprocessing-style API whose
//! processes are cluster jobs.
//!
//! The crate is organised in the paper's three architectural layers:
//!
//! * **API layer** ([`api`]): processes, pipes, queues, pools and managers
//!   with `multiprocessing` semantics, extended to distributed settings.
//! * **Backend layer** ([`cluster`]): pluggable cluster backends that create,
//!   track and terminate jobs (threads, real OS processes, or a simulated
//!   Kubernetes cluster with a virtual clock).
//! * **Cluster layer**: the simulated cluster manager in
//!   [`cluster::simk8s`] plus the real-OS substrate.
//!
//! Beside Pool/Queue sits the collective-communication layer:
//!
//! * **Ring layer** ([`ring`]): a rendezvous service that turns cluster
//!   jobs into ranked members of a ring (with generation bumps on
//!   join/leave/resize, mirroring dynamic scaling), plus chunked ring
//!   allreduce / broadcast / all-gather over `f32` buffers that work
//!   identically on the thread and OS-process backends. This is what lets
//!   ES and PPO combine updates peer-to-peer (`O(θ)` per node) instead of
//!   funnelling `O(pop·θ)` through one leader. Since the elastic-collectives
//!   refactor the ring is **self-healing**: collectives execute an explicit
//!   per-chunk step plan with recorded progress, members heartbeat the
//!   rendezvous while they wait, a dead member is reported and excised, and
//!   the survivors re-rank and resume from the first chunk any of them had
//!   not completed — the paper's pending-table failure story applied to
//!   collectives. The chunk pipeline is double-buffered so the next chunk's
//!   traffic is in flight while the current one reduces. And since the
//!   auto-grow change the elasticity runs both ways: standby members wait
//!   in a [`ring::spare`] pool, every heal (or an explicit
//!   [`ring::Rendezvous::grow`]) drains them into the new sealed
//!   generation, and the drained member adopts the in-flight collective
//!   through the same resume min-barrier — kill → heal → auto-grow back
//!   to the original world, inside one op. Algorithm drivers re-shard
//!   upward and state-sync the rejoiner
//!   ([`algo::es::EsRingNode::join_ring_as_spare`],
//!   [`algo::ppo::PpoTrainer::join_ring_as_spare`]), re-warming bulk
//!   tables through the store as cache hits.
//!
//! Fourth building block, beside Pool/Queue/Ring:
//!
//! * **Store layer** ([`store`]): a content-addressed, ref-counted
//!   distributed object store — per-node in-memory [`store::LocalStore`]
//!   (chunked blobs, LRU eviction under a byte budget, pin/unpin), a
//!   [`store::Directory`] service mapping `ObjId → locations` (in-process
//!   or over [`comms::rpc`]), and peer-to-peer chunk fetch with
//!   single-flight dedup. Pool tasks pass large payloads **by reference**
//!   ([`store::ObjRef`]): the payload crosses to each worker node once,
//!   no matter how many tasks name it, and
//!   [`ring::RingMember::store_broadcast`] lets post-heal and rejoining
//!   ring members cache-hit a broadcast (e.g. the ES noise table) instead
//!   of re-streaming it.
//!
//! Fifth, the **population layer** — the workload the paper's title
//! promises:
//!
//! * **Pop layer** ([`pop`]): an asynchronous population-based-training
//!   orchestrator. A population of [`pop::Trial`]s (hyper-parameters + a
//!   model checkpoint held as a reference-counted [`store::ObjRef`]) runs
//!   fixed-budget train slices as Pool tasks with **no generation
//!   barrier** — each trial re-dispatches the moment its slice returns —
//!   and truncation-selection exploit/explore clones checkpoints by
//!   24-byte reference through the store. Two trial backends (ES and
//!   PPO over [`envs::cartpole`] / [`envs::walker2d`]) prove the
//!   subsystem algorithm-generic; a [`pop::Leaderboard`] logs every
//!   slice/clone/mutation for post-hoc lineage analysis. A killed worker
//!   mid-slice heals through the pending table: the slice is requeued
//!   with the same checkpoint reference, so no trial is ever lost.
//!
//! Cross-cutting the four blocks is the **observability layer**:
//!
//! * **Trace layer** ([`trace`]): causally-linked event tracing — every
//!   Pool dispatch/run, ring chunk/heal/resume/adopt, store put/fetch and
//!   pop slice/exploit records into a bounded per-node [`trace::Journal`]
//!   (one relaxed-atomic check per site when disabled), span ids ride the
//!   task envelopes so parent/child links cross machines, a leader-side
//!   [`trace::collect::Collector`] drains journals (in-proc `Arc` or
//!   [`comms::rpc`] with clock-offset alignment), and exporters render
//!   Chrome trace-event JSON for Perfetto plus replayable JSONL — the
//!   record half of future record/replay. `--trace <file>` on the CLI
//!   drivers captures a run; `fiber-cli trace-view` summarizes one.
//!
//! Supporting substrates: [`comms`] (the Nanomsg-substitute message layer),
//! [`wire`] (binary serialization), [`runtime`] (PJRT execution of
//! AOT-compiled JAX/Pallas artifacts), [`envs`] (simulators), [`algo`]
//! (ES/PPO built on the Fiber API), [`baselines`] (IPyParallel-, Spark- and
//! multiprocessing-style comparator executors) and [`benchkit`]/[`metrics`].

// Crate-wide style decisions the CI clippy gate (-D warnings) must not
// fight: indexed hot loops in the hand-written backprop/optimizer kernels
// are deliberate (they mirror the artifact math element-by-element), the
// experiment configs take many scalar knobs, and the manual div-ceil
// predates a ubiquitous `usize::div_ceil`.
#![allow(
    clippy::needless_range_loop,
    clippy::too_many_arguments,
    clippy::manual_div_ceil
)]

pub mod algo;
pub mod api;
pub mod baselines;
pub mod benchkit;
pub mod cluster;
pub mod comms;
pub mod coordinator;
pub mod envs;
pub mod experiments;
pub mod metrics;
pub mod pop;
pub mod ring;
pub mod runtime;
pub mod store;
pub mod trace;
pub mod util;
pub mod wire;

/// Crate-wide error type (re-export of `anyhow`).
pub use anyhow::{anyhow, bail, Context, Error, Result};
