//! Mini bench harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated sampling with mean/std/min/max, and markdown
//! table rendering so every `cargo bench` target prints the same rows the
//! paper's figures plot. Used by the `rust/benches/*.rs` targets (all
//! `harness = false`).

use crate::util::{Stopwatch, Welford};

/// One measured configuration (a row in a results table).
#[derive(Clone, Debug)]
pub struct Sample {
    pub label: String,
    pub stats: Welford,
}

impl Sample {
    pub fn mean_s(&self) -> f64 {
        self.stats.mean()
    }
}

/// Measure `f` for `samples` runs after `warmup` runs; returns seconds stats.
pub fn measure(warmup: usize, samples: usize, mut f: impl FnMut()) -> Welford {
    for _ in 0..warmup {
        f();
    }
    let mut w = Welford::new();
    for _ in 0..samples {
        let sw = Stopwatch::start();
        f();
        w.add(sw.elapsed_s());
    }
    w
}

/// A results table: rows × columns of `Option<f64>` seconds (None = failed,
/// rendered as the paper's red ✗).
pub struct Table {
    pub title: String,
    pub row_header: String,
    pub col_labels: Vec<String>,
    pub rows: Vec<(String, Vec<Option<f64>>)>,
    /// Unit formatter for cells (defaults to seconds with 3 sig figs).
    pub unit: &'static str,
}

impl Table {
    pub fn new(
        title: impl Into<String>,
        row_header: impl Into<String>,
        col_labels: Vec<String>,
    ) -> Self {
        Self {
            title: title.into(),
            row_header: row_header.into(),
            col_labels,
            rows: Vec::new(),
            unit: "s",
        }
    }

    pub fn add_row(&mut self, label: impl Into<String>, cells: Vec<Option<f64>>) {
        assert_eq!(cells.len(), self.col_labels.len(), "row width");
        self.rows.push((label.into(), cells));
    }

    fn fmt_cell(&self, v: Option<f64>) -> String {
        match v {
            None => "✗".to_string(),
            Some(x) if x >= 100.0 => format!("{x:.0}{}", self.unit),
            Some(x) if x >= 1.0 => format!("{x:.2}{}", self.unit),
            Some(x) if x >= 1e-3 => format!("{:.2}m{}", x * 1e3, self.unit),
            Some(x) => format!("{:.1}µ{}", x * 1e6, self.unit),
        }
    }

    /// Render as a markdown table.
    pub fn render(&self) -> String {
        let mut out = format!("\n### {}\n\n", self.title);
        out += &format!("| {} |", self.row_header);
        for c in &self.col_labels {
            out += &format!(" {c} |");
        }
        out += "\n|---|";
        out += &"---|".repeat(self.col_labels.len());
        out += "\n";
        for (label, cells) in &self.rows {
            out += &format!("| {label} |");
            for &c in cells {
                out += &format!(" {} |", self.fmt_cell(c));
            }
            out += "\n";
        }
        out
    }

    /// Render and print.
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

/// A minimal JSON value (serde is unavailable offline) so bench targets
/// can persist machine-readable results (e.g. `BENCH_ring.json`) next to
/// the human-readable tables.
#[derive(Clone, Debug)]
pub enum Json {
    Num(f64),
    Str(String),
    Bool(bool),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    /// Render as compact JSON text.
    pub fn render(&self) -> String {
        let mut out = String::new();
        self.render_into(&mut out);
        out
    }

    fn render_into(&self, out: &mut String) {
        match self {
            Json::Num(x) => {
                if x.is_finite() {
                    out.push_str(&format!("{x}"));
                } else {
                    out.push_str("null"); // JSON has no NaN/Inf
                }
            }
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(items) => {
                out.push('[');
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.render_into(out);
                }
                out.push(']');
            }
            Json::Obj(fields) => {
                out.push('{');
                for (i, (k, v)) in fields.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).render_into(out);
                    out.push(':');
                    v.render_into(out);
                }
                out.push('}');
            }
        }
    }

    /// Render and write to `path` (with a trailing newline).
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.render() + "\n")
    }

    /// Field lookup on an object (`None` on missing keys or non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Element lookup on an array.
    pub fn at(&self, index: usize) -> Option<&Json> {
        match self {
            Json::Arr(items) => items.get(index),
            _ => None,
        }
    }

    /// Parse JSON text back into a [`Json`] value — the inverse of
    /// [`Json::render`], so bench records and lineage exports round-trip
    /// without serde. `null` parses as a non-finite number (the renderer
    /// writes non-finite numbers as `null`, so the pair stays stable).
    pub fn parse(text: &str) -> Result<Json, String> {
        let bytes = text.as_bytes();
        let mut pos = 0usize;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            return Err(format!("trailing data at byte {pos}"));
        }
        Ok(v)
    }
}

fn skip_ws(b: &[u8], i: &mut usize) {
    while *i < b.len() && matches!(b[*i], b' ' | b'\t' | b'\n' | b'\r') {
        *i += 1;
    }
}

fn expect(b: &[u8], i: &mut usize, lit: &str) -> Result<(), String> {
    if b[*i..].starts_with(lit.as_bytes()) {
        *i += lit.len();
        Ok(())
    } else {
        Err(format!("expected {lit:?} at byte {i}", i = *i))
    }
}

fn parse_value(b: &[u8], i: &mut usize) -> Result<Json, String> {
    skip_ws(b, i);
    match b.get(*i) {
        None => Err("unexpected end of input".into()),
        Some(b't') => expect(b, i, "true").map(|()| Json::Bool(true)),
        Some(b'f') => expect(b, i, "false").map(|()| Json::Bool(false)),
        Some(b'n') => expect(b, i, "null").map(|()| Json::Num(f64::NAN)),
        Some(b'"') => parse_string(b, i).map(Json::Str),
        Some(b'[') => {
            *i += 1;
            let mut items = Vec::new();
            skip_ws(b, i);
            if b.get(*i) == Some(&b']') {
                *i += 1;
                return Ok(Json::Arr(items));
            }
            loop {
                items.push(parse_value(b, i)?);
                skip_ws(b, i);
                match b.get(*i) {
                    Some(b',') => *i += 1,
                    Some(b']') => {
                        *i += 1;
                        return Ok(Json::Arr(items));
                    }
                    _ => return Err(format!("expected ',' or ']' at byte {i}", i = *i)),
                }
            }
        }
        Some(b'{') => {
            *i += 1;
            let mut fields = Vec::new();
            skip_ws(b, i);
            if b.get(*i) == Some(&b'}') {
                *i += 1;
                return Ok(Json::Obj(fields));
            }
            loop {
                skip_ws(b, i);
                let key = parse_string(b, i)?;
                skip_ws(b, i);
                expect(b, i, ":")?;
                let value = parse_value(b, i)?;
                fields.push((key, value));
                skip_ws(b, i);
                match b.get(*i) {
                    Some(b',') => *i += 1,
                    Some(b'}') => {
                        *i += 1;
                        return Ok(Json::Obj(fields));
                    }
                    _ => return Err(format!("expected ',' or '}}' at byte {i}", i = *i)),
                }
            }
        }
        Some(_) => {
            // Number: consume the maximal number-shaped span and let the
            // std parser judge it.
            let start = *i;
            while *i < b.len()
                && matches!(b[*i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
            {
                *i += 1;
            }
            let s = std::str::from_utf8(&b[start..*i]).map_err(|e| e.to_string())?;
            s.parse::<f64>()
                .map(Json::Num)
                .map_err(|e| format!("bad number {s:?} at byte {start}: {e}"))
        }
    }
}

fn parse_string(b: &[u8], i: &mut usize) -> Result<String, String> {
    if b.get(*i) != Some(&b'"') {
        return Err(format!("expected string at byte {i}", i = *i));
    }
    *i += 1;
    let mut out = String::new();
    loop {
        match b.get(*i) {
            None => return Err("unterminated string".into()),
            Some(b'"') => {
                *i += 1;
                return Ok(out);
            }
            Some(b'\\') => {
                *i += 1;
                match b.get(*i) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        let hex = b
                            .get(*i + 1..*i + 5)
                            .ok_or("truncated \\u escape")?;
                        let code = u32::from_str_radix(
                            std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                            16,
                        )
                        .map_err(|e| e.to_string())?;
                        out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        *i += 4;
                    }
                    other => return Err(format!("bad escape {other:?}")),
                }
                *i += 1;
            }
            Some(&c) => {
                // Multi-byte UTF-8 sequences pass through unchanged.
                let len = match c {
                    0x00..=0x7F => 1,
                    0xC0..=0xDF => 2,
                    0xE0..=0xEF => 3,
                    _ => 4,
                };
                let chunk = b.get(*i..*i + len).ok_or("truncated utf-8")?;
                out.push_str(std::str::from_utf8(chunk).map_err(|e| e.to_string())?);
                *i += len;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_samples() {
        let mut calls = 0;
        let w = measure(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(w.count(), 5);
        assert!(w.mean() >= 0.0);
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Overhead", "framework", vec!["1s".into(), "1ms".into()]);
        t.add_row("fiber", vec![Some(1.02), Some(0.0013)]);
        t.add_row("ipyparallel", vec![Some(1.5), None]);
        let s = t.render();
        assert!(s.contains("| fiber | 1.02s | 1.30ms |"), "{s}");
        assert!(s.contains("| ipyparallel | 1.50s | ✗ |"), "{s}");
        assert!(s.contains("### Overhead"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("t", "r", vec!["a".into()]);
        t.add_row("x", vec![Some(1.0), Some(2.0)]);
    }

    #[test]
    fn json_renders_and_escapes() {
        let j = Json::Obj(vec![
            ("name".into(), Json::str("ring \"allreduce\"\n")),
            ("world".into(), Json::num(4.0)),
            ("ok".into(), Json::Bool(true)),
            ("xs".into(), Json::Arr(vec![Json::num(1.5), Json::Num(f64::NAN)])),
        ]);
        let s = j.render();
        assert_eq!(
            s,
            r#"{"name":"ring \"allreduce\"\n","world":4,"ok":true,"xs":[1.5,null]}"#
        );
    }

    #[test]
    fn json_parse_roundtrips_render() {
        let j = Json::Obj(vec![
            ("label".into(), Json::str("kill → heal\t\"grow\"")),
            ("n".into(), Json::num(-12.25)),
            ("big".into(), Json::num(3.5e9)),
            ("flag".into(), Json::Bool(false)),
            (
                "rows".into(),
                Json::Arr(vec![
                    Json::Obj(vec![("t".into(), Json::num(0.5))]),
                    Json::Arr(vec![]),
                    Json::Obj(vec![]),
                    Json::Num(f64::NEG_INFINITY), // renders as null
                ]),
            ),
        ]);
        let rendered = j.render();
        let back = Json::parse(&rendered).unwrap();
        assert_eq!(back.render(), rendered, "parse ∘ render must be identity");
        // Structured access survives the round trip.
        assert!(matches!(back.get("n"), Some(Json::Num(x)) if *x == -12.25));
        assert!(matches!(back.get("rows").and_then(|r| r.at(0)).and_then(|o| o.get("t")),
            Some(Json::Num(x)) if *x == 0.5));
        // Whitespace-tolerant; trailing garbage rejected.
        assert!(Json::parse(" { \"a\" : [ 1 , 2 ] } ").is_ok());
        assert!(Json::parse("{}g").is_err());
        assert!(Json::parse("{").is_err());
    }
}
