//! Mini bench harness (criterion is unavailable offline).
//!
//! Provides warmup + repeated sampling with mean/std/min/max, and markdown
//! table rendering so every `cargo bench` target prints the same rows the
//! paper's figures plot. Used by the `rust/benches/*.rs` targets (all
//! `harness = false`).

use crate::util::{Stopwatch, Welford};

/// One measured configuration (a row in a results table).
#[derive(Clone, Debug)]
pub struct Sample {
    pub label: String,
    pub stats: Welford,
}

impl Sample {
    pub fn mean_s(&self) -> f64 {
        self.stats.mean()
    }
}

/// Measure `f` for `samples` runs after `warmup` runs; returns seconds stats.
pub fn measure(warmup: usize, samples: usize, mut f: impl FnMut()) -> Welford {
    for _ in 0..warmup {
        f();
    }
    let mut w = Welford::new();
    for _ in 0..samples {
        let sw = Stopwatch::start();
        f();
        w.add(sw.elapsed_s());
    }
    w
}

/// A results table: rows × columns of `Option<f64>` seconds (None = failed,
/// rendered as the paper's red ✗).
pub struct Table {
    pub title: String,
    pub row_header: String,
    pub col_labels: Vec<String>,
    pub rows: Vec<(String, Vec<Option<f64>>)>,
    /// Unit formatter for cells (defaults to seconds with 3 sig figs).
    pub unit: &'static str,
}

impl Table {
    pub fn new(
        title: impl Into<String>,
        row_header: impl Into<String>,
        col_labels: Vec<String>,
    ) -> Self {
        Self {
            title: title.into(),
            row_header: row_header.into(),
            col_labels,
            rows: Vec::new(),
            unit: "s",
        }
    }

    pub fn add_row(&mut self, label: impl Into<String>, cells: Vec<Option<f64>>) {
        assert_eq!(cells.len(), self.col_labels.len(), "row width");
        self.rows.push((label.into(), cells));
    }

    fn fmt_cell(&self, v: Option<f64>) -> String {
        match v {
            None => "✗".to_string(),
            Some(x) if x >= 100.0 => format!("{x:.0}{}", self.unit),
            Some(x) if x >= 1.0 => format!("{x:.2}{}", self.unit),
            Some(x) if x >= 1e-3 => format!("{:.2}m{}", x * 1e3, self.unit),
            Some(x) => format!("{:.1}µ{}", x * 1e6, self.unit),
        }
    }

    /// Render as a markdown table.
    pub fn render(&self) -> String {
        let mut out = format!("\n### {}\n\n", self.title);
        out += &format!("| {} |", self.row_header);
        for c in &self.col_labels {
            out += &format!(" {c} |");
        }
        out += "\n|---|";
        out += &"---|".repeat(self.col_labels.len());
        out += "\n";
        for (label, cells) in &self.rows {
            out += &format!("| {label} |");
            for &c in cells {
                out += &format!(" {} |", self.fmt_cell(c));
            }
            out += "\n";
        }
        out
    }

    /// Render and print.
    pub fn print(&self) {
        println!("{}", self.render());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_counts_samples() {
        let mut calls = 0;
        let w = measure(2, 5, || calls += 1);
        assert_eq!(calls, 7);
        assert_eq!(w.count(), 5);
        assert!(w.mean() >= 0.0);
    }

    #[test]
    fn table_renders_markdown() {
        let mut t = Table::new("Overhead", "framework", vec!["1s".into(), "1ms".into()]);
        t.add_row("fiber", vec![Some(1.02), Some(0.0013)]);
        t.add_row("ipyparallel", vec![Some(1.5), None]);
        let s = t.render();
        assert!(s.contains("| fiber | 1.02s | 1.30ms |"), "{s}");
        assert!(s.contains("| ipyparallel | 1.50s | ✗ |"), "{s}");
        assert!(s.contains("### Overhead"));
    }

    #[test]
    #[should_panic(expected = "row width")]
    fn row_width_checked() {
        let mut t = Table::new("t", "r", vec!["a".into()]);
        t.add_row("x", vec![Some(1.0), Some(2.0)]);
    }
}
