//! Binary serialization for messages crossing process boundaries.
//!
//! `serde`/`bincode` are unavailable offline, so fiber-rs ships its own
//! minimal, explicit codec: little-endian fixed-width integers, length-
//! prefixed sequences, no varint cleverness. The format is versioned per
//! message (the [`crate::comms`] frame header carries a message tag).
//!
//! The two traits mirror `Serialize`/`Deserialize`:
//!
//! ```
//! use fiber::wire::{Decode, Encode};
//! let mut buf = Vec::new();
//! (42u32, "hello".to_string()).encode(&mut buf);
//! let mut r = fiber::wire::Reader::new(&buf);
//! let (n, s) = <(u32, String)>::decode(&mut r).unwrap();
//! assert_eq!((n, s.as_str()), (42, "hello"));
//! ```

mod codec;

pub use codec::{Decode, Encode, Reader, WireError};

/// Encode a value into a fresh buffer.
pub fn to_bytes<T: Encode>(v: &T) -> Vec<u8> {
    let mut buf = Vec::new();
    v.encode(&mut buf);
    buf
}

/// Decode a value from a complete buffer, requiring full consumption.
pub fn from_bytes<T: Decode>(bytes: &[u8]) -> Result<T, WireError> {
    let mut r = Reader::new(bytes);
    let v = T::decode(&mut r)?;
    if !r.is_empty() {
        return Err(WireError::TrailingBytes(r.remaining()));
    }
    Ok(v)
}
