//! The codec itself: `Encode`/`Decode` + a bounds-checked `Reader`.

use std::collections::HashMap;

/// Errors produced while decoding.
#[derive(Debug, thiserror::Error, PartialEq, Eq)]
pub enum WireError {
    #[error("unexpected end of buffer: wanted {wanted} more bytes, had {had}")]
    Eof { wanted: usize, had: usize },
    #[error("invalid utf-8 string")]
    Utf8,
    #[error("invalid enum/bool tag {0}")]
    BadTag(u32),
    #[error("length {0} exceeds sanity limit")]
    TooLong(usize),
    #[error("{0} trailing bytes after decode")]
    TrailingBytes(usize),
}

/// Sanity cap on decoded sequence lengths (guards against corrupt frames).
const MAX_SEQ: usize = 1 << 28; // 256 Mi elements

/// Bounds-checked cursor over a byte slice.
pub struct Reader<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Reader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], WireError> {
        if self.remaining() < n {
            return Err(WireError::Eof {
                wanted: n,
                had: self.remaining(),
            });
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }
}

/// Types that can be written to a byte buffer.
pub trait Encode {
    fn encode(&self, buf: &mut Vec<u8>);
}

/// Types that can be read back from a byte buffer.
pub trait Decode: Sized {
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError>;
}

macro_rules! impl_fixed {
    ($($t:ty),*) => {$(
        impl Encode for $t {
            #[inline]
            fn encode(&self, buf: &mut Vec<u8>) {
                buf.extend_from_slice(&self.to_le_bytes());
            }
        }
        impl Decode for $t {
            #[inline]
            fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
                let bytes = r.take(std::mem::size_of::<$t>())?;
                Ok(<$t>::from_le_bytes(bytes.try_into().unwrap()))
            }
        }
    )*};
}

impl_fixed!(u8, u16, u32, u64, i8, i16, i32, i64, f32, f64);

impl Encode for usize {
    #[inline]
    fn encode(&self, buf: &mut Vec<u8>) {
        (*self as u64).encode(buf);
    }
}

impl Decode for usize {
    #[inline]
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(u64::decode(r)? as usize)
    }
}

// Fixed-size byte arrays (content hashes, digests): no length prefix —
// the size is part of the type.
impl<const N: usize> Encode for [u8; N] {
    #[inline]
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.extend_from_slice(self);
    }
}

impl<const N: usize> Decode for [u8; N] {
    #[inline]
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        let bytes = r.take(N)?;
        Ok(bytes.try_into().unwrap())
    }
}

impl Encode for bool {
    #[inline]
    fn encode(&self, buf: &mut Vec<u8>) {
        buf.push(*self as u8);
    }
}

impl Decode for bool {
    #[inline]
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        match u8::decode(r)? {
            0 => Ok(false),
            1 => Ok(true),
            t => Err(WireError::BadTag(t as u32)),
        }
    }
}

impl Encode for String {
    fn encode(&self, buf: &mut Vec<u8>) {
        (self.len() as u64).encode(buf);
        buf.extend_from_slice(self.as_bytes());
    }
}

impl Encode for &str {
    fn encode(&self, buf: &mut Vec<u8>) {
        (self.len() as u64).encode(buf);
        buf.extend_from_slice(self.as_bytes());
    }
}

impl Decode for String {
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        let n = u64::decode(r)? as usize;
        if n > MAX_SEQ {
            return Err(WireError::TooLong(n));
        }
        let bytes = r.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| WireError::Utf8)
    }
}

impl<T: Encode> Encode for Vec<T> {
    fn encode(&self, buf: &mut Vec<u8>) {
        (self.len() as u64).encode(buf);
        for item in self {
            item.encode(buf);
        }
    }
}

impl<T: Decode> Decode for Vec<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        let n = u64::decode(r)? as usize;
        if n > MAX_SEQ {
            return Err(WireError::TooLong(n));
        }
        let mut v = Vec::with_capacity(n.min(1 << 16));
        for _ in 0..n {
            v.push(T::decode(r)?);
        }
        Ok(v)
    }
}

impl<T: Encode> Encode for Option<T> {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            None => buf.push(0),
            Some(v) => {
                buf.push(1);
                v.encode(buf);
            }
        }
    }
}

impl<T: Decode> Decode for Option<T> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        match u8::decode(r)? {
            0 => Ok(None),
            1 => Ok(Some(T::decode(r)?)),
            t => Err(WireError::BadTag(t as u32)),
        }
    }
}

impl<K: Encode + Eq + std::hash::Hash, V: Encode> Encode for HashMap<K, V> {
    fn encode(&self, buf: &mut Vec<u8>) {
        (self.len() as u64).encode(buf);
        for (k, v) in self {
            k.encode(buf);
            v.encode(buf);
        }
    }
}

impl<K: Decode + Eq + std::hash::Hash, V: Decode> Decode for HashMap<K, V> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        let n = u64::decode(r)? as usize;
        if n > MAX_SEQ {
            return Err(WireError::TooLong(n));
        }
        let mut m = HashMap::with_capacity(n.min(1 << 12));
        for _ in 0..n {
            let k = K::decode(r)?;
            let v = V::decode(r)?;
            m.insert(k, v);
        }
        Ok(m)
    }
}

impl Encode for () {
    fn encode(&self, _buf: &mut Vec<u8>) {}
}

impl Decode for () {
    fn decode(_r: &mut Reader<'_>) -> Result<Self, WireError> {
        Ok(())
    }
}

macro_rules! impl_tuple {
    ($($name:ident : $idx:tt),+) => {
        impl<$($name: Encode),+> Encode for ($($name,)+) {
            fn encode(&self, buf: &mut Vec<u8>) {
                $(self.$idx.encode(buf);)+
            }
        }
        impl<$($name: Decode),+> Decode for ($($name,)+) {
            fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
                Ok(($($name::decode(r)?,)+))
            }
        }
    };
}

impl_tuple!(A: 0);
impl_tuple!(A: 0, B: 1);
impl_tuple!(A: 0, B: 1, C: 2);
impl_tuple!(A: 0, B: 1, C: 2, D: 3);
impl_tuple!(A: 0, B: 1, C: 2, D: 3, E: 4);
impl_tuple!(A: 0, B: 1, C: 2, D: 3, E: 4, F: 5);
impl_tuple!(A: 0, B: 1, C: 2, D: 3, E: 4, F: 5, G: 6);
impl_tuple!(A: 0, B: 1, C: 2, D: 3, E: 4, F: 5, G: 6, H: 7);
impl_tuple!(A: 0, B: 1, C: 2, D: 3, E: 4, F: 5, G: 6, H: 7, I: 8);
impl_tuple!(A: 0, B: 1, C: 2, D: 3, E: 4, F: 5, G: 6, H: 7, I: 8, J: 9);

impl<T: Encode, E2: Encode> Encode for Result<T, E2> {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Ok(v) => {
                buf.push(0);
                v.encode(buf);
            }
            Err(e) => {
                buf.push(1);
                e.encode(buf);
            }
        }
    }
}

impl<T: Decode, E2: Decode> Decode for Result<T, E2> {
    fn decode(r: &mut Reader<'_>) -> Result<Self, WireError> {
        match u8::decode(r)? {
            0 => Ok(Ok(T::decode(r)?)),
            1 => Ok(Err(E2::decode(r)?)),
            t => Err(WireError::BadTag(t as u32)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire::{from_bytes, to_bytes};

    fn roundtrip<T: Encode + Decode + PartialEq + std::fmt::Debug>(v: T) {
        let bytes = to_bytes(&v);
        let back: T = from_bytes(&bytes).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn primitives_roundtrip() {
        roundtrip(0u8);
        roundtrip(255u8);
        roundtrip(u16::MAX);
        roundtrip(123456789u32);
        roundtrip(u64::MAX);
        roundtrip(-42i32);
        roundtrip(i64::MIN);
        roundtrip(3.14159f32);
        roundtrip(f64::NEG_INFINITY);
        roundtrip(true);
        roundtrip(false);
    }

    #[test]
    fn f32_nan_roundtrips_bitwise() {
        let bytes = to_bytes(&f32::NAN);
        let back: f32 = from_bytes(&bytes).unwrap();
        assert!(back.is_nan());
    }

    #[test]
    fn compound_roundtrip() {
        roundtrip("héllo wörld".to_string());
        roundtrip(String::new());
        roundtrip(vec![1.0f32, -2.5, 3.25]);
        roundtrip(Vec::<u64>::new());
        roundtrip(Some(vec!["a".to_string(), "b".to_string()]));
        roundtrip(Option::<u32>::None);
        roundtrip((7u32, "x".to_string(), vec![1u8, 2, 3]));
        roundtrip(Ok::<u32, String>(5));
        roundtrip(Err::<u32, String>("boom".into()));
        let mut m = HashMap::new();
        m.insert("k1".to_string(), 10u64);
        m.insert("k2".to_string(), 20u64);
        roundtrip(m);
    }

    #[test]
    fn nested_vectors() {
        roundtrip(vec![vec![1u32, 2], vec![], vec![3]]);
    }

    #[test]
    fn fixed_byte_arrays_are_raw() {
        roundtrip([0u8; 0]);
        roundtrip([7u8, 8, 9]);
        roundtrip([0xffu8; 16]);
        // No length prefix: 16 bytes encode to exactly 16 bytes.
        assert_eq!(to_bytes(&[0xabu8; 16]).len(), 16);
        let r: Result<[u8; 16], _> = from_bytes(&[0u8; 15]);
        assert!(matches!(r, Err(WireError::Eof { .. })));
    }

    #[test]
    fn eof_detected() {
        let bytes = to_bytes(&12345u64);
        let r: Result<u64, _> = from_bytes(&bytes[..4]);
        assert!(matches!(r, Err(WireError::Eof { .. })));
    }

    #[test]
    fn trailing_bytes_detected() {
        let mut bytes = to_bytes(&1u8);
        bytes.push(0xff);
        let r: Result<u8, _> = from_bytes(&bytes);
        assert_eq!(r, Err(WireError::TrailingBytes(1)));
    }

    #[test]
    fn bad_bool_tag() {
        let r: Result<bool, _> = from_bytes(&[7]);
        assert_eq!(r, Err(WireError::BadTag(7)));
    }

    #[test]
    fn corrupt_length_rejected() {
        // A huge length prefix must not cause a giant allocation.
        let bytes = to_bytes(&(u64::MAX / 2));
        let r: Result<Vec<u8>, _> = from_bytes(&bytes);
        assert!(matches!(r, Err(WireError::TooLong(_)) | Err(WireError::Eof { .. })));
    }
}
