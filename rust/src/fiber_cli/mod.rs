//! CLI dispatch for `fiber-cli` (hand-rolled; clap is unavailable offline).
//!
//! The `worker` subcommand is the entrypoint of **job-backed worker
//! processes**: `ProcBackend` spawns `fiber-cli worker --leader <addr>
//! --worker <id>` children of the current binary, which register the same
//! task functions as the leader (the container-image guarantee) and serve
//! the pool protocol over RPC until retired.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use fiber::algo::es::register_es_tasks;
use fiber::baselines::exec::register_bench_tasks;
use fiber::comms::rpc::RpcClient;
use fiber::coordinator::pool_server::{tags, FetchBatchReply};
use fiber::coordinator::task::execute_registered;
use fiber::wire;

mod demo;
mod experiments;
mod pbt;
mod ring;
mod top;

/// Parse `--key value` style options.
pub(crate) struct Opts {
    pairs: Vec<(String, String)>,
}

impl Opts {
    fn parse(args: &[String]) -> Result<Opts> {
        let mut pairs = Vec::new();
        let mut i = 0;
        while i < args.len() {
            let k = &args[i];
            if let Some(name) = k.strip_prefix("--") {
                let v = args
                    .get(i + 1)
                    .with_context(|| format!("missing value for --{name}"))?;
                pairs.push((name.to_string(), v.clone()));
                i += 2;
            } else {
                bail!("unexpected argument {k:?}");
            }
        }
        Ok(Opts { pairs })
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.pairs
            .iter()
            .rev()
            .find(|(k, _)| k == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    pub fn parse_or<T: std::str::FromStr>(&self, name: &str, default: T) -> Result<T>
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|e| anyhow::anyhow!("--{name} {v:?}: {e}")),
        }
    }

    pub fn require(&self, name: &str) -> Result<&str> {
        self.get(name).with_context(|| format!("--{name} is required"))
    }
}

/// Register every task function this binary can serve. Leader and workers
/// call the same function, which is what makes fn-name dispatch safe.
pub fn register_all_tasks() {
    register_es_tasks();
    register_bench_tasks();
    fiber::pop::register_pbt_tasks();
    fiber::coordinator::batch::register_chunk_runner();
    fiber::api::pool::register_autoref_runner();
}

pub fn run(args: Vec<String>) -> Result<()> {
    let cmd = args.first().map(String::as_str).unwrap_or("help");
    let opts = Opts::parse(args.get(1..).unwrap_or(&[]))?;
    register_all_tasks();
    // `--trace <file>` works on every *recording* subcommand: enable the
    // journal before dispatch, export after. Thread-backed runs (the
    // default) record every layer in this one process; proc-backed workers
    // keep tracing disabled in their own processes (their leader-side
    // spans — dispatch, queue, collect — still land in the trace). The
    // read-side commands are excluded: `trace-view`/`trace-check` consume
    // traces, and `replay` reuses `--trace` as the *output* path for the
    // trace it synthesizes.
    let read_side = matches!(
        cmd,
        "trace-view" | "trace-check" | "replay" | "top" | "help" | "--help" | "-h"
    );
    let trace_out = if read_side {
        None
    } else {
        opts.get("trace").map(str::to_string)
    };
    // `--live DIR` streams the journal to rotating on-disk JSONL segments
    // *during* the run (`fiber::trace::live`): a run killed mid-flight
    // leaves everything already drained, and `--serve-top ADDR` exposes
    // the live health model to `fiber-cli top --connect`.
    let live_dir = if read_side {
        None
    } else {
        opts.get("live").map(str::to_string)
    };
    if trace_out.is_some() || live_dir.is_some() {
        fiber::trace::global().set_node_name("leader");
        fiber::trace::set_enabled(true);
    }
    // The crash flight recorder is on by default for every recording
    // command (`--flight false` opts out): a bounded in-memory ring whose
    // only cost is the ring itself, dumped to `fiber-crash-<pid>.jsonl`
    // on panic or simulated fatal error (`--crash-dir` overrides where).
    if !read_side && opts.parse_or("flight", true)? {
        fiber::trace::set_flight_enabled(true);
        fiber::trace::live::install_crash_hook();
        if let Some(dir) = opts.get("crash-dir") {
            fiber::trace::live::set_crash_dir(std::path::Path::new(dir));
        }
    }
    let mut streamer = None;
    if let Some(dir) = &live_dir {
        let mut collector = fiber::trace::collect::Collector::new();
        collector.add_global();
        let mut cfg =
            fiber::trace::live::StreamerConfig::to_dir(std::path::Path::new(dir));
        cfg.interval = Duration::from_millis(opts.parse_or("live-interval-ms", 200u64)?);
        cfg.serve = opts.get("serve-top").map(str::to_string);
        cfg.metrics_file = opts.get("metrics-file").map(str::to_string);
        cfg.straggler_k = opts.parse_or("straggler-k", 3u64)?;
        streamer = Some(fiber::trace::live::Streamer::start(collector, cfg)?);
    }
    let result = match cmd {
        "worker" => worker(&opts),
        "ring" => ring::ring_demo(&opts),
        "ring-node" => ring::ring_node(&opts),
        "demo" => demo::pi_demo(&opts),
        "sched-demo" => demo::sched_demo(&opts),
        "overhead" => experiments::overhead(&opts),
        "es" => experiments::es(&opts),
        "es-node" => experiments::es_node(&opts),
        "ppo" => experiments::ppo(&opts),
        "pbt" => pbt::pbt(&opts),
        "scaling-sim" => experiments::scaling_sim(&opts),
        "trace-view" => trace_view(&opts),
        "trace-check" => trace_check(&opts),
        "replay" => replay(&opts),
        "top" => top::top(&opts),
        "help" | "--help" | "-h" => {
            print_help();
            Ok(())
        }
        other => bail!("unknown subcommand {other:?} (see `fiber-cli help`)"),
    };
    fiber::trace::set_enabled(false);
    if let Some(s) = streamer {
        // Final incremental drain + segment footer, then the end-of-run
        // health readout the live `top` view was showing.
        let snap = s.stop()?;
        print!("{}", snap.render());
        println!(
            "live trace segments in {}/",
            live_dir.as_deref().unwrap_or(".")
        );
        if let Some(path) = &trace_out {
            // `--trace` + `--live` compose: reassemble the segment stream
            // into the single requested file.
            let dump =
                fiber::trace::export::read_trace(live_dir.as_deref().unwrap_or("."))?;
            if path.ends_with(".jsonl") {
                fiber::trace::export::write_jsonl(path, &dump)?;
            } else {
                fiber::trace::export::write_chrome(path, &dump)?;
            }
            warn_lossy(&dump);
            fiber::trace::export::summary(&dump).print();
            println!("trace written to {path}");
        }
    } else if let Some(path) = &trace_out {
        write_trace(path)?;
    }
    // `--metrics-file <file>` on any subcommand: drop a Prometheus
    // text-exposition snapshot of the run's counters/gauges/latencies
    // (with `--live` it is also rewritten on every streamer tick).
    if let Some(path) = opts.get("metrics-file") {
        std::fs::write(path, fiber::metrics::export_prometheus())
            .with_context(|| format!("write metrics {path}"))?;
        println!("metrics written to {path}");
    }
    result
}

/// Drain the process journal and export it to `path`: replayable JSONL
/// when the extension is `.jsonl`, Chrome trace-event JSON (Perfetto /
/// `chrome://tracing`-loadable) otherwise. Prints the per-span-kind
/// summary table either way.
fn write_trace(path: &str) -> Result<()> {
    let mut collector = fiber::trace::collect::Collector::new();
    collector.add_global();
    let dump = collector.drain();
    if path.ends_with(".jsonl") {
        fiber::trace::export::write_jsonl(path, &dump)?;
    } else {
        fiber::trace::export::write_chrome(path, &dump)?;
    }
    warn_lossy(&dump);
    fiber::trace::export::summary(&dump).print();
    println!("trace written to {path}");
    Ok(())
}

/// Print the explicit lossy-trace warning when bounded journals dropped
/// events: every view/audit of such a trace is analyzing a hole-y record,
/// and the reader must know before trusting gaps in it.
fn warn_lossy(dump: &fiber::trace::collect::TraceDump) {
    if dump.dropped > 0 {
        eprintln!(
            "warning: LOSSY TRACE — {} event(s) dropped by bounded journals; \
             causal links may dangle and gaps may be recording loss, not idleness \
             (raise the journal capacity to record more)",
            dump.dropped
        );
    }
}

/// Summarize a previously written trace file (either export format):
/// per-span-kind count and latency quantiles. `--critical-path true` adds
/// the longest causal chain with per-span-kind attribution plus per-node
/// busy/idle occupancy; `--folded FILE` writes flamegraph folded stacks.
fn trace_view(opts: &Opts) -> Result<()> {
    let path = opts.require("input")?;
    let dump = fiber::trace::export::read_trace(path)?;
    warn_lossy(&dump);
    fiber::trace::export::summary(&dump).print();
    if opts.parse_or("critical-path", false)? {
        match fiber::trace::analyze::critical_path(&dump) {
            Some(cp) => {
                fiber::trace::analyze::critical_path_table(&cp).print();
                fiber::trace::analyze::busy_idle(&dump).print();
            }
            None => println!("no spans — critical path is empty"),
        }
    }
    if let Some(out) = opts.get("folded") {
        fiber::trace::export::write_folded(out, &dump)?;
        println!("folded stacks written to {out}");
    }
    Ok(())
}

/// Audit a recorded trace against the causal invariant catalog
/// (`fiber::trace::check`; the catalog is documented in
/// `docs/trace_schema.md`). Exits non-zero when any invariant is violated,
/// so CI can pipe chaos runs straight through it.
fn trace_check(opts: &Opts) -> Result<()> {
    let path = opts.require("input")?;
    let dump = fiber::trace::export::read_trace(path)?;
    warn_lossy(&dump);
    let cfg = fiber::trace::check::CheckOptions {
        skew_ns: opts.parse_or(
            "skew-ns",
            fiber::trace::check::CheckOptions::default().skew_ns,
        )?,
    };
    let report = fiber::trace::check::check_with(&dump, path, &cfg);
    print!("{}", report.render());
    if !report.ok() {
        bail!(
            "trace audit failed: {} invariant violation(s)",
            report.violations.len()
        );
    }
    Ok(())
}

/// Re-drive a recorded chaos schedule against simulated nodes on the
/// virtual clock: load a scenario file (`docs/trace_schema.md`), replay it
/// at `--nodes N` (default: the scenario's own size), audit the synthesized
/// trace, and optionally export it with `--trace FILE`.
fn replay(opts: &Opts) -> Result<()> {
    let path = opts.require("scenario")?;
    let mut sc = fiber::trace::replay::Scenario::load(path)?;
    if let Some(n) = opts.get("nodes") {
        sc.nodes = n.parse().map_err(|e| anyhow::anyhow!("--nodes {n:?}: {e}"))?;
        if sc.nodes < 2 {
            bail!("--nodes must be >= 2");
        }
    }
    // Span durations come from defaults, or from a recorded trace's
    // measured means so the replayed timeline matches the real cluster.
    let cal = match opts.get("calibrate-from") {
        Some(p) => fiber::trace::replay::Calibration::from_dump(
            &fiber::trace::export::read_trace(p)?,
        ),
        None => fiber::trace::replay::Calibration::default(),
    };
    println!(
        "replaying scenario {:?}: {} nodes, {} spares, {} iters, {} chaos event(s)",
        sc.name,
        sc.nodes,
        sc.spares,
        sc.iters,
        sc.events.len()
    );
    let (dump, stats) = fiber::trace::replay::replay(&sc, &cal)?;
    println!(
        "replay done at t={:.1} ms virtual: {} events, {} pods, {} kill(s), \
         {} heal(s), {} grow(s), {} members at end",
        stats.final_ns as f64 / 1e6,
        stats.events,
        stats.pods,
        stats.kills,
        stats.heals,
        stats.grows,
        stats.members_final
    );
    if let Some(out) = opts.get("trace") {
        if out.ends_with(".jsonl") {
            fiber::trace::export::write_jsonl(out, &dump)?;
        } else {
            fiber::trace::export::write_chrome(out, &dump)?;
        }
        println!("replayed trace written to {out}");
    }
    let report = fiber::trace::check::check(&dump, &format!("replay({})", sc.name));
    print!("{}", report.render());
    if !report.ok() {
        bail!(
            "replayed trace failed its own audit: {} violation(s)",
            report.violations.len()
        );
    }
    Ok(())
}

/// The job-backed worker process loop (proc backend).
fn worker(opts: &Opts) -> Result<()> {
    let leader: std::net::SocketAddr = opts.require("leader")?.parse()?;
    let worker_id: u64 = opts.require("worker")?.parse()?;
    fiber::coordinator::task::set_current_worker(worker_id);
    let mut store_endpoint: Option<String> = None;
    if let Some(store) = opts.get("store") {
        // Join the leader's object store: ObjRef task arguments resolve
        // through this node (one transfer per payload per worker process,
        // then cache hits), and serving makes by-reference *results*
        // fetchable by the leader and by sibling workers.
        let budget: usize = opts.parse_or("store-budget", 256usize << 20)?;
        let node = fiber::store::StoreNode::connect(store, budget)
            .context("connect to object store")?;
        if let Some(dir) = opts.get("spill-dir") {
            // Over-budget LRU victims spill to disk instead of evicting,
            // and fault back in (hash-verified) on the next access.
            node.local()
                .set_spill_dir(Some(dir.into()))
                .with_context(|| format!("create spill dir {dir}"))?;
        }
        let ep = node.serve("127.0.0.1:0").context("serve worker store node")?;
        store_endpoint = Some(ep);
        fiber::store::install_node(node);
    }
    let cli = RpcClient::connect(leader).context("connect to leader")?;
    // HELLO: report the store endpoint this worker publishes blobs under,
    // so the leader's scheduler can route operand-holding tasks here
    // (`sched.local_hit`) instead of treating every proc worker alike.
    cli.call(tags::HELLO, &wire::to_bytes(&(worker_id, store_endpoint)))?;
    let batch: u64 = opts.parse_or("batch", 8u64)?;
    loop {
        // One envelope moves a whole slice of this node's run queue. A
        // `Wait` reply means the leader's 500 ms blocking fetch found
        // nothing — loop straight back into it, no client-side sleep.
        let reply = cli.call(tags::FETCH_BATCH, &wire::to_bytes(&(worker_id, batch)))?;
        let fetched: FetchBatchReply =
            wire::from_bytes(&reply).map_err(|e| anyhow::anyhow!("fetch decode: {e}"))?;
        match fetched {
            FetchBatchReply::Tasks(tasks) => {
                for task in tasks {
                    // Mirror of the in-process worker loop: the run span
                    // parents under the span id the envelope carried from
                    // the leader (recorded only if this process enables
                    // tracing).
                    let run = fiber::trace::Span::begin_child("pool.run", task.span)
                        .arg("worker", worker_id as i64)
                        .arg("index", task.index as i64);
                    let result = fiber::trace::with_span(run.id(), || {
                        execute_registered(&task.fn_name, &task.payload)
                    });
                    drop(run);
                    cli.call(
                        tags::PUT,
                        &wire::to_bytes(&(worker_id, task.id.0, result)),
                    )?;
                }
            }
            FetchBatchReply::Wait => continue,
            FetchBatchReply::Retire => return Ok(()),
        }
    }
}

fn print_help() {
    println!(
        "fiber-cli — fiber-rs driver (Fiber reproduction; see README.md)\n\
         \n\
         USAGE: fiber-cli <SUBCOMMAND> [--key value ...]\n\
         \n\
         SUBCOMMANDS:\n\
           worker       worker-process entrypoint (spawned by ProcBackend)\n\
                        --leader <addr> --worker <id> [--batch N tasks/envelope]\n\
                        [--store tcp://addr [--store-budget BYTES] [--spill-dir DIR]]\n\
           ring         ring-allreduce collective demo\n\
                        [--world N] [--elems N] [--proc true] [--overlap false]\n\
           ring-node    ring-member process entrypoint (spawned by `ring --proc true`)\n\
                        --rendezvous <addr> [--elems N] [--bind ip:port] [--overlap false]\n\
           demo         pi-estimation smoke demo  [--workers N] [--samples N] [--proc true]\n\
           sched-demo   deterministic two-level-scheduler demo: a pinned worker\n\
                        forces a steal, a store-resident ObjRef forces locality\n\
                        routing; exits non-zero unless sched.steal and\n\
                        sched.local_hit both fired\n\
                        [--long-ms MS] [--short-ms MS] [--shorts N]\n\
           overhead     E1 Fig 3a framework-overhead experiment [--workers N]\n\
           es           E2 distributed ES on walker2d\n\
                        [--pop N] [--iters N] [--workers N] [--artifacts DIR]\n\
                        [--decentralized true [--world N] [--proc true]\n\
                         [--kill-rank R --kill-iter I --kill-chunk K] [--toy true]\n\
                         [--spares N [--grow-iter I]] [--store true]]\n\
           es-node      decentralized-ES replica process entrypoint\n\
                        --rendezvous <addr> [--iters N] [--store tcp://addr]\n\
                        [--kill-rank R --kill-iter I --kill-chunk K]\n\
                        [--spare true] [--grow-iter I]\n\
           ppo          E3 distributed PPO on breakout\n\
                        [--envs N] [--iters N] [--workers N] [--artifacts DIR]\n\
                        [--decentralized true [--world N]\n\
                         [--kill-rank R --kill-iter I --kill-chunk K]\n\
                         [--spares N [--grow-iter I]]]\n\
           pbt          population-based training over Pool workers\n\
                        --algo {{es,ppo}} [--env {{cartpole,walker2d}}] [--pop N]\n\
                        [--workers W] [--slices N] [--iters N] [--proc true]\n\
                        [--sync true] [--quantile Q] [--kill-rank R]\n\
           scaling-sim  E2/E3 virtual-time scaling curves (Fig 3b/3c)\n\
           trace-view   summarize a recorded trace (per-span-kind count/p50/p99)\n\
                        --input <file> [--critical-path true] [--folded FILE]\n\
           trace-check  audit a recorded trace against the causal invariant\n\
                        catalog (docs/trace_schema.md); non-zero exit on violation\n\
                        --input <file> [--skew-ns N]\n\
           replay       re-drive a chaos scenario on simulated nodes (virtual\n\
                        clock), audit the synthesized trace, optionally export it\n\
                        --scenario <file> [--nodes N] [--trace FILE]\n\
                        [--calibrate-from RECORDED_TRACE]\n\
           top          cluster health readout: node liveness, pool throughput,\n\
                        ring op/chunk progress, store hit-rate, pop leaderboard,\n\
                        straggler flags\n\
                        --connect ADDR (live, from a run with --serve-top) |\n\
                        --input FILE_OR_DIR (offline, incl. --live segment dirs)\n\
                        [--once] [--interval-ms MS] [--straggler-k K]\n\
           help         this message\n\
         \n\
         GLOBAL OPTIONS:\n\
           --trace FILE record causally-linked trace events and export on exit:\n\
                        Chrome trace-event JSON (open in Perfetto), or replayable\n\
                        JSONL when FILE ends in .jsonl (see docs/trace_schema.md)\n\
           --live DIR   stream the journal to rotating JSONL segments in DIR\n\
                        *during* the run (kill-safe; trace-view/trace-check/top\n\
                        accept the directory) [--live-interval-ms MS]\n\
                        [--serve-top ADDR serve live health for `top --connect`]\n\
                        [--straggler-k K flag spans over K x rolling p99]\n\
           --flight BOOL\n\
                        crash flight recorder (default true on recording\n\
                        commands): keeps the last {} events in memory and dumps\n\
                        fiber-crash-<pid>.jsonl on panic/fatal error\n\
                        [--crash-dir DIR]\n\
           --metrics-file FILE\n\
                        write a Prometheus text-exposition snapshot of the run's\n\
                        counters/gauges/latency summaries on exit (with --live:\n\
                        rewritten on every streamer tick)",
        fiber::trace::FLIGHT_CAP
    );
}
