//! CLI wrappers for the paper's experiments (E1–E5) and the real ES/PPO
//! training drivers used by EXPERIMENTS.md — including the decentralized
//! (leaderless) ES path over ring collectives, with a chaos switch that
//! kills a rank mid-allreduce to demo pool-style healing live.

use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use fiber::algo::es::{EsConfig, EsMaster, EsRingNode};
use fiber::algo::ppo::{PpoConfig, PpoTrainer};
use fiber::algo::vec_env::VecEnv;
use fiber::api::pool::Pool;
use fiber::api::queue::QueueHub;
use fiber::cluster::{ClusterBackend, JobHandle, JobSpec, JobStatus, LocalBackend, ProcBackend};
use fiber::comms::Addr;
use fiber::experiments::{
    calibrate_fiber_dispatch_ns, dynamic_scaling_experiment, es_scaling_figure,
    overhead_experiment, pbt_figure, ppo_scaling_figure, ring_collectives_figure,
    OverheadConfig, ScalingConfig,
};
use fiber::ring::{is_chaos_killed, Rendezvous, RingMember};
use fiber::runtime::Runtime;
use fiber::store::StoreNode;

use super::Opts;

fn load_runtime(opts: &Opts) -> Option<Runtime> {
    let dir = opts.get_or("artifacts", "artifacts");
    match Runtime::load_dir(dir) {
        Ok(rt) => {
            println!("runtime: loaded artifacts {:?} from {dir}", rt.models());
            Some(rt)
        }
        Err(e) => {
            println!("runtime: no artifacts ({e:#}); using pure-Rust fallback paths");
            None
        }
    }
}

/// E1 — Fig 3a.
pub fn overhead(opts: &Opts) -> Result<()> {
    let cfg = OverheadConfig {
        workers: opts.parse_or("workers", 5)?,
        samples: opts.parse_or("samples", 3)?,
        ..Default::default()
    };
    overhead_experiment(&cfg)?.print();
    Ok(())
}

/// E2 (real execution): distributed ES on walker2d-hardcore. With
/// `--decentralized true` the leader-centric pool path is replaced by
/// [`EsRingNode`] replicas combining peer-to-peer over ring collectives.
pub fn es(opts: &Opts) -> Result<()> {
    if opts.parse_or("decentralized", false)? {
        return es_decentralized(opts);
    }
    let pop: usize = opts.parse_or("pop", 256)?;
    let iters: usize = opts.parse_or("iters", 30)?;
    let workers: usize = opts.parse_or("workers", 4)?;
    let proc: bool = opts.parse_or("proc", false)?;
    let runtime = load_runtime(opts);
    let pool = Pool::builder().processes(workers).proc_workers(proc).build()?;
    let cfg = EsConfig {
        pop,
        max_steps: opts.parse_or("max-steps", 400)?,
        hardcore: opts.parse_or("hardcore", true)?,
        seed: opts.parse_or("seed", 7u64)?,
        ..Default::default()
    };
    let mut master = EsMaster::new(cfg);
    println!("iter,mean_reward,max_reward,env_steps,grad_norm,elapsed_s");
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let s = master.iterate(&pool, runtime.as_ref())?;
        println!(
            "{},{:.3},{:.3},{},{:.4},{:.2}",
            s.iteration,
            s.mean_reward,
            s.max_reward,
            s.total_env_steps,
            s.grad_norm,
            t0.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

/// The shared ES hyper-parameter surface of the decentralized paths.
fn es_cfg_from_opts(opts: &Opts) -> Result<EsConfig> {
    Ok(EsConfig {
        pop: opts.parse_or("pop", 64)?,
        sigma: opts.parse_or("sigma", 0.05)?,
        lr: opts.parse_or("lr", 0.02)?,
        noise_seed: opts.parse_or("noise-seed", 1234u64)?,
        table_size: opts.parse_or("table-size", 1usize << 20)?,
        max_steps: opts.parse_or("max-steps", 400)?,
        hardcore: opts.parse_or("hardcore", true)?,
        seed: opts.parse_or("seed", 7u64)?,
        eval_task: if opts.parse_or("toy", false)? {
            "es.eval_toy".into()
        } else {
            "es.eval_walker".into()
        },
    })
}

/// Every rank must construct an identical replica (same cfg, same θ).
fn es_ring_replica(opts: &Opts, cfg: EsConfig) -> Result<EsRingNode> {
    if opts.parse_or("toy", false)? {
        let dim: usize = opts.parse_or("dim", 16)?;
        Ok(EsRingNode::new(cfg, vec![0.0; dim]))
    } else {
        Ok(EsRingNode::walker(cfg))
    }
}

/// Peer-wait budget: toy evals are instant; walker rollouts are the long
/// compute phase and need a far larger allowance.
fn replica_timeout(toy: bool) -> Duration {
    if toy {
        Duration::from_secs(2)
    } else {
        Duration::from_secs(20)
    }
}

/// Heartbeat grace matched to the eval cadence: replicas heartbeat once
/// per mirrored rollout pair, so the walker grace must exceed the longest
/// single pair or a live-but-slow rank gets evicted as dead.
fn replica_grace(toy: bool) -> Duration {
    if toy {
        Duration::from_millis(150)
    } else {
        Duration::from_secs(10)
    }
}

/// With the 32Ki-element default chunking, a pop-sized reward buffer is a
/// single chunk and `--kill-chunk` would silently never fire. When chaos
/// is armed, every replica (victim and survivors alike — chunking is SPMD
/// state) narrows its chunks so a handful of kill points exist.
fn chaos_chunk_elems(pop: usize) -> usize {
    (pop / 4).max(1)
}

/// One decentralized replica's run, shared by the thread path and the
/// `es-node` process path so the two backends cannot drift. `kill` is the
/// chaos switch `(rank, iter, chunk)` handed to *every* replica; the one
/// whose joined ring rank matches plays the victim. `grow_after` makes
/// rank 0 request an explicit spare-pool drain after that iteration (the
/// no-chaos way to demo auto-grow). Returns `None` when this replica died
/// (simulated crash — caller drops/exits without `leave()`), else
/// `(rank, generation, world, heals, θ)`.
#[allow(clippy::type_complexity)]
fn run_es_replica(
    mut m: RingMember,
    mut node: EsRingNode,
    iters: usize,
    toy: bool,
    kill: Option<(usize, usize, u64)>,
    grow_after: Option<usize>,
    store: Option<Arc<StoreNode>>,
    log_every_rank: bool,
) -> Result<Option<(usize, u64, usize, u64, Vec<f32>)>> {
    m.set_timeout(replica_timeout(toy));
    let victim = kill.is_some_and(|(r, _, _)| r == m.rank());
    // Warm the table on the default (wide) chunking — the whole point of
    // the broadcast is a handful of big frames — and only then narrow the
    // chunks so the training collectives expose chaos kill points. The
    // store-backed path moves only a 24-byte content id over the ring:
    // replicas that already cache the table blob skip the stream entirely.
    match &store {
        Some(sn) => node.warm_noise_table_store(&mut m, sn)?,
        None => node.warm_noise_table(&mut m)?,
    }
    if kill.is_some() {
        m.set_chunk_elems(chaos_chunk_elems(node.cfg.pop));
    }
    for i in 0..iters {
        if victim && kill.is_some_and(|(_, ki, _)| ki == i) {
            m.set_kill_after_chunk(kill.map(|(_, _, kc)| kc));
        }
        match node.iterate(&mut m) {
            Ok(s) => {
                if log_every_rank || m.rank() == 0 {
                    println!(
                        "rank {}/{} gen {}: iter {:>3}  mean {:>9.3}  max {:>9.3}  \
                         steps {:>8}  |g| {:.4}",
                        m.rank(),
                        m.world(),
                        m.generation(),
                        s.iteration,
                        s.mean_reward,
                        s.max_reward,
                        s.total_env_steps,
                        s.grad_norm,
                    );
                }
                if grow_after == Some(i) && m.rank() == 0 && m.request_grow()? {
                    println!(
                        "rank 0 requested an explicit grow after iter {i}: spares drain \
                         into the next generation"
                    );
                }
            }
            Err(e) if is_chaos_killed(&e) => {
                println!(
                    "rank {} chaos-killed mid-allreduce (iter {i}) — crashing without leave()",
                    m.rank()
                );
                return Ok(None);
            }
            Err(e) => return Err(e),
        }
    }
    Ok(Some((
        m.rank(),
        m.generation(),
        m.world(),
        m.heal_count(),
        node.theta,
    )))
}

/// A standby replica's run: blocks in the spare pool until a heal (or an
/// explicit grow) drafts it, relays the interrupted collective, syncs
/// state from the survivors, then trains the remaining iterations as a
/// full member. Same return contract as [`run_es_replica`]; `None` when
/// the admission window expired without a draft.
#[allow(clippy::type_complexity)]
fn run_es_spare(
    m: Result<RingMember>,
    node: EsRingNode,
    iters: usize,
    toy: bool,
    chaos: bool,
    store: Option<Arc<StoreNode>>,
) -> Result<Option<(usize, u64, usize, u64, Vec<f32>)>> {
    let mut m = match m {
        Ok(m) => m,
        Err(e) => {
            println!("spare was never drafted: {e:#}");
            return Ok(None);
        }
    };
    m.set_timeout(replica_timeout(toy));
    if chaos {
        // SPMD: match the survivors' chaos-narrowed chunking before the
        // first (adopted) collective.
        m.set_chunk_elems(chaos_chunk_elems(node.cfg.pop));
    }
    let (mut node, mut m) = node.join_ring_as_spare(m, store.as_deref())?;
    println!(
        "spare drafted as rank {}/{} (gen {}): state synced, resuming at iter {}",
        m.rank(),
        m.world(),
        m.generation(),
        node.iteration(),
    );
    for _ in node.iteration()..iters {
        node.iterate(&mut m)?;
    }
    Ok(Some((
        m.rank(),
        m.generation(),
        m.world(),
        m.heal_count(),
        node.theta,
    )))
}

/// How long a standby replica waits to be drafted before giving up.
fn spare_admission(iters: usize, toy: bool) -> Duration {
    replica_timeout(toy) * (iters as u32 + 2)
}

/// Block until `count` spares are pending at the rendezvous — bounded, so
/// a spare that dies before registering turns into a clean error instead
/// of a silent hang.
fn await_spare_registration(rv: &Arc<Rendezvous>, count: usize) -> Result<()> {
    let deadline = Instant::now() + Duration::from_secs(30);
    while rv.spares().len() < count {
        anyhow::ensure!(
            Instant::now() < deadline,
            "only {}/{count} spare(s) registered within 30s — a standby \
             replica failed to start",
            rv.spares().len()
        );
        std::thread::sleep(Duration::from_millis(2));
    }
    Ok(())
}

/// `fiber-cli es --decentralized true [--world N] [--iters N] [--proc true]
/// [--kill-rank R --kill-iter I --kill-chunk K] [--spares N [--grow-iter I]]
/// [--toy true]` — leaderless ES over ring collectives. `--kill-rank` is
/// the chaos switch: that rank dies mid-allreduce at iteration I, the
/// survivors heal, and — with `--spares` — the pool drains a standby
/// replica back in, restoring the original world (kill → heal → auto-grow
/// → identical θ including the rejoiner). Without a kill, `--spares`
/// drafts through an explicit grow request after `--grow-iter`.
fn es_decentralized(opts: &Opts) -> Result<()> {
    let world: usize = opts.parse_or("world", 4)?;
    let iters: usize = opts.parse_or("iters", 10)?;
    let proc_mode: bool = opts.parse_or("proc", false)?;
    let kill_rank: i64 = opts.parse_or("kill-rank", -1i64)?;
    let spares: usize = opts.parse_or("spares", 0)?;
    anyhow::ensure!(world >= 1, "--world must be >= 1");
    anyhow::ensure!(
        kill_rank < world as i64,
        "--kill-rank {kill_rank} out of range for world {world}"
    );
    if proc_mode {
        es_decentralized_proc(opts, world, iters, kill_rank, spares)
    } else {
        es_decentralized_threads(opts, world, iters, kill_rank, spares)
    }
}

fn es_decentralized_threads(
    opts: &Opts,
    world: usize,
    iters: usize,
    kill_rank: i64,
    spares: usize,
) -> Result<()> {
    let kill_iter: usize = opts.parse_or("kill-iter", 1)?;
    let kill_chunk: u64 = opts.parse_or("kill-chunk", 0u64)?;
    let toy: bool = opts.parse_or("toy", false)?;
    let cfg = es_cfg_from_opts(opts)?;
    println!(
        "decentralized ES: {world} ring replicas (threads), pop {}, {iters} iters{}{}",
        cfg.pop,
        if kill_rank >= 0 {
            format!(" — chaos: kill rank {kill_rank} at iter {kill_iter} chunk {kill_chunk}")
        } else {
            String::new()
        },
        if spares > 0 {
            format!(" — {spares} spare(s) standing by")
        } else {
            String::new()
        },
    );
    let rv = Rendezvous::new(world);
    rv.set_heartbeat_grace(replica_grace(toy));
    let kill = (kill_rank >= 0).then_some((kill_rank as usize, kill_iter, kill_chunk));
    // Without a kill there is no heal to drain the pool, so rank 0 issues
    // an explicit grow request after --grow-iter instead.
    let grow_after = (spares > 0 && kill.is_none()).then_some(opts.parse_or("grow-iter", 0)?);
    // `--store true`: warm the noise table through the object store (one
    // shared node on the thread backend — the broadcast degenerates to a
    // header exchange plus local cache hits).
    let store = opts
        .parse_or("store", false)?
        .then(|| StoreNode::host(1usize << 30));
    let mut handles = Vec::new();
    for _ in 0..spares {
        let rv = rv.clone();
        let replica = es_ring_replica(opts, cfg.clone())?;
        let store = store.clone();
        let admission = spare_admission(iters, toy);
        handles.push(std::thread::spawn(
            move || -> Result<Option<(usize, u64, usize, u64, Vec<f32>)>> {
                let m = RingMember::join_spare_inproc(&rv, admission);
                run_es_spare(m, replica, iters, toy, kill.is_some(), store)
            },
        ));
    }
    // Gate: pending spares must be registered before the ring can heal or
    // grow into them.
    await_spare_registration(&rv, spares)?;
    for _ in 0..world {
        let rv = rv.clone();
        let replica = es_ring_replica(opts, cfg.clone())?;
        let store = store.clone();
        handles.push(std::thread::spawn(
            move || -> Result<Option<(usize, u64, usize, u64, Vec<f32>)>> {
                let m = RingMember::join_inproc(&rv)?;
                run_es_replica(m, replica, iters, toy, kill, grow_after, store, false)
            },
        ));
    }
    let mut survivors: Vec<(usize, u64, usize, u64, Vec<f32>)> = Vec::new();
    for h in handles {
        if let Some(s) = h.join().expect("replica thread")? {
            survivors.push(s);
        }
    }
    survivors.sort_by_key(|s| s.0);
    let first = survivors.first().context("no surviving replicas")?;
    for s in &survivors[1..] {
        anyhow::ensure!(
            s.4 == first.4,
            "replicas diverged: rank {} disagrees with rank {}",
            s.0,
            first.0
        );
    }
    anyhow::ensure!(
        first.4.iter().all(|v| v.is_finite()),
        "post-heal parameters must be finite"
    );
    println!(
        "{} replicas finished in agreement (generation {}, world {}, {} heal(s)); \
         θ finite and identical",
        survivors.len(),
        first.1,
        first.2,
        first.3,
    );
    Ok(())
}

fn es_decentralized_proc(
    opts: &Opts,
    world: usize,
    iters: usize,
    kill_rank: i64,
    spares: usize,
) -> Result<()> {
    let kill_iter: usize = opts.parse_or("kill-iter", 1)?;
    let kill_chunk: u64 = opts.parse_or("kill-chunk", 0u64)?;
    println!(
        "decentralized ES: {world} es-node OS processes over TCP rendezvous{}",
        if spares > 0 {
            format!(" + {spares} spare es-node(s)")
        } else {
            String::new()
        }
    );
    let rv = Rendezvous::new(world);
    rv.set_heartbeat_grace(replica_grace(opts.parse_or("toy", false)?));
    let srv = rv.serve_rpc("127.0.0.1:0")?;
    let rv_addr = format!("tcp://{}", srv.local_addr());
    // `--store true`: this process hosts the object-store directory; each
    // es-node child connects its own serving node, so the noise table
    // streams once per process cold and cache-hits warm.
    let store_host = if opts.parse_or("store", false)? {
        let sn = StoreNode::host(1usize << 30);
        let ep = sn.serve("127.0.0.1:0")?;
        Some((sn, ep))
    } else {
        None
    };
    let backend = ProcBackend::new()?;
    let forward = [
        "pop", "sigma", "lr", "noise-seed", "table-size", "max-steps", "hardcore", "seed", "toy",
        "dim", "crash-dir", "flight",
    ];
    let grow_iter_armed = spares > 0 && kill_rank < 0;
    let mk_args = |spare: bool| {
        let mut args = vec![
            "es-node".to_string(),
            "--rendezvous".into(),
            rv_addr.clone(),
            "--iters".into(),
            iters.to_string(),
        ];
        for key in forward {
            if let Some(v) = opts.get(key) {
                args.push(format!("--{key}"));
                args.push(v.to_string());
            }
        }
        if spare {
            args.extend(["--spare".into(), "true".into()]);
        }
        if kill_rank >= 0 {
            // Ring ranks are assigned by registration order, not spawn
            // order, so every child gets the chaos flags and compares
            // against the rank it actually receives — same contract as
            // the thread backend. Spares learn whether chaos is armed so
            // they match the chaos-narrowed chunking.
            args.extend([
                "--kill-rank".into(),
                kill_rank.to_string(),
                "--kill-iter".into(),
                kill_iter.to_string(),
                "--kill-chunk".into(),
                kill_chunk.to_string(),
            ]);
        }
        if grow_iter_armed && !spare {
            // No chaos: rank 0 drafts the spares through an explicit grow.
            args.extend([
                "--grow-iter".into(),
                opts.get_or("grow-iter", "0").to_string(),
            ]);
        }
        if let Some((_, ep)) = &store_host {
            args.extend(["--store".into(), ep.clone()]);
        }
        args
    };
    // Spares first, and gated: the pool must be populated before a heal
    // (or the explicit grow) can drain it.
    let spare_handles: Vec<_> = (0..spares)
        .map(|i| backend.submit(JobSpec::command(format!("es-spare-{i}"), mk_args(true))))
        .collect::<Result<Vec<_>>>()?;
    await_spare_registration(&rv, spares)?;
    let handles: Vec<_> = (0..world)
        .map(|i| backend.submit(JobSpec::command(format!("es-node-{i}"), mk_args(false))))
        .collect::<Result<Vec<_>>>()?;
    for h in handles.into_iter().chain(spare_handles) {
        match h.wait() {
            JobStatus::Succeeded => {}
            other => anyhow::bail!("es-node child ended {other:?}"),
        }
    }
    println!("all es-node processes exited cleanly (victim included — it simulated a crash)");
    Ok(())
}

/// `fiber-cli es-node --rendezvous tcp://… [--iters N] [--kill-rank R
/// --kill-iter I --kill-chunk K] [--spare true] [--grow-iter I]
/// [--toy true]` — one OS-process decentralized-ES replica (spawned by
/// `es --decentralized true --proc true`). Every replica receives the
/// same chaos flags and the one whose **joined ring rank** matches
/// `--kill-rank` plays the victim. With `--spare true` the process stands
/// by in the spare pool instead of joining the founding ring, and trains
/// the remaining iterations once a heal (or a peer's `--grow-iter` grow
/// request) drafts it.
pub fn es_node(opts: &Opts) -> Result<()> {
    let rv_addr = Addr::parse(opts.require("rendezvous")?)?;
    let iters: usize = opts.parse_or("iters", 10)?;
    let kill_rank: i64 = opts.parse_or("kill-rank", -1i64)?;
    let kill_iter: usize = opts.parse_or("kill-iter", 1)?;
    let kill_chunk: u64 = opts.parse_or("kill-chunk", 0u64)?;
    let grow_iter: i64 = opts.parse_or("grow-iter", -1i64)?;
    let toy: bool = opts.parse_or("toy", false)?;
    let spare: bool = opts.parse_or("spare", false)?;
    let cfg = es_cfg_from_opts(opts)?;
    let node = es_ring_replica(opts, cfg)?;
    // `--store tcp://…` (handed down by the parent): join the object
    // store with a serving node so this replica's cached blobs are
    // fetchable by its peers.
    let store = match opts.get("store") {
        Some(addr) => {
            let sn = StoreNode::connect(addr, 1usize << 30).context("join object store")?;
            sn.serve("127.0.0.1:0").context("serve store node")?;
            Some(sn)
        }
        None => None,
    };
    let kill = (kill_rank >= 0).then_some((kill_rank as usize, kill_iter, kill_chunk));
    if spare {
        let m = RingMember::join_spare_addr(&rv_addr, spare_admission(iters, toy));
        return match run_es_spare(m, node, iters, toy, kill.is_some(), store)? {
            None => Ok(()), // never drafted: stood down cleanly
            Some((rank, generation, world, heals, _theta)) => {
                println!(
                    "es-node (ex-spare) rank {rank}/{world} done: generation {generation}, \
                     {heals} heal(s) survived"
                );
                Ok(())
            }
        };
    }
    let m = RingMember::join_addr(&rv_addr).context("join ring")?;
    let grow_after = (grow_iter >= 0).then_some(grow_iter as usize);
    match run_es_replica(m, node, iters, toy, kill, grow_after, store, true)? {
        None => {
            // The victim's last act: dump the crash flight recorder (the
            // ring of events leading up to the simulated crash) exactly
            // like a real panic hook would, then skip destructors — a
            // crash does not shut down cleanly.
            fiber::trace::live::crash_dump_now("chaos kill");
            std::process::exit(0)
        }
        Some((rank, generation, world, heals, _theta)) => {
            println!(
                "es-node rank {rank}/{world} done: generation {generation}, \
                 {heals} heal(s) survived"
            );
            Ok(())
        }
    }
}

/// E3 (real execution): distributed PPO on breakout. With
/// `--decentralized true` the leader-centric path is replaced by
/// data-parallel ring replicas averaging gradients through
/// [`PpoTrainer::train_iteration_ring`].
pub fn ppo(opts: &Opts) -> Result<()> {
    if opts.parse_or("decentralized", false)? {
        return ppo_decentralized(opts);
    }
    let n_envs: usize = opts.parse_or("envs", 16)?;
    let iters: usize = opts.parse_or("iters", 50)?;
    let workers: usize = opts.parse_or("workers", 4)?;
    let runtime = load_runtime(opts);
    let hub = QueueHub::new();
    let backend = LocalBackend::new();
    let cfg = PpoConfig {
        n_envs,
        horizon: opts.parse_or("horizon", 128)?,
        seed: opts.parse_or("seed", 0u64)?,
        ..Default::default()
    };
    let ve = VecEnv::breakout(&backend, &hub, n_envs, workers)?;
    let mut tr = PpoTrainer::new(cfg);
    let mut obs = ve.reset(1)?;
    println!("iter,frames,mean_ep_reward,episodes,pi_loss,v_loss,entropy,elapsed_s");
    let t0 = std::time::Instant::now();
    let mut frames = 0u64;
    for _ in 0..iters {
        let s = tr.train_iteration(&ve, &mut obs, runtime.as_ref())?;
        frames += s.frames;
        println!(
            "{},{},{:.2},{},{:.4},{:.4},{:.4},{:.2}",
            s.iteration,
            frames,
            s.mean_episode_reward,
            s.episodes,
            s.pi_loss,
            s.v_loss,
            s.entropy,
            t0.elapsed().as_secs_f64()
        );
    }
    ve.close();
    Ok(())
}

/// One decentralized PPO replica's summary: `(rank, generation, world,
/// heals, θ)`.
type PpoSurvivor = (usize, u64, usize, u64, Vec<f32>);

/// `fiber-cli ppo --decentralized true [--world N] [--envs N] [--iters N]
/// [--kill-rank R --kill-iter I --kill-chunk K] [--spares N
/// [--grow-iter I]]` — data-parallel PPO over ring collectives, mirroring
/// `es --decentralized`. Every replica owns `--envs` breakout
/// environments (distinct seeds), computes local clipped-surrogate
/// gradients, and ring-averages them, so one update covers
/// `world × envs` environments with `O(θ)` traffic per replica.
/// `--kill-rank` is the same chaos switch: that rank dies mid-allreduce
/// at iteration I, the survivors heal — and with `--spares`, the pool
/// drains a standby replica back in (kill → heal → auto-grow → identical
/// θ including the rejoiner).
fn ppo_decentralized(opts: &Opts) -> Result<()> {
    let world: usize = opts.parse_or("world", 4)?;
    let iters: usize = opts.parse_or("iters", 5)?;
    let kill_rank: i64 = opts.parse_or("kill-rank", -1i64)?;
    let kill_iter: usize = opts.parse_or("kill-iter", 1)?;
    let kill_chunk: u64 = opts.parse_or("kill-chunk", 0u64)?;
    let spares: usize = opts.parse_or("spares", 0)?;
    anyhow::ensure!(world >= 1, "--world must be >= 1");
    anyhow::ensure!(
        kill_rank < world as i64,
        "--kill-rank {kill_rank} out of range for world {world}"
    );
    let cfg = PpoConfig {
        n_envs: opts.parse_or("envs", 4)?,
        horizon: opts.parse_or("horizon", 64)?,
        epochs: opts.parse_or("epochs", 2)?,
        minibatch: opts.parse_or("minibatch", 64)?,
        seed: opts.parse_or("seed", 0u64)?,
        ..Default::default()
    };
    println!(
        "decentralized PPO: {world} ring replicas (threads), {} envs each, {iters} iters{}",
        cfg.n_envs,
        if kill_rank >= 0 {
            format!(" — chaos: kill rank {kill_rank} at iter {kill_iter} chunk {kill_chunk}")
        } else {
            String::new()
        }
    );
    let rv = Rendezvous::new(world);
    rv.set_heartbeat_grace(Duration::from_secs(5));
    let kill = (kill_rank >= 0).then_some((kill_rank as usize, kill_iter, kill_chunk));
    let grow_after = (spares > 0 && kill.is_none()).then_some(opts.parse_or("grow-iter", 0)?);
    // Narrow the gradient chunks only when chaos is armed (SPMD state), so
    // `--kill-chunk` has real kill points inside the O(θ) allreduce.
    let chunk_elems = (fiber::algo::nn::ppo_param_count() / 4).max(1);
    let mut handles = Vec::new();
    for s in 0..spares {
        let rv = rv.clone();
        let cfg = cfg.clone();
        let chaos = kill.is_some();
        handles.push(std::thread::spawn(move || -> Result<Option<PpoSurvivor>> {
            let m = match RingMember::join_spare_inproc(
                &rv,
                Duration::from_secs(10 * (iters as u64 + 2)),
            ) {
                Ok(m) => m,
                Err(e) => {
                    println!("ppo spare was never drafted: {e:#}");
                    return Ok(None);
                }
            };
            let mut m = m;
            m.set_timeout(Duration::from_secs(10));
            if chaos {
                m.set_chunk_elems(chunk_elems);
            }
            let tr = PpoTrainer::new(cfg.clone());
            let (mut tr, mut m) = tr.join_ring_as_spare(m)?;
            println!(
                "ppo spare drafted as rank {}/{} (gen {}): resuming at iter {}",
                m.rank(),
                m.world(),
                m.generation(),
                tr.iteration(),
            );
            let hub = QueueHub::new();
            let backend = LocalBackend::new();
            let ve = VecEnv::breakout(&backend, &hub, cfg.n_envs, 2)?;
            let mut obs = ve.reset(2000 + s as u64)?;
            for _ in tr.iteration()..iters {
                tr.train_iteration_ring(&ve, &mut obs, None, &mut m)?;
            }
            ve.close();
            Ok(Some((
                m.rank(),
                m.generation(),
                m.world(),
                m.heal_count(),
                tr.net.params,
            )))
        }));
    }
    await_spare_registration(&rv, spares)?;
    for _ in 0..world {
        let rv = rv.clone();
        let cfg = cfg.clone();
        handles.push(std::thread::spawn(move || -> Result<Option<PpoSurvivor>> {
            let mut m = RingMember::join_inproc(&rv)?;
            m.set_timeout(Duration::from_secs(10));
            if kill.is_some() {
                m.set_chunk_elems(chunk_elems);
            }
            let victim = kill.is_some_and(|(r, _, _)| r == m.rank());
            let hub = QueueHub::new();
            let backend = LocalBackend::new();
            let ve = VecEnv::breakout(&backend, &hub, cfg.n_envs, 2)?;
            let mut tr = PpoTrainer::new(cfg);
            // Identical parameters everywhere, distinct env streams.
            let mut obs = ve.reset(1000 + m.rank() as u64)?;
            for i in 0..iters {
                if victim && kill.is_some_and(|(_, ki, _)| ki == i) {
                    m.set_kill_after_chunk(kill.map(|(_, _, kc)| kc));
                }
                match tr.train_iteration_ring(&ve, &mut obs, None, &mut m) {
                    Ok(s) => {
                        if m.rank() == 0 {
                            println!(
                                "rank {}/{} gen {}: iter {:>3}  ep_reward {:>7.2}  \
                                 pi {:.4}  v {:.4}  H {:.4}",
                                m.rank(),
                                m.world(),
                                m.generation(),
                                s.iteration,
                                s.mean_episode_reward,
                                s.pi_loss,
                                s.v_loss,
                                s.entropy,
                            );
                        }
                        if grow_after == Some(i) && m.rank() == 0 && m.request_grow()? {
                            println!(
                                "rank 0 requested an explicit grow after iter {i}: \
                                 spares drain into the next generation"
                            );
                        }
                    }
                    Err(e) if is_chaos_killed(&e) => {
                        println!(
                            "rank {} chaos-killed mid-allreduce (iter {i}) — \
                             crashing without leave()",
                            m.rank()
                        );
                        ve.close();
                        return Ok(None);
                    }
                    Err(e) => return Err(e),
                }
            }
            ve.close();
            Ok(Some((
                m.rank(),
                m.generation(),
                m.world(),
                m.heal_count(),
                tr.net.params,
            )))
        }));
    }
    let mut survivors: Vec<PpoSurvivor> = Vec::new();
    for h in handles {
        if let Some(s) = h.join().expect("replica thread")? {
            survivors.push(s);
        }
    }
    survivors.sort_by_key(|s| s.0);
    let first = survivors.first().context("no surviving replicas")?;
    for s in &survivors[1..] {
        anyhow::ensure!(
            s.4 == first.4,
            "replicas diverged: rank {} disagrees with rank {}",
            s.0,
            first.0
        );
    }
    anyhow::ensure!(
        first.4.iter().all(|v| v.is_finite()),
        "post-heal parameters must be finite"
    );
    println!(
        "{} PPO replicas finished in agreement (generation {}, world {}, {} heal(s)); \
         θ finite and identical",
        survivors.len(),
        first.1,
        first.2,
        first.3,
    );
    Ok(())
}

/// E2/E3 virtual-time scaling curves + E5 dynamic scaling.
pub fn scaling_sim(opts: &Opts) -> Result<()> {
    println!("calibrating fiber per-task dispatch cost…");
    let dispatch_ns = calibrate_fiber_dispatch_ns(4, 512)?;
    println!("  measured {dispatch_ns} ns/task");
    let cfg = ScalingConfig {
        pop: opts.parse_or("pop", 2048)?,
        iterations: opts.parse_or("iters", 50)?,
        ppo_frames: opts.parse_or("frames", 10_000_000u64)?,
        ..Default::default()
    };
    es_scaling_figure(&cfg, dispatch_ns)?.print();
    // PPO model step measured from the artifact path when present, else a
    // representative constant (Breakout CNN on a 1080 Ti ≈ 30 ms/update).
    let model_step_ns: u64 = opts.parse_or("model-step-ns", 30_000_000u64)?;
    ppo_scaling_figure(&cfg, 500, model_step_ns)?.print();
    dynamic_scaling_experiment()?.print();
    // Ring-collectives panel: overlap on/off + kill-one recovery, folded in
    // beside the scaling curves (full sweep: `cargo bench --bench
    // ring_allreduce`, which persists BENCH_ring.json).
    ring_collectives_figure()?.print();
    // Population layer: async vs lock-step PBT dispatch (full sweep with
    // pop 8/32 and exploit costs: `cargo bench --bench pbt`, which
    // persists BENCH_pbt.json).
    pbt_figure()?.print();
    Ok(())
}

/// Used by `FiberProcess::spawn_cmd` examples; keep Arc import used.
#[allow(dead_code)]
fn _keep(_: Arc<()>) {}
