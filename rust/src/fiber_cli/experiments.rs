//! CLI wrappers for the paper's experiments (E1–E5) and the real ES/PPO
//! training drivers used by EXPERIMENTS.md.

use std::sync::Arc;

use anyhow::Result;

use fiber::algo::es::{EsConfig, EsMaster};
use fiber::algo::ppo::{PpoConfig, PpoTrainer};
use fiber::algo::vec_env::VecEnv;
use fiber::api::pool::Pool;
use fiber::api::queue::QueueHub;
use fiber::cluster::LocalBackend;
use fiber::experiments::{
    calibrate_fiber_dispatch_ns, dynamic_scaling_experiment, es_scaling_figure,
    overhead_experiment, ppo_scaling_figure, OverheadConfig, ScalingConfig,
};
use fiber::runtime::Runtime;

use super::Opts;

fn load_runtime(opts: &Opts) -> Option<Runtime> {
    let dir = opts.get_or("artifacts", "artifacts");
    match Runtime::load_dir(dir) {
        Ok(rt) => {
            println!("runtime: loaded artifacts {:?} from {dir}", rt.models());
            Some(rt)
        }
        Err(e) => {
            println!("runtime: no artifacts ({e:#}); using pure-Rust fallback paths");
            None
        }
    }
}

/// E1 — Fig 3a.
pub fn overhead(opts: &Opts) -> Result<()> {
    let cfg = OverheadConfig {
        workers: opts.parse_or("workers", 5)?,
        samples: opts.parse_or("samples", 3)?,
        ..Default::default()
    };
    overhead_experiment(&cfg)?.print();
    Ok(())
}

/// E2 (real execution): distributed ES on walker2d-hardcore.
pub fn es(opts: &Opts) -> Result<()> {
    let pop: usize = opts.parse_or("pop", 256)?;
    let iters: usize = opts.parse_or("iters", 30)?;
    let workers: usize = opts.parse_or("workers", 4)?;
    let proc: bool = opts.parse_or("proc", false)?;
    let runtime = load_runtime(opts);
    let pool = Pool::builder().processes(workers).proc_workers(proc).build()?;
    let cfg = EsConfig {
        pop,
        max_steps: opts.parse_or("max-steps", 400)?,
        hardcore: opts.parse_or("hardcore", true)?,
        seed: opts.parse_or("seed", 7u64)?,
        ..Default::default()
    };
    let mut master = EsMaster::new(cfg);
    println!("iter,mean_reward,max_reward,env_steps,grad_norm,elapsed_s");
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        let s = master.iterate(&pool, runtime.as_ref())?;
        println!(
            "{},{:.3},{:.3},{},{:.4},{:.2}",
            s.iteration,
            s.mean_reward,
            s.max_reward,
            s.total_env_steps,
            s.grad_norm,
            t0.elapsed().as_secs_f64()
        );
    }
    Ok(())
}

/// E3 (real execution): distributed PPO on breakout.
pub fn ppo(opts: &Opts) -> Result<()> {
    let n_envs: usize = opts.parse_or("envs", 16)?;
    let iters: usize = opts.parse_or("iters", 50)?;
    let workers: usize = opts.parse_or("workers", 4)?;
    let runtime = load_runtime(opts);
    let hub = QueueHub::new();
    let backend = LocalBackend::new();
    let cfg = PpoConfig {
        n_envs,
        horizon: opts.parse_or("horizon", 128)?,
        seed: opts.parse_or("seed", 0u64)?,
        ..Default::default()
    };
    let ve = VecEnv::breakout(&backend, &hub, n_envs, workers)?;
    let mut tr = PpoTrainer::new(cfg);
    let mut obs = ve.reset(1)?;
    println!("iter,frames,mean_ep_reward,episodes,pi_loss,v_loss,entropy,elapsed_s");
    let t0 = std::time::Instant::now();
    let mut frames = 0u64;
    for _ in 0..iters {
        let s = tr.train_iteration(&ve, &mut obs, runtime.as_ref())?;
        frames += s.frames;
        println!(
            "{},{},{:.2},{},{:.4},{:.4},{:.4},{:.2}",
            s.iteration,
            frames,
            s.mean_episode_reward,
            s.episodes,
            s.pi_loss,
            s.v_loss,
            s.entropy,
            t0.elapsed().as_secs_f64()
        );
    }
    ve.close();
    Ok(())
}

/// E2/E3 virtual-time scaling curves + E5 dynamic scaling.
pub fn scaling_sim(opts: &Opts) -> Result<()> {
    println!("calibrating fiber per-task dispatch cost…");
    let dispatch_ns = calibrate_fiber_dispatch_ns(4, 512)?;
    println!("  measured {dispatch_ns} ns/task");
    let cfg = ScalingConfig {
        pop: opts.parse_or("pop", 2048)?,
        iterations: opts.parse_or("iters", 50)?,
        ppo_frames: opts.parse_or("frames", 10_000_000u64)?,
        ..Default::default()
    };
    es_scaling_figure(&cfg, dispatch_ns)?.print();
    // PPO model step measured from the artifact path when present, else a
    // representative constant (Breakout CNN on a 1080 Ti ≈ 30 ms/update).
    let model_step_ns: u64 = opts.parse_or("model-step-ns", 30_000_000u64)?;
    ppo_scaling_figure(&cfg, 500, model_step_ns)?.print();
    dynamic_scaling_experiment()?.print();
    Ok(())
}

/// Used by `FiberProcess::spawn_cmd` examples; keep Arc import used.
#[allow(dead_code)]
fn _keep(_: Arc<()>) {}
