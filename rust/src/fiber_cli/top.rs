//! `fiber-cli top` — the cluster health readout.
//!
//! Two sources, one renderer ([`fiber::trace::live::HealthSnapshot`]):
//!
//! * `--connect ADDR` pulls live snapshots from a run started with
//!   `--serve-top ADDR` (node liveness, pool throughput/queue depth, ring
//!   op/chunk progress, store hit-rate, pop leaderboard, straggler flags).
//!   Default is a refreshing view; `--once` prints a single plain-text
//!   snapshot — the CI mode.
//! * `--input FILE_OR_DIR` replays a recorded trace (a JSONL file or a
//!   live segment directory) through the same [`fiber::trace::live::Health`]
//!   model offline: the readout a live `top` would have shown at the end
//!   of that run.

use std::time::Duration;

use anyhow::{bail, Context, Result};

use fiber::trace::live::{fetch_snapshot, health_from_dump, HealthSnapshot};

use super::Opts;

pub fn top(opts: &Opts) -> Result<()> {
    let once = opts.parse_or("once", false)?;
    let interval = Duration::from_millis(opts.parse_or("interval-ms", 1000u64)?);
    let k: u64 = opts.parse_or("straggler-k", 3)?;
    match (opts.get("connect"), opts.get("input")) {
        (Some(_), Some(_)) => bail!("--connect and --input are mutually exclusive"),
        (None, None) => bail!("top needs --connect ADDR (live) or --input FILE_OR_DIR (offline)"),
        (None, Some(path)) => {
            // Offline: fold the whole recorded stream through the health
            // model. Gauge-backed fields (queue depth, store bytes) read
            // this process's registry and render as zero.
            let dump = fiber::trace::export::read_trace(path)?;
            let health = health_from_dump(&dump, k);
            print!("{}", health.snapshot().render());
            if dump.crash {
                println!("(crash flight-recorder window — counts cover the last moments only)");
            }
            Ok(())
        }
        (Some(addr), None) => {
            let addr: std::net::SocketAddr = addr
                .parse()
                .with_context(|| format!("--connect {addr:?} is not host:port"))?;
            loop {
                let snap: HealthSnapshot = fetch_snapshot(addr)?;
                if once {
                    print!("{}", snap.render());
                    return Ok(());
                }
                // Refreshing view: clear, home, redraw.
                print!("\x1b[2J\x1b[H{}", snap.render());
                println!("(refreshing every {} ms — ctrl-c to quit)", interval.as_millis());
                std::thread::sleep(interval);
            }
        }
    }
}
