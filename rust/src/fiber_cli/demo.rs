//! The paper's code example 1: Monte-Carlo π over a Fiber pool.

use anyhow::Result;

use fiber::api::pool::Pool;
use fiber::coordinator::register_task;
use fiber::util::Rng;

use super::Opts;

pub fn register() {
    register_task("demo.pi_batch", |(seed, n): (u64, u64)| {
        let mut rng = Rng::new(seed);
        let mut inside = 0u64;
        for _ in 0..n {
            let (x, y) = (rng.f64(), rng.f64());
            if x * x + y * y < 1.0 {
                inside += 1;
            }
        }
        Ok::<u64, String>(inside)
    });
}

/// Deterministic two-level-scheduler demo (and the CI sched smoke).
///
/// Phase 1 — **stealing**: one long task pins worker 1 while short tasks
/// queue behind it; worker 2 drains its own queue well inside the long
/// task's runtime and must steal from worker 1 (the longest queue) —
/// guaranteeing at least one `sched.steal` event without relying on race
/// timing. Phase 2 — **locality**: a warm `apply` faults a store blob
/// into one worker's node, then a map over the same [`ObjRef`] routes to
/// that holder, producing `sched.local_hit` events. Run with `--trace
/// FILE.jsonl` and the events land in the exported trace; the demo exits
/// non-zero if either phase failed to produce its event.
pub fn sched_demo(opts: &Opts) -> Result<()> {
    use fiber::store::{ObjRef, StoreNode};
    register_task("sched.spin", |ms: u64| {
        std::thread::sleep(std::time::Duration::from_millis(ms));
        Ok::<u64, String>(ms)
    });
    register_task("sched.ref_sum", |r: ObjRef<Vec<f32>>| {
        let v: Vec<f32> = r.get().map_err(|e| e.to_string())?;
        Ok::<f32, String>(v.iter().sum())
    });
    let long_ms: u64 = opts.parse_or("long-ms", 120u64)?;
    let short_ms: u64 = opts.parse_or("short-ms", 5u64)?;
    let shorts: usize = opts.parse_or("shorts", 8usize)?;
    let leader = StoreNode::host(64 << 20);
    let pool = Pool::builder()
        .processes(2)
        .store(leader.clone())
        .worker_store_budget(16 << 20)
        .build()?;
    // Phase 1: the long task is placed first (worker 1's queue), shorts
    // alternate across both queues behind it.
    let mut work = vec![long_ms];
    work.extend(std::iter::repeat(short_ms).take(shorts));
    let done: Vec<u64> = pool.map("sched.spin", work)?;
    anyhow::ensure!(done.len() == shorts + 1);
    // Phase 2: fault the blob into exactly one worker, then map over it.
    let payload: Vec<f32> = (0..50_000).map(|i| (i % 11) as f32).collect();
    let want: f32 = payload.iter().sum();
    let r = pool.put_ref(&payload)?;
    let warm: f32 = pool.apply("sched.ref_sum", r)?;
    anyhow::ensure!((warm - want).abs() < 1.0, "warm sum {warm} != {want}");
    let sums: Vec<f32> = pool.map("sched.ref_sum", std::iter::repeat(r).take(shorts))?;
    anyhow::ensure!(sums.iter().all(|s| (s - want).abs() < 1.0));
    let s = pool.sched_stats();
    let routed = s.local_hits + s.local_misses;
    println!(
        "sched-demo: {} tasks in {} node batches | locality {}/{routed} hit \
         | steals {} | spills {} | reassigned {}",
        s.assigned_tasks, s.assigned_batches, s.local_hits, s.steals, s.spills, s.reassigned
    );
    let transfers: u64 = pool.worker_stores().iter().map(|(_, n)| n.transfers()).sum();
    println!(
        "sched-demo: worker-node blob transfers {transfers} (one fault-in, \
         then cache hits on the holder)"
    );
    anyhow::ensure!(
        s.steals >= 1,
        "phase 1 produced no sched.steal (long {long_ms}ms, {shorts} x {short_ms}ms)"
    );
    anyhow::ensure!(s.local_hits >= 1, "phase 2 produced no sched.local_hit");
    anyhow::ensure!(
        transfers == 1,
        "the by-ref blob must cross to the worker tier exactly once, got {transfers}"
    );
    Ok(())
}

pub fn pi_demo(opts: &Opts) -> Result<()> {
    register();
    let workers: usize = opts.parse_or("workers", 4)?;
    let samples: u64 = opts.parse_or("samples", 10_000_000u64)?;
    let proc: bool = opts.parse_or("proc", false)?;
    let batches = 64u64;
    let per = samples / batches;
    let pool = Pool::builder().processes(workers).proc_workers(proc).build()?;
    let t0 = std::time::Instant::now();
    let counts: Vec<u64> =
        pool.map("demo.pi_batch", (0..batches).map(|b| (b + 1, per)))?;
    let inside: u64 = counts.iter().sum();
    let pi = 4.0 * inside as f64 / (per * batches) as f64;
    println!(
        "pi ≈ {pi:.6} ({} samples, {workers} {} workers, {:.2?})",
        per * batches,
        if proc { "process" } else { "thread" },
        t0.elapsed()
    );
    Ok(())
}
