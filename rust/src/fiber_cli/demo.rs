//! The paper's code example 1: Monte-Carlo π over a Fiber pool.

use anyhow::Result;

use fiber::api::pool::Pool;
use fiber::coordinator::register_task;
use fiber::util::Rng;

use super::Opts;

pub fn register() {
    register_task("demo.pi_batch", |(seed, n): (u64, u64)| {
        let mut rng = Rng::new(seed);
        let mut inside = 0u64;
        for _ in 0..n {
            let (x, y) = (rng.f64(), rng.f64());
            if x * x + y * y < 1.0 {
                inside += 1;
            }
        }
        Ok::<u64, String>(inside)
    });
}

pub fn pi_demo(opts: &Opts) -> Result<()> {
    register();
    let workers: usize = opts.parse_or("workers", 4)?;
    let samples: u64 = opts.parse_or("samples", 10_000_000u64)?;
    let proc: bool = opts.parse_or("proc", false)?;
    let batches = 64u64;
    let per = samples / batches;
    let pool = Pool::builder().processes(workers).proc_workers(proc).build()?;
    let t0 = std::time::Instant::now();
    let counts: Vec<u64> =
        pool.map("demo.pi_batch", (0..batches).map(|b| (b + 1, per)))?;
    let inside: u64 = counts.iter().sum();
    let pi = 4.0 * inside as f64 / (per * batches) as f64;
    println!(
        "pi ≈ {pi:.6} ({} samples, {workers} {} workers, {:.2?})",
        per * batches,
        if proc { "process" } else { "thread" },
        t0.elapsed()
    );
    Ok(())
}
