//! `fiber-cli pbt` — the population-based-training driver.
//!
//! Runs an asynchronous PBT population over Pool workers (threads by
//! default, `--proc true` for `fiber-cli worker` OS processes wired to
//! the leader's object store). `--kill-rank R` is the chaos switch: the
//! pool worker with that rank dies mid-slice, the pool heals, the slice
//! is requeued with the same checkpoint reference, and the run must end
//! with every trial lineage intact.

use anyhow::{Context, Result};

use fiber::api::pool::Pool;
use fiber::pop::{
    DispatchMode, EnvKind, LineageEventKind, PbtAlgo, PbtConfig, PopulationRunner,
};

use super::Opts;

/// `fiber-cli pbt --algo {es,ppo} --pop N --workers W [--env cartpole]
/// [--slices N] [--iters N] [--proc true] [--sync true] [--kill-rank R]`
pub fn pbt(opts: &Opts) -> Result<()> {
    let algo = PbtAlgo::parse(opts.get_or("algo", "es"))?;
    let env = EnvKind::parse(opts.get_or("env", "cartpole"))?;
    let pop: usize = opts.parse_or("pop", 8)?;
    let workers: usize = opts.parse_or("workers", 4)?;
    let slices: usize = opts.parse_or("slices", 4)?;
    let proc_mode: bool = opts.parse_or("proc", false)?;
    let sync: bool = opts.parse_or("sync", false)?;
    let kill_rank: i64 = opts.parse_or("kill-rank", -1i64)?;
    anyhow::ensure!(
        kill_rank < workers as i64,
        "--kill-rank {kill_rank} out of range for {workers} workers"
    );
    // Only the worker whose id matches the kill target can die, so the
    // queue must be deep enough that every worker (the victim included)
    // is guaranteed to fetch an armed slice.
    anyhow::ensure!(
        kill_rank < 0 || pop >= workers,
        "--kill-rank needs --pop >= --workers ({pop} < {workers}): with fewer armed \
         slices than workers the victim may never fetch one"
    );
    let cfg = PbtConfig {
        algo,
        env,
        pop,
        slices,
        iters_per_slice: opts.parse_or("iters", 2)?,
        max_steps: opts.parse_or("max-steps", 200)?,
        pop_inner: opts.parse_or("pop-inner", 16)?,
        horizon: opts.parse_or("horizon", 64)?,
        quantile: opts.parse_or("quantile", 0.25)?,
        seed: opts.parse_or("seed", 7u64)?,
        // Worker ids are 1-based; rank R is the (R+1)-th spawned worker.
        kill_worker: if kill_rank >= 0 { kill_rank as u64 + 1 } else { 0 },
        store_noise_table: algo == PbtAlgo::Es,
        verbose: true,
        ..Default::default()
    };
    let mode = if sync {
        DispatchMode::Generational
    } else {
        DispatchMode::Async
    };
    println!(
        "pbt: {algo:?} on {env:?} — pop {pop} × {slices} slices, {workers} {} workers, \
         {mode:?} dispatch{}",
        if proc_mode { "OS-process" } else { "thread" },
        if kill_rank >= 0 {
            format!(" — chaos: kill worker rank {kill_rank} mid-slice")
        } else {
            String::new()
        }
    );
    // One process-global store node: checkpoints pass by reference, and
    // with --proc true every worker process joins it over TCP.
    let store = fiber::store::node_or_host(1 << 30);
    let pool = Pool::builder()
        .processes(workers)
        .proc_workers(proc_mode)
        .store(store.clone())
        .build()
        .context("build pool")?;
    let mut runner = PopulationRunner::new(cfg, store)?;
    let report = runner.run(&pool, mode)?;

    // Final standings.
    let mut rows: Vec<_> = runner.trials().iter().collect();
    rows.sort_by(|a, b| {
        b.best_score
            .partial_cmp(&a.best_score)
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    println!("\ntrial | score    | best     | slices | clones | parent | hparams");
    for t in &rows {
        let hp: Vec<String> = t
            .hparams
            .0
            .iter()
            .map(|h| format!("{}={:.4}", h.name, h.value))
            .collect();
        println!(
            "{:>5} | {:>8.2} | {:>8.2} | {:>6} | {:>6} | {:>6} | {}",
            t.id.to_string(),
            t.score,
            t.best_score,
            t.slices_done,
            t.clones,
            t.parent.map(|p| p.to_string()).unwrap_or_else(|| "-".into()),
            hp.join(" ")
        );
    }

    // Lineage integrity: the acceptance bar for the chaos path.
    for t in runner.trials() {
        anyhow::ensure!(
            t.slices_done == slices,
            "trial {} lost slices: {}/{slices}",
            t.id,
            t.slices_done
        );
        anyhow::ensure!(
            runner.leaderboard().best_is_monotone(t.id),
            "trial {} best-reward regressed in its lineage",
            t.id
        );
    }
    let exploits = runner
        .leaderboard()
        .events()
        .iter()
        .filter(|e| matches!(e.kind, LineageEventKind::Clone { .. }))
        .count();
    if kill_rank >= 0 {
        anyhow::ensure!(
            pool.restarts() >= 1,
            "chaos was armed but no worker died"
        );
        let (_, _, requeued) = pool.counters();
        anyhow::ensure!(
            requeued >= 1,
            "the killed worker's slice must have been requeued, not dropped"
        );
        println!(
            "\nchaos: worker rank {kill_rank} died mid-slice; pool healed \
             ({} restart(s), {requeued} task(s) requeued) and no trial was lost",
            pool.restarts()
        );
    }
    println!(
        "\nall {pop} trial lineages intact: best {} at {:.2} (population mean {:.2}), \
         {} slices, {exploits} exploit(s) in {:.1}s",
        report.best, report.best_score, report.mean_score, report.slices_completed, report.wall_s
    );

    // Post-hoc artifact: the full lineage log — every slice, clone and
    // mutation with per-event hyper-parameter snapshots — beside the
    // BENCH files, ready for schedule plots.
    match runner.leaderboard().export("pbt_lineage.json") {
        Ok(()) => println!("wrote pbt_lineage.json (per-trial hyper-parameter schedules)"),
        Err(e) => eprintln!("failed to write pbt_lineage.json: {e}"),
    }
    Ok(())
}
