//! `fiber-cli ring` — the collective-communication demo, and `ring-node`,
//! the OS-process ring member entrypoint (the collective analogue of the
//! `worker` subcommand).
//!
//! Thread mode (default) forms the ring in-process; `--proc true` spawns
//! `fiber-cli ring-node` children through [`ProcBackend`] that rendezvous
//! over TCP and run the same allreduce — the same program on both
//! backends, which is the ring layer's version of the paper's one-line
//! migration story.

use anyhow::{Context, Result};

use fiber::cluster::{ClusterBackend, JobHandle, JobSpec, JobStatus, ProcBackend};
use fiber::comms::Addr;
use fiber::ring::{Rendezvous, RingMember};

use super::Opts;

/// Fill a member's buffer: every element is `rank + 1`, so the allreduced
/// value of every element is `world·(world+1)/2`.
fn member_buf(rank: usize, elems: usize) -> Vec<f32> {
    vec![(rank + 1) as f32; elems]
}

fn expected_sum(world: usize) -> f32 {
    (world * (world + 1) / 2) as f32
}

/// Check every element of an allreduced buffer against the closed form.
fn verify(buf: &[f32], world: usize) -> Result<()> {
    let want = expected_sum(world);
    for (i, v) in buf.iter().enumerate() {
        anyhow::ensure!(
            (v - want).abs() < 1e-4,
            "allreduce mismatch at element {i}: got {v}, want {want}"
        );
    }
    Ok(())
}

/// `fiber-cli ring [--world N] [--elems N] [--proc true] [--overlap false]`
pub fn ring_demo(opts: &Opts) -> Result<()> {
    let world: usize = opts.parse_or("world", 4)?;
    let elems: usize = opts.parse_or("elems", 1 << 16)?;
    let proc_mode: bool = opts.parse_or("proc", false)?;
    let overlap: bool = opts.parse_or("overlap", true)?;
    anyhow::ensure!(world >= 1, "--world must be >= 1");
    if proc_mode {
        ring_demo_proc(world, elems, overlap)
    } else {
        ring_demo_threads(world, elems, overlap)
    }
}

fn ring_demo_threads(world: usize, elems: usize, overlap: bool) -> Result<()> {
    println!(
        "ring demo: {world} thread members, {elems} f32 elements ({} KB), overlap {}",
        elems * 4 / 1024,
        if overlap { "on" } else { "off" }
    );
    let rv = Rendezvous::new(world);
    let handles: Vec<_> = (0..world)
        .map(|_| {
            let rv = rv.clone();
            std::thread::spawn(move || -> Result<(usize, u64, u64, f64)> {
                let mut m = RingMember::join_inproc(&rv)?;
                m.set_overlap(overlap);
                let mut buf = member_buf(m.rank(), elems);
                m.allreduce_sum(&mut buf)?;
                verify(&buf, m.world())?;
                let ring_bytes = m.bytes_sent() + m.bytes_received();
                let overlap_eff = m.overlap_efficiency();
                m.reset_counters();
                let mut buf = member_buf(m.rank(), elems);
                m.gather_broadcast_sum(0, &mut buf)?;
                verify(&buf, m.world())?;
                let naive_bytes = m.bytes_sent() + m.bytes_received();
                Ok((m.rank(), ring_bytes, naive_bytes, overlap_eff))
            })
        })
        .collect();
    let mut rows: Vec<(usize, u64, u64, f64)> = Vec::new();
    for h in handles {
        rows.push(h.join().expect("ring member thread")?);
    }
    rows.sort_by_key(|r| r.0);
    println!("rank | ring allreduce bytes | gather-broadcast bytes | overlap");
    for (rank, ring_bytes, naive_bytes, overlap_eff) in &rows {
        println!(
            "{rank:>4} | {ring_bytes:>20} | {naive_bytes:>22} | {:>6.1}%",
            overlap_eff * 100.0
        );
    }
    let ring_max = rows.iter().map(|r| r.1).max().unwrap_or(0);
    let naive_root = rows.first().map(|r| r.2).unwrap_or(0);
    println!(
        "busiest node: ring {ring_max} B vs gather-broadcast root {naive_root} B \
         ({}% of the leader hotspot)",
        if naive_root > 0 { 100 * ring_max / naive_root } else { 0 }
    );
    println!("all {world} members verified sum {}", expected_sum(world));
    Ok(())
}

fn ring_demo_proc(world: usize, elems: usize, overlap: bool) -> Result<()> {
    println!(
        "ring demo: {world} OS-process members, {elems} f32 elements, overlap {}",
        if overlap { "on" } else { "off" }
    );
    let rv = Rendezvous::new(world);
    let srv = rv.serve_rpc("127.0.0.1:0")?;
    let rv_addr = format!("tcp://{}", srv.local_addr());
    let backend = ProcBackend::new()?;
    let handles: Vec<_> = (0..world)
        .map(|i| {
            backend.submit(JobSpec::command(
                format!("ring-node-{i}"),
                vec![
                    "ring-node".into(),
                    "--rendezvous".into(),
                    rv_addr.clone(),
                    "--elems".into(),
                    elems.to_string(),
                    "--overlap".into(),
                    overlap.to_string(),
                ],
            ))
        })
        .collect::<Result<Vec<_>>>()?;
    for h in handles {
        match h.wait() {
            JobStatus::Succeeded => {}
            other => anyhow::bail!("ring-node child ended {other:?}"),
        }
    }
    println!("all {world} ring-node processes verified sum {}", expected_sum(world));
    Ok(())
}

/// `fiber-cli ring-node --rendezvous tcp://… [--elems N] [--bind ip:port]`
/// — one OS-process ring member: rendezvous, allreduce, verify, exit.
/// `--bind` must name a peer-reachable interface on multi-host rings
/// (default loopback serves the single-host proc backend).
pub fn ring_node(opts: &Opts) -> Result<()> {
    let rv_addr = Addr::parse(opts.require("rendezvous")?)?;
    let elems: usize = opts.parse_or("elems", 1 << 16)?;
    let overlap: bool = opts.parse_or("overlap", true)?;
    let bind = opts.get_or("bind", "127.0.0.1:0");
    let mut m = RingMember::join_addr_bind(&rv_addr, bind).context("join ring")?;
    m.set_overlap(overlap);
    let mut buf = member_buf(m.rank(), elems);
    m.allreduce_sum(&mut buf)?;
    verify(&buf, m.world())?;
    println!(
        "ring-node rank {}/{} ok: {} B sent, {} B received",
        m.rank(),
        m.world(),
        m.bytes_sent(),
        m.bytes_received()
    );
    Ok(())
}
