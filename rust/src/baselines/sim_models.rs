//! Virtual-time framework models for the scaling figures.
//!
//! The paper's Fig 3b (ES, 32–1024 workers) and Fig 3c (PPO, 8–256 workers)
//! need three orders of magnitude more parallelism than this testbed's one
//! core. Per DESIGN.md §2 the scaling curves are produced by discrete-event
//! simulation of each framework's *dispatch protocol*, with cost parameters
//! **calibrated from real measurements** of the executors in this crate
//! (per-task pool overhead, hub per-message service time) and task
//! durations sampled from real environment rollouts. The virtualization
//! changes the clock, not the queueing structure: completion time =
//! dispatch serialization + central-server queueing + parallel service +
//! collection, which is exactly what the figures show.

use crate::cluster::des::EventQueue;
use crate::util::Rng;

/// Cost parameters of one framework's map/dispatch protocol, all in ns.
#[derive(Clone, Debug)]
pub struct FrameworkModel {
    pub name: &'static str,
    /// Client/master cost to serialize + enqueue one chunk.
    pub dispatch_ns: u64,
    /// Central-hub service time per message (0 = direct worker channels).
    /// Every chunk crosses the hub twice (dispatch + result).
    pub hub_service_ns: u64,
    /// Hub bookkeeping per connected worker per batch (connection polling,
    /// heartbeats). Grows the hub's fixed cost with worker count — the
    /// reason IPyParallel *degrades* past 256 workers in Fig 3b.
    pub hub_per_worker_ns: u64,
    /// Worker-side overhead per chunk (deserialize, context).
    pub worker_overhead_ns: u64,
    /// Hard failure above this many workers (None = no limit).
    pub worker_limit: Option<usize>,
    /// Items per dispatch chunk: `(items, workers) -> chunksize`.
    pub chunksize: fn(usize, usize) -> usize,
}

fn mp_chunks(items: usize, workers: usize) -> usize {
    items.div_ceil(4 * workers.max(1)).max(1)
}

fn no_chunks(_items: usize, _workers: usize) -> usize {
    1
}

impl FrameworkModel {
    /// Fiber: direct leader→worker dispatch, µs-scale per-chunk cost.
    /// `dispatch_ns` should be overridden with the measured value from the
    /// micro bench (see EXPERIMENTS.md §calibration). Per-task dispatch
    /// (no batching): the ES workload's rollouts are 100 ms-scale, where
    /// batching only hurts load balance; Fiber enables batching for the
    /// ms-scale regime of Fig 3a instead (see [`FrameworkModel::fiber_batched`]).
    pub fn fiber() -> Self {
        Self {
            name: "fiber",
            dispatch_ns: 15_000,
            hub_service_ns: 0,
            hub_per_worker_ns: 0,
            worker_overhead_ns: 5_000,
            worker_limit: None,
            chunksize: no_chunks,
        }
    }

    /// Fiber with multiprocessing-style chunking (the Fig 3a configuration).
    pub fn fiber_batched() -> Self {
        Self {
            chunksize: mp_chunks,
            ..Self::fiber()
        }
    }

    /// IPyParallel: central hub, no chunking, per-worker hub bookkeeping,
    /// connection collapse at high engine counts. Hub service calibrated
    /// to its ~1.2 ms/task measured overhead (2 hops/task).
    pub fn ipyparallel() -> Self {
        Self {
            name: "ipyparallel",
            dispatch_ns: 60_000,
            hub_service_ns: 600_000,
            // Connection management (heartbeats, per-engine scheduler state)
            // per engine per batch. Fitted to the paper's observed Fig 3b
            // degradation between 256 and 512 engines (~ms-scale per engine
            // per iteration), since we cannot measure a real 512-engine hub
            // on this testbed — documented in EXPERIMENTS.md §E2.
            hub_per_worker_ns: 8_000_000,
            worker_overhead_ns: 30_000,
            worker_limit: Some(768),
            chunksize: no_chunks,
        }
    }

    /// Spark: sequential driver dispatch with ms-scale per-task cost
    /// (calibrated to its ~2.6 ms/task measured overhead).
    pub fn spark() -> Self {
        Self {
            name: "spark",
            dispatch_ns: 2_400_000,
            hub_service_ns: 0,
            hub_per_worker_ns: 0,
            worker_overhead_ns: 200_000,
            worker_limit: None,
            chunksize: no_chunks,
        }
    }

    /// multiprocessing: near-zero overhead, but hard-capped at one machine.
    pub fn multiprocessing(machine_cores: usize) -> Self {
        let cores = machine_cores;
        Self {
            name: "multiprocessing",
            dispatch_ns: 3_000,
            hub_service_ns: 0,
            hub_per_worker_ns: 0,
            worker_overhead_ns: 1_000,
            worker_limit: Some(cores),
            chunksize: mp_chunks,
        }
    }
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Ev {
    /// Master finishes serializing chunk i → enters hub (or worker queue).
    Dispatched(usize),
    /// Hub finishes forwarding chunk i to a worker.
    HubForwarded(usize),
    /// Worker w finishes chunk i.
    WorkerDone { chunk: usize, worker: usize },
    /// Hub finishes forwarding result i back to the master.
    ResultDelivered(usize),
}

/// Simulate one `map` of `durations_ns` task durations over `workers`
/// workers under `model`. Returns completion time in ns, or `None` if the
/// framework fails at this worker count.
pub fn simulate_map(
    model: &FrameworkModel,
    durations_ns: &[u64],
    workers: usize,
) -> Option<u64> {
    if let Some(limit) = model.worker_limit {
        if workers > limit {
            return None;
        }
    }
    let items = durations_ns.len();
    if items == 0 {
        return Some(0);
    }
    let cs = (model.chunksize)(items, workers);
    // Chunk i covers items [i*cs, min((i+1)*cs, items)).
    let n_chunks = items.div_ceil(cs);
    let chunk_work: Vec<u64> = (0..n_chunks)
        .map(|i| {
            durations_ns[i * cs..((i + 1) * cs).min(items)]
                .iter()
                .sum::<u64>()
                + model.worker_overhead_ns
        })
        .collect();

    let mut q: EventQueue<Ev> = EventQueue::new();
    // Master serializes dispatches sequentially.
    for (i, _) in chunk_work.iter().enumerate() {
        q.push_at((i as u64 + 1) * model.dispatch_ns, Ev::Dispatched(i));
    }
    // Hub: single FIFO server; per-batch fixed cost charged upfront.
    let hub = model.hub_service_ns > 0;
    let mut hub_free_at: u64 = if hub {
        model.hub_per_worker_ns * workers as u64
    } else {
        0
    };
    // Worker pool.
    let mut idle: Vec<usize> = (0..workers).collect();
    let mut ready: std::collections::VecDeque<usize> = Default::default();
    let mut done = 0usize;
    let mut finish = 0u64;

    while let Some((t, ev)) = q.pop() {
        match ev {
            Ev::Dispatched(i) => {
                if hub {
                    hub_free_at = hub_free_at.max(t) + model.hub_service_ns;
                    q.push_at(hub_free_at, Ev::HubForwarded(i));
                } else {
                    q.push_at(t, Ev::HubForwarded(i));
                }
            }
            Ev::HubForwarded(i) => {
                if let Some(w) = idle.pop() {
                    q.push_at(t + chunk_work[i], Ev::WorkerDone { chunk: i, worker: w });
                } else {
                    ready.push_back(i);
                }
            }
            Ev::WorkerDone { chunk, worker } => {
                if let Some(next) = ready.pop_front() {
                    q.push_at(t + chunk_work[next], Ev::WorkerDone { chunk: next, worker });
                } else {
                    idle.push(worker);
                }
                if hub {
                    hub_free_at = hub_free_at.max(t) + model.hub_service_ns;
                    q.push_at(hub_free_at, Ev::ResultDelivered(chunk));
                } else {
                    q.push_at(t, Ev::ResultDelivered(chunk));
                }
            }
            Ev::ResultDelivered(_) => {
                done += 1;
                finish = finish.max(t);
                if done == n_chunks {
                    return Some(finish);
                }
            }
        }
    }
    Some(finish)
}

/// Sample `n` task durations (ns) from a lognormal-ish rollout distribution
/// with the given mean and coefficient of variation — rollout lengths in RL
/// are heavy-tailed ("different simulation rollouts can take significantly
/// different lengths of time").
pub fn sample_durations(rng: &mut Rng, n: usize, mean_ns: f64, cv: f64) -> Vec<u64> {
    // Lognormal with E[X]=mean: sigma² = ln(1+cv²), mu = ln(mean) - sigma²/2.
    let sigma2 = (1.0 + cv * cv).ln();
    let mu = mean_ns.ln() - sigma2 / 2.0;
    let sigma = sigma2.sqrt();
    (0..n)
        .map(|_| (mu + sigma * rng.normal()).exp().max(1.0) as u64)
        .collect()
}

/// PPO iteration model for Fig 3c: one synchronous rollout phase of
/// `steps_per_iter` vectorized environment steps across `workers` envs,
/// followed by a fixed model step (GPU learner — does not parallelize; the
/// paper notes the resulting sub-linear speedup).
#[derive(Clone, Debug)]
pub struct PpoModel {
    pub name: &'static str,
    /// Per environment-step simulation cost, ns.
    pub env_step_ns: u64,
    /// Per-step per-worker communication cost paid by the leader
    /// (action scatter + observation gather), ns.
    pub sync_per_worker_ns: u64,
    /// Fixed learner (model fwd/bwd/update) cost per iteration, ns.
    pub model_step_ns: u64,
    /// Hard worker cap (multiprocessing: one machine).
    pub worker_limit: Option<usize>,
}

impl PpoModel {
    /// Total time to consume `total_frames` with `workers` env workers and
    /// `horizon` steps per iteration per worker. `None` past worker_limit.
    pub fn total_time_ns(&self, total_frames: u64, horizon: u64, workers: usize) -> Option<u64> {
        if let Some(limit) = self.worker_limit {
            if workers > limit {
                return None;
            }
        }
        let frames_per_iter = horizon * workers as u64;
        let iters = total_frames.div_ceil(frames_per_iter);
        // Env phase: each of `horizon` synchronous vector steps costs the
        // slowest env (≈ env_step) plus leader-side gather/scatter that is
        // linear in workers.
        let env_phase = horizon * (self.env_step_ns + self.sync_per_worker_ns * workers as u64);
        Some(iters * (env_phase + self.model_step_ns))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn flat(n: usize, d: u64) -> Vec<u64> {
        vec![d; n]
    }

    #[test]
    fn perfect_scaling_without_overhead() {
        let mut m = FrameworkModel::fiber();
        m.dispatch_ns = 1; // negligible
        m.worker_overhead_ns = 0;
        m.chunksize = no_chunks;
        let d = flat(64, 1_000_000);
        let t16 = simulate_map(&m, &d, 16).unwrap();
        let t64 = simulate_map(&m, &d, 64).unwrap();
        assert!(t16 >= 4_000_000 && t16 < 4_200_000, "{t16}");
        assert!(t64 >= 1_000_000 && t64 < 1_200_000, "{t64}");
    }

    #[test]
    fn hub_saturation_floors_completion_time() {
        let m = FrameworkModel::ipyparallel();
        // 1000 tiny tasks: hub handles 2000 messages ≥ 2000×120 µs = 240 ms
        // regardless of worker count.
        let d = flat(1000, 1_000); // 1 µs of work each
        let t = simulate_map(&m, &d, 512).unwrap();
        assert!(t >= 240_000_000, "hub must floor the time: {t}");
    }

    #[test]
    fn ipp_degrades_with_more_workers_on_fixed_work() {
        let m = FrameworkModel::ipyparallel();
        let mut rng = Rng::new(7);
        let d = sample_durations(&mut rng, 2048, 30_000_000.0, 0.5);
        let t256 = simulate_map(&m, &d, 256).unwrap();
        let t512 = simulate_map(&m, &d, 512).unwrap();
        assert!(
            t512 > t256,
            "per-worker hub cost should degrade ipp past 256: {t256} vs {t512}"
        );
        assert!(simulate_map(&m, &d, 1024).is_none(), "ipp fails at 1024");
    }

    #[test]
    fn fiber_keeps_improving_to_1024() {
        let m = FrameworkModel::fiber();
        let mut rng = Rng::new(7);
        let d = sample_durations(&mut rng, 2048, 30_000_000.0, 0.5);
        let mut prev = u64::MAX;
        for w in [32, 64, 128, 256, 512, 1024] {
            let t = simulate_map(&m, &d, w).unwrap();
            assert!(t < prev, "fiber should monotonically improve at {w}: {t} !< {prev}");
            prev = t;
        }
    }

    #[test]
    fn mp_capped_at_machine() {
        let m = FrameworkModel::multiprocessing(32);
        let d = flat(64, 1_000_000);
        assert!(simulate_map(&m, &d, 32).is_some());
        assert!(simulate_map(&m, &d, 64).is_none());
    }

    #[test]
    fn durations_have_requested_mean() {
        let mut rng = Rng::new(3);
        let d = sample_durations(&mut rng, 20_000, 5_000_000.0, 0.8);
        let mean = d.iter().sum::<u64>() as f64 / d.len() as f64;
        assert!((mean - 5_000_000.0).abs() / 5_000_000.0 < 0.05, "mean {mean}");
    }

    #[test]
    fn ppo_model_scales_sublinearly() {
        let m = PpoModel {
            name: "fiber",
            env_step_ns: 50_000,
            sync_per_worker_ns: 400,
            model_step_ns: 30_000_000,
            worker_limit: None,
        };
        let t8 = m.total_time_ns(1_000_000, 128, 8).unwrap();
        let t64 = m.total_time_ns(1_000_000, 128, 64).unwrap();
        let t256 = m.total_time_ns(1_000_000, 128, 256).unwrap();
        assert!(t64 < t8, "more workers help");
        assert!(t256 < t8 / 2, "paper: 256 workers less than half of 8-worker time");
        let speedup = t8 as f64 / t256 as f64;
        assert!(speedup < 32.0, "sub-linear: model step doesn't parallelize");
    }

    #[test]
    fn ppo_mp_capped() {
        let m = PpoModel {
            name: "multiprocessing",
            env_step_ns: 50_000,
            sync_per_worker_ns: 300,
            model_step_ns: 30_000_000,
            worker_limit: Some(32),
        };
        assert!(m.total_time_ns(1_000_000, 128, 32).is_some());
        assert!(m.total_time_ns(1_000_000, 128, 64).is_none());
    }
}
