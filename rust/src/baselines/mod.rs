//! Comparator executors for the paper's experiments.
//!
//! Fig 3a compares Fiber against Python multiprocessing, IPyParallel and
//! Spark. Running the originals here would measure JVM-vs-Rust, not
//! architecture, so each comparator is re-implemented *architecturally*
//! (DESIGN.md §2): the multiprocessing-like pool is local-only with
//! per-worker channels and upfront chunking; the IPyParallel-like executor
//! routes **every** message through a central hub with per-message
//! bookkeeping; the Spark-like executor has a driver that schedules tasks
//! one at a time with a per-task dispatch cost. Per-message "interpreter
//! tax" constants calibrate each architecture to its published overhead
//! scale and are documented in EXPERIMENTS.md.
//!
//! [`sim_models`] contains the virtual-time counterparts used for the
//! 32–1024-worker scaling figures on this 1-core testbed.

pub mod exec;
pub mod ipp_like;
pub mod sim_models;
pub mod spark_like;

pub use exec::{busy_wait, Executor, FiberExec, MpLike};
pub use ipp_like::IppLike;
pub use spark_like::SparkLike;
