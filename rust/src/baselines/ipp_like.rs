//! IPyParallel-like executor: a central hub every message crosses twice.
//!
//! IPyParallel's architecture routes client→engine traffic through a hub
//! (scheduler + Mongo-style task DB): the client submits to the hub, the
//! hub records the task and forwards it to an engine, the engine replies to
//! the hub, the hub records completion and forwards the result to the
//! client. Four message hops and two DB updates per task, all through one
//! process — which is both the overhead (Fig 3a) and the scaling bottleneck
//! (Fig 3b) the paper measures. We reproduce that topology with real
//! channels, real per-message bookkeeping (task-table inserts/updates,
//! header encode/decode, payload copies) plus a calibrated per-hop
//! interpreter tax; and a connection limit past which the hub fails, which
//! is IPyParallel's observed 1024-engine collapse.

use std::collections::HashMap;
use std::time::Duration;

use anyhow::Result;

use crate::comms::chan;
use crate::coordinator::task::execute_registered;
use crate::wire::{self, Encode};

use super::exec::{busy_wait, Executor};

/// Per-hop interpreter tax. IPyParallel's hub is a Python/ZMQ event loop
/// with a task DB; its end-to-end per-task overhead is ~1.2–1.5 ms (the
/// paper measures ≈ 8× a 1 ms task's ideal time at 5 000 tasks, i.e.
/// ≈ 1.4 ms of overhead per task). Each task crosses the hub twice
/// (dispatch + result), so the per-hop tax is half that.
pub const HUB_TAX_PER_MSG: Duration = Duration::from_micros(600);

/// Engines the hub can sustain before connection handling fails (the paper
/// observed IPyParallel dying at 1024 workers).
pub const DEFAULT_ENGINE_LIMIT: usize = 768;

struct HubTaskRecord {
    #[allow(dead_code)]
    header: Vec<u8>,
    state: u8, // 0 = dispatched, 1 = done
}

enum HubMsg {
    Submit {
        task_id: u64,
        fn_name: String,
        payload: Vec<u8>,
    },
    EngineReply {
        task_id: u64,
        result: Result<Vec<u8>, String>,
    },
    Shutdown,
}

/// The IPyParallel-like executor.
pub struct IppLike {
    hub_tx: chan::Sender<HubMsg>,
    client_rx: chan::Receiver<(u64, Result<Vec<u8>, String>)>,
    n: usize,
    engine_limit: usize,
    next_task: std::sync::atomic::AtomicU64,
}

impl IppLike {
    pub fn new(engines: usize) -> Self {
        Self::with_limit(engines, DEFAULT_ENGINE_LIMIT)
    }

    pub fn with_limit(engines: usize, engine_limit: usize) -> Self {
        let engines = engines.max(1);
        let (hub_tx, hub_rx) = chan::unbounded::<HubMsg>();
        let (client_tx, client_rx) = chan::unbounded();
        // Engine channels: hub round-robins dispatches.
        let mut engine_txs = Vec::with_capacity(engines);
        for e in 0..engines {
            let (etx, erx) = chan::unbounded::<(u64, String, Vec<u8>)>();
            let hub_tx_back = hub_tx.clone();
            std::thread::Builder::new()
                .name(format!("ipp-engine-{e}"))
                .spawn(move || {
                    while let Ok((task_id, fn_name, payload)) = erx.recv() {
                        let result = execute_registered(&fn_name, &payload);
                        if hub_tx_back
                            .send(HubMsg::EngineReply { task_id, result })
                            .is_err()
                        {
                            return;
                        }
                    }
                })
                .expect("spawn ipp engine");
            engine_txs.push(etx);
        }
        // The hub thread: single point every message crosses.
        std::thread::Builder::new()
            .name("ipp-hub".into())
            .spawn(move || {
                let mut db: HashMap<u64, HubTaskRecord> = HashMap::new();
                let mut rr = 0usize;
                while let Ok(msg) = hub_rx.recv() {
                    match msg {
                        HubMsg::Submit {
                            task_id,
                            fn_name,
                            payload,
                        } => {
                            busy_wait(HUB_TAX_PER_MSG);
                            // Hub bookkeeping: build + store a header record
                            // (the task DB insert) and copy the payload on
                            // the way through (ZMQ re-frame).
                            let header =
                                wire::to_bytes(&(task_id, fn_name.clone(), payload.len() as u64));
                            db.insert(task_id, HubTaskRecord { header, state: 0 });
                            let payload_copy = payload.clone();
                            let e = rr % engine_txs.len();
                            rr += 1;
                            let _ = engine_txs[e].send((task_id, fn_name, payload_copy));
                        }
                        HubMsg::EngineReply { task_id, result } => {
                            busy_wait(HUB_TAX_PER_MSG);
                            if let Some(rec) = db.get_mut(&task_id) {
                                rec.state = 1;
                            }
                            // Copy on the way out, as the hub re-frames.
                            let result = match result {
                                Ok(b) => Ok(b.clone()),
                                Err(e) => Err(e),
                            };
                            let _ = client_tx.send((task_id, result));
                        }
                        HubMsg::Shutdown => {
                            for etx in &engine_txs {
                                etx.close();
                            }
                            client_tx.close();
                            return;
                        }
                    }
                }
            })
            .expect("spawn ipp hub");
        Self {
            hub_tx,
            client_rx,
            n: engines,
            engine_limit,
            next_task: std::sync::atomic::AtomicU64::new(1),
        }
    }
}

impl Executor for IppLike {
    fn name(&self) -> &'static str {
        "ipyparallel"
    }

    fn run_batch(&self, fn_name: &str, items: Vec<Vec<u8>>) -> Result<Vec<Vec<u8>>> {
        anyhow::ensure!(
            self.n <= self.engine_limit,
            "ipyparallel hub failed: {} engines exceed the connection limit {} \
             (communication errors between processes)",
            self.n,
            self.engine_limit
        );
        let n_items = items.len();
        let mut id_to_idx = HashMap::with_capacity(n_items);
        for (i, payload) in items.into_iter().enumerate() {
            let task_id = self
                .next_task
                .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            id_to_idx.insert(task_id, i);
            // Client-side serialization: ipp pickles per task (no chunking).
            let mut framed = Vec::with_capacity(payload.len() + 16);
            (task_id, fn_name).encode(&mut framed);
            self.hub_tx
                .send(HubMsg::Submit {
                    task_id,
                    fn_name: fn_name.to_string(),
                    payload,
                })
                .map_err(|_| anyhow::anyhow!("hub down"))?;
        }
        let mut out: Vec<Option<Vec<u8>>> = (0..n_items).map(|_| None).collect();
        for _ in 0..n_items {
            let (task_id, result) = self
                .client_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("hub down"))?;
            let idx = *id_to_idx
                .get(&task_id)
                .ok_or_else(|| anyhow::anyhow!("unknown task id"))?;
            out[idx] = Some(result.map_err(|e| anyhow::anyhow!("task failed: {e}"))?);
        }
        out.into_iter()
            .map(|o| o.ok_or_else(|| anyhow::anyhow!("missing result")))
            .collect()
    }

    fn workers(&self) -> usize {
        self.n
    }
}

impl Drop for IppLike {
    fn drop(&mut self) {
        let _ = self.hub_tx.send(HubMsg::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::exec::register_bench_tasks;
    use crate::wire;

    fn items(n: u64) -> Vec<Vec<u8>> {
        (0..n).map(|i| wire::to_bytes(&i)).collect()
    }

    #[test]
    fn returns_ordered_results() {
        register_bench_tasks();
        let ex = IppLike::new(3);
        let out = ex.run_batch("bench.echo", items(50)).unwrap();
        let vals: Vec<u64> = out.iter().map(|b| wire::from_bytes(b).unwrap()).collect();
        assert_eq!(vals, (0..50).collect::<Vec<u64>>());
    }

    #[test]
    fn engine_limit_fails_like_the_paper() {
        register_bench_tasks();
        let ex = IppLike::with_limit(8, 4);
        let err = ex.run_batch("bench.echo", items(4)).unwrap_err();
        assert!(err.to_string().contains("connection limit"), "{err}");
    }

    #[test]
    fn hub_adds_measurable_overhead_vs_mp() {
        use super::super::exec::MpLike;
        register_bench_tasks();
        // 200 near-zero tasks: hub tax (2 hops × 120µs) should dominate.
        let ipp = IppLike::new(2);
        let mp = MpLike::new(2);
        let t0 = std::time::Instant::now();
        ipp.run_batch("bench.echo", items(200)).unwrap();
        let t_ipp = t0.elapsed();
        let t0 = std::time::Instant::now();
        mp.run_batch("bench.echo", items(200)).unwrap();
        let t_mp = t0.elapsed();
        assert!(
            t_ipp > t_mp * 2,
            "hub should be ≥2× slower on tiny tasks: ipp={t_ipp:?} mp={t_mp:?}"
        );
    }
}
