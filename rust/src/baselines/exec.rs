//! The executor contract + the two cheap architectures (fiber & mp-like).

use std::time::{Duration, Instant};

use anyhow::Result;

use crate::api::pool::Pool;
use crate::comms::chan;
use crate::coordinator::task::execute_registered;

/// Common interface the Fig 3a harness drives.
pub trait Executor: Send + Sync {
    fn name(&self) -> &'static str;
    /// Execute every item with the registered function `fn_name`, returning
    /// outputs in input order.
    fn run_batch(&self, fn_name: &str, items: Vec<Vec<u8>>) -> Result<Vec<Vec<u8>>>;
    /// Worker count (for reporting).
    fn workers(&self) -> usize;
}

/// Busy-wait for `dur` (models interpreter/JVM per-message cost without
/// yielding the core the way `sleep` would).
pub fn busy_wait(dur: Duration) {
    let t0 = Instant::now();
    while t0.elapsed() < dur {
        std::hint::spin_loop();
    }
}

/// Fiber itself, adapted to the harness. Uses `map_chunked` with
/// multiprocessing-compatible default chunking so the Fig 3a comparison is
/// batching-fair.
pub struct FiberExec {
    pool: Pool,
    n: usize,
}

impl FiberExec {
    pub fn new(workers: usize) -> Result<Self> {
        Ok(Self {
            pool: Pool::new(workers)?,
            n: workers,
        })
    }

    /// multiprocessing's default chunksize: `ceil(len / (4 * workers))`.
    pub fn default_chunksize(len: usize, workers: usize) -> usize {
        len.div_ceil(4 * workers.max(1)).max(1)
    }
}

impl Executor for FiberExec {
    fn name(&self) -> &'static str {
        "fiber"
    }

    fn run_batch(&self, fn_name: &str, items: Vec<Vec<u8>>) -> Result<Vec<Vec<u8>>> {
        let cs = Self::default_chunksize(items.len(), self.n);
        self.pool.map_raw_chunked(fn_name, items, cs)
    }

    fn workers(&self) -> usize {
        self.n
    }
}

/// Python-multiprocessing-like pool: strictly local, one dedicated channel
/// per worker, all chunks dealt out **upfront** (mp's `map` semantics), no
/// pending table, no failure handling, no remote capability. This is the
/// lower-bound reference in Fig 3a.
pub struct MpLike {
    task_txs: Vec<chan::Sender<(u64, String, Vec<Vec<u8>>)>>,
    results_rx: chan::Receiver<(u64, Result<Vec<Vec<u8>>, String>)>,
    n: usize,
    rr: std::sync::atomic::AtomicUsize,
}

impl MpLike {
    pub fn new(workers: usize) -> Self {
        let workers = workers.max(1);
        let (results_tx, results_rx) = chan::unbounded();
        let mut task_txs = Vec::with_capacity(workers);
        for w in 0..workers {
            let (tx, rx) = chan::unbounded::<(u64, String, Vec<Vec<u8>>)>();
            let results_tx = results_tx.clone();
            std::thread::Builder::new()
                .name(format!("mp-worker-{w}"))
                .spawn(move || {
                    while let Ok((chunk_id, fn_name, chunk)) = rx.recv() {
                        let mut outs = Vec::with_capacity(chunk.len());
                        let mut err = None;
                        for item in &chunk {
                            match execute_registered(&fn_name, item) {
                                Ok(o) => outs.push(o),
                                Err(e) => {
                                    err = Some(e);
                                    break;
                                }
                            }
                        }
                        let msg = match err {
                            None => (chunk_id, Ok(outs)),
                            Some(e) => (chunk_id, Err(e)),
                        };
                        if results_tx.send(msg).is_err() {
                            return;
                        }
                    }
                })
                .expect("spawn mp worker");
            task_txs.push(tx);
        }
        Self {
            task_txs,
            results_rx,
            n: workers,
            rr: std::sync::atomic::AtomicUsize::new(0),
        }
    }
}

impl Executor for MpLike {
    fn name(&self) -> &'static str {
        "multiprocessing"
    }

    fn run_batch(&self, fn_name: &str, items: Vec<Vec<u8>>) -> Result<Vec<Vec<u8>>> {
        let n_items = items.len();
        if n_items == 0 {
            return Ok(vec![]);
        }
        let cs = FiberExec::default_chunksize(n_items, self.n);
        // Deal chunks round-robin upfront, like mp.Pool._map_async.
        let mut chunk_sizes = Vec::new();
        let mut iter = items.into_iter().peekable();
        let mut chunk_id = 0u64;
        while iter.peek().is_some() {
            let chunk: Vec<Vec<u8>> = iter.by_ref().take(cs).collect();
            chunk_sizes.push(chunk.len());
            let w = self.rr.fetch_add(1, std::sync::atomic::Ordering::Relaxed) % self.n;
            self.task_txs[w]
                .send((chunk_id, fn_name.to_string(), chunk))
                .map_err(|_| anyhow::anyhow!("mp pool closed"))?;
            chunk_id += 1;
        }
        let starts: Vec<usize> = chunk_sizes
            .iter()
            .scan(0usize, |acc, &k| {
                let s = *acc;
                *acc += k;
                Some(s)
            })
            .collect();
        let mut out: Vec<Option<Vec<u8>>> = (0..n_items).map(|_| None).collect();
        for _ in 0..chunk_sizes.len() {
            let (cid, res) = self
                .results_rx
                .recv()
                .map_err(|_| anyhow::anyhow!("mp pool closed"))?;
            let outs = res.map_err(|e| anyhow::anyhow!("task failed: {e}"))?;
            let start = starts[cid as usize];
            for (k, o) in outs.into_iter().enumerate() {
                out[start + k] = Some(o);
            }
        }
        out.into_iter()
            .map(|o| o.ok_or_else(|| anyhow::anyhow!("missing result")))
            .collect()
    }

    fn workers(&self) -> usize {
        self.n
    }
}

impl Drop for MpLike {
    fn drop(&mut self) {
        for tx in &self.task_txs {
            tx.close();
        }
    }
}

/// Register the benchmark task functions (sleep + echo + walker rollout).
/// Idempotent; called by benches, tests and `fiber-cli worker`.
pub fn register_bench_tasks() {
    use crate::coordinator::task::register_task;
    register_task("bench.sleep_us", |us: u64| {
        std::thread::sleep(Duration::from_micros(us));
        Ok::<u64, String>(us)
    });
    register_task("bench.echo", |x: u64| Ok::<u64, String>(x));
    register_task("bench.walker_rollout", |(seed, max_steps): (u64, u64)| {
        use crate::envs::{rollout, Action, Walker2d};
        let mut env = Walker2d::hardcore(seed);
        let mut s = seed;
        let (reward, steps) = rollout(&mut env, seed, max_steps as usize, |_| {
            // xorshift-cheap random policy: the bench measures dispatch, not
            // learning.
            s ^= s << 13;
            s ^= s >> 7;
            s ^= s << 17;
            Action::Continuous(vec![
                (s & 0xff) as f32 / 127.5 - 1.0,
                ((s >> 8) & 0xff) as f32 / 127.5 - 1.0,
                ((s >> 16) & 0xff) as f32 / 127.5 - 1.0,
                ((s >> 24) & 0xff) as f32 / 127.5 - 1.0,
            ])
        });
        Ok::<(f32, u64), String>((reward, steps as u64))
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::wire;

    fn items(n: u64) -> Vec<Vec<u8>> {
        (0..n).map(|i| wire::to_bytes(&i)).collect()
    }

    #[test]
    fn mp_like_returns_ordered() {
        register_bench_tasks();
        let ex = MpLike::new(4);
        let out = ex.run_batch("bench.echo", items(100)).unwrap();
        let vals: Vec<u64> = out.iter().map(|b| wire::from_bytes(b).unwrap()).collect();
        assert_eq!(vals, (0..100).collect::<Vec<u64>>());
    }

    #[test]
    fn fiber_exec_matches_mp_like() {
        register_bench_tasks();
        let f = FiberExec::new(4).unwrap();
        let m = MpLike::new(4);
        let a = f.run_batch("bench.echo", items(53)).unwrap();
        let b = m.run_batch("bench.echo", items(53)).unwrap();
        assert_eq!(a, b);
    }

    #[test]
    fn empty_batch() {
        register_bench_tasks();
        let ex = MpLike::new(2);
        assert!(ex.run_batch("bench.echo", vec![]).unwrap().is_empty());
    }

    #[test]
    fn default_chunksize_matches_python() {
        // divmod semantics of CPython's Pool.map default.
        assert_eq!(FiberExec::default_chunksize(5000, 5), 250);
        assert_eq!(FiberExec::default_chunksize(10, 5), 1);
        assert_eq!(FiberExec::default_chunksize(0, 5), 1);
    }

    #[test]
    fn busy_wait_duration() {
        let t0 = Instant::now();
        busy_wait(Duration::from_micros(500));
        assert!(t0.elapsed() >= Duration::from_micros(450));
    }
}
