//! Spark-like executor: stage-oriented driver with per-task scheduling cost.
//!
//! Spark's driver turns a job into a stage of tasks and schedules them one
//! at a time (DAGScheduler → TaskScheduler → RPC to an executor), paying
//! task serialization + dispatch bookkeeping per task — published overhead
//! is on the order of milliseconds per task, which is why Spark loses
//! badly at 1 ms task durations in Fig 3a. We reproduce the topology: a
//! driver thread owns scheduling; executors request work via a "resource
//! offer" loop; each dispatch pays a serialization copy + calibrated
//! driver tax.

use std::collections::HashMap;
use std::time::Duration;

use anyhow::Result;

use crate::comms::chan;
use crate::coordinator::task::execute_registered;

use super::exec::{busy_wait, Executor};

/// Driver-side cost per task dispatch (task serialization, DAG/TaskScheduler
/// bookkeeping, RPC framing). Spark's documented scheduling overhead is
/// ~1–10 ms/task; the paper measures ≈ 14× a 1 ms task's ideal time at
/// 5 000 tasks, i.e. ≈ 2.6 ms of overhead per task (driver + executor).
pub const DRIVER_TAX_PER_TASK: Duration = Duration::from_micros(2_400);

/// Executor-side cost per task (deserialization + context setup).
pub const EXECUTOR_TAX_PER_TASK: Duration = Duration::from_micros(200);

enum DriverMsg {
    RunStage {
        fn_name: String,
        items: Vec<Vec<u8>>,
        reply: chan::Sender<Result<Vec<Vec<u8>>, String>>,
    },
    Shutdown,
}

/// The Spark-like executor.
pub struct SparkLike {
    driver_tx: chan::Sender<DriverMsg>,
    n: usize,
}

impl SparkLike {
    pub fn new(executors: usize) -> Self {
        let executors = executors.max(1);
        let (driver_tx, driver_rx) = chan::unbounded::<DriverMsg>();
        // Executor worker threads: pull (task_id, fn, payload), reply.
        let (task_tx, task_rx) = chan::unbounded::<(u64, String, Vec<u8>)>();
        let (done_tx, done_rx) = chan::unbounded::<(u64, Result<Vec<u8>, String>)>();
        for e in 0..executors {
            let task_rx = task_rx.clone();
            let done_tx = done_tx.clone();
            std::thread::Builder::new()
                .name(format!("spark-exec-{e}"))
                .spawn(move || {
                    while let Ok((task_id, fn_name, payload)) = task_rx.recv() {
                        busy_wait(EXECUTOR_TAX_PER_TASK);
                        let result = execute_registered(&fn_name, &payload);
                        if done_tx.send((task_id, result)).is_err() {
                            return;
                        }
                    }
                })
                .expect("spawn spark executor");
        }
        // Driver thread: owns stage execution.
        std::thread::Builder::new()
            .name("spark-driver".into())
            .spawn(move || {
                while let Ok(msg) = driver_rx.recv() {
                    match msg {
                        DriverMsg::RunStage {
                            fn_name,
                            items,
                            reply,
                        } => {
                            let n = items.len();
                            let mut idx_of: HashMap<u64, usize> = HashMap::with_capacity(n);
                            // Sequential dispatch: the driver serializes each
                            // task closure before it can launch (the Spark
                            // bottleneck at small task durations).
                            for (i, payload) in items.into_iter().enumerate() {
                                busy_wait(DRIVER_TAX_PER_TASK);
                                let serialized = payload.clone(); // closure ser.
                                let task_id = i as u64;
                                idx_of.insert(task_id, i);
                                if task_tx.send((task_id, fn_name.clone(), serialized)).is_err()
                                {
                                    let _ = reply.send(Err("executors down".into()));
                                    return;
                                }
                            }
                            let mut out: Vec<Option<Vec<u8>>> = (0..n).map(|_| None).collect();
                            let mut err: Option<String> = None;
                            for _ in 0..n {
                                match done_rx.recv() {
                                    Ok((task_id, Ok(bytes))) => {
                                        out[idx_of[&task_id]] = Some(bytes);
                                    }
                                    Ok((_, Err(e))) => {
                                        err.get_or_insert(e);
                                    }
                                    Err(_) => {
                                        err.get_or_insert("executors down".into());
                                        break;
                                    }
                                }
                            }
                            let result = match err {
                                Some(e) => Err(e),
                                None => out
                                    .into_iter()
                                    .map(|o| o.ok_or_else(|| "missing result".to_string()))
                                    .collect(),
                            };
                            let _ = reply.send(result);
                        }
                        DriverMsg::Shutdown => {
                            task_tx.close();
                            return;
                        }
                    }
                }
            })
            .expect("spawn spark driver");
        Self {
            driver_tx,
            n: executors,
        }
    }
}

impl Executor for SparkLike {
    fn name(&self) -> &'static str {
        "spark"
    }

    fn run_batch(&self, fn_name: &str, items: Vec<Vec<u8>>) -> Result<Vec<Vec<u8>>> {
        let (reply_tx, reply_rx) = chan::unbounded();
        self.driver_tx
            .send(DriverMsg::RunStage {
                fn_name: fn_name.to_string(),
                items,
                reply: reply_tx,
            })
            .map_err(|_| anyhow::anyhow!("driver down"))?;
        reply_rx
            .recv()
            .map_err(|_| anyhow::anyhow!("driver down"))?
            .map_err(|e| anyhow::anyhow!("stage failed: {e}"))
    }

    fn workers(&self) -> usize {
        self.n
    }
}

impl Drop for SparkLike {
    fn drop(&mut self) {
        let _ = self.driver_tx.send(DriverMsg::Shutdown);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::baselines::exec::register_bench_tasks;
    use crate::wire;

    fn items(n: u64) -> Vec<Vec<u8>> {
        (0..n).map(|i| wire::to_bytes(&i)).collect()
    }

    #[test]
    fn returns_ordered_results() {
        register_bench_tasks();
        let ex = SparkLike::new(3);
        let out = ex.run_batch("bench.echo", items(40)).unwrap();
        let vals: Vec<u64> = out.iter().map(|b| wire::from_bytes(b).unwrap()).collect();
        assert_eq!(vals, (0..40).collect::<Vec<u64>>());
    }

    #[test]
    fn driver_is_slower_than_hub_on_tiny_tasks() {
        use super::super::ipp_like::IppLike;
        register_bench_tasks();
        let spark = SparkLike::new(2);
        let ipp = IppLike::new(2);
        let t0 = std::time::Instant::now();
        spark.run_batch("bench.echo", items(100)).unwrap();
        let t_spark = t0.elapsed();
        let t0 = std::time::Instant::now();
        ipp.run_batch("bench.echo", items(100)).unwrap();
        let t_ipp = t0.elapsed();
        assert!(
            t_spark > t_ipp,
            "paper: spark (14×) slower than ipp (8×) at 1 ms: spark={t_spark:?} ipp={t_ipp:?}"
        );
    }

    #[test]
    fn sequential_stages_reuse_executors() {
        register_bench_tasks();
        let ex = SparkLike::new(2);
        for _ in 0..3 {
            let out = ex.run_batch("bench.echo", items(10)).unwrap();
            assert_eq!(out.len(), 10);
        }
    }
}
