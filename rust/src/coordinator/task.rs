//! Task envelopes and the global function registry.
//!
//! Rust cannot pickle closures across processes, so fiber-rs makes the
//! paper's container guarantee explicit: leader and workers run the **same
//! binary**, and tasks name a function registered in a global table. A task
//! is `(id, routing, fn_name, payload-bytes)`; payloads are [`crate::wire`]
//! encodings of the function's input type.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use once_cell::sync::Lazy;

use crate::wire::{self, Decode, Encode};

/// Unique task id within a leader.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct TaskId(pub u64);

static NEXT_TASK: AtomicU64 = AtomicU64::new(1);

impl TaskId {
    pub fn fresh() -> Self {
        TaskId(NEXT_TASK.fetch_add(1, Ordering::Relaxed))
    }
}

/// A schedulable unit.
#[derive(Clone, Debug, PartialEq)]
pub struct Task {
    pub id: TaskId,
    /// Which `map`/`apply` call this task belongs to.
    pub map_id: u64,
    /// Index of this task's result within its map call.
    pub index: u64,
    /// Causal trace-span id of the submitting scope (0 = untraced). Rides
    /// the envelope to the worker, where the task's run span parents under
    /// it — how a PBT slice's span reaches its worker-side execution
    /// across a process boundary ([`crate::trace`]).
    pub span: u64,
    pub fn_name: String,
    pub payload: Vec<u8>,
    /// Store blobs this task reads ([`crate::store::ObjRef`] arguments,
    /// recorded at encode time, plus any auto-put payload blob). The
    /// scheduler's placement query resolves these against the store
    /// directory to route the task onto a node already holding its
    /// operands ([`crate::api::sched`]); they ride the envelope so a
    /// re-assignment after node failure can re-derive the same placement.
    pub operands: Vec<crate::store::ObjId>,
}

impl Encode for Task {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.id.0.encode(buf);
        self.map_id.encode(buf);
        self.index.encode(buf);
        self.span.encode(buf);
        self.fn_name.encode(buf);
        self.payload.encode(buf);
        self.operands.encode(buf);
    }
}

impl Decode for Task {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        Ok(Task {
            id: TaskId(u64::decode(r)?),
            map_id: u64::decode(r)?,
            index: u64::decode(r)?,
            span: u64::decode(r)?,
            fn_name: String::decode(r)?,
            payload: Vec::<u8>::decode(r)?,
            operands: Vec::<crate::store::ObjId>::decode(r)?,
        })
    }
}

type TaskFn = Arc<dyn Fn(&[u8]) -> Result<Vec<u8>, String> + Send + Sync>;

static REGISTRY: Lazy<Mutex<HashMap<String, TaskFn>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

/// Register a typed task function under `name`. Re-registering a name
/// replaces the entry (tests rely on this; production code registers once
/// at startup on both leader and workers).
pub fn register_task<I, O, F>(name: &str, f: F)
where
    I: Decode,
    O: Encode,
    F: Fn(I) -> Result<O, String> + Send + Sync + 'static,
{
    let wrapped: TaskFn = Arc::new(move |bytes: &[u8]| {
        let input: I = wire::from_bytes(bytes).map_err(|e| format!("task input decode: {e}"))?;
        let out = f(input)?;
        Ok(wire::to_bytes(&out))
    });
    REGISTRY.lock().unwrap().insert(name.to_string(), wrapped);
}

/// Register a **raw** task function: payload bytes in, already-encoded
/// output bytes out, with no typed wrapping on either side. Wrapper
/// runners that re-dispatch to an inner registered function use this —
/// the inner function's output is already wire-encoded, and wrapping it
/// again would double-encode (the chunk runner avoids this by declaring
/// `Vec<Vec<u8>>`; pass-through wrappers like the pool's auto-ref runner
/// cannot, because the inner output type is unknown to them).
pub fn register_task_raw<F>(name: &str, f: F)
where
    F: Fn(&[u8]) -> Result<Vec<u8>, String> + Send + Sync + 'static,
{
    REGISTRY.lock().unwrap().insert(name.to_string(), Arc::new(f));
}

thread_local! {
    /// Pool worker id executing on this thread (0 = not a worker thread).
    static CURRENT_WORKER: std::cell::Cell<u64> = const { std::cell::Cell::new(0) };
}

/// Mark this thread as the execution thread of pool worker `id`. Both
/// worker loops (in-process threads and `fiber-cli worker` processes) call
/// this before their first fetch, so task functions can observe which
/// worker is running them (chaos injection, observability).
pub fn set_current_worker(id: u64) {
    CURRENT_WORKER.with(|c| c.set(id));
}

/// The pool worker id executing on this thread (0 when not on a worker).
pub fn current_worker() -> u64 {
    CURRENT_WORKER.with(|c| c.get())
}

/// Execute a registered function on raw payload bytes.
pub fn execute_registered(fn_name: &str, payload: &[u8]) -> Result<Vec<u8>, String> {
    let f = {
        let reg = REGISTRY.lock().unwrap();
        reg.get(fn_name)
            .cloned()
            .ok_or_else(|| format!("unregistered task function {fn_name:?}"))?
    };
    f(payload)
}

/// Names currently registered (diagnostics).
pub fn registered_names() -> Vec<String> {
    let mut v: Vec<String> = REGISTRY.lock().unwrap().keys().cloned().collect();
    v.sort();
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn register_and_execute() {
        register_task("test.square", |x: i64| Ok::<i64, String>(x * x));
        let out = execute_registered("test.square", &wire::to_bytes(&7i64)).unwrap();
        let v: i64 = wire::from_bytes(&out).unwrap();
        assert_eq!(v, 49);
    }

    #[test]
    fn unregistered_is_error() {
        let err = execute_registered("test.nope", &[]).unwrap_err();
        assert!(err.contains("unregistered"));
    }

    #[test]
    fn task_fn_errors_propagate() {
        register_task("test.fail", |_x: u8| Err::<u8, String>("sad".into()));
        let err = execute_registered("test.fail", &wire::to_bytes(&1u8)).unwrap_err();
        assert_eq!(err, "sad");
    }

    #[test]
    fn bad_payload_is_decode_error() {
        register_task("test.id", |x: u64| Ok::<u64, String>(x));
        let err = execute_registered("test.id", &[1, 2]).unwrap_err();
        assert!(err.contains("decode"), "{err}");
    }

    #[test]
    fn task_roundtrips_wire() {
        let t = Task {
            id: TaskId(5),
            map_id: 2,
            index: 9,
            span: 42,
            fn_name: "f".into(),
            payload: vec![1, 2, 3],
            operands: vec![crate::store::ObjId::of(b"operand")],
        };
        let bytes = wire::to_bytes(&t);
        let back: Task = wire::from_bytes(&bytes).unwrap();
        assert_eq!(t, back);
    }
}
