//! The pending table (paper, Figure 2).
//!
//! Invariant maintained with the task queue and result queue: at any moment
//! every submitted-but-unfinished task is in **exactly one** of {task queue,
//! pending table}. The property tests in `rust/tests/prop_invariants.rs`
//! drive random fetch/complete/fail schedules against this invariant.

use std::collections::HashMap;

use super::pool_server::WorkerId;
use super::task::{Task, TaskId};

/// Tracks which worker is executing which task.
#[derive(Default, Debug)]
pub struct PendingTable {
    by_task: HashMap<TaskId, (WorkerId, Task)>,
    /// Total entries ever inserted (diagnostics; monotone).
    inserted: u64,
    /// Entries removed by successful completion.
    completed: u64,
    /// Entries drained by worker failure (→ resubmitted).
    requeued: u64,
}

impl PendingTable {
    pub fn new() -> Self {
        Self::default()
    }

    /// Record that `worker` fetched `task`.
    pub fn insert(&mut self, worker: WorkerId, task: Task) {
        self.inserted += 1;
        let prev = self.by_task.insert(task.id, (worker, task));
        debug_assert!(prev.is_none(), "task fetched twice without requeue");
    }

    /// Remove the entry when its result arrives. Returns `false` if the task
    /// was not pending (e.g. a duplicate result after a requeue race).
    pub fn complete(&mut self, task: TaskId) -> bool {
        self.take(task).is_some()
    }

    /// Remove the entry and return its task envelope (result routing needs
    /// the `map_id`/`index`). `None` if not pending — a duplicate result.
    pub fn take(&mut self, task: TaskId) -> Option<Task> {
        let hit = self.by_task.remove(&task).map(|(_, t)| t);
        if hit.is_some() {
            self.completed += 1;
        }
        hit
    }

    /// Drain every task the failed worker was executing, for resubmission.
    /// Tasks come back in submission order (TaskIds are monotonic).
    pub fn drain_worker(&mut self, worker: WorkerId) -> Vec<Task> {
        let mut ids: Vec<TaskId> = self
            .by_task
            .iter()
            .filter(|(_, (w, _))| *w == worker)
            .map(|(id, _)| *id)
            .collect();
        ids.sort();
        let mut tasks = Vec::with_capacity(ids.len());
        for id in ids {
            let (_, task) = self.by_task.remove(&id).unwrap();
            tasks.push(task);
        }
        self.requeued += tasks.len() as u64;
        tasks
    }

    pub fn len(&self) -> usize {
        self.by_task.len()
    }

    pub fn is_empty(&self) -> bool {
        self.by_task.is_empty()
    }

    pub fn contains(&self, task: TaskId) -> bool {
        self.by_task.contains_key(&task)
    }

    /// Worker currently executing `task`, if any.
    pub fn worker_of(&self, task: TaskId) -> Option<WorkerId> {
        self.by_task.get(&task).map(|(w, _)| *w)
    }

    /// (inserted, completed, requeued) counters.
    pub fn counters(&self) -> (u64, u64, u64) {
        (self.inserted, self.completed, self.requeued)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: u64) -> Task {
        Task {
            id: TaskId(id),
            map_id: 0,
            index: id,
            span: 0,
            fn_name: "t".into(),
            payload: vec![],
            operands: vec![],
        }
    }

    #[test]
    fn insert_complete_cycle() {
        let mut p = PendingTable::new();
        p.insert(WorkerId(1), task(10));
        assert_eq!(p.len(), 1);
        assert!(p.contains(TaskId(10)));
        assert_eq!(p.worker_of(TaskId(10)), Some(WorkerId(1)));
        assert!(p.complete(TaskId(10)));
        assert!(p.is_empty());
        assert_eq!(p.counters(), (1, 1, 0));
    }

    #[test]
    fn duplicate_complete_is_noop() {
        let mut p = PendingTable::new();
        p.insert(WorkerId(1), task(10));
        assert!(p.complete(TaskId(10)));
        assert!(!p.complete(TaskId(10)));
        assert_eq!(p.counters(), (1, 1, 0));
    }

    #[test]
    fn drain_worker_returns_only_its_tasks() {
        let mut p = PendingTable::new();
        p.insert(WorkerId(1), task(1));
        p.insert(WorkerId(2), task(2));
        p.insert(WorkerId(1), task(3));
        let mut drained = p.drain_worker(WorkerId(1));
        drained.sort_by_key(|t| t.id);
        assert_eq!(
            drained.iter().map(|t| t.id.0).collect::<Vec<_>>(),
            vec![1, 3]
        );
        assert_eq!(p.len(), 1);
        assert!(p.contains(TaskId(2)));
        assert_eq!(p.counters(), (3, 0, 2));
    }

    #[test]
    fn drain_empty_worker_is_empty() {
        let mut p = PendingTable::new();
        p.insert(WorkerId(1), task(1));
        assert!(p.drain_worker(WorkerId(9)).is_empty());
        assert_eq!(p.len(), 1);
    }
}
