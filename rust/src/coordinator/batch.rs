//! Task batching ("chunksize" in multiprocessing terms).
//!
//! A chunk task carries `k` encoded inputs and is executed by a wrapper that
//! calls the registered function on each, returning `k` encoded outputs.
//! Batching amortises per-task dispatch overhead — the Fig 3a experiment
//! shows why this matters at millisecond task durations.

use crate::wire::{self, Decode, Encode};

use super::task::execute_registered;

/// Payload of a chunk task: the inner function name + each encoded input.
pub struct ChunkPayload {
    pub fn_name: String,
    pub items: Vec<Vec<u8>>,
}

impl Encode for ChunkPayload {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.fn_name.encode(buf);
        self.items.encode(buf);
    }
}

impl Decode for ChunkPayload {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        Ok(Self {
            fn_name: String::decode(r)?,
            items: Vec::<Vec<u8>>::decode(r)?,
        })
    }
}

/// Name under which the chunk runner is registered (see
/// [`register_chunk_runner`], called once at pool construction).
pub const CHUNK_FN: &str = "fiber.chunk";

/// Register the chunk runner (idempotent).
pub fn register_chunk_runner() {
    super::task::register_task(CHUNK_FN, |chunk: ChunkPayload| {
        let mut outs = Vec::with_capacity(chunk.items.len());
        for item in &chunk.items {
            outs.push(execute_registered(&chunk.fn_name, item)?);
        }
        Ok::<Vec<Vec<u8>>, String>(outs)
    });
}

/// Split `items` (already encoded) into chunk payloads of `chunksize`.
pub fn make_chunks(fn_name: &str, items: Vec<Vec<u8>>, chunksize: usize) -> Vec<ChunkPayload> {
    let chunksize = chunksize.max(1);
    let mut chunks = Vec::with_capacity(items.len().div_ceil(chunksize));
    let mut iter = items.into_iter().peekable();
    while iter.peek().is_some() {
        let batch: Vec<Vec<u8>> = iter.by_ref().take(chunksize).collect();
        chunks.push(ChunkPayload {
            fn_name: fn_name.to_string(),
            items: batch,
        });
    }
    chunks
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::task::register_task;

    #[test]
    fn chunks_cover_all_items_in_order() {
        let items: Vec<Vec<u8>> = (0..10u8).map(|i| vec![i]).collect();
        let chunks = make_chunks("f", items, 3);
        assert_eq!(chunks.len(), 4);
        assert_eq!(chunks[0].items, vec![vec![0], vec![1], vec![2]]);
        assert_eq!(chunks[3].items, vec![vec![9]]);
    }

    #[test]
    fn chunksize_zero_treated_as_one() {
        let chunks = make_chunks("f", vec![vec![1], vec![2]], 0);
        assert_eq!(chunks.len(), 2);
    }

    #[test]
    fn chunk_runner_executes_inner_fn() {
        register_task("test.batch.double", |x: u32| Ok::<u32, String>(x * 2));
        register_chunk_runner();
        let payload = ChunkPayload {
            fn_name: "test.batch.double".into(),
            items: (0..5u32).map(|i| wire::to_bytes(&i)).collect(),
        };
        let out = execute_registered(CHUNK_FN, &wire::to_bytes(&payload)).unwrap();
        let outs: Vec<Vec<u8>> = wire::from_bytes(&out).unwrap();
        let vals: Vec<u32> = outs
            .iter()
            .map(|b| wire::from_bytes::<u32>(b).unwrap())
            .collect();
        assert_eq!(vals, vec![0, 2, 4, 6, 8]);
    }

    #[test]
    fn chunk_runner_propagates_inner_error() {
        register_task("test.batch.err", |x: u32| {
            if x == 3 {
                Err("item 3 bad".into())
            } else {
                Ok::<u32, String>(x)
            }
        });
        register_chunk_runner();
        let payload = ChunkPayload {
            fn_name: "test.batch.err".into(),
            items: (0..5u32).map(|i| wire::to_bytes(&i)).collect(),
        };
        let err = execute_registered(CHUNK_FN, &wire::to_bytes(&payload)).unwrap_err();
        assert!(err.contains("item 3 bad"));
    }
}
