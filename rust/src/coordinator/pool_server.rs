//! The leader-side pool service: two-level scheduler + pending table +
//! result queue.
//!
//! Thread workers call [`PoolServer`] methods directly through an `Arc`;
//! OS-process workers reach the same methods through the RPC facade
//! ([`PoolServer::serve_rpc`]). Placement lives in the two-level
//! [`GlobalScheduler`](crate::api::sched::GlobalScheduler): every worker
//! node owns a bounded local run queue, batches are assigned per node,
//! idle nodes steal from the longest queue, and operand-holding nodes are
//! preferred ([`crate::api::sched`]). Fetching (own queue, overflow or a
//! steal) and pending-table insertion stay one atomic step under the
//! server lock — the paper's "each time a task is removed from the task
//! queue, an entry in the pending table is added".

use std::collections::HashSet;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::api::sched::{GlobalScheduler, LookupFn, Origin, SchedStats, DEFAULT_QUEUE_CAP};
use crate::comms::chan::{self, Receiver, Sender};
use crate::comms::rpc::RpcServer;
use crate::wire::{self, Decode, Encode};

use super::pending::PendingTable;
use super::task::{Task, TaskId};

/// Worker identity (assigned by the pool at spawn time).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WorkerId(pub u64);

/// Reply to a fetch request.
#[derive(Clone, Debug, PartialEq)]
pub enum FetchReply {
    /// Run this task.
    Task(Task),
    /// Nothing available right now; poll again.
    Wait,
    /// Worker should exit cleanly (pool closed or scale-down).
    Retire,
}

impl Encode for FetchReply {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            FetchReply::Task(t) => {
                buf.push(0);
                t.encode(buf);
            }
            FetchReply::Wait => buf.push(1),
            FetchReply::Retire => buf.push(2),
        }
    }
}

impl Decode for FetchReply {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        match u8::decode(r)? {
            0 => Ok(FetchReply::Task(Task::decode(r)?)),
            1 => Ok(FetchReply::Wait),
            2 => Ok(FetchReply::Retire),
            t => Err(wire::WireError::BadTag(t as u32)),
        }
    }
}

/// Reply to a batched fetch (`FETCH_BATCH`): the node-batch envelope —
/// one round trip moves a worker's whole next slice of its run queue.
#[derive(Clone, Debug, PartialEq)]
pub enum FetchBatchReply {
    /// Run these tasks, in order.
    Tasks(Vec<Task>),
    /// Nothing available right now; poll again.
    Wait,
    /// Worker should exit cleanly.
    Retire,
}

impl Encode for FetchBatchReply {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            FetchBatchReply::Tasks(ts) => {
                buf.push(0);
                ts.encode(buf);
            }
            FetchBatchReply::Wait => buf.push(1),
            FetchBatchReply::Retire => buf.push(2),
        }
    }
}

impl Decode for FetchBatchReply {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        match u8::decode(r)? {
            0 => Ok(FetchBatchReply::Tasks(Vec::<Task>::decode(r)?)),
            1 => Ok(FetchBatchReply::Wait),
            2 => Ok(FetchBatchReply::Retire),
            t => Err(wire::WireError::BadTag(t as u32)),
        }
    }
}

/// A completed task's result as delivered to the pool's collector.
#[derive(Clone, Debug)]
pub struct ResultMsg {
    pub task: Task,
    pub result: Result<Vec<u8>, String>,
}

/// RPC tags for the proc-worker protocol.
pub mod tags {
    pub const FETCH: u32 = 1;
    pub const PUT: u32 = 2;
    pub const QLEN: u32 = 3;
    /// `HELLO(worker_id: u64, store_endpoint: Option<String>) -> ()` —
    /// a spawned worker reports the endpoint its store node publishes
    /// under, giving the scheduler's locality query a node to route to.
    pub const HELLO: u32 = 4;
    /// `FETCH_BATCH(worker_id: u64, max: u64) -> FetchBatchReply`.
    pub const FETCH_BATCH: u32 = 5;
}

struct Inner {
    sched: GlobalScheduler,
    pending: PendingTable,
    retiring: HashSet<WorkerId>,
    closed: bool,
}

/// The pool service.
pub struct PoolServer {
    inner: Mutex<Inner>,
    task_ready: Condvar,
    results_tx: Sender<ResultMsg>,
    results_rx: Receiver<ResultMsg>,
    /// `pool.queue.depth` gauge, cached so queue mutations do not take the
    /// metrics-registry lock.
    queue_depth: Arc<crate::metrics::Gauge>,
}

impl Default for PoolServer {
    fn default() -> Self {
        Self::new()
    }
}

impl PoolServer {
    pub fn new() -> Self {
        Self::with_queue_cap(DEFAULT_QUEUE_CAP)
    }

    /// A server whose per-node run queues are bounded at `cap` tasks.
    pub fn with_queue_cap(cap: usize) -> Self {
        let (results_tx, results_rx) = chan::unbounded();
        Self {
            inner: Mutex::new(Inner {
                sched: GlobalScheduler::new(cap, true),
                pending: PendingTable::new(),
                retiring: HashSet::new(),
                closed: false,
            }),
            task_ready: Condvar::new(),
            results_tx,
            results_rx,
            queue_depth: crate::metrics::gauge("pool.queue.depth"),
        }
    }

    /// Install the directory query placement consults ([`crate::api::sched`]).
    pub fn set_lookup(&self, lookup: LookupFn) {
        self.inner.lock().unwrap().sched.set_lookup(lookup);
    }

    /// Register a worker node with the scheduler (idempotent; a second
    /// call may supply the store endpoint a proc worker reported late).
    pub fn register_node(&self, worker: WorkerId, endpoint: Option<String>) {
        let mut inner = self.inner.lock().unwrap();
        inner.sched.register_node(worker, endpoint);
        drop(inner);
        // A node registration can make queued work reachable (e.g. tasks
        // parked in overflow before the first node appeared).
        self.task_ready.notify_all();
    }

    /// Enqueue a single task (convenience for [`PoolServer::submit_batch`]).
    pub fn submit(&self, task: Task) {
        self.submit_batch(vec![task]);
    }

    /// Place a batch of tasks: one scheduler assignment per node batch.
    pub fn submit_batch(&self, tasks: Vec<Task>) {
        if tasks.is_empty() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.sched.submit_batch(tasks);
        self.queue_depth.set(inner.sched.queue_len() as i64);
        drop(inner);
        self.task_ready.notify_all();
    }

    /// Re-queue tasks at the *front* (failure resubmission retries sooner).
    pub fn resubmit_front(&self, tasks: Vec<Task>) {
        if tasks.is_empty() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        inner.sched.resubmit_front(tasks);
        self.queue_depth.set(inner.sched.queue_len() as i64);
        drop(inner);
        self.task_ready.notify_all();
    }

    /// Blocking fetch: wait up to `timeout` for a task. Atomically records
    /// the task in the pending table under `worker`. The pop order is the
    /// node scheduler's: own queue, overflow, then a steal from the
    /// longest queue.
    pub fn fetch(&self, worker: WorkerId, timeout: Duration) -> FetchReply {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.sched.contains_node(worker) && !inner.closed {
                // First contact (tests and bare drivers skip explicit
                // registration): a node with no known store endpoint.
                inner.sched.register_node(worker, None);
            }
            if inner.retiring.remove(&worker) {
                self.drop_node(&mut inner, worker);
                return FetchReply::Retire;
            }
            if let Some((task, origin)) = inner.sched.pop_local(worker) {
                self.queue_depth.set(inner.sched.queue_len() as i64);
                inner.pending.insert(worker, task.clone());
                let _ = origin;
                return FetchReply::Task(task);
            }
            if inner.closed {
                self.drop_node(&mut inner, worker);
                return FetchReply::Retire;
            }
            let now = Instant::now();
            if now >= deadline {
                return FetchReply::Wait;
            }
            let (guard, _) = self
                .task_ready
                .wait_timeout(inner, deadline - now)
                .unwrap();
            inner = guard;
        }
    }

    /// Blocking batched fetch: up to `max` tasks for `worker` in one
    /// envelope (own queue, then overflow, then steals). Each task is
    /// atomically moved into the pending table.
    pub fn fetch_batch(&self, worker: WorkerId, max: usize, timeout: Duration) -> FetchBatchReply {
        let max = max.max(1);
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if !inner.sched.contains_node(worker) && !inner.closed {
                inner.sched.register_node(worker, None);
            }
            if inner.retiring.remove(&worker) {
                self.drop_node(&mut inner, worker);
                return FetchBatchReply::Retire;
            }
            let mut got: Vec<Task> = Vec::new();
            while got.len() < max {
                match inner.sched.pop_local(worker) {
                    Some((task, _origin)) => {
                        inner.pending.insert(worker, task.clone());
                        got.push(task);
                    }
                    None => break,
                }
            }
            if !got.is_empty() {
                self.queue_depth.set(inner.sched.queue_len() as i64);
                return FetchBatchReply::Tasks(got);
            }
            if inner.closed {
                self.drop_node(&mut inner, worker);
                return FetchBatchReply::Retire;
            }
            let now = Instant::now();
            if now >= deadline {
                return FetchBatchReply::Wait;
            }
            let (guard, _) = self
                .task_ready
                .wait_timeout(inner, deadline - now)
                .unwrap();
            inner = guard;
        }
    }

    /// Remove a departing worker's node; any queued-but-unstarted tasks it
    /// still held are re-assigned across the surviving nodes.
    fn drop_node(&self, inner: &mut Inner, worker: WorkerId) {
        let orphaned = inner.sched.remove_node(worker);
        if !orphaned.is_empty() {
            inner.sched.reassign_batch(orphaned);
            self.queue_depth.set(inner.sched.queue_len() as i64);
            self.task_ready.notify_all();
        }
    }

    /// Deliver a result. Duplicate results (possible when a slow worker
    /// races its own failure-resubmission) are dropped — the pending table
    /// is the arbiter, making result delivery exactly-once per task.
    pub fn put_result(&self, task_id: TaskId, result: Result<Vec<u8>, String>) {
        let task = self.inner.lock().unwrap().pending.take(task_id);
        if let Some(task) = task {
            let _ = self.results_tx.send(ResultMsg { task, result });
        }
    }

    /// Handle a worker failure: its queued-but-unstarted tasks are
    /// **re-assigned** across surviving nodes, and its pending (started)
    /// tasks are resubmitted at the front for a re-run. Returns
    /// `(reruns, reassigned)`.
    pub fn fail_worker(&self, worker: WorkerId) -> (usize, usize) {
        let mut inner = self.inner.lock().unwrap();
        let orphaned = inner.sched.remove_node(worker);
        let reassigned = orphaned.len();
        if reassigned > 0 {
            inner.sched.reassign_batch(orphaned);
        }
        let started = inner.pending.drain_worker(worker);
        let reruns = started.len();
        if reruns > 0 {
            inner.sched.resubmit_front(started);
        }
        self.queue_depth.set(inner.sched.queue_len() as i64);
        drop(inner);
        if reruns + reassigned > 0 {
            self.task_ready.notify_all();
        }
        (reruns, reassigned)
    }

    /// Ask a specific worker to retire at its next fetch.
    pub fn retire(&self, worker: WorkerId) {
        let mut inner = self.inner.lock().unwrap();
        inner.retiring.insert(worker);
        drop(inner);
        self.task_ready.notify_all();
    }

    /// Close the pool: workers retire once the queues drain.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        drop(inner);
        self.task_ready.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    pub fn queue_len(&self) -> usize {
        self.inner.lock().unwrap().sched.queue_len()
    }

    pub fn pending_len(&self) -> usize {
        self.inner.lock().unwrap().pending.len()
    }

    /// (inserted, completed, requeued) pending-table counters.
    pub fn counters(&self) -> (u64, u64, u64) {
        self.inner.lock().unwrap().pending.counters()
    }

    /// Scheduler counters (placement, locality, stealing, re-assignment).
    pub fn sched_stats(&self) -> SchedStats {
        self.inner.lock().unwrap().sched.stats()
    }

    /// `(node, queue length)` snapshot of every node scheduler.
    pub fn queue_lens(&self) -> Vec<(WorkerId, usize)> {
        self.inner.lock().unwrap().sched.queue_lens()
    }

    /// Receiver of completed results (consumed by the pool's collector).
    pub fn results(&self) -> Receiver<ResultMsg> {
        self.results_rx.clone()
    }

    /// Expose this server over TCP for OS-process workers.
    ///
    /// Protocol: `FETCH(worker_id: u64) -> FetchReply`,
    /// `PUT(worker_id: u64, task_id: u64, result: Result<Vec<u8>, String>) -> ()`,
    /// `QLEN(()) -> u64`,
    /// `HELLO(worker_id: u64, store_endpoint: Option<String>) -> ()`,
    /// `FETCH_BATCH(worker_id: u64, max: u64) -> FetchBatchReply`.
    pub fn serve_rpc(self: &Arc<Self>, bind: &str) -> anyhow::Result<RpcServer> {
        let srv = self.clone();
        RpcServer::bind(
            bind,
            Arc::new(move |tag, payload| match tag {
                tags::FETCH => {
                    let worker: u64 =
                        wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    let reply = srv.fetch(WorkerId(worker), Duration::from_millis(500));
                    Ok(wire::to_bytes(&reply))
                }
                tags::FETCH_BATCH => {
                    let (worker, max): (u64, u64) =
                        wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    let reply = srv.fetch_batch(
                        WorkerId(worker),
                        max as usize,
                        Duration::from_millis(500),
                    );
                    Ok(wire::to_bytes(&reply))
                }
                tags::PUT => {
                    let (_worker, task_id, result): (u64, u64, Result<Vec<u8>, String>) =
                        wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    srv.put_result(TaskId(task_id), result);
                    Ok(Vec::new())
                }
                tags::HELLO => {
                    let (worker, endpoint): (u64, Option<String>) =
                        wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    srv.register_node(WorkerId(worker), endpoint);
                    Ok(Vec::new())
                }
                tags::QLEN => Ok(wire::to_bytes(&(srv.queue_len() as u64))),
                t => Err(format!("bad pool rpc tag {t}")),
            }),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: u64) -> Task {
        Task {
            id: TaskId(id),
            map_id: 1,
            index: id,
            span: 0,
            fn_name: "f".into(),
            payload: vec![id as u8],
            operands: vec![],
        }
    }

    const T: Duration = Duration::from_millis(50);

    #[test]
    fn fetch_moves_task_to_pending() {
        let s = PoolServer::new();
        s.submit(task(1));
        assert_eq!(s.queue_len(), 1);
        let r = s.fetch(WorkerId(1), T);
        assert_eq!(r, FetchReply::Task(task(1)));
        assert_eq!(s.queue_len(), 0);
        assert_eq!(s.pending_len(), 1);
    }

    #[test]
    fn fetch_times_out_with_wait() {
        let s = PoolServer::new();
        assert_eq!(s.fetch(WorkerId(1), Duration::from_millis(10)), FetchReply::Wait);
    }

    #[test]
    fn result_clears_pending_and_routes() {
        let s = PoolServer::new();
        s.submit(task(1));
        s.fetch(WorkerId(1), T);
        s.put_result(TaskId(1), Ok(vec![42]));
        assert_eq!(s.pending_len(), 0);
        let msg = s.results().try_recv().unwrap();
        assert_eq!(msg.task.id, TaskId(1));
        assert_eq!(msg.result, Ok(vec![42]));
    }

    #[test]
    fn duplicate_results_dropped() {
        let s = PoolServer::new();
        s.submit(task(1));
        s.fetch(WorkerId(1), T);
        s.put_result(TaskId(1), Ok(vec![1]));
        s.put_result(TaskId(1), Ok(vec![2])); // duplicate
        let rx = s.results();
        assert!(rx.try_recv().is_ok());
        assert!(rx.try_recv().is_err(), "second result must be dropped");
    }

    #[test]
    fn fail_worker_requeues_in_order() {
        let s = PoolServer::new();
        s.register_node(WorkerId(7), None);
        s.submit(task(1));
        s.submit(task(2));
        s.submit(task(3));
        assert!(matches!(s.fetch(WorkerId(7), T), FetchReply::Task(_)));
        assert!(matches!(s.fetch(WorkerId(7), T), FetchReply::Task(_)));
        let (reruns, reassigned) = s.fail_worker(WorkerId(7));
        assert_eq!(reruns, 2, "both started tasks re-run");
        assert_eq!(reassigned, 1, "the unstarted task is re-assigned");
        assert_eq!(s.queue_len(), 3);
        // Resubmitted tasks come back out first, in original order.
        let r = s.fetch(WorkerId(8), T);
        assert_eq!(r, FetchReply::Task(task(1)));
        let r = s.fetch(WorkerId(8), T);
        assert_eq!(r, FetchReply::Task(task(2)));
        let r = s.fetch(WorkerId(8), T);
        assert_eq!(r, FetchReply::Task(task(3)));
    }

    #[test]
    fn retire_targets_one_worker() {
        let s = PoolServer::new();
        s.retire(WorkerId(3));
        assert_eq!(s.fetch(WorkerId(3), T), FetchReply::Retire);
        // Other workers unaffected.
        assert_eq!(s.fetch(WorkerId(4), Duration::from_millis(10)), FetchReply::Wait);
    }

    #[test]
    fn retiring_node_queue_is_reassigned() {
        let s = PoolServer::new();
        s.register_node(WorkerId(1), None);
        s.register_node(WorkerId(2), None);
        for i in 0..4 {
            s.submit(task(i));
        }
        // Node 1 retires with 2 queued tasks: both must move to node 2.
        s.retire(WorkerId(1));
        assert_eq!(s.fetch(WorkerId(1), T), FetchReply::Retire);
        assert_eq!(s.sched_stats().reassigned, 2);
        let mut got = 0;
        while matches!(s.fetch(WorkerId(2), Duration::from_millis(10)), FetchReply::Task(_)) {
            got += 1;
        }
        assert_eq!(got, 4, "no task may be lost to a retired node's queue");
    }

    #[test]
    fn close_retires_after_drain() {
        let s = PoolServer::new();
        s.submit(task(1));
        s.close();
        assert!(matches!(s.fetch(WorkerId(1), T), FetchReply::Task(_)));
        assert_eq!(s.fetch(WorkerId(1), T), FetchReply::Retire);
    }

    #[test]
    fn blocked_fetch_wakes_on_submit() {
        let s = Arc::new(PoolServer::new());
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.fetch(WorkerId(1), Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(20));
        s.submit(task(9));
        match h.join().unwrap() {
            FetchReply::Task(t) => assert_eq!(t.id, TaskId(9)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn fetch_batch_ships_one_envelope() {
        let s = PoolServer::new();
        s.register_node(WorkerId(1), None);
        s.submit_batch((0..5).map(task).collect());
        let r = s.fetch_batch(WorkerId(1), 3, T);
        let FetchBatchReply::Tasks(ts) = r else {
            panic!("expected a task batch, got {r:?}");
        };
        assert_eq!(ts.len(), 3, "bounded by max");
        assert_eq!(s.pending_len(), 3, "each batched task is pending");
        let FetchBatchReply::Tasks(rest) = s.fetch_batch(WorkerId(1), 8, T) else {
            panic!("second batch expected");
        };
        assert_eq!(rest.len(), 2);
        assert_eq!(
            s.fetch_batch(WorkerId(1), 8, Duration::from_millis(10)),
            FetchBatchReply::Wait
        );
    }

    #[test]
    fn rpc_facade_roundtrip() {
        use crate::comms::rpc::RpcClient;
        let s = Arc::new(PoolServer::new());
        let rpc = s.serve_rpc("127.0.0.1:0").unwrap();
        s.submit(task(5));
        let cli = RpcClient::connect(rpc.local_addr()).unwrap();
        // HELLO registers the node (with no store endpoint here).
        cli.call(
            tags::HELLO,
            &wire::to_bytes(&(11u64, Option::<String>::None)),
        )
        .unwrap();
        let reply: FetchReply = {
            let bytes = cli.call(tags::FETCH, &wire::to_bytes(&11u64)).unwrap();
            wire::from_bytes(&bytes).unwrap()
        };
        match reply {
            FetchReply::Task(t) => assert_eq!(t.id, TaskId(5)),
            other => panic!("{other:?}"),
        }
        cli.call(
            tags::PUT,
            &wire::to_bytes(&(11u64, 5u64, Ok::<Vec<u8>, String>(vec![9]))),
        )
        .unwrap();
        let msg = s.results().recv().unwrap();
        assert_eq!(msg.result, Ok(vec![9]));
        let qlen: u64 = cli.call_typed(tags::QLEN, &()).unwrap();
        assert_eq!(qlen, 0);
        // Batched fetch over RPC.
        s.submit_batch((20..23).map(task).collect());
        let bytes = cli
            .call(tags::FETCH_BATCH, &wire::to_bytes(&(11u64, 8u64)))
            .unwrap();
        let batch: FetchBatchReply = wire::from_bytes(&bytes).unwrap();
        let FetchBatchReply::Tasks(ts) = batch else {
            panic!("expected batch, got {batch:?}");
        };
        assert_eq!(ts.len(), 3);
    }
}
