//! The leader-side pool service: task queue + pending table + result queue.
//!
//! Thread workers call [`PoolServer`] methods directly through an `Arc`;
//! OS-process workers reach the same methods through the RPC facade
//! ([`PoolServer::serve_rpc`]). Fetching and pending-table insertion are one
//! atomic step under the server lock — the paper's "each time a task is
//! removed from the task queue, an entry in the pending table is added".

use std::collections::{HashSet, VecDeque};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::comms::chan::{self, Receiver, Sender};
use crate::comms::rpc::RpcServer;
use crate::wire::{self, Decode, Encode};

use super::pending::PendingTable;
use super::task::{Task, TaskId};

/// Worker identity (assigned by the pool at spawn time).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct WorkerId(pub u64);

/// Reply to a fetch request.
#[derive(Clone, Debug, PartialEq)]
pub enum FetchReply {
    /// Run this task.
    Task(Task),
    /// Nothing available right now; poll again.
    Wait,
    /// Worker should exit cleanly (pool closed or scale-down).
    Retire,
}

impl Encode for FetchReply {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            FetchReply::Task(t) => {
                buf.push(0);
                t.encode(buf);
            }
            FetchReply::Wait => buf.push(1),
            FetchReply::Retire => buf.push(2),
        }
    }
}

impl Decode for FetchReply {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        match u8::decode(r)? {
            0 => Ok(FetchReply::Task(Task::decode(r)?)),
            1 => Ok(FetchReply::Wait),
            2 => Ok(FetchReply::Retire),
            t => Err(wire::WireError::BadTag(t as u32)),
        }
    }
}

/// A completed task's result as delivered to the pool's collector.
#[derive(Clone, Debug)]
pub struct ResultMsg {
    pub task: Task,
    pub result: Result<Vec<u8>, String>,
}

/// RPC tags for the proc-worker protocol.
pub mod tags {
    pub const FETCH: u32 = 1;
    pub const PUT: u32 = 2;
    pub const QLEN: u32 = 3;
}

struct Inner {
    queue: VecDeque<Task>,
    pending: PendingTable,
    retiring: HashSet<WorkerId>,
    closed: bool,
}

/// The pool service.
pub struct PoolServer {
    inner: Mutex<Inner>,
    task_ready: Condvar,
    results_tx: Sender<ResultMsg>,
    results_rx: Receiver<ResultMsg>,
    /// `pool.queue.depth` gauge, cached so queue mutations do not take the
    /// metrics-registry lock.
    queue_depth: Arc<crate::metrics::Gauge>,
}

impl Default for PoolServer {
    fn default() -> Self {
        Self::new()
    }
}

impl PoolServer {
    pub fn new() -> Self {
        let (results_tx, results_rx) = chan::unbounded();
        Self {
            inner: Mutex::new(Inner {
                queue: VecDeque::new(),
                pending: PendingTable::new(),
                retiring: HashSet::new(),
                closed: false,
            }),
            task_ready: Condvar::new(),
            results_tx,
            results_rx,
            queue_depth: crate::metrics::gauge("pool.queue.depth"),
        }
    }

    /// Enqueue a new task at the back of the task queue.
    pub fn submit(&self, task: Task) {
        let mut inner = self.inner.lock().unwrap();
        inner.queue.push_back(task);
        self.queue_depth.set(inner.queue.len() as i64);
        drop(inner);
        self.task_ready.notify_one();
    }

    /// Re-queue tasks at the *front* (failure resubmission retries sooner).
    pub fn resubmit_front(&self, tasks: Vec<Task>) {
        if tasks.is_empty() {
            return;
        }
        let mut inner = self.inner.lock().unwrap();
        for t in tasks.into_iter().rev() {
            inner.queue.push_front(t);
        }
        self.queue_depth.set(inner.queue.len() as i64);
        drop(inner);
        self.task_ready.notify_all();
    }

    /// Blocking fetch: wait up to `timeout` for a task. Atomically records
    /// the task in the pending table under `worker`.
    pub fn fetch(&self, worker: WorkerId, timeout: Duration) -> FetchReply {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.retiring.remove(&worker) {
                return FetchReply::Retire;
            }
            if let Some(task) = inner.queue.pop_front() {
                self.queue_depth.set(inner.queue.len() as i64);
                inner.pending.insert(worker, task.clone());
                return FetchReply::Task(task);
            }
            if inner.closed {
                return FetchReply::Retire;
            }
            let now = Instant::now();
            if now >= deadline {
                return FetchReply::Wait;
            }
            let (guard, _) = self
                .task_ready
                .wait_timeout(inner, deadline - now)
                .unwrap();
            inner = guard;
        }
    }

    /// Deliver a result. Duplicate results (possible when a slow worker
    /// races its own failure-resubmission) are dropped — the pending table
    /// is the arbiter, making result delivery exactly-once per task.
    pub fn put_result(&self, task_id: TaskId, result: Result<Vec<u8>, String>) {
        let task = self.inner.lock().unwrap().pending.take(task_id);
        if let Some(task) = task {
            let _ = self.results_tx.send(ResultMsg { task, result });
        }
    }

    /// Handle a worker failure: move its pending tasks back to the queue.
    /// Returns how many tasks were resubmitted.
    pub fn fail_worker(&self, worker: WorkerId) -> usize {
        let mut inner = self.inner.lock().unwrap();
        let tasks = inner.pending.drain_worker(worker);
        let n = tasks.len();
        for t in tasks.into_iter().rev() {
            inner.queue.push_front(t);
        }
        self.queue_depth.set(inner.queue.len() as i64);
        drop(inner);
        if n > 0 {
            self.task_ready.notify_all();
        }
        n
    }

    /// Ask a specific worker to retire at its next fetch.
    pub fn retire(&self, worker: WorkerId) {
        let mut inner = self.inner.lock().unwrap();
        inner.retiring.insert(worker);
        drop(inner);
        self.task_ready.notify_all();
    }

    /// Close the pool: workers retire once the queue drains.
    pub fn close(&self) {
        let mut inner = self.inner.lock().unwrap();
        inner.closed = true;
        drop(inner);
        self.task_ready.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.inner.lock().unwrap().closed
    }

    pub fn queue_len(&self) -> usize {
        self.inner.lock().unwrap().queue.len()
    }

    pub fn pending_len(&self) -> usize {
        self.inner.lock().unwrap().pending.len()
    }

    /// (inserted, completed, requeued) pending-table counters.
    pub fn counters(&self) -> (u64, u64, u64) {
        self.inner.lock().unwrap().pending.counters()
    }

    /// Receiver of completed results (consumed by the pool's collector).
    pub fn results(&self) -> Receiver<ResultMsg> {
        self.results_rx.clone()
    }

    /// Expose this server over TCP for OS-process workers.
    ///
    /// Protocol: `FETCH(worker_id: u64) -> FetchReply`,
    /// `PUT(worker_id: u64, task_id: u64, result: Result<Vec<u8>, String>) -> ()`,
    /// `QLEN(()) -> u64`.
    pub fn serve_rpc(self: &Arc<Self>, bind: &str) -> anyhow::Result<RpcServer> {
        let srv = self.clone();
        RpcServer::bind(
            bind,
            Arc::new(move |tag, payload| match tag {
                tags::FETCH => {
                    let worker: u64 =
                        wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    let reply = srv.fetch(WorkerId(worker), Duration::from_millis(500));
                    Ok(wire::to_bytes(&reply))
                }
                tags::PUT => {
                    let (_worker, task_id, result): (u64, u64, Result<Vec<u8>, String>) =
                        wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    srv.put_result(TaskId(task_id), result);
                    Ok(Vec::new())
                }
                tags::QLEN => Ok(wire::to_bytes(&(srv.queue_len() as u64))),
                t => Err(format!("bad pool rpc tag {t}")),
            }),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn task(id: u64) -> Task {
        Task {
            id: TaskId(id),
            map_id: 1,
            index: id,
            span: 0,
            fn_name: "f".into(),
            payload: vec![id as u8],
        }
    }

    const T: Duration = Duration::from_millis(50);

    #[test]
    fn fetch_moves_task_to_pending() {
        let s = PoolServer::new();
        s.submit(task(1));
        assert_eq!(s.queue_len(), 1);
        let r = s.fetch(WorkerId(1), T);
        assert_eq!(r, FetchReply::Task(task(1)));
        assert_eq!(s.queue_len(), 0);
        assert_eq!(s.pending_len(), 1);
    }

    #[test]
    fn fetch_times_out_with_wait() {
        let s = PoolServer::new();
        assert_eq!(s.fetch(WorkerId(1), Duration::from_millis(10)), FetchReply::Wait);
    }

    #[test]
    fn result_clears_pending_and_routes() {
        let s = PoolServer::new();
        s.submit(task(1));
        s.fetch(WorkerId(1), T);
        s.put_result(TaskId(1), Ok(vec![42]));
        assert_eq!(s.pending_len(), 0);
        let msg = s.results().try_recv().unwrap();
        assert_eq!(msg.task.id, TaskId(1));
        assert_eq!(msg.result, Ok(vec![42]));
    }

    #[test]
    fn duplicate_results_dropped() {
        let s = PoolServer::new();
        s.submit(task(1));
        s.fetch(WorkerId(1), T);
        s.put_result(TaskId(1), Ok(vec![1]));
        s.put_result(TaskId(1), Ok(vec![2])); // duplicate
        let rx = s.results();
        assert!(rx.try_recv().is_ok());
        assert!(rx.try_recv().is_err(), "second result must be dropped");
    }

    #[test]
    fn fail_worker_requeues_in_order() {
        let s = PoolServer::new();
        s.submit(task(1));
        s.submit(task(2));
        s.submit(task(3));
        assert!(matches!(s.fetch(WorkerId(7), T), FetchReply::Task(_)));
        assert!(matches!(s.fetch(WorkerId(7), T), FetchReply::Task(_)));
        assert_eq!(s.fail_worker(WorkerId(7)), 2);
        assert_eq!(s.queue_len(), 3);
        // Requeued tasks come back out first, in original order.
        let r = s.fetch(WorkerId(8), T);
        assert_eq!(r, FetchReply::Task(task(1)));
        let r = s.fetch(WorkerId(8), T);
        assert_eq!(r, FetchReply::Task(task(2)));
        let r = s.fetch(WorkerId(8), T);
        assert_eq!(r, FetchReply::Task(task(3)));
    }

    #[test]
    fn retire_targets_one_worker() {
        let s = PoolServer::new();
        s.retire(WorkerId(3));
        assert_eq!(s.fetch(WorkerId(3), T), FetchReply::Retire);
        // Other workers unaffected.
        assert_eq!(s.fetch(WorkerId(4), Duration::from_millis(10)), FetchReply::Wait);
    }

    #[test]
    fn close_retires_after_drain() {
        let s = PoolServer::new();
        s.submit(task(1));
        s.close();
        assert!(matches!(s.fetch(WorkerId(1), T), FetchReply::Task(_)));
        assert_eq!(s.fetch(WorkerId(1), T), FetchReply::Retire);
    }

    #[test]
    fn blocked_fetch_wakes_on_submit() {
        let s = Arc::new(PoolServer::new());
        let s2 = s.clone();
        let h = std::thread::spawn(move || s2.fetch(WorkerId(1), Duration::from_secs(5)));
        std::thread::sleep(Duration::from_millis(20));
        s.submit(task(9));
        match h.join().unwrap() {
            FetchReply::Task(t) => assert_eq!(t.id, TaskId(9)),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn rpc_facade_roundtrip() {
        use crate::comms::rpc::RpcClient;
        let s = Arc::new(PoolServer::new());
        let rpc = s.serve_rpc("127.0.0.1:0").unwrap();
        s.submit(task(5));
        let cli = RpcClient::connect(rpc.local_addr()).unwrap();
        let reply: FetchReply = {
            let bytes = cli.call(tags::FETCH, &wire::to_bytes(&11u64)).unwrap();
            wire::from_bytes(&bytes).unwrap()
        };
        match reply {
            FetchReply::Task(t) => assert_eq!(t.id, TaskId(5)),
            other => panic!("{other:?}"),
        }
        cli.call(
            tags::PUT,
            &wire::to_bytes(&(11u64, 5u64, Ok::<Vec<u8>, String>(vec![9]))),
        )
        .unwrap();
        let msg = s.results().recv().unwrap();
        assert_eq!(msg.result, Ok(vec![9]));
        let qlen: u64 = cli.call_typed(tags::QLEN, &()).unwrap();
        assert_eq!(qlen, 0);
    }
}
