//! Coordinator internals behind the Fiber API: the task pool machinery.
//!
//! The paper's Figure 2 describes the contract implemented here: a pool owns
//! a **task queue**, a **result queue** and a **pending table**. Fetching a
//! task atomically moves it into the pending table keyed by the fetching
//! worker; delivering a result removes the entry; a worker failure re-queues
//! everything that worker had pending and the pool replaces the worker.
//!
//! * [`task`] — task envelopes and the registered-function table (the
//!   container-image analogue: leader and workers run the same binary, so a
//!   function name resolves identically everywhere).
//! * [`pending`] — the pending table.
//! * [`pool_server`] — the leader-side service workers talk to (direct
//!   in-process calls for thread workers; RPC for OS-process workers).
//! * [`batch`] — task batching ("when batching is enabled, multiple tasks
//!   can be scheduled at the same time to improve efficiency").
//! * [`scaling`] — the autoscale policy driving dynamic worker counts.

pub mod batch;
pub mod pending;
pub mod pool_server;
pub mod scaling;
pub mod task;

pub use pending::PendingTable;
pub use pool_server::{FetchReply, PoolServer, WorkerId};
pub use scaling::AutoscalePolicy;
pub use task::{execute_registered, register_task, registered_names, Task, TaskId};
