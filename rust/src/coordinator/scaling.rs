//! Dynamic-scaling policy.
//!
//! Fiber "does not require pre-allocating resources and can scale up and
//! down with the algorithm it runs". The policy here is deliberately simple
//! and testable: target enough workers to keep per-worker backlog near
//! `tasks_per_worker`, clamped to `[min, max]`, with hysteresis (a scale
//! step is only emitted when the target drifts from the current size and a
//! cooldown has elapsed). The pool applies targets via `Pool::resize`; the
//! E5 bench measures utilization vs. static peak allocation.

/// Autoscaling policy parameters.
#[derive(Clone, Copy, Debug)]
pub struct AutoscalePolicy {
    pub min_workers: usize,
    pub max_workers: usize,
    /// Desired queue backlog per worker.
    pub tasks_per_worker: f64,
    /// Minimum virtual/real time between scale steps, ns.
    pub cooldown_ns: u64,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        Self {
            min_workers: 1,
            max_workers: 256,
            tasks_per_worker: 4.0,
            cooldown_ns: 500_000_000,
        }
    }
}

/// Stateful evaluator applying cooldown/hysteresis on top of the policy.
#[derive(Clone, Debug)]
pub struct Autoscaler {
    policy: AutoscalePolicy,
    last_change_ns: Option<u64>,
}

impl Autoscaler {
    pub fn new(policy: AutoscalePolicy) -> Self {
        Self {
            policy,
            last_change_ns: None,
        }
    }

    /// Pure target computation (no hysteresis): how many workers should we
    /// have for `backlog` queued tasks plus `in_flight` executing tasks?
    pub fn target(&self, backlog: usize, in_flight: usize) -> usize {
        let demand = backlog + in_flight;
        let raw = (demand as f64 / self.policy.tasks_per_worker).ceil() as usize;
        raw.clamp(self.policy.min_workers, self.policy.max_workers)
    }

    /// Decide a resize at time `now_ns`. Returns `Some(new_size)` only when
    /// the target differs from `current` and the cooldown has elapsed.
    pub fn decide(
        &mut self,
        now_ns: u64,
        current: usize,
        backlog: usize,
        in_flight: usize,
    ) -> Option<usize> {
        let target = self.target(backlog, in_flight);
        if target == current {
            return None;
        }
        if let Some(last) = self.last_change_ns {
            if now_ns.saturating_sub(last) < self.policy.cooldown_ns {
                return None;
            }
        }
        self.last_change_ns = Some(now_ns);
        Some(target)
    }

    pub fn policy(&self) -> &AutoscalePolicy {
        &self.policy
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pol() -> AutoscalePolicy {
        AutoscalePolicy {
            min_workers: 2,
            max_workers: 64,
            tasks_per_worker: 4.0,
            cooldown_ns: 1_000,
        }
    }

    #[test]
    fn target_scales_with_demand() {
        let a = Autoscaler::new(pol());
        assert_eq!(a.target(0, 0), 2, "clamped to min");
        assert_eq!(a.target(16, 0), 4);
        assert_eq!(a.target(100, 28), 32);
        assert_eq!(a.target(10_000, 0), 64, "clamped to max");
    }

    #[test]
    fn no_decision_when_already_at_target() {
        let mut a = Autoscaler::new(pol());
        assert_eq!(a.decide(0, 4, 16, 0), None);
    }

    #[test]
    fn cooldown_suppresses_flapping() {
        let mut a = Autoscaler::new(pol());
        assert_eq!(a.decide(0, 2, 64, 0), Some(16));
        // Immediately wants to shrink, but cooldown not elapsed.
        assert_eq!(a.decide(500, 16, 0, 0), None);
        // After cooldown it may shrink.
        assert_eq!(a.decide(2_000, 16, 0, 0), Some(2));
    }

    #[test]
    fn scale_down_to_min_when_idle() {
        let mut a = Autoscaler::new(pol());
        assert_eq!(a.decide(10_000, 32, 0, 0), Some(2));
    }
}
