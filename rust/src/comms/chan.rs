//! In-process MPMC blocking channels.
//!
//! `std::sync::mpsc` is single-consumer; Fiber pools need multi-consumer
//! task queues, so we implement a small Mutex+Condvar MPMC channel with
//! optional capacity bounds, close semantics and timeouts. This is the
//! `inproc://` transport.

use std::collections::VecDeque;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

/// Error returned by send operations.
#[derive(Debug, PartialEq, Eq, thiserror::Error)]
pub enum SendError {
    #[error("channel closed")]
    Closed,
}

/// Error returned by receive operations.
#[derive(Debug, PartialEq, Eq, thiserror::Error)]
pub enum RecvError {
    #[error("channel closed and drained")]
    Closed,
    #[error("receive timed out")]
    Timeout,
    #[error("channel empty")]
    Empty,
}

struct Core<T> {
    q: Mutex<State<T>>,
    not_empty: Condvar,
    not_full: Condvar,
    cap: Option<usize>,
}

struct State<T> {
    items: VecDeque<T>,
    closed: bool,
}

/// Sending half (cloneable).
pub struct Sender<T> {
    core: Arc<Core<T>>,
}

/// Receiving half (cloneable — MPMC).
pub struct Receiver<T> {
    core: Arc<Core<T>>,
}

impl<T> Clone for Sender<T> {
    fn clone(&self) -> Self {
        Self {
            core: self.core.clone(),
        }
    }
}

impl<T> Clone for Receiver<T> {
    fn clone(&self) -> Self {
        Self {
            core: self.core.clone(),
        }
    }
}

/// Create an unbounded channel.
pub fn unbounded<T>() -> (Sender<T>, Receiver<T>) {
    with_cap(None)
}

/// Create a bounded channel; `send` blocks when full.
pub fn bounded<T>(cap: usize) -> (Sender<T>, Receiver<T>) {
    with_cap(Some(cap.max(1)))
}

fn with_cap<T>(cap: Option<usize>) -> (Sender<T>, Receiver<T>) {
    let core = Arc::new(Core {
        q: Mutex::new(State {
            items: VecDeque::new(),
            closed: false,
        }),
        not_empty: Condvar::new(),
        not_full: Condvar::new(),
        cap,
    });
    (
        Sender { core: core.clone() },
        Receiver { core },
    )
}

impl<T> Sender<T> {
    /// Blocking send (waits for space on bounded channels).
    pub fn send(&self, v: T) -> Result<(), SendError> {
        let mut st = self.core.q.lock().unwrap();
        loop {
            if st.closed {
                return Err(SendError::Closed);
            }
            if self.core.cap.map_or(true, |c| st.items.len() < c) {
                st.items.push_back(v);
                self.core.not_empty.notify_one();
                return Ok(());
            }
            st = self.core.not_full.wait(st).unwrap();
        }
    }

    /// Close the channel: further sends fail, receivers drain then see
    /// [`RecvError::Closed`].
    pub fn close(&self) {
        let mut st = self.core.q.lock().unwrap();
        st.closed = true;
        self.core.not_empty.notify_all();
        self.core.not_full.notify_all();
    }

    pub fn is_closed(&self) -> bool {
        self.core.q.lock().unwrap().closed
    }

    pub fn len(&self) -> usize {
        self.core.q.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

impl<T> Receiver<T> {
    /// Blocking receive.
    pub fn recv(&self) -> Result<T, RecvError> {
        let mut st = self.core.q.lock().unwrap();
        loop {
            if let Some(v) = st.items.pop_front() {
                self.core.not_full.notify_one();
                return Ok(v);
            }
            if st.closed {
                return Err(RecvError::Closed);
            }
            st = self.core.not_empty.wait(st).unwrap();
        }
    }

    /// Receive with a relative timeout.
    pub fn recv_timeout(&self, timeout: Duration) -> Result<T, RecvError> {
        self.recv_deadline(Instant::now() + timeout)
    }

    /// Receive with an absolute deadline — the primitive behind the ring
    /// collectives' resumable waits, where one logical wait is sliced into
    /// many short probes that must not stretch the overall budget.
    pub fn recv_deadline(&self, deadline: Instant) -> Result<T, RecvError> {
        let mut st = self.core.q.lock().unwrap();
        loop {
            if let Some(v) = st.items.pop_front() {
                self.core.not_full.notify_one();
                return Ok(v);
            }
            if st.closed {
                return Err(RecvError::Closed);
            }
            let now = Instant::now();
            if now >= deadline {
                return Err(RecvError::Timeout);
            }
            let (g, res) = self
                .core
                .not_empty
                .wait_timeout(st, deadline - now)
                .unwrap();
            st = g;
            if res.timed_out() && st.items.is_empty() {
                if st.closed {
                    return Err(RecvError::Closed);
                }
                return Err(RecvError::Timeout);
            }
        }
    }

    /// Non-blocking receive.
    pub fn try_recv(&self) -> Result<T, RecvError> {
        let mut st = self.core.q.lock().unwrap();
        if let Some(v) = st.items.pop_front() {
            self.core.not_full.notify_one();
            Ok(v)
        } else if st.closed {
            Err(RecvError::Closed)
        } else {
            Err(RecvError::Empty)
        }
    }

    pub fn len(&self) -> usize {
        self.core.q.lock().unwrap().items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    #[test]
    fn fifo_order() {
        let (tx, rx) = unbounded();
        for i in 0..10 {
            tx.send(i).unwrap();
        }
        for i in 0..10 {
            assert_eq!(rx.recv().unwrap(), i);
        }
    }

    #[test]
    fn mpmc_all_items_delivered_once() {
        let (tx, rx) = unbounded();
        let n_producers = 4;
        let n_consumers = 4;
        let per = 250usize;
        let mut handles = vec![];
        for p in 0..n_producers {
            let tx = tx.clone();
            handles.push(thread::spawn(move || {
                for i in 0..per {
                    tx.send(p * per + i).unwrap();
                }
            }));
        }
        let (otx, orx) = unbounded();
        for _ in 0..n_consumers {
            let rx = rx.clone();
            let otx = otx.clone();
            handles.push(thread::spawn(move || loop {
                match rx.recv() {
                    Ok(v) => otx.send(v).unwrap(),
                    Err(_) => break,
                }
            }));
        }
        for h in handles.drain(..n_producers) {
            h.join().unwrap();
        }
        tx.close();
        for h in handles {
            h.join().unwrap();
        }
        let mut got: Vec<usize> = (0..n_producers * per).map(|_| orx.recv().unwrap()).collect();
        got.sort();
        assert_eq!(got, (0..n_producers * per).collect::<Vec<_>>());
        assert!(orx.try_recv().is_err(), "no duplicates");
    }

    #[test]
    fn bounded_blocks_then_unblocks() {
        let (tx, rx) = bounded(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        let tx2 = tx.clone();
        let h = thread::spawn(move || tx2.send(3)); // blocks
        thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 1);
        h.join().unwrap().unwrap();
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.recv().unwrap(), 3);
    }

    #[test]
    fn close_drains_then_errors() {
        let (tx, rx) = unbounded();
        tx.send(7).unwrap();
        tx.close();
        assert_eq!(tx.send(8), Err(SendError::Closed));
        assert_eq!(rx.recv().unwrap(), 7);
        assert_eq!(rx.recv(), Err(RecvError::Closed));
    }

    #[test]
    fn recv_timeout_times_out() {
        let (_tx, rx) = unbounded::<u32>();
        let t = Instant::now();
        assert_eq!(
            rx.recv_timeout(Duration::from_millis(30)),
            Err(RecvError::Timeout)
        );
        assert!(t.elapsed() >= Duration::from_millis(25));
    }

    #[test]
    fn recv_deadline_in_past_times_out_immediately() {
        let (tx, rx) = unbounded::<u32>();
        tx.send(5).unwrap();
        // An item is available: delivered even with an expired deadline.
        assert_eq!(rx.recv_deadline(Instant::now()).unwrap(), 5);
        let t = Instant::now();
        assert_eq!(rx.recv_deadline(t), Err(RecvError::Timeout));
        assert!(t.elapsed() < Duration::from_millis(20));
    }

    #[test]
    fn recv_timeout_gets_late_item() {
        let (tx, rx) = unbounded();
        thread::spawn(move || {
            thread::sleep(Duration::from_millis(10));
            tx.send(99).unwrap();
        });
        assert_eq!(rx.recv_timeout(Duration::from_millis(500)).unwrap(), 99);
    }

    #[test]
    fn try_recv_empty_vs_closed() {
        let (tx, rx) = unbounded::<u8>();
        assert_eq!(rx.try_recv(), Err(RecvError::Empty));
        tx.close();
        assert_eq!(rx.try_recv(), Err(RecvError::Closed));
    }
}
