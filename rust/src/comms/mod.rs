//! The message substrate (Nanomsg substitute).
//!
//! Fiber's queues and pipes are built on a high-performance asynchronous
//! message layer; offline we build our own from `std`:
//!
//! * [`chan`] — in-process MPMC blocking channels (the `inproc://` transport
//!   and the building block for pools running on the thread backend).
//! * [`frame`] — length-prefixed binary framing over any `Read`/`Write`.
//! * [`rpc`] — request/reply servers and clients over TCP (thread per
//!   connection), the transport behind distributed queues, pipes and
//!   managers when workers are real OS processes.
//!
//! Addressing is uniform: [`Addr::Inproc`] names a channel in a global
//! registry, [`Addr::Tcp`] is a socket address. Components accept an `Addr`
//! and work identically across both, which is what lets a Fiber program move
//! from multiprocessing-style local runs to distributed runs unchanged
//! (the paper's "one line of code" claim).

pub mod chan;
pub mod frame;
pub mod rpc;

pub use chan::{bounded, unbounded, Receiver, RecvError, SendError, Sender};
pub use frame::{read_frame, read_frame_into, write_frame, FrameError, MAX_FRAME};
pub use rpc::{coded_err, RemoteError, RpcClient, RpcServer, StreamReply};

use std::net::SocketAddr;

/// A transport endpoint.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Addr {
    /// In-process endpoint, named in a global registry.
    Inproc(String),
    /// TCP endpoint.
    Tcp(SocketAddr),
}

impl Addr {
    /// Parse `inproc://name` or `tcp://host:port`.
    ///
    /// The TCP form accepts both a literal socket address
    /// (`tcp://127.0.0.1:9000`) and a resolvable hostname
    /// (`tcp://localhost:9000`, `tcp://node7:9000`) — hostnames go through
    /// the system resolver, preferring an IPv4 result for a stable
    /// `Display` round-trip.
    pub fn parse(s: &str) -> anyhow::Result<Addr> {
        if let Some(name) = s.strip_prefix("inproc://") {
            anyhow::ensure!(!name.is_empty(), "empty inproc name");
            Ok(Addr::Inproc(name.to_string()))
        } else if let Some(hp) = s.strip_prefix("tcp://") {
            if let Ok(sa) = hp.parse::<SocketAddr>() {
                return Ok(Addr::Tcp(sa));
            }
            use std::net::ToSocketAddrs;
            let resolved: Vec<SocketAddr> = hp
                .to_socket_addrs()
                .map_err(|e| anyhow::anyhow!("cannot resolve {hp:?}: {e}"))?
                .collect();
            resolved
                .iter()
                .find(|sa| sa.is_ipv4())
                .or_else(|| resolved.first())
                .copied()
                .map(Addr::Tcp)
                .ok_or_else(|| anyhow::anyhow!("{hp:?} resolved to no addresses"))
        } else {
            anyhow::bail!("unrecognised address {s:?} (want inproc:// or tcp://)")
        }
    }
}

impl std::fmt::Display for Addr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Addr::Inproc(n) => write!(f, "inproc://{n}"),
            Addr::Tcp(a) => write!(f, "tcp://{a}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_parse_roundtrip() {
        let a = Addr::parse("inproc://tasks").unwrap();
        assert_eq!(a, Addr::Inproc("tasks".into()));
        assert_eq!(a.to_string(), "inproc://tasks");
        let b = Addr::parse("tcp://127.0.0.1:9000").unwrap();
        assert_eq!(b.to_string(), "tcp://127.0.0.1:9000");
    }

    #[test]
    fn addr_parse_rejects_garbage() {
        assert!(Addr::parse("http://x").is_err());
        assert!(Addr::parse("inproc://").is_err());
        assert!(Addr::parse("tcp://nonsense").is_err());
    }

    #[test]
    fn addr_parse_resolves_hostnames() {
        let a = Addr::parse("tcp://localhost:9000").unwrap();
        let Addr::Tcp(sa) = a else {
            panic!("expected a tcp addr")
        };
        assert_eq!(sa.port(), 9000);
        assert!(sa.ip().is_loopback(), "localhost must resolve to loopback, got {sa}");
    }

    #[test]
    fn addr_parse_literal_and_hostname_agree() {
        // A numeric host:port takes the literal fast path and must equal
        // the resolver's answer for the same input.
        let lit = Addr::parse("tcp://127.0.0.1:8125").unwrap();
        assert_eq!(lit, Addr::Tcp("127.0.0.1:8125".parse().unwrap()));
        // IPv6 literals still parse (bracketed form).
        let v6 = Addr::parse("tcp://[::1]:8126").unwrap();
        let Addr::Tcp(sa) = v6 else {
            panic!("expected a tcp addr")
        };
        assert_eq!(sa.port(), 8126);
        assert!(sa.is_ipv6());
    }

    #[test]
    fn addr_parse_hostname_missing_port_is_error() {
        assert!(Addr::parse("tcp://localhost").is_err());
    }
}
