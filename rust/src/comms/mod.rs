//! The message substrate (Nanomsg substitute).
//!
//! Fiber's queues and pipes are built on a high-performance asynchronous
//! message layer; offline we build our own from `std`:
//!
//! * [`chan`] — in-process MPMC blocking channels (the `inproc://` transport
//!   and the building block for pools running on the thread backend).
//! * [`frame`] — length-prefixed binary framing over any `Read`/`Write`.
//! * [`rpc`] — request/reply servers and clients over TCP (thread per
//!   connection), the transport behind distributed queues, pipes and
//!   managers when workers are real OS processes.
//!
//! Addressing is uniform: [`Addr::Inproc`] names a channel in a global
//! registry, [`Addr::Tcp`] is a socket address. Components accept an `Addr`
//! and work identically across both, which is what lets a Fiber program move
//! from multiprocessing-style local runs to distributed runs unchanged
//! (the paper's "one line of code" claim).

pub mod chan;
pub mod frame;
pub mod rpc;

pub use chan::{bounded, unbounded, Receiver, RecvError, SendError, Sender};
pub use frame::{read_frame, write_frame, FrameError, MAX_FRAME};
pub use rpc::{RpcClient, RpcServer};

use std::net::SocketAddr;

/// A transport endpoint.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub enum Addr {
    /// In-process endpoint, named in a global registry.
    Inproc(String),
    /// TCP endpoint.
    Tcp(SocketAddr),
}

impl Addr {
    /// Parse `inproc://name` or `tcp://host:port`.
    pub fn parse(s: &str) -> anyhow::Result<Addr> {
        if let Some(name) = s.strip_prefix("inproc://") {
            anyhow::ensure!(!name.is_empty(), "empty inproc name");
            Ok(Addr::Inproc(name.to_string()))
        } else if let Some(hp) = s.strip_prefix("tcp://") {
            Ok(Addr::Tcp(hp.parse()?))
        } else {
            anyhow::bail!("unrecognised address {s:?} (want inproc:// or tcp://)")
        }
    }
}

impl std::fmt::Display for Addr {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Addr::Inproc(n) => write!(f, "inproc://{n}"),
            Addr::Tcp(a) => write!(f, "tcp://{a}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_parse_roundtrip() {
        let a = Addr::parse("inproc://tasks").unwrap();
        assert_eq!(a, Addr::Inproc("tasks".into()));
        assert_eq!(a.to_string(), "inproc://tasks");
        let b = Addr::parse("tcp://127.0.0.1:9000").unwrap();
        assert_eq!(b.to_string(), "tcp://127.0.0.1:9000");
    }

    #[test]
    fn addr_parse_rejects_garbage() {
        assert!(Addr::parse("http://x").is_err());
        assert!(Addr::parse("inproc://").is_err());
        assert!(Addr::parse("tcp://nonsense").is_err());
    }
}
