//! Request/reply over TCP — the transport behind distributed queues, pipes
//! and managers when Fiber processes are real OS processes.
//!
//! A [`RpcServer`] runs one thread per connection (handlers may block — a
//! queue `GET` waits for an item, exactly like Nanomsg REP sockets serving
//! a blocking protocol). A [`RpcClient`] is a connection with exclusive
//! request/reply framing; clone-per-thread for concurrency.
//!
//! Wire format: request `[u32 tag][payload]`, reply `Result<Vec<u8>, String>`
//! in [`crate::wire`] encoding.

use std::io::BufWriter;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::comms::frame::{read_frame, write_frame, FrameError};
use crate::wire;

/// Handler invoked per request: `(tag, payload) -> Result<reply, error-msg>`.
pub type Handler = Arc<dyn Fn(u32, &[u8]) -> Result<Vec<u8>, String> + Send + Sync>;

/// A TCP request/reply server.
pub struct RpcServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept_thread: Option<JoinHandle<()>>,
}

impl RpcServer {
    /// Bind and serve. Use port 0 for an ephemeral port; read it back with
    /// [`RpcServer::local_addr`].
    pub fn bind(bind_addr: &str, handler: Handler) -> Result<Self> {
        let listener = TcpListener::bind(bind_addr).context("rpc bind")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept_thread = {
            let stop = stop.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name(format!("rpc-accept-{addr}"))
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = incoming else { continue };
                        let _ = stream.set_nodelay(true);
                        if let Ok(clone) = stream.try_clone() {
                            conns.lock().unwrap().push(clone);
                        }
                        let handler = handler.clone();
                        let stop2 = stop.clone();
                        let _ = std::thread::Builder::new()
                            .name("rpc-conn".into())
                            .spawn(move || serve_conn(stream, handler, stop2));
                    }
                })?
        };
        Ok(Self {
            addr,
            stop,
            conns,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop accepting and tear down existing connections.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        for c in self.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn serve_conn(stream: TcpStream, handler: Handler, stop: Arc<AtomicBool>) {
    let mut reader = stream.try_clone().expect("clone stream");
    let mut writer = BufWriter::new(stream);
    loop {
        let req = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(FrameError::Eof) => return,
            Err(_) => return,
        };
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if req.len() < 4 {
            return; // corrupt
        }
        let tag = u32::from_le_bytes(req[..4].try_into().unwrap());
        let reply: Result<Vec<u8>, String> = handler(tag, &req[4..]);
        let buf = wire::to_bytes(&reply);
        if write_frame(&mut writer, &buf).is_err() {
            return;
        }
    }
}

/// A client connection. `call` is synchronous; the connection carries one
/// outstanding request at a time (clone a new client per worker thread).
pub struct RpcClient {
    inner: Mutex<ClientInner>,
    addr: SocketAddr,
}

struct ClientInner {
    reader: TcpStream,
    writer: BufWriter<TcpStream>,
}

impl RpcClient {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("rpc connect {addr}"))?;
        Self::from_stream(stream, addr)
    }

    fn from_stream(stream: TcpStream, addr: SocketAddr) -> Result<Self> {
        stream.set_nodelay(true)?;
        let reader = stream.try_clone()?;
        Ok(Self {
            inner: Mutex::new(ClientInner {
                reader,
                writer: BufWriter::new(stream),
            }),
            addr,
        })
    }

    /// [`RpcClient::connect`] with a bound on the TCP connect itself.
    /// Callers probing possibly-dead endpoints (the object store walking a
    /// blob's location list) must fail over quickly rather than sit in the
    /// OS default connect timeout.
    pub fn connect_timeout(addr: SocketAddr, timeout: Duration) -> Result<Self> {
        let stream = TcpStream::connect_timeout(&addr, timeout)
            .with_context(|| format!("rpc connect {addr} (within {timeout:?})"))?;
        Self::from_stream(stream, addr)
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Bound every subsequent reply wait (deadline support for callers that
    /// must not block forever on a wedged peer — the ring data plane sets
    /// this to its collective timeout). `None` restores blocking reads.
    /// A timed-out call leaves the connection with a half-read reply, so
    /// treat timeout errors as fatal for this client and reconnect.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        let inner = self.inner.lock().unwrap();
        inner
            .reader
            .set_read_timeout(timeout)
            .context("rpc set_read_timeout")?;
        Ok(())
    }

    /// Issue a request and wait for the reply.
    pub fn call(&self, tag: u32, payload: &[u8]) -> Result<Vec<u8>> {
        let mut inner = self.inner.lock().unwrap();
        let mut req = Vec::with_capacity(4 + payload.len());
        req.extend_from_slice(&tag.to_le_bytes());
        req.extend_from_slice(payload);
        write_frame(&mut inner.writer, &req).context("rpc send")?;
        let reply = read_frame(&mut inner.reader).context("rpc recv")?;
        let result: Result<Vec<u8>, String> =
            wire::from_bytes(&reply).map_err(|e| anyhow::anyhow!("rpc decode: {e}"))?;
        result.map_err(|e| anyhow::anyhow!("rpc remote error: {e}"))
    }

    /// Typed convenience: encode `req`, decode the reply.
    pub fn call_typed<Req: wire::Encode, Resp: wire::Decode>(
        &self,
        tag: u32,
        req: &Req,
    ) -> Result<Resp> {
        let reply = self.call(tag, &wire::to_bytes(req))?;
        wire::from_bytes(&reply).map_err(|e| anyhow::anyhow!("rpc reply decode: {e}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> RpcServer {
        RpcServer::bind(
            "127.0.0.1:0",
            Arc::new(|tag, payload| {
                if tag == 99 {
                    Err("boom".to_string())
                } else {
                    let mut out = tag.to_le_bytes().to_vec();
                    out.extend_from_slice(payload);
                    Ok(out)
                }
            }),
        )
        .unwrap()
    }

    #[test]
    fn echo_roundtrip() {
        let srv = echo_server();
        let cli = RpcClient::connect(srv.local_addr()).unwrap();
        let out = cli.call(7, b"abc").unwrap();
        assert_eq!(&out[..4], &7u32.to_le_bytes());
        assert_eq!(&out[4..], b"abc");
    }

    #[test]
    fn remote_error_propagates() {
        let srv = echo_server();
        let cli = RpcClient::connect(srv.local_addr()).unwrap();
        let err = cli.call(99, b"").unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
    }

    #[test]
    fn many_sequential_calls() {
        let srv = echo_server();
        let cli = RpcClient::connect(srv.local_addr()).unwrap();
        for i in 0..500u32 {
            let tag = i + 1000; // avoid the error tag 99
            let out = cli.call(tag, &i.to_le_bytes()).unwrap();
            assert_eq!(&out[..4], &tag.to_le_bytes());
        }
    }

    #[test]
    fn concurrent_clients() {
        let srv = echo_server();
        let addr = srv.local_addr();
        let mut handles = vec![];
        for t in 0..8u32 {
            handles.push(std::thread::spawn(move || {
                let cli = RpcClient::connect(addr).unwrap();
                for i in 0..100u32 {
                    let out = cli.call(t, &i.to_le_bytes()).unwrap();
                    assert_eq!(&out[..4], &t.to_le_bytes());
                    assert_eq!(&out[4..], &i.to_le_bytes());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn shutdown_breaks_clients() {
        let srv = echo_server();
        let cli = RpcClient::connect(srv.local_addr()).unwrap();
        cli.call(1, b"x").unwrap();
        srv.shutdown();
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(cli.call(1, b"x").is_err());
    }

    #[test]
    fn read_timeout_bounds_a_wedged_server() {
        // A listener that accepts but never replies: the deadline-equipped
        // client must give up instead of blocking the collective forever.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let hold = std::thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            std::thread::sleep(std::time::Duration::from_millis(500));
            drop(conn);
        });
        let cli = RpcClient::connect(addr).unwrap();
        cli.set_read_timeout(Some(std::time::Duration::from_millis(40)))
            .unwrap();
        let t = std::time::Instant::now();
        assert!(cli.call(1, b"x").is_err());
        assert!(t.elapsed() < std::time::Duration::from_millis(400));
        hold.join().unwrap();
    }

    #[test]
    fn call_typed_roundtrip() {
        let srv = RpcServer::bind(
            "127.0.0.1:0",
            Arc::new(|_tag, payload| {
                let v: Vec<f32> = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                let s: f32 = v.iter().sum();
                Ok(wire::to_bytes(&s))
            }),
        )
        .unwrap();
        let cli = RpcClient::connect(srv.local_addr()).unwrap();
        let s: f32 = cli.call_typed(0, &vec![1.0f32, 2.0, 3.5]).unwrap();
        assert_eq!(s, 6.5);
    }
}
