//! Request/reply over TCP — the transport behind distributed queues, pipes
//! and managers when Fiber processes are real OS processes.
//!
//! A [`RpcServer`] runs one thread per connection (handlers may block — a
//! queue `GET` waits for an item, exactly like Nanomsg REP sockets serving
//! a blocking protocol). A [`RpcClient`] is a connection with exclusive
//! request/reply framing; clone-per-thread for concurrency.
//!
//! Wire format: request `[u32 tag][payload]`, reply `Result<Vec<u8>, String>`
//! in [`crate::wire`] encoding.

use std::io::BufWriter;
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::comms::frame::{read_frame, read_frame_into, write_frame, FrameError};
use crate::wire;

/// Handler invoked per request: `(tag, payload) -> Result<reply, error-msg>`.
pub type Handler = Arc<dyn Fn(u32, &[u8]) -> Result<Vec<u8>, String> + Send + Sync>;

/// An error a server handler raised, as seen by the calling client. The
/// reply wire format carries only a `String`, so machine-readable codes
/// travel as a parseable prefix (see [`coded_err`]); `call` strips the
/// prefix back out and exposes it here. Callers branch on [`RemoteError::code`]
/// instead of substring-matching the human text — `anyhow` chains preserve
/// this type, so `err.downcast_ref::<RemoteError>()` (or walking
/// `err.chain()`) recovers it.
#[derive(Debug, Clone, thiserror::Error)]
#[error("rpc remote error: {msg}")]
pub struct RemoteError {
    /// Protocol-defined error code, when the handler attached one.
    pub code: Option<u32>,
    /// Human-readable message (code prefix already stripped).
    pub msg: String,
}

/// Prefix marking a coded error message: `"[e#{code}] {msg}"`.
const CODE_PREFIX: &str = "[e#";

/// Format a handler error that carries a machine-readable `code` across
/// the string-typed reply channel. The peer's `call` parses it back into
/// a [`RemoteError`] with `code: Some(code)`.
pub fn coded_err(code: u32, msg: impl std::fmt::Display) -> String {
    format!("{CODE_PREFIX}{code}] {msg}")
}

impl RemoteError {
    /// Parse a wire error string, splitting off a [`coded_err`] prefix.
    fn parse(wire_msg: String) -> RemoteError {
        if let Some(rest) = wire_msg.strip_prefix(CODE_PREFIX) {
            if let Some((num, msg)) = rest.split_once("] ") {
                if let Ok(code) = num.parse::<u32>() {
                    return RemoteError {
                        code: Some(code),
                        msg: msg.to_string(),
                    };
                }
            }
        }
        RemoteError {
            code: None,
            msg: wire_msg,
        }
    }
}

/// A streaming reply (see [`RpcServer::bind_streaming`]): the `header`
/// travels as the ordinary reply frame; when it is `Ok`, `body` then emits
/// zero or more **raw** frames back-to-back on the same connection. The
/// in-flight window is bounded by the socket send buffer — the server's
/// blocking writes stall when the reader lags, so a slow client applies
/// backpressure instead of ballooning server memory.
pub struct StreamReply {
    pub header: Result<Vec<u8>, String>,
    #[allow(clippy::type_complexity)]
    pub body: Option<
        Box<dyn FnOnce(&mut dyn FnMut(&[u8]) -> Result<(), FrameError>) -> Result<(), FrameError> + Send>,
    >,
}

impl StreamReply {
    /// A header-only error reply (no body frames follow).
    pub fn err(msg: String) -> StreamReply {
        StreamReply {
            header: Err(msg),
            body: None,
        }
    }
}

/// Handler for streaming verbs: return `None` to decline the tag (the
/// ordinary [`Handler`] then serves it), `Some` to take over the reply.
pub type StreamHandler = Arc<dyn Fn(u32, &[u8]) -> Option<StreamReply> + Send + Sync>;

/// A TCP request/reply server.
pub struct RpcServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    conns: Arc<Mutex<Vec<TcpStream>>>,
    accept_thread: Option<JoinHandle<()>>,
}

impl RpcServer {
    /// Bind and serve. Use port 0 for an ephemeral port; read it back with
    /// [`RpcServer::local_addr`].
    pub fn bind(bind_addr: &str, handler: Handler) -> Result<Self> {
        Self::bind_streaming(bind_addr, handler, Arc::new(|_, _| None))
    }

    /// [`RpcServer::bind`] with a [`StreamHandler`] consulted first for
    /// every request: a `Some` reply writes the header frame and then the
    /// body's raw frames pipelined on the same connection (the client
    /// reads them with [`RpcClient::call_streamed`]); `None` falls through
    /// to the ordinary call/response `handler`.
    pub fn bind_streaming(
        bind_addr: &str,
        handler: Handler,
        stream_handler: StreamHandler,
    ) -> Result<Self> {
        let listener = TcpListener::bind(bind_addr).context("rpc bind")?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let accept_thread = {
            let stop = stop.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name(format!("rpc-accept-{addr}"))
                .spawn(move || {
                    for incoming in listener.incoming() {
                        if stop.load(Ordering::SeqCst) {
                            break;
                        }
                        let Ok(stream) = incoming else { continue };
                        let _ = stream.set_nodelay(true);
                        if let Ok(clone) = stream.try_clone() {
                            conns.lock().unwrap().push(clone);
                        }
                        let handler = handler.clone();
                        let stream_handler = stream_handler.clone();
                        let stop2 = stop.clone();
                        let _ = std::thread::Builder::new()
                            .name("rpc-conn".into())
                            .spawn(move || serve_conn(stream, handler, stream_handler, stop2));
                    }
                })?
        };
        Ok(Self {
            addr,
            stop,
            conns,
            accept_thread: Some(accept_thread),
        })
    }

    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections accepted over this server's lifetime (they are tracked
    /// for shutdown and never forgotten). Tests use this to prove a whole
    /// blob streamed over **one** connection rather than per-chunk dials.
    pub fn connections(&self) -> usize {
        self.conns.lock().unwrap().len()
    }

    /// Stop accepting and tear down existing connections.
    pub fn shutdown(&self) {
        self.stop.store(true, Ordering::SeqCst);
        // Unblock the accept loop with a dummy connection.
        let _ = TcpStream::connect(self.addr);
        for c in self.conns.lock().unwrap().drain(..) {
            let _ = c.shutdown(std::net::Shutdown::Both);
        }
    }
}

impl Drop for RpcServer {
    fn drop(&mut self) {
        self.shutdown();
        if let Some(h) = self.accept_thread.take() {
            let _ = h.join();
        }
    }
}

fn serve_conn(
    stream: TcpStream,
    handler: Handler,
    stream_handler: StreamHandler,
    stop: Arc<AtomicBool>,
) {
    let mut reader = stream.try_clone().expect("clone stream");
    let mut writer = BufWriter::new(stream);
    loop {
        let req = match read_frame(&mut reader) {
            Ok(f) => f,
            Err(FrameError::Eof) => return,
            Err(_) => return,
        };
        if stop.load(Ordering::SeqCst) {
            return;
        }
        if req.len() < 4 {
            return; // corrupt
        }
        let tag = u32::from_le_bytes(req[..4].try_into().unwrap());
        if let Some(sr) = stream_handler(tag, &req[4..]) {
            let ok = sr.header.is_ok();
            let buf = wire::to_bytes(&sr.header);
            if write_frame(&mut writer, &buf).is_err() {
                return;
            }
            // Body frames follow the header only on success — an error
            // header leaves the connection at a clean request boundary.
            if ok {
                if let Some(body) = sr.body {
                    let mut emit =
                        |payload: &[u8]| write_frame(&mut writer, payload);
                    if body(&mut emit).is_err() {
                        return;
                    }
                }
            }
            continue;
        }
        let reply: Result<Vec<u8>, String> = handler(tag, &req[4..]);
        let buf = wire::to_bytes(&reply);
        if write_frame(&mut writer, &buf).is_err() {
            return;
        }
    }
}

/// A client connection. `call` is synchronous; the connection carries one
/// outstanding request at a time (clone a new client per worker thread).
pub struct RpcClient {
    inner: Mutex<ClientInner>,
    addr: SocketAddr,
}

struct ClientInner {
    reader: TcpStream,
    writer: BufWriter<TcpStream>,
}

impl RpcClient {
    pub fn connect(addr: SocketAddr) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("rpc connect {addr}"))?;
        Self::from_stream(stream, addr)
    }

    fn from_stream(stream: TcpStream, addr: SocketAddr) -> Result<Self> {
        stream.set_nodelay(true)?;
        let reader = stream.try_clone()?;
        Ok(Self {
            inner: Mutex::new(ClientInner {
                reader,
                writer: BufWriter::new(stream),
            }),
            addr,
        })
    }

    /// [`RpcClient::connect`] with a bound on the TCP connect itself.
    /// Callers probing possibly-dead endpoints (the object store walking a
    /// blob's location list) must fail over quickly rather than sit in the
    /// OS default connect timeout.
    pub fn connect_timeout(addr: SocketAddr, timeout: Duration) -> Result<Self> {
        let stream = TcpStream::connect_timeout(&addr, timeout)
            .with_context(|| format!("rpc connect {addr} (within {timeout:?})"))?;
        Self::from_stream(stream, addr)
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Bound every subsequent reply wait (deadline support for callers that
    /// must not block forever on a wedged peer — the ring data plane sets
    /// this to its collective timeout). `None` restores blocking reads.
    /// A timed-out call leaves the connection with a half-read reply, so
    /// treat timeout errors as fatal for this client and reconnect.
    pub fn set_read_timeout(&self, timeout: Option<Duration>) -> Result<()> {
        let inner = self.inner.lock().unwrap();
        inner
            .reader
            .set_read_timeout(timeout)
            .context("rpc set_read_timeout")?;
        Ok(())
    }

    /// Issue a request and wait for the reply. A remote handler error
    /// comes back as a typed [`RemoteError`] in the chain (carrying its
    /// code when the handler used [`coded_err`]).
    pub fn call(&self, tag: u32, payload: &[u8]) -> Result<Vec<u8>> {
        let mut inner = self.inner.lock().unwrap();
        let mut req = Vec::with_capacity(4 + payload.len());
        req.extend_from_slice(&tag.to_le_bytes());
        req.extend_from_slice(payload);
        write_frame(&mut inner.writer, &req).context("rpc send")?;
        let reply = read_frame(&mut inner.reader).context("rpc recv")?;
        let result: Result<Vec<u8>, String> =
            wire::from_bytes(&reply).map_err(|e| anyhow::anyhow!("rpc decode: {e}"))?;
        result.map_err(|e| anyhow::Error::new(RemoteError::parse(e)))
    }

    /// Issue a request whose reply is a header frame followed by pipelined
    /// raw body frames (a [`StreamReply`] on the server side). Holds the
    /// connection exclusively for the whole stream; `f` receives the
    /// decoded `Ok` header and a [`FrameStream`] to pull body frames from.
    /// An `Err` header returns a [`RemoteError`] without invoking `f` (no
    /// body frames follow an error). If `f` fails mid-stream the
    /// connection holds unread frames and must be discarded — callers that
    /// cache clients (the store's peer map) drop the client on any error.
    pub fn call_streamed<T>(
        &self,
        tag: u32,
        payload: &[u8],
        f: impl FnOnce(&[u8], &mut FrameStream<'_>) -> Result<T>,
    ) -> Result<T> {
        let mut inner = self.inner.lock().unwrap();
        let mut req = Vec::with_capacity(4 + payload.len());
        req.extend_from_slice(&tag.to_le_bytes());
        req.extend_from_slice(payload);
        write_frame(&mut inner.writer, &req).context("rpc send")?;
        let reply = read_frame(&mut inner.reader).context("rpc recv")?;
        let header: Result<Vec<u8>, String> =
            wire::from_bytes(&reply).map_err(|e| anyhow::anyhow!("rpc decode: {e}"))?;
        let header = header.map_err(|e| anyhow::Error::new(RemoteError::parse(e)))?;
        f(&header, &mut FrameStream {
            reader: &mut inner.reader,
        })
    }

    /// Typed convenience: encode `req`, decode the reply.
    pub fn call_typed<Req: wire::Encode, Resp: wire::Decode>(
        &self,
        tag: u32,
        req: &Req,
    ) -> Result<Resp> {
        let reply = self.call(tag, &wire::to_bytes(req))?;
        wire::from_bytes(&reply).map_err(|e| anyhow::anyhow!("rpc reply decode: {e}"))
    }
}

/// The body half of a streamed reply, handed to the `call_streamed`
/// closure: pulls raw frames off the (exclusively held) connection.
pub struct FrameStream<'a> {
    reader: &'a mut TcpStream,
}

impl FrameStream<'_> {
    /// Read the next body frame into `buf` (no allocation); returns its
    /// length. Frames larger than `buf` error — the caller sized `buf`
    /// from the header, so an oversize frame is a protocol violation.
    pub fn next_into(&mut self, buf: &mut [u8]) -> Result<usize> {
        read_frame_into(self.reader, buf).context("rpc stream recv")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> RpcServer {
        RpcServer::bind(
            "127.0.0.1:0",
            Arc::new(|tag, payload| {
                if tag == 99 {
                    Err("boom".to_string())
                } else {
                    let mut out = tag.to_le_bytes().to_vec();
                    out.extend_from_slice(payload);
                    Ok(out)
                }
            }),
        )
        .unwrap()
    }

    #[test]
    fn echo_roundtrip() {
        let srv = echo_server();
        let cli = RpcClient::connect(srv.local_addr()).unwrap();
        let out = cli.call(7, b"abc").unwrap();
        assert_eq!(&out[..4], &7u32.to_le_bytes());
        assert_eq!(&out[4..], b"abc");
    }

    #[test]
    fn remote_error_propagates() {
        let srv = echo_server();
        let cli = RpcClient::connect(srv.local_addr()).unwrap();
        let err = cli.call(99, b"").unwrap_err();
        assert!(err.to_string().contains("boom"), "{err}");
    }

    #[test]
    fn many_sequential_calls() {
        let srv = echo_server();
        let cli = RpcClient::connect(srv.local_addr()).unwrap();
        for i in 0..500u32 {
            let tag = i + 1000; // avoid the error tag 99
            let out = cli.call(tag, &i.to_le_bytes()).unwrap();
            assert_eq!(&out[..4], &tag.to_le_bytes());
        }
    }

    #[test]
    fn concurrent_clients() {
        let srv = echo_server();
        let addr = srv.local_addr();
        let mut handles = vec![];
        for t in 0..8u32 {
            handles.push(std::thread::spawn(move || {
                let cli = RpcClient::connect(addr).unwrap();
                for i in 0..100u32 {
                    let out = cli.call(t, &i.to_le_bytes()).unwrap();
                    assert_eq!(&out[..4], &t.to_le_bytes());
                    assert_eq!(&out[4..], &i.to_le_bytes());
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn shutdown_breaks_clients() {
        let srv = echo_server();
        let cli = RpcClient::connect(srv.local_addr()).unwrap();
        cli.call(1, b"x").unwrap();
        srv.shutdown();
        std::thread::sleep(std::time::Duration::from_millis(30));
        assert!(cli.call(1, b"x").is_err());
    }

    #[test]
    fn read_timeout_bounds_a_wedged_server() {
        // A listener that accepts but never replies: the deadline-equipped
        // client must give up instead of blocking the collective forever.
        let listener = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap();
        let hold = std::thread::spawn(move || {
            let (conn, _) = listener.accept().unwrap();
            std::thread::sleep(std::time::Duration::from_millis(500));
            drop(conn);
        });
        let cli = RpcClient::connect(addr).unwrap();
        cli.set_read_timeout(Some(std::time::Duration::from_millis(40)))
            .unwrap();
        let t = std::time::Instant::now();
        assert!(cli.call(1, b"x").is_err());
        assert!(t.elapsed() < std::time::Duration::from_millis(400));
        hold.join().unwrap();
    }

    /// A streaming server: tag 1 streams `count` frames of `frame_len`
    /// bytes (both read from the request), tag 2 declines (falls through
    /// to the plain handler), tag 3 errors with a code.
    fn stream_server() -> RpcServer {
        RpcServer::bind_streaming(
            "127.0.0.1:0",
            Arc::new(|tag, _| Ok(tag.to_le_bytes().to_vec())),
            Arc::new(|tag, payload| match tag {
                1 => {
                    let count = payload[0] as usize;
                    let frame_len = payload[1] as usize;
                    Some(StreamReply {
                        header: Ok((count as u32).to_le_bytes().to_vec()),
                        body: Some(Box::new(move |emit| {
                            for i in 0..count {
                                emit(&vec![i as u8; frame_len])?;
                            }
                            Ok(())
                        })),
                    })
                }
                3 => Some(StreamReply::err(coded_err(42, "not here"))),
                _ => None,
            }),
        )
        .unwrap()
    }

    #[test]
    fn streamed_reply_pipelines_frames() {
        let srv = stream_server();
        let cli = RpcClient::connect(srv.local_addr()).unwrap();
        let frames = cli
            .call_streamed(1, &[4, 9], |header, stream| {
                let n = u32::from_le_bytes(header.try_into().unwrap());
                let mut got = Vec::new();
                let mut buf = [0u8; 16];
                for _ in 0..n {
                    let len = stream.next_into(&mut buf)?;
                    got.push(buf[..len].to_vec());
                }
                Ok(got)
            })
            .unwrap();
        assert_eq!(frames.len(), 4);
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(f, &vec![i as u8; 9]);
        }
        // The connection is clean after a fully-drained stream: an
        // ordinary call on the same client still works.
        assert_eq!(cli.call(7, b"").unwrap(), 7u32.to_le_bytes().to_vec());
        // Declined tags fall through to the plain handler.
        assert_eq!(cli.call(2, b"").unwrap(), 2u32.to_le_bytes().to_vec());
        assert_eq!(srv.connections(), 1, "everything rode one connection");
    }

    #[test]
    fn coded_error_roundtrips_typed() {
        let srv = stream_server();
        let cli = RpcClient::connect(srv.local_addr()).unwrap();
        let err = cli
            .call_streamed(3, b"", |_h, _s| Ok(()))
            .unwrap_err();
        let remote = err
            .downcast_ref::<RemoteError>()
            .expect("RemoteError in chain");
        assert_eq!(remote.code, Some(42));
        assert_eq!(remote.msg, "not here");
        // Uncoded errors parse with code: None and keep their text.
        let plain = RemoteError::parse("boom".into());
        assert_eq!(plain.code, None);
        assert_eq!(plain.msg, "boom");
        // Malformed prefixes degrade to uncoded, never panic.
        let odd = RemoteError::parse("[e#zzz] x".into());
        assert_eq!(odd.code, None);
    }

    #[test]
    fn call_typed_roundtrip() {
        let srv = RpcServer::bind(
            "127.0.0.1:0",
            Arc::new(|_tag, payload| {
                let v: Vec<f32> = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                let s: f32 = v.iter().sum();
                Ok(wire::to_bytes(&s))
            }),
        )
        .unwrap();
        let cli = RpcClient::connect(srv.local_addr()).unwrap();
        let s: f32 = cli.call_typed(0, &vec![1.0f32, 2.0, 3.5]).unwrap();
        assert_eq!(s, 6.5);
    }
}
