//! Length-prefixed binary framing.
//!
//! Every message on a TCP transport is `[u32-LE length][payload]`. A frame
//! cap guards against corrupt prefixes. This is deliberately the same cost
//! structure as Nanomsg's SP framing: one small header, one copy, one
//! syscall per message — the overheads the Fig 3a experiment measures.

use std::io::{Read, Write};

/// Maximum frame payload (64 MiB) — larger means a corrupt stream.
pub const MAX_FRAME: usize = 64 << 20;

/// Framing errors.
#[derive(Debug, thiserror::Error)]
pub enum FrameError {
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
    #[error("frame of {0} bytes exceeds MAX_FRAME")]
    TooBig(usize),
    #[error("peer closed the connection")]
    Eof,
}

/// Write one frame.
pub fn write_frame<W: Write>(w: &mut W, payload: &[u8]) -> Result<(), FrameError> {
    if payload.len() > MAX_FRAME {
        return Err(FrameError::TooBig(payload.len()));
    }
    w.write_all(&(payload.len() as u32).to_le_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

/// Read one frame. Returns `FrameError::Eof` on a clean close at a frame
/// boundary.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Vec<u8>, FrameError> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Err(FrameError::Eof),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME {
        return Err(FrameError::TooBig(len));
    }
    let mut payload = vec![0u8; len];
    r.read_exact(&mut payload)?;
    Ok(payload)
}

/// Read one frame straight into `buf` — the allocation-free mirror of
/// [`read_frame`] for receivers that pre-sized a destination from protocol
/// metadata (the streaming blob fetch reads each chunk into its final
/// slice of one big buffer). Returns the payload length. A frame larger
/// than `buf` is a protocol violation and errors `TooBig` without
/// consuming the payload, so the connection must be discarded.
pub fn read_frame_into<R: Read>(r: &mut R, buf: &mut [u8]) -> Result<usize, FrameError> {
    let mut len_buf = [0u8; 4];
    match r.read_exact(&mut len_buf) {
        Ok(()) => {}
        Err(e) if e.kind() == std::io::ErrorKind::UnexpectedEof => return Err(FrameError::Eof),
        Err(e) => return Err(e.into()),
    }
    let len = u32::from_le_bytes(len_buf) as usize;
    if len > MAX_FRAME || len > buf.len() {
        return Err(FrameError::TooBig(len));
    }
    r.read_exact(&mut buf[..len])?;
    Ok(len)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_frames() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        write_frame(&mut buf, &[9u8; 1000]).unwrap();
        let mut cur = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut cur).unwrap(), b"hello");
        assert_eq!(read_frame(&mut cur).unwrap(), b"");
        assert_eq!(read_frame(&mut cur).unwrap(), vec![9u8; 1000]);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Eof)));
    }

    #[test]
    fn oversize_rejected_on_write() {
        struct NullW;
        impl Write for NullW {
            fn write(&mut self, b: &[u8]) -> std::io::Result<usize> {
                Ok(b.len())
            }
            fn flush(&mut self) -> std::io::Result<()> {
                Ok(())
            }
        }
        // Don't allocate 64MiB+1 for real; use a zero-len slice trick is not
        // possible, so just exercise the length check with a modest cap test
        // via read path below.
        let _ = NullW; // silence
    }

    #[test]
    fn oversize_rejected_on_read() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&(u32::MAX).to_le_bytes());
        let mut cur = std::io::Cursor::new(buf);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::TooBig(_))));
    }

    #[test]
    fn read_into_fills_prefix_and_rejects_oversize() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"abc").unwrap();
        write_frame(&mut buf, b"toolong").unwrap();
        let mut cur = std::io::Cursor::new(buf);
        let mut dst = [0u8; 5];
        assert_eq!(read_frame_into(&mut cur, &mut dst).unwrap(), 3);
        assert_eq!(&dst[..3], b"abc");
        // 7-byte frame into a 5-byte buffer: protocol violation.
        assert!(matches!(
            read_frame_into(&mut cur, &mut dst),
            Err(FrameError::TooBig(7))
        ));
        // Clean close at a boundary is Eof, same as read_frame.
        let mut empty = std::io::Cursor::new(Vec::new());
        assert!(matches!(
            read_frame_into(&mut empty, &mut dst),
            Err(FrameError::Eof)
        ));
    }

    #[test]
    fn truncated_payload_is_io_error() {
        let mut buf = Vec::new();
        buf.extend_from_slice(&10u32.to_le_bytes());
        buf.extend_from_slice(b"abc"); // 3 of 10 bytes
        let mut cur = std::io::Cursor::new(buf);
        assert!(matches!(read_frame(&mut cur), Err(FrameError::Io(_))));
    }
}
