//! The spare pool: standby members that let a healed ring **grow back**.
//!
//! Healing ([`super::topology::Rendezvous::report_dead`]) only shrinks a
//! ring: the dead member is excised and the survivors re-rank into a
//! smaller sealed generation. The spare pool closes the other half of the
//! elasticity loop. A standby process registers as a **spare** — pending,
//! not ranked, exactly like a pool worker sitting in the coordinator's
//! pending table — and heartbeats while it waits. When the ring next
//! changes membership (a heal, or an explicit
//! [`super::topology::Rendezvous::grow`] request), the rendezvous *drains*
//! the live spares into the new sealed generation: survivors keep their
//! relative order in the low ranks, drained spares are appended after
//! them, and the generation seals immediately.
//!
//! ## Rejoining an in-flight collective
//!
//! The subtle part is that a heal usually happens **mid-collective**. The
//! survivors agree where to resume through the `resume_poll` min-barrier;
//! with spares in play, each survivor's barrier report also carries an
//! [`OpDesc`] describing the interrupted operation — its op-sequence
//! number, collective kind, buffer length, broadcast root and the
//! caller-supplied *op note* (an algorithm-level program counter, see
//! [`super::RingMember::set_op_note`]). The barrier is **op-aware**: it
//! releases the most-advanced reported op and the minimum completed
//! chunk among the members driving it, so a membership change landing
//! exactly on a collective boundary (an explicit grow racing one
//! member's final bookkeeping, say) tells the member that already
//! finished the superseded op to move on rather than rolling it back
//! into an op its peers have left. The drained spare reads the completed
//! barrier through `resume_observe` — which also promotes it from
//! *observer* to *participant*, so later heals wait for its report — and
//! receives a [`ColdStart`]: the chunk index the collective resumes from
//! plus the `OpDesc`. Its first matching collective call adopts the op
//! (same message tags as the survivors, resuming at the barrier minimum)
//! and participates as a **neutral relay** — it contributes the op's
//! identity element (zeros for a sum, pass-through for a broadcast), so
//! the survivors' results are exactly what a plain heal would have
//! produced, while the ring topology already includes the rejoiner.
//!
//! A freshly drained member is **cold**: its local output for chunks the
//! survivors had already banked is unset, and it holds none of the
//! algorithm's iteration state. Warm-up is the algorithm layer's job —
//! [`crate::algo::es::EsRingNode::join_ring_as_spare`] relays the
//! interrupted op, follows the survivors through the rest of the
//! iteration (steered by the op note), and then receives a state-sync
//! broadcast; the ES noise table is recovered through the object store as
//! a cache hit ([`crate::store`]), never a re-stream.
//!
//! ```
//! use std::time::Duration;
//! use fiber::ring::{Rendezvous, RingMember};
//!
//! // A 2-ring forms; a spare stands by; rank 0 requests an explicit grow.
//! let rv = Rendezvous::inproc("spare-doc", 2);
//! rv.set_heartbeat_grace(Duration::from_millis(50));
//! let spare_rv = rv.clone();
//! let standby = std::thread::spawn(move || {
//!     let mut m = RingMember::join_spare_inproc(&spare_rv, Duration::from_secs(10)).unwrap();
//!     // Admitted mid-op: relay the collective the survivors are running.
//!     let cold = m.cold_op().cloned().unwrap();
//!     let mut buf = vec![0.0f32; cold.op.elems as usize];
//!     m.allreduce_sum(&mut buf).unwrap();
//!     m.world()
//! });
//! let members: Vec<_> = (0..2)
//!     .map(|_| {
//!         let rv = rv.clone();
//!         std::thread::spawn(move || {
//!             let mut m = RingMember::join_inproc(&rv).unwrap();
//!             if m.rank() == 0 {
//!                 // Collective-boundary grow: drafts the pending spare.
//!                 while !m.request_grow().unwrap() {
//!                     std::thread::sleep(Duration::from_millis(2));
//!                 }
//!             }
//!             let mut buf = vec![1.0f32; 64];
//!             m.allreduce_sum(&mut buf).unwrap();
//!             (m.world(), buf[0])
//!         })
//!     })
//!     .collect();
//! for t in members {
//!     let (world, v) = t.join().unwrap();
//!     assert_eq!(world, 3, "the ring grew back");
//!     assert_eq!(v, 2.0, "spare contributed the sum's identity element");
//! }
//! assert_eq!(standby.join().unwrap(), 3);
//! Rendezvous::unpublish("spare-doc");
//! ```

use crate::wire::{self, Decode, Encode};

/// [`OpDesc::kind`] for a chunked ring allreduce.
pub const KIND_ALLREDUCE: u8 = 0;
/// [`OpDesc::kind`] for a pipelined ring broadcast.
pub const KIND_BROADCAST: u8 = 1;

/// Description of an in-flight collective, carried through the resume
/// min-barrier so a drained spare can adopt it (same message tags, same
/// chunk plan) instead of wedging the survivors' resumed traffic.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct OpDesc {
    /// The survivors' op-sequence number for the interrupted collective.
    /// The rejoiner aligns its own sequence to this, so every later
    /// collective also agrees on message tags.
    pub op_seq: u64,
    /// [`KIND_ALLREDUCE`] or [`KIND_BROADCAST`].
    pub kind: u8,
    /// Buffer length of the collective, in `f32` elements. The rejoiner's
    /// first collective call must match it exactly (SPMD).
    pub elems: u64,
    /// Root data endpoint for a broadcast (empty for allreduce). Endpoint,
    /// not rank: ranks renumber across heals, endpoints do not.
    pub root: String,
    /// The algorithm-level program counter the survivors attached via
    /// [`super::RingMember::set_op_note`] — e.g. which phase of an ES
    /// iteration (or which minibatch of a PPO epoch schedule) the
    /// interrupted collective belongs to, so the rejoiner knows which
    /// collectives remain before the state sync.
    pub note: u64,
}

impl Encode for OpDesc {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.op_seq.encode(buf);
        self.kind.encode(buf);
        self.elems.encode(buf);
        self.root.encode(buf);
        self.note.encode(buf);
    }
}

impl Decode for OpDesc {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        Ok(OpDesc {
            op_seq: u64::decode(r)?,
            kind: u8::decode(r)?,
            elems: u64::decode(r)?,
            root: String::decode(r)?,
            note: u64::decode(r)?,
        })
    }
}

/// What a drained spare learns from the completed resume barrier: the
/// chunk index the interrupted collective resumes from (the survivors'
/// minimum) and the [`OpDesc`] to adopt. Held by the member until its
/// first collective call consumes it (see
/// [`super::RingMember::cold_op`]).
#[derive(Clone, Debug)]
pub struct ColdStart {
    /// First chunk the resumed collective will execute. Chunks below this
    /// index were banked by the survivors; the rejoiner's local buffer
    /// for them is left untouched (unset — cold).
    pub resume_chunk: u64,
    /// The interrupted operation.
    pub op: OpDesc,
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ring::topology::Rendezvous;
    use std::time::Duration;

    #[test]
    fn opdesc_roundtrips_wire() {
        let d = OpDesc {
            op_seq: 17,
            kind: KIND_BROADCAST,
            elems: 4096,
            root: "tcp://127.0.0.1:9000".into(),
            note: 0xA5,
        };
        let bytes = wire::to_bytes(&d);
        let back: OpDesc = wire::from_bytes(&bytes).unwrap();
        assert_eq!(back, d);
    }

    // ---- the spare-registration table ----------------------------------

    #[test]
    fn spare_joins_mid_generation_and_stays_pending_until_next_seal() {
        let rv = Rendezvous::new(2);
        rv.set_heartbeat_grace(Duration::from_millis(30));
        rv.register("inproc://a");
        rv.register("inproc://b");
        assert!(rv.membership().sealed);
        // A spare registering against a sealed generation does NOT bump it.
        rv.register_spare("inproc://s");
        let m = rv.membership();
        assert_eq!(m.generation, 0, "spare registration must not re-rendezvous");
        assert_eq!(m.members.len(), 2);
        assert_eq!(rv.spares(), vec!["inproc://s".to_string()]);
        // The next seal — here a heal — drains it in, appended after the
        // survivors, stamped with the generation it entered.
        std::thread::sleep(Duration::from_millis(40));
        rv.heartbeat("inproc://s");
        assert!(rv.report_dead(0, 0));
        let m = rv.membership();
        assert_eq!(m.generation, 1);
        assert!(m.sealed);
        let addrs: Vec<_> = m.members.iter().map(|i| i.addr.as_str()).collect();
        assert_eq!(addrs, vec!["inproc://b", "inproc://s"]);
        assert_eq!(m.members[0].since, 0, "survivors keep their entry generation");
        assert_eq!(
            m.members[1].since,
            1,
            "the drained spare is stamped with the healed generation"
        );
        assert!(rv.spares().is_empty(), "drained spares leave the pending table");
    }

    #[test]
    fn stale_spare_is_excised_without_a_generation_bump() {
        let rv = Rendezvous::new(2);
        rv.set_heartbeat_grace(Duration::from_millis(20));
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.register_spare("inproc://dead");
        rv.register_spare("inproc://live");
        std::thread::sleep(Duration::from_millis(30));
        rv.heartbeat("inproc://live"); // only one spare is still breathing
        let before = rv.membership().generation;
        assert_eq!(rv.spares(), vec!["inproc://live".to_string()]);
        assert_eq!(
            rv.membership().generation,
            before,
            "pruning a dead spare must not re-rendezvous the ring"
        );
        // An explicit grow drafts only the live spare.
        assert!(rv.grow(before));
        let m = rv.membership();
        let addrs: Vec<_> = m.members.iter().map(|i| i.addr.as_str()).collect();
        assert_eq!(addrs, vec!["inproc://a", "inproc://b", "inproc://live"]);
    }

    #[test]
    fn grow_with_no_live_spares_is_a_no_op() {
        let rv = Rendezvous::new(2);
        rv.set_heartbeat_grace(Duration::from_millis(20));
        rv.register("inproc://a");
        rv.register("inproc://b");
        assert!(!rv.grow(0), "no spares: nothing to grow into");
        rv.register_spare("inproc://stale");
        std::thread::sleep(Duration::from_millis(30));
        assert!(!rv.grow(0), "a stale spare must not be drafted");
        assert_eq!(rv.membership().generation, 0);
        // Stale reports against the wrong generation are rejected too.
        assert!(!rv.grow(7));
    }

    #[test]
    fn grow_opens_a_resume_barrier_for_the_pre_grow_members() {
        let rv = Rendezvous::new(2);
        rv.set_heartbeat_grace(Duration::from_millis(30));
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.register_spare("inproc://s");
        rv.heartbeat("inproc://s");
        assert!(rv.grow(0));
        let m = rv.membership();
        assert_eq!((m.generation, m.members.len()), (1, 3));
        // The two pre-grow members report (completed = 0 at an op start);
        // the spare observes without reporting.
        let desc = OpDesc {
            op_seq: 3,
            kind: KIND_ALLREDUCE,
            elems: 64,
            ..OpDesc::default()
        };
        assert_eq!(rv.resume_observe(1, 2), None, "barrier must wait for the members");
        assert_eq!(rv.resume_poll(1, 0, 0, &desc), None);
        assert_eq!(rv.resume_poll(1, 1, 0, &desc), Some((3, 0)));
        let (min, op) = rv
            .resume_observe(1, 2)
            .expect("observer sees the completed barrier");
        assert_eq!(min, 0);
        assert_eq!(op, desc);
    }

    #[test]
    fn heal_barrier_carries_the_interrupted_op_to_the_observer() {
        let rv = Rendezvous::new(3);
        rv.set_heartbeat_grace(Duration::from_millis(1));
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.register("inproc://c");
        rv.register_spare("inproc://s");
        std::thread::sleep(Duration::from_millis(5));
        rv.heartbeat("inproc://s");
        assert!(rv.report_dead(0, 2));
        assert_eq!(rv.membership().members.len(), 3, "healed straight back to world 3");
        let desc = OpDesc {
            op_seq: 9,
            kind: KIND_ALLREDUCE,
            elems: 35,
            note: 2,
            ..OpDesc::default()
        };
        // Only the two *survivors* report; the drained spare observes.
        assert_eq!(rv.resume_poll(1, 0, 4, &desc), None);
        assert_eq!(rv.resume_observe(1, 2), None);
        assert_eq!(rv.resume_poll(1, 1, 2, &desc), Some((9, 2)));
        assert_eq!(rv.resume_observe(1, 2), Some((2, desc)));
    }

    #[test]
    fn boundary_skewed_barrier_resumes_the_most_advanced_op() {
        // An explicit grow can land between two collectives: one member
        // observes the bump at the *tail* of op N (fully complete), the
        // other at the *start* of op N+1. The barrier must release the
        // most-advanced op — never roll the finished member back into an
        // op its peer has left behind.
        let rv = Rendezvous::new(2);
        rv.set_heartbeat_grace(Duration::from_millis(30));
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.register_spare("inproc://s");
        rv.heartbeat("inproc://s");
        assert!(rv.grow(0));
        let done_n = OpDesc {
            op_seq: 4,
            kind: KIND_ALLREDUCE,
            elems: 32,
            ..OpDesc::default()
        };
        let starting_n1 = OpDesc {
            op_seq: 5,
            kind: KIND_ALLREDUCE,
            elems: 48,
            ..OpDesc::default()
        };
        // Rank 1 finished op 4 (all 8 chunks); rank 0 is entering op 5.
        assert_eq!(rv.resume_poll(1, 1, 8, &done_n), None);
        let got = rv.resume_poll(1, 0, 0, &starting_n1);
        assert_eq!(got, Some((5, 0)), "resume must name op 5 at chunk 0");
        // The observer adopts the op-5 descriptor, not the stale op 4.
        assert_eq!(rv.resume_observe(1, 2), Some((0, starting_n1)));
    }

    #[test]
    fn second_heal_does_not_require_a_report_from_a_still_observing_spare() {
        // Regression: a spare drained at generation 1 that has not yet
        // adopted (its admission barrier is still forming) must not be a
        // required reporter of a generation-2 barrier — it has nothing to
        // report and would deadlock every survivor.
        let rv = Rendezvous::new(3);
        rv.set_heartbeat_grace(Duration::from_millis(1));
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.register("inproc://c");
        rv.register_spare("inproc://s");
        std::thread::sleep(Duration::from_millis(5));
        rv.heartbeat("inproc://s");
        assert!(rv.report_dead(0, 2)); // gen 1: [a, b, s]; s observing
        // Before the gen-1 barrier completes, b dies too.
        rv.heartbeat("inproc://s");
        assert!(rv.report_dead(1, 1)); // gen 2: [a, s]
        let desc = OpDesc {
            op_seq: 7,
            kind: KIND_ALLREDUCE,
            elems: 16,
            ..OpDesc::default()
        };
        // The sole participating survivor completes the barrier alone.
        assert_eq!(
            rv.resume_poll(2, 0, 3, &desc),
            Some((7, 3)),
            "the still-observing spare must not block the barrier"
        );
        // The spare observes gen 2 and is promoted to a participant…
        assert_eq!(rv.resume_observe(2, 1), Some((3, desc.clone())));
        // …so a third heal *does* require its report.
        rv.register_spare("inproc://t");
        rv.heartbeat("inproc://t");
        assert!(rv.report_dead(2, 0)); // gen 3: [s, t]; t observing
        let d3 = OpDesc {
            op_seq: 8,
            kind: KIND_ALLREDUCE,
            elems: 16,
            ..OpDesc::default()
        };
        assert_eq!(rv.resume_missing(3), Some(vec![0]), "s (now rank 0) must report");
        assert_eq!(rv.resume_poll(3, 0, 1, &d3), Some((8, 1)));
    }
}
