//! Collective operations over a sealed ring, built as a **resumable step
//! state machine** with failure healing and compute/communication overlap.
//!
//! A [`RingMember`] owns one data-plane endpoint (an `inproc://` channel on
//! the thread backend, a [`crate::comms::rpc`] server on the OS-process
//! backend) and lazily-connected links to its peers. Collectives are SPMD:
//! **every member of a generation must call the same collectives in the
//! same order with the same buffer lengths and the same `chunk_elems`** —
//! the op-sequence number baked into message tags keeps concurrent steps
//! apart, not divergent programs.
//!
//! ## The step state machine
//!
//! `allreduce_sum` no longer runs one monolithic blocking loop. The buffer
//! is partitioned into **chunks** of at most `chunk_elems` elements, and
//! each chunk is ring-allreduced by executing an explicit
//! [`CollectiveStep`] plan — `n-1` reduce-scatter steps followed by `n-1`
//! all-gather steps, each naming the segment to send right and the segment
//! to receive from the left (see [`allreduce_plan`]). Progress is recorded
//! per chunk, which buys two capabilities:
//!
//! * **Healing.** Every receive carries a deadline. When it expires, the
//!   member accuses the silent peer through
//!   [`super::topology::Rendezvous::report_dead`]; if accepted (the accused
//!   stopped heartbeating), the rendezvous re-ranks the survivors into a
//!   new sealed generation. Survivors agree on the resume point through the
//!   `resume_poll` min-barrier and the collective **resumes from the first
//!   chunk any survivor had not completed** — completed chunks keep their
//!   reduced values (banked work, including the dead member's
//!   contribution), unfinished chunks are rolled back to the input snapshot
//!   and re-reduced over the survivors only.
//! * **Overlap.** With `set_overlap(true)` (the default) two chunks are in
//!   flight at once: chunk *k+1*'s sends are issued before chunk *k*'s
//!   blocking receive + reduce, so its traffic rides the wire while *k*
//!   reduces. [`RingMember::overlap_efficiency`] reports the fraction of
//!   pipeline steps that ran with a second chunk in flight.
//!
//! Known limitation (documented, surfaced as an error rather than a hang):
//! healing assumes the survivors share the interrupted collective. A crash
//! landing exactly on a collective boundary — the dead member delivered
//! all but the tail of collective *N*, letting some survivors advance into
//! *N+1* — strands members in different ops; after three report strikes
//! the stragglers fail with `PeerUnresponsive` instead of healing.
//!
//! Cost model (θ = buffer elements, n = world): ring allreduce moves
//! `2·(n-1)/n·θ` elements through every member — no hot spot — while the
//! gather-broadcast baseline moves `2·(n-1)·θ` through the root. The
//! per-member [`RingMember::bytes_sent`]/[`RingMember::bytes_received`]
//! counters make that asymmetry measurable in `benches/ring_allreduce.rs`.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use once_cell::sync::Lazy;

use crate::comms::chan::{self, Receiver, Sender};
use crate::comms::rpc::{RpcClient, RpcServer};
use crate::comms::Addr;
use crate::wire;

use super::kernels;
use super::spare::{ColdStart, OpDesc, KIND_ALLREDUCE, KIND_BROADCAST};
use super::topology::{Rendezvous, RendezvousClient, RingView};

/// RPC tag carrying one data-plane message on TCP endpoints.
pub const DATA_TAG: u32 = 1;

/// A data-plane message: `(from_rank, generation, op_tag, payload)`. The
/// generation stamp lets survivors of a heal drop stale traffic without
/// mistaking an old rank numbering for the new one.
type Msg = (u64, u64, u64, Vec<u8>);

/// Global registry of `inproc://` data endpoints (thread backend).
static INPROC_EP: Lazy<Mutex<HashMap<String, Sender<Msg>>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

static EP_SEQ: AtomicU64 = AtomicU64::new(1);

/// How a member exposes its data-plane endpoint.
pub enum Transport {
    /// An in-process channel (thread backend).
    Inproc,
    /// Bind a TCP RPC server at this address (OS-process backend); use port
    /// 0 for an ephemeral port. The advertised endpoint is the bound
    /// address, so bind a peer-reachable interface.
    TcpBind(String),
}

enum PeerTx {
    Inproc(Sender<Msg>),
    Tcp(RpcClient),
}

/// Typed faults the collective engine distinguishes from generic errors.
#[derive(Debug, PartialEq, Eq, thiserror::Error)]
pub enum RingError {
    /// The ring healed to a new generation; the interrupted collective
    /// must re-sync and resume (handled internally by the retry loop).
    #[error("ring healed to a new generation; collective must resume")]
    HealNeeded,
    /// A peer went silent but the rendezvous kept rejecting the death
    /// report (it heartbeated, or the generation is in flux).
    #[error("rank {0} is unresponsive but could not be evicted")]
    PeerUnresponsive(usize),
    /// Fault injection (`set_kill_after_chunk`) fired: this member is
    /// simulating a crash and must stop participating immediately.
    #[error("chaos fault injection: member killed after completing chunk")]
    ChaosKilled,
}

/// True when `err` is the chaos-kill signal — CLI chaos drivers and tests
/// use this to tell a simulated crash from a real failure.
pub fn is_chaos_killed(err: &anyhow::Error) -> bool {
    matches!(err.downcast_ref::<RingError>(), Some(RingError::ChaosKilled))
}

fn is_heal_needed(err: &anyhow::Error) -> bool {
    matches!(err.downcast_ref::<RingError>(), Some(RingError::HealNeeded))
}

/// The two phases of a chunked ring allreduce.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum StepPhase {
    /// Incoming segment is summed into the local buffer.
    ReduceScatter,
    /// Incoming segment (fully reduced) overwrites the local buffer.
    AllGather,
}

/// One pipeline step of the per-chunk ring-allreduce plan: which segment
/// goes to the right neighbour, which arrives from the left, and how the
/// arrival combines with the local buffer.
#[derive(Clone, Copy, Debug)]
pub struct CollectiveStep {
    pub phase: StepPhase,
    /// Step index within the phase (`0..n-1`).
    pub step: usize,
    /// Segment index sent to the right neighbour.
    pub send_seg: usize,
    /// Segment index received from the left neighbour.
    pub recv_seg: usize,
}

/// The explicit `2·(n-1)`-step plan one rank executes per chunk. After
/// reduce-scatter step `s` the received segment holds the sum of `s+2`
/// contributions; after `n-1` steps rank `r` fully owns segment
/// `(r+1) mod n`, which the all-gather phase then circulates.
pub fn allreduce_plan(world: usize, rank: usize) -> Vec<CollectiveStep> {
    let (n, r) = (world, rank);
    if n < 2 {
        return Vec::new();
    }
    let mut plan = Vec::with_capacity(2 * (n - 1));
    for s in 0..n - 1 {
        plan.push(CollectiveStep {
            phase: StepPhase::ReduceScatter,
            step: s,
            send_seg: (r + n - s) % n,
            recv_seg: (r + 2 * n - s - 1) % n,
        });
    }
    for s in 0..n - 1 {
        plan.push(CollectiveStep {
            phase: StepPhase::AllGather,
            step: s,
            send_seg: (r + 1 + n - s) % n,
            recv_seg: (r + n - s) % n,
        });
    }
    plan
}

/// Chunk partition of a buffer: contiguous ranges of at most `chunk`
/// elements (an empty buffer is one empty chunk, keeping SPMD lockstep).
fn chunk_ranges(len: usize, chunk: usize) -> Vec<(usize, usize)> {
    if len == 0 {
        return vec![(0, 0)];
    }
    (0..len)
        .step_by(chunk.max(1))
        .map(|lo| (lo, (lo + chunk).min(len)))
        .collect()
}

/// Segment `i` of `n` within a chunk of `clen` elements.
fn seg_bounds(clen: usize, n: usize, i: usize) -> (usize, usize) {
    (i * clen / n, (i + 1) * clen / n)
}

/// Progress of one in-flight chunk through its step plan.
#[derive(Clone, Copy, Debug)]
struct ChunkRun {
    chunk: usize,
    step: usize,
}

enum RecvMode {
    /// Timeouts trigger `report_dead` + healing (resumable collectives).
    Heal,
    /// Timeouts are hard errors (legacy lockstep collectives).
    Fail,
}

/// One ranked member of a sealed ring generation.
pub struct RingMember {
    view: RingView,
    rendezvous: RendezvousClient,
    endpoint: String,
    rx: Receiver<Msg>,
    _server: Option<RpcServer>,
    peers: HashMap<usize, PeerTx>,
    stash: VecDeque<Msg>,
    op_seq: u64,
    /// Set on a member drained from the spare pool; consumed by its first
    /// collective call, which adopts the interrupted op instead of
    /// starting a fresh one.
    cold_start: Option<ColdStart>,
    /// Algorithm-level program counter attached to collectives (carried
    /// through the resume barrier for cold rejoiners).
    op_note: u64,
    chunk_elems: usize,
    timeout: Duration,
    probe: Duration,
    overlap: bool,
    bytes_tx: u64,
    bytes_rx: u64,
    steps_total: u64,
    steps_overlapped: u64,
    heals: u64,
    kill_after_chunk: Option<u64>,
    /// Double-buffered receive scratch: collective steps alternate between
    /// the two halves (`step & 1`), so decoding a peer's frame reuses a
    /// warm allocation instead of growing a fresh `Vec<f32>` per step.
    scratch: [Vec<f32>; 2],
}

impl RingMember {
    /// Join through an already-held in-process rendezvous (thread backend).
    pub fn join_inproc(rv: &Arc<Rendezvous>) -> Result<RingMember> {
        Self::join_with(RendezvousClient::local(rv.clone()), Transport::Inproc)
    }

    /// Join a rendezvous at `addr` (`inproc://…` or `tcp://…`), exposing a
    /// TCP data endpoint when the rendezvous itself is remote. The data
    /// endpoint binds loopback, which serves the single-host OS-process
    /// backend; **multi-host members must use [`RingMember::join_addr_bind`]
    /// with an interface their peers can route to**, since the bound
    /// address is what gets advertised to the ring.
    pub fn join_addr(addr: &Addr) -> Result<RingMember> {
        Self::join_addr_bind(addr, "127.0.0.1:0")
    }

    /// [`RingMember::join_addr`] with an explicit TCP bind address for the
    /// data endpoint (e.g. `10.0.0.7:0` on a cluster node). Ignored when
    /// the rendezvous is `inproc://`.
    pub fn join_addr_bind(addr: &Addr, tcp_bind: &str) -> Result<RingMember> {
        let transport = match addr {
            Addr::Inproc(_) => Transport::Inproc,
            Addr::Tcp(_) => Transport::TcpBind(tcp_bind.to_string()),
        };
        Self::join_with(RendezvousClient::connect(addr)?, transport)
    }

    /// Build a data-plane endpoint for `transport`: the advertised
    /// endpoint string, the local receive side, and (TCP only) the
    /// serving RPC server. Shared by ranked joins and spare joins.
    fn make_endpoint(transport: Transport) -> Result<(String, Receiver<Msg>, Option<RpcServer>)> {
        let (tx, rx) = chan::unbounded::<Msg>();
        let (endpoint, server) = match transport {
            Transport::Inproc => {
                let name = format!("ring-ep-{}", EP_SEQ.fetch_add(1, Ordering::Relaxed));
                INPROC_EP.lock().unwrap().insert(name.clone(), tx);
                (format!("inproc://{name}"), None)
            }
            Transport::TcpBind(bind) => {
                let srv = RpcServer::bind(
                    &bind,
                    Arc::new(move |tag, payload| {
                        if tag != DATA_TAG {
                            return Err(format!("bad ring data tag {tag}"));
                        }
                        let msg: Msg = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                        tx.send(msg).map_err(|e| e.to_string())?;
                        Ok(Vec::new())
                    }),
                )?;
                (format!("tcp://{}", srv.local_addr()), Some(srv))
            }
        };
        Ok((endpoint, rx, server))
    }

    fn drop_endpoint(endpoint: &str) {
        if let Some(name) = endpoint.strip_prefix("inproc://") {
            INPROC_EP.lock().unwrap().remove(name);
        }
    }

    fn from_parts(
        view: RingView,
        rendezvous: RendezvousClient,
        endpoint: String,
        rx: Receiver<Msg>,
        server: Option<RpcServer>,
        cold_start: Option<ColdStart>,
    ) -> RingMember {
        let op_seq = cold_start.as_ref().map_or(0, |c| c.op.op_seq);
        RingMember {
            view,
            rendezvous,
            endpoint,
            rx,
            _server: server,
            peers: HashMap::new(),
            stash: VecDeque::new(),
            op_seq,
            cold_start,
            op_note: 0,
            chunk_elems: 1 << 15, // 128 KiB frames
            timeout: Duration::from_secs(30),
            probe: Duration::from_millis(25),
            overlap: true,
            bytes_tx: 0,
            bytes_rx: 0,
            steps_total: 0,
            steps_overlapped: 0,
            heals: 0,
            kill_after_chunk: None,
            scratch: [Vec::new(), Vec::new()],
        }
    }

    /// Join with explicit rendezvous client + data transport.
    pub fn join_with(rendezvous: RendezvousClient, transport: Transport) -> Result<RingMember> {
        let (endpoint, rx, server) = Self::make_endpoint(transport)?;
        let view = match rendezvous.join(&endpoint, Duration::from_secs(30)) {
            Ok(v) => v,
            Err(e) => {
                Self::drop_endpoint(&endpoint);
                return Err(e);
            }
        };
        Ok(Self::from_parts(view, rendezvous, endpoint, rx, server, None))
    }

    /// Stand by in the **spare pool** until a heal (or an explicit
    /// [`RingMember::request_grow`]) drains this member into a sealed
    /// generation, then return it as a ranked — but **cold** — member.
    /// Blocks up to `admission`, heartbeating while pending (a silent
    /// spare is excised from the pool); on timeout the spare withdraws
    /// and errors.
    ///
    /// The returned member holds a [`ColdStart`] (see
    /// [`RingMember::cold_op`]): its first collective call must match the
    /// interrupted op's kind and length — it adopts the survivors' op
    /// sequence and resumes at the min-barrier chunk, contributing the
    /// op's identity element. Configure `set_chunk_elems`/`set_timeout`
    /// to the ring's SPMD values **before** that first call. Algorithm
    /// drivers ([`crate::algo::es::EsRingNode::join_ring_as_spare`]) wrap
    /// this with the relay-then-state-sync protocol.
    pub fn join_spare_with(
        rendezvous: RendezvousClient,
        transport: Transport,
        admission: Duration,
    ) -> Result<RingMember> {
        let (endpoint, rx, server) = Self::make_endpoint(transport)?;
        if let Err(e) = rendezvous.register_spare(&endpoint) {
            Self::drop_endpoint(&endpoint);
            return Err(e);
        }
        let deadline = Instant::now() + admission;
        // (generation, rank, resolved view) once a seal drafts us. The
        // membership snapshot is only re-fetched when the heartbeat's
        // returned generation moves — steady-state pending costs one
        // control-plane call per slice, not three.
        let mut drafted: Option<(u64, usize, RingView)> = None;
        // Set at the first draft: bounds the post-draft adoption wait and
        // arms the missing-reporter accusations (a required survivor that
        // dies before reporting must be excised, not waited on forever).
        let mut drafted_at: Option<Instant> = None;
        let fail = |endpoint: &str, e: anyhow::Error| {
            Self::drop_endpoint(endpoint);
            Err(e)
        };
        loop {
            // Heartbeat every poll slice: a pending spare that goes
            // silent past the grace window is excised from the pool.
            let gen_now = match rendezvous.heartbeat(&endpoint) {
                Ok(g) => g,
                Err(e) => return fail(&endpoint, e),
            };
            if drafted.as_ref().map(|(g, _, _)| *g) != Some(gen_now) {
                drafted = None;
                drafted_at = None;
                let m = match rendezvous.membership() {
                    Ok(m) => m,
                    Err(e) => return fail(&endpoint, e),
                };
                if m.sealed && m.generation == gen_now {
                    if let Some(idx) = m.members.iter().position(|i| i.addr == endpoint) {
                        match m.resolve_view(idx) {
                            Ok(view) => {
                                // Fresh draft (possibly a re-draft into a
                                // newer generation): the adoption clocks
                                // start from here.
                                drafted = Some((gen_now, idx, view));
                                drafted_at = Some(Instant::now());
                            }
                            Err(e) => return fail(&endpoint, e),
                        }
                    }
                }
            }
            if let Some((g, idx, view)) = &drafted {
                let since_draft = drafted_at.unwrap_or_else(Instant::now);
                // Drafted. The survivors' resume barrier tells us where
                // the interrupted collective resumes and what it is (and
                // the observe promotes us to a participant).
                match rendezvous.resume_observe(*g, *idx as u64) {
                    Ok(Some((resume_chunk, op))) => {
                        let cold = ColdStart { resume_chunk, op };
                        return Ok(Self::from_parts(
                            view.clone(),
                            rendezvous,
                            endpoint,
                            rx,
                            server,
                            Some(cold),
                        ));
                    }
                    Ok(None) => {
                        // A required reporter that died before reporting
                        // would stall this barrier forever: past a grace
                        // period, accuse the missing ranks (the heartbeat
                        // veto shields anyone actually alive; an accepted
                        // report heals the ring and this loop re-syncs).
                        if since_draft.elapsed() > Duration::from_secs(5) {
                            let missing = match rendezvous.resume_missing(*g) {
                                Ok(m) => m,
                                Err(e) => return fail(&endpoint, e),
                            };
                            for rank in missing.unwrap_or_default() {
                                if rank == *idx as u64 {
                                    continue;
                                }
                                match rendezvous.report_dead(*g, rank) {
                                    Ok(true) => break,
                                    Ok(false) => {}
                                    Err(e) => return fail(&endpoint, e),
                                }
                            }
                        }
                        if since_draft.elapsed() > admission {
                            Self::drop_endpoint(&endpoint);
                            anyhow::bail!(
                                "spare at {endpoint} was drafted into generation {g} but \
                                 the admission barrier never completed within {admission:?} \
                                 (the survivors died or went silent); the ring will excise \
                                 this seat on its next heal"
                            );
                        }
                    }
                    Err(e) => return fail(&endpoint, e),
                }
            } else if Instant::now() >= deadline {
                // Never drafted: withdraw cleanly. (Once drafted we hold a
                // rank in a sealed generation and MUST see the admission
                // through — abandoning would leave a ghost member the
                // survivors pay a heal cycle to excise — so the deadline
                // only applies while still pending.)
                let _ = rendezvous.deregister_spare(&endpoint);
                Self::drop_endpoint(&endpoint);
                anyhow::bail!(
                    "spare at {endpoint} was never drafted within {admission:?} \
                     (no heal or grow drained the spare pool)"
                );
            }
            std::thread::sleep(Duration::from_millis(10));
        }
    }

    /// [`RingMember::join_spare_with`] through an in-process rendezvous
    /// (thread backend).
    pub fn join_spare_inproc(rv: &Arc<Rendezvous>, admission: Duration) -> Result<RingMember> {
        Self::join_spare_with(
            RendezvousClient::local(rv.clone()),
            Transport::Inproc,
            admission,
        )
    }

    /// [`RingMember::join_spare_with`] against a rendezvous at `addr`,
    /// exposing a TCP data endpoint when the rendezvous is remote (same
    /// bind rules as [`RingMember::join_addr`]).
    pub fn join_spare_addr(addr: &Addr, admission: Duration) -> Result<RingMember> {
        let transport = match addr {
            Addr::Inproc(_) => Transport::Inproc,
            Addr::Tcp(_) => Transport::TcpBind("127.0.0.1:0".into()),
        };
        Self::join_spare_with(RendezvousClient::connect(addr)?, transport, admission)
    }

    pub fn rank(&self) -> usize {
        self.view.rank
    }

    pub fn world(&self) -> usize {
        self.view.world
    }

    pub fn generation(&self) -> u64 {
        self.view.generation
    }

    pub fn view(&self) -> &RingView {
        &self.view
    }

    /// Payload bytes sent / received by this member so far.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_tx
    }

    pub fn bytes_received(&self) -> u64 {
        self.bytes_rx
    }

    /// Fraction of pipeline steps executed with a second chunk in flight
    /// (0.0 with overlap disabled or single-chunk buffers; approaches 1.0
    /// when the double-buffer keeps the wire busy through every reduce).
    pub fn overlap_efficiency(&self) -> f64 {
        if self.steps_total == 0 {
            0.0
        } else {
            self.steps_overlapped as f64 / self.steps_total as f64
        }
    }

    /// Number of generation heals this member has survived mid-collective.
    pub fn heal_count(&self) -> u64 {
        self.heals
    }

    pub fn reset_counters(&mut self) {
        self.bytes_tx = 0;
        self.bytes_rx = 0;
        self.steps_total = 0;
        self.steps_overlapped = 0;
    }

    /// Maximum `f32`s per chunk **and** per frame (must agree across all
    /// members): chunk granularity is also the healing resume granularity.
    pub fn set_chunk_elems(&mut self, elems: usize) {
        self.chunk_elems = elems.max(1);
    }

    /// Deadline for any single peer wait before the member accuses the
    /// peer of being dead (must exceed the rendezvous heartbeat grace).
    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    /// How often a blocked receive heartbeats the rendezvous and checks
    /// for a generation bump started by another survivor.
    pub fn set_probe_interval(&mut self, probe: Duration) {
        self.probe = probe.max(Duration::from_millis(1));
    }

    /// Toggle the double-buffered chunk pipeline (on by default).
    pub fn set_overlap(&mut self, overlap: bool) {
        self.overlap = overlap;
    }

    /// Chaos fault injection: simulate a crash by erroring with
    /// [`RingError::ChaosKilled`] right after chunk `chunk` of a healing
    /// collective completes. The caller is expected to drop the member (or
    /// exit the process) without calling [`RingMember::leave`], exactly
    /// like a real crash.
    pub fn set_kill_after_chunk(&mut self, chunk: Option<u64>) {
        self.kill_after_chunk = chunk;
    }

    /// Announce departure: bumps the ring generation so survivors
    /// re-rendezvous (pair with [`RendezvousClient::resize`] on scale-down).
    pub fn leave(&mut self) -> Result<()> {
        self.rendezvous
            .leave(self.view.generation, self.view.rank as u64)
    }

    /// Attach an algorithm-level **op note** (a program counter) to the
    /// collectives that follow. The note travels through the resume
    /// min-barrier when a heal interrupts a collective, so a spare drained
    /// into the healed generation learns *which* step of the algorithm's
    /// iteration it is relaying — e.g. [`crate::algo::es`]'s
    /// rewards/gradient/sync phases. Purely advisory for warm members.
    pub fn set_op_note(&mut self, note: u64) {
        self.op_note = note;
    }

    /// The interrupted op a freshly drained spare must adopt, if any —
    /// `Some` from [`RingMember::join_spare_with`] until the first
    /// matching collective call consumes it.
    pub fn cold_op(&self) -> Option<&ColdStart> {
        self.cold_start.as_ref()
    }

    /// Ask the rendezvous to drain the spare pool into a grown sealed
    /// generation (see [`super::topology::Rendezvous::grow`]). Call
    /// between collectives; every member's next collective adopts the
    /// grown world through the ordinary heal/resume machinery. Returns
    /// `false` when no live spare is pending or this member's view is
    /// already stale.
    pub fn request_grow(&self) -> Result<bool> {
        let grew = self.rendezvous.grow(self.view.generation)?;
        if grew {
            crate::trace::instant("ring.grow", &[("gen", self.view.generation as i64)]);
        }
        Ok(grew)
    }

    /// Describe the collective this member is currently driving, for the
    /// resume barrier.
    fn op_desc(&self, kind: u8, elems: usize, root: String) -> OpDesc {
        OpDesc {
            op_seq: self.op_seq,
            kind,
            elems: elems as u64,
            root,
            note: self.op_note,
        }
    }

    /// Begin a collective: adopt the pending [`ColdStart`] when this is a
    /// drained spare's first call (aligning the op sequence with the
    /// survivors and resuming at the min-barrier chunk), else allocate the
    /// next op in sequence and start at chunk 0.
    fn begin_op(&mut self, kind: u8, elems: usize) -> Result<(u64, usize)> {
        if let Some(cold) = self.cold_start.as_ref() {
            // Validate before consuming, so a driver that called the
            // wrong collective can recover: the adoption state survives
            // the error and the correct call still adopts.
            anyhow::ensure!(
                cold.op.kind == kind && cold.op.elems as usize == elems,
                "cold join mismatch: drained into op (kind {}, {} elems) but the first \
                 collective call is (kind {kind}, {elems} elems) — the spare must mirror \
                 the survivors' program (see ring::spare)",
                cold.op.kind,
                cold.op.elems,
            );
            let cold = self.cold_start.take().expect("checked above");
            self.op_seq = cold.op.op_seq;
            // The adoption event names the interrupted op — the causal
            // join between this rejoiner's timeline and the op it rode
            // through the heal.
            crate::trace::instant(
                "ring.adopt",
                &[
                    ("op_seq", cold.op.op_seq as i64),
                    ("kind", cold.op.kind as i64),
                    ("resume_chunk", cold.resume_chunk as i64),
                    ("note", cold.op.note as i64),
                    ("gen", self.view.generation as i64),
                ],
            );
            return Ok((cold.op.op_seq << 24, cold.resume_chunk as usize));
        }
        Ok((self.next_op(), 0))
    }

    // ---- collectives -----------------------------------------------------

    /// In-place elementwise sum across all members: chunked ring allreduce
    /// driven by the [`CollectiveStep`] state machine, double-buffered when
    /// overlap is on, and **self-healing** — a member death mid-collective
    /// bumps the generation and the survivors resume from the first chunk
    /// any of them had not completed. Completed chunks keep the old
    /// generation's sum (banked work); resumed chunks hold the sum over
    /// the survivors only.
    pub fn allreduce_sum(&mut self, buf: &mut [f32]) -> Result<()> {
        if self.view.world == 1 && self.heartbeat()? <= self.view.generation {
            // Sole member, no membership change pending: the sum of one.
            // (The heartbeat probe is what lets a world-1 ring adopt an
            // explicit grow: with the generation bumped we fall through
            // and the first drive's heal drafts the spares in.)
            return Ok(());
        }
        let _op_span = crate::trace::Span::begin("ring.allreduce")
            .arg("elems", buf.len() as i64)
            .arg("gen", self.view.generation as i64)
            .arg("rank", self.view.rank as i64);
        let (op, resume_at) = self.begin_op(KIND_ALLREDUCE, buf.len())?;
        let chunks = chunk_ranges(buf.len(), self.chunk_elems);
        self.ensure_tag_capacity(chunks.len())?;
        let snapshot = buf.to_vec();
        let mut start = resume_at;
        let mut completed = resume_at;
        loop {
            match self.drive_allreduce(op, buf, &chunks, start, &mut completed) {
                Ok(()) => return Ok(()),
                Err(e) => {
                    if !is_heal_needed(&e) {
                        return Err(e);
                    }
                    let desc = self.op_desc(KIND_ALLREDUCE, buf.len(), String::new());
                    let (resume_op, resume) = self.heal_and_sync(completed as u64, &desc)?;
                    if resume_op > desc.op_seq {
                        // The membership changed on a collective boundary
                        // (e.g. an explicit grow) after this op finished:
                        // peers already moved on to a later op. Only a
                        // locally complete op may take this exit — a
                        // member genuinely stranded mid-op cannot resume
                        // a collective the ring has left behind.
                        anyhow::ensure!(
                            completed == chunks.len(),
                            "ring resumed op {resume_op} but this member is mid-op {} \
                             ({completed}/{} chunks) — boundary-skewed, not resumable",
                            desc.op_seq,
                            chunks.len()
                        );
                        return Ok(());
                    }
                    let resume = resume as usize;
                    // Unfinished chunks roll back to the pre-collective
                    // input and re-reduce over the survivors.
                    for &(lo, hi) in chunks.iter().skip(resume) {
                        buf[lo..hi].copy_from_slice(&snapshot[lo..hi]);
                    }
                    start = resume;
                    if self.view.world == 1 {
                        return Ok(());
                    }
                }
            }
        }
    }

    /// Allreduce then divide by the world size (data-parallel averaging).
    /// The divisor is the world size **after** the sum, so a mid-collective
    /// heal averages over the surviving replicas.
    pub fn allreduce_mean(&mut self, buf: &mut [f32]) -> Result<()> {
        self.allreduce_sum(buf)?;
        kernels::scale(buf, 1.0 / self.view.world as f32);
        Ok(())
    }

    /// Pipelined ring broadcast of `root`'s buffer into every member's.
    /// Chunk progress is recorded, so a non-root death mid-broadcast heals
    /// and resumes like allreduce; a dead root is unrecoverable and errors.
    /// `root` names a rank of the generation current at call time — the
    /// member is tracked by endpoint across heals.
    pub fn broadcast(&mut self, root: usize, buf: &mut [f32]) -> Result<()> {
        let n = self.view.world;
        anyhow::ensure!(root < n, "broadcast root {root} out of range (world {n})");
        if n == 1 && self.heartbeat()? <= self.view.generation {
            // Sole member and no pending grow (see allreduce_sum).
            return Ok(());
        }
        let root_addr = self.view.members[root].clone();
        let _op_span = crate::trace::Span::begin("ring.broadcast")
            .arg("elems", buf.len() as i64)
            .arg("gen", self.view.generation as i64)
            .arg("root", root as i64);
        let (op, resume_at) = self.begin_op(KIND_BROADCAST, buf.len())?;
        let chunks = chunk_ranges(buf.len(), self.chunk_elems);
        self.ensure_tag_capacity(chunks.len())?;
        let mut start = resume_at;
        let mut completed = resume_at;
        loop {
            let root_now = self
                .view
                .members
                .iter()
                .position(|a| *a == root_addr)
                .context("broadcast root died; its buffer is unrecoverable")?;
            // (A post-heal world of 1 — the sole survivor is the root
            // itself — is handled by drive_broadcast's n == 1 branch.)
            match self.drive_broadcast(op, root_now, buf, &chunks, start, &mut completed) {
                Ok(()) => return Ok(()),
                Err(e) => {
                    if !is_heal_needed(&e) {
                        return Err(e);
                    }
                    let desc = self.op_desc(KIND_BROADCAST, buf.len(), root_addr.to_string());
                    let (resume_op, resume) = self.heal_and_sync(completed as u64, &desc)?;
                    if resume_op > desc.op_seq {
                        // Boundary bump after this broadcast completed:
                        // peers are in a later op (see allreduce_sum).
                        anyhow::ensure!(
                            completed == chunks.len(),
                            "ring resumed op {resume_op} but this member is mid-op {} \
                             ({completed}/{} chunks) — boundary-skewed, not resumable",
                            desc.op_seq,
                            chunks.len()
                        );
                        return Ok(());
                    }
                    start = resume as usize;
                }
            }
        }
    }

    /// Store-backed broadcast: the root publishes the payload into the
    /// distributed object store and the ring circulates only a 24-byte
    /// header (content id + length) — via the healing [`RingMember::broadcast`]
    /// machinery, so a non-root death mid-header still heals. Every other
    /// member then resolves the blob through its [`crate::store::StoreNode`]:
    /// a **local cache hit** when it already holds the chunks (post-heal
    /// retries, rejoining members, repeated tables — the warm path moves
    /// no payload at all), a peer-to-peer chunk fetch otherwise, and
    /// concurrent members fetching through one shared node ride a single
    /// transfer (single-flight dedup). Returns the blob id.
    ///
    /// Like every collective this is SPMD: all members call it with the
    /// same `root` and equal buffer lengths. Members may share one node
    /// (thread backend) or each own a node wired to a common directory
    /// (OS-process backend).
    pub fn store_broadcast(
        &mut self,
        node: &crate::store::StoreNode,
        root: usize,
        buf: &mut [f32],
    ) -> Result<crate::store::ObjId> {
        let n = self.view.world;
        anyhow::ensure!(root < n, "store_broadcast root {root} out of range (world {n})");
        if self.view.rank == root {
            let bytes = f32s_to_bytes(buf);
            let id = node.put_bytes(&bytes)?;
            let mut hdr = pack_store_header(id, buf.len() as u64);
            self.broadcast(root, &mut hdr)?;
            Ok(id)
        } else {
            let mut hdr = [0.0f32; 6];
            self.broadcast(root, &mut hdr)?;
            let (id, len) = unpack_store_header(&hdr);
            anyhow::ensure!(
                len as usize == buf.len(),
                "store_broadcast length mismatch: root published {len} elems, \
                 local buffer holds {}",
                buf.len()
            );
            let bytes = node.get_bytes(id)?;
            let vals = bytes_to_f32s(&bytes)?;
            anyhow::ensure!(
                vals.len() == buf.len(),
                "store_broadcast blob {id} holds {} elems, want {}",
                vals.len(),
                buf.len()
            );
            buf.copy_from_slice(&vals);
            Ok(id)
        }
    }

    /// Ring all-gather: every member contributes `mine` (equal lengths
    /// across members); returns the world's contributions concatenated in
    /// rank order.
    ///
    /// # Fail-fast semantics (deliberately non-healing)
    ///
    /// Unlike [`RingMember::allreduce_sum`]/[`RingMember::broadcast`],
    /// this collective does **not** resume across a heal, because its
    /// result shape is rank-indexed: if the world shrinks from `n` to
    /// `n-1` mid-gather, there is no coherent answer for the dead rank's
    /// slot — survivors that already banked it would disagree with
    /// survivors that did not, and downstream code indexing `out[r*len..]`
    /// by old ranks would silently read the wrong member's data. Instead:
    ///
    /// * a dead peer surfaces as a recv-timeout **error** (`ring recv
    ///   timed out waiting for rank …`);
    /// * a generation bump started by another member surfaces as `ring
    ///   healed to a new generation mid-collective; this collective is
    ///   not resumable`.
    ///
    /// Callers that need healing semantics should restructure the
    /// exchange as a sum with disjoint slots (the
    /// [`crate::algo::es::EsRingNode`] reward vector does exactly this)
    /// or re-run the gather on the healed generation from scratch.
    pub fn all_gather(&mut self, mine: &[f32]) -> Result<Vec<f32>> {
        let n = self.view.world;
        let len = mine.len();
        let r = self.view.rank;
        let mut out = vec![0.0f32; n * len];
        out[r * len..(r + 1) * len].copy_from_slice(mine);
        if n == 1 {
            return Ok(out);
        }
        let op = self.next_op();
        let right = self.view.right();
        let left = self.view.left();
        for s in 0..n - 1 {
            let tag = op | s as u64;
            let send_seg = (r + n - s) % n;
            let recv_seg = (r + 2 * n - 1 - s) % n;
            self.send_chunks(right, tag, &out[send_seg * len..(send_seg + 1) * len])?;
            let incoming = self.recv_elems(left, tag, len)?;
            out[recv_seg * len..(recv_seg + 1) * len].copy_from_slice(&incoming);
        }
        Ok(out)
    }

    /// The leader-centric baseline: every member ships its full buffer to
    /// `root`, which sums and ships the result back — `O(n·θ)` at the root.
    /// Same result as [`RingMember::allreduce_sum`] up to summation order;
    /// exists as the comparison target for `benches/ring_allreduce.rs`.
    ///
    /// # Fail-fast semantics (deliberately non-healing)
    ///
    /// Lockstep, like [`RingMember::all_gather`], and for the same reason
    /// with one more: the root is a single point of failure holding the
    /// only partial sum, so there is no survivor set that could resume the
    /// reduction. A dead peer (or root) surfaces as a recv-timeout error
    /// and a concurrent heal as a `not resumable` error — the baseline
    /// stays a faithful model of the leader-centric architecture it
    /// benchmarks, including its fragility.
    pub fn gather_broadcast_sum(&mut self, root: usize, buf: &mut [f32]) -> Result<()> {
        let n = self.view.world;
        anyhow::ensure!(root < n, "root {root} out of range (world {n})");
        if n == 1 {
            return Ok(());
        }
        let op = self.next_op();
        if self.view.rank == root {
            for other in 0..n {
                if other == root {
                    continue;
                }
                let incoming = self.recv_elems(other, op, buf.len())?;
                kernels::add_assign(buf, &incoming);
            }
            for other in 0..n {
                if other == root {
                    continue;
                }
                self.send_chunks(other, op | 1 << 8, buf)?;
            }
        } else {
            self.send_chunks(root, op, buf)?;
            let incoming = self.recv_elems(root, op | 1 << 8, buf.len())?;
            buf.copy_from_slice(&incoming);
        }
        Ok(())
    }

    // ---- the step-machine engine ----------------------------------------

    /// Drive the chunked allreduce from chunk `start`, recording progress
    /// in `completed` (count of fully all-gathered chunks — the value the
    /// resume barrier reports). With overlap on, two chunks are in flight:
    /// every tick issues both sends before either blocking receive.
    fn drive_allreduce(
        &mut self,
        op: u64,
        buf: &mut [f32],
        chunks: &[(usize, usize)],
        start: usize,
        completed: &mut usize,
    ) -> Result<()> {
        let n = self.view.world;
        *completed = start;
        if n == 1 {
            // A sole member banks everything — but must still notice a
            // generation bump (explicit grow), or a world-1 ring could
            // never adopt its drafted spares.
            *completed = chunks.len();
            self.heartbeat_check(false)?;
            return Ok(());
        }
        let plan = allreduce_plan(n, self.view.rank);
        let spc = plan.len() as u64;
        let right = self.view.right();
        let left = self.view.left();
        self.heartbeat_check(false)?;
        let window = if self.overlap { 2 } else { 1 };
        let mut active: VecDeque<ChunkRun> = VecDeque::new();
        let mut next_chunk = start;
        while *completed < chunks.len() {
            while active.len() < window && next_chunk < chunks.len() {
                active.push_back(ChunkRun {
                    chunk: next_chunk,
                    step: 0,
                });
                next_chunk += 1;
            }
            let in_flight = active.len() as u64;
            self.steps_total += in_flight;
            if in_flight > 1 {
                self.steps_overlapped += in_flight;
            }
            // Send half: every in-flight chunk's current step goes out
            // before any blocking receive.
            for i in 0..active.len() {
                let run = active[i];
                let st = plan[run.step];
                let (lo, hi) = chunks[run.chunk];
                let (slo, shi) = seg_bounds(hi - lo, n, st.send_seg);
                let tag = op | (run.chunk as u64 * spc + run.step as u64);
                let payload = f32s_to_bytes(&buf[lo + slo..lo + shi]);
                self.send_msg_healing(right, tag, payload)?;
                crate::trace::instant(
                    "ring.chunk.send",
                    &[
                        ("chunk", run.chunk as i64),
                        ("step", run.step as i64),
                        ("elems", (shi - slo) as i64),
                    ],
                );
            }
            // Receive half, oldest chunk first.
            for i in 0..active.len() {
                let run = active[i];
                let st = plan[run.step];
                let (lo, hi) = chunks[run.chunk];
                let (rlo, rhi) = seg_bounds(hi - lo, n, st.recv_seg);
                let tag = op | (run.chunk as u64 * spc + run.step as u64);
                let bytes = self.recv_data(left, tag, RecvMode::Heal)?;
                // Decode into one half of the double-buffered scratch pair:
                // with two chunks in flight, alternating steps reuse two
                // warm allocations instead of growing a fresh Vec each step.
                // (An early error return leaves the taken half empty — the
                // heal path just re-warms it.)
                let mut incoming = std::mem::take(&mut self.scratch[run.step & 1]);
                bytes_to_f32s_into(&bytes, &mut incoming)?;
                anyhow::ensure!(
                    incoming.len() == rhi - rlo,
                    "ring step payload mismatch from rank {left}: got {}, want {}",
                    incoming.len(),
                    rhi - rlo
                );
                let dst = &mut buf[lo + rlo..lo + rhi];
                match st.phase {
                    StepPhase::ReduceScatter => {
                        kernels::add_assign(dst, &incoming);
                        crate::trace::instant(
                            "ring.chunk.reduce",
                            &[("chunk", run.chunk as i64), ("step", run.step as i64)],
                        );
                    }
                    StepPhase::AllGather => {
                        dst.copy_from_slice(&incoming);
                        crate::trace::instant(
                            "ring.chunk.recv",
                            &[("chunk", run.chunk as i64), ("step", run.step as i64)],
                        );
                    }
                }
                self.scratch[run.step & 1] = incoming;
                active[i].step += 1;
            }
            // Retire finished chunks in admission order (keeps `completed`
            // a prefix count, which the resume barrier relies on).
            while active.front().is_some_and(|r| r.step == plan.len()) {
                let run = active.pop_front().unwrap();
                *completed += 1;
                self.heartbeat_check(true)?;
                if self.kill_after_chunk == Some(run.chunk as u64) {
                    return Err(RingError::ChaosKilled.into());
                }
            }
        }
        Ok(())
    }

    /// Drive the chunked broadcast from chunk `start` (root re-sends,
    /// non-roots receive and forward still-encoded chunks — the pipeline).
    fn drive_broadcast(
        &mut self,
        op: u64,
        root: usize,
        buf: &mut [f32],
        chunks: &[(usize, usize)],
        start: usize,
        completed: &mut usize,
    ) -> Result<()> {
        let n = self.view.world;
        *completed = start;
        if n == 1 {
            // See drive_allreduce: bank all, but notice a pending grow.
            *completed = chunks.len();
            self.heartbeat_check(false)?;
            return Ok(());
        }
        let right = self.view.right();
        let left = self.view.left();
        let rank = self.view.rank;
        self.heartbeat_check(false)?;
        for ci in start..chunks.len() {
            let (lo, hi) = chunks[ci];
            let tag = op | ci as u64;
            if rank == root {
                let payload = f32s_to_bytes(&buf[lo..hi]);
                self.send_msg_healing(right, tag, payload)?;
                crate::trace::instant(
                    "ring.chunk.send",
                    &[("chunk", ci as i64), ("elems", (hi - lo) as i64)],
                );
            } else {
                let bytes = self.recv_data(left, tag, RecvMode::Heal)?;
                let vals = bytes_to_f32s(&bytes)?;
                anyhow::ensure!(
                    vals.len() == hi - lo,
                    "broadcast chunk {ci} length mismatch: got {}, want {}",
                    vals.len(),
                    hi - lo
                );
                buf[lo..hi].copy_from_slice(&vals);
                crate::trace::instant(
                    "ring.chunk.recv",
                    &[("chunk", ci as i64), ("elems", (hi - lo) as i64)],
                );
                if right != root {
                    self.send_msg_healing(right, tag, bytes)?;
                }
            }
            *completed += 1;
            self.heartbeat_check(true)?;
            if self.kill_after_chunk == Some(ci as u64) {
                return Err(RingError::ChaosKilled.into());
            }
        }
        Ok(())
    }

    // ---- healing ---------------------------------------------------------

    /// Prove liveness to the rendezvous outside a collective. Members only
    /// heartbeat automatically while they wait *inside* collectives, so a
    /// long compute phase (e.g. a slow rollout shard) looks exactly like
    /// death to an impatient peer — pump this between units of compute
    /// work to keep the heartbeat-grace veto protecting you.
    pub fn heartbeat_now(&self) -> Result<()> {
        self.heartbeat().map(|_| ())
    }

    /// Heartbeat and learn the rendezvous' current generation in one
    /// control-plane call (blocked receivers poll this every probe slice;
    /// a full membership snapshot per slice would be needless weight).
    fn heartbeat(&self) -> Result<u64> {
        self.rendezvous.heartbeat(&self.endpoint)
    }

    /// Heartbeat and join any heal another survivor already started. This
    /// is how a member that never blocks in a collective — a broadcast
    /// root is pure-send — still observes a downstream death in bounded
    /// time: the per-chunk heartbeat carries the bumped generation back.
    ///
    /// With `mid_op` set, a bump that only **added** members (an explicit
    /// spare-pool grow — see [`RingMember::growth_only`]) is deferred:
    /// every participant of the in-flight op is still present, so the op
    /// completes over the old topology and all members adopt the grown
    /// generation together at their next op start. Without the deferral,
    /// a grow racing one member's final chunks would put that member and
    /// its peers into *different* ops at the resume barrier. A bump that
    /// excised anyone is a heal and interrupts immediately either way.
    fn heartbeat_check(&self, mid_op: bool) -> Result<()> {
        if self.heartbeat()? > self.view.generation {
            if mid_op && self.growth_only()? {
                return Ok(());
            }
            return Err(RingError::HealNeeded.into());
        }
        Ok(())
    }

    fn generation_bumped(&self) -> Result<bool> {
        Ok(self.heartbeat()? > self.view.generation)
    }

    /// True when the rendezvous' current membership still ranks every
    /// endpoint of this member's view — the generation bump only *grew*
    /// the ring (nobody excised). Used to defer explicit grows to op
    /// boundaries.
    fn growth_only(&self) -> Result<bool> {
        let m = self.rendezvous.membership()?;
        if !m.sealed {
            return Ok(false);
        }
        Ok(self.view.members.iter().all(|a| {
            let s = a.to_string();
            m.members.iter().any(|i| i.addr == s)
        }))
    }

    /// Adopt the healed generation (same endpoint, new rank/world), purge
    /// stale state, and run the resume min-barrier, reporting `desc` (the
    /// in-flight op) so drained spares can adopt it. Returns
    /// `(resume_op_seq, resume_chunk)` — the most-advanced op reported
    /// into the barrier and the chunk it resumes from (callers whose own
    /// op is behind `resume_op_seq` were superseded at a boundary and must
    /// not roll back). Loops if yet another member dies while the barrier
    /// is forming.
    fn heal_and_sync(&mut self, completed: u64, desc: &OpDesc) -> Result<(u64, u64)> {
        // The heal span covers re-rendezvous + resume barrier; the resume
        // event is recorded **under** it, so the trace shows which heal a
        // resume belongs to even when several heals stack up.
        let heal = crate::trace::Span::begin("ring.heal")
            .arg("from_gen", self.view.generation as i64)
            .arg("op_seq", desc.op_seq as i64)
            .arg("completed", completed as i64);
        let heal_id = heal.id();
        loop {
            let deadline = Instant::now() + self.timeout;
            let view = loop {
                let m = self.rendezvous.membership()?;
                if m.generation > self.view.generation && m.sealed {
                    match m.members.iter().position(|i| i.addr == self.endpoint) {
                        Some(idx) => break m.resolve_view(idx)?,
                        None => anyhow::bail!(
                            "this member was evicted from the ring (reported dead) \
                             at generation {}",
                            m.generation
                        ),
                    }
                }
                anyhow::ensure!(
                    Instant::now() < deadline,
                    "ring heal: no healed generation appeared within the timeout \
                     (a leave/resize mid-collective is not resumable)"
                );
                std::thread::sleep(Duration::from_millis(2));
            };
            let new_gen = view.generation;
            self.view = view;
            self.peers.clear();
            self.stash.retain(|m| m.1 >= new_gen);
            self.heals += 1;
            self.heartbeat_check(false)?;
            // The resume barrier can wait on survivors that are deep in a
            // compute phase (e.g. ES rollouts) and have not touched the
            // ring yet, so its budget is far larger than one peer wait.
            // Past half that budget, a member that still has not reported
            // is presumed a second simultaneous death and gets accused
            // (the heartbeat grace still shields anyone actually alive).
            let barrier_deadline = Instant::now() + self.timeout * 10;
            let accuse_after = Instant::now() + self.timeout * 5;
            let mut healed_again = false;
            loop {
                if let Some(resume) =
                    self.rendezvous
                        .resume_poll(new_gen, self.view.rank as u64, completed, desc)?
                {
                    crate::trace::instant_under(
                        "ring.resume",
                        heal_id,
                        &[
                            ("op_seq", resume.0 as i64),
                            ("chunk", resume.1 as i64),
                            ("gen", new_gen as i64),
                        ],
                    );
                    return Ok(resume);
                }
                if self.heartbeat()? > new_gen {
                    healed_again = true; // another death while re-forming
                    break;
                }
                if Instant::now() >= accuse_after {
                    if let Some(missing) = self.rendezvous.resume_missing(new_gen)? {
                        for rank in missing {
                            if rank != self.view.rank as u64
                                && self.rendezvous.report_dead(new_gen, rank)?
                            {
                                break;
                            }
                        }
                    }
                }
                anyhow::ensure!(
                    Instant::now() < barrier_deadline,
                    "ring heal: resume barrier timed out at generation {new_gen}"
                );
                std::thread::sleep(Duration::from_millis(2));
            }
            debug_assert!(healed_again);
        }
    }

    // ---- plumbing --------------------------------------------------------

    /// Per-collective namespace for message tags: high 40 bits are the op
    /// sequence number, low 24 the chunk×step slot within the op.
    fn next_op(&mut self) -> u64 {
        self.op_seq += 1;
        self.op_seq << 24
    }

    /// The chunk×step slot index must fit the 24-bit tag namespace.
    fn ensure_tag_capacity(&self, n_chunks: usize) -> Result<()> {
        let spc = 2 * self.view.world.saturating_sub(1).max(1);
        anyhow::ensure!(
            (n_chunks as u64) * (spc as u64) < 1 << 24,
            "collective too finely chunked for the tag namespace: raise chunk_elems \
             ({n_chunks} chunks × {spc} steps)"
        );
        Ok(())
    }

    fn peer(&mut self, to: usize) -> Result<&PeerTx> {
        if !self.peers.contains_key(&to) {
            let addr = self
                .view
                .members
                .get(to)
                .with_context(|| format!("no ring member at rank {to}"))?;
            let link = match addr {
                Addr::Inproc(name) => {
                    let tx = INPROC_EP
                        .lock()
                        .unwrap()
                        .get(name)
                        .cloned()
                        .with_context(|| format!("ring endpoint inproc://{name} is gone"))?;
                    PeerTx::Inproc(tx)
                }
                Addr::Tcp(sa) => {
                    let cli = RpcClient::connect(*sa)?;
                    // Deadline support threaded through comms::rpc: a send
                    // to a wedged peer must not outlive the recv timeout.
                    cli.set_read_timeout(Some(self.timeout))?;
                    PeerTx::Tcp(cli)
                }
            };
            self.peers.insert(to, link);
        }
        Ok(&self.peers[&to])
    }

    fn send_msg(&mut self, to: usize, tag: u64, bytes: Vec<u8>) -> Result<()> {
        let from = self.view.rank as u64;
        let generation = self.view.generation;
        let len = bytes.len() as u64;
        match self.peer(to)? {
            PeerTx::Inproc(tx) => {
                tx.send((from, generation, tag, bytes))
                    .map_err(|e| anyhow::anyhow!("ring send to rank {to}: {e}"))?;
            }
            PeerTx::Tcp(cli) => {
                cli.call(DATA_TAG, &wire::to_bytes(&(from, generation, tag, bytes)))
                    .with_context(|| format!("ring send to rank {to}"))?;
            }
        }
        self.bytes_tx += len;
        Ok(())
    }

    /// One TCP data-plane call with an already-framed message (lets the
    /// healing send retry on a fresh connection without re-encoding or
    /// cloning the payload — `RpcClient::call` takes a borrow).
    fn tcp_call(&mut self, to: usize, framed: &[u8]) -> Result<()> {
        match self.peer(to)? {
            PeerTx::Tcp(cli) => cli
                .call(DATA_TAG, framed)
                .map(|_| ())
                .with_context(|| format!("ring send to rank {to}")),
            PeerTx::Inproc(_) => anyhow::bail!("rank {to} is not a TCP peer"),
        }
    }

    /// Healing-aware send. A failed TCP delivery retries once on a fresh
    /// connection; any still-failing delivery (including an in-process
    /// endpoint that vanished with its thread) accuses the peer and joins
    /// the heal. The TCP path frames the message once up front so the
    /// retry needs no payload clone; the in-process path moves the payload
    /// straight into the channel (its only failure mode is a dead
    /// endpoint, where the payload is moot).
    fn send_msg_healing(&mut self, to: usize, tag: u64, bytes: Vec<u8>) -> Result<()> {
        let err = if matches!(self.view.members.get(to), Some(Addr::Tcp(_))) {
            let from = self.view.rank as u64;
            let generation = self.view.generation;
            let len = bytes.len() as u64;
            let framed = wire::to_bytes(&(from, generation, tag, bytes));
            match self.tcp_call(to, &framed) {
                Ok(()) => {
                    self.bytes_tx += len;
                    return Ok(());
                }
                Err(_) => {
                    self.peers.remove(&to); // drop the broken link, reconnect once
                    match self.tcp_call(to, &framed) {
                        Ok(()) => {
                            self.bytes_tx += len;
                            return Ok(());
                        }
                        Err(e) => e,
                    }
                }
            }
        } else {
            match self.send_msg(to, tag, bytes) {
                Ok(()) => return Ok(()),
                Err(e) => e,
            }
        };
        self.peers.remove(&to);
        let deadline = Instant::now() + self.timeout;
        loop {
            if self.rendezvous.report_dead(self.view.generation, to as u64)? {
                return Err(RingError::HealNeeded.into());
            }
            // A growth-only bump is not a heal (see heartbeat_check): keep
            // retrying the report until the dead peer's grace expires.
            if self.generation_bumped()? && !self.growth_only()? {
                return Err(RingError::HealNeeded.into());
            }
            if Instant::now() >= deadline {
                return Err(err.context(format!(
                    "ring send to rank {to} kept failing and the death report was rejected"
                )));
            }
            std::thread::sleep(Duration::from_millis(10).min(self.probe));
        }
    }

    /// Next message from `from` with tag `tag` in the current generation,
    /// buffering whatever else arrives. Waits are sliced into probe
    /// intervals: each slice heartbeats the rendezvous and checks for a
    /// generation bump started by another survivor. In `Heal` mode an
    /// expired deadline accuses the peer; in `Fail` mode it is an error.
    fn recv_data(&mut self, from: usize, tag: u64, mode: RecvMode) -> Result<Vec<u8>> {
        if let Some(pos) = self
            .stash
            .iter()
            .position(|m| m.0 == from as u64 && m.1 == self.view.generation && m.2 == tag)
        {
            let msg = self.stash.remove(pos).unwrap();
            self.bytes_rx += msg.3.len() as u64;
            return Ok(msg.3);
        }
        let mut deadline = Instant::now() + self.timeout;
        let mut strikes = 0u32;
        loop {
            let slice = (Instant::now() + self.probe).min(deadline);
            match self.rx.recv_deadline(slice) {
                Ok(msg) => {
                    let generation = self.view.generation;
                    if msg.1 < generation {
                        continue; // stale traffic from a healed-away ring
                    }
                    if msg.1 > generation {
                        // A peer already healed past us: keep its message
                        // for the resumed attempt and go heal ourselves.
                        self.stash.push_back(msg);
                        match mode {
                            RecvMode::Heal => return Err(RingError::HealNeeded.into()),
                            RecvMode::Fail => anyhow::bail!(
                                "ring healed to a new generation mid-collective; \
                                 this collective is not resumable"
                            ),
                        }
                    }
                    if msg.0 == from as u64 && msg.2 == tag {
                        self.bytes_rx += msg.3.len() as u64;
                        return Ok(msg.3);
                    }
                    self.stash.push_back(msg);
                }
                Err(chan::RecvError::Timeout) => {
                    // One control-plane call per slice: heartbeat + bump check.
                    // A growth-only bump (explicit grow) is deferred to the
                    // op boundary: the sender is still ranked and still in
                    // this op, so its traffic is coming — keep waiting.
                    if self.generation_bumped()? && !self.growth_only()? {
                        match mode {
                            RecvMode::Heal => return Err(RingError::HealNeeded.into()),
                            RecvMode::Fail => anyhow::bail!(
                                "ring healed to a new generation mid-collective; \
                                 this collective is not resumable"
                            ),
                        }
                    }
                    if Instant::now() >= deadline {
                        match mode {
                            RecvMode::Heal => {
                                if self
                                    .rendezvous
                                    .report_dead(self.view.generation, from as u64)?
                                {
                                    return Err(RingError::HealNeeded.into());
                                }
                                if self.generation_bumped()? && !self.growth_only()? {
                                    return Err(RingError::HealNeeded.into());
                                }
                                // Rejected (the peer heartbeated): extend
                                // and keep waiting, up to three strikes.
                                strikes += 1;
                                if strikes >= 3 {
                                    return Err(RingError::PeerUnresponsive(from).into());
                                }
                                deadline = Instant::now() + self.timeout;
                            }
                            RecvMode::Fail => anyhow::bail!(
                                "ring recv timed out waiting for rank {from} (generation {})",
                                self.view.generation
                            ),
                        }
                    }
                }
                Err(e) => anyhow::bail!("ring data channel: {e}"),
            }
        }
    }

    /// Send `vals` as one or more frames of at most `chunk_elems` each (an
    /// empty slice still sends one empty frame to keep peers in lockstep).
    /// Used by the lockstep collectives; the step machine sends exactly one
    /// frame per segment because segments never exceed `chunk_elems`.
    fn send_chunks(&mut self, to: usize, tag: u64, vals: &[f32]) -> Result<()> {
        if vals.is_empty() {
            return self.send_msg(to, tag, Vec::new());
        }
        for chunk in vals.chunks(self.chunk_elems) {
            self.send_msg(to, tag, f32s_to_bytes(chunk))?;
        }
        Ok(())
    }

    /// Receive exactly `expected` f32 elements under `tag` from `from`
    /// (the mirror of [`RingMember::send_chunks`]).
    fn recv_elems(&mut self, from: usize, tag: u64, expected: usize) -> Result<Vec<f32>> {
        let k = msg_count(expected, self.chunk_elems);
        let mut out = Vec::with_capacity(expected);
        for _ in 0..k {
            let bytes = self.recv_data(from, tag, RecvMode::Fail)?;
            out.extend(bytes_to_f32s(&bytes)?);
        }
        anyhow::ensure!(
            out.len() == expected,
            "ring recv length mismatch from rank {from}: got {}, want {expected}",
            out.len()
        );
        Ok(out)
    }
}

impl Drop for RingMember {
    fn drop(&mut self) {
        if let Some(name) = self.endpoint.strip_prefix("inproc://") {
            INPROC_EP.lock().unwrap().remove(name);
        }
    }
}

/// Frames needed for `len` elements at `chunk` elements per frame (an empty
/// buffer still costs one frame).
fn msg_count(len: usize, chunk: usize) -> usize {
    if len == 0 {
        1
    } else {
        (len + chunk - 1) / chunk
    }
}

/// A 16-byte [`crate::store::ObjId`] as 4 bit-preserving f32 lanes —
/// `from_bits`/`to_bits` plus the `to_le_bytes` framing never reinterpret
/// the value arithmetically, so arbitrary bit patterns (including NaN
/// encodings) survive any f32 broadcast path. Shared by the store-header
/// broadcast and the algorithm state-sync codecs.
pub(crate) fn objid_to_lanes(id: crate::store::ObjId) -> [f32; 4] {
    let b = id.0;
    let word = |i: usize| f32::from_bits(u32::from_le_bytes([b[i], b[i + 1], b[i + 2], b[i + 3]]));
    [word(0), word(4), word(8), word(12)]
}

/// Inverse of [`objid_to_lanes`].
pub(crate) fn objid_from_lanes(lanes: &[f32]) -> crate::store::ObjId {
    let mut b = [0u8; 16];
    for (i, w) in lanes[..4].iter().enumerate() {
        b[i * 4..(i + 1) * 4].copy_from_slice(&w.to_bits().to_le_bytes());
    }
    crate::store::ObjId(b)
}

/// Pack `(ObjId, len)` into 6 f32 lanes, bit-preserving: the header rides
/// the ordinary f32 broadcast path.
pub(crate) fn pack_store_header(id: crate::store::ObjId, len: u64) -> [f32; 6] {
    let [a, b, c, d] = objid_to_lanes(id);
    [
        a,
        b,
        c,
        d,
        f32::from_bits((len & 0xFFFF_FFFF) as u32),
        f32::from_bits((len >> 32) as u32),
    ]
}

pub(crate) fn unpack_store_header(h: &[f32; 6]) -> (crate::store::ObjId, u64) {
    let id = objid_from_lanes(&h[..4]);
    let len = (h[4].to_bits() as u64) | ((h[5].to_bits() as u64) << 32);
    (id, len)
}

pub(crate) fn f32s_to_bytes(vals: &[f32]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    bytes
}

pub(crate) fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "ring payload of {} bytes is not a whole number of f32s",
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

/// [`bytes_to_f32s`] into a reused buffer: `out` is cleared and refilled,
/// so a warm `Vec` decodes with zero allocation. The step-machine hot loop
/// uses this with [`RingMember`]'s double-buffered scratch pair.
pub(crate) fn bytes_to_f32s_into(bytes: &[u8], out: &mut Vec<f32>) -> Result<()> {
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "ring payload of {} bytes is not a whole number of f32s",
        bytes.len()
    );
    out.clear();
    out.reserve(bytes.len() / 4);
    out.extend(
        bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])),
    );
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run `world` members as threads; each runs `f(member)`.
    fn run_ring<T: Send + 'static>(
        world: usize,
        f: impl Fn(RingMember) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let rv = Rendezvous::new(world);
        let f = Arc::new(f);
        let handles: Vec<_> = (0..world)
            .map(|_| {
                let rv = rv.clone();
                let f = f.clone();
                std::thread::spawn(move || {
                    let m = RingMember::join_inproc(&rv).unwrap();
                    f(m)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn member_input(rank: usize, len: usize) -> Vec<f32> {
        (0..len)
            .map(|i| ((rank * 31 + i * 7) % 13) as f32 * 0.25 - 1.5)
            .collect()
    }

    fn reference_sum(world: usize, len: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; len];
        for r in 0..world {
            for (o, v) in out.iter_mut().zip(member_input(r, len)) {
                *o += v;
            }
        }
        out
    }

    #[test]
    fn allreduce_plan_covers_every_segment_once_per_phase() {
        for n in [2usize, 3, 5, 8] {
            for r in 0..n {
                let plan = allreduce_plan(n, r);
                assert_eq!(plan.len(), 2 * (n - 1));
                // The left neighbour's send at step s must be this rank's
                // recv at step s, in both phases.
                let left = (r + n - 1) % n;
                let lplan = allreduce_plan(n, left);
                for (mine, theirs) in plan.iter().zip(&lplan) {
                    assert_eq!(mine.recv_seg, theirs.send_seg, "n={n} r={r}");
                    assert_eq!(mine.phase, theirs.phase);
                }
                // Reduce-scatter ends owning segment (r+1)%n; all-gather
                // first circulates exactly that segment.
                assert_eq!(plan[n - 1].send_seg, (r + 1) % n);
            }
        }
        assert!(allreduce_plan(1, 0).is_empty());
    }

    #[test]
    fn chunk_ranges_partition_exactly() {
        assert_eq!(chunk_ranges(0, 8), vec![(0, 0)]);
        assert_eq!(chunk_ranges(5, 8), vec![(0, 5)]);
        assert_eq!(chunk_ranges(8, 8), vec![(0, 8)]);
        assert_eq!(chunk_ranges(17, 8), vec![(0, 8), (8, 16), (16, 17)]);
        for (i, w) in chunk_ranges(1000, 7).windows(2).enumerate() {
            assert_eq!(w[0].1, w[1].0, "chunk {i} not contiguous");
        }
    }

    #[test]
    fn allreduce_matches_reference_small_worlds() {
        for world in [2usize, 3, 4, 5] {
            // Lengths around segment boundaries, incl. len < world.
            for len in [1usize, 2, 7, 64, 129] {
                let out = run_ring(world, move |mut m| {
                    let mut buf = member_input(m.rank(), len);
                    m.allreduce_sum(&mut buf).unwrap();
                    buf
                });
                let want = reference_sum(world, len);
                for buf in out {
                    for (a, b) in buf.iter().zip(&want) {
                        assert!(
                            (a - b).abs() < 1e-5,
                            "world {world} len {len}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn allreduce_chunked_framing() {
        let out = run_ring(3, |mut m| {
            m.set_chunk_elems(5); // force many chunks through the pipeline
            let mut buf = member_input(m.rank(), 100);
            m.allreduce_sum(&mut buf).unwrap();
            buf
        });
        let want = reference_sum(3, 100);
        for buf in out {
            for (a, b) in buf.iter().zip(&want) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn overlap_off_matches_overlap_on_bitwise() {
        let on = run_ring(4, |mut m| {
            m.set_chunk_elems(16);
            let mut buf = member_input(m.rank(), 200);
            m.allreduce_sum(&mut buf).unwrap();
            assert!(
                m.overlap_efficiency() > 0.5,
                "multi-chunk overlap run should pipeline: {}",
                m.overlap_efficiency()
            );
            buf
        });
        let off = run_ring(4, |mut m| {
            m.set_chunk_elems(16);
            m.set_overlap(false);
            let mut buf = member_input(m.rank(), 200);
            m.allreduce_sum(&mut buf).unwrap();
            assert_eq!(m.overlap_efficiency(), 0.0);
            buf
        });
        // Same per-chunk summation order → bitwise-identical results.
        for (a, b) in on.iter().zip(&off) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn allreduce_world_one_is_identity() {
        let out = run_ring(1, |mut m| {
            let mut buf = vec![1.0f32, 2.0, 3.0];
            m.allreduce_sum(&mut buf).unwrap();
            buf
        });
        assert_eq!(out[0], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn allreduce_over_tcp_endpoints() {
        let rv = Rendezvous::new(3);
        let srv = rv.serve_rpc("127.0.0.1:0").unwrap();
        let addr = Addr::Tcp(srv.local_addr());
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut m = RingMember::join_addr(&addr).unwrap();
                    let mut buf = member_input(m.rank(), 50);
                    m.allreduce_sum(&mut buf).unwrap();
                    buf
                })
            })
            .collect();
        let want = reference_sum(3, 50);
        for h in handles {
            let buf = h.join().unwrap();
            for (a, b) in buf.iter().zip(&want) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn broadcast_distributes_root_buffer() {
        let out = run_ring(4, |mut m| {
            let mut buf = if m.rank() == 2 {
                member_input(2, 33)
            } else {
                vec![0.0; 33]
            };
            m.broadcast(2, &mut buf).unwrap();
            buf
        });
        let want = member_input(2, 33);
        for buf in out {
            assert_eq!(buf, want);
        }
    }

    #[test]
    fn all_gather_concatenates_in_rank_order() {
        let out = run_ring(4, |mut m| {
            let mine = member_input(m.rank(), 6);
            m.all_gather(&mine).unwrap()
        });
        let mut want = Vec::new();
        for r in 0..4 {
            want.extend(member_input(r, 6));
        }
        for buf in out {
            assert_eq!(buf, want);
        }
    }

    #[test]
    fn gather_broadcast_matches_allreduce_and_shows_root_hotspot() {
        let world = 4;
        let len = 256;
        let out = run_ring(world, move |mut m| {
            let mut ring_buf = member_input(m.rank(), len);
            m.allreduce_sum(&mut ring_buf).unwrap();
            let ring_bytes = m.bytes_sent() + m.bytes_received();
            m.reset_counters();
            let mut naive_buf = member_input(m.rank(), len);
            m.gather_broadcast_sum(0, &mut naive_buf).unwrap();
            let naive_bytes = m.bytes_sent() + m.bytes_received();
            (m.rank(), ring_buf, naive_buf, ring_bytes, naive_bytes)
        });
        let want = reference_sum(world, len);
        let mut ring_max = 0;
        let mut root_naive = 0;
        for (rank, ring_buf, naive_buf, ring_bytes, naive_bytes) in out {
            for ((a, b), c) in ring_buf.iter().zip(&naive_buf).zip(&want) {
                assert!((a - c).abs() < 1e-4 && (b - c).abs() < 1e-4);
            }
            ring_max = ring_bytes.max(ring_max);
            if rank == 0 {
                root_naive = naive_bytes;
            }
        }
        // Ring: ~2(n-1)/n·θ per member. Naive root: 2(n-1)·θ — n× hotter.
        let theta_bytes = (len * 4) as u64;
        assert_eq!(root_naive, 2 * (world as u64 - 1) * theta_bytes);
        assert!(
            ring_max < root_naive,
            "ring per-member traffic {ring_max} must undercut naive root {root_naive}"
        );
    }

    #[test]
    fn collectives_compose_in_sequence() {
        let out = run_ring(3, |mut m| {
            let mut a = vec![m.rank() as f32; 10];
            m.allreduce_sum(&mut a).unwrap(); // 0+1+2 = 3
            let mut b = vec![if m.rank() == 0 { 7.0 } else { 0.0 }; 4];
            m.broadcast(0, &mut b).unwrap();
            let g = m.all_gather(&[m.rank() as f32]).unwrap();
            let mut c = vec![1.0f32; 5];
            m.allreduce_mean(&mut c).unwrap();
            (a, b, g, c)
        });
        for (a, b, g, c) in out {
            assert_eq!(a, vec![3.0; 10]);
            assert_eq!(b, vec![7.0; 4]);
            assert_eq!(g, vec![0.0, 1.0, 2.0]);
            assert_eq!(c, vec![1.0; 5]);
        }
    }

    #[test]
    fn kill_one_member_heals_and_resumes_from_completed_chunks() {
        // World 3, 4 chunks of 8 elems; rank 2 dies after completing chunk
        // 1. Survivors must finish with chunks 0–1 holding the full 3-way
        // sum (banked work) and chunks 2–3 the survivors' 2-way sum.
        let world = 3;
        let len = 32;
        let rv = Rendezvous::new(world);
        rv.set_heartbeat_grace(Duration::from_millis(40));
        let handles: Vec<_> = (0..world)
            .map(|_| {
                let rv = rv.clone();
                std::thread::spawn(move || {
                    let mut m = RingMember::join_inproc(&rv).unwrap();
                    m.set_chunk_elems(8);
                    m.set_timeout(Duration::from_millis(250));
                    m.set_probe_interval(Duration::from_millis(10));
                    let victim = m.rank() == 2;
                    if victim {
                        m.set_kill_after_chunk(Some(1));
                    }
                    let mut buf = member_input(m.rank(), len);
                    match m.allreduce_sum(&mut buf) {
                        Ok(()) => {
                            assert!(!victim, "victim must not survive");
                            Some((m.rank(), m.world(), m.generation(), m.heal_count(), buf))
                        }
                        Err(e) => {
                            assert!(victim, "survivor failed: {e:#}");
                            assert!(is_chaos_killed(&e), "unexpected fault: {e:#}");
                            None // crash: drop the member without leave()
                        }
                    }
                })
            })
            .collect();
        let mut survivors: Vec<_> = handles
            .into_iter()
            .filter_map(|h| h.join().unwrap())
            .collect();
        survivors.sort_by_key(|s| s.0);
        assert_eq!(survivors.len(), 2);
        let full = reference_sum(3, len);
        let mut partial = vec![0.0f32; len];
        for r in [0usize, 1] {
            for (o, v) in partial.iter_mut().zip(member_input(r, len)) {
                *o += v;
            }
        }
        for (_, w, generation, heals, buf) in &survivors {
            assert_eq!(*w, 2, "world must shrink to the survivors");
            assert_eq!(*generation, 1, "healing bumps the generation");
            assert_eq!(*heals, 1);
            for (i, v) in buf.iter().enumerate() {
                let want = if i < 16 { full[i] } else { partial[i] };
                assert!(
                    (v - want).abs() < 1e-5,
                    "elem {i}: got {v}, want {want} (full {} / partial {})",
                    full[i],
                    partial[i]
                );
            }
        }
        // Survivors agree bitwise.
        assert_eq!(survivors[0].4, survivors[1].4);
    }

    #[test]
    fn kill_with_spare_heals_and_autogrows_mid_allreduce() {
        // World 3 + 1 spare, 4 chunks of 8; rank 2 dies after chunk 1.
        // The heal drains the spare: world returns to 3, the collective
        // resumes via the min-barrier with the rejoiner relaying zeros.
        // Survivors: chunks 0–1 keep the 3-way sum (banked), chunks 2–3
        // re-reduce over the two survivors (+ the rejoiner's zeros).
        let world = 3;
        let len = 32;
        let rv = Rendezvous::new(world);
        rv.set_heartbeat_grace(Duration::from_millis(40));
        let spare_rv = rv.clone();
        let spare = std::thread::spawn(move || {
            let mut m =
                RingMember::join_spare_inproc(&spare_rv, Duration::from_secs(10)).unwrap();
            m.set_chunk_elems(8);
            m.set_timeout(Duration::from_millis(250));
            m.set_probe_interval(Duration::from_millis(10));
            let cold = m.cold_op().cloned().expect("drained mid-op");
            assert_eq!(cold.op.kind, KIND_ALLREDUCE);
            assert_eq!(cold.op.elems as usize, len);
            assert!(cold.resume_chunk >= 1, "min-barrier must bank completed chunks");
            let mut buf = vec![0.0f32; len];
            m.allreduce_sum(&mut buf).unwrap();
            (m.rank(), m.world(), m.generation(), cold.resume_chunk, buf)
        });
        // Gate: the spare must be pending before the chaos kill can heal,
        // or the drain finds an empty pool and the spare is never drafted.
        while rv.spares().is_empty() {
            std::thread::sleep(Duration::from_millis(1));
        }
        let handles: Vec<_> = (0..world)
            .map(|_| {
                let rv = rv.clone();
                std::thread::spawn(move || {
                    let mut m = RingMember::join_inproc(&rv).unwrap();
                    m.set_chunk_elems(8);
                    m.set_timeout(Duration::from_millis(250));
                    m.set_probe_interval(Duration::from_millis(10));
                    let victim = m.rank() == 2;
                    if victim {
                        m.set_kill_after_chunk(Some(1));
                    }
                    let mut buf = member_input(m.rank(), len);
                    match m.allreduce_sum(&mut buf) {
                        Ok(()) => Some((m.rank(), m.world(), m.generation(), buf)),
                        Err(e) => {
                            assert!(victim && is_chaos_killed(&e), "{e:#}");
                            None
                        }
                    }
                })
            })
            .collect();
        let mut survivors: Vec<_> = handles
            .into_iter()
            .filter_map(|h| h.join().unwrap())
            .collect();
        survivors.sort_by_key(|s| s.0);
        assert_eq!(survivors.len(), 2);
        let full = reference_sum(3, len);
        let mut partial = vec![0.0f32; len];
        for r in [0usize, 1] {
            for (o, v) in partial.iter_mut().zip(member_input(r, len)) {
                *o += v;
            }
        }
        let (s_rank, s_world, s_gen, resume_chunk, s_buf) = spare.join().unwrap();
        assert_eq!(s_rank, 2, "the rejoiner takes the appended rank");
        assert_eq!(s_world, 3, "auto-grow restores the original world size");
        assert_eq!(s_gen, 1);
        let boundary = (resume_chunk * 8) as usize;
        for (rank, w, generation, buf) in &survivors {
            assert_eq!(*w, 3, "survivors see the grown world too");
            assert_eq!(*generation, 1);
            for (i, v) in buf.iter().enumerate() {
                let want = if i < boundary { full[i] } else { partial[i] };
                assert!(
                    (v - want).abs() < 1e-5,
                    "rank {rank} elem {i}: got {v}, want {want}"
                );
            }
        }
        assert_eq!(survivors[0].3, survivors[1].3, "survivors agree bitwise");
        // The rejoiner's resumed chunks hold the survivors' sum (its own
        // contribution was the identity element); banked chunks stay cold
        // (zeros — it never saw them).
        for (i, v) in s_buf.iter().enumerate() {
            let want = if i < boundary { 0.0 } else { partial[i] };
            assert!((v - want).abs() < 1e-5, "rejoiner elem {i}: got {v}, want {want}");
        }
    }

    #[test]
    fn store_header_roundtrips_bitwise() {
        use crate::store::ObjId;
        for (seed, len) in [
            (b"a".as_slice(), 0u64),
            (b"bb".as_slice(), 7),
            (b"ccc".as_slice(), u64::MAX >> 3),
        ] {
            let id = ObjId::of(seed);
            let h = pack_store_header(id, len);
            assert_eq!(unpack_store_header(&h), (id, len));
        }
    }

    #[test]
    fn store_broadcast_delivers_then_cache_hits() {
        use crate::store::StoreNode;
        // One serving host node (rank 0's) + one connected node per other
        // member: the cold pass transfers once per non-root node, the warm
        // pass moves no payload at all.
        let host = StoreNode::host(64 << 20);
        let host_ep = host.serve("127.0.0.1:0").unwrap();
        let rv = Rendezvous::new(3);
        let want = member_input(0, 500);
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let rv = rv.clone();
                let host = host.clone();
                let host_ep = host_ep.clone();
                let want = want.clone();
                std::thread::spawn(move || {
                    let mut m = RingMember::join_inproc(&rv).unwrap();
                    let node = if m.rank() == 0 {
                        host
                    } else {
                        StoreNode::connect(&host_ep, 64 << 20).unwrap()
                    };
                    let mut buf = if m.rank() == 0 {
                        want.clone()
                    } else {
                        vec![0.0f32; 500]
                    };
                    let id1 = m.store_broadcast(&node, 0, &mut buf).unwrap();
                    assert_eq!(buf, want);
                    let cold = node.transfers();
                    let mut buf2 = if m.rank() == 0 {
                        want.clone()
                    } else {
                        vec![0.0f32; 500]
                    };
                    let id2 = m.store_broadcast(&node, 0, &mut buf2).unwrap();
                    assert_eq!(id1, id2, "content addressing: same payload, same id");
                    assert_eq!(buf2, want);
                    assert_eq!(node.transfers(), cold, "warm pass must not re-transfer");
                    (m.rank(), cold)
                })
            })
            .collect();
        for h in handles {
            let (rank, cold) = h.join().unwrap();
            let expect = u64::from(rank != 0);
            assert_eq!(cold, expect, "rank {rank}: one cold transfer per non-root node");
        }
    }

    #[test]
    fn msg_count_boundaries() {
        assert_eq!(msg_count(0, 8), 1);
        assert_eq!(msg_count(1, 8), 1);
        assert_eq!(msg_count(8, 8), 1);
        assert_eq!(msg_count(9, 8), 2);
    }

    #[test]
    fn f32_bytes_roundtrip_and_reject_ragged() {
        let vals = vec![1.5f32, -2.25, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&vals)).unwrap(), vals);
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }
}
