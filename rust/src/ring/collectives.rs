//! Collective operations over a sealed ring: chunked ring allreduce
//! (reduce-scatter + all-gather), broadcast, all-gather, and the naive
//! gather-broadcast baseline the benches compare against.
//!
//! A [`RingMember`] owns one data-plane endpoint (an `inproc://` channel on
//! the thread backend, a [`crate::comms::rpc`] server on the OS-process
//! backend) and lazily-connected links to its peers. Collectives are SPMD:
//! **every member of a generation must call the same collectives in the
//! same order with the same buffer lengths and the same `chunk_elems`** —
//! the op-sequence number baked into message tags keeps concurrent steps
//! apart, not divergent programs.
//!
//! Cost model (θ = buffer elements, n = world): ring allreduce moves
//! `2·(n-1)/n·θ` elements through every member — no hot spot — while the
//! gather-broadcast baseline moves `2·(n-1)·θ` through the root. The
//! per-member [`RingMember::bytes_sent`]/[`RingMember::bytes_received`]
//! counters make that asymmetry measurable in `benches/ring_allreduce.rs`.

use std::collections::{HashMap, VecDeque};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use once_cell::sync::Lazy;

use crate::comms::chan::{self, Receiver, Sender};
use crate::comms::rpc::{RpcClient, RpcServer};
use crate::comms::Addr;
use crate::wire;

use super::topology::{Rendezvous, RendezvousClient, RingView};

/// RPC tag carrying one data-plane message on TCP endpoints.
pub const DATA_TAG: u32 = 1;

/// A data-plane message: `(from_rank, op_tag, payload)`.
type Msg = (u64, u64, Vec<u8>);

/// Global registry of `inproc://` data endpoints (thread backend).
static INPROC_EP: Lazy<Mutex<HashMap<String, Sender<Msg>>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

static EP_SEQ: AtomicU64 = AtomicU64::new(1);

/// How a member exposes its data-plane endpoint.
pub enum Transport {
    /// An in-process channel (thread backend).
    Inproc,
    /// Bind a TCP RPC server at this address (OS-process backend); use port
    /// 0 for an ephemeral port. The advertised endpoint is the bound
    /// address, so bind a peer-reachable interface.
    TcpBind(String),
}

enum PeerTx {
    Inproc(Sender<Msg>),
    Tcp(RpcClient),
}

/// One ranked member of a sealed ring generation.
pub struct RingMember {
    view: RingView,
    rendezvous: RendezvousClient,
    endpoint: String,
    rx: Receiver<Msg>,
    _server: Option<RpcServer>,
    peers: HashMap<usize, PeerTx>,
    stash: VecDeque<Msg>,
    op_seq: u64,
    chunk_elems: usize,
    timeout: Duration,
    bytes_tx: u64,
    bytes_rx: u64,
}

impl RingMember {
    /// Join through an already-held in-process rendezvous (thread backend).
    pub fn join_inproc(rv: &Arc<Rendezvous>) -> Result<RingMember> {
        Self::join_with(RendezvousClient::local(rv.clone()), Transport::Inproc)
    }

    /// Join a rendezvous at `addr` (`inproc://…` or `tcp://…`), exposing a
    /// TCP data endpoint when the rendezvous itself is remote. The data
    /// endpoint binds loopback, which serves the single-host OS-process
    /// backend; **multi-host members must use [`RingMember::join_addr_bind`]
    /// with an interface their peers can route to**, since the bound
    /// address is what gets advertised to the ring.
    pub fn join_addr(addr: &Addr) -> Result<RingMember> {
        Self::join_addr_bind(addr, "127.0.0.1:0")
    }

    /// [`RingMember::join_addr`] with an explicit TCP bind address for the
    /// data endpoint (e.g. `10.0.0.7:0` on a cluster node). Ignored when
    /// the rendezvous is `inproc://`.
    pub fn join_addr_bind(addr: &Addr, tcp_bind: &str) -> Result<RingMember> {
        let transport = match addr {
            Addr::Inproc(_) => Transport::Inproc,
            Addr::Tcp(_) => Transport::TcpBind(tcp_bind.to_string()),
        };
        Self::join_with(RendezvousClient::connect(addr)?, transport)
    }

    /// Join with explicit rendezvous client + data transport.
    pub fn join_with(rendezvous: RendezvousClient, transport: Transport) -> Result<RingMember> {
        let (tx, rx) = chan::unbounded::<Msg>();
        let (endpoint, server) = match transport {
            Transport::Inproc => {
                let name = format!("ring-ep-{}", EP_SEQ.fetch_add(1, Ordering::Relaxed));
                INPROC_EP.lock().unwrap().insert(name.clone(), tx);
                (format!("inproc://{name}"), None)
            }
            Transport::TcpBind(bind) => {
                let srv = RpcServer::bind(
                    &bind,
                    Arc::new(move |tag, payload| {
                        if tag != DATA_TAG {
                            return Err(format!("bad ring data tag {tag}"));
                        }
                        let msg: Msg = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                        tx.send(msg).map_err(|e| e.to_string())?;
                        Ok(Vec::new())
                    }),
                )?;
                (format!("tcp://{}", srv.local_addr()), Some(srv))
            }
        };
        let view = match rendezvous.join(&endpoint, Duration::from_secs(30)) {
            Ok(v) => v,
            Err(e) => {
                if let Some(name) = endpoint.strip_prefix("inproc://") {
                    INPROC_EP.lock().unwrap().remove(name);
                }
                return Err(e);
            }
        };
        Ok(RingMember {
            view,
            rendezvous,
            endpoint,
            rx,
            _server: server,
            peers: HashMap::new(),
            stash: VecDeque::new(),
            op_seq: 0,
            chunk_elems: 1 << 15, // 128 KiB frames
            timeout: Duration::from_secs(30),
            bytes_tx: 0,
            bytes_rx: 0,
        })
    }

    pub fn rank(&self) -> usize {
        self.view.rank
    }

    pub fn world(&self) -> usize {
        self.view.world
    }

    pub fn generation(&self) -> u64 {
        self.view.generation
    }

    pub fn view(&self) -> &RingView {
        &self.view
    }

    /// Payload bytes sent / received by this member so far.
    pub fn bytes_sent(&self) -> u64 {
        self.bytes_tx
    }

    pub fn bytes_received(&self) -> u64 {
        self.bytes_rx
    }

    pub fn reset_counters(&mut self) {
        self.bytes_tx = 0;
        self.bytes_rx = 0;
    }

    /// Maximum `f32`s per frame (must agree across all members).
    pub fn set_chunk_elems(&mut self, elems: usize) {
        self.chunk_elems = elems.max(1);
    }

    pub fn set_timeout(&mut self, timeout: Duration) {
        self.timeout = timeout;
    }

    /// Announce departure: bumps the ring generation so survivors
    /// re-rendezvous (pair with [`RendezvousClient::resize`] on scale-down).
    pub fn leave(&mut self) -> Result<()> {
        self.rendezvous
            .leave(self.view.generation, self.view.rank as u64)
    }

    // ---- collectives -----------------------------------------------------

    /// In-place elementwise sum across all members (chunked ring
    /// allreduce: reduce-scatter then all-gather, `2·(n-1)` pipeline steps).
    pub fn allreduce_sum(&mut self, buf: &mut [f32]) -> Result<()> {
        let n = self.view.world;
        if n == 1 {
            return Ok(());
        }
        let op = self.next_op();
        let r = self.view.rank;
        let right = self.view.right();
        let left = self.view.left();
        let bounds: Vec<(usize, usize)> = (0..n)
            .map(|i| (i * buf.len() / n, (i + 1) * buf.len() / n))
            .collect();
        // Reduce-scatter: after step s, the received segment holds the sum
        // of s+2 contributions; after n-1 steps rank r fully owns segment
        // (r+1) mod n.
        for s in 0..n - 1 {
            let tag = op | s as u64;
            let (lo, hi) = bounds[(r + n - s) % n];
            self.send_chunks(right, tag, &buf[lo..hi])?;
            let (lo, hi) = bounds[(r + 2 * n - s - 1) % n];
            let incoming = self.recv_elems(left, tag, hi - lo)?;
            for (d, v) in buf[lo..hi].iter_mut().zip(&incoming) {
                *d += *v;
            }
        }
        // All-gather: circulate the fully-reduced segments.
        for s in 0..n - 1 {
            let tag = op | (n - 1 + s) as u64;
            let (lo, hi) = bounds[(r + 1 + n - s) % n];
            self.send_chunks(right, tag, &buf[lo..hi])?;
            let (lo, hi) = bounds[(r + n - s) % n];
            let incoming = self.recv_elems(left, tag, hi - lo)?;
            buf[lo..hi].copy_from_slice(&incoming);
        }
        Ok(())
    }

    /// Allreduce then divide by the world size (data-parallel averaging).
    pub fn allreduce_mean(&mut self, buf: &mut [f32]) -> Result<()> {
        self.allreduce_sum(buf)?;
        let inv = 1.0 / self.view.world as f32;
        for v in buf.iter_mut() {
            *v *= inv;
        }
        Ok(())
    }

    /// Pipelined ring broadcast of `root`'s buffer into every member's.
    pub fn broadcast(&mut self, root: usize, buf: &mut [f32]) -> Result<()> {
        let n = self.view.world;
        anyhow::ensure!(root < n, "broadcast root {root} out of range (world {n})");
        if n == 1 {
            return Ok(());
        }
        let op = self.next_op();
        let right = self.view.right();
        let left = self.view.left();
        if self.view.rank == root {
            self.send_chunks(right, op, buf)?;
        } else {
            let k = msg_count(buf.len(), self.chunk_elems);
            let mut pos = 0;
            for _ in 0..k {
                let bytes = self.recv_msg(left, op)?;
                let vals = bytes_to_f32s(&bytes)?;
                anyhow::ensure!(
                    pos + vals.len() <= buf.len(),
                    "broadcast overflow: peer sent more than the local buffer holds"
                );
                buf[pos..pos + vals.len()].copy_from_slice(&vals);
                pos += vals.len();
                if right != root {
                    // Forward the still-encoded chunk immediately (pipeline).
                    self.send_msg(right, op, bytes)?;
                }
            }
            anyhow::ensure!(
                pos == buf.len(),
                "broadcast length mismatch: got {pos}, want {}",
                buf.len()
            );
        }
        Ok(())
    }

    /// Ring all-gather: every member contributes `mine` (equal lengths
    /// across members); returns the world's contributions concatenated in
    /// rank order.
    pub fn all_gather(&mut self, mine: &[f32]) -> Result<Vec<f32>> {
        let n = self.view.world;
        let len = mine.len();
        let r = self.view.rank;
        let mut out = vec![0.0f32; n * len];
        out[r * len..(r + 1) * len].copy_from_slice(mine);
        if n == 1 {
            return Ok(out);
        }
        let op = self.next_op();
        let right = self.view.right();
        let left = self.view.left();
        for s in 0..n - 1 {
            let tag = op | s as u64;
            let send_seg = (r + n - s) % n;
            let recv_seg = (r + 2 * n - 1 - s) % n;
            self.send_chunks(right, tag, &out[send_seg * len..(send_seg + 1) * len])?;
            let incoming = self.recv_elems(left, tag, len)?;
            out[recv_seg * len..(recv_seg + 1) * len].copy_from_slice(&incoming);
        }
        Ok(out)
    }

    /// The leader-centric baseline: every member ships its full buffer to
    /// `root`, which sums and ships the result back — `O(n·θ)` at the root.
    /// Same result as [`RingMember::allreduce_sum`] up to summation order;
    /// exists as the comparison target for `benches/ring_allreduce.rs`.
    pub fn gather_broadcast_sum(&mut self, root: usize, buf: &mut [f32]) -> Result<()> {
        let n = self.view.world;
        anyhow::ensure!(root < n, "root {root} out of range (world {n})");
        if n == 1 {
            return Ok(());
        }
        let op = self.next_op();
        if self.view.rank == root {
            for other in 0..n {
                if other == root {
                    continue;
                }
                let incoming = self.recv_elems(other, op, buf.len())?;
                for (d, v) in buf.iter_mut().zip(&incoming) {
                    *d += *v;
                }
            }
            for other in 0..n {
                if other == root {
                    continue;
                }
                self.send_chunks(other, op | 1 << 8, buf)?;
            }
        } else {
            self.send_chunks(root, op, buf)?;
            let incoming = self.recv_elems(root, op | 1 << 8, buf.len())?;
            buf.copy_from_slice(&incoming);
        }
        Ok(())
    }

    // ---- plumbing --------------------------------------------------------

    /// Per-collective namespace for message tags: high 48 bits are the op
    /// sequence number, low 16 the phase/step within the op.
    fn next_op(&mut self) -> u64 {
        self.op_seq += 1;
        self.op_seq << 16
    }

    fn peer(&mut self, to: usize) -> Result<&PeerTx> {
        if !self.peers.contains_key(&to) {
            let addr = self
                .view
                .members
                .get(to)
                .with_context(|| format!("no ring member at rank {to}"))?;
            let link = match addr {
                Addr::Inproc(name) => {
                    let tx = INPROC_EP
                        .lock()
                        .unwrap()
                        .get(name)
                        .cloned()
                        .with_context(|| format!("ring endpoint inproc://{name} is gone"))?;
                    PeerTx::Inproc(tx)
                }
                Addr::Tcp(sa) => PeerTx::Tcp(RpcClient::connect(*sa)?),
            };
            self.peers.insert(to, link);
        }
        Ok(&self.peers[&to])
    }

    fn send_msg(&mut self, to: usize, tag: u64, bytes: Vec<u8>) -> Result<()> {
        let from = self.view.rank as u64;
        let len = bytes.len() as u64;
        match self.peer(to)? {
            PeerTx::Inproc(tx) => {
                tx.send((from, tag, bytes))
                    .map_err(|e| anyhow::anyhow!("ring send to rank {to}: {e}"))?;
            }
            PeerTx::Tcp(cli) => {
                cli.call(DATA_TAG, &wire::to_bytes(&(from, tag, bytes)))
                    .with_context(|| format!("ring send to rank {to}"))?;
            }
        }
        self.bytes_tx += len;
        Ok(())
    }

    /// Send `vals` as one or more frames of at most `chunk_elems` each (an
    /// empty slice still sends one empty frame to keep peers in lockstep).
    fn send_chunks(&mut self, to: usize, tag: u64, vals: &[f32]) -> Result<()> {
        if vals.is_empty() {
            return self.send_msg(to, tag, Vec::new());
        }
        for chunk in vals.chunks(self.chunk_elems) {
            self.send_msg(to, tag, f32s_to_bytes(chunk))?;
        }
        Ok(())
    }

    /// Next message from `from` with tag `tag`, buffering whatever else
    /// arrives in the meantime.
    fn recv_msg(&mut self, from: usize, tag: u64) -> Result<Vec<u8>> {
        if let Some(pos) = self
            .stash
            .iter()
            .position(|m| m.0 == from as u64 && m.1 == tag)
        {
            let msg = self.stash.remove(pos).unwrap();
            self.bytes_rx += msg.2.len() as u64;
            return Ok(msg.2);
        }
        let deadline = Instant::now() + self.timeout;
        loop {
            let now = Instant::now();
            anyhow::ensure!(
                now < deadline,
                "ring recv timed out waiting for rank {from} (generation {})",
                self.view.generation
            );
            match self.rx.recv_timeout(deadline - now) {
                Ok(msg) => {
                    if msg.0 == from as u64 && msg.1 == tag {
                        self.bytes_rx += msg.2.len() as u64;
                        return Ok(msg.2);
                    }
                    self.stash.push_back(msg);
                }
                Err(chan::RecvError::Timeout) => continue,
                Err(e) => anyhow::bail!("ring data channel: {e}"),
            }
        }
    }

    /// Receive exactly `expected` f32 elements under `tag` from `from`
    /// (the mirror of [`RingMember::send_chunks`]).
    fn recv_elems(&mut self, from: usize, tag: u64, expected: usize) -> Result<Vec<f32>> {
        let k = msg_count(expected, self.chunk_elems);
        let mut out = Vec::with_capacity(expected);
        for _ in 0..k {
            let bytes = self.recv_msg(from, tag)?;
            out.extend(bytes_to_f32s(&bytes)?);
        }
        anyhow::ensure!(
            out.len() == expected,
            "ring recv length mismatch from rank {from}: got {}, want {expected}",
            out.len()
        );
        Ok(out)
    }
}

impl Drop for RingMember {
    fn drop(&mut self) {
        if let Some(name) = self.endpoint.strip_prefix("inproc://") {
            INPROC_EP.lock().unwrap().remove(name);
        }
    }
}

/// Frames needed for `len` elements at `chunk` elements per frame (an empty
/// buffer still costs one frame).
fn msg_count(len: usize, chunk: usize) -> usize {
    if len == 0 {
        1
    } else {
        (len + chunk - 1) / chunk
    }
}

fn f32s_to_bytes(vals: &[f32]) -> Vec<u8> {
    let mut bytes = Vec::with_capacity(vals.len() * 4);
    for v in vals {
        bytes.extend_from_slice(&v.to_le_bytes());
    }
    bytes
}

fn bytes_to_f32s(bytes: &[u8]) -> Result<Vec<f32>> {
    anyhow::ensure!(
        bytes.len() % 4 == 0,
        "ring payload of {} bytes is not a whole number of f32s",
        bytes.len()
    );
    Ok(bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Run `world` members as threads; each runs `f(member)`.
    fn run_ring<T: Send + 'static>(
        world: usize,
        f: impl Fn(RingMember) -> T + Send + Sync + 'static,
    ) -> Vec<T> {
        let rv = Rendezvous::new(world);
        let f = Arc::new(f);
        let handles: Vec<_> = (0..world)
            .map(|_| {
                let rv = rv.clone();
                let f = f.clone();
                std::thread::spawn(move || {
                    let m = RingMember::join_inproc(&rv).unwrap();
                    f(m)
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    fn member_input(rank: usize, len: usize) -> Vec<f32> {
        (0..len)
            .map(|i| ((rank * 31 + i * 7) % 13) as f32 * 0.25 - 1.5)
            .collect()
    }

    fn reference_sum(world: usize, len: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; len];
        for r in 0..world {
            for (o, v) in out.iter_mut().zip(member_input(r, len)) {
                *o += v;
            }
        }
        out
    }

    #[test]
    fn allreduce_matches_reference_small_worlds() {
        for world in [2usize, 3, 4, 5] {
            // Lengths around segment boundaries, incl. len < world.
            for len in [1usize, 2, 7, 64, 129] {
                let out = run_ring(world, move |mut m| {
                    let mut buf = member_input(m.rank(), len);
                    m.allreduce_sum(&mut buf).unwrap();
                    buf
                });
                let want = reference_sum(world, len);
                for buf in out {
                    for (a, b) in buf.iter().zip(&want) {
                        assert!(
                            (a - b).abs() < 1e-5,
                            "world {world} len {len}: {a} vs {b}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn allreduce_chunked_framing() {
        let out = run_ring(3, |mut m| {
            m.set_chunk_elems(5); // force many frames per segment
            let mut buf = member_input(m.rank(), 100);
            m.allreduce_sum(&mut buf).unwrap();
            buf
        });
        let want = reference_sum(3, 100);
        for buf in out {
            for (a, b) in buf.iter().zip(&want) {
                assert!((a - b).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn allreduce_world_one_is_identity() {
        let out = run_ring(1, |mut m| {
            let mut buf = vec![1.0f32, 2.0, 3.0];
            m.allreduce_sum(&mut buf).unwrap();
            buf
        });
        assert_eq!(out[0], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn allreduce_over_tcp_endpoints() {
        let rv = Rendezvous::new(3);
        let srv = rv.serve_rpc("127.0.0.1:0").unwrap();
        let addr = Addr::Tcp(srv.local_addr());
        let handles: Vec<_> = (0..3)
            .map(|_| {
                let addr = addr.clone();
                std::thread::spawn(move || {
                    let mut m = RingMember::join_addr(&addr).unwrap();
                    let mut buf = member_input(m.rank(), 50);
                    m.allreduce_sum(&mut buf).unwrap();
                    buf
                })
            })
            .collect();
        let want = reference_sum(3, 50);
        for h in handles {
            let buf = h.join().unwrap();
            for (a, b) in buf.iter().zip(&want) {
                assert!((a - b).abs() < 1e-5, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn broadcast_distributes_root_buffer() {
        let out = run_ring(4, |mut m| {
            let mut buf = if m.rank() == 2 {
                member_input(2, 33)
            } else {
                vec![0.0; 33]
            };
            m.broadcast(2, &mut buf).unwrap();
            buf
        });
        let want = member_input(2, 33);
        for buf in out {
            assert_eq!(buf, want);
        }
    }

    #[test]
    fn all_gather_concatenates_in_rank_order() {
        let out = run_ring(4, |mut m| {
            let mine = member_input(m.rank(), 6);
            m.all_gather(&mine).unwrap()
        });
        let mut want = Vec::new();
        for r in 0..4 {
            want.extend(member_input(r, 6));
        }
        for buf in out {
            assert_eq!(buf, want);
        }
    }

    #[test]
    fn gather_broadcast_matches_allreduce_and_shows_root_hotspot() {
        let world = 4;
        let len = 256;
        let out = run_ring(world, move |mut m| {
            let mut ring_buf = member_input(m.rank(), len);
            m.allreduce_sum(&mut ring_buf).unwrap();
            let ring_bytes = m.bytes_sent() + m.bytes_received();
            m.reset_counters();
            let mut naive_buf = member_input(m.rank(), len);
            m.gather_broadcast_sum(0, &mut naive_buf).unwrap();
            let naive_bytes = m.bytes_sent() + m.bytes_received();
            (m.rank(), ring_buf, naive_buf, ring_bytes, naive_bytes)
        });
        let want = reference_sum(world, len);
        let mut ring_max = 0;
        let mut root_naive = 0;
        for (rank, ring_buf, naive_buf, ring_bytes, naive_bytes) in out {
            for ((a, b), c) in ring_buf.iter().zip(&naive_buf).zip(&want) {
                assert!((a - c).abs() < 1e-4 && (b - c).abs() < 1e-4);
            }
            ring_max = ring_bytes.max(ring_max);
            if rank == 0 {
                root_naive = naive_bytes;
            }
        }
        // Ring: ~2(n-1)/n·θ per member. Naive root: 2(n-1)·θ — n× hotter.
        let theta_bytes = (len * 4) as u64;
        assert_eq!(root_naive, 2 * (world as u64 - 1) * theta_bytes);
        assert!(
            ring_max < root_naive,
            "ring per-member traffic {ring_max} must undercut naive root {root_naive}"
        );
    }

    #[test]
    fn collectives_compose_in_sequence() {
        let out = run_ring(3, |mut m| {
            let mut a = vec![m.rank() as f32; 10];
            m.allreduce_sum(&mut a).unwrap(); // 0+1+2 = 3
            let mut b = vec![if m.rank() == 0 { 7.0 } else { 0.0 }; 4];
            m.broadcast(0, &mut b).unwrap();
            let g = m.all_gather(&[m.rank() as f32]).unwrap();
            let mut c = vec![1.0f32; 5];
            m.allreduce_mean(&mut c).unwrap();
            (a, b, g, c)
        });
        for (a, b, g, c) in out {
            assert_eq!(a, vec![3.0; 10]);
            assert_eq!(b, vec![7.0; 4]);
            assert_eq!(g, vec![0.0, 1.0, 2.0]);
            assert_eq!(c, vec![1.0; 5]);
        }
    }

    #[test]
    fn msg_count_boundaries() {
        assert_eq!(msg_count(0, 8), 1);
        assert_eq!(msg_count(1, 8), 1);
        assert_eq!(msg_count(8, 8), 1);
        assert_eq!(msg_count(9, 8), 2);
    }

    #[test]
    fn f32_bytes_roundtrip_and_reject_ragged() {
        let vals = vec![1.5f32, -2.25, f32::MIN_POSITIVE];
        assert_eq!(bytes_to_f32s(&f32s_to_bytes(&vals)).unwrap(), vals);
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }
}
