//! Vectorized elementwise kernels for the collective hot path.
//!
//! Every ring collective bottoms out in a handful of dense `f32` loops:
//! the reduce-scatter sum, gradient averaging (scale by `1/n`), the ES
//! rank-weighted noise accumulation (axpy), and statistic merges. The
//! naive `for (d, v) in dst.iter_mut().zip(src)` form optimizes poorly —
//! the compiler must prove the slices disjoint and equal-length on every
//! iteration. These kernels restate the loops over **fixed-width chunks**
//! (`chunks_exact` of [`LANES`]), which hoists the bounds checks and lets
//! LLVM emit packed SIMD adds/mults for the body, with a scalar tail for
//! the remainder.
//!
//! Two implementations share each signature:
//!
//! * the default build uses the chunked-slice form — safe, stable, and
//!   reliably autovectorized;
//! * `--features simd` swaps in `std::simd` (`f32x8`) bodies — explicit
//!   vector ops that do not depend on the autovectorizer. Portable SIMD
//!   is nightly-only, which is why it rides behind a feature gate.
//!
//! The `scalar` submodule keeps the naive forms alive as the measured
//! baseline (`benches/ring_allreduce.rs` records scalar-vs-vectorized
//! throughput) and as the reference the tests check against.

/// Fixed chunk width: 8 f32 lanes = one AVX2 register, two NEON registers.
pub const LANES: usize = 8;

/// Reference (naive) forms: the baseline the vectorized kernels are
/// benchmarked and tested against.
pub mod scalar {
    /// `dst[i] += src[i]`.
    pub fn add_assign(dst: &mut [f32], src: &[f32]) {
        assert_eq!(dst.len(), src.len(), "add_assign length mismatch");
        for (d, v) in dst.iter_mut().zip(src) {
            *d += *v;
        }
    }

    /// `buf[i] *= k`.
    pub fn scale(buf: &mut [f32], k: f32) {
        for v in buf.iter_mut() {
            *v *= k;
        }
    }

    /// `dst[i] += k * src[i]`.
    pub fn axpy(dst: &mut [f32], k: f32, src: &[f32]) {
        assert_eq!(dst.len(), src.len(), "axpy length mismatch");
        for (d, v) in dst.iter_mut().zip(src) {
            *d += k * *v;
        }
    }

    /// `Σ xs[i]²` (accumulated in f64 for stability).
    pub fn sum_squares(xs: &[f32]) -> f64 {
        xs.iter().map(|&x| (x as f64) * (x as f64)).sum()
    }
}

#[cfg(not(feature = "simd"))]
mod imp {
    use super::LANES;

    pub fn add_assign(dst: &mut [f32], src: &[f32]) {
        assert_eq!(dst.len(), src.len(), "add_assign length mismatch");
        let mut d = dst.chunks_exact_mut(LANES);
        let mut s = src.chunks_exact(LANES);
        for (dc, sc) in (&mut d).zip(&mut s) {
            for i in 0..LANES {
                dc[i] += sc[i];
            }
        }
        for (dv, sv) in d.into_remainder().iter_mut().zip(s.remainder()) {
            *dv += *sv;
        }
    }

    pub fn scale(buf: &mut [f32], k: f32) {
        let mut b = buf.chunks_exact_mut(LANES);
        for bc in &mut b {
            for v in bc.iter_mut() {
                *v *= k;
            }
        }
        for v in b.into_remainder() {
            *v *= k;
        }
    }

    pub fn axpy(dst: &mut [f32], k: f32, src: &[f32]) {
        assert_eq!(dst.len(), src.len(), "axpy length mismatch");
        let mut d = dst.chunks_exact_mut(LANES);
        let mut s = src.chunks_exact(LANES);
        for (dc, sc) in (&mut d).zip(&mut s) {
            for i in 0..LANES {
                dc[i] += k * sc[i];
            }
        }
        for (dv, sv) in d.into_remainder().iter_mut().zip(s.remainder()) {
            *dv += k * *sv;
        }
    }

    pub fn sum_squares(xs: &[f32]) -> f64 {
        // Eight independent f32 partial accumulators vectorize; the f64
        // combine at chunk granularity keeps the result stable enough for
        // gradient norms (relative error ~1e-6 over millions of elements).
        let mut acc = 0.0f64;
        let mut it = xs.chunks_exact(LANES);
        for c in &mut it {
            let mut lanes = [0.0f32; LANES];
            for i in 0..LANES {
                lanes[i] = c[i] * c[i];
            }
            acc += lanes.iter().map(|&x| x as f64).sum::<f64>();
        }
        for &x in it.remainder() {
            acc += (x as f64) * (x as f64);
        }
        acc
    }
}

#[cfg(feature = "simd")]
mod imp {
    use super::LANES;
    use std::simd::f32x8;
    use std::simd::num::SimdFloat;

    pub fn add_assign(dst: &mut [f32], src: &[f32]) {
        assert_eq!(dst.len(), src.len(), "add_assign length mismatch");
        let mut d = dst.chunks_exact_mut(LANES);
        let mut s = src.chunks_exact(LANES);
        for (dc, sc) in (&mut d).zip(&mut s) {
            let sum = f32x8::from_slice(dc) + f32x8::from_slice(sc);
            sum.copy_to_slice(dc);
        }
        for (dv, sv) in d.into_remainder().iter_mut().zip(s.remainder()) {
            *dv += *sv;
        }
    }

    pub fn scale(buf: &mut [f32], k: f32) {
        let kv = f32x8::splat(k);
        let mut b = buf.chunks_exact_mut(LANES);
        for bc in &mut b {
            (f32x8::from_slice(bc) * kv).copy_to_slice(bc);
        }
        for v in b.into_remainder() {
            *v *= k;
        }
    }

    pub fn axpy(dst: &mut [f32], k: f32, src: &[f32]) {
        assert_eq!(dst.len(), src.len(), "axpy length mismatch");
        let kv = f32x8::splat(k);
        let mut d = dst.chunks_exact_mut(LANES);
        let mut s = src.chunks_exact(LANES);
        for (dc, sc) in (&mut d).zip(&mut s) {
            let sum = f32x8::from_slice(dc) + kv * f32x8::from_slice(sc);
            sum.copy_to_slice(dc);
        }
        for (dv, sv) in d.into_remainder().iter_mut().zip(s.remainder()) {
            *dv += k * *sv;
        }
    }

    pub fn sum_squares(xs: &[f32]) -> f64 {
        let mut acc = 0.0f64;
        let mut it = xs.chunks_exact(LANES);
        for c in &mut it {
            let v = f32x8::from_slice(c);
            acc += (v * v).reduce_sum() as f64;
        }
        for &x in it.remainder() {
            acc += (x as f64) * (x as f64);
        }
        acc
    }
}

/// `dst[i] += src[i]` — the reduce-scatter inner loop.
pub fn add_assign(dst: &mut [f32], src: &[f32]) {
    imp::add_assign(dst, src)
}

/// `buf[i] *= k` — gradient averaging (`allreduce_mean`, PPO's warm-count
/// divide, ES's `-1/(popσ)` rescale).
pub fn scale(buf: &mut [f32], k: f32) {
    imp::scale(buf, k)
}

/// `dst[i] += k * src[i]` — the ES rank-weighted noise accumulation.
pub fn axpy(dst: &mut [f32], k: f32, src: &[f32]) {
    imp::axpy(dst, k, src)
}

/// `Σ xs[i]²` in f64 — gradient norms without a second pass.
pub fn sum_squares(xs: &[f32]) -> f64 {
    imp::sum_squares(xs)
}

/// One-pass batch statistics of a slice, shaped for a Welford/Chan merge
/// (see [`crate::util::stats::Welford::add_slice_f32`]).
pub struct SliceStats {
    pub n: u64,
    pub mean: f64,
    pub m2: f64,
    pub min: f64,
    pub max: f64,
}

/// Batch mean / M2 / min / max of `xs` (`None` when empty). Two chunked
/// passes — sum, then centered squares — both of which vectorize; for the
/// stat-merge sizes that matter (reward vectors, latency batches) this
/// beats `n` scalar Welford updates by the same margin as the kernels
/// above.
pub fn slice_stats(xs: &[f32]) -> Option<SliceStats> {
    if xs.is_empty() {
        return None;
    }
    let n = xs.len();
    let (mut sum, mut lo, mut hi) = (0.0f64, f64::INFINITY, f64::NEG_INFINITY);
    let mut it = xs.chunks_exact(LANES);
    for c in &mut it {
        let mut part = 0.0f32;
        for &x in c {
            part += x;
            lo = lo.min(x as f64);
            hi = hi.max(x as f64);
        }
        sum += part as f64;
    }
    for &x in it.remainder() {
        sum += x as f64;
        lo = lo.min(x as f64);
        hi = hi.max(x as f64);
    }
    let mean = sum / n as f64;
    let mut m2 = 0.0f64;
    let mut it = xs.chunks_exact(LANES);
    for c in &mut it {
        let mut part = 0.0f64;
        for &x in c {
            let d = x as f64 - mean;
            part += d * d;
        }
        m2 += part;
    }
    for &x in it.remainder() {
        let d = x as f64 - mean;
        m2 += d * d;
    }
    Some(SliceStats {
        n: n as u64,
        mean,
        m2,
        min: lo,
        max: hi,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stream(tag: u64, len: usize) -> Vec<f32> {
        // Deterministic pseudo-random values spanning signs/magnitudes.
        let mut state = tag.wrapping_mul(0x9E37_79B9_7F4A_7C15) | 1;
        (0..len)
            .map(|_| {
                state ^= state << 13;
                state ^= state >> 7;
                state ^= state << 17;
                ((state % 20_001) as f32 - 10_000.0) / 97.0
            })
            .collect()
    }

    /// Lengths that cover the empty, sub-lane, exact-lane, and ragged
    /// cases — the remainder handling is where chunked kernels go wrong.
    const LENS: [usize; 7] = [0, 1, 7, 8, 9, 64, 1000 + 3];

    #[test]
    fn add_assign_matches_scalar() {
        for len in LENS {
            let src = stream(1, len);
            let mut a = stream(2, len);
            let mut b = a.clone();
            add_assign(&mut a, &src);
            scalar::add_assign(&mut b, &src);
            assert_eq!(a, b, "len {len}");
        }
    }

    #[test]
    fn scale_matches_scalar() {
        for len in LENS {
            let mut a = stream(3, len);
            let mut b = a.clone();
            scale(&mut a, 0.37);
            scalar::scale(&mut b, 0.37);
            assert_eq!(a, b, "len {len}");
        }
    }

    #[test]
    fn axpy_matches_scalar() {
        for len in LENS {
            let src = stream(4, len);
            let mut a = stream(5, len);
            let mut b = a.clone();
            axpy(&mut a, -1.75, &src);
            scalar::axpy(&mut b, -1.75, &src);
            assert_eq!(a, b, "len {len}");
        }
    }

    #[test]
    fn sum_squares_matches_scalar() {
        for len in LENS {
            let xs = stream(6, len);
            let got = sum_squares(&xs);
            let want = scalar::sum_squares(&xs);
            let tol = 1e-9 * (1.0 + want.abs());
            assert!((got - want).abs() < tol, "len {len}: {got} vs {want}");
        }
    }

    #[test]
    fn slice_stats_matches_direct() {
        assert!(slice_stats(&[]).is_none());
        for len in LENS.into_iter().skip(1) {
            let xs = stream(7, len);
            let s = slice_stats(&xs).unwrap();
            let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / len as f64;
            let m2 = xs.iter().map(|&x| (x as f64 - mean).powi(2)).sum::<f64>();
            assert_eq!(s.n, len as u64);
            assert!((s.mean - mean).abs() < 1e-9 * (1.0 + mean.abs()));
            assert!((s.m2 - m2).abs() < 1e-7 * (1.0 + m2.abs()));
            let lo = xs.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
            let hi = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
            assert_eq!(s.min, lo);
            assert_eq!(s.max, hi);
        }
    }

    #[test]
    fn kernels_reject_length_mismatch() {
        let mut a = vec![0.0; 4];
        let b = vec![0.0; 5];
        assert!(std::panic::catch_unwind(move || {
            add_assign(&mut a, &b);
        })
        .is_err());
    }
}
