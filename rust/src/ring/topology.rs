//! The ring rendezvous service: ranks, membership, generations — and,
//! since the elastic-collectives refactor, **failure healing**.
//!
//! Members register with a rendezvous point (in-process `Arc` for the
//! thread backend, [`crate::comms::rpc`] over TCP for OS-process workers),
//! receive a stable **rank** and, once `world` members have arrived, the
//! full membership of the current **generation**. Any join after the ring
//! sealed, any [`Rendezvous::leave`] and any [`Rendezvous::resize`] (the
//! collective analogue of `Pool::resize` dynamic scaling) bumps the
//! generation: members discover the bump through [`RendezvousClient::
//! membership`] and re-register, exactly like pool workers re-fetching
//! after a scale event in [`crate::coordinator::scaling`].
//!
//! Healing is the pool's pending-table story applied to rings. Members
//! [`Rendezvous::heartbeat`] while they wait on peers; a member whose recv
//! deadline expires calls [`Rendezvous::report_dead`]. If the accused rank
//! has not heartbeated within the grace window the rendezvous **re-ranks
//! the survivors of the sealed generation into a new, immediately-sealed
//! generation** (dense ranks, same endpoints, dead member excised) — no
//! re-registration round-trip, because the sealed membership is the
//! archive of who survives. Survivors then agree on where to resume the
//! interrupted collective through the [`Rendezvous::resume_poll`]
//! min-barrier: each reports how many chunks it completed, and everyone
//! resumes from the minimum.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use once_cell::sync::Lazy;

use crate::comms::rpc::{RpcClient, RpcServer};
use crate::comms::Addr;
use crate::wire::{self, Decode, Encode};

/// RPC tags for the rendezvous protocol.
pub mod tags {
    pub const REGISTER: u32 = 1;
    pub const MEMBERSHIP: u32 = 2;
    pub const LEAVE: u32 = 3;
    pub const RESIZE: u32 = 4;
    pub const HEARTBEAT: u32 = 5;
    pub const REPORT_DEAD: u32 = 6;
    pub const RESUME: u32 = 7;
    pub const RESUME_MISSING: u32 = 8;
}

/// One registered member as seen by the rendezvous.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MemberInfo {
    /// Rank within the generation (0-based, dense).
    pub rank: u64,
    /// The member's data-plane endpoint (`inproc://…` or `tcp://…`).
    pub addr: String,
}

impl Encode for MemberInfo {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.rank.encode(buf);
        self.addr.encode(buf);
    }
}

impl Decode for MemberInfo {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        Ok(MemberInfo {
            rank: u64::decode(r)?,
            addr: String::decode(r)?,
        })
    }
}

/// A membership snapshot (the reply to [`tags::MEMBERSHIP`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Membership {
    pub generation: u64,
    pub world: u64,
    pub sealed: bool,
    pub members: Vec<MemberInfo>,
    /// The most recent sealed generation, retained after a late join bumps
    /// the forming generation so members of the just-sealed ring that have
    /// not read their membership yet are not stranded. Cleared by
    /// `leave`/`resize`, which genuinely invalidate old rings.
    pub last_sealed: Option<(u64, Vec<MemberInfo>)>,
}

impl Encode for Membership {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.generation.encode(buf);
        self.world.encode(buf);
        self.sealed.encode(buf);
        self.members.encode(buf);
        self.last_sealed.encode(buf);
    }
}

impl Decode for Membership {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        Ok(Membership {
            generation: u64::decode(r)?,
            world: u64::decode(r)?,
            sealed: bool::decode(r)?,
            members: Vec::<MemberInfo>::decode(r)?,
            last_sealed: Option::<(u64, Vec<MemberInfo>)>::decode(r)?,
        })
    }
}

impl Membership {
    /// Resolve this membership into the [`RingView`] of the member at
    /// `rank` — the single place endpoint strings become [`Addr`]s, shared
    /// by the initial join and by mid-collective healing.
    pub fn resolve_view(&self, rank: usize) -> Result<RingView> {
        let mut members = Vec::with_capacity(self.members.len());
        for info in &self.members {
            members.push(Addr::parse(&info.addr)?);
        }
        Ok(RingView {
            generation: self.generation,
            rank,
            world: members.len(),
            members,
        })
    }
}

/// A member's resolved view of a sealed ring generation.
#[derive(Clone, Debug)]
pub struct RingView {
    pub generation: u64,
    pub rank: usize,
    pub world: usize,
    /// Data-plane endpoints indexed by rank.
    pub members: Vec<Addr>,
}

impl RingView {
    /// Rank of the right-hand neighbour (`rank + 1`, wrapping).
    pub fn right(&self) -> usize {
        (self.rank + 1) % self.world
    }

    /// Rank of the left-hand neighbour (`rank - 1`, wrapping).
    pub fn left(&self) -> usize {
        (self.rank + self.world - 1) % self.world
    }
}

/// The per-healed-generation resume barrier: every survivor reports its
/// completed-chunk count; the minimum is released once all have reported.
struct ResumeState {
    expected: usize,
    reports: HashMap<u64, u64>,
}

struct RvInner {
    world: usize,
    generation: u64,
    sealed: bool,
    members: Vec<String>,
    /// `(generation, members)` of the last sealed generation, kept across a
    /// late-join bump (see [`Membership::last_sealed`]).
    last_sealed: Option<(u64, Vec<String>)>,
    /// Last heartbeat per data-plane endpoint. Keyed by endpoint — not by
    /// (generation, rank) — so a live member that has not yet noticed a
    /// heal (its view still names the old generation) keeps its liveness
    /// protection while ranks renumber around it.
    heartbeats: HashMap<String, Instant>,
    /// A `report_dead` against a rank that heartbeated within this window
    /// is rejected — protects live-but-slow members from eviction.
    grace: Duration,
    /// Resume barriers for healed generations, keyed by generation.
    resume: HashMap<u64, ResumeState>,
}

fn member_infos(members: &[String]) -> Vec<MemberInfo> {
    members
        .iter()
        .enumerate()
        .map(|(i, a)| MemberInfo {
            rank: i as u64,
            addr: a.clone(),
        })
        .collect()
}

/// The rendezvous point itself (server side).
pub struct Rendezvous {
    inner: Mutex<RvInner>,
    changed: Condvar,
}

static INPROC_RV: Lazy<Mutex<HashMap<String, Arc<Rendezvous>>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

impl Rendezvous {
    /// A fresh rendezvous expecting `world` members per generation.
    pub fn new(world: usize) -> Arc<Self> {
        Arc::new(Self {
            inner: Mutex::new(RvInner {
                world: world.max(1),
                generation: 0,
                sealed: false,
                members: Vec::new(),
                last_sealed: None,
                heartbeats: HashMap::new(),
                grace: Duration::from_millis(150),
                resume: HashMap::new(),
            }),
            changed: Condvar::new(),
        })
    }

    /// How fresh a rank's heartbeat must be for a `report_dead` against it
    /// to be rejected (default 150 ms). Tune below the members' recv
    /// timeout, above their probe interval.
    pub fn set_heartbeat_grace(&self, grace: Duration) {
        self.inner.lock().unwrap().grace = grace;
    }

    /// Create and publish under `inproc://name` so thread-backend members
    /// can find it through [`RendezvousClient::connect`].
    pub fn inproc(name: &str, world: usize) -> Arc<Self> {
        let rv = Self::new(world);
        INPROC_RV
            .lock()
            .unwrap()
            .insert(name.to_string(), rv.clone());
        rv
    }

    /// Remove an `inproc://` rendezvous from the global registry.
    pub fn unpublish(name: &str) {
        INPROC_RV.lock().unwrap().remove(name);
    }

    /// Register a member's data endpoint. A join after the current
    /// generation sealed starts a new generation (re-rendezvous). Returns
    /// `(generation, rank)`.
    pub fn register(&self, data_addr: &str) -> (u64, u64) {
        let mut inner = self.inner.lock().unwrap();
        if inner.sealed {
            // Archive the sealed membership before starting the next
            // generation: members of the sealed ring that have not read it
            // yet must still be able to (a late join must not strand a
            // healthy generation mid-rendezvous).
            let generation = inner.generation;
            let archived = std::mem::take(&mut inner.members);
            inner.last_sealed = Some((generation, archived));
            inner.generation += 1;
            inner.sealed = false;
            // heartbeats are endpoint-keyed and deliberately survive the
            // bump: the archived generation's members are still live.
        }
        inner.members.push(data_addr.to_string());
        let rank = (inner.members.len() - 1) as u64;
        if inner.members.len() >= inner.world {
            inner.sealed = true;
        }
        let generation = inner.generation;
        drop(inner);
        self.changed.notify_all();
        (generation, rank)
    }

    /// Current membership snapshot.
    pub fn membership(&self) -> Membership {
        let inner = self.inner.lock().unwrap();
        Membership {
            generation: inner.generation,
            world: inner.world as u64,
            sealed: inner.sealed,
            members: member_infos(&inner.members),
            last_sealed: inner
                .last_sealed
                .as_ref()
                .map(|(g, m)| (*g, member_infos(m))),
        }
    }

    /// A member leaves `generation`: bump the generation so survivors
    /// re-rendezvous. Stale calls (old generation) are ignored. Pair with
    /// [`Rendezvous::resize`] when the departure is a scale-down rather
    /// than churn, otherwise the next generation waits for a replacement.
    pub fn leave(&self, generation: u64, _rank: u64) {
        let mut inner = self.inner.lock().unwrap();
        if inner.generation == generation {
            inner.generation += 1;
            inner.sealed = false;
            inner.members.clear();
            // A departure invalidates old rings outright — no archived
            // snapshot may resurrect a generation missing a member.
            inner.last_sealed = None;
            inner.heartbeats.clear();
            drop(inner);
            self.changed.notify_all();
        }
    }

    /// Change the expected world size (dynamic scaling). Bumps the
    /// generation; all members re-register.
    pub fn resize(&self, world: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.world = world.max(1);
        inner.generation += 1;
        inner.sealed = false;
        inner.members.clear();
        inner.last_sealed = None;
        inner.heartbeats.clear();
        drop(inner);
        self.changed.notify_all();
    }

    /// Record liveness for the member advertising `endpoint`. Members call
    /// this while they wait on peers (and between units of compute work),
    /// so silence is evidence of death rather than of a long compute
    /// phase. Endpoint-keyed on purpose: it stays valid across heals and
    /// rank renumbering. Returns the current generation, so one heartbeat
    /// doubles as the generation-bump probe blocked receivers poll with —
    /// no full membership snapshot needed per probe slice.
    pub fn heartbeat(&self, endpoint: &str) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        inner
            .heartbeats
            .insert(endpoint.to_string(), Instant::now());
        inner.generation
    }

    /// Accuse `rank` of `generation` of being dead. Returns `true` when the
    /// accusation is accepted and the ring **healed**: the survivors of the
    /// sealed generation are re-ranked (densely, in their old rank order)
    /// into a new generation that seals immediately, and a resume barrier
    /// is opened for it (see [`Rendezvous::resume_poll`]). Returns `false`
    /// when the report is stale (generation already moved on), the ring is
    /// not sealed, the rank is out of range, or the accused heartbeated
    /// within the grace window — in the last case the reporter should keep
    /// waiting and retry.
    pub fn report_dead(&self, generation: u64, rank: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.generation != generation || !inner.sealed {
            return false;
        }
        if rank as usize >= inner.members.len() {
            return false;
        }
        if let Some(seen) = inner.heartbeats.get(&inner.members[rank as usize]) {
            if seen.elapsed() < inner.grace {
                return false; // alive by heartbeat — reject the accusation
            }
        }
        inner.members.remove(rank as usize);
        inner.generation += 1;
        // The dead generation must not be resurrected from the archive.
        inner.last_sealed = None;
        // Drop liveness records for endpoints no longer in the ring (the
        // dead member's among them); survivors' records stay valid.
        let live: Vec<String> = inner.members.clone();
        inner.heartbeats.retain(|addr, _| live.contains(addr));
        let expected = inner.members.len();
        if expected == 0 {
            // The sole member died: nothing survives to resume. The next
            // generation forms from scratch (world unchanged).
            inner.sealed = false;
        } else {
            inner.sealed = true;
            inner.world = expected;
            let healed = inner.generation;
            inner.resume.retain(|g, _| g + 8 > healed);
            inner.resume.insert(
                healed,
                ResumeState {
                    expected,
                    reports: HashMap::new(),
                },
            );
        }
        drop(inner);
        self.changed.notify_all();
        true
    }

    /// The healed-generation resume barrier. Each survivor of `generation`
    /// reports the number of collective chunks it had fully completed when
    /// the failure hit; once every survivor has reported, everyone receives
    /// the **minimum** — the chunk index the collective resumes from.
    /// Returns `None` while reports are still outstanding (poll again) or
    /// when `generation` has no open barrier. Re-reports from the same rank
    /// overwrite idempotently.
    pub fn resume_poll(&self, generation: u64, rank: u64, completed: u64) -> Option<u64> {
        let mut inner = self.inner.lock().unwrap();
        let st = inner.resume.get_mut(&generation)?;
        st.reports.insert(rank, completed);
        if st.reports.len() >= st.expected {
            st.reports.values().min().copied()
        } else {
            None
        }
    }

    /// Ranks of `generation` that have not reported into its resume
    /// barrier yet — `None` when the generation has no open barrier.
    /// Lets barrier waiters accuse a member that died *between* the first
    /// death and the barrier (a second simultaneous failure) instead of
    /// waiting on a corpse forever.
    pub fn resume_missing(&self, generation: u64) -> Option<Vec<u64>> {
        let inner = self.inner.lock().unwrap();
        let st = inner.resume.get(&generation)?;
        Some(
            (0..st.expected as u64)
                .filter(|r| !st.reports.contains_key(r))
                .collect(),
        )
    }

    /// Block until the given generation seals (or any later generation
    /// starts, which means the caller's registration is stale).
    fn wait_sealed(&self, generation: u64, timeout: Duration) -> Result<Membership> {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.sealed && inner.generation == generation {
                // Snapshot under the held lock: a join-after-seal on another
                // thread must not be able to clear the membership between
                // our check and the read.
                return Ok(Membership {
                    generation,
                    world: inner.world as u64,
                    sealed: true,
                    members: member_infos(&inner.members),
                    last_sealed: None,
                });
            }
            // Our generation sealed but a late join already started the
            // next one: the archived snapshot is still valid for us.
            if let Some((g, archived)) = &inner.last_sealed {
                if *g == generation {
                    return Ok(Membership {
                        generation,
                        world: archived.len() as u64,
                        sealed: true,
                        members: member_infos(archived),
                        last_sealed: None,
                    });
                }
            }
            if inner.generation > generation {
                anyhow::bail!(
                    "ring generation bumped to {} while waiting on {generation} — re-register",
                    inner.generation
                );
            }
            let now = Instant::now();
            anyhow::ensure!(now < deadline, "rendezvous timed out waiting for the ring to fill");
            let (g, _) = self.changed.wait_timeout(inner, deadline - now).unwrap();
            inner = g;
        }
    }

    /// Expose this rendezvous over TCP for OS-process members.
    pub fn serve_rpc(self: &Arc<Self>, bind: &str) -> Result<RpcServer> {
        let rv = self.clone();
        RpcServer::bind(
            bind,
            Arc::new(move |tag, payload| match tag {
                tags::REGISTER => {
                    let addr: String = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    Ok(wire::to_bytes(&rv.register(&addr)))
                }
                tags::MEMBERSHIP => Ok(wire::to_bytes(&rv.membership())),
                tags::LEAVE => {
                    let (generation, rank): (u64, u64) =
                        wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    rv.leave(generation, rank);
                    Ok(Vec::new())
                }
                tags::RESIZE => {
                    let world: u64 = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    rv.resize(world as usize);
                    Ok(Vec::new())
                }
                tags::HEARTBEAT => {
                    let endpoint: String = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    Ok(wire::to_bytes(&rv.heartbeat(&endpoint)))
                }
                tags::REPORT_DEAD => {
                    let (generation, rank): (u64, u64) =
                        wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    Ok(wire::to_bytes(&rv.report_dead(generation, rank)))
                }
                tags::RESUME => {
                    let (generation, rank, completed): (u64, u64, u64) =
                        wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    Ok(wire::to_bytes(&rv.resume_poll(generation, rank, completed)))
                }
                tags::RESUME_MISSING => {
                    let generation: u64 = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    Ok(wire::to_bytes(&rv.resume_missing(generation)))
                }
                t => Err(format!("bad rendezvous rpc tag {t}")),
            }),
        )
    }
}

/// Client handle to a rendezvous, local or remote — the same four verbs
/// over either transport, which is what lets ring programs move between
/// the thread and OS-process backends unchanged.
pub enum RendezvousClient {
    Local(Arc<Rendezvous>),
    Remote(RpcClient),
}

impl RendezvousClient {
    /// Connect to `inproc://name` (published via [`Rendezvous::inproc`])
    /// or `tcp://host:port` (served via [`Rendezvous::serve_rpc`]).
    pub fn connect(addr: &Addr) -> Result<Self> {
        match addr {
            Addr::Inproc(name) => {
                let rv = INPROC_RV
                    .lock()
                    .unwrap()
                    .get(name)
                    .cloned()
                    .with_context(|| format!("no inproc rendezvous named {name:?}"))?;
                Ok(RendezvousClient::Local(rv))
            }
            Addr::Tcp(sa) => Ok(RendezvousClient::Remote(RpcClient::connect(*sa)?)),
        }
    }

    /// Wrap an already-held local rendezvous.
    pub fn local(rv: Arc<Rendezvous>) -> Self {
        RendezvousClient::Local(rv)
    }

    pub fn register(&self, data_addr: &str) -> Result<(u64, u64)> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.register(data_addr)),
            RendezvousClient::Remote(cli) => {
                cli.call_typed(tags::REGISTER, &data_addr.to_string())
            }
        }
    }

    pub fn membership(&self) -> Result<Membership> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.membership()),
            RendezvousClient::Remote(cli) => cli.call_typed(tags::MEMBERSHIP, &()),
        }
    }

    pub fn leave(&self, generation: u64, rank: u64) -> Result<()> {
        match self {
            RendezvousClient::Local(rv) => {
                rv.leave(generation, rank);
                Ok(())
            }
            RendezvousClient::Remote(cli) => cli.call_typed(tags::LEAVE, &(generation, rank)),
        }
    }

    pub fn resize(&self, world: usize) -> Result<()> {
        match self {
            RendezvousClient::Local(rv) => {
                rv.resize(world);
                Ok(())
            }
            RendezvousClient::Remote(cli) => cli.call_typed(tags::RESIZE, &(world as u64)),
        }
    }

    /// Record liveness; returns the rendezvous' current generation (see
    /// [`Rendezvous::heartbeat`]).
    pub fn heartbeat(&self, endpoint: &str) -> Result<u64> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.heartbeat(endpoint)),
            RendezvousClient::Remote(cli) => {
                cli.call_typed(tags::HEARTBEAT, &endpoint.to_string())
            }
        }
    }

    /// Accuse a rank of being dead (see [`Rendezvous::report_dead`]).
    pub fn report_dead(&self, generation: u64, rank: u64) -> Result<bool> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.report_dead(generation, rank)),
            RendezvousClient::Remote(cli) => {
                cli.call_typed(tags::REPORT_DEAD, &(generation, rank))
            }
        }
    }

    /// Poll the healed-generation resume barrier (see
    /// [`Rendezvous::resume_poll`]).
    pub fn resume_poll(&self, generation: u64, rank: u64, completed: u64) -> Result<Option<u64>> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.resume_poll(generation, rank, completed)),
            RendezvousClient::Remote(cli) => {
                cli.call_typed(tags::RESUME, &(generation, rank, completed))
            }
        }
    }

    /// Ranks still missing from a resume barrier (see
    /// [`Rendezvous::resume_missing`]).
    pub fn resume_missing(&self, generation: u64) -> Result<Option<Vec<u64>>> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.resume_missing(generation)),
            RendezvousClient::Remote(cli) => cli.call_typed(tags::RESUME_MISSING, &generation),
        }
    }

    /// Register `data_addr` and block until the generation seals, returning
    /// the member's resolved [`RingView`]. Errors if the generation bumps
    /// mid-wait (caller should retry) or `timeout` elapses.
    pub fn join(&self, data_addr: &str, timeout: Duration) -> Result<RingView> {
        let (generation, rank) = self.register(data_addr)?;
        let m = match self {
            RendezvousClient::Local(rv) => rv.wait_sealed(generation, timeout)?,
            RendezvousClient::Remote(_) => {
                // Poll: RPC handlers shouldn't hold a server thread hostage
                // for the whole rendezvous window.
                let deadline = Instant::now() + timeout;
                loop {
                    let m = self.membership()?;
                    if m.sealed && m.generation == generation {
                        break m;
                    }
                    // A late join may have bumped the forming generation
                    // right after ours sealed; the archive still serves us.
                    if let Some((g, archived)) = &m.last_sealed {
                        if *g == generation {
                            break Membership {
                                generation,
                                world: archived.len() as u64,
                                sealed: true,
                                members: archived.clone(),
                                last_sealed: None,
                            };
                        }
                    }
                    if m.generation > generation {
                        anyhow::bail!(
                            "ring generation bumped to {} while waiting on {generation} — re-register",
                            m.generation
                        );
                    }
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "rendezvous timed out waiting for the ring to fill"
                    );
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
        };
        m.resolve_view(rank as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_are_dense_and_seal_at_world() {
        let rv = Rendezvous::new(3);
        assert_eq!(rv.register("inproc://a"), (0, 0));
        assert_eq!(rv.register("inproc://b"), (0, 1));
        assert!(!rv.membership().sealed);
        assert_eq!(rv.register("inproc://c"), (0, 2));
        let m = rv.membership();
        assert!(m.sealed);
        assert_eq!(m.members.len(), 3);
        assert_eq!(m.members[1].addr, "inproc://b");
    }

    #[test]
    fn join_after_seal_bumps_generation() {
        let rv = Rendezvous::new(2);
        rv.register("inproc://a");
        rv.register("inproc://b");
        assert_eq!(rv.membership().generation, 0);
        // A third member joining forces re-rendezvous.
        let (generation, rank) = rv.register("inproc://c");
        assert_eq!((generation, rank), (1, 0));
        let m = rv.membership();
        assert_eq!(m.generation, 1);
        assert!(!m.sealed);
        assert_eq!(m.members.len(), 1);
        // The sealed generation 0 is archived, not destroyed.
        let (g, archived) = m.last_sealed.expect("sealed gen 0 archived");
        assert_eq!(g, 0);
        assert_eq!(archived.len(), 2);
    }

    #[test]
    fn late_join_preserves_sealed_snapshot_for_unread_members() {
        // Regression: a join landing right after a generation seals must
        // not strand members of that generation that have not read their
        // membership yet.
        let rv = Rendezvous::new(2);
        let (g0, _) = rv.register("inproc://a");
        rv.register("inproc://b");
        rv.register("inproc://c"); // bumps the forming generation to 1
        assert_eq!(rv.membership().generation, 1);
        // A generation-0 member reading late still gets its sealed ring.
        let m = rv.wait_sealed(g0, Duration::from_millis(50)).unwrap();
        assert_eq!(m.generation, 0);
        assert!(m.sealed);
        assert_eq!(m.members.len(), 2);
        assert_eq!(m.members[1].addr, "inproc://b");
        // leave() invalidates the archive — no resurrecting a ring that
        // lost a member.
        rv.leave(1, 0);
        assert!(rv.membership().last_sealed.is_none());
        assert!(rv.wait_sealed(g0, Duration::from_millis(20)).is_err());
    }

    #[test]
    fn leave_and_resize_bump_generation() {
        let rv = Rendezvous::new(2);
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.leave(0, 1);
        assert_eq!(rv.membership().generation, 1);
        rv.leave(0, 0); // stale: already bumped
        assert_eq!(rv.membership().generation, 1);
        rv.resize(3);
        let m = rv.membership();
        assert_eq!(m.generation, 2);
        assert_eq!(m.world, 3);
    }

    #[test]
    fn join_blocks_until_full() {
        let rv = Rendezvous::new(2);
        let rv2 = rv.clone();
        let h = std::thread::spawn(move || {
            RendezvousClient::local(rv2)
                .join("inproc://first", Duration::from_secs(5))
                .unwrap()
        });
        std::thread::sleep(Duration::from_millis(20));
        let v2 = RendezvousClient::local(rv.clone())
            .join("inproc://second", Duration::from_secs(5))
            .unwrap();
        let v1 = h.join().unwrap();
        assert_eq!(v1.rank, 0);
        assert_eq!(v2.rank, 1);
        assert_eq!(v1.world, 2);
        assert_eq!(v1.members, v2.members);
        assert_eq!(v1.right(), 1);
        assert_eq!(v1.left(), 1);
    }

    #[test]
    fn join_times_out_when_ring_never_fills() {
        let rv = Rendezvous::new(2);
        let err = RendezvousClient::local(rv)
            .join("inproc://lonely", Duration::from_millis(30))
            .unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
    }

    #[test]
    fn rpc_rendezvous_roundtrip() {
        let rv = Rendezvous::new(2);
        let srv = rv.serve_rpc("127.0.0.1:0").unwrap();
        let addr = Addr::Tcp(srv.local_addr());
        let a1 = addr.clone();
        let h = std::thread::spawn(move || {
            RendezvousClient::connect(&a1)
                .unwrap()
                .join("tcp://127.0.0.1:7001", Duration::from_secs(5))
                .unwrap()
        });
        let v2 = RendezvousClient::connect(&addr)
            .unwrap()
            .join("tcp://127.0.0.1:7002", Duration::from_secs(5))
            .unwrap();
        let v1 = h.join().unwrap();
        assert_eq!(v1.world, 2);
        assert_eq!(v2.world, 2);
        assert_ne!(v1.rank, v2.rank);
        assert_eq!(v1.members, v2.members);
    }

    #[test]
    fn membership_wire_roundtrip() {
        let m = Membership {
            generation: 3,
            world: 2,
            sealed: true,
            members: vec![
                MemberInfo {
                    rank: 0,
                    addr: "tcp://127.0.0.1:9000".into(),
                },
                MemberInfo {
                    rank: 1,
                    addr: "inproc://x".into(),
                },
            ],
            last_sealed: Some((
                2,
                vec![MemberInfo {
                    rank: 0,
                    addr: "tcp://127.0.0.1:8000".into(),
                }],
            )),
        };
        let bytes = wire::to_bytes(&m);
        let back: Membership = wire::from_bytes(&bytes).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn report_dead_heals_with_dense_survivor_ranks() {
        let rv = Rendezvous::new(3);
        rv.set_heartbeat_grace(Duration::from_millis(20));
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.register("inproc://c");
        assert!(rv.membership().sealed);
        // Rank 1 dies: survivors re-rank densely, generation bumps, sealed.
        assert!(rv.report_dead(0, 1));
        let m = rv.membership();
        assert_eq!(m.generation, 1);
        assert!(m.sealed);
        assert_eq!(m.world, 2);
        let addrs: Vec<_> = m.members.iter().map(|i| i.addr.as_str()).collect();
        assert_eq!(addrs, vec!["inproc://a", "inproc://c"]);
        for (i, info) in m.members.iter().enumerate() {
            assert_eq!(info.rank, i as u64, "ranks must stay dense");
        }
        // Stale report against the old generation is a no-op.
        assert!(!rv.report_dead(0, 0));
        assert_eq!(rv.membership().generation, 1);
    }

    #[test]
    fn report_dead_rejected_within_heartbeat_grace() {
        let rv = Rendezvous::new(2);
        rv.set_heartbeat_grace(Duration::from_secs(10));
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.heartbeat("inproc://b");
        assert!(!rv.report_dead(0, 1), "fresh heartbeat must veto the report");
        assert_eq!(rv.membership().generation, 0);
        // Without a heartbeat on record the report is accepted.
        assert!(rv.report_dead(0, 0));
        assert_eq!(rv.membership().generation, 1);
        // The endpoint-keyed heartbeat still protects b after the heal and
        // rank renumbering (b is now rank 0 of generation 1).
        assert!(!rv.report_dead(1, 0), "stale-view member must stay protected");
    }

    #[test]
    fn resume_barrier_releases_min_once_all_report() {
        let rv = Rendezvous::new(3);
        rv.set_heartbeat_grace(Duration::from_millis(1));
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.register("inproc://c");
        std::thread::sleep(Duration::from_millis(5));
        assert!(rv.report_dead(0, 2));
        // Two survivors: barrier holds until both report, then min wins.
        assert_eq!(rv.resume_poll(1, 0, 7), None);
        assert_eq!(rv.resume_poll(1, 0, 7), None, "re-report is idempotent");
        assert_eq!(rv.resume_poll(1, 1, 3), Some(3));
        assert_eq!(rv.resume_poll(1, 0, 7), Some(3), "late re-poll still sees the min");
        // No barrier for generations that never healed.
        assert_eq!(rv.resume_poll(0, 0, 0), None);
    }

    #[test]
    fn resume_missing_names_unreported_ranks() {
        let rv = Rendezvous::new(3);
        rv.set_heartbeat_grace(Duration::from_millis(1));
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.register("inproc://c");
        std::thread::sleep(Duration::from_millis(5));
        assert!(rv.report_dead(0, 0));
        assert_eq!(rv.resume_missing(1), Some(vec![0, 1]));
        assert_eq!(rv.resume_poll(1, 1, 9), None);
        assert_eq!(rv.resume_missing(1), Some(vec![0]));
        assert_eq!(rv.resume_missing(0), None, "no barrier for unhealed generations");
    }

    #[test]
    fn healing_rpc_roundtrip() {
        let rv = Rendezvous::new(2);
        rv.set_heartbeat_grace(Duration::from_millis(1));
        let srv = rv.serve_rpc("127.0.0.1:0").unwrap();
        let cli = RendezvousClient::connect(&Addr::Tcp(srv.local_addr())).unwrap();
        rv.register("tcp://127.0.0.1:7101");
        rv.register("tcp://127.0.0.1:7102");
        cli.heartbeat("tcp://127.0.0.1:7101").unwrap();
        std::thread::sleep(Duration::from_millis(5));
        assert!(cli.report_dead(0, 1).unwrap());
        assert_eq!(cli.resume_poll(1, 0, 4).unwrap(), Some(4));
    }

    #[test]
    fn inproc_registry_publish_and_connect() {
        let _rv = Rendezvous::inproc("topo-test-rv", 1);
        let cli = RendezvousClient::connect(&Addr::parse("inproc://topo-test-rv").unwrap());
        assert!(cli.is_ok());
        Rendezvous::unpublish("topo-test-rv");
        let cli = RendezvousClient::connect(&Addr::parse("inproc://topo-test-rv").unwrap());
        assert!(cli.is_err());
    }
}
