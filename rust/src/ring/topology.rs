//! The ring rendezvous service: ranks, membership, generations — and,
//! since the elastic-collectives refactor, **failure healing**.
//!
//! Members register with a rendezvous point (in-process `Arc` for the
//! thread backend, [`crate::comms::rpc`] over TCP for OS-process workers),
//! receive a stable **rank** and, once `world` members have arrived, the
//! full membership of the current **generation**. Any join after the ring
//! sealed, any [`Rendezvous::leave`] and any [`Rendezvous::resize`] (the
//! collective analogue of `Pool::resize` dynamic scaling) bumps the
//! generation: members discover the bump through [`RendezvousClient::
//! membership`] and re-register, exactly like pool workers re-fetching
//! after a scale event in [`crate::coordinator::scaling`].
//!
//! Healing is the pool's pending-table story applied to rings. Members
//! [`Rendezvous::heartbeat`] while they wait on peers; a member whose recv
//! deadline expires calls [`Rendezvous::report_dead`]. If the accused rank
//! has not heartbeated within the grace window the rendezvous **re-ranks
//! the survivors of the sealed generation into a new, immediately-sealed
//! generation** (dense ranks, same endpoints, dead member excised) — no
//! re-registration round-trip, because the sealed membership is the
//! archive of who survives. Survivors then agree on where to resume the
//! interrupted collective through the [`Rendezvous::resume_poll`]
//! min-barrier: each reports how many chunks it completed, and everyone
//! resumes from the minimum.
//!
//! Since the auto-grow change the shrink is no longer one-way. Standby
//! members register into a **spare pool** ([`Rendezvous::register_spare`],
//! pending and heartbeating, exactly like pool workers in the
//! coordinator's pending table) and every membership change that seals a
//! new generation — a heal, or an explicit [`Rendezvous::grow`] — drains
//! the live spares in after the survivors, stamped with the generation
//! they entered ([`MemberInfo::since`]). The survivors' resume reports
//! carry an [`super::spare::OpDesc`] so the drained spare can adopt the
//! in-flight collective through [`Rendezvous::resume_observe`]; see
//! [`super::spare`] for the full rejoin story.

use std::collections::HashMap;
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};
use once_cell::sync::Lazy;

use crate::comms::rpc::{RpcClient, RpcServer};
use crate::comms::Addr;
use crate::wire::{self, Decode, Encode};

use super::spare::OpDesc;

/// RPC tags for the rendezvous protocol.
pub mod tags {
    pub const REGISTER: u32 = 1;
    pub const MEMBERSHIP: u32 = 2;
    pub const LEAVE: u32 = 3;
    pub const RESIZE: u32 = 4;
    pub const HEARTBEAT: u32 = 5;
    pub const REPORT_DEAD: u32 = 6;
    pub const RESUME: u32 = 7;
    pub const RESUME_MISSING: u32 = 8;
    pub const REGISTER_SPARE: u32 = 9;
    pub const DEREGISTER_SPARE: u32 = 10;
    pub const GROW: u32 = 11;
    pub const RESUME_OBSERVE: u32 = 12;
}

/// One registered member as seen by the rendezvous.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct MemberInfo {
    /// Rank within the generation (0-based, dense).
    pub rank: u64,
    /// The member's data-plane endpoint (`inproc://…` or `tcp://…`).
    pub addr: String,
    /// Generation at which this member entered the ring's lineage: its
    /// registration generation for founding members, the healed/grown
    /// generation for drained spares. Survivors keep their stamp across
    /// heals, which is how algorithms tell warm members (shared iteration
    /// state) from cold rejoiners — see [`super::RingView::warm_count`].
    pub since: u64,
}

impl Encode for MemberInfo {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.rank.encode(buf);
        self.addr.encode(buf);
        self.since.encode(buf);
    }
}

impl Decode for MemberInfo {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        Ok(MemberInfo {
            rank: u64::decode(r)?,
            addr: String::decode(r)?,
            since: u64::decode(r)?,
        })
    }
}

/// A membership snapshot (the reply to [`tags::MEMBERSHIP`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Membership {
    pub generation: u64,
    pub world: u64,
    pub sealed: bool,
    pub members: Vec<MemberInfo>,
    /// The most recent sealed generation, retained after a late join bumps
    /// the forming generation so members of the just-sealed ring that have
    /// not read their membership yet are not stranded. Cleared by
    /// `leave`/`resize`, which genuinely invalidate old rings.
    pub last_sealed: Option<(u64, Vec<MemberInfo>)>,
}

impl Encode for Membership {
    fn encode(&self, buf: &mut Vec<u8>) {
        self.generation.encode(buf);
        self.world.encode(buf);
        self.sealed.encode(buf);
        self.members.encode(buf);
        self.last_sealed.encode(buf);
    }
}

impl Decode for Membership {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        Ok(Membership {
            generation: u64::decode(r)?,
            world: u64::decode(r)?,
            sealed: bool::decode(r)?,
            members: Vec::<MemberInfo>::decode(r)?,
            last_sealed: Option::<(u64, Vec<MemberInfo>)>::decode(r)?,
        })
    }
}

impl Membership {
    /// Resolve this membership into the [`RingView`] of the member at
    /// `rank` — the single place endpoint strings become [`Addr`]s, shared
    /// by the initial join and by mid-collective healing.
    pub fn resolve_view(&self, rank: usize) -> Result<RingView> {
        let mut members = Vec::with_capacity(self.members.len());
        let mut joined = Vec::with_capacity(self.members.len());
        for info in &self.members {
            members.push(Addr::parse(&info.addr)?);
            joined.push(info.since);
        }
        Ok(RingView {
            generation: self.generation,
            rank,
            world: members.len(),
            members,
            joined,
        })
    }
}

/// A member's resolved view of a sealed ring generation.
#[derive(Clone, Debug)]
pub struct RingView {
    pub generation: u64,
    pub rank: usize,
    pub world: usize,
    /// Data-plane endpoints indexed by rank.
    pub members: Vec<Addr>,
    /// Per-rank entry generation ([`MemberInfo::since`]), indexed by rank.
    pub joined: Vec<u64>,
}

impl RingView {
    /// Rank of the right-hand neighbour (`rank + 1`, wrapping).
    pub fn right(&self) -> usize {
        (self.rank + 1) % self.world
    }

    /// Rank of the left-hand neighbour (`rank - 1`, wrapping).
    pub fn left(&self) -> usize {
        (self.rank + self.world - 1) % self.world
    }

    /// Members that entered the ring at or before `generation` — the
    /// **warm** members, which share whatever iteration state existed at
    /// that generation. Heals keep survivors in their old relative order
    /// and append drained spares after them, so the warm members always
    /// occupy the rank prefix `0..warm_count` and rank 0 is always warm.
    /// Algorithms shard work over this count after a mid-iteration grow
    /// (cold rejoiners relay collectives but own no shard until synced).
    pub fn warm_count(&self, generation: u64) -> usize {
        self.joined.iter().filter(|&&j| j <= generation).count()
    }

    /// The rank currently holding `endpoint`, if any — the way a cold
    /// rejoiner turns an [`super::spare::OpDesc::root`] endpoint back into
    /// a rank of its own (post-grow) generation.
    pub fn rank_of_endpoint(&self, endpoint: &str) -> Option<usize> {
        let addr = Addr::parse(endpoint).ok()?;
        self.members.iter().position(|a| *a == addr)
    }
}

/// The per-healed-generation resume barrier: every **participating**
/// survivor reports its completed-chunk count plus the op-sequence number
/// of the collective it was driving; the barrier releases once every
/// required rank has reported. The release value is op-aware: it is the
/// minimum completed count **among the reports of the most-advanced op**
/// — a member that had already finished the superseded op (a membership
/// bump landing exactly on a collective boundary, e.g. an explicit grow)
/// reports the older op as fully complete and is told to move on rather
/// than rolled back into a collective its peers have left behind.
struct ResumeState {
    /// Ranks whose report the barrier waits for: the members that were
    /// already participating in collectives when the generation sealed.
    /// Freshly drained spares are *observers* — they adopt through
    /// [`Rendezvous::resume_observe`] instead of reporting.
    required: Vec<u64>,
    /// rank → (completed chunks, op-sequence number of the reporter's
    /// in-flight collective).
    reports: HashMap<u64, (u64, u64)>,
    /// The descriptor of the most-advanced reported op.
    op: Option<OpDesc>,
}

/// `(resume_op_seq, resume_chunk)` once `st` is complete: the
/// most-advanced reported op and the minimum completed count among the
/// members driving *that* op.
fn barrier_result(st: &ResumeState) -> Option<(u64, u64)> {
    if st.required.iter().any(|r| !st.reports.contains_key(r)) {
        return None;
    }
    let max_seq = st.reports.values().map(|&(_, s)| s).max()?;
    let min = st
        .reports
        .values()
        .filter(|&&(_, s)| s == max_seq)
        .map(|&(c, _)| c)
        .min()?;
    Some((max_seq, min))
}

/// One ranked seat of a generation: the endpoint, the generation at
/// which the member entered the lineage (see [`MemberInfo::since`]), and
/// whether it is still an **observer** — a drained spare that has not yet
/// adopted the in-flight op through `resume_observe`. Observers are
/// excluded from resume barriers' required-reporter sets (they have
/// nothing to report and would deadlock a barrier opened by a second
/// membership change during their admission window).
#[derive(Clone)]
struct Seat {
    addr: String,
    since: u64,
    observer: bool,
}

struct RvInner {
    world: usize,
    generation: u64,
    sealed: bool,
    members: Vec<Seat>,
    /// `(generation, members)` of the last sealed generation, kept across a
    /// late-join bump (see [`Membership::last_sealed`]).
    last_sealed: Option<(u64, Vec<Seat>)>,
    /// Standby members awaiting a heal or an explicit grow, in
    /// registration order. Pending — never ranked until drained.
    spares: Vec<String>,
    /// Last heartbeat per data-plane endpoint. Keyed by endpoint — not by
    /// (generation, rank) — so a live member that has not yet noticed a
    /// heal (its view still names the old generation) keeps its liveness
    /// protection while ranks renumber around it. Spares heartbeat here
    /// too while pending.
    heartbeats: HashMap<String, Instant>,
    /// A `report_dead` against a rank that heartbeated within this window
    /// is rejected — protects live-but-slow members from eviction. The
    /// same window decides whether a pending spare is still draftable.
    grace: Duration,
    /// Resume barriers for healed generations, keyed by generation.
    resume: HashMap<u64, ResumeState>,
}

impl RvInner {
    /// Drop pending spares whose heartbeat went stale (died while
    /// pending): excised from the table without a generation bump.
    fn prune_spares(&mut self) {
        let grace = self.grace;
        let heartbeats = &self.heartbeats;
        self.spares
            .retain(|a| heartbeats.get(a).is_some_and(|t| t.elapsed() < grace));
    }

    /// Take every live pending spare (pruning the stale ones first).
    fn drain_live_spares(&mut self) -> Vec<String> {
        self.prune_spares();
        std::mem::take(&mut self.spares)
    }

    /// Seal the (already bumped) current generation after a membership
    /// change: surviving seats keep their order, live pending spares
    /// drain in after them as observers, and a resume barrier opens
    /// requiring a report from every member that was already
    /// participating in collectives. Shared by the heal
    /// ([`Rendezvous::report_dead`]) and the explicit
    /// [`Rendezvous::grow`], so the two seal paths cannot drift.
    fn seal_grown(&mut self) {
        let sealed_gen = self.generation;
        let required: Vec<u64> = self
            .members
            .iter()
            .enumerate()
            .filter(|(_, s)| !s.observer)
            .map(|(i, _)| i as u64)
            .collect();
        for addr in self.drain_live_spares() {
            self.members.push(Seat {
                addr,
                since: sealed_gen,
                observer: true,
            });
        }
        self.sealed = true;
        self.world = self.members.len();
        self.resume.retain(|g, _| g + 8 > sealed_gen);
        self.resume.insert(
            sealed_gen,
            ResumeState {
                required,
                reports: HashMap::new(),
                op: None,
            },
        );
    }
}

fn member_infos(members: &[Seat]) -> Vec<MemberInfo> {
    members
        .iter()
        .enumerate()
        .map(|(i, s)| MemberInfo {
            rank: i as u64,
            addr: s.addr.clone(),
            since: s.since,
        })
        .collect()
}

/// The rendezvous point itself (server side).
pub struct Rendezvous {
    inner: Mutex<RvInner>,
    changed: Condvar,
}

static INPROC_RV: Lazy<Mutex<HashMap<String, Arc<Rendezvous>>>> =
    Lazy::new(|| Mutex::new(HashMap::new()));

impl Rendezvous {
    /// A fresh rendezvous expecting `world` members per generation.
    pub fn new(world: usize) -> Arc<Self> {
        Arc::new(Self {
            inner: Mutex::new(RvInner {
                world: world.max(1),
                generation: 0,
                sealed: false,
                members: Vec::new(),
                last_sealed: None,
                spares: Vec::new(),
                heartbeats: HashMap::new(),
                grace: Duration::from_millis(150),
                resume: HashMap::new(),
            }),
            changed: Condvar::new(),
        })
    }

    /// How fresh a rank's heartbeat must be for a `report_dead` against it
    /// to be rejected (default 150 ms). Tune below the members' recv
    /// timeout, above their probe interval.
    pub fn set_heartbeat_grace(&self, grace: Duration) {
        self.inner.lock().unwrap().grace = grace;
    }

    /// Create and publish under `inproc://name` so thread-backend members
    /// can find it through [`RendezvousClient::connect`].
    pub fn inproc(name: &str, world: usize) -> Arc<Self> {
        let rv = Self::new(world);
        INPROC_RV
            .lock()
            .unwrap()
            .insert(name.to_string(), rv.clone());
        rv
    }

    /// Remove an `inproc://` rendezvous from the global registry.
    pub fn unpublish(name: &str) {
        INPROC_RV.lock().unwrap().remove(name);
    }

    /// Register a member's data endpoint. A join after the current
    /// generation sealed starts a new generation (re-rendezvous). Returns
    /// `(generation, rank)`.
    pub fn register(&self, data_addr: &str) -> (u64, u64) {
        let mut inner = self.inner.lock().unwrap();
        if inner.sealed {
            // Archive the sealed membership before starting the next
            // generation: members of the sealed ring that have not read it
            // yet must still be able to (a late join must not strand a
            // healthy generation mid-rendezvous).
            let generation = inner.generation;
            let archived = std::mem::take(&mut inner.members);
            inner.last_sealed = Some((generation, archived));
            inner.generation += 1;
            inner.sealed = false;
            // heartbeats are endpoint-keyed and deliberately survive the
            // bump: the archived generation's members are still live.
        }
        let since = inner.generation;
        inner.members.push(Seat {
            addr: data_addr.to_string(),
            since,
            observer: false,
        });
        let rank = (inner.members.len() - 1) as u64;
        if inner.members.len() >= inner.world {
            inner.sealed = true;
        }
        let generation = inner.generation;
        drop(inner);
        self.changed.notify_all();
        (generation, rank)
    }

    /// Current membership snapshot.
    pub fn membership(&self) -> Membership {
        let inner = self.inner.lock().unwrap();
        Membership {
            generation: inner.generation,
            world: inner.world as u64,
            sealed: inner.sealed,
            members: member_infos(&inner.members),
            last_sealed: inner
                .last_sealed
                .as_ref()
                .map(|(g, m)| (*g, member_infos(m))),
        }
    }

    /// A member leaves `generation`: bump the generation so survivors
    /// re-rendezvous. Stale calls (old generation) are ignored. Pair with
    /// [`Rendezvous::resize`] when the departure is a scale-down rather
    /// than churn, otherwise the next generation waits for a replacement.
    pub fn leave(&self, generation: u64, _rank: u64) {
        let mut inner = self.inner.lock().unwrap();
        if inner.generation == generation {
            inner.generation += 1;
            inner.sealed = false;
            inner.members.clear();
            // A departure invalidates old rings outright — no archived
            // snapshot may resurrect a generation missing a member.
            inner.last_sealed = None;
            // Pending spares outlive the departure (they were never part
            // of the ring); keep their liveness records too.
            let spares = inner.spares.clone();
            inner.heartbeats.retain(|a, _| spares.contains(a));
            drop(inner);
            self.changed.notify_all();
        }
    }

    /// Change the expected world size (dynamic scaling). Bumps the
    /// generation; all members re-register.
    pub fn resize(&self, world: usize) {
        let mut inner = self.inner.lock().unwrap();
        inner.world = world.max(1);
        inner.generation += 1;
        inner.sealed = false;
        inner.members.clear();
        inner.last_sealed = None;
        let spares = inner.spares.clone();
        inner.heartbeats.retain(|a, _| spares.contains(a));
        drop(inner);
        self.changed.notify_all();
    }

    /// Record liveness for the member advertising `endpoint`. Members call
    /// this while they wait on peers (and between units of compute work),
    /// so silence is evidence of death rather than of a long compute
    /// phase. Endpoint-keyed on purpose: it stays valid across heals and
    /// rank renumbering. Returns the current generation, so one heartbeat
    /// doubles as the generation-bump probe blocked receivers poll with —
    /// no full membership snapshot needed per probe slice.
    pub fn heartbeat(&self, endpoint: &str) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        inner
            .heartbeats
            .insert(endpoint.to_string(), Instant::now());
        inner.generation
    }

    /// Accuse `rank` of `generation` of being dead. Returns `true` when the
    /// accusation is accepted and the ring **healed**: the survivors of the
    /// sealed generation are re-ranked (densely, in their old rank order)
    /// into a new generation that seals immediately, any live pending
    /// spares are **drained in after them** (auto-grow — stamped with the
    /// healed generation, see [`MemberInfo::since`]), and a resume barrier
    /// is opened for the survivors (see [`Rendezvous::resume_poll`]).
    /// Returns `false` when the report is stale (generation already moved
    /// on), the ring is not sealed, the rank is out of range, or the
    /// accused heartbeated within the grace window — in the last case the
    /// reporter should keep waiting and retry.
    pub fn report_dead(&self, generation: u64, rank: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.generation != generation || !inner.sealed {
            return false;
        }
        if rank as usize >= inner.members.len() {
            return false;
        }
        if let Some(seen) = inner.heartbeats.get(&inner.members[rank as usize].addr) {
            if seen.elapsed() < inner.grace {
                return false; // alive by heartbeat — reject the accusation
            }
        }
        inner.members.remove(rank as usize);
        inner.generation += 1;
        // The dead generation must not be resurrected from the archive.
        inner.last_sealed = None;
        if inner.members.is_empty() {
            // The sole member died: nothing survives to resume (and no
            // one a drained spare could sync state from). The next
            // generation forms from scratch (world unchanged); spares
            // stay pending.
            inner.sealed = false;
        } else {
            // Auto-grow: the healed generation seals with the survivors
            // in the low ranks and every live pending spare appended.
            // Only the participating survivors report into the resume
            // barrier — rejoiners (this heal's and any still-observing
            // earlier drainee's) adopt through `resume_observe`.
            inner.seal_grown();
        }
        // Drop liveness records for endpoints neither ranked nor pending
        // (the dead member's among them); survivors' records stay valid.
        let live: Vec<String> = inner
            .members
            .iter()
            .map(|s| s.addr.clone())
            .chain(inner.spares.iter().cloned())
            .collect();
        inner.heartbeats.retain(|addr, _| live.contains(addr));
        drop(inner);
        self.changed.notify_all();
        true
    }

    /// Register a standby member into the spare pool: pending, unranked,
    /// and drafted into the next sealed generation — the next heal, or an
    /// explicit [`Rendezvous::grow`]. The spare must keep heartbeating its
    /// endpoint while pending; a spare silent past the grace window is
    /// excised from the pool without any generation bump. Registering is
    /// idempotent per endpoint and never disturbs the current generation.
    /// Returns the current generation.
    pub fn register_spare(&self, data_addr: &str) -> u64 {
        let mut inner = self.inner.lock().unwrap();
        if !inner.spares.iter().any(|a| a == data_addr)
            && !inner.members.iter().any(|s| s.addr == data_addr)
        {
            inner.spares.push(data_addr.to_string());
        }
        inner
            .heartbeats
            .insert(data_addr.to_string(), Instant::now());
        inner.generation
    }

    /// Withdraw a pending spare (e.g. its admission wait timed out). A
    /// no-op if the endpoint was already drained or never registered.
    pub fn deregister_spare(&self, data_addr: &str) {
        let mut inner = self.inner.lock().unwrap();
        inner.spares.retain(|a| a != data_addr);
        inner.heartbeats.remove(data_addr);
    }

    /// The live pending spares, in registration order. Prunes (excises)
    /// spares whose heartbeat went stale — a spare dying while pending
    /// never bumps the generation, it just vanishes from the pool.
    pub fn spares(&self) -> Vec<String> {
        let mut inner = self.inner.lock().unwrap();
        inner.prune_spares();
        inner.spares.clone()
    }

    /// Explicitly grow the sealed generation `generation` by draining the
    /// live pending spares into a new, immediately-sealed generation
    /// (members keep their ranks, spares append after them). Opens a
    /// resume barrier for the pre-grow members: their next collective
    /// heals into the grown generation and reports `completed = 0`, so
    /// the rejoiners adopt it from chunk 0 via the same min-barrier
    /// machinery a failure heal uses. Returns `false` when the request is
    /// stale, the generation is unsealed, or no live spare is pending.
    ///
    /// A **collective-boundary** operation: callers should be between
    /// collectives (any member's next collective performs the adoption) —
    /// typically rank 0 calls [`super::RingMember::request_grow`] between
    /// iterations.
    pub fn grow(&self, generation: u64) -> bool {
        let mut inner = self.inner.lock().unwrap();
        if inner.generation != generation || !inner.sealed {
            return false;
        }
        inner.prune_spares();
        if inner.spares.is_empty() {
            return false;
        }
        inner.generation += 1;
        inner.last_sealed = None;
        inner.seal_grown();
        drop(inner);
        self.changed.notify_all();
        true
    }

    /// The healed-generation resume barrier. Each participating survivor
    /// of `generation` reports the number of collective chunks it had
    /// fully completed when the membership changed, plus the [`OpDesc`] of
    /// the collective it was driving; once every required rank has
    /// reported, everyone receives **`(resume_op_seq, resume_chunk)`** —
    /// the most-advanced reported op and the minimum completed count among
    /// the members driving it. A member whose own op sequence is behind
    /// `resume_op_seq` learns that its collective was superseded at a
    /// boundary (it must be locally complete — see
    /// `RingMember::allreduce_sum`'s boundary handling) instead of being
    /// rolled back into an op its peers have already left. Returns `None`
    /// while reports are outstanding (poll again) or when `generation` has
    /// no open barrier. Re-reports from the same rank overwrite
    /// idempotently.
    pub fn resume_poll(
        &self,
        generation: u64,
        rank: u64,
        completed: u64,
        op: &OpDesc,
    ) -> Option<(u64, u64)> {
        let mut inner = self.inner.lock().unwrap();
        let st = inner.resume.get_mut(&generation)?;
        st.reports.insert(rank, (completed, op.op_seq));
        let replace = match &st.op {
            Some(cur) => op.op_seq > cur.op_seq,
            None => true,
        };
        if replace {
            st.op = Some(op.clone());
        }
        barrier_result(st)
    }

    /// Read `generation`'s resume barrier without reporting into it — the
    /// drained spare's side of the handshake. `rank` is the observer's own
    /// rank. Returns the resume chunk and the most-advanced collective's
    /// [`OpDesc`] once every required survivor has reported; `None` while
    /// the barrier is still forming, when the generation has no open
    /// barrier, or when the generation has already been superseded (the
    /// observer must re-sync and observe the *current* generation's
    /// barrier instead — adopting a superseded op would desynchronize
    /// it). A successful observe also **promotes the observer to a
    /// participant**: it now holds the op to adopt, so any later heal's
    /// barrier must require its report.
    pub fn resume_observe(&self, generation: u64, rank: u64) -> Option<(u64, OpDesc)> {
        let mut inner = self.inner.lock().unwrap();
        if inner.generation != generation {
            return None;
        }
        let (min, op) = {
            let st = inner.resume.get(&generation)?;
            let (_, min) = barrier_result(st)?;
            (min, st.op.clone()?)
        };
        if let Some(seat) = inner.members.get_mut(rank as usize) {
            seat.observer = false;
        }
        Some((min, op))
    }

    /// Required ranks of `generation` that have not reported into its
    /// resume barrier yet — `None` when the generation has no open
    /// barrier. Lets barrier waiters accuse a member that died *between*
    /// the first death and the barrier (a second simultaneous failure)
    /// instead of waiting on a corpse forever.
    pub fn resume_missing(&self, generation: u64) -> Option<Vec<u64>> {
        let inner = self.inner.lock().unwrap();
        let st = inner.resume.get(&generation)?;
        Some(
            st.required
                .iter()
                .filter(|r| !st.reports.contains_key(r))
                .copied()
                .collect(),
        )
    }

    /// Block until the given generation seals (or any later generation
    /// starts, which means the caller's registration is stale).
    fn wait_sealed(&self, generation: u64, timeout: Duration) -> Result<Membership> {
        let deadline = Instant::now() + timeout;
        let mut inner = self.inner.lock().unwrap();
        loop {
            if inner.sealed && inner.generation == generation {
                // Snapshot under the held lock: a join-after-seal on another
                // thread must not be able to clear the membership between
                // our check and the read.
                return Ok(Membership {
                    generation,
                    world: inner.world as u64,
                    sealed: true,
                    members: member_infos(&inner.members),
                    last_sealed: None,
                });
            }
            // Our generation sealed but a late join already started the
            // next one: the archived snapshot is still valid for us.
            if let Some((g, archived)) = &inner.last_sealed {
                if *g == generation {
                    return Ok(Membership {
                        generation,
                        world: archived.len() as u64,
                        sealed: true,
                        members: member_infos(archived),
                        last_sealed: None,
                    });
                }
            }
            if inner.generation > generation {
                anyhow::bail!(
                    "ring generation bumped to {} while waiting on {generation} — re-register",
                    inner.generation
                );
            }
            let now = Instant::now();
            anyhow::ensure!(now < deadline, "rendezvous timed out waiting for the ring to fill");
            let (g, _) = self.changed.wait_timeout(inner, deadline - now).unwrap();
            inner = g;
        }
    }

    /// Expose this rendezvous over TCP for OS-process members.
    pub fn serve_rpc(self: &Arc<Self>, bind: &str) -> Result<RpcServer> {
        let rv = self.clone();
        RpcServer::bind(
            bind,
            Arc::new(move |tag, payload| match tag {
                tags::REGISTER => {
                    let addr: String = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    Ok(wire::to_bytes(&rv.register(&addr)))
                }
                tags::MEMBERSHIP => Ok(wire::to_bytes(&rv.membership())),
                tags::LEAVE => {
                    let (generation, rank): (u64, u64) =
                        wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    rv.leave(generation, rank);
                    Ok(Vec::new())
                }
                tags::RESIZE => {
                    let world: u64 = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    rv.resize(world as usize);
                    Ok(Vec::new())
                }
                tags::HEARTBEAT => {
                    let endpoint: String = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    Ok(wire::to_bytes(&rv.heartbeat(&endpoint)))
                }
                tags::REPORT_DEAD => {
                    let (generation, rank): (u64, u64) =
                        wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    Ok(wire::to_bytes(&rv.report_dead(generation, rank)))
                }
                tags::RESUME => {
                    let (generation, rank, completed, op): (u64, u64, u64, OpDesc) =
                        wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    Ok(wire::to_bytes(&rv.resume_poll(generation, rank, completed, &op)))
                }
                tags::RESUME_MISSING => {
                    let generation: u64 = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    Ok(wire::to_bytes(&rv.resume_missing(generation)))
                }
                tags::RESUME_OBSERVE => {
                    let (generation, rank): (u64, u64) =
                        wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    Ok(wire::to_bytes(&rv.resume_observe(generation, rank)))
                }
                tags::REGISTER_SPARE => {
                    let addr: String = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    Ok(wire::to_bytes(&rv.register_spare(&addr)))
                }
                tags::DEREGISTER_SPARE => {
                    let addr: String = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    rv.deregister_spare(&addr);
                    Ok(Vec::new())
                }
                tags::GROW => {
                    let generation: u64 = wire::from_bytes(payload).map_err(|e| e.to_string())?;
                    Ok(wire::to_bytes(&rv.grow(generation)))
                }
                t => Err(format!("bad rendezvous rpc tag {t}")),
            }),
        )
    }
}

/// Client handle to a rendezvous, local or remote — the same four verbs
/// over either transport, which is what lets ring programs move between
/// the thread and OS-process backends unchanged.
pub enum RendezvousClient {
    Local(Arc<Rendezvous>),
    Remote(RpcClient),
}

impl RendezvousClient {
    /// Connect to `inproc://name` (published via [`Rendezvous::inproc`])
    /// or `tcp://host:port` (served via [`Rendezvous::serve_rpc`]).
    pub fn connect(addr: &Addr) -> Result<Self> {
        match addr {
            Addr::Inproc(name) => {
                let rv = INPROC_RV
                    .lock()
                    .unwrap()
                    .get(name)
                    .cloned()
                    .with_context(|| format!("no inproc rendezvous named {name:?}"))?;
                Ok(RendezvousClient::Local(rv))
            }
            Addr::Tcp(sa) => Ok(RendezvousClient::Remote(RpcClient::connect(*sa)?)),
        }
    }

    /// Wrap an already-held local rendezvous.
    pub fn local(rv: Arc<Rendezvous>) -> Self {
        RendezvousClient::Local(rv)
    }

    pub fn register(&self, data_addr: &str) -> Result<(u64, u64)> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.register(data_addr)),
            RendezvousClient::Remote(cli) => {
                cli.call_typed(tags::REGISTER, &data_addr.to_string())
            }
        }
    }

    pub fn membership(&self) -> Result<Membership> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.membership()),
            RendezvousClient::Remote(cli) => cli.call_typed(tags::MEMBERSHIP, &()),
        }
    }

    pub fn leave(&self, generation: u64, rank: u64) -> Result<()> {
        match self {
            RendezvousClient::Local(rv) => {
                rv.leave(generation, rank);
                Ok(())
            }
            RendezvousClient::Remote(cli) => cli.call_typed(tags::LEAVE, &(generation, rank)),
        }
    }

    pub fn resize(&self, world: usize) -> Result<()> {
        match self {
            RendezvousClient::Local(rv) => {
                rv.resize(world);
                Ok(())
            }
            RendezvousClient::Remote(cli) => cli.call_typed(tags::RESIZE, &(world as u64)),
        }
    }

    /// Record liveness; returns the rendezvous' current generation (see
    /// [`Rendezvous::heartbeat`]).
    pub fn heartbeat(&self, endpoint: &str) -> Result<u64> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.heartbeat(endpoint)),
            RendezvousClient::Remote(cli) => {
                cli.call_typed(tags::HEARTBEAT, &endpoint.to_string())
            }
        }
    }

    /// Accuse a rank of being dead (see [`Rendezvous::report_dead`]).
    pub fn report_dead(&self, generation: u64, rank: u64) -> Result<bool> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.report_dead(generation, rank)),
            RendezvousClient::Remote(cli) => {
                cli.call_typed(tags::REPORT_DEAD, &(generation, rank))
            }
        }
    }

    /// Poll the healed-generation resume barrier (see
    /// [`Rendezvous::resume_poll`]).
    pub fn resume_poll(
        &self,
        generation: u64,
        rank: u64,
        completed: u64,
        op: &OpDesc,
    ) -> Result<Option<(u64, u64)>> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.resume_poll(generation, rank, completed, op)),
            RendezvousClient::Remote(cli) => {
                cli.call_typed(tags::RESUME, &(generation, rank, completed, op.clone()))
            }
        }
    }

    /// Observe a resume barrier without reporting (see
    /// [`Rendezvous::resume_observe`]).
    pub fn resume_observe(&self, generation: u64, rank: u64) -> Result<Option<(u64, OpDesc)>> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.resume_observe(generation, rank)),
            RendezvousClient::Remote(cli) => {
                cli.call_typed(tags::RESUME_OBSERVE, &(generation, rank))
            }
        }
    }

    /// Enter the spare pool (see [`Rendezvous::register_spare`]).
    pub fn register_spare(&self, data_addr: &str) -> Result<u64> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.register_spare(data_addr)),
            RendezvousClient::Remote(cli) => {
                cli.call_typed(tags::REGISTER_SPARE, &data_addr.to_string())
            }
        }
    }

    /// Withdraw from the spare pool (see [`Rendezvous::deregister_spare`]).
    pub fn deregister_spare(&self, data_addr: &str) -> Result<()> {
        match self {
            RendezvousClient::Local(rv) => {
                rv.deregister_spare(data_addr);
                Ok(())
            }
            RendezvousClient::Remote(cli) => {
                cli.call_typed(tags::DEREGISTER_SPARE, &data_addr.to_string())
            }
        }
    }

    /// Request an explicit grow (see [`Rendezvous::grow`]).
    pub fn grow(&self, generation: u64) -> Result<bool> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.grow(generation)),
            RendezvousClient::Remote(cli) => cli.call_typed(tags::GROW, &generation),
        }
    }

    /// Ranks still missing from a resume barrier (see
    /// [`Rendezvous::resume_missing`]).
    pub fn resume_missing(&self, generation: u64) -> Result<Option<Vec<u64>>> {
        match self {
            RendezvousClient::Local(rv) => Ok(rv.resume_missing(generation)),
            RendezvousClient::Remote(cli) => cli.call_typed(tags::RESUME_MISSING, &generation),
        }
    }

    /// Register `data_addr` and block until the generation seals, returning
    /// the member's resolved [`RingView`]. Errors if the generation bumps
    /// mid-wait (caller should retry) or `timeout` elapses.
    pub fn join(&self, data_addr: &str, timeout: Duration) -> Result<RingView> {
        let (generation, rank) = self.register(data_addr)?;
        let m = match self {
            RendezvousClient::Local(rv) => rv.wait_sealed(generation, timeout)?,
            RendezvousClient::Remote(_) => {
                // Poll: RPC handlers shouldn't hold a server thread hostage
                // for the whole rendezvous window.
                let deadline = Instant::now() + timeout;
                loop {
                    let m = self.membership()?;
                    if m.sealed && m.generation == generation {
                        break m;
                    }
                    // A late join may have bumped the forming generation
                    // right after ours sealed; the archive still serves us.
                    if let Some((g, archived)) = &m.last_sealed {
                        if *g == generation {
                            break Membership {
                                generation,
                                world: archived.len() as u64,
                                sealed: true,
                                members: archived.clone(),
                                last_sealed: None,
                            };
                        }
                    }
                    if m.generation > generation {
                        anyhow::bail!(
                            "ring generation bumped to {} while waiting on {generation} — re-register",
                            m.generation
                        );
                    }
                    anyhow::ensure!(
                        Instant::now() < deadline,
                        "rendezvous timed out waiting for the ring to fill"
                    );
                    std::thread::sleep(Duration::from_millis(2));
                }
            }
        };
        m.resolve_view(rank as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranks_are_dense_and_seal_at_world() {
        let rv = Rendezvous::new(3);
        assert_eq!(rv.register("inproc://a"), (0, 0));
        assert_eq!(rv.register("inproc://b"), (0, 1));
        assert!(!rv.membership().sealed);
        assert_eq!(rv.register("inproc://c"), (0, 2));
        let m = rv.membership();
        assert!(m.sealed);
        assert_eq!(m.members.len(), 3);
        assert_eq!(m.members[1].addr, "inproc://b");
    }

    #[test]
    fn join_after_seal_bumps_generation() {
        let rv = Rendezvous::new(2);
        rv.register("inproc://a");
        rv.register("inproc://b");
        assert_eq!(rv.membership().generation, 0);
        // A third member joining forces re-rendezvous.
        let (generation, rank) = rv.register("inproc://c");
        assert_eq!((generation, rank), (1, 0));
        let m = rv.membership();
        assert_eq!(m.generation, 1);
        assert!(!m.sealed);
        assert_eq!(m.members.len(), 1);
        // The sealed generation 0 is archived, not destroyed.
        let (g, archived) = m.last_sealed.expect("sealed gen 0 archived");
        assert_eq!(g, 0);
        assert_eq!(archived.len(), 2);
    }

    #[test]
    fn late_join_preserves_sealed_snapshot_for_unread_members() {
        // Regression: a join landing right after a generation seals must
        // not strand members of that generation that have not read their
        // membership yet.
        let rv = Rendezvous::new(2);
        let (g0, _) = rv.register("inproc://a");
        rv.register("inproc://b");
        rv.register("inproc://c"); // bumps the forming generation to 1
        assert_eq!(rv.membership().generation, 1);
        // A generation-0 member reading late still gets its sealed ring.
        let m = rv.wait_sealed(g0, Duration::from_millis(50)).unwrap();
        assert_eq!(m.generation, 0);
        assert!(m.sealed);
        assert_eq!(m.members.len(), 2);
        assert_eq!(m.members[1].addr, "inproc://b");
        // leave() invalidates the archive — no resurrecting a ring that
        // lost a member.
        rv.leave(1, 0);
        assert!(rv.membership().last_sealed.is_none());
        assert!(rv.wait_sealed(g0, Duration::from_millis(20)).is_err());
    }

    #[test]
    fn leave_and_resize_bump_generation() {
        let rv = Rendezvous::new(2);
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.leave(0, 1);
        assert_eq!(rv.membership().generation, 1);
        rv.leave(0, 0); // stale: already bumped
        assert_eq!(rv.membership().generation, 1);
        rv.resize(3);
        let m = rv.membership();
        assert_eq!(m.generation, 2);
        assert_eq!(m.world, 3);
    }

    #[test]
    fn join_blocks_until_full() {
        let rv = Rendezvous::new(2);
        let rv2 = rv.clone();
        let h = std::thread::spawn(move || {
            RendezvousClient::local(rv2)
                .join("inproc://first", Duration::from_secs(5))
                .unwrap()
        });
        std::thread::sleep(Duration::from_millis(20));
        let v2 = RendezvousClient::local(rv.clone())
            .join("inproc://second", Duration::from_secs(5))
            .unwrap();
        let v1 = h.join().unwrap();
        assert_eq!(v1.rank, 0);
        assert_eq!(v2.rank, 1);
        assert_eq!(v1.world, 2);
        assert_eq!(v1.members, v2.members);
        assert_eq!(v1.right(), 1);
        assert_eq!(v1.left(), 1);
    }

    #[test]
    fn join_times_out_when_ring_never_fills() {
        let rv = Rendezvous::new(2);
        let err = RendezvousClient::local(rv)
            .join("inproc://lonely", Duration::from_millis(30))
            .unwrap_err();
        assert!(err.to_string().contains("timed out"), "{err}");
    }

    #[test]
    fn rpc_rendezvous_roundtrip() {
        let rv = Rendezvous::new(2);
        let srv = rv.serve_rpc("127.0.0.1:0").unwrap();
        let addr = Addr::Tcp(srv.local_addr());
        let a1 = addr.clone();
        let h = std::thread::spawn(move || {
            RendezvousClient::connect(&a1)
                .unwrap()
                .join("tcp://127.0.0.1:7001", Duration::from_secs(5))
                .unwrap()
        });
        let v2 = RendezvousClient::connect(&addr)
            .unwrap()
            .join("tcp://127.0.0.1:7002", Duration::from_secs(5))
            .unwrap();
        let v1 = h.join().unwrap();
        assert_eq!(v1.world, 2);
        assert_eq!(v2.world, 2);
        assert_ne!(v1.rank, v2.rank);
        assert_eq!(v1.members, v2.members);
    }

    #[test]
    fn membership_wire_roundtrip() {
        let m = Membership {
            generation: 3,
            world: 2,
            sealed: true,
            members: vec![
                MemberInfo {
                    rank: 0,
                    addr: "tcp://127.0.0.1:9000".into(),
                    since: 0,
                },
                MemberInfo {
                    rank: 1,
                    addr: "inproc://x".into(),
                    since: 3,
                },
            ],
            last_sealed: Some((
                2,
                vec![MemberInfo {
                    rank: 0,
                    addr: "tcp://127.0.0.1:8000".into(),
                    since: 1,
                }],
            )),
        };
        let bytes = wire::to_bytes(&m);
        let back: Membership = wire::from_bytes(&bytes).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn report_dead_heals_with_dense_survivor_ranks() {
        let rv = Rendezvous::new(3);
        rv.set_heartbeat_grace(Duration::from_millis(20));
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.register("inproc://c");
        assert!(rv.membership().sealed);
        // Rank 1 dies: survivors re-rank densely, generation bumps, sealed.
        assert!(rv.report_dead(0, 1));
        let m = rv.membership();
        assert_eq!(m.generation, 1);
        assert!(m.sealed);
        assert_eq!(m.world, 2);
        let addrs: Vec<_> = m.members.iter().map(|i| i.addr.as_str()).collect();
        assert_eq!(addrs, vec!["inproc://a", "inproc://c"]);
        for (i, info) in m.members.iter().enumerate() {
            assert_eq!(info.rank, i as u64, "ranks must stay dense");
        }
        // Stale report against the old generation is a no-op.
        assert!(!rv.report_dead(0, 0));
        assert_eq!(rv.membership().generation, 1);
    }

    #[test]
    fn report_dead_rejected_within_heartbeat_grace() {
        let rv = Rendezvous::new(2);
        rv.set_heartbeat_grace(Duration::from_secs(10));
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.heartbeat("inproc://b");
        assert!(!rv.report_dead(0, 1), "fresh heartbeat must veto the report");
        assert_eq!(rv.membership().generation, 0);
        // Without a heartbeat on record the report is accepted.
        assert!(rv.report_dead(0, 0));
        assert_eq!(rv.membership().generation, 1);
        // The endpoint-keyed heartbeat still protects b after the heal and
        // rank renumbering (b is now rank 0 of generation 1).
        assert!(!rv.report_dead(1, 0), "stale-view member must stay protected");
    }

    #[test]
    fn resume_barrier_releases_min_once_all_report() {
        let rv = Rendezvous::new(3);
        rv.set_heartbeat_grace(Duration::from_millis(1));
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.register("inproc://c");
        std::thread::sleep(Duration::from_millis(5));
        assert!(rv.report_dead(0, 2));
        let op = OpDesc {
            op_seq: 2,
            ..OpDesc::default()
        };
        // Two survivors: barrier holds until both report, then min wins.
        assert_eq!(rv.resume_poll(1, 0, 7, &op), None);
        assert_eq!(rv.resume_poll(1, 0, 7, &op), None, "re-report is idempotent");
        assert_eq!(rv.resume_poll(1, 1, 3, &op), Some((2, 3)));
        assert_eq!(
            rv.resume_poll(1, 0, 7, &op),
            Some((2, 3)),
            "late re-poll still sees the min"
        );
        // No barrier for generations that never healed.
        assert_eq!(rv.resume_poll(0, 0, 0, &op), None);
    }

    #[test]
    fn resume_missing_names_unreported_ranks() {
        let rv = Rendezvous::new(3);
        rv.set_heartbeat_grace(Duration::from_millis(1));
        rv.register("inproc://a");
        rv.register("inproc://b");
        rv.register("inproc://c");
        std::thread::sleep(Duration::from_millis(5));
        assert!(rv.report_dead(0, 0));
        assert_eq!(rv.resume_missing(1), Some(vec![0, 1]));
        assert_eq!(rv.resume_poll(1, 1, 9, &OpDesc::default()), None);
        assert_eq!(rv.resume_missing(1), Some(vec![0]));
        assert_eq!(rv.resume_missing(0), None, "no barrier for unhealed generations");
    }

    #[test]
    fn healing_rpc_roundtrip() {
        let rv = Rendezvous::new(2);
        rv.set_heartbeat_grace(Duration::from_millis(1));
        let srv = rv.serve_rpc("127.0.0.1:0").unwrap();
        let cli = RendezvousClient::connect(&Addr::Tcp(srv.local_addr())).unwrap();
        rv.register("tcp://127.0.0.1:7101");
        rv.register("tcp://127.0.0.1:7102");
        cli.heartbeat("tcp://127.0.0.1:7101").unwrap();
        std::thread::sleep(Duration::from_millis(5));
        assert!(cli.report_dead(0, 1).unwrap());
        let op = OpDesc {
            op_seq: 5,
            kind: 0,
            elems: 12,
            ..OpDesc::default()
        };
        assert_eq!(cli.resume_poll(1, 0, 4, &op).unwrap(), Some((5, 4)));
        assert_eq!(cli.resume_observe(1, 0).unwrap(), Some((4, op)));
        // Spare verbs over RPC: register, list through a grow, deregister.
        assert_eq!(cli.register_spare("tcp://127.0.0.1:7103").unwrap(), 1);
        assert!(cli.grow(1).unwrap());
        assert_eq!(rv.membership().members.len(), 2);
        cli.deregister_spare("tcp://127.0.0.1:7104").unwrap(); // no-op
    }

    #[test]
    fn inproc_registry_publish_and_connect() {
        let _rv = Rendezvous::inproc("topo-test-rv", 1);
        let cli = RendezvousClient::connect(&Addr::parse("inproc://topo-test-rv").unwrap());
        assert!(cli.is_ok());
        Rendezvous::unpublish("topo-test-rv");
        let cli = RendezvousClient::connect(&Addr::parse("inproc://topo-test-rv").unwrap());
        assert!(cli.is_err());
    }
}
