//! `fiber.Ring` — the collective-communication subsystem.
//!
//! Pool and Queue move *tasks*; Ring moves *tensors*. The paper's third
//! building block turns a set of cluster jobs into ranked members of a ring
//! so population-based methods and distributed SGD can combine results
//! peer-to-peer instead of funnelling everything through one leader. With a
//! ring allreduce the leader-side traffic drops from `O(pop·θ)` to `O(θ)`
//! per node — each member sends and receives `2·(n-1)/n · θ` elements no
//! matter how large the world grows.
//!
//! Three layers:
//!
//! * [`topology`] — the rendezvous service. Members register, receive a
//!   stable **rank** and the full ring membership for the current
//!   **generation**; joins and leaves bump the generation so members
//!   re-rendezvous (the dynamic-scaling story of
//!   [`crate::coordinator::scaling`], applied to collectives). Members
//!   heartbeat while they wait; `report_dead` heals a sealed generation by
//!   re-ranking the survivors, and the `resume_poll` min-barrier lets them
//!   agree where an interrupted collective resumes.
//! * [`spare`] — the **auto-grow** half of elasticity. Standby members
//!   register as pending spares (pool-style); every heal — and any
//!   explicit [`topology::Rendezvous::grow`] — drains the live spares
//!   into the new sealed generation after the survivors, and the drained
//!   member adopts the in-flight collective through the same min-barrier
//!   (resuming as a neutral relay), so a kill → heal → auto-grow cycle
//!   returns the ring to its original world without restarting the
//!   collective. Cold rejoiners are brought up to algorithm state by
//!   their driver (e.g. [`crate::algo::es::EsRingNode::join_ring_as_spare`]),
//!   re-warming bulk tables through the object store as cache hits.
//! * [`kernels`] — the vectorized elementwise loops (`add_assign`,
//!   `scale`, `axpy`, …) under every reduce: fixed-width chunked slices
//!   the autovectorizer turns into packed SIMD, with an explicit
//!   `std::simd` variant behind the nightly-only `simd` feature and the
//!   naive scalar forms kept as the measured baseline.
//! * [`collectives`] — chunked ring allreduce (reduce-scatter + all-gather),
//!   broadcast and all-gather over `f32` buffers, framed with
//!   [`crate::wire`] and working identically over `inproc://` channels
//!   (thread backend, [`crate::cluster::LocalBackend`]) and `tcp://` RPC
//!   (OS-process backend, [`crate::cluster::ProcBackend`]). Allreduce and
//!   broadcast execute an explicit per-chunk [`CollectiveStep`] plan with
//!   recorded progress, so a member death mid-collective **heals**: the
//!   generation bumps, survivors re-rank and resume from the first chunk
//!   any of them had not completed. The chunk pipeline is double-buffered
//!   (chunk *k+1*'s traffic in flight while chunk *k* reduces) — see
//!   [`RingMember::overlap_efficiency`]. Bulk one-to-all payloads can also
//!   ride the object store: [`RingMember::store_broadcast`] circulates a
//!   24-byte content id instead of the payload, so members that already
//!   hold the blob (post-heal retries, rejoining replacements) cache-hit
//!   through [`crate::store`] instead of re-streaming.
//!
//! ```
//! use fiber::ring::{Rendezvous, RingMember};
//!
//! let rv = Rendezvous::inproc("doc-ring", 2);
//! let h: Vec<_> = (0..2)
//!     .map(|_| {
//!         let rv = rv.clone();
//!         std::thread::spawn(move || {
//!             let mut m = RingMember::join_inproc(&rv).unwrap();
//!             let mut buf = vec![(m.rank() + 1) as f32; 8];
//!             m.allreduce_sum(&mut buf).unwrap();
//!             buf
//!         })
//!     })
//!     .collect();
//! for t in h {
//!     assert_eq!(t.join().unwrap(), vec![3.0f32; 8]); // 1 + 2
//! }
//! ```

pub mod collectives;
pub mod kernels;
pub mod spare;
pub mod topology;

pub use collectives::{
    allreduce_plan, is_chaos_killed, CollectiveStep, RingError, RingMember, StepPhase, Transport,
};
pub use spare::{ColdStart, OpDesc};
pub use topology::{MemberInfo, Rendezvous, RendezvousClient, RingView};
