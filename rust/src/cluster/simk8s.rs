//! A simulated Kubernetes-style cluster manager in virtual time.
//!
//! This is the documented substitution for the paper's Kubernetes/Peloton
//! testbed (DESIGN.md §2): nodes with CPU/mem/GPU capacity, a FIFO first-fit
//! pod scheduler with a configurable scheduling latency (the paper's k8s
//! clusters take tens of ms to hundreds of ms to place a pod), pod start
//! latency (container boot), and exponential failure injection. The
//! dynamic-scaling experiment (E5) and the virtual-time scaling runs (E2)
//! measure pod placement, utilization and recovery against this model.
//!
//! Pods here don't execute code — they occupy resources for a requested
//! virtual duration (or indefinitely for service pods until terminated).
//! The *protocol* simulations (task dispatch etc.) are layered on the same
//! event engine in `baselines::sim_models`.

use std::collections::{HashMap, VecDeque};

use crate::cluster::des::{EventQueue, SimTime};
use crate::cluster::Resources;
use crate::util::Rng;

/// Capacity of one simulated node.
#[derive(Clone, Copy, Debug)]
pub struct NodeSpec {
    pub cpu_milli: u32,
    pub mem_mb: u32,
    pub gpu: u32,
}

impl NodeSpec {
    pub fn cpu_only(cores: u32, mem_mb: u32) -> Self {
        Self {
            cpu_milli: cores * 1000,
            mem_mb,
            gpu: 0,
        }
    }

    pub fn with_gpu(cores: u32, mem_mb: u32, gpu: u32) -> Self {
        Self {
            cpu_milli: cores * 1000,
            mem_mb,
            gpu,
        }
    }
}

/// Cluster-wide simulation parameters.
#[derive(Clone, Debug)]
pub struct SimClusterConfig {
    pub nodes: Vec<NodeSpec>,
    /// Mean scheduler decision latency per pod (exponential), ns.
    pub schedule_latency_ns: u64,
    /// Mean container start latency (exponential), ns.
    pub start_latency_ns: u64,
    /// Pod failure rate per virtual second (0 disables failure injection).
    pub failure_rate_per_s: f64,
    pub seed: u64,
}

impl Default for SimClusterConfig {
    fn default() -> Self {
        Self {
            // 32 nodes × 32 cores = 1024 cores, the paper's ES scale.
            nodes: vec![NodeSpec::cpu_only(32, 128_000); 32],
            schedule_latency_ns: 50_000_000, // 50 ms
            start_latency_ns: 800_000_000,   // 0.8 s container boot
            failure_rate_per_s: 0.0,
            seed: 0,
        }
    }
}

/// Pod identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PodId(pub u64);

/// A pod request.
#[derive(Clone, Debug)]
pub struct PodSpec {
    pub name: String,
    pub resources: Resources,
    /// Run duration in virtual ns; `None` = service pod (runs until
    /// terminated).
    pub duration_ns: Option<u64>,
}

/// Pod lifecycle state.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PodPhase {
    Pending,
    Scheduled { node: usize },
    Running { node: usize },
    Succeeded,
    Failed(String),
    Terminated,
}

impl PodPhase {
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            PodPhase::Succeeded | PodPhase::Failed(_) | PodPhase::Terminated
        )
    }
}

#[derive(Debug)]
enum Ev {
    /// Scheduler decision ready for this pod.
    Schedule(PodId),
    /// Container finished booting.
    Started(PodId),
    /// Work completed.
    Completed(PodId),
    /// Injected failure.
    Fail(PodId),
}

struct Pod {
    spec: PodSpec,
    phase: PodPhase,
    /// Generation counter: stale events (e.g. a Completed for a pod that
    /// already failed) are ignored by comparing generations.
    gen: u64,
    started_at: Option<SimTime>,
    finished_at: Option<SimTime>,
}

struct Node {
    spec: NodeSpec,
    used: Resources,
}

impl Node {
    fn fits(&self, r: &Resources) -> bool {
        self.used.cpu_milli + r.cpu_milli <= self.spec.cpu_milli
            && self.used.mem_mb + r.mem_mb <= self.spec.mem_mb
            && self.used.gpu + r.gpu <= self.spec.gpu
    }

    fn alloc(&mut self, r: &Resources) {
        self.used.cpu_milli += r.cpu_milli;
        self.used.mem_mb += r.mem_mb;
        self.used.gpu += r.gpu;
    }

    fn free(&mut self, r: &Resources) {
        self.used.cpu_milli -= r.cpu_milli;
        self.used.mem_mb -= r.mem_mb;
        self.used.gpu -= r.gpu;
    }
}

/// One (time, pod, phase) transition, for assertions and utilization plots.
#[derive(Clone, Debug)]
pub struct PodEvent {
    pub at: SimTime,
    pub pod: PodId,
    pub phase: PodPhase,
}

/// The simulated cluster.
pub struct SimCluster {
    cfg: SimClusterConfig,
    nodes: Vec<Node>,
    pods: HashMap<PodId, Pod>,
    queue: EventQueue<(u64, Ev)>, // (generation, event)
    pending: VecDeque<PodId>,
    rng: Rng,
    next_pod: u64,
    pub log: Vec<PodEvent>,
}

impl SimCluster {
    pub fn new(cfg: SimClusterConfig) -> Self {
        let nodes = cfg
            .nodes
            .iter()
            .map(|&spec| Node {
                spec,
                used: Resources {
                    cpu_milli: 0,
                    mem_mb: 0,
                    gpu: 0,
                },
            })
            .collect();
        let rng = Rng::new(cfg.seed ^ 0x5153_u64);
        Self {
            cfg,
            nodes,
            pods: HashMap::new(),
            queue: EventQueue::new(),
            pending: VecDeque::new(),
            rng,
            next_pod: 1,
            log: Vec::new(),
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.queue.now()
    }

    /// Submit a pod; scheduling begins after the scheduler latency.
    pub fn submit(&mut self, spec: PodSpec) -> PodId {
        let id = PodId(self.next_pod);
        self.next_pod += 1;
        self.pods.insert(
            id,
            Pod {
                spec,
                phase: PodPhase::Pending,
                gen: 0,
                started_at: None,
                finished_at: None,
            },
        );
        self.push_log(id, PodPhase::Pending);
        let lat = self.rng.exponential(self.cfg.schedule_latency_ns as f64) as u64;
        self.queue.push_after(lat, (0, Ev::Schedule(id)));
        id
    }

    /// Terminate a pod (frees resources immediately at the current time).
    pub fn terminate(&mut self, id: PodId) {
        let Some(pod) = self.pods.get_mut(&id) else { return };
        if pod.phase.is_terminal() {
            return;
        }
        if let PodPhase::Running { node } | PodPhase::Scheduled { node } = pod.phase {
            let res = pod.spec.resources;
            self.nodes[node].free(&res);
        }
        pod.gen += 1;
        pod.phase = PodPhase::Terminated;
        pod.finished_at = Some(self.queue.now());
        self.push_log(id, PodPhase::Terminated);
        self.pending.retain(|&p| p != id);
        self.try_schedule_pending();
    }

    pub fn phase(&self, id: PodId) -> Option<&PodPhase> {
        self.pods.get(&id).map(|p| &p.phase)
    }

    pub fn started_at(&self, id: PodId) -> Option<SimTime> {
        self.pods.get(&id).and_then(|p| p.started_at)
    }

    pub fn finished_at(&self, id: PodId) -> Option<SimTime> {
        self.pods.get(&id).and_then(|p| p.finished_at)
    }

    /// (used cpu_milli, total cpu_milli) across the cluster.
    pub fn cpu_utilization(&self) -> (u64, u64) {
        let used = self.nodes.iter().map(|n| n.used.cpu_milli as u64).sum();
        let total = self.nodes.iter().map(|n| n.spec.cpu_milli as u64).sum();
        (used, total)
    }

    /// Number of pods not yet in a terminal phase.
    pub fn live_pods(&self) -> usize {
        self.pods.values().filter(|p| !p.phase.is_terminal()).count()
    }

    /// Process events until the queue is empty or `until` is reached.
    /// Returns the final virtual time.
    pub fn run_until(&mut self, until: SimTime) -> SimTime {
        while let Some(t) = self.queue.peek_time() {
            if t > until {
                break;
            }
            let (_, (gen, ev)) = self.queue.pop().unwrap();
            self.handle(gen, ev);
        }
        self.queue.now().min(until)
    }

    /// Process all events to quiescence.
    pub fn run_to_quiescence(&mut self) -> SimTime {
        while let Some((_, (gen, ev))) = self.queue.pop() {
            self.handle(gen, ev);
        }
        self.queue.now()
    }

    fn handle(&mut self, gen: u64, ev: Ev) {
        match ev {
            Ev::Schedule(id) => {
                if self.stale(id, gen) {
                    return;
                }
                if !self.try_place(id) {
                    self.pending.push_back(id);
                }
            }
            Ev::Started(id) => {
                if self.stale(id, gen) {
                    return;
                }
                let now = self.queue.now();
                let pod = self.pods.get_mut(&id).unwrap();
                let PodPhase::Scheduled { node } = pod.phase else { return };
                pod.phase = PodPhase::Running { node };
                pod.started_at = Some(now);
                self.push_log(id, PodPhase::Running { node });
                let (duration, gen_now) = {
                    let pod = self.pods.get(&id).unwrap();
                    (pod.spec.duration_ns, pod.gen)
                };
                if let Some(d) = duration {
                    self.queue.push_after(d, (gen_now, Ev::Completed(id)));
                }
                if self.cfg.failure_rate_per_s > 0.0 {
                    let mean_ns = 1e9 / self.cfg.failure_rate_per_s;
                    let t = self.rng.exponential(mean_ns) as u64;
                    // Only fails if it fires before completion/termination
                    // (stale-generation check handles the race).
                    self.queue.push_after(t, (gen_now, Ev::Fail(id)));
                }
            }
            Ev::Completed(id) => {
                if self.stale(id, gen) {
                    return;
                }
                self.finish(id, PodPhase::Succeeded);
            }
            Ev::Fail(id) => {
                if self.stale(id, gen) {
                    return;
                }
                self.finish(id, PodPhase::Failed("injected node failure".into()));
            }
        }
    }

    fn stale(&self, id: PodId, gen: u64) -> bool {
        self.pods.get(&id).map_or(true, |p| p.gen != gen || p.phase.is_terminal())
    }

    fn finish(&mut self, id: PodId, phase: PodPhase) {
        let now = self.queue.now();
        let pod = self.pods.get_mut(&id).unwrap();
        if let PodPhase::Running { node } | PodPhase::Scheduled { node } = pod.phase {
            let res = pod.spec.resources;
            self.nodes[node].free(&res);
        }
        pod.gen += 1;
        pod.phase = phase.clone();
        pod.finished_at = Some(now);
        self.push_log(id, phase);
        self.try_schedule_pending();
    }

    /// First-fit placement. Returns false if no node has capacity.
    fn try_place(&mut self, id: PodId) -> bool {
        let res = self.pods[&id].spec.resources;
        let Some(node_idx) = self.nodes.iter().position(|n| n.fits(&res)) else {
            return false;
        };
        self.nodes[node_idx].alloc(&res);
        let pod = self.pods.get_mut(&id).unwrap();
        pod.phase = PodPhase::Scheduled { node: node_idx };
        let gen = pod.gen;
        self.push_log(id, PodPhase::Scheduled { node: node_idx });
        let boot = self.rng.exponential(self.cfg.start_latency_ns as f64) as u64;
        self.queue.push_after(boot, (gen, Ev::Started(id)));
        true
    }

    fn try_schedule_pending(&mut self) {
        let mut still_pending = VecDeque::new();
        while let Some(id) = self.pending.pop_front() {
            if self.pods[&id].phase.is_terminal() {
                continue;
            }
            if !self.try_place(id) {
                still_pending.push_back(id);
            }
        }
        self.pending = still_pending;
    }

    fn push_log(&mut self, pod: PodId, phase: PodPhase) {
        self.log.push(PodEvent {
            at: self.queue.now(),
            pod,
            phase,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> SimClusterConfig {
        SimClusterConfig {
            nodes: vec![NodeSpec::cpu_only(4, 8000); 2], // 8 cores total
            schedule_latency_ns: 1_000_000,
            start_latency_ns: 5_000_000,
            failure_rate_per_s: 0.0,
            seed: 1,
        }
    }

    fn one_cpu_pod(name: &str, dur: Option<u64>) -> PodSpec {
        PodSpec {
            name: name.into(),
            resources: Resources {
                cpu_milli: 1000,
                mem_mb: 100,
                gpu: 0,
            },
            duration_ns: dur,
        }
    }

    #[test]
    fn pod_runs_to_completion() {
        let mut c = SimCluster::new(small_cfg());
        let id = c.submit(one_cpu_pod("p", Some(1_000_000_000)));
        c.run_to_quiescence();
        assert_eq!(c.phase(id), Some(&PodPhase::Succeeded));
        let (used, _) = c.cpu_utilization();
        assert_eq!(used, 0, "resources freed");
        assert!(c.finished_at(id).unwrap() >= 1_000_000_000);
    }

    #[test]
    fn capacity_limits_queue_pods() {
        let mut c = SimCluster::new(small_cfg());
        // 10 one-core pods on 8 cores: 2 must wait for completions.
        let ids: Vec<_> = (0..10)
            .map(|i| c.submit(one_cpu_pod(&format!("p{i}"), Some(100_000_000))))
            .collect();
        c.run_to_quiescence();
        for id in &ids {
            assert_eq!(c.phase(*id), Some(&PodPhase::Succeeded));
        }
        // The last pods' start must be after the first completions.
        let first_finish = ids
            .iter()
            .filter_map(|&i| c.finished_at(i))
            .min()
            .unwrap();
        let last_start = ids.iter().filter_map(|&i| c.started_at(i)).max().unwrap();
        assert!(last_start >= first_finish, "queued pods waited for capacity");
    }

    #[test]
    fn service_pod_runs_until_terminated() {
        let mut c = SimCluster::new(small_cfg());
        let id = c.submit(one_cpu_pod("svc", None));
        c.run_until(1_000_000_000);
        assert!(matches!(c.phase(id), Some(PodPhase::Running { .. })));
        let (used, _) = c.cpu_utilization();
        assert_eq!(used, 1000);
        c.terminate(id);
        assert_eq!(c.phase(id), Some(&PodPhase::Terminated));
        assert_eq!(c.cpu_utilization().0, 0);
    }

    #[test]
    fn terminating_frees_capacity_for_pending() {
        let mut cfg = small_cfg();
        cfg.nodes = vec![NodeSpec::cpu_only(1, 1000)]; // 1 core
        let mut c = SimCluster::new(cfg);
        let a = c.submit(one_cpu_pod("a", None));
        let b = c.submit(one_cpu_pod("b", None));
        c.run_until(10_000_000_000);
        // Scheduling latency is random, so either pod may have won the only
        // core; exactly one must be Running and the other Pending.
        let (winner, loser) = match (c.phase(a), c.phase(b)) {
            (Some(PodPhase::Running { .. }), Some(PodPhase::Pending)) => (a, b),
            (Some(PodPhase::Pending), Some(PodPhase::Running { .. })) => (b, a),
            other => panic!("expected one running + one pending, got {other:?}"),
        };
        c.terminate(winner);
        c.run_until(20_000_000_000);
        assert!(matches!(c.phase(loser), Some(PodPhase::Running { .. })));
    }

    #[test]
    fn gpu_pods_only_fit_gpu_nodes() {
        let mut cfg = small_cfg();
        cfg.nodes = vec![NodeSpec::cpu_only(4, 8000), NodeSpec::with_gpu(4, 8000, 1)];
        let mut c = SimCluster::new(cfg);
        let spec = PodSpec {
            name: "gpu".into(),
            resources: Resources {
                cpu_milli: 1000,
                mem_mb: 100,
                gpu: 1,
            },
            duration_ns: None,
        };
        let id = c.submit(spec);
        c.run_until(10_000_000_000);
        match c.phase(id) {
            Some(PodPhase::Running { node }) => assert_eq!(*node, 1),
            other => panic!("expected running on gpu node, got {other:?}"),
        }
    }

    #[test]
    fn failure_injection_fails_long_pods() {
        let mut cfg = small_cfg();
        cfg.failure_rate_per_s = 2.0; // mean 0.5 s to failure
        let mut c = SimCluster::new(cfg);
        // 60-second pods will almost surely fail first.
        let ids: Vec<_> = (0..6)
            .map(|i| c.submit(one_cpu_pod(&format!("p{i}"), Some(60_000_000_000))))
            .collect();
        c.run_to_quiescence();
        let failed = ids
            .iter()
            .filter(|&&i| matches!(c.phase(i), Some(PodPhase::Failed(_))))
            .count();
        assert!(failed >= 5, "expected most pods to fail, got {failed}");
        assert_eq!(c.cpu_utilization().0, 0, "failures free resources");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut c = SimCluster::new(small_cfg());
            let ids: Vec<_> = (0..5)
                .map(|i| c.submit(one_cpu_pod(&format!("p{i}"), Some(50_000_000))))
                .collect();
            c.run_to_quiescence();
            ids.iter().map(|&i| c.finished_at(i).unwrap()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
