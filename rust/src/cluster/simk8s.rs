//! A simulated Kubernetes-style cluster manager in virtual time.
//!
//! This is the documented substitution for the paper's Kubernetes/Peloton
//! testbed (DESIGN.md §2): nodes with CPU/mem/GPU capacity, a FIFO first-fit
//! pod scheduler with a configurable scheduling latency (the paper's k8s
//! clusters take tens of ms to hundreds of ms to place a pod), pod start
//! latency (container boot), and exponential failure injection. The
//! dynamic-scaling experiment (E5) and the virtual-time scaling runs (E2)
//! measure pod placement, utilization and recovery against this model.
//!
//! Pods here don't execute code — they occupy resources for a requested
//! virtual duration (or indefinitely for service pods until terminated).
//! The *protocol* simulations (task dispatch etc.) are layered on the same
//! event engine in `baselines::sim_models`.

use std::collections::{HashMap, VecDeque};

use crate::cluster::des::{EventQueue, SimTime};
use crate::cluster::Resources;
use crate::util::Rng;

/// Capacity of one simulated node.
#[derive(Clone, Copy, Debug)]
pub struct NodeSpec {
    pub cpu_milli: u32,
    pub mem_mb: u32,
    pub gpu: u32,
}

impl NodeSpec {
    pub fn cpu_only(cores: u32, mem_mb: u32) -> Self {
        Self {
            cpu_milli: cores * 1000,
            mem_mb,
            gpu: 0,
        }
    }

    pub fn with_gpu(cores: u32, mem_mb: u32, gpu: u32) -> Self {
        Self {
            cpu_milli: cores * 1000,
            mem_mb,
            gpu,
        }
    }
}

/// Cluster-wide simulation parameters.
#[derive(Clone, Debug)]
pub struct SimClusterConfig {
    pub nodes: Vec<NodeSpec>,
    /// Mean scheduler decision latency per pod (exponential), ns.
    pub schedule_latency_ns: u64,
    /// Mean container start latency (exponential), ns.
    pub start_latency_ns: u64,
    /// Pod failure rate per virtual second (0 disables failure injection).
    pub failure_rate_per_s: f64,
    pub seed: u64,
}

impl Default for SimClusterConfig {
    fn default() -> Self {
        Self {
            // 32 nodes × 32 cores = 1024 cores, the paper's ES scale.
            nodes: vec![NodeSpec::cpu_only(32, 128_000); 32],
            schedule_latency_ns: 50_000_000, // 50 ms
            start_latency_ns: 800_000_000,   // 0.8 s container boot
            failure_rate_per_s: 0.0,
            seed: 0,
        }
    }
}

/// Pod identifier.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct PodId(pub u64);

/// A pod request.
#[derive(Clone, Debug)]
pub struct PodSpec {
    pub name: String,
    pub resources: Resources,
    /// Run duration in virtual ns; `None` = service pod (runs until
    /// terminated).
    pub duration_ns: Option<u64>,
}

/// Pod lifecycle state.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum PodPhase {
    Pending,
    Scheduled { node: usize },
    Running { node: usize },
    Succeeded,
    Failed(String),
    Terminated,
}

impl PodPhase {
    pub fn is_terminal(&self) -> bool {
        matches!(
            self,
            PodPhase::Succeeded | PodPhase::Failed(_) | PodPhase::Terminated
        )
    }
}

#[derive(Debug)]
enum Ev {
    /// Scheduler decision ready for this pod.
    Schedule(PodId),
    /// Container finished booting.
    Started(PodId),
    /// Work completed.
    Completed(PodId),
    /// Injected failure.
    Fail(PodId),
}

struct Pod {
    spec: PodSpec,
    phase: PodPhase,
    /// Generation counter: stale events (e.g. a Completed for a pod that
    /// already failed) are ignored by comparing generations.
    gen: u64,
    started_at: Option<SimTime>,
    finished_at: Option<SimTime>,
}

struct Node {
    spec: NodeSpec,
    used: Resources,
}

impl Node {
    fn fits(&self, r: &Resources) -> bool {
        self.used.cpu_milli + r.cpu_milli <= self.spec.cpu_milli
            && self.used.mem_mb + r.mem_mb <= self.spec.mem_mb
            && self.used.gpu + r.gpu <= self.spec.gpu
    }

    fn alloc(&mut self, r: &Resources) {
        self.used.cpu_milli += r.cpu_milli;
        self.used.mem_mb += r.mem_mb;
        self.used.gpu += r.gpu;
    }

    fn free(&mut self, r: &Resources) {
        self.used.cpu_milli -= r.cpu_milli;
        self.used.mem_mb -= r.mem_mb;
        self.used.gpu -= r.gpu;
    }
}

/// One (time, pod, phase) transition, for assertions and utilization plots.
#[derive(Clone, Debug)]
pub struct PodEvent {
    pub at: SimTime,
    pub pod: PodId,
    pub phase: PodPhase,
}

/// The simulated cluster.
pub struct SimCluster {
    cfg: SimClusterConfig,
    nodes: Vec<Node>,
    pods: HashMap<PodId, Pod>,
    queue: EventQueue<(u64, Ev)>, // (generation, event)
    pending: VecDeque<PodId>,
    rng: Rng,
    next_pod: u64,
    pub log: Vec<PodEvent>,
}

impl SimCluster {
    pub fn new(cfg: SimClusterConfig) -> Self {
        let nodes = cfg
            .nodes
            .iter()
            .map(|&spec| Node {
                spec,
                used: Resources {
                    cpu_milli: 0,
                    mem_mb: 0,
                    gpu: 0,
                },
            })
            .collect();
        let rng = Rng::new(cfg.seed ^ 0x5153_u64);
        Self {
            cfg,
            nodes,
            pods: HashMap::new(),
            queue: EventQueue::new(),
            pending: VecDeque::new(),
            rng,
            next_pod: 1,
            log: Vec::new(),
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.queue.now()
    }

    /// Submit a pod; scheduling begins after the scheduler latency.
    pub fn submit(&mut self, spec: PodSpec) -> PodId {
        let id = PodId(self.next_pod);
        self.next_pod += 1;
        self.pods.insert(
            id,
            Pod {
                spec,
                phase: PodPhase::Pending,
                gen: 0,
                started_at: None,
                finished_at: None,
            },
        );
        self.push_log(id, PodPhase::Pending);
        let lat = self.rng.exponential(self.cfg.schedule_latency_ns as f64) as u64;
        self.queue.push_after(lat, (0, Ev::Schedule(id)));
        id
    }

    /// Terminate a pod (frees resources immediately at the current time).
    pub fn terminate(&mut self, id: PodId) {
        let Some(pod) = self.pods.get_mut(&id) else { return };
        if pod.phase.is_terminal() {
            return;
        }
        if let PodPhase::Running { node } | PodPhase::Scheduled { node } = pod.phase {
            let res = pod.spec.resources;
            self.nodes[node].free(&res);
        }
        pod.gen += 1;
        pod.phase = PodPhase::Terminated;
        pod.finished_at = Some(self.queue.now());
        self.push_log(id, PodPhase::Terminated);
        self.pending.retain(|&p| p != id);
        self.try_schedule_pending();
    }

    pub fn phase(&self, id: PodId) -> Option<&PodPhase> {
        self.pods.get(&id).map(|p| &p.phase)
    }

    pub fn started_at(&self, id: PodId) -> Option<SimTime> {
        self.pods.get(&id).and_then(|p| p.started_at)
    }

    pub fn finished_at(&self, id: PodId) -> Option<SimTime> {
        self.pods.get(&id).and_then(|p| p.finished_at)
    }

    /// (used cpu_milli, total cpu_milli) across the cluster.
    pub fn cpu_utilization(&self) -> (u64, u64) {
        let used = self.nodes.iter().map(|n| n.used.cpu_milli as u64).sum();
        let total = self.nodes.iter().map(|n| n.spec.cpu_milli as u64).sum();
        (used, total)
    }

    /// Number of pods not yet in a terminal phase.
    pub fn live_pods(&self) -> usize {
        self.pods.values().filter(|p| !p.phase.is_terminal()).count()
    }

    /// Drain events up to `t`, then advance the idle clock to `t` (clamped
    /// to any still-pending event). The trace replay driver uses this to
    /// keep collective-phase arithmetic and pod lifecycle on one timeline.
    pub fn advance_to(&mut self, t: SimTime) -> SimTime {
        self.run_until(t);
        self.queue.advance_to(t)
    }

    /// Process events until the queue is empty or `until` is reached.
    /// Returns the final virtual time.
    pub fn run_until(&mut self, until: SimTime) -> SimTime {
        while let Some(t) = self.queue.peek_time() {
            if t > until {
                break;
            }
            let (_, (gen, ev)) = self.queue.pop().unwrap();
            self.handle(gen, ev);
        }
        self.queue.now().min(until)
    }

    /// Process all events to quiescence.
    pub fn run_to_quiescence(&mut self) -> SimTime {
        while let Some((_, (gen, ev))) = self.queue.pop() {
            self.handle(gen, ev);
        }
        self.queue.now()
    }

    fn handle(&mut self, gen: u64, ev: Ev) {
        match ev {
            Ev::Schedule(id) => {
                if self.stale(id, gen) {
                    return;
                }
                if !self.try_place(id) {
                    self.pending.push_back(id);
                }
            }
            Ev::Started(id) => {
                if self.stale(id, gen) {
                    return;
                }
                let now = self.queue.now();
                let pod = self.pods.get_mut(&id).unwrap();
                let PodPhase::Scheduled { node } = pod.phase else { return };
                pod.phase = PodPhase::Running { node };
                pod.started_at = Some(now);
                self.push_log(id, PodPhase::Running { node });
                let (duration, gen_now) = {
                    let pod = self.pods.get(&id).unwrap();
                    (pod.spec.duration_ns, pod.gen)
                };
                if let Some(d) = duration {
                    self.queue.push_after(d, (gen_now, Ev::Completed(id)));
                }
                if self.cfg.failure_rate_per_s > 0.0 {
                    let mean_ns = 1e9 / self.cfg.failure_rate_per_s;
                    let t = self.rng.exponential(mean_ns) as u64;
                    // Only fails if it fires before completion/termination
                    // (stale-generation check handles the race).
                    self.queue.push_after(t, (gen_now, Ev::Fail(id)));
                }
            }
            Ev::Completed(id) => {
                if self.stale(id, gen) {
                    return;
                }
                self.finish(id, PodPhase::Succeeded);
            }
            Ev::Fail(id) => {
                if self.stale(id, gen) {
                    return;
                }
                self.finish(id, PodPhase::Failed("injected node failure".into()));
            }
        }
    }

    fn stale(&self, id: PodId, gen: u64) -> bool {
        self.pods.get(&id).map_or(true, |p| p.gen != gen || p.phase.is_terminal())
    }

    fn finish(&mut self, id: PodId, phase: PodPhase) {
        let now = self.queue.now();
        let pod = self.pods.get_mut(&id).unwrap();
        if let PodPhase::Running { node } | PodPhase::Scheduled { node } = pod.phase {
            let res = pod.spec.resources;
            self.nodes[node].free(&res);
        }
        pod.gen += 1;
        pod.phase = phase.clone();
        pod.finished_at = Some(now);
        self.push_log(id, phase);
        self.try_schedule_pending();
    }

    /// First-fit placement. Returns false if no node has capacity.
    fn try_place(&mut self, id: PodId) -> bool {
        let res = self.pods[&id].spec.resources;
        let Some(node_idx) = self.nodes.iter().position(|n| n.fits(&res)) else {
            return false;
        };
        self.nodes[node_idx].alloc(&res);
        let pod = self.pods.get_mut(&id).unwrap();
        pod.phase = PodPhase::Scheduled { node: node_idx };
        let gen = pod.gen;
        self.push_log(id, PodPhase::Scheduled { node: node_idx });
        let boot = self.rng.exponential(self.cfg.start_latency_ns as f64) as u64;
        self.queue.push_after(boot, (gen, Ev::Started(id)));
        true
    }

    fn try_schedule_pending(&mut self) {
        let mut still_pending = VecDeque::new();
        while let Some(id) = self.pending.pop_front() {
            if self.pods[&id].phase.is_terminal() {
                continue;
            }
            if !self.try_place(id) {
                still_pending.push_back(id);
            }
        }
        self.pending = still_pending;
    }

    fn push_log(&mut self, pod: PodId, phase: PodPhase) {
        self.log.push(PodEvent {
            at: self.queue.now(),
            pod,
            phase,
        });
    }
}

// ---------------------------------------------------------------------------
// Scenario replay: re-drive a recorded chaos schedule against virtual pods.
// ---------------------------------------------------------------------------

use anyhow::Result;

use crate::trace::replay::{Calibration, ChaosEvent, ChaosKind, Scenario};
use crate::trace::TraceEvent;

/// The checkpoint ObjId every replayed run shares (`store.put` once on the
/// leader, one cold `store.fetch` per node, `store.hit` afterwards).
const CKPT_OBJ: i64 = 1;

/// Counters summarizing one replay run.
#[derive(Clone, Debug, Default)]
pub struct ReplayStats {
    /// Members alive when the run ended (adopted spares and grows included).
    pub members_final: usize,
    /// Every pod ever submitted (members, spares, respawns, grows).
    pub pods: usize,
    pub kills: usize,
    /// `ring.heal` spans emitted across all survivors and chaos batches.
    pub heals: usize,
    /// Grow joins + partition rejoins.
    pub grows: usize,
    pub events: usize,
    /// Final virtual time of the run.
    pub final_ns: u64,
}

/// What a replay produces: the synthesized per-node event stream (unsorted;
/// [`crate::trace::replay::replay`] time-sorts it into a `TraceDump`) and
/// the run counters.
pub struct ReplayOutcome {
    pub events: Vec<(String, TraceEvent)>,
    pub stats: ReplayStats,
}

/// One simulated ring member / spare, pinned to a service pod.
struct SimNode {
    name: String,
    pod: PodId,
    /// Has this node cold-fetched the checkpoint? Later accesses must be
    /// `store.hit`s or the emitted trace violates `store.fetch-once`.
    fetched: bool,
    /// One-iteration compute slowdown: `(iter, factor)`.
    straggle: Option<(usize, f64)>,
    /// Partitioned through (exclusive) this iteration; 0 = connected.
    partitioned_until: usize,
    /// Earliest virtual time this node can start a task (pod boot / adopt).
    ready_at: SimTime,
    /// Killed nodes stay in the member list (stable indices) but inert.
    dead: bool,
}

/// A `pool.run` emitted during the current iteration.
struct RunRec {
    mi: usize,
    task_idx: usize,
    span: u64,
    start: SimTime,
    dur: u64,
}

impl RunRec {
    fn end(&self) -> SimTime {
        self.start + self.dur
    }
}

/// Re-drives a [`Scenario`] against [`SimCluster`] pods on the shared
/// virtual clock, synthesizing the causally-linked trace the equivalent
/// real run would have recorded. The driver's contract — enforced by the
/// `trace::replay` tests and the CI replay smoke — is that its output
/// passes every invariant in [`crate::trace::check`]:
///
/// * a killed member's in-flight spans die with its journal (nothing may
///   dangle on them), survivors heal and `ring.resume` under their heal
///   span, a spare `ring.adopt`s naming the interrupted `op_seq`, the
///   leader `pool.restart`s the victim's task, and the rerun reuses the
///   dispatch envelope and task index;
/// * every node cold-fetches the checkpoint exactly once — partition
///   rejoiners `store.hit`, they do not re-fetch;
/// * the single held `store.put` is `store.release`d at the end, keeping
///   refcounts balanced.
pub struct ReplayDriver {
    sc: Scenario,
    cal: Calibration,
    cluster: SimCluster,
    rng: Rng,
    members: Vec<SimNode>,
    spares: Vec<SimNode>,
    events: Vec<(String, TraceEvent)>,
    stats: ReplayStats,
    next_span: u64,
    next_node: usize,
    gen: i64,
}

impl ReplayDriver {
    pub fn new(sc: Scenario, cal: Calibration) -> ReplayDriver {
        let kills = sc
            .events
            .iter()
            .filter(|e| matches!(e.kind, ChaosKind::Kill { .. }))
            .count();
        let grows: usize = sc
            .events
            .iter()
            .map(|e| match e.kind {
                ChaosKind::Grow { count } => count,
                _ => 0,
            })
            .sum();
        // Two 1-core service pods per simulated 2-core host; capacity for
        // every pod the schedule can ever create, so nothing queues.
        let capacity = sc.nodes + sc.spares + kills + grows;
        let cfg = SimClusterConfig {
            nodes: vec![NodeSpec::cpu_only(2, 4000); capacity.div_ceil(2)],
            schedule_latency_ns: 2_000_000,
            start_latency_ns: 50_000_000,
            failure_rate_per_s: 0.0,
            seed: sc.seed,
        };
        let rng = Rng::new(sc.seed ^ 0x5250_4c59);
        ReplayDriver {
            sc,
            cal,
            cluster: SimCluster::new(cfg),
            rng,
            members: Vec::new(),
            spares: Vec::new(),
            events: Vec::new(),
            stats: ReplayStats::default(),
            next_span: 0,
            next_node: 0,
            gen: 0,
        }
    }

    fn span_id(&mut self) -> u64 {
        self.next_span += 1;
        self.next_span
    }

    fn jitter(&mut self, mean: u64) -> u64 {
        self.rng.exponential(mean.max(1) as f64) as u64
    }

    fn emit(
        &mut self,
        node: &str,
        ts: SimTime,
        dur: u64,
        span: u64,
        parent: u64,
        name: &str,
        args: &[(&str, i64)],
    ) {
        self.events.push((
            node.to_string(),
            TraceEvent {
                ts_ns: ts,
                dur_ns: dur,
                span,
                parent,
                tid: 1,
                name: name.to_string(),
                args: args.iter().map(|(k, v)| (k.to_string(), *v)).collect(),
            },
        ));
    }

    /// Submit a fresh 1-core service pod; `ready_at` is filled in by the
    /// caller once the cluster has processed its boot.
    fn spawn_node(&mut self) -> SimNode {
        let name = format!("sim-{}", self.next_node);
        self.next_node += 1;
        self.stats.pods += 1;
        let pod = self.cluster.submit(PodSpec {
            name: name.clone(),
            resources: Resources {
                cpu_milli: 1000,
                mem_mb: 100,
                gpu: 0,
            },
            duration_ns: None,
        });
        SimNode {
            name,
            pod,
            fetched: false,
            straggle: None,
            partitioned_until: 0,
            ready_at: 0,
            dead: false,
        }
    }

    /// Resolve a scenario rank to a member index: alive, never the leader,
    /// and (when `need_active`) not partitioned. `None` when no member
    /// qualifies — that chaos event is skipped rather than misfiring.
    fn resolve_rank(&self, rank: usize, iter: usize, need_active: bool) -> Option<usize> {
        let candidates: Vec<usize> = (1..self.members.len())
            .filter(|&i| {
                let m = &self.members[i];
                !m.dead && (!need_active || m.partitioned_until <= iter)
            })
            .collect();
        if candidates.is_empty() {
            return None;
        }
        Some(candidates[rank % candidates.len()])
    }

    pub fn run(mut self) -> Result<ReplayOutcome> {
        // Boot the initial fleet: members + warm spares.
        for _ in 0..self.sc.nodes {
            let n = self.spawn_node();
            self.members.push(n);
        }
        for _ in 0..self.sc.spares {
            let n = self.spawn_node();
            self.spares.push(n);
        }
        self.cluster.run_to_quiescence();
        for list in [&mut self.members, &mut self.spares] {
            for n in list.iter_mut() {
                n.ready_at = self.cluster.started_at(n.pod).unwrap_or(0);
            }
        }
        let t0 = self.cluster.now();

        // The leader seeds the shared checkpoint: one held put; everyone
        // else cold-fetches it inside their first task.
        let leader = self.members[0].name.clone();
        let put = self.span_id();
        let elems = self.sc.elems as i64;
        self.emit(
            &leader,
            t0,
            self.cal.put_ns.max(1),
            put,
            0,
            "store.put",
            &[("obj", CKPT_OBJ), ("held", 1), ("len", elems * 8)],
        );
        self.members[0].fetched = true; // the put leaves the blob local

        let mut t = t0 + self.cal.put_ns + 10_000;
        for iter in 0..self.sc.iters {
            t = self.run_iter(iter, t)?;
        }

        // End of run: drop the held checkpoint reference.
        let rel = self.span_id();
        self.emit(&leader, t, 0, rel, 0, "store.release", &[("obj", CKPT_OBJ)]);
        self.cluster.advance_to(t);

        self.stats.members_final = self.members.iter().filter(|m| !m.dead).count();
        self.stats.events = self.events.len();
        self.stats.final_ns = self.cluster.now();
        Ok(ReplayOutcome {
            events: self.events,
            stats: self.stats,
        })
    }

    fn run_iter(&mut self, iter: usize, t0: SimTime) -> Result<SimTime> {
        let leader = self.members[0].name.clone();
        let scheduled: Vec<ChaosEvent> = self
            .sc
            .events
            .iter()
            .filter(|e| e.at_iter == iter)
            .cloned()
            .collect();

        // -- iteration-start chaos: stragglers, partitions, grows --------
        let mut partition_started = false;
        for ev in &scheduled {
            match ev.kind {
                ChaosKind::Straggle { rank, factor } => {
                    if let Some(mi) = self.resolve_rank(rank, iter, true) {
                        self.members[mi].straggle = Some((iter, factor));
                    }
                }
                ChaosKind::Partition { rank, iters } => {
                    if let Some(mi) = self.resolve_rank(rank, iter, true) {
                        self.members[mi].partitioned_until = iter + iters;
                        partition_started = true;
                    }
                }
                ChaosKind::Grow { count } => {
                    self.cluster.advance_to(t0);
                    let mut joined = Vec::with_capacity(count);
                    for _ in 0..count {
                        joined.push(self.spawn_node());
                    }
                    self.cluster.run_to_quiescence();
                    self.gen += 1;
                    for mut n in joined {
                        let join_ts =
                            self.cluster.started_at(n.pod).unwrap_or(t0).max(t0);
                        n.ready_at = join_ts;
                        n.fetched = false;
                        let s = self.span_id();
                        let rank = self.members.len() as i64;
                        let gen = self.gen;
                        self.emit(
                            &n.name,
                            join_ts,
                            0,
                            s,
                            0,
                            "ring.grow",
                            &[("gen", gen), ("rank", rank)],
                        );
                        self.members.push(n);
                        self.stats.grows += 1;
                    }
                }
                ChaosKind::Kill { .. } => {} // lands mid-compute, below
            }
        }
        // Partition rejoins re-enter through the regrow path. (At iter 0
        // `partitioned_until == 0` means "never partitioned", hence the
        // `iter > 0` guard.)
        let rejoiners: Vec<String> = self
            .members
            .iter()
            .filter(|m| !m.dead && iter > 0 && m.partitioned_until == iter)
            .map(|m| m.name.clone())
            .collect();
        if !rejoiners.is_empty() {
            self.gen += 1;
            for name in rejoiners {
                let s = self.span_id();
                let gen = self.gen;
                self.emit(&name, t0, 0, s, 0, "ring.grow", &[("gen", gen), ("rejoin", 1)]);
                self.stats.grows += 1;
            }
        }

        // -- dispatch: one slice of work, one task per connected member --
        let active: Vec<usize> = (0..self.members.len())
            .filter(|&i| !self.members[i].dead && self.members[i].partitioned_until <= iter)
            .collect();
        let slice_span = self.span_id();
        let dispatch_span = self.span_id();
        let d_ts = t0 + 10_000;
        let d_dur = self.cal.dispatch_ns.max(1);
        self.emit(
            &leader,
            d_ts,
            d_dur,
            dispatch_span,
            slice_span,
            "pool.dispatch",
            &[("map_id", iter as i64), ("tasks", active.len() as i64)],
        );
        let d_end = d_ts + d_dur;

        let mut runs: Vec<RunRec> = Vec::with_capacity(active.len());
        for (task_idx, &mi) in active.iter().enumerate() {
            let rec = self.emit_run(mi, task_idx, iter, d_end, dispatch_span);
            runs.push(rec);
        }

        // -- mid-compute kills: journal loss, heal, adopt, requeue -------
        for ev in &scheduled {
            let ChaosKind::Kill { rank } = ev.kind else { continue };
            let Some(vi) = self.resolve_rank(rank, iter, true) else { continue };
            let Some(pos) = runs.iter().position(|r| r.mi == vi) else { continue };
            let victim_run = runs.remove(pos);
            let t_kill = victim_run.start + victim_run.dur * 2 / 5;
            // The victim's journal dies with it: every span it recorded
            // this iteration vanishes before any collector can drain it.
            let lost: Vec<u64> = self
                .events
                .iter()
                .filter(|(n, e)| *n == self.members[vi].name && e.ts_ns >= t0)
                .map(|(_, e)| e.span)
                .collect();
            self.events.retain(|(_, e)| !lost.contains(&e.span));
            self.members[vi].dead = true;
            self.stats.kills += 1;

            // Pod teardown + elastic respawn of the spare pool.
            self.cluster.advance_to(t_kill);
            self.cluster.terminate(self.members[vi].pod);
            let mut respawn = self.spawn_node();
            self.cluster.run_to_quiescence();
            respawn.ready_at = self.cluster.started_at(respawn.pod).unwrap_or(t_kill);
            self.spares.push(respawn);

            // Every survivor heals and resumes under its own heal span.
            let from_gen = self.gen;
            self.gen += 1;
            let mut heal_end_max = t_kill;
            let survivor_names: Vec<String> =
                runs.iter().map(|r| self.members[r.mi].name.clone()).collect();
            let completed = survivor_names.len() as i64;
            for name in survivor_names {
                let h = self.span_id();
                let h_ts = t_kill + 500_000 + self.jitter(100_000);
                let h_dur = self.cal.heal_ns.max(1) + self.jitter(self.cal.heal_ns / 10);
                let gen = self.gen;
                self.emit(
                    &name,
                    h_ts,
                    h_dur,
                    h,
                    0,
                    "ring.heal",
                    &[("from_gen", from_gen), ("op_seq", iter as i64), ("completed", completed)],
                );
                let r = self.span_id();
                self.emit(
                    &name,
                    h_ts + h_dur,
                    0,
                    r,
                    h,
                    "ring.resume",
                    &[("op_seq", iter as i64), ("chunk", 0), ("gen", gen)],
                );
                heal_end_max = heal_end_max.max(h_ts + h_dur);
                self.stats.heals += 1;
            }

            // A warm spare adopts the vacant slot and reruns the task.
            if !self.spares.is_empty() {
                let mut sp = self.spares.remove(0);
                let adopt_ts = heal_end_max.max(sp.ready_at) + 200_000;
                let a = self.span_id();
                let gen = self.gen;
                let sp_name = sp.name.clone();
                self.emit(
                    &sp_name,
                    adopt_ts,
                    0,
                    a,
                    0,
                    "ring.adopt",
                    &[("op_seq", iter as i64), ("kind", 1), ("resume_chunk", 0), ("gen", gen)],
                );
                sp.ready_at = adopt_ts;
                sp.fetched = false;
                let new_mi = self.members.len();
                self.members.push(sp);
                let rs = self.span_id();
                let victim_rank = vi as i64;
                self.emit(
                    &leader,
                    t_kill + self.cal.rpc_ns,
                    0,
                    rs,
                    0,
                    "pool.restart",
                    &[("worker", victim_rank), ("requeued", 1)],
                );
                let rerun =
                    self.emit_run(new_mi, victim_run.task_idx, iter, adopt_ts, dispatch_span);
                runs.push(rerun);
            } else {
                // No spare left: the ring shrinks and the leader reruns
                // the orphaned task itself after its own slice.
                let rs = self.span_id();
                let victim_rank = vi as i64;
                self.emit(
                    &leader,
                    t_kill + self.cal.rpc_ns,
                    0,
                    rs,
                    0,
                    "pool.restart",
                    &[("worker", victim_rank), ("requeued", 1)],
                );
                let after = runs.iter().find(|r| r.mi == 0).map_or(heal_end_max, RunRec::end);
                let rerun = self.emit_run(
                    0,
                    victim_run.task_idx,
                    iter,
                    after.max(heal_end_max),
                    dispatch_span,
                );
                runs.push(rerun);
            }
        }

        // -- collective: a barrier allreduce on every member's run tail --
        // A partition starting this iteration is detected when the op
        // starts: every participant heals (shrink, no adopt) first.
        let mut entries: Vec<(usize, SimTime, u64)> = Vec::with_capacity(runs.len());
        if partition_started {
            let from_gen = self.gen;
            self.gen += 1;
            for r in &runs {
                let h = self.span_id();
                let h_ts = r.end() + 5_000;
                let h_dur = self.cal.heal_ns.max(1) + self.jitter(self.cal.heal_ns / 10);
                let name = self.members[r.mi].name.clone();
                let completed = runs.len() as i64;
                let gen = self.gen;
                self.emit(
                    &name,
                    h_ts,
                    h_dur,
                    h,
                    0,
                    "ring.heal",
                    &[("from_gen", from_gen), ("op_seq", iter as i64), ("completed", completed)],
                );
                let rr = self.span_id();
                self.emit(
                    &name,
                    h_ts + h_dur,
                    0,
                    rr,
                    h,
                    "ring.resume",
                    &[("op_seq", iter as i64), ("chunk", 0), ("gen", gen)],
                );
                entries.push((r.mi, h_ts + h_dur + 5_000, r.span));
                self.stats.heals += 1;
            }
        } else {
            for r in &runs {
                entries.push((r.mi, r.end() + 5_000, r.span));
            }
        }
        let coll_start_max = entries.iter().map(|&(_, ts, _)| ts).max().unwrap_or(t0);
        let coll_end = coll_start_max + self.cal.allreduce_ns.max(1);
        for (mi, ts, run_span) in entries {
            let a = self.span_id();
            let name = self.members[mi].name.clone();
            let gen = self.gen;
            self.emit(
                &name,
                ts,
                coll_end - ts,
                a,
                run_span,
                "ring.allreduce",
                &[("elems", self.sc.elems as i64), ("op_seq", iter as i64), ("gen", gen)],
            );
        }

        // -- close the slice over the whole iteration --------------------
        let t_end = coll_end + 10_000;
        self.emit(
            &leader,
            t0,
            t_end - t0,
            slice_span,
            0,
            "pop.slice",
            &[("trial", 0), ("slice", iter as i64), ("ckpt", CKPT_OBJ)],
        );
        self.cluster.advance_to(t_end);
        Ok(t_end + 10_000)
    }

    /// Emit one `pool.run` under the dispatch envelope, with the member's
    /// checkpoint access inside it: a cold `store.fetch` on first touch, a
    /// `store.hit` afterwards.
    fn emit_run(
        &mut self,
        mi: usize,
        task_idx: usize,
        iter: usize,
        earliest: SimTime,
        dispatch_span: u64,
    ) -> RunRec {
        let span = self.span_id();
        let m = &self.members[mi];
        let name = m.name.clone();
        let ready_at = m.ready_at;
        let factor = match m.straggle {
            Some((it, f)) if it == iter => f,
            _ => 1.0,
        };
        let cold = !m.fetched;
        let start = earliest.max(ready_at) + self.jitter(self.cal.rpc_ns);
        let mut dur =
            (self.cal.pool_run_ns as f64 * factor) as u64 + self.jitter(self.cal.pool_run_ns / 10);
        if cold {
            dur += self.cal.fetch_ns;
        }
        dur = dur.max(1);
        self.emit(
            &name,
            start,
            dur,
            span,
            dispatch_span,
            "pool.run",
            &[("worker", mi as i64), ("index", task_idx as i64)],
        );
        let s = self.span_id();
        if cold {
            self.emit(
                &name,
                start + 1_000,
                self.cal.fetch_ns.max(1),
                s,
                span,
                "store.fetch",
                &[("obj", CKPT_OBJ)],
            );
            self.members[mi].fetched = true;
        } else {
            self.emit(&name, start + 1_000, 0, s, span, "store.hit", &[("obj", CKPT_OBJ)]);
        }
        RunRec {
            mi,
            task_idx,
            span,
            start,
            dur,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_cfg() -> SimClusterConfig {
        SimClusterConfig {
            nodes: vec![NodeSpec::cpu_only(4, 8000); 2], // 8 cores total
            schedule_latency_ns: 1_000_000,
            start_latency_ns: 5_000_000,
            failure_rate_per_s: 0.0,
            seed: 1,
        }
    }

    fn one_cpu_pod(name: &str, dur: Option<u64>) -> PodSpec {
        PodSpec {
            name: name.into(),
            resources: Resources {
                cpu_milli: 1000,
                mem_mb: 100,
                gpu: 0,
            },
            duration_ns: dur,
        }
    }

    #[test]
    fn pod_runs_to_completion() {
        let mut c = SimCluster::new(small_cfg());
        let id = c.submit(one_cpu_pod("p", Some(1_000_000_000)));
        c.run_to_quiescence();
        assert_eq!(c.phase(id), Some(&PodPhase::Succeeded));
        let (used, _) = c.cpu_utilization();
        assert_eq!(used, 0, "resources freed");
        assert!(c.finished_at(id).unwrap() >= 1_000_000_000);
    }

    #[test]
    fn capacity_limits_queue_pods() {
        let mut c = SimCluster::new(small_cfg());
        // 10 one-core pods on 8 cores: 2 must wait for completions.
        let ids: Vec<_> = (0..10)
            .map(|i| c.submit(one_cpu_pod(&format!("p{i}"), Some(100_000_000))))
            .collect();
        c.run_to_quiescence();
        for id in &ids {
            assert_eq!(c.phase(*id), Some(&PodPhase::Succeeded));
        }
        // The last pods' start must be after the first completions.
        let first_finish = ids
            .iter()
            .filter_map(|&i| c.finished_at(i))
            .min()
            .unwrap();
        let last_start = ids.iter().filter_map(|&i| c.started_at(i)).max().unwrap();
        assert!(last_start >= first_finish, "queued pods waited for capacity");
    }

    #[test]
    fn service_pod_runs_until_terminated() {
        let mut c = SimCluster::new(small_cfg());
        let id = c.submit(one_cpu_pod("svc", None));
        c.run_until(1_000_000_000);
        assert!(matches!(c.phase(id), Some(PodPhase::Running { .. })));
        let (used, _) = c.cpu_utilization();
        assert_eq!(used, 1000);
        c.terminate(id);
        assert_eq!(c.phase(id), Some(&PodPhase::Terminated));
        assert_eq!(c.cpu_utilization().0, 0);
    }

    #[test]
    fn terminating_frees_capacity_for_pending() {
        let mut cfg = small_cfg();
        cfg.nodes = vec![NodeSpec::cpu_only(1, 1000)]; // 1 core
        let mut c = SimCluster::new(cfg);
        let a = c.submit(one_cpu_pod("a", None));
        let b = c.submit(one_cpu_pod("b", None));
        c.run_until(10_000_000_000);
        // Scheduling latency is random, so either pod may have won the only
        // core; exactly one must be Running and the other Pending.
        let (winner, loser) = match (c.phase(a), c.phase(b)) {
            (Some(PodPhase::Running { .. }), Some(PodPhase::Pending)) => (a, b),
            (Some(PodPhase::Pending), Some(PodPhase::Running { .. })) => (b, a),
            other => panic!("expected one running + one pending, got {other:?}"),
        };
        c.terminate(winner);
        c.run_until(20_000_000_000);
        assert!(matches!(c.phase(loser), Some(PodPhase::Running { .. })));
    }

    #[test]
    fn gpu_pods_only_fit_gpu_nodes() {
        let mut cfg = small_cfg();
        cfg.nodes = vec![NodeSpec::cpu_only(4, 8000), NodeSpec::with_gpu(4, 8000, 1)];
        let mut c = SimCluster::new(cfg);
        let spec = PodSpec {
            name: "gpu".into(),
            resources: Resources {
                cpu_milli: 1000,
                mem_mb: 100,
                gpu: 1,
            },
            duration_ns: None,
        };
        let id = c.submit(spec);
        c.run_until(10_000_000_000);
        match c.phase(id) {
            Some(PodPhase::Running { node }) => assert_eq!(*node, 1),
            other => panic!("expected running on gpu node, got {other:?}"),
        }
    }

    #[test]
    fn failure_injection_fails_long_pods() {
        let mut cfg = small_cfg();
        cfg.failure_rate_per_s = 2.0; // mean 0.5 s to failure
        let mut c = SimCluster::new(cfg);
        // 60-second pods will almost surely fail first.
        let ids: Vec<_> = (0..6)
            .map(|i| c.submit(one_cpu_pod(&format!("p{i}"), Some(60_000_000_000))))
            .collect();
        c.run_to_quiescence();
        let failed = ids
            .iter()
            .filter(|&&i| matches!(c.phase(i), Some(PodPhase::Failed(_))))
            .count();
        assert!(failed >= 5, "expected most pods to fail, got {failed}");
        assert_eq!(c.cpu_utilization().0, 0, "failures free resources");
    }

    #[test]
    fn deterministic_given_seed() {
        let run = || {
            let mut c = SimCluster::new(small_cfg());
            let ids: Vec<_> = (0..5)
                .map(|i| c.submit(one_cpu_pod(&format!("p{i}"), Some(50_000_000))))
                .collect();
            c.run_to_quiescence();
            ids.iter().map(|&i| c.finished_at(i).unwrap()).collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
