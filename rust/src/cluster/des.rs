//! A minimal discrete-event simulation engine.
//!
//! Virtual time is `u64` nanoseconds. Events are user types ordered by
//! `(time, insertion-seq)` so simultaneous events fire in submission order
//! (deterministic replays). The engine underlies [`super::simk8s`] and the
//! calibrated framework models in [`crate::baselines::sim_models`], which is
//! how the 1024-worker experiments run on a 1-core testbed.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Virtual time in nanoseconds.
pub type SimTime = u64;

struct Entry<E> {
    at: SimTime,
    seq: u64,
    ev: E,
}

impl<E> PartialEq for Entry<E> {
    fn eq(&self, other: &Self) -> bool {
        self.at == other.at && self.seq == other.seq
    }
}
impl<E> Eq for Entry<E> {}
impl<E> PartialOrd for Entry<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for Entry<E> {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        (self.at, self.seq).cmp(&(other.at, other.seq))
    }
}

/// Time-ordered event queue with a monotone virtual clock.
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<Entry<E>>>,
    now: SimTime,
    seq: u64,
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        Self {
            heap: BinaryHeap::new(),
            now: 0,
            seq: 0,
        }
    }

    /// Current virtual time.
    pub fn now(&self) -> SimTime {
        self.now
    }

    /// Schedule `ev` at absolute time `at` (clamped to now if in the past).
    pub fn push_at(&mut self, at: SimTime, ev: E) {
        let at = at.max(self.now);
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Reverse(Entry { at, seq, ev }));
    }

    /// Schedule `ev` after a delay from now.
    pub fn push_after(&mut self, delay: SimTime, ev: E) {
        self.push_at(self.now.saturating_add(delay), ev);
    }

    /// Pop the next event, advancing the clock to its timestamp.
    pub fn pop(&mut self) -> Option<(SimTime, E)> {
        let Reverse(e) = self.heap.pop()?;
        self.now = e.at;
        Some((e.at, e.ev))
    }

    /// Timestamp of the next event without popping.
    pub fn peek_time(&self) -> Option<SimTime> {
        self.heap.peek().map(|Reverse(e)| e.at)
    }

    /// Advance the clock to `t` without processing events, clamped so it
    /// never moves past a pending event (drain those first — see
    /// [`super::simk8s::SimCluster::advance_to`]). A `t` in the past is a
    /// no-op; returns the resulting time. This is how an external
    /// time-driver (the trace replay harness) keeps one shared clock with
    /// the pod machinery instead of running a second timeline.
    pub fn advance_to(&mut self, t: SimTime) -> SimTime {
        let cap = self.peek_time().unwrap_or(SimTime::MAX);
        self.now = self.now.max(t.min(cap));
        self.now
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn events_pop_in_time_order() {
        let mut q = EventQueue::new();
        q.push_at(30, "c");
        q.push_at(10, "a");
        q.push_at(20, "b");
        assert_eq!(q.pop(), Some((10, "a")));
        assert_eq!(q.pop(), Some((20, "b")));
        assert_eq!(q.pop(), Some((30, "c")));
        assert_eq!(q.pop(), None);
        assert_eq!(q.now(), 30);
    }

    #[test]
    fn ties_break_by_insertion_order() {
        let mut q = EventQueue::new();
        q.push_at(5, 1);
        q.push_at(5, 2);
        q.push_at(5, 3);
        assert_eq!(q.pop().unwrap().1, 1);
        assert_eq!(q.pop().unwrap().1, 2);
        assert_eq!(q.pop().unwrap().1, 3);
    }

    #[test]
    fn past_events_clamp_to_now() {
        let mut q = EventQueue::new();
        q.push_at(100, ());
        q.pop();
        q.push_at(50, ()); // in the past → fires at now=100
        assert_eq!(q.pop(), Some((100, ())));
    }

    #[test]
    fn advance_to_moves_the_idle_clock_but_not_past_events() {
        let mut q: EventQueue<&str> = EventQueue::new();
        assert_eq!(q.advance_to(500), 500);
        q.push_at(600, "e");
        assert_eq!(q.advance_to(1000), 600, "clamped to the pending event");
        assert_eq!(q.pop(), Some((600, "e")));
        assert_eq!(q.advance_to(100), 600, "the past is a no-op");
    }

    #[test]
    fn push_after_is_relative() {
        let mut q = EventQueue::new();
        q.push_at(40, "x");
        q.pop();
        q.push_after(10, "y");
        assert_eq!(q.pop(), Some((50, "y")));
    }
}
