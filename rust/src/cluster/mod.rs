//! The backend + cluster layers: job creation, tracking and termination.
//!
//! Fiber's key architectural move is that **a process is a cluster job**:
//! starting a Fiber process submits a job to whatever cluster manager the
//! program runs on, and the job's lifecycle *is* the process lifecycle.
//! This module provides that abstraction and three backends:
//!
//! * [`LocalBackend`] — jobs are threads in the current process (the
//!   "prototype on a laptop" backend; analogous to multiprocessing).
//! * [`ProcBackend`] — jobs are real OS child processes running this same
//!   binary (`fiber-cli worker …`), the truthful realization of job-backed
//!   processes on one machine.
//! * [`simk8s::SimCluster`] — a simulated Kubernetes-style cluster manager
//!   (nodes, pods, resource accounting, scheduling latency, failure
//!   injection) driven in **virtual time** by the discrete-event engine in
//!   [`des`]. This is the documented substitution for the paper's
//!   1000-core Kubernetes/Peloton testbed on this 1-core machine.

pub mod backend;
pub mod des;
pub mod local;
pub mod proc;
pub mod simk8s;

pub use backend::{
    CancelToken, ClusterBackend, JobHandle, JobId, JobSpec, JobStatus, Resources, WorkSpec,
};
pub use des::{EventQueue, SimTime};
pub use local::LocalBackend;
pub use proc::ProcBackend;
pub use simk8s::{NodeSpec, PodSpec, SimCluster, SimClusterConfig};
