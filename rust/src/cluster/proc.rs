//! `ProcBackend` — jobs are real OS child processes of this binary.
//!
//! This realizes the paper's *job-backed process* faithfully on a single
//! machine: every Fiber process is a separate OS process with its own
//! address space, spawned with the same executable (the container-image
//! analogue: identical code + environment for parent and children), tracked
//! by pid, and killable. Workers rendezvous with the leader over TCP
//! ([`crate::comms::rpc`]); see `fiber_cli::worker` for the entrypoint.

use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use super::backend::{ClusterBackend, JobHandle, JobId, JobSpec, JobStatus, WorkSpec};

/// OS-process cluster backend.
pub struct ProcBackend {
    exe: std::path::PathBuf,
    active: Arc<AtomicUsize>,
}

impl ProcBackend {
    /// Spawn children of the current executable (the normal case).
    pub fn new() -> Result<Self> {
        Ok(Self {
            exe: std::env::current_exe().context("current_exe")?,
            active: Arc::new(AtomicUsize::new(0)),
        })
    }

    /// Spawn children of an explicit executable (tests use /bin/sh etc.).
    pub fn with_exe(exe: impl Into<std::path::PathBuf>) -> Self {
        Self {
            exe: exe.into(),
            active: Arc::new(AtomicUsize::new(0)),
        }
    }
}

struct ProcJob {
    id: JobId,
    child: Mutex<Child>,
    done: Mutex<Option<JobStatus>>,
    terminated: std::sync::atomic::AtomicBool,
    active: Arc<AtomicUsize>,
}

impl ProcJob {
    fn poll(&self) -> JobStatus {
        let mut done = self.done.lock().unwrap();
        if let Some(st) = done.clone() {
            return st;
        }
        let mut child = self.child.lock().unwrap();
        match child.try_wait() {
            Ok(Some(status)) => {
                let st = if self.terminated.load(Ordering::SeqCst) {
                    JobStatus::Terminated
                } else if status.success() {
                    JobStatus::Succeeded
                } else {
                    JobStatus::Failed(format!("exit status {status}"))
                };
                *done = Some(st.clone());
                self.active.fetch_sub(1, Ordering::SeqCst);
                st
            }
            Ok(None) => JobStatus::Running,
            Err(e) => {
                let st = JobStatus::Failed(format!("wait error: {e}"));
                *done = Some(st.clone());
                self.active.fetch_sub(1, Ordering::SeqCst);
                st
            }
        }
    }
}

impl JobHandle for ProcJob {
    fn id(&self) -> JobId {
        self.id
    }

    fn status(&self) -> JobStatus {
        self.poll()
    }

    fn wait(&self) -> JobStatus {
        loop {
            let st = self.poll();
            if st.is_terminal() {
                return st;
            }
            std::thread::sleep(Duration::from_millis(5));
        }
    }

    fn terminate(&self) {
        self.terminated.store(true, Ordering::SeqCst);
        let mut child = self.child.lock().unwrap();
        let _ = child.kill();
    }
}

impl ClusterBackend for ProcBackend {
    fn name(&self) -> &'static str {
        "proc"
    }

    fn submit(&self, spec: JobSpec) -> Result<Arc<dyn JobHandle>> {
        let WorkSpec::Command { args } = spec.work else {
            anyhow::bail!("ProcBackend only runs WorkSpec::Command jobs");
        };
        let child = Command::new(&self.exe)
            .args(&args)
            .stdin(Stdio::null())
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .with_context(|| format!("spawn {:?} {:?}", self.exe, args))?;
        self.active.fetch_add(1, Ordering::SeqCst);
        Ok(Arc::new(ProcJob {
            id: JobId::fresh(),
            child: Mutex::new(child),
            done: Mutex::new(None),
            terminated: std::sync::atomic::AtomicBool::new(false),
            active: self.active.clone(),
        }))
    }

    fn active_jobs(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sh() -> ProcBackend {
        ProcBackend::with_exe("/bin/sh")
    }

    #[test]
    fn successful_process() {
        let b = sh();
        let h = b
            .submit(JobSpec::command("ok", vec!["-c".into(), "exit 0".into()]))
            .unwrap();
        assert_eq!(h.wait(), JobStatus::Succeeded);
        assert_eq!(b.active_jobs(), 0);
    }

    #[test]
    fn failing_process() {
        let b = sh();
        let h = b
            .submit(JobSpec::command("bad", vec!["-c".into(), "exit 3".into()]))
            .unwrap();
        match h.wait() {
            JobStatus::Failed(msg) => assert!(msg.contains("3"), "{msg}"),
            other => panic!("expected Failed, got {other:?}"),
        }
    }

    #[test]
    fn terminate_kills() {
        let b = sh();
        let h = b
            .submit(JobSpec::command("sleep", vec!["-c".into(), "sleep 30".into()]))
            .unwrap();
        assert_eq!(h.status(), JobStatus::Running);
        h.terminate();
        assert_eq!(h.wait(), JobStatus::Terminated);
    }

    #[test]
    fn rejects_closure_jobs() {
        let b = sh();
        assert!(b.submit(JobSpec::thread("t", |_| {})).is_err());
    }
}
