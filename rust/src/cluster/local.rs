//! `LocalBackend` — jobs are threads in the current process.
//!
//! This is the "laptop" backend: the same program that later runs on a
//! cluster runs here with zero setup, the property the paper's API design
//! optimises for. Panics in job closures are caught and surface as
//! [`JobStatus::Failed`], which is what drives pool worker replacement in
//! the fault-tolerance tests.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};

use anyhow::Result;

use super::backend::{
    CancelToken, ClusterBackend, JobHandle, JobId, JobSpec, JobStatus, WorkSpec,
};

/// Thread-backed cluster backend.
#[derive(Default)]
pub struct LocalBackend {
    active: Arc<AtomicUsize>,
}

impl LocalBackend {
    pub fn new() -> Self {
        Self::default()
    }
}

struct LocalJob {
    id: JobId,
    state: Arc<(Mutex<JobStatus>, Condvar)>,
    token: CancelToken,
}

impl JobHandle for LocalJob {
    fn id(&self) -> JobId {
        self.id
    }

    fn status(&self) -> JobStatus {
        self.state.0.lock().unwrap().clone()
    }

    fn wait(&self) -> JobStatus {
        let (lock, cv) = &*self.state;
        let mut st = lock.lock().unwrap();
        while !st.is_terminal() {
            st = cv.wait(st).unwrap();
        }
        st.clone()
    }

    fn terminate(&self) {
        self.token.cancel();
    }
}

impl ClusterBackend for LocalBackend {
    fn name(&self) -> &'static str {
        "local"
    }

    fn submit(&self, spec: JobSpec) -> Result<Arc<dyn JobHandle>> {
        let WorkSpec::Closure(f) = spec.work else {
            anyhow::bail!("LocalBackend only runs WorkSpec::Closure jobs");
        };
        let id = JobId::fresh();
        let state = Arc::new((Mutex::new(JobStatus::Running), Condvar::new()));
        let token = CancelToken::new();
        let job = Arc::new(LocalJob {
            id,
            state: state.clone(),
            token: token.clone(),
        });
        let active = self.active.clone();
        active.fetch_add(1, Ordering::SeqCst);
        std::thread::Builder::new()
            .name(format!("{}-{id}", spec.name))
            .spawn(move || {
                let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    f(token.clone())
                }));
                let final_status = match result {
                    Ok(()) if token.is_cancelled() => JobStatus::Terminated,
                    Ok(()) => JobStatus::Succeeded,
                    Err(p) => JobStatus::Failed(panic_msg(&*p)),
                };
                // Decrement before notifying so `wait()`-then-`active_jobs()`
                // observes a consistent count.
                active.fetch_sub(1, Ordering::SeqCst);
                let (lock, cv) = &*state;
                *lock.lock().unwrap() = final_status;
                cv.notify_all();
            })?;
        Ok(job)
    }

    fn active_jobs(&self) -> usize {
        self.active.load(Ordering::SeqCst)
    }
}

fn panic_msg(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "panic (non-string payload)".to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn successful_job() {
        let b = LocalBackend::new();
        let h = b
            .submit(JobSpec::thread("t", |_tok| {
                std::thread::sleep(Duration::from_millis(5));
            }))
            .unwrap();
        assert_eq!(h.wait(), JobStatus::Succeeded);
        assert_eq!(b.active_jobs(), 0);
    }

    #[test]
    fn panicking_job_reports_failed() {
        let b = LocalBackend::new();
        let h = b
            .submit(JobSpec::thread("boom", |_tok| panic!("exploded")))
            .unwrap();
        match h.wait() {
            JobStatus::Failed(msg) => assert!(msg.contains("exploded")),
            other => panic!("expected Failed, got {other:?}"),
        }
    }

    #[test]
    fn terminate_is_cooperative() {
        let b = LocalBackend::new();
        let h = b
            .submit(JobSpec::thread("loop", |tok| {
                while !tok.is_cancelled() {
                    std::thread::sleep(Duration::from_millis(1));
                }
            }))
            .unwrap();
        assert_eq!(h.status(), JobStatus::Running);
        h.terminate();
        assert_eq!(h.wait(), JobStatus::Terminated);
    }

    #[test]
    fn rejects_command_jobs() {
        let b = LocalBackend::new();
        assert!(b
            .submit(JobSpec::command("c", vec!["worker".into()]))
            .is_err());
    }

    #[test]
    fn active_jobs_counts() {
        let b = LocalBackend::new();
        let hs: Vec<_> = (0..3)
            .map(|_| {
                b.submit(JobSpec::thread("w", |tok| {
                    while !tok.is_cancelled() {
                        std::thread::sleep(Duration::from_millis(1));
                    }
                }))
                .unwrap()
            })
            .collect();
        std::thread::sleep(Duration::from_millis(10));
        assert_eq!(b.active_jobs(), 3);
        for h in &hs {
            h.terminate();
        }
        for h in &hs {
            h.wait();
        }
        assert_eq!(b.active_jobs(), 0);
    }
}
