//! The `ClusterBackend` contract shared by all real-execution backends.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;

/// Globally unique job id within this leader.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct JobId(pub u64);

static NEXT_JOB_ID: AtomicU64 = AtomicU64::new(1);

impl JobId {
    pub fn fresh() -> Self {
        JobId(NEXT_JOB_ID.fetch_add(1, Ordering::Relaxed))
    }
}

impl std::fmt::Display for JobId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "job-{}", self.0)
    }
}

/// Resource request carried by every job (the cluster layer does the
/// accounting; local backends ignore it but keep it for parity with the
/// simulated cluster).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Resources {
    pub cpu_milli: u32,
    pub mem_mb: u32,
    pub gpu: u32,
}

impl Default for Resources {
    fn default() -> Self {
        Self {
            cpu_milli: 1000,
            mem_mb: 256,
            gpu: 0,
        }
    }
}

/// Cooperative cancellation token handed to thread-backed jobs.
///
/// Real cluster managers deliver SIGTERM; a thread cannot be killed safely,
/// so thread jobs poll this token at loop boundaries — the same contract k8s
/// pods have with graceful termination.
#[derive(Clone, Debug, Default)]
pub struct CancelToken {
    flag: Arc<AtomicBool>,
}

impl CancelToken {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn cancel(&self) {
        self.flag.store(true, Ordering::SeqCst);
    }

    pub fn is_cancelled(&self) -> bool {
        self.flag.load(Ordering::SeqCst)
    }
}

/// What a job runs.
pub enum WorkSpec {
    /// A closure executed on a dedicated thread (LocalBackend). The closure
    /// must poll the [`CancelToken`] to honour termination.
    Closure(Box<dyn FnOnce(CancelToken) + Send + 'static>),
    /// `fiber-cli <args…>` as a child OS process (ProcBackend). The leader
    /// address etc. are passed through args.
    Command { args: Vec<String> },
}

impl std::fmt::Debug for WorkSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WorkSpec::Closure(_) => write!(f, "WorkSpec::Closure"),
            WorkSpec::Command { args } => write!(f, "WorkSpec::Command({args:?})"),
        }
    }
}

/// A job submission: name + resources + payload, mirroring a pod spec.
#[derive(Debug)]
pub struct JobSpec {
    pub name: String,
    pub resources: Resources,
    pub work: WorkSpec,
}

impl JobSpec {
    pub fn thread(
        name: impl Into<String>,
        f: impl FnOnce(CancelToken) + Send + 'static,
    ) -> Self {
        Self {
            name: name.into(),
            resources: Resources::default(),
            work: WorkSpec::Closure(Box::new(f)),
        }
    }

    pub fn command(name: impl Into<String>, args: Vec<String>) -> Self {
        Self {
            name: name.into(),
            resources: Resources::default(),
            work: WorkSpec::Command { args },
        }
    }

    pub fn with_resources(mut self, r: Resources) -> Self {
        self.resources = r;
        self
    }
}

/// Lifecycle state of a job, as tracked by its backend.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum JobStatus {
    Pending,
    Running,
    Succeeded,
    /// The job failed (panic, nonzero exit, node failure, …).
    Failed(String),
    /// The job was terminated by request.
    Terminated,
}

impl JobStatus {
    pub fn is_terminal(&self) -> bool {
        !matches!(self, JobStatus::Pending | JobStatus::Running)
    }
}

/// Handle to a submitted job.
pub trait JobHandle: Send + Sync {
    fn id(&self) -> JobId;
    fn status(&self) -> JobStatus;
    /// Block until the job reaches a terminal state.
    fn wait(&self) -> JobStatus;
    /// Request termination (idempotent, asynchronous).
    fn terminate(&self);
}

/// A backend that can create/track/terminate jobs on some cluster manager.
pub trait ClusterBackend: Send + Sync {
    fn name(&self) -> &'static str;
    fn submit(&self, spec: JobSpec) -> anyhow::Result<Arc<dyn JobHandle>>;
    /// Number of jobs currently in a non-terminal state.
    fn active_jobs(&self) -> usize;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_ids_are_unique() {
        let a = JobId::fresh();
        let b = JobId::fresh();
        assert_ne!(a, b);
    }

    #[test]
    fn cancel_token_shared() {
        let t = CancelToken::new();
        let t2 = t.clone();
        assert!(!t.is_cancelled());
        t2.cancel();
        assert!(t.is_cancelled());
    }

    #[test]
    fn status_terminality() {
        assert!(!JobStatus::Pending.is_terminal());
        assert!(!JobStatus::Running.is_terminal());
        assert!(JobStatus::Succeeded.is_terminal());
        assert!(JobStatus::Failed("x".into()).is_terminal());
        assert!(JobStatus::Terminated.is_terminal());
    }
}
