//! Vectorized environments over pipes to fixed worker processes.
//!
//! The pipe pattern from the paper's code example 3: "Each simulator is
//! mapped to a fixed process so that worker processes can maintain their
//! internal state after each step." Each worker job hosts a block of
//! environments; the leader scatters actions and gathers transitions every
//! step, in order, over [`crate::api::pipe`].

use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use crate::api::pipe::{Pipe, PipeEnd};
use crate::api::process::FiberProcess;
use crate::api::queue::QueueHub;
use crate::cluster::ClusterBackend;
use crate::envs::{Action, Breakout, Env};
use crate::wire::{self, Decode, Encode};

/// Leader → worker command.
enum Cmd {
    /// Reset all envs in this worker with the given base seed.
    Reset(u64),
    /// Step each env with its action index.
    Step(Vec<u32>),
    /// Shut down.
    Close,
}

impl Encode for Cmd {
    fn encode(&self, buf: &mut Vec<u8>) {
        match self {
            Cmd::Reset(seed) => {
                buf.push(0);
                seed.encode(buf);
            }
            Cmd::Step(actions) => {
                buf.push(1);
                actions.encode(buf);
            }
            Cmd::Close => buf.push(2),
        }
    }
}

impl Decode for Cmd {
    fn decode(r: &mut wire::Reader<'_>) -> Result<Self, wire::WireError> {
        match u8::decode(r)? {
            0 => Ok(Cmd::Reset(u64::decode(r)?)),
            1 => Ok(Cmd::Step(Vec::<u32>::decode(r)?)),
            2 => Ok(Cmd::Close),
            t => Err(wire::WireError::BadTag(t as u32)),
        }
    }
}

/// Worker → leader reply: per-env (obs, reward, done) after auto-reset.
type Reply = (Vec<Vec<f32>>, Vec<f32>, Vec<u8>);

/// A block of Breakout environments spread over worker processes.
pub struct VecEnv {
    pipes: Vec<PipeEnd<Cmd, Reply>>,
    workers: Vec<FiberProcess>,
    n_envs: usize,
    per_worker: Vec<usize>,
    timeout: Duration,
}

impl VecEnv {
    /// `n_envs` environments over `n_workers` worker jobs on `backend`.
    pub fn breakout(
        backend: &dyn ClusterBackend,
        hub: &Arc<QueueHub>,
        n_envs: usize,
        n_workers: usize,
    ) -> Result<VecEnv> {
        let n_workers = n_workers.clamp(1, n_envs.max(1));
        let base = n_envs / n_workers;
        let extra = n_envs % n_workers;
        let mut pipes = Vec::with_capacity(n_workers);
        let mut workers = Vec::with_capacity(n_workers);
        let mut per_worker = Vec::with_capacity(n_workers);
        // Unique instance id: pipe names must not collide when several
        // VecEnvs (sequential or concurrent) share one hub.
        static INSTANCE: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);
        let inst = INSTANCE.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        for w in 0..n_workers {
            let k = base + usize::from(w < extra);
            per_worker.push(k);
            let name = format!("vecenv-{inst}-{w}");
            let (leader_end, worker_end) = Pipe::local::<Cmd, Reply>(hub, &name);
            pipes.push(leader_end);
            let proc = FiberProcess::spawn(backend, name, move |token| {
                env_worker_loop(worker_end, k, &token)
            })?;
            workers.push(proc);
        }
        Ok(VecEnv {
            pipes,
            workers,
            n_envs,
            per_worker,
            timeout: Duration::from_secs(30),
        })
    }

    pub fn n_envs(&self) -> usize {
        self.n_envs
    }

    pub fn n_workers(&self) -> usize {
        self.workers.len()
    }

    /// Reset every environment; returns the initial observations.
    pub fn reset(&self, seed: u64) -> Result<Vec<Vec<f32>>> {
        for (w, pipe) in self.pipes.iter().enumerate() {
            pipe.send(&Cmd::Reset(seed.wrapping_add(w as u64 * 9973)))?;
        }
        let mut obs = Vec::with_capacity(self.n_envs);
        for pipe in &self.pipes {
            let (o, _, _) = pipe
                .recv(self.timeout)?
                .context("env worker dropped during reset")?;
            obs.extend(o);
        }
        Ok(obs)
    }

    /// Step every environment. Done envs auto-reset (obs is the new
    /// episode's first observation; `done=1` flags the boundary).
    pub fn step(&self, actions: &[usize]) -> Result<(Vec<Vec<f32>>, Vec<f32>, Vec<u8>)> {
        anyhow::ensure!(actions.len() == self.n_envs, "need one action per env");
        let mut start = 0;
        for (w, pipe) in self.pipes.iter().enumerate() {
            let k = self.per_worker[w];
            let slice: Vec<u32> = actions[start..start + k].iter().map(|&a| a as u32).collect();
            pipe.send(&Cmd::Step(slice))?;
            start += k;
        }
        let mut obs = Vec::with_capacity(self.n_envs);
        let mut rewards = Vec::with_capacity(self.n_envs);
        let mut dones = Vec::with_capacity(self.n_envs);
        for pipe in &self.pipes {
            let (o, r, d) = pipe
                .recv(self.timeout)?
                .context("env worker dropped during step")?;
            obs.extend(o);
            rewards.extend(r);
            dones.extend(d);
        }
        Ok((obs, rewards, dones))
    }

    /// Shut the workers down.
    pub fn close(&self) {
        for pipe in &self.pipes {
            let _ = pipe.send(&Cmd::Close);
        }
        for w in &self.workers {
            w.join();
        }
    }
}

impl Drop for VecEnv {
    fn drop(&mut self) {
        for pipe in &self.pipes {
            let _ = pipe.send(&Cmd::Close);
        }
        for w in &self.workers {
            w.terminate();
        }
    }
}

fn env_worker_loop(
    pipe: PipeEnd<Reply, Cmd>,
    k: usize,
    token: &crate::cluster::CancelToken,
) {
    let mut envs: Vec<Breakout> = (0..k).map(|_| Breakout::new()).collect();
    let mut episode: Vec<u64> = vec![0; k];
    let mut base_seed = 0u64;
    loop {
        if token.is_cancelled() {
            return;
        }
        let cmd = match pipe.recv(Duration::from_millis(200)) {
            Ok(Some(c)) => c,
            Ok(None) => continue,
            Err(_) => return,
        };
        match cmd {
            Cmd::Reset(seed) => {
                base_seed = seed;
                let mut obs = Vec::with_capacity(k);
                for (i, env) in envs.iter_mut().enumerate() {
                    episode[i] = 0;
                    obs.push(env.reset(seed.wrapping_add(i as u64)));
                }
                if pipe.send(&(obs, vec![0.0; k], vec![0u8; k])).is_err() {
                    return;
                }
            }
            Cmd::Step(actions) => {
                let mut obs = Vec::with_capacity(k);
                let mut rewards = Vec::with_capacity(k);
                let mut dones = Vec::with_capacity(k);
                for (i, env) in envs.iter_mut().enumerate() {
                    let a = Action::Discrete(actions.get(i).map(|&a| a as usize).unwrap_or(0));
                    let r = env.step(&a);
                    rewards.push(r.reward);
                    dones.push(u8::from(r.done));
                    if r.done {
                        episode[i] += 1;
                        obs.push(env.reset(
                            base_seed
                                .wrapping_add(i as u64)
                                .wrapping_add(episode[i] * 7919),
                        ));
                    } else {
                        obs.push(r.obs);
                    }
                }
                if pipe.send(&(obs, rewards, dones)).is_err() {
                    return;
                }
            }
            Cmd::Close => return,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::LocalBackend;

    fn make(n_envs: usize, n_workers: usize) -> (VecEnv, Arc<QueueHub>) {
        let hub = QueueHub::new();
        let be = LocalBackend::new();
        let ve = VecEnv::breakout(&be, &hub, n_envs, n_workers).unwrap();
        (ve, hub)
    }

    #[test]
    fn reset_returns_all_obs() {
        let (ve, _hub) = make(6, 2);
        let obs = ve.reset(1).unwrap();
        assert_eq!(obs.len(), 6);
        assert!(obs.iter().all(|o| o.len() == 32));
        ve.close();
    }

    #[test]
    fn step_round_trips() {
        let (ve, _hub) = make(5, 3);
        ve.reset(2).unwrap();
        for _ in 0..20 {
            let (obs, rewards, dones) = ve.step(&vec![1; 5]).unwrap();
            assert_eq!(obs.len(), 5);
            assert_eq!(rewards.len(), 5);
            assert_eq!(dones.len(), 5);
        }
        ve.close();
    }

    #[test]
    fn uneven_split_covers_all_envs() {
        let (ve, _hub) = make(7, 3);
        assert_eq!(ve.n_envs(), 7);
        assert_eq!(ve.n_workers(), 3);
        let obs = ve.reset(3).unwrap();
        assert_eq!(obs.len(), 7);
        ve.close();
    }

    #[test]
    fn wrong_action_count_is_error() {
        let (ve, _hub) = make(4, 2);
        ve.reset(4).unwrap();
        assert!(ve.step(&vec![0; 3]).is_err());
        ve.close();
    }

    #[test]
    fn envs_auto_reset_and_continue() {
        let (ve, _hub) = make(2, 1);
        ve.reset(5).unwrap();
        // Fire + noop forever: episodes will end (lives run out) and the
        // vec env must keep stepping without error.
        let mut saw_done = false;
        for _ in 0..30_000 {
            let (_, _, dones) = ve.step(&vec![1, 1]).unwrap();
            if dones.iter().any(|&d| d == 1) {
                saw_done = true;
                break;
            }
        }
        assert!(saw_done, "episodes should terminate under fire-only policy");
        // Continue stepping after the auto-reset.
        for _ in 0..10 {
            ve.step(&vec![0, 0]).unwrap();
        }
        ve.close();
    }
}
