//! Pure-Rust MLP forward passes with a flat parameter layout shared with
//! the JAX models.
//!
//! Layout contract (must match `python/compile/model.py`): parameters are
//! concatenated layer by layer as `W` then `b`, with `W` stored row-major
//! as `(in, out)` — `flat[i*out + j] = W[i][j]`, forward `y = x·W + b`.
//! `python/tests/test_model.py` and the Rust integration tests check the
//! two implementations agree numerically on random inputs.

use crate::util::Rng;

/// Walker policy architecture: 24 → 40 → 40 → 4, tanh everywhere.
pub const WALKER_SIZES: [usize; 4] = [24, 40, 40, 4];

/// PPO trunk: 32 → 64 → 64, with a 4-logit policy head + 1 value head.
pub const PPO_TRUNK: [usize; 3] = [32, 64, 64];
pub const PPO_ACTIONS: usize = 4;

/// A dense tanh MLP (tanh on the output too — torque actions in [-1, 1]).
#[derive(Clone, Debug)]
pub struct Mlp {
    pub sizes: Vec<usize>,
    /// Flat parameters in the shared layout.
    pub params: Vec<f32>,
}

/// Number of parameters for a layer-size list.
pub fn param_count(sizes: &[usize]) -> usize {
    sizes.windows(2).map(|w| w[0] * w[1] + w[1]).sum()
}

impl Mlp {
    /// Zero-initialised network.
    pub fn zeros(sizes: &[usize]) -> Self {
        Self {
            sizes: sizes.to_vec(),
            params: vec![0.0; param_count(sizes)],
        }
    }

    /// He-style random init (matching model.py's initializer scale).
    pub fn init(sizes: &[usize], rng: &mut Rng) -> Self {
        let mut params = Vec::with_capacity(param_count(sizes));
        for w in sizes.windows(2) {
            let (fan_in, fan_out) = (w[0], w[1]);
            let scale = (2.0 / fan_in as f64).sqrt();
            for _ in 0..fan_in * fan_out {
                params.push((rng.normal() * scale) as f32);
            }
            for _ in 0..fan_out {
                params.push(0.0);
            }
        }
        Self {
            sizes: sizes.to_vec(),
            params,
        }
    }

    /// The walker policy network.
    pub fn walker_policy(rng: &mut Rng) -> Self {
        Self::init(&WALKER_SIZES, rng)
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Forward pass for a single observation.
    pub fn forward(&self, x: &[f32]) -> Vec<f32> {
        assert_eq!(x.len(), self.sizes[0], "input dim");
        let mut h = x.to_vec();
        let mut off = 0;
        for (li, w) in self.sizes.windows(2).enumerate() {
            let (n_in, n_out) = (w[0], w[1]);
            let wmat = &self.params[off..off + n_in * n_out];
            let bias = &self.params[off + n_in * n_out..off + n_in * n_out + n_out];
            off += n_in * n_out + n_out;
            let mut out = bias.to_vec();
            for (i, &xi) in h.iter().enumerate() {
                if xi != 0.0 {
                    let row = &wmat[i * n_out..(i + 1) * n_out];
                    for (o, &wv) in out.iter_mut().zip(row) {
                        *o += xi * wv;
                    }
                }
            }
            let last = li == self.sizes.len() - 2;
            for o in out.iter_mut() {
                *o = o.tanh();
            }
            let _ = last; // tanh on every layer, including output
            h = out;
        }
        h
    }

    /// Apply a perturbation: `self.params + sigma * noise`.
    pub fn perturbed(&self, noise: &[f32], sigma: f32) -> Mlp {
        assert_eq!(noise.len(), self.params.len());
        let params = self
            .params
            .iter()
            .zip(noise)
            .map(|(p, n)| p + sigma * n)
            .collect();
        Mlp {
            sizes: self.sizes.clone(),
            params,
        }
    }
}

/// The PPO network: shared tanh trunk, linear policy logits + value head.
///
/// Flat layout: trunk W1,b1,W2,b2 then policy Wp,bp then value Wv,bv.
#[derive(Clone, Debug)]
pub struct PpoNet {
    pub params: Vec<f32>,
}

/// PPO parameter count (trunk + heads).
pub fn ppo_param_count() -> usize {
    let t = &PPO_TRUNK;
    let trunk: usize = t.windows(2).map(|w| w[0] * w[1] + w[1]).sum();
    let h = *t.last().unwrap();
    trunk + (h * PPO_ACTIONS + PPO_ACTIONS) + (h + 1)
}

impl PpoNet {
    pub fn init(rng: &mut Rng) -> Self {
        let mut params = Vec::with_capacity(ppo_param_count());
        for w in PPO_TRUNK.windows(2) {
            let scale = (2.0 / w[0] as f64).sqrt();
            for _ in 0..w[0] * w[1] {
                params.push((rng.normal() * scale) as f32);
            }
            for _ in 0..w[1] {
                params.push(0.0);
            }
        }
        let h = *PPO_TRUNK.last().unwrap();
        // Small policy head (standard PPO init), tiny value head.
        let scale = 0.01;
        for _ in 0..h * PPO_ACTIONS {
            params.push((rng.normal() * scale) as f32);
        }
        for _ in 0..PPO_ACTIONS {
            params.push(0.0);
        }
        for _ in 0..h {
            params.push((rng.normal() * 0.1) as f32);
        }
        params.push(0.0);
        Self { params }
    }

    pub fn n_params(&self) -> usize {
        self.params.len()
    }

    /// Forward one observation → (logits, value). Reference implementation
    /// for tests; the hot path uses the `ppo_act` artifact.
    pub fn forward(&self, x: &[f32]) -> (Vec<f32>, f32) {
        assert_eq!(x.len(), PPO_TRUNK[0]);
        let mut h = x.to_vec();
        let mut off = 0;
        for w in PPO_TRUNK.windows(2) {
            let (n_in, n_out) = (w[0], w[1]);
            let wmat = &self.params[off..off + n_in * n_out];
            let bias = &self.params[off + n_in * n_out..off + n_in * n_out + n_out];
            off += n_in * n_out + n_out;
            let mut out = bias.to_vec();
            for (i, &xi) in h.iter().enumerate() {
                let row = &wmat[i * n_out..(i + 1) * n_out];
                for (o, &wv) in out.iter_mut().zip(row) {
                    *o += xi * wv;
                }
            }
            for o in out.iter_mut() {
                *o = o.tanh();
            }
            h = out;
        }
        let hn = h.len();
        let wp = &self.params[off..off + hn * PPO_ACTIONS];
        let bp = &self.params[off + hn * PPO_ACTIONS..off + hn * PPO_ACTIONS + PPO_ACTIONS];
        off += hn * PPO_ACTIONS + PPO_ACTIONS;
        let mut logits = bp.to_vec();
        for (i, &hi) in h.iter().enumerate() {
            let row = &wp[i * PPO_ACTIONS..(i + 1) * PPO_ACTIONS];
            for (l, &wv) in logits.iter_mut().zip(row) {
                *l += hi * wv;
            }
        }
        let wv = &self.params[off..off + hn];
        let bv = self.params[off + hn];
        let value = h.iter().zip(wv).map(|(a, b)| a * b).sum::<f32>() + bv;
        (logits, value)
    }
}

/// Numerically-stable log-softmax.
pub fn log_softmax(logits: &[f32]) -> Vec<f32> {
    let m = logits.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let lse = logits.iter().map(|l| (l - m).exp()).sum::<f32>().ln() + m;
    logits.iter().map(|l| l - lse).collect()
}

/// Sample from categorical logits.
pub fn sample_logits(logits: &[f32], rng: &mut Rng) -> usize {
    let lp = log_softmax(logits);
    let u = rng.f64() as f32;
    let mut acc = 0.0;
    for (i, l) in lp.iter().enumerate() {
        acc += l.exp();
        if u < acc {
            return i;
        }
    }
    lp.len() - 1
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_count_walker() {
        assert_eq!(param_count(&WALKER_SIZES), 24 * 40 + 40 + 40 * 40 + 40 + 40 * 4 + 4);
        assert_eq!(param_count(&WALKER_SIZES), 2804);
    }

    #[test]
    fn ppo_param_count_value() {
        assert_eq!(
            ppo_param_count(),
            32 * 64 + 64 + 64 * 64 + 64 + 64 * 4 + 4 + 64 + 1
        );
        assert_eq!(ppo_param_count(), 6597);
    }

    #[test]
    fn forward_bounded_by_tanh() {
        let mut rng = Rng::new(1);
        let net = Mlp::walker_policy(&mut rng);
        let x: Vec<f32> = (0..24).map(|i| (i as f32 * 0.37).sin()).collect();
        let y = net.forward(&x);
        assert_eq!(y.len(), 4);
        for v in &y {
            assert!(v.abs() <= 1.0);
        }
    }

    #[test]
    fn zero_net_outputs_zero() {
        let net = Mlp::zeros(&WALKER_SIZES);
        let y = net.forward(&vec![1.0; 24]);
        assert!(y.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn perturbation_changes_output() {
        let mut rng = Rng::new(2);
        let net = Mlp::walker_policy(&mut rng);
        let noise: Vec<f32> = (0..net.n_params()).map(|i| ((i * 31) % 7) as f32 - 3.0).collect();
        let net2 = net.perturbed(&noise, 0.1);
        let x = vec![0.3; 24];
        assert_ne!(net.forward(&x), net2.forward(&x));
        // sigma = 0 is the identity.
        let net3 = net.perturbed(&noise, 0.0);
        assert_eq!(net.forward(&x), net3.forward(&x));
    }

    #[test]
    fn ppo_forward_shapes() {
        let mut rng = Rng::new(3);
        let net = PpoNet::init(&mut rng);
        let x: Vec<f32> = (0..32).map(|i| (i as f32 * 0.1).cos()).collect();
        let (logits, v) = net.forward(&x);
        assert_eq!(logits.len(), 4);
        assert!(v.is_finite());
    }

    #[test]
    fn log_softmax_normalises() {
        let lp = log_softmax(&[1.0, 2.0, 3.0]);
        let total: f32 = lp.iter().map(|l| l.exp()).sum();
        assert!((total - 1.0).abs() < 1e-5);
        assert!(lp.iter().all(|&l| l <= 0.0));
    }

    #[test]
    fn sample_logits_respects_distribution() {
        let mut rng = Rng::new(4);
        // Strongly peaked logits: argmax should dominate.
        let mut counts = [0usize; 3];
        for _ in 0..1000 {
            counts[sample_logits(&[0.0, 5.0, 0.0], &mut rng)] += 1;
        }
        assert!(counts[1] > 950, "{counts:?}");
    }
}
