//! The paper's workload algorithms, built **on the Fiber API**.
//!
//! * [`nn`] — a minimal MLP whose flat parameter layout matches the L2 JAX
//!   models bit-for-bit (`python/compile/model.py`), so workers can run
//!   policies in pure Rust while the leader updates parameters through the
//!   AOT-compiled artifacts.
//! * [`noise`] — the shared noise table of Salimans et al. (2017): every
//!   process regenerates the same table from a seed, so only *indices* move
//!   over the network.
//! * [`es`] — Evolution Strategies over a `fiber::Pool` (code example 2 in
//!   the paper): stateless rollouts fan out to workers, the parameter
//!   update runs through the `es_update` PJRT artifact. The decentralized
//!   [`es::EsRingNode`] variant replaces the leader's `O(pop·θ)` combine
//!   with an `O(θ)` ring allreduce over [`crate::ring`].
//! * [`vec_env`] — vectorized environments over pipes to fixed worker
//!   processes (ordered, stateful — the pipe pattern from code example 3).
//! * [`ppo`] — PPO with GAE; action selection and the clipped-surrogate
//!   Adam update both run through PJRT artifacts (`ppo_act`, `ppo_update`).

pub mod es;
pub mod nn;
pub mod noise;
pub mod ppo;
pub mod vec_env;

pub use es::{EsConfig, EsMaster, EsRingNode};
pub use nn::{Mlp, PpoNet};
pub use noise::NoiseTable;
pub use ppo::{PpoConfig, PpoTrainer};
pub use vec_env::VecEnv;
